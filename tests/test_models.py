"""Model zoo: per-arch smoke tests + numerical equivalence properties."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS
from repro.configs.base import RunConfig, ShapeConfig, smoke_model
from repro.models import attention as attn
from repro.models import model as M
from repro.models import ssm
from repro.moe import moe_layer

SHAPE = ShapeConfig("smoke", 32, 2, "train")
KEY = jax.random.PRNGKey(0)


def _batch(cfg, key, s=32, b=2):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.encoder_layers:
        batch["frames"] = jax.random.normal(
            key, (b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    if cfg.frontend == "patch":
        batch["tokens"] = toks[:, :s - cfg.frontend_seq]
        batch["patch_embeds"] = jax.random.normal(
            key, (b, cfg.frontend_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_arch_smoke_forward_and_grad(arch):
    """Reduced same-family config: one forward + one grad step on CPU,
    output shapes correct, no NaNs (assignment requirement)."""
    cfg = smoke_model(ARCHS[arch])
    rcfg = RunConfig(model=cfg, shape=SHAPE, remat="none")
    params, _ = M.init(cfg, KEY)
    batch = _batch(cfg, KEY)
    logits, _, _ = M._forward(cfg, rcfg, params, batch, mode="train")
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.padded_vocab
    assert logits.shape[1] == 32
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: M.loss_fn(cfg, rcfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss))
    for g in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(g))), "NaN/inf grad"


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "gemma2-27b", "mamba2-780m",
                                  "jamba-1.5-large-398b",
                                  "qwen3-moe-235b-a22b"])
def test_decode_matches_forward(arch):
    """Stepwise decode (KV cache / ring buffers / SSM states) reproduces the
    teacher-forced forward logits exactly.  Both sides run inference
    semantics (prefill): MoE capacity dropping is train-only, so a batched
    forward and a stepwise decode see identical dropless routing."""
    cfg = smoke_model(ARCHS[arch])
    rcfg = RunConfig(model=cfg, shape=SHAPE, remat="none")
    params, _ = M.init(cfg, KEY)
    s = 24
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, s + 1), 0,
                              cfg.vocab_size)
    logits_full, _, _ = M._forward(cfg, rcfg, params, {"tokens": toks},
                                   mode="prefill")
    cache = M.init_cache(cfg, rcfg, 2, s + 8)
    lg = None
    for t in range(s + 1):
        lg, cache = M.decode_step(cfg, rcfg, params, cache,
                                  toks[:, t:t + 1], jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32), atol=2e-2, rtol=2e-2)


def test_prefill_then_decode_whisper():
    """Enc-dec path: prefill computes cross-KV once; decode continues."""
    from repro.serve.serve_step import generate
    cfg = smoke_model(ARCHS["whisper-small"])
    rcfg = RunConfig(model=cfg, shape=SHAPE, remat="none")
    params, _ = M.init(cfg, KEY)
    batch = _batch(cfg, KEY, s=16)
    del batch["labels"]
    toks = generate(cfg, rcfg, params, batch, max_new_tokens=4)
    assert toks.shape == (2, 4)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.padded_vocab)))


def test_flash_attention_equals_direct():
    b, s, h, d = 2, 256, 4, 16
    key = jax.random.PRNGKey(2)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (b, s, h, d),
                                 jnp.float32) for i in range(3))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    direct = attn.attention_core(q, k, v, pos, pos, force_direct=True)
    chunked = attn.attention_core(q, k, v, pos, pos, chunk=64)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked),
                               atol=2e-5, rtol=2e-5)
    skip = attn.attention_core(q, k, v, pos, pos, chunk=64, causal_skip=True)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(skip),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_local_window():
    b, s, h, d = 1, 128, 2, 8
    key = jax.random.PRNGKey(3)
    q, k, v = (jax.random.normal(jax.random.fold_in(key, i), (b, s, h, d),
                                 jnp.float32) for i in range(3))
    pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    direct = attn.attention_core(q, k, v, pos, pos, window=16,
                                 force_direct=True)
    chunked = attn.attention_core(q, k, v, pos, pos, window=16, chunk=32)
    np.testing.assert_allclose(np.asarray(direct), np.asarray(chunked),
                               atol=2e-5, rtol=2e-5)


def test_ssd_chunked_equals_sequential():
    cfg = smoke_model(ARCHS["mamba2-780m"])
    p, _ = ssm.ssm_init(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 24, cfg.d_model),
                          jnp.float32)
    y_chunk, _ = ssm.ssm_apply(cfg, p, x, chunk=8)
    y_ref = ssm.ssm_ref(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)


def test_ssd_pallas_path_matches_einsum():
    cfg = smoke_model(ARCHS["mamba2-780m"])
    p, _ = ssm.ssm_init(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 32, cfg.d_model),
                          jnp.float32)
    y0, _ = ssm.ssm_apply(cfg, p, x, chunk=16, use_pallas=False)
    y1, _ = ssm.ssm_apply(cfg, p, x, chunk=16, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-4)


def test_moe_aam_equals_dense():
    """The coalesced AAM dispatch must agree exactly with the GShard
    one-hot dispatch (same arrival-order capacity priority)."""
    cfg = smoke_model(ARCHS["qwen3-moe-235b-a22b"])
    p, _ = moe_layer.moe_init(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(6), (64, cfg.d_model),
                          jnp.float32)
    ya, ma = moe_layer.moe_apply_aam(cfg, p, x)
    yd, md = moe_layer.moe_apply_dense(cfg, p, x)
    np.testing.assert_allclose(np.asarray(ya), np.asarray(yd), atol=1e-5)
    assert int(ma["moe_dropped"]) == int(md["moe_dropped"])


def test_param_counts_match_published():
    expect = {"jamba-1.5-large-398b": 398, "granite-34b": 34,
              "gemma2-27b": 27.2, "deepseek-67b": 67.4, "qwen2-1.5b": 1.5,
              "phi3.5-moe-42b-a6.6b": 41.9, "qwen3-moe-235b-a22b": 235,
              "mamba2-780m": 0.78, "pixtral-12b": 12.2,
              "whisper-small": 0.24}
    for name, bn in expect.items():
        got = ARCHS[name].param_count() / 1e9
        assert abs(got - bn) / bn < 0.12, (name, got, bn)


def test_logit_softcap_and_vocab_mask():
    cfg = smoke_model(ARCHS["gemma2-27b"])
    rcfg = RunConfig(model=cfg, shape=SHAPE, remat="none")
    params, _ = M.init(cfg, KEY)
    batch = _batch(cfg, KEY)
    logits, _, _ = M._forward(cfg, rcfg, params, batch, mode="train")
    live = logits[..., :cfg.vocab_size].astype(jnp.float32)
    pad = logits[..., cfg.vocab_size:].astype(jnp.float32)
    assert float(jnp.max(jnp.abs(live))) <= cfg.logit_softcap + 1e-3
    assert float(jnp.max(pad)) < -1e29
