"""Distributed tests on 8 forced host devices (subprocess: the dry-run is
the ONLY place allowed to force 512; tests use their own interpreter so the
main test session keeps 1 device).

The parity matrix runs every algorithm ported to the shared
``run_distributed`` harness against its single-shard and numpy-reference
results, for both ``coarse`` and ``pallas`` commit specs, on a kronecker
and a uniform random graph — with a coalescing capacity small enough to
force sub-round requeue, and asserting the harness ``delivered_all``
anti-wedge flag every time."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow   # spawns 8-device subprocesses

REPO = Path(__file__).resolve().parent.parent


def _tail(x, n):
    if x is None:
        return ""
    if isinstance(x, bytes):
        x = x.decode(errors="replace")
    return x[-n:]


def run_devices(code: str, n: int = 8, timeout: int = 900) -> dict:
    env = dict(os.environ)
    flags = f"--xla_force_host_platform_device_count={n}"
    extra = env.get("REPRO_XLA_EXTRA")      # tier2 pins a fixed flag matrix
    env["XLA_FLAGS"] = f"{flags} {extra}" if extra else flags
    env["PYTHONPATH"] = str(REPO / "src")
    try:
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                             capture_output=True, text=True, env=env,
                             timeout=timeout)
    except subprocess.TimeoutExpired as e:
        pytest.fail(f"child timed out after {timeout}s\n"
                    f"--- captured stderr tail ---\n{_tail(e.stderr, 4000)}\n"
                    f"--- captured stdout tail ---\n{_tail(e.stdout, 2000)}")
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    return json.loads(line[-1][len("RESULT "):])


# ---------------------------------------------------------------------------
# Distributed × single-shard × reference parity matrix (all six algorithms)
# ---------------------------------------------------------------------------

ALGORITHMS = ("bfs", "sssp", "pagerank", "coloring", "boruvka", "stconn")

PARITY_CHILD = """
import json, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.core.commit import CommitSpec
from repro.graphs.generators import kronecker, erdos_renyi, random_weights
from repro.graphs.algorithms import bfs as B, sssp as S, pagerank as PR
from repro.graphs.algorithms import coloring as CO, boruvka as BO
from repro.graphs.algorithms import stconn as ST

ALG = "{alg}"
mesh = make_host_mesh(8, 1)
out = {{}}
graphs = [("kron", kronecker(8, 8, seed=3)),
          ("uniform", erdos_renyi(300, 6.0, seed=11))]
for gname, g in graphs:
    gw = random_weights(g, seed=4)
    src = int(np.argmax(np.asarray(g.degrees)))
    t = int(np.argmin(np.asarray(g.degrees)))
    for backend in ("coarse", "pallas", "auto"):
        # capacity 64 < the hub in-degrees: forces coalescing requeue;
        # m=48 forces multi-transaction commits on the static backends,
        # "auto" calibrates + adapts M from the conflict feedback
        m = None if backend == "auto" else 48
        kw = dict(capacity=64, spec=CommitSpec(backend=backend, m=m),
                  telemetry=True)
        if ALG == "bfs":
            ref = B.bfs_reference(g, src)
            one = B.bfs(g, src)
            dist, _, res = B.distributed_bfs(mesh, g, src, **kw)
            ok = (np.array_equal(np.asarray(dist, np.int64), ref)
                  and np.array_equal(np.asarray(dist), np.asarray(one.dist)))
        elif ALG == "sssp":
            ref = S.sssp_reference(gw, src)
            one, _ = S.sssp(gw, src)
            dist, _, res = S.distributed_sssp(mesh, gw, src, **kw)
            d = np.asarray(dist, np.float64)
            reach = np.isfinite(ref)
            ok = (np.array_equal(np.asarray(dist), np.asarray(one))
                  and bool(np.allclose(d[reach], ref[reach], rtol=1e-5))
                  and bool((d[~reach] > 1e37).all()))
        elif ALG == "pagerank":
            ref = PR.pagerank_reference(g, iters=8)
            one, _ = PR.pagerank(g, iters=8)
            rank, res = PR.distributed_pagerank(mesh, g, iters=8, **kw)
            r = np.asarray(rank, np.float64)
            ok = (float(np.abs(r - ref).max()) < 1e-5
                  and float(np.abs(r - np.asarray(one, np.float64)).max())
                  < 1e-5)
        elif ALG == "coloring":
            one_c, one_r, _ = CO.coloring(g, seed=0)
            c, r, nc, res = CO.distributed_coloring(mesh, g, seed=0, **kw)
            ok = (np.array_equal(np.asarray(c), np.asarray(one_c))
                  and CO.validate_coloring(g, c) and not bool(nc)
                  and int(r) == int(one_r))
        elif ALG == "boruvka":
            one_comp, one_w, one_ne, _ = BO.boruvka(gw)
            ref_w = BO.mst_reference(gw)
            comp, w, ne, ro, res = BO.distributed_boruvka(mesh, gw, **kw)
            ok = (np.array_equal(np.asarray(comp), np.asarray(one_comp))
                  and abs(float(w) - ref_w) < 1e-3 * max(ref_w, 1.0)
                  and int(ne) == int(one_ne))
        else:
            ref = ST.st_reference(g, src, t)
            one_f, _ = ST.st_connectivity(g, src, t)
            f, r, res = ST.distributed_stconn(mesh, g, src, t, **kw)
            ok = bool(f) == bool(ref) == bool(one_f)
        out[gname + "/" + backend] = dict(
            ok=bool(ok), delivered_all=bool(res.delivered_all),
            subrounds=int(res.subrounds), rounds=int(res.rounds),
            conflicts=int(res.conflicts))
print("RESULT", json.dumps(out))
"""


@pytest.mark.parametrize("alg", ALGORITHMS)
def test_distributed_parity_matrix(alg):
    r = run_devices(PARITY_CHILD.format(alg=alg), timeout=1500)
    assert len(r) == 6, r          # 2 graphs x 3 backends (incl. auto)
    for case, row in r.items():
        assert row["ok"], (alg, case, row)
        # the anti-wedge flag: capacity C < max in-degree must terminate
        # by requeueing, never by silently dropping pending messages
        assert row["delivered_all"], (alg, case, row)
        assert row["subrounds"] >= row["rounds"], (alg, case, row)


# ---------------------------------------------------------------------------
# Lane-batched (multi-source) parity: 8-device fused waves == single-shard
# fused loops == L looped single-query runs (ISSUE 4)
# ---------------------------------------------------------------------------

MULTI_CHILD = """
import json, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.core.commit import CommitSpec
from repro.graphs.generators import kronecker, random_weights
from repro.graphs.algorithms import bfs as B, sssp as S
from repro.graphs.algorithms import pagerank as PR, stconn as ST

mesh = make_host_mesh(8, 1)
g = kronecker(8, 8, seed=3)
gw = random_weights(g, seed=4)
deg = np.asarray(g.degrees)
srcs = jnp.asarray([int(np.argmax(deg)), 0, 5, int(np.argmin(deg))],
                   jnp.int32)
ts = jnp.asarray([3, 0, int(np.argmin(deg)), 17], jnp.int32)
out = {}
for backend in ("coarse", "pallas", "auto"):
    # capacity 64 < hub in-degree: lane-tagged messages must survive the
    # sub-round requeue; m=48 forces multi-transaction composite commits
    m = None if backend == "auto" else 48
    kw = dict(capacity=64, spec=CommitSpec(backend=backend, m=m),
              max_subrounds=256, telemetry=True)

    one = B.multi_source_bfs(g, srcs)
    dist, _, res = B.distributed_multi_source_bfs(mesh, g, srcs, **kw)
    looped = all(
        np.array_equal(np.asarray(dist[l]),
                       np.asarray(B.bfs(g, int(srcs[l])).dist))
        for l in range(len(srcs)))
    out["bfs/" + backend] = dict(
        ok=bool(np.array_equal(np.asarray(dist), np.asarray(one.dist))
                and looped),
        dall=bool(res.delivered_all), subrounds=int(res.subrounds),
        rounds=int(res.rounds))

    md, _ = S.multi_source_sssp(gw, srcs)
    dd, _, res = S.distributed_multi_source_sssp(mesh, gw, srcs, **kw)
    out["sssp/" + backend] = dict(
        ok=bool(np.array_equal(np.asarray(dd), np.asarray(md))),
        dall=bool(res.delivered_all), subrounds=int(res.subrounds),
        rounds=int(res.rounds))

    mr, _ = PR.multi_source_pagerank(g, srcs, iters=6)
    dr, res = PR.distributed_multi_source_pagerank(mesh, g, srcs, iters=6,
                                                   **kw)
    out["pagerank/" + backend] = dict(
        ok=bool(np.abs(np.asarray(dr) - np.asarray(mr)).max() < 1e-6),
        dall=bool(res.delivered_all), subrounds=int(res.subrounds),
        rounds=int(res.rounds))

    mf, _ = ST.multi_source_stconn(g, srcs, ts)
    df, _, res = ST.distributed_multi_source_stconn(mesh, g, srcs, ts,
                                                    **kw)
    refs = [ST.st_reference(g, int(srcs[l]), int(ts[l]))
            for l in range(len(srcs))]
    out["stconn/" + backend] = dict(
        ok=bool(np.array_equal(np.asarray(df), np.asarray(mf))
                and all(bool(df[l]) == refs[l] for l in range(len(srcs)))),
        dall=bool(res.delivered_all), subrounds=int(res.subrounds),
        rounds=int(res.rounds))
print("RESULT", json.dumps(out))
"""


def test_distributed_multi_source_parity_matrix():
    r = run_devices(MULTI_CHILD, timeout=1500)
    assert len(r) == 12, r          # 4 algorithms x 3 backends
    for case, row in r.items():
        assert row["ok"], (case, row)
        assert row["dall"], (case, row)
        assert row["subrounds"] >= row["rounds"], (case, row)


# ---------------------------------------------------------------------------
# GraphBatch axis on 8 devices: batched_over_graphs_* through the union
# run_distributed path vs the looped single-graph references (ISSUE 5)
# ---------------------------------------------------------------------------

GRAPHS_CHILD = """
import json, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.core.commit import CommitSpec
from repro.graphs.csr import GraphSet
from repro.graphs.generators import kronecker, erdos_renyi, grid2d, \\
    random_weights
from repro.graphs.algorithms import bfs as B, sssp as S, pagerank as PR
from repro.graphs.algorithms import coloring as CO, boruvka as BO
from repro.graphs.algorithms import stconn as ST

mesh = make_host_mesh(8, 1)
graphs = [kronecker(6, 6, seed=1), erdos_renyi(90, 4.0, seed=2), grid2d(8),
          kronecker(5, 4, seed=7)]
wgraphs = [random_weights(g, seed=i) for i, g in enumerate(graphs)]
gs, gws = GraphSet(graphs), GraphSet(wgraphs)
srcs = [0, 3, 5, 1]
ts = [7, 7, 0, 0]
out = {}
for backend in ("coarse", "auto"):
    spec = CommitSpec(backend=backend, stats=False)
    # capacity 64 forces sub-round requeue of the flat union-keyed waves
    kw = dict(mesh=mesh, capacity=64, max_subrounds=256, spec=spec)

    rows = B.batched_over_graphs_bfs(gs, srcs, **kw)
    out["bfs/" + backend] = all(
        np.array_equal(np.asarray(rows[i]),
                       np.asarray(B.bfs(g, s, spec=spec).dist))
        for i, (g, s) in enumerate(zip(graphs, srcs)))

    rows = S.batched_over_graphs_sssp(gws, srcs, **kw)
    out["sssp/" + backend] = all(
        np.array_equal(np.asarray(rows[i]),
                       np.asarray(S.sssp(g, s, spec=spec)[0]))
        for i, (g, s) in enumerate(zip(wgraphs, srcs)))

    rows = PR.batched_over_graphs_pagerank(gs, srcs, iters=5, **kw)
    out["pagerank/" + backend] = all(
        np.allclose(np.asarray(rows[i]),
                    np.asarray(PR.personalized_pagerank(
                        g, s, iters=5, spec=spec)[0]), atol=1e-6)
        for i, (g, s) in enumerate(zip(graphs, srcs)))

    found = ST.batched_over_graphs_stconn(gs, srcs, ts, **kw)
    out["stconn/" + backend] = all(
        bool(found[i]) == ST.st_reference(g, s, t)
        for i, (g, s, t) in enumerate(zip(graphs, srcs, ts)))

    colors, _, not_conv = CO.batched_over_graphs_coloring(gs, seed=0, **kw)
    out["coloring/" + backend] = all(
        np.array_equal(np.asarray(colors[i]),
                       np.asarray(CO.coloring(g, seed=0)[0]))
        and CO.validate_coloring(g, colors[i])
        for i, g in enumerate(graphs)) and not bool(np.any(
            np.asarray(not_conv)))

    mst, _ = BO.batched_over_graphs_boruvka(gws, **kw)
    ok = True
    for i, g in enumerate(wgraphs):
        comp1, w1, ne1, _ = BO.boruvka(g)
        comp, w, ne = mst[i]
        ok = ok and bool(np.array_equal(np.asarray(comp),
                                        np.asarray(comp1))
                         and float(w) == float(w1) and int(ne) == int(ne1))
    out["boruvka/" + backend] = ok
print("RESULT", json.dumps(out))
"""


def test_distributed_batched_over_graphs_parity_matrix():
    """All six algorithms, graph batch of 4 heterogeneous tenants, on 8
    forced devices — each batched element must equal its looped
    single-graph run (ppr to float-add rounding)."""
    r = run_devices(GRAPHS_CHILD, timeout=1500)
    assert len(r) == 12, r          # 6 algorithms x {coarse, auto}
    for case, ok in r.items():
        assert ok, case


# ---------------------------------------------------------------------------
# Degraded-mesh mode: survive a host drop mid-query on 8 devices (ISSUE 6)
# ---------------------------------------------------------------------------

DEGRADED_CHILD = """
import json, numpy as np, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.core.commit import CommitSpec
from repro.graphs.generators import kronecker
from repro.graphs.algorithms import bfs as B

mesh = make_host_mesh(8, 1)
g = kronecker(8, 8, seed=3)
src = int(np.argmax(np.asarray(g.degrees)))
ref = B.bfs_reference(g, src)
out = {}

# a) vertex-state replay: BFS state is [vpad], so the 8->7 shrink re-homes
#    the round snapshot and resumes mid-query
fired = {"n": 0}
def injector(chunk, rounds_done):
    if chunk == 1 and fired["n"] == 0:
        fired["n"] = 1
        raise RuntimeError("host 7 lost")
dist, _, res = B.distributed_bfs(
    mesh, g, src, capacity=64, max_subrounds=256,
    spec=CommitSpec(backend="coarse", m=48), telemetry=True,
    snapshot_rounds=2, fault_injector=injector)
out["single"] = dict(
    ok=bool(np.array_equal(np.asarray(dist, np.int64), ref)),
    degraded=bool(res.degraded), delivered_all=bool(res.delivered_all),
    fired=fired["n"])

# b) lane-batched: vertex-major [vpad*L] state can't be re-homed, so the
#    shrink restarts the fused query from round 0 on the 7 survivors —
#    answers still exact
srcs = jnp.asarray([src, 0, 5, 17], jnp.int32)
fired2 = {"n": 0}
def injector2(chunk, rounds_done):
    if chunk == 1 and fired2["n"] == 0:
        fired2["n"] = 1
        raise RuntimeError("host 7 lost")
md, _, mres = B.distributed_multi_source_bfs(
    mesh, g, srcs, capacity=64, max_subrounds=256,
    spec=CommitSpec(backend="coarse", m=48), telemetry=True,
    snapshot_rounds=2, fault_injector=injector2)
looped = all(
    np.array_equal(np.asarray(md[l]),
                   np.asarray(B.bfs(g, int(srcs[l])).dist))
    for l in range(len(srcs)))
out["lanes"] = dict(ok=bool(looped), degraded=bool(mres.degraded),
                    delivered_all=bool(mres.delivered_all),
                    fired=fired2["n"])

# c) fault-free control on the same args: degraded must stay False
dist0, _, res0 = B.distributed_bfs(
    mesh, g, src, capacity=64, max_subrounds=256,
    spec=CommitSpec(backend="coarse", m=48), telemetry=True,
    snapshot_rounds=2)
out["control"] = dict(
    ok=bool(np.array_equal(np.asarray(dist0, np.int64), ref)),
    degraded=bool(res0.degraded))
print("RESULT", json.dumps(out))
"""


def test_degraded_mesh_parity_8_devices():
    """A host drop mid-query on 8 devices: the run shrinks to 7, replays
    the round snapshot (vertex state) or restarts from round 0 (lane
    state), and the answers still match the reference exactly."""
    r = run_devices(DEGRADED_CHILD, timeout=1500)
    for case in ("single", "lanes"):
        assert r[case]["fired"] == 1, (case, r[case])
        assert r[case]["degraded"], (case, r[case])
        assert r[case]["delivered_all"], (case, r[case])
        assert r[case]["ok"], (case, r[case])
    assert r["control"]["ok"] and not r["control"]["degraded"], r["control"]


# ---------------------------------------------------------------------------
# Conflict-telemetry invariant (Tables 3c/3f analogue across the refactor)
# ---------------------------------------------------------------------------


def test_distributed_conflicts_match_single_shard_counts():
    """With capacity >= the whole batch and one transaction per owner, the
    distributed per-owner conflict totals must equal the single-shard
    ``coarse_commit(stats=True)`` count on the same message multiset."""
    r = run_devices("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as Ps
        from repro import compat
        from repro.launch.mesh import make_host_mesh
        from repro.core import commit as C
        from repro.core.engine import EngineConfig, wave_until_delivered
        from repro.core.messages import make_messages
        mesh = make_host_mesh(4, 1)
        P, block, n = 4, 32, 512
        V = P * block
        rng = np.random.default_rng(0)
        INIT = {"min": 2**20, "max": -2**20, "add": 0, "or": 0, "first": -1}
        out = {}
        for op in ("min", "max", "add", "or", "first"):
            tgt = rng.integers(0, V, n).astype(np.int32)
            if op == "or":
                pay = rng.integers(0, 2, n).astype(np.int32)
            elif op == "first":
                pay = rng.integers(0, 100, n).astype(np.int32)
            else:
                pay = rng.integers(-50, 50, n).astype(np.int32)
            state0 = np.full(V, INIT[op], np.int32)
            ref = C.coarse_commit(jnp.asarray(state0),
                                  make_messages(tgt, pay), op, stats=True)
            ecfg = EngineConfig(P, block, capacity=n, op=op)
            tgt_s = jnp.asarray(tgt.reshape(P, n // P))
            pay_s = jnp.asarray(pay.reshape(P, n // P))

            def shard_fn(st, tg, pl):
                st2, _, cf, _, dall = wave_until_delivered(
                    ecfg, st, tg[0], pl[0],
                    jnp.ones((n // P,), bool))
                return st2, cf, dall

            fn = compat.shard_map(
                shard_fn, mesh=mesh,
                in_specs=(Ps("data"), Ps("data"), Ps("data")),
                out_specs=(Ps("data"), Ps(), Ps()), check_vma=False)
            st2, cf, dall = jax.jit(fn)(jnp.asarray(state0), tgt_s, pay_s)
            # state parity holds for order-independent ops ('first' tie-
            # breaks by arrival order, which routing permutes)
            state_ok = (op == "first"
                        or np.array_equal(np.asarray(st2),
                                          np.asarray(ref.state)))
            out[op] = {"single": int(ref.conflicts), "dist": int(cf),
                       "state_ok": bool(state_ok), "dall": bool(dall)}
        # a multi-payload wave carries several fields per routed message —
        # conflicts must be counted once per message, not once per field
        tgt = rng.integers(0, V, n).astype(np.int32)
        pay = rng.integers(0, 2, n).astype(np.int32)
        ref = C.coarse_commit(jnp.zeros((V,), jnp.int32),
                              make_messages(tgt, pay), "or", stats=True)
        ecfg = EngineConfig(P, block, capacity=n, op="or")
        tgt_s = jnp.asarray(tgt.reshape(P, n // P))
        pay_s = jnp.asarray(pay.reshape(P, n // P))

        def shard2(st, tg, pl):
            st2, _, cf, _, _ = wave_until_delivered(
                ecfg, {"a": st, "b": st}, tg[0],
                {"a": pl[0], "b": pl[0]}, jnp.ones((n // P,), bool))
            return st2["a"], cf

        fn2 = compat.shard_map(
            shard2, mesh=mesh,
            in_specs=(Ps("data"), Ps("data"), Ps("data")),
            out_specs=(Ps("data"), Ps()), check_vma=False)
        st2, cf2 = jax.jit(fn2)(jnp.zeros((V,), jnp.int32), tgt_s, pay_s)
        out["or_2field"] = {
            "single": int(ref.conflicts), "dist": int(cf2),
            "state_ok": bool(np.array_equal(np.asarray(st2),
                                            np.asarray(ref.state))),
            "dall": True}
        print("RESULT", json.dumps(out))
    """, n=4)
    for op, row in r.items():
        assert row["dist"] == row["single"], (op, row)
        assert row["state_ok"] and row["dall"], (op, row)


# ---------------------------------------------------------------------------
# delivered_all anti-wedge flag (the silent-wedge bugfix)
# ---------------------------------------------------------------------------


def test_wave_surfaces_wedge_instead_of_silent_drop():
    """max_subrounds exhausted with messages pending => delivered_all is
    False (previously the wave returned quietly); with enough sub-rounds a
    capacity far below the per-owner in-degree still terminates and
    delivers everything."""
    r = run_devices("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as Ps
        from repro import compat
        from repro.launch.mesh import make_host_mesh
        from repro.core.engine import EngineConfig, wave_until_delivered
        mesh = make_host_mesh(2, 1)
        P, block, n = 2, 16, 64
        V = P * block
        # every shard sends all 64 messages to vertex 0: per-owner load 128
        tgt = jnp.zeros((n,), jnp.int32)
        pay = jnp.arange(n, dtype=jnp.int32)
        out = {}
        for name, cap, msr in (("wedged", 4, 3), ("requeued", 4, 64)):
            ecfg = EngineConfig(P, block, capacity=cap, op="min")

            def shard_fn(st):
                st2, _, _, sr, dall = wave_until_delivered(
                    ecfg, st, tgt, pay, jnp.ones((n,), bool),
                    max_subrounds=msr)
                return st2, sr, dall

            fn = compat.shard_map(shard_fn, mesh=mesh,
                                  in_specs=(Ps("data"),),
                                  out_specs=(Ps("data"), Ps(), Ps()),
                                  check_vma=False)
            st2, sr, dall = jax.jit(fn)(
                jnp.full((V,), 2**20, jnp.int32))
            out[name] = {"delivered_all": bool(dall), "subrounds": int(sr),
                         "min0": int(np.asarray(st2)[0])}
        print("RESULT", json.dumps(out))
    """, n=2)
    assert not r["wedged"]["delivered_all"], r
    assert r["requeued"]["delivered_all"], r
    assert r["requeued"]["min0"] == 0, r       # full multiset committed
    assert r["requeued"]["subrounds"] == 16, r  # 64 msgs / C=4 per shard


def test_ownership_protocol_converges_under_conflict():
    r = run_devices("""
        import json, numpy as np, jax.numpy as jnp
        from repro.launch.mesh import make_host_mesh
        from repro.core.ownership import run_transactions
        mesh = make_host_mesh(8, 1)
        rng = np.random.default_rng(7)
        P, X, K, V = 8, 32, 6, 512       # small V = heavy conflicts
        txns = rng.integers(0, V, (P, X, K)).astype(np.int32)
        visited, st = run_transactions(mesh, jnp.asarray(txns), V,
                                       capacity=512)
        exp = np.zeros(V, bool); exp[txns.reshape(-1)] = True
        print("RESULT", json.dumps({
            "ok": bool(np.array_equal(np.asarray(visited), exp)),
            "rounds": int(st.rounds), "retries": int(st.retries)}))
    """)
    assert r["ok"]
    assert r["retries"] > 0          # conflicts actually happened


def test_grad_compression_tracks_uncompressed_loss():
    r = run_devices("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs.archs import ARCHS
        from repro.configs.base import RunConfig, ShapeConfig, smoke_model
        from repro.data.pipeline import TokenStream
        from repro.models import model as M
        from repro.train.optimizer import make_optimizer
        from repro.train.grad_compression import (init_error_feedback,
                                                  make_compressed_dp_step)
        from repro.train.train_step import make_train_step
        mesh = jax.make_mesh((2,), ("pod",))
        cfg = smoke_model(ARCHS["qwen2-1.5b"])
        shape = ShapeConfig("t", 64, 8, "train")
        rcfg = RunConfig(model=cfg, shape=shape, remat="none",
                         learning_rate=1e-3)
        params, _ = M.init(cfg, jax.random.PRNGKey(0))
        opt = make_optimizer(rcfg)
        stream = TokenStream(cfg, shape, seed=0)
        bat = lambda i: jax.tree.map(jnp.asarray, stream.batch(i))

        step0 = jax.jit(make_train_step(cfg, rcfg, opt))
        p0, o0 = params, opt.init(params)
        for i in range(25):
            p0, o0, m0 = step0(p0, o0, jnp.int32(i), bat(i))

        loss_fn = lambda p, b: M.loss_fn(cfg, rcfg, p, b)
        stepc = make_compressed_dp_step(loss_fn, opt, mesh, axis="pod")
        pc, oc = params, opt.init(params)
        ef = init_error_feedback(params)
        for i in range(25):
            b = jax.tree.map(
                lambda x: x.reshape((2, 4) + x.shape[1:]), bat(i))
            pc, oc, ef, lc = stepc(pc, oc, ef, jnp.int32(i), b)
        print("RESULT", json.dumps({
            "loss_base": float(m0["loss"]), "loss_comp": float(lc)}))
    """)
    # compressed loss within 10% of uncompressed after 25 steps
    assert abs(r["loss_comp"] - r["loss_base"]) / r["loss_base"] < 0.10, r


def test_pipeline_parallel_matches_plain_forward():
    r = run_devices("""
        import json, jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs.archs import ARCHS
        from repro.configs.base import RunConfig, ShapeConfig, smoke_model
        from repro.models import model as M
        from repro.train.pipeline import pipeline_forward
        mesh = jax.make_mesh((2,), ("pod",))
        cfg = smoke_model(ARCHS["qwen2-1.5b"])
        cfg = dataclasses.replace(cfg, num_layers=4)   # 4 blocks / 2 stages
        shape = ShapeConfig("t", 32, 4, "train")
        rcfg = RunConfig(model=cfg, shape=shape, remat="none")
        params, _ = M.init(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab_size)
        ref, _, _ = M._forward(cfg, rcfg, params, {"tokens": toks},
                               mode="train")
        pp = pipeline_forward(cfg, rcfg, mesh, "pod", num_microbatches=2)
        with mesh:
            out = pp(params, toks)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                    ref.astype(jnp.float32))))
        print("RESULT", json.dumps({"err": err}))
    """)
    assert r["err"] < 1e-2, r


def test_sharded_train_step_runs_on_2d_mesh():
    r = run_devices("""
        import json, jax, jax.numpy as jnp
        from repro.configs.archs import ARCHS
        from repro.configs.base import RunConfig, ShapeConfig, smoke_model
        from repro.data.pipeline import TokenStream
        from repro.launch.mesh import make_host_mesh
        from repro.models import model as M
        from repro.runtime import sharding as shd
        from repro.train.optimizer import make_optimizer
        from repro.train.train_step import make_train_step
        RULES = shd.ShardingRules(shd.TRAIN_RULES)
        mesh = make_host_mesh(2, 4)
        cfg = smoke_model(ARCHS["phi3.5-moe-42b-a6.6b"])
        # same shape/lr as the test_system learning tests: a (32, 4) batch
        # at the default lr carries too little signal per step to assert a
        # loss decrease deterministically
        shape = ShapeConfig("t", 64, 8, "train")
        rcfg = RunConfig(model=cfg, shape=shape, remat="full",
                         microbatches=2, learning_rate=3e-3)
        with mesh:
            params, _ = M.init(cfg, jax.random.PRNGKey(0))
            opt = make_optimizer(rcfg)
            opt_state = opt.init(params)
            psh = shd.tree_shardings(RULES, params, mesh)
            osh = shd.tree_shardings(RULES, opt_state, mesh)
            params = jax.device_put(params, psh)
            opt_state = jax.device_put(opt_state, osh)
            step = jax.jit(make_train_step(cfg, rcfg, opt),
                           donate_argnums=(0, 1))
            stream = TokenStream(cfg, shape, seed=0)
            losses = []
            for i in range(16):
                batch = jax.tree.map(jnp.asarray, stream.batch(i))
                params, opt_state, metrics = step(params, opt_state,
                                                  jnp.int32(i), batch)
                losses.append(float(metrics["loss"]))
        print("RESULT", json.dumps({"first": sum(losses[:4]) / 4,
                                    "last": sum(losses[-4:]) / 4}))
    """)
    assert r["last"] < r["first"]
