"""Distributed tests on 8 forced host devices (subprocess: the dry-run is
the ONLY place allowed to force 512; tests use their own interpreter so the
main test session keeps 1 device)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow   # spawns 8-device subprocesses

REPO = Path(__file__).resolve().parent.parent


def run_devices(code: str, n: int = 8) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = str(REPO / "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert out.returncode == 0, out.stderr[-4000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, out.stdout[-2000:]
    return json.loads(line[-1][len("RESULT "):])


def test_distributed_bfs_and_pagerank_match_reference():
    r = run_devices("""
        import json, numpy as np, jax
        from repro.launch.mesh import make_host_mesh
        from repro.graphs.generators import kronecker
        from repro.graphs.algorithms.bfs import bfs_reference
        from repro.graphs.algorithms.pagerank import pagerank_reference
        from repro.core.engine import distributed_bfs, distributed_pagerank
        mesh = make_host_mesh(8, 1)
        g = kronecker(9, 8, seed=3)
        src = int(np.argmax(np.asarray(g.degrees)))
        dist, rounds = distributed_bfs(mesh, g, src, capacity=256, m=64)
        ok_bfs = bool(np.array_equal(np.asarray(dist, np.int64),
                                     bfs_reference(g, src)))
        pr = distributed_pagerank(mesh, g, iters=8, capacity=256)
        err = float(np.abs(np.asarray(pr) -
                           pagerank_reference(g, iters=8)).max())
        print("RESULT", json.dumps({"bfs": ok_bfs, "pr_err": err}))
    """)
    assert r["bfs"] and r["pr_err"] < 1e-5


def test_ownership_protocol_converges_under_conflict():
    r = run_devices("""
        import json, numpy as np, jax.numpy as jnp
        from repro.launch.mesh import make_host_mesh
        from repro.core.ownership import run_transactions
        mesh = make_host_mesh(8, 1)
        rng = np.random.default_rng(7)
        P, X, K, V = 8, 32, 6, 512       # small V = heavy conflicts
        txns = rng.integers(0, V, (P, X, K)).astype(np.int32)
        visited, st = run_transactions(mesh, jnp.asarray(txns), V,
                                       capacity=512)
        exp = np.zeros(V, bool); exp[txns.reshape(-1)] = True
        print("RESULT", json.dumps({
            "ok": bool(np.array_equal(np.asarray(visited), exp)),
            "rounds": int(st.rounds), "retries": int(st.retries)}))
    """)
    assert r["ok"]
    assert r["retries"] > 0          # conflicts actually happened


def test_grad_compression_tracks_uncompressed_loss():
    r = run_devices("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro.configs.archs import ARCHS
        from repro.configs.base import RunConfig, ShapeConfig, smoke_model
        from repro.data.pipeline import TokenStream
        from repro.models import model as M
        from repro.train.optimizer import make_optimizer
        from repro.train.grad_compression import (init_error_feedback,
                                                  make_compressed_dp_step)
        from repro.train.train_step import make_train_step
        mesh = jax.make_mesh((2,), ("pod",))
        cfg = smoke_model(ARCHS["qwen2-1.5b"])
        shape = ShapeConfig("t", 64, 8, "train")
        rcfg = RunConfig(model=cfg, shape=shape, remat="none",
                         learning_rate=1e-3)
        params, _ = M.init(cfg, jax.random.PRNGKey(0))
        opt = make_optimizer(rcfg)
        stream = TokenStream(cfg, shape, seed=0)
        bat = lambda i: jax.tree.map(jnp.asarray, stream.batch(i))

        step0 = jax.jit(make_train_step(cfg, rcfg, opt))
        p0, o0 = params, opt.init(params)
        for i in range(25):
            p0, o0, m0 = step0(p0, o0, jnp.int32(i), bat(i))

        loss_fn = lambda p, b: M.loss_fn(cfg, rcfg, p, b)
        stepc = make_compressed_dp_step(loss_fn, opt, mesh, axis="pod")
        pc, oc = params, opt.init(params)
        ef = init_error_feedback(params)
        for i in range(25):
            b = jax.tree.map(
                lambda x: x.reshape((2, 4) + x.shape[1:]), bat(i))
            pc, oc, ef, lc = stepc(pc, oc, ef, jnp.int32(i), b)
        print("RESULT", json.dumps({
            "loss_base": float(m0["loss"]), "loss_comp": float(lc)}))
    """)
    # compressed loss within 10% of uncompressed after 25 steps
    assert abs(r["loss_comp"] - r["loss_base"]) / r["loss_base"] < 0.10, r


def test_pipeline_parallel_matches_plain_forward():
    r = run_devices("""
        import json, jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs.archs import ARCHS
        from repro.configs.base import RunConfig, ShapeConfig, smoke_model
        from repro.models import model as M
        from repro.train.pipeline import pipeline_forward
        mesh = jax.make_mesh((2,), ("pod",))
        cfg = smoke_model(ARCHS["qwen2-1.5b"])
        cfg = dataclasses.replace(cfg, num_layers=4)   # 4 blocks / 2 stages
        shape = ShapeConfig("t", 32, 4, "train")
        rcfg = RunConfig(model=cfg, shape=shape, remat="none")
        params, _ = M.init(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                  cfg.vocab_size)
        ref, _, _ = M._forward(cfg, rcfg, params, {"tokens": toks},
                               mode="train")
        pp = pipeline_forward(cfg, rcfg, mesh, "pod", num_microbatches=2)
        with mesh:
            out = pp(params, toks)
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32) -
                                    ref.astype(jnp.float32))))
        print("RESULT", json.dumps({"err": err}))
    """)
    assert r["err"] < 1e-2, r


def test_sharded_train_step_runs_on_2d_mesh():
    r = run_devices("""
        import json, jax, jax.numpy as jnp
        from repro.configs.archs import ARCHS
        from repro.configs.base import RunConfig, ShapeConfig, smoke_model
        from repro.data.pipeline import TokenStream
        from repro.launch.mesh import make_host_mesh
        from repro.models import model as M
        from repro.runtime import sharding as shd
        from repro.train.optimizer import make_optimizer
        from repro.train.train_step import make_train_step
        RULES = shd.ShardingRules(shd.TRAIN_RULES)
        mesh = make_host_mesh(2, 4)
        cfg = smoke_model(ARCHS["phi3.5-moe-42b-a6.6b"])
        # same shape/lr as the test_system learning tests: a (32, 4) batch
        # at the default lr carries too little signal per step to assert a
        # loss decrease deterministically
        shape = ShapeConfig("t", 64, 8, "train")
        rcfg = RunConfig(model=cfg, shape=shape, remat="full",
                         microbatches=2, learning_rate=3e-3)
        with mesh:
            params, _ = M.init(cfg, jax.random.PRNGKey(0))
            opt = make_optimizer(rcfg)
            opt_state = opt.init(params)
            psh = shd.tree_shardings(RULES, params, mesh)
            osh = shd.tree_shardings(RULES, opt_state, mesh)
            params = jax.device_put(params, psh)
            opt_state = jax.device_put(opt_state, osh)
            step = jax.jit(make_train_step(cfg, rcfg, opt),
                           donate_argnums=(0, 1))
            stream = TokenStream(cfg, shape, seed=0)
            losses = []
            for i in range(16):
                batch = jax.tree.map(jnp.asarray, stream.batch(i))
                params, opt_state, metrics = step(params, opt_state,
                                                  jnp.int32(i), batch)
                losses.append(float(metrics["loss"]))
        print("RESULT", json.dumps({"first": sum(losses[:4]) / 4,
                                    "last": sum(losses[-4:]) / 4}))
    """)
    assert r["last"] < r["first"]
