"""Sharding rules, coalescing properties, perf model, data determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs.archs import ARCHS
from repro.configs.base import SHAPES, ShapeConfig
from repro.core.coalescing import (gather_from_buckets, plan_buckets,
                                   plan_buckets_sorted, scatter_to_buckets)
from repro.core.perf_model import crossing_point, fit, select_m
from repro.data.pipeline import TokenStream
from repro.runtime import sharding as shd

SET = dict(max_examples=25, deadline=None)


# ------------------------------------------------------------- sharding
def test_divisibility_fallback():
    import jax as j
    mesh = j.make_mesh((1, 1), ("data", "model"))
    rules = shd.ShardingRules(shd.TRAIN_RULES)
    # kv_heads=8 with model=16 on real mesh -> replicated: emulate via spec
    mesh16 = None
    spec = rules.spec_for(("embed", "kv_heads", "head_dim"), (4096, 8, 128),
                          _mesh((16, 16)))
    assert spec == jax.sharding.PartitionSpec("data",)  # kv 8 !| 16 dropped
    spec2 = rules.spec_for(("embed", "heads", "head_dim"), (4096, 64, 128),
                           _mesh((16, 16)))
    assert spec2 == jax.sharding.PartitionSpec("data", "model")


def _mesh(shape):
    import numpy as np

    class FakeMesh:
        def __init__(self, shape):
            self.shape = {"data": shape[0], "model": shape[1]}
    return FakeMesh(shape)


def test_resolve_axes_param_paths():
    from repro.models import model as M
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    specs = M.param_specs(cfg)
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    by_name = {".".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in p): shd.resolve_axes(p, len(x.shape))
               for p, x in flat}
    moe_wi = [a for n, a in by_name.items() if n.endswith("mlp.wi")]
    assert moe_wi and all(a == (None, "experts", "embed", "mlp")
                          for a in moe_wi)
    assert by_name["embed.embedding"] == ("vocab", "embed")
    att_wo = [a for n, a in by_name.items() if n.endswith("mixer.wo")]
    assert att_wo and all(a == (None, "heads", "head_dim", "embed")
                          for a in att_wo)


def test_resolve_axes_optimizer_states():
    from repro.models import model as M
    from repro.configs.base import RunConfig
    from repro.train.optimizer import adafactor
    cfg = ARCHS["qwen2-1.5b"]
    rcfg = RunConfig(model=cfg, shape=SHAPES["train_4k"],
                     optimizer="adafactor")
    specs = M.param_specs(cfg)
    opt_s = jax.eval_shape(adafactor(rcfg).init, specs)
    flat = jax.tree_util.tree_flatten_with_path(opt_s)[0]
    by_name = {".".join(str(getattr(k, "key", getattr(k, "idx", "")))
                        for k in p): shd.resolve_axes(p, len(x.shape))
               for p, x in flat}
    # adafactor factored moments inherit the parent param's axes
    assert by_name["embed.embedding.vr"] == ("vocab",)
    assert by_name["embed.embedding.vc"] == ("embed",)


# ----------------------------------------------------------- coalescing
@given(st.integers(1, 400), st.integers(1, 12), st.integers(1, 64),
       st.integers(0, 99))
@settings(**SET)
def test_bucket_roundtrip(n, nb, cap, seed):
    rng = np.random.default_rng(seed)
    owner = jnp.asarray(rng.integers(0, nb, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    plan = plan_buckets(owner, valid, nb, cap)
    plan2, _ = plan_buckets_sorted(owner, valid, nb, cap)
    np.testing.assert_array_equal(np.asarray(plan.position),
                                  np.asarray(plan2.position))
    np.testing.assert_array_equal(np.asarray(plan.counts),
                                  np.asarray(plan2.counts))
    payload = jnp.asarray(rng.normal(size=n), jnp.float32)
    buf = scatter_to_buckets(plan, payload, nb, cap)
    back = gather_from_buckets(buf, plan, cap)
    kept = np.asarray(plan.kept)
    np.testing.assert_allclose(np.asarray(back)[kept],
                               np.asarray(payload)[kept])
    # conservation: kept + dropped == valid
    assert int(plan.dropped) + kept.sum() == int(np.asarray(valid).sum())
    # arrival-order priority: dropped messages are the latest per bucket
    pos = np.asarray(plan.position)
    assert (pos[kept] < cap).all()


# ------------------------------------------------------------ perf model
def test_perf_model_fit_and_crossing():
    ns = np.array([1, 2, 4, 8, 16, 32, 64])
    fine = fit(ns, 1.0 + 0.9 * ns)       # cheap dispatch, costly per-vertex
    coarse = fit(ns, 12.0 + 0.2 * ns)    # costly begin/commit, cheap vertex
    assert fine.r2 > 0.999 and coarse.r2 > 0.999
    n_star = crossing_point(fine, coarse)
    # N*(analytic) = 12 / (1.9 - 0.2) ≈ 7.06
    assert 6.0 < n_star < 8.0
    m = select_m(fine, coarse, cap=4096)
    assert m >= 8 and (m & (m - 1)) == 0


def test_perf_model_no_crossing():
    ns = np.array([1, 2, 4, 8])
    fine = fit(ns, 0.1 + 0.1 * ns)
    coarse = fit(ns, 5.0 + 5.0 * ns)
    assert crossing_point(fine, coarse) is None
    assert select_m(fine, coarse) == 1


def test_select_m_never_exceeds_cap():
    """Regression: the power-of-two round-up used to overshoot a non-pow2
    cap (cap=3000 with n*safety >= 2049 returned 4096); it must round DOWN
    to the largest power of two <= cap instead."""
    ns = np.array([1, 2, 4, 8, 16, 32, 64])
    fine = fit(ns, 1.0 + 0.9 * ns)               # N* ~ 7, M* ~ 14 -> 16
    coarse = fit(ns, 12.0 + 0.2 * ns)
    for cap in (3000, 4096, 2048, 17, 7, 3, 1):
        m = select_m(fine, coarse, cap=cap, safety=2000.0)  # force the cap
        assert m <= cap, (cap, m)
        assert (m & (m - 1)) == 0                # still a power of two
    assert select_m(fine, coarse, cap=3000, safety=2000.0) == 2048
    # an in-cap crossing point is untouched by the clamp
    assert select_m(fine, coarse, cap=4096) == 16


# ------------------------------------------------------------------ data
def test_data_determinism_and_host_sharding():
    cfg = ARCHS["qwen2-1.5b"]
    shape = ShapeConfig("t", 32, 8, "train")
    s1 = TokenStream(cfg, shape, seed=3)
    s2 = TokenStream(cfg, shape, seed=3)
    b1 = s1.batch(17)
    b2 = s2.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # different steps differ
    assert not np.array_equal(b1["tokens"], s1.batch(18)["tokens"])
    # host shards are disjoint slices of the same global batch
    h0 = TokenStream(cfg, shape, seed=3).batch(17, host_id=0, num_hosts=2)
    h1 = TokenStream(cfg, shape, seed=3).batch(17, host_id=1, num_hosts=2)
    assert h0["tokens"].shape[0] == 4
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_input_specs_cover_all_cells():
    from repro.models import model as M
    for arch, cfg in ARCHS.items():
        for shape in SHAPES.values():
            specs = M.input_specs(cfg, shape)
            assert "tokens" in specs or "token" in specs, (arch, shape.name)
