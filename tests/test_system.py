"""End-to-end behaviour: train loop convergence, exact resume, serving."""
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.archs import ARCHS
from repro.configs.base import RunConfig, ShapeConfig, smoke_model
from repro.data.pipeline import TokenStream
from repro.models import model as M
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step

pytestmark = pytest.mark.slow   # full train-loop / system tests


def _train(cfg, rcfg, steps, params=None, opt_state=None, start=0, seed=0):
    opt = make_optimizer(rcfg)
    if params is None:
        params, _ = M.init(cfg, jax.random.PRNGKey(seed))
        opt_state = opt.init(params)
    step = jax.jit(make_train_step(cfg, rcfg, opt))
    stream = TokenStream(cfg, rcfg.shape, seed=seed)
    losses = []
    for i in range(start, steps):
        batch = jax.tree.map(jnp.asarray, stream.batch(i))
        params, opt_state, metrics = step(params, opt_state, jnp.int32(i),
                                          batch)
        losses.append(float(metrics["loss"]))
    return params, opt_state, losses


def _assert_learning(losses):
    first = sum(losses[:5]) / 5
    last = sum(losses[-5:]) / 5
    assert last < first - 0.05, (first, last, losses[::6])


def test_loss_decreases_dense():
    cfg = smoke_model(ARCHS["qwen2-1.5b"])
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 64, 8, "train"),
                     remat="none", learning_rate=1e-3)
    _, _, losses = _train(cfg, rcfg, 25)
    _assert_learning(losses)


def test_loss_decreases_moe_aam_path():
    cfg = smoke_model(ARCHS["phi3.5-moe-42b-a6.6b"])
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 64, 8, "train"),
                     remat="none", learning_rate=3e-3, moe_impl="aam")
    _, _, losses = _train(cfg, rcfg, 30)
    _assert_learning(losses)


def test_loss_decreases_ssm():
    cfg = smoke_model(ARCHS["mamba2-780m"])
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 64, 8, "train"),
                     remat="none", learning_rate=3e-3)
    _, _, losses = _train(cfg, rcfg, 30)
    _assert_learning(losses)


def test_microbatched_grads_match_full_batch():
    import dataclasses
    from repro.train.train_step import grads_fn
    cfg = smoke_model(ARCHS["qwen2-1.5b"])
    shape = ShapeConfig("t", 32, 8, "train")
    rcfg1 = RunConfig(model=cfg, shape=shape, remat="none", microbatches=1)
    rcfg4 = dataclasses.replace(rcfg1, microbatches=4)
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    stream = TokenStream(cfg, shape, seed=0)
    batch = jax.tree.map(jnp.asarray, stream.batch(0))
    g1, l1, _ = grads_fn(cfg, rcfg1, params, batch)
    g4, l4, _ = grads_fn(cfg, rcfg4, params, batch)
    assert abs(float(l1) - float(l4)) < 1e-2
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-3, rtol=5e-2)


def test_checkpoint_resume_is_exact(tmp_path):
    """Resume mid-run == uninterrupted run (deterministic data + state)."""
    cfg = smoke_model(ARCHS["qwen2-1.5b"])
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 32, 4, "train"),
                     remat="none", learning_rate=1e-3)
    # uninterrupted 12 steps
    p_full, o_full, losses_full = _train(cfg, rcfg, 12)
    # 6 steps, checkpoint, resume 6 more
    p6, o6, _ = _train(cfg, rcfg, 6)
    ck = Checkpointer(tmp_path)
    ck.save(6, (p6, o6))
    (p6r, o6r), start = ck.restore(jax.eval_shape(lambda: (p6, o6)))
    p_res, o_res, losses_res = _train(cfg, rcfg, 12, params=p6r,
                                      opt_state=o6r, start=start)
    assert abs(losses_res[-1] - losses_full[-1]) < 1e-4
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_generate_shapes_and_determinism():
    from repro.serve.serve_step import generate
    cfg = smoke_model(ARCHS["qwen2-1.5b"])
    rcfg = RunConfig(model=cfg, shape=ShapeConfig("t", 48, 2, "decode"),
                     remat="none")
    params, _ = M.init(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    g1 = generate(cfg, rcfg, params, {"tokens": toks}, max_new_tokens=8)
    g2 = generate(cfg, rcfg, params, {"tokens": toks}, max_new_tokens=8)
    assert g1.shape == (2, 8)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
