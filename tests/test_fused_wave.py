"""The fused route+commit tier (``backend="fused"``, ISSUE 10).

One Pallas launch takes the post-exchange bucket buffers (global target
ids with -1 sentinels, optional lane ids, traced base offset) and
computes composite keys, reorders in VMEM, and applies the commit op —
replacing the jnp-side ``local_idx``/``fuse_keys``/``make_messages``
materialization plus separate ``coarse_commit_pallas`` launch.

Parity contract: bit-identical to the ``pallas`` tier launch-for-launch
(same tile semantics, including the per-transaction conflict counts) and
state-identical to ``coarse``/``atomic``; the engine fast path
(``fused_commit_site`` with base/lane/width) must match the unfused
oracle on every batch axis.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core import perf_model
from repro.core.commit import BACKENDS, CommitSpec, commit
from repro.core.messages import make_messages

OPS5 = ("min", "max", "add", "or", "first")


@pytest.fixture(autouse=True)
def _no_timed_autotune(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")


def _init(op, v, dtype=jnp.int32):
    if op == "first":
        return jnp.full((v,), -1, dtype)
    if op in ("add", "or"):
        return jnp.zeros((v,), dtype)
    big = 1 << 30 if dtype == jnp.int32 else 1e9
    return jnp.full((v,), big if op == "min" else -big, dtype)


def _batch(v, n, seed=0, dtype=np.int32):
    rng = np.random.default_rng(seed)
    tgt = rng.integers(0, v, n).astype(np.int32)
    val = rng.integers(0, 50, n).astype(dtype)
    valid = rng.random(n) < 0.8
    return (jnp.asarray(tgt), jnp.asarray(val), jnp.asarray(valid))


def _spec(backend, stats, **kw):
    kw.setdefault("tile_m", 32)
    kw.setdefault("block_v", 64)
    return CommitSpec(backend=backend, stats=stats, interpret=True, **kw)


# -- generic commit() dispatch ----------------------------------------------


def test_fused_is_registered_backend():
    assert "fused" in BACKENDS


@pytest.mark.parametrize("op", OPS5)
@pytest.mark.parametrize("stats", [False, True])
def test_commit_parity_vs_all_tiers(op, stats):
    """fused == pallas bit-for-bit (full CommitResult, multi-tile grid)
    and state-identical to coarse and atomic."""
    v, n = 96, 70
    tgt, val, valid = _batch(v, n, seed=op.__hash__() % 97)
    if op == "or":
        val = val % 2
    msgs = make_messages(tgt, val, valid)
    st0 = _init(op, v)
    rf = commit(st0, msgs, op, _spec("fused", stats))
    rp = commit(st0, msgs, op, _spec("pallas", stats))
    for field in ("state", "success", "conflicts", "applied"):
        np.testing.assert_array_equal(np.asarray(getattr(rf, field)),
                                      np.asarray(getattr(rp, field)),
                                      err_msg=f"{op}/{field}")
    for ref_backend in ("coarse", "atomic"):
        rr = commit(st0, msgs, op, CommitSpec(backend=ref_backend,
                                              stats=stats))
        np.testing.assert_array_equal(np.asarray(rf.state),
                                      np.asarray(rr.state),
                                      err_msg=f"{op} vs {ref_backend}")
        if stats:
            np.testing.assert_array_equal(np.asarray(rf.success),
                                          np.asarray(rr.success))


@pytest.mark.parametrize("stats", [False, True])
def test_commit_float_add_tolerance(stats):
    """float32 add: bit-identical to pallas (same reduction), within the
    documented reassociation tolerance of coarse."""
    from repro.analysis.sanitize import ADD_ATOL, ADD_RTOL
    v, n = 96, 70
    tgt, val, valid = _batch(v, n, seed=5)
    valf = jnp.asarray(np.asarray(val), jnp.float32) / 7.0
    msgs = make_messages(tgt, valf, valid)
    st0 = jnp.zeros((v,), jnp.float32)
    rf = commit(st0, msgs, "add", _spec("fused", stats))
    rp = commit(st0, msgs, "add", _spec("pallas", stats))
    np.testing.assert_array_equal(np.asarray(rf.state),
                                  np.asarray(rp.state))
    rc = commit(st0, msgs, "add", CommitSpec(backend="coarse",
                                             stats=stats))
    np.testing.assert_allclose(np.asarray(rf.state),
                               np.asarray(rc.state),
                               rtol=ADD_RTOL, atol=ADD_ATOL)


def test_fused_falls_back_for_unsupported_payloads():
    """The kernel envelope is scalar int32/float32 [n] payloads — a bool
    state through backend="fused" silently takes the coarse path (same
    contract as the pallas tier), and the site-support predicate rejects
    what the engine fast path must not fuse."""
    msgs = make_messages(jnp.asarray([0, 1], jnp.int32),
                         jnp.asarray([True, False]))
    res = commit(jnp.zeros((4,), bool), msgs, "or",
                 CommitSpec(backend="fused"))
    np.testing.assert_array_equal(np.asarray(res.state),
                                  [True, False, False, False])
    st = jnp.zeros((8,), jnp.int32)
    assert C.fused_site_supported(st, jnp.zeros((4,), jnp.int32))
    assert C.fused_site_supported(st, jnp.zeros((2, 3), jnp.float32))
    assert not C.fused_site_supported(st, jnp.zeros((4,), bool))
    assert not C.fused_site_supported(st, jnp.zeros((2, 2, 2), jnp.int32))
    assert not C.fused_site_supported(jnp.zeros((4, 2), jnp.int32),
                                      jnp.zeros((4,), jnp.int32))


# -- the engine fast path: fused_commit_site --------------------------------


def _site_oracle(state, tgt, val, lane, base, width, op, stats):
    """The unfused route tail the kernel replaces: jnp key computation +
    make_messages + coarse commit."""
    nrows = state.shape[0] // width
    ok = (tgt >= 0) & (tgt - base >= 0) & (tgt - base < nrows)
    key = jnp.where(ok, tgt - base, 0) * width
    if lane is not None:
        ok = ok & (lane >= 0) & (lane < width)
        key = key + jnp.where(ok, lane, 0)
    msgs = make_messages(key.astype(jnp.int32), val, ok)
    return commit(state, msgs, op, CommitSpec(backend="coarse",
                                              stats=stats))


@pytest.mark.parametrize("stats", [False, True])
@pytest.mark.parametrize("op", ["min", "add", "first"])
def test_site_parity_base_lane_width(op, stats):
    width, nrows, base, n = 3, 40, 128, 90
    rng = np.random.default_rng(11)
    st0 = _init(op, nrows * width)
    tgt = rng.integers(base - 5, base + nrows + 5, n).astype(np.int32)
    tgt[rng.random(n) < 0.15] = -1            # bucket-fill sentinels
    lane = jnp.asarray(rng.integers(0, width, n), jnp.int32)
    val = jnp.asarray(rng.integers(0, 50, n), jnp.int32)
    tgt = jnp.asarray(tgt)
    rf = C.fused_commit_site(st0, tgt, val, op, _spec("fused", stats),
                             lane=lane, base=base, width=width)
    rr = _site_oracle(st0, tgt, val, lane, base, width, op, stats)
    np.testing.assert_array_equal(np.asarray(rf.state),
                                  np.asarray(rr.state))
    if stats:
        np.testing.assert_array_equal(np.asarray(rf.success),
                                      np.asarray(rr.success))
        assert int(rf.applied) == int(rr.applied)


def test_site_base_only_width1():
    nrows, base, n = 50, 64, 70
    rng = np.random.default_rng(12)
    st0 = _init("min", nrows)
    tgt = jnp.asarray(rng.integers(base - 8, base + nrows + 8, n),
                      jnp.int32)
    val = jnp.asarray(rng.integers(0, 50, n), jnp.int32)
    rf = C.fused_commit_site(st0, tgt, val, "min", _spec("fused", False),
                             base=base, width=1)
    rr = _site_oracle(st0, tgt, val, None, base, 1, "min", False)
    np.testing.assert_array_equal(np.asarray(rf.state),
                                  np.asarray(rr.state))


def test_lane_width_contract():
    from repro.kernels.fused_wave import fused_route_commit_pallas
    st0 = jnp.zeros((8,), jnp.int32)
    tgt = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError, match="lane ids"):
        fused_route_commit_pallas(st0, tgt, tgt, width=2, op="add")
    with pytest.raises(ValueError, match="lane ids"):
        fused_route_commit_pallas(st0, tgt, tgt, lane=tgt, width=1,
                                  op="add")


def test_ladder_fused_site_matches_static():
    """The lax.switch ladder twin must equal the static site at every
    traced level."""
    pol = AT.TunerPolicy(backend="fused", ladder=AT.M_LADDER,
                         init_level=1, adaptive=True, sort=False,
                         stats=False, tile_m=32, block_v=64,
                         interpret=True)
    width, nrows, base, n = 2, 30, 32, 50
    rng = np.random.default_rng(13)
    st0 = _init("min", nrows * width)
    tgt = jnp.asarray(rng.integers(base, base + nrows, n), jnp.int32)
    lane = jnp.asarray(rng.integers(0, width, n), jnp.int32)
    val = jnp.asarray(rng.integers(0, 50, n), jnp.int32)
    for level in (0, len(AT.M_LADDER) - 1):
        ra = AT.ladder_fused_site(st0, tgt, val, "min", pol,
                                  jnp.asarray(level, jnp.int32),
                                  lane=lane, base=base, width=width)
        rs = C.fused_commit_site(st0, tgt, val, "min",
                                 pol.spec_at(level), lane=lane,
                                 base=base, width=width)
        np.testing.assert_array_equal(np.asarray(ra.state),
                                      np.asarray(rs.state))


# -- the three batch axes through the distributed engine --------------------


def _mesh1():
    from repro.launch.mesh import make_host_mesh
    return make_host_mesh(1, 1)


def _tree_eq(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _engine_specs():
    return (CommitSpec(backend="fused", stats=False, interpret=True),
            CommitSpec(backend="coarse", stats=False))


def test_engine_single_query_parity():
    from repro.graphs.algorithms.bfs import distributed_bfs
    from repro.graphs.generators import kronecker
    g = kronecker(6, 4, seed=2)
    sf, sc = _engine_specs()
    mesh = _mesh1()
    _tree_eq(distributed_bfs(mesh, g, 3, spec=sf, capacity=256),
             distributed_bfs(mesh, g, 3, spec=sc, capacity=256))


def test_engine_query_lanes_parity():
    from repro.graphs.algorithms.bfs import distributed_multi_source_bfs
    from repro.graphs.generators import kronecker
    g = kronecker(6, 4, seed=2)
    sf, sc = _engine_specs()
    mesh = _mesh1()
    srcs = [1, 5, 9]
    _tree_eq(
        distributed_multi_source_bfs(mesh, g, srcs, spec=sf,
                                     capacity=256),
        distributed_multi_source_bfs(mesh, g, srcs, spec=sc,
                                     capacity=256))


def test_engine_graph_batch_parity():
    from repro.graphs.algorithms.bfs import batched_over_graphs_bfs
    from repro.graphs.csr import GraphSet
    from repro.graphs.generators import kronecker
    gs = GraphSet([kronecker(5, 4, seed=3), kronecker(5, 4, seed=4)])
    sf, sc = _engine_specs()
    _tree_eq(batched_over_graphs_bfs(gs, [1, 2], spec=sf, capacity=256),
             batched_over_graphs_bfs(gs, [1, 2], spec=sc, capacity=256))


def test_engine_product_axis_parity():
    from repro.graphs.algorithms.bfs import distributed_product_bfs
    from repro.graphs.csr import GraphSet
    from repro.graphs.generators import kronecker
    gs = GraphSet([kronecker(5, 4, seed=3), kronecker(5, 4, seed=4)])
    sources = jnp.asarray([[1, 2], [3, 4]], jnp.int32)   # [L=2, G=2]
    sf, sc = _engine_specs()
    mesh = _mesh1()
    _tree_eq(
        distributed_product_bfs(mesh, gs, sources, spec=sf,
                                capacity=256),
        distributed_product_bfs(mesh, gs, sources, spec=sc,
                                capacity=256))


# -- autotuner: interpret exclusion + escape hatch --------------------------


def _small_tuner():
    return AT.AutoTuner(ns=(4, 16), v_cal=256, warmup=0, repeats=1)


def test_autotune_excludes_interp_kernel_tiers(monkeypatch):
    """On a host where the kernels would run in interpret mode, neither
    pallas nor fused may enter the candidate set — simulator timings
    would mis-seed the cost model (the autotune-on-interpret fix)."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    monkeypatch.delenv(AT._ALLOW_INTERP_ENV, raising=False)
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "off")
    tuner = _small_tuner()
    st = jnp.zeros((256,), jnp.int32)
    msgs = make_messages(jnp.zeros((16,), jnp.int32),
                         jnp.zeros((16,), jnp.int32))
    spec = CommitSpec(backend="auto", stats=False, interpret=True)
    pol = AT.policy_for(spec, st, msgs, op="min", tuner=tuner)
    assert pol.backend not in AT.KERNEL_BACKENDS
    events = [e for e in tuner.audit
              if e.get("event") == "kernel_tiers_excluded"]
    assert events and set(events[0]["backends"]) == set(AT.KERNEL_BACKENDS)
    assert events[0]["escape_hatch"] == AT._ALLOW_INTERP_ENV


def test_allow_interp_escape_hatch(monkeypatch):
    monkeypatch.delenv(AT._ALLOW_INTERP_ENV, raising=False)
    assert not AT._kernel_compiled(CommitSpec(backend="auto",
                                              interpret=True))
    monkeypatch.setenv(AT._ALLOW_INTERP_ENV, "1")
    assert AT._kernel_compiled(CommitSpec(backend="auto",
                                          interpret=True))


def test_auto_can_select_fused(monkeypatch):
    """With the escape hatch set and a calibration that ranks the fused
    tier fastest, backend="auto" resolves to fused and the resulting
    spec commits with coarse-parity."""
    monkeypatch.setenv("REPRO_AUTOTUNE", "on")
    monkeypatch.setenv(AT._ALLOW_INTERP_ENV, "1")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "off")
    tuner = _small_tuner()
    fit = perf_model.LinearFit
    cal = AT.Calibration(
        fine=fit(intercept=0.0, slope=1e-6, r2=1.0),
        tiers=(("atomic", fit(intercept=1e-3, slope=1e-6, r2=1.0)),
               ("coarse", fit(intercept=1e-3, slope=1e-6, r2=1.0)),
               ("pallas", fit(intercept=9e-4, slope=1e-6, r2=1.0)),
               ("fused", fit(intercept=1e-5, slope=1e-8, r2=1.0))))
    monkeypatch.setattr(AT.AutoTuner, "calibrate",
                        lambda self, **kw: cal)
    monkeypatch.setattr(AT.AutoTuner, "race",
                        lambda self, finalists, n, **kw:
                        min(finalists, key=lambda b:
                            0 if b == "fused" else 1))
    st = jnp.full((96,), 1 << 30, jnp.int32)
    tgt, val, valid = _batch(96, 40, seed=21)
    msgs = make_messages(tgt, val, valid)
    spec = CommitSpec(backend="auto", stats=False, interpret=True)
    pol = AT.policy_for(spec, st, msgs, op="min", tuner=tuner)
    assert pol.backend == "fused"
    rf = commit(st, msgs, "min", pol.spec_at(pol.init_level))
    rc = commit(st, msgs, "min", CommitSpec(backend="coarse",
                                            stats=False))
    np.testing.assert_array_equal(np.asarray(rf.state),
                                  np.asarray(rc.state))


# -- satellite: the pallas bucket-count path --------------------------------


def test_bucket_count_backends_agree():
    from repro.core.coalescing import plan_buckets_sorted
    rng = np.random.default_rng(31)
    owner = jnp.asarray(rng.integers(0, 40, 257), jnp.int32)
    valid = jnp.asarray(rng.random(257) < 0.8)
    pj, oj = plan_buckets_sorted(owner, valid, 40, 8)
    pp, op_ = plan_buckets_sorted(owner, valid, 40, 8,
                                  count_backend="pallas")
    for f in ("owner", "position", "counts", "kept", "dropped"):
        np.testing.assert_array_equal(np.asarray(getattr(pj, f)),
                                      np.asarray(getattr(pp, f)))
    np.testing.assert_array_equal(np.asarray(oj), np.asarray(op_))


def test_bucket_count_env_and_validation(monkeypatch):
    from repro.core import coalescing as CO
    rng = np.random.default_rng(32)
    owner = jnp.asarray(rng.integers(0, 10, 64), jnp.int32)
    valid = jnp.ones((64,), bool)
    monkeypatch.setenv(CO.BUCKET_COUNT_ENV, "pallas")
    pe, _ = CO.plan_buckets_sorted(owner, valid, 10, 8)
    monkeypatch.delenv(CO.BUCKET_COUNT_ENV)
    pj, _ = CO.plan_buckets_sorted(owner, valid, 10, 8)
    np.testing.assert_array_equal(np.asarray(pe.counts),
                                  np.asarray(pj.counts))
    with pytest.raises(ValueError, match="count_backend"):
        CO.plan_buckets_sorted(owner, valid, 10, 8, count_backend="nope")


# -- satellite: waverace knows the fused commit site ------------------------


def test_waverace_scoped_fused_commit_is_commit():
    from repro.analysis import waverace

    def scoped(state):
        msgs = make_messages(jnp.asarray([1, 2, 2], jnp.int32),
                             state[:3] + 1)
        return commit(state, msgs, "min",
                      _spec("fused", False, tile_m=4, block_v=8)).state

    rep = waverace.check_traceable("scoped fused", scoped,
                                   jnp.full((8,), 9, jnp.int32))
    assert rep.commits >= 1 and not rep.findings


def test_waverace_flags_unscoped_kernel_launch():
    from repro.analysis import waverace
    from tests.fixtures.planted_race import LINT_TRACEABLES
    name, fn, state = LINT_TRACEABLES[1]
    rep = waverace.check_traceable(name, fn, state)
    assert rep.findings
    assert any("pallas_call" in f.detail for f in rep.findings)
