"""Durable serving (ISSUE 6): service snapshot/restore, the supervised
crash-resume loop, warm autotune state, learned-M ladder seeding, and the
engine's degraded-mesh mode.

Four layers:

* ServiceSnapshot round-trip — a restored service serves the same
  answers, keeps its cache/results/pending queue (original tickets), and
  refuses ids/queries the schema can't carry;
* warm restore — on every backend (incl. ``auto``) the restored service
  is bit-identical to the original, and for ``auto`` a fresh-process
  stand-in (fresh DEFAULT_TUNER, disk cache off) serves with ZERO timed
  calibration runs because the snapshot carries the fits;
* ServiceSupervisor — WAL-journaled submits survive a crash mid-drain
  (restore + replay: no acknowledged ticket lost, none answered twice),
  and a crash mid-save leaves the previous snapshot intact;
* degraded-mesh engine — ``run_distributed(snapshot_rounds=...,
  fault_injector=...)`` survives an injected fault by replaying the last
  round snapshot (P=1 retry here; the 8-device shrink parity test lives
  in tests/test_distributed.py under ``slow``).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import autotune as AT
from repro.core.commit import BACKENDS, CommitSpec
from repro.graphs.generators import erdos_renyi, kronecker, random_weights
from repro.graphs.algorithms import bfs as B
from repro.serve.durable import (ServiceSupervisor, build_snapshot,
                                 load_snapshot, restore_service,
                                 save_snapshot)
from repro.serve.graph_service import GraphService
from repro.serve.queries import (BfsQuery, MstQuery, SsspQuery, StConnQuery,
                                 query_from_dict, query_to_dict)

ALL_BACKENDS = BACKENDS + ("auto",)


def _service(**kw):
    kw.setdefault("spec", CommitSpec(backend="coarse", stats=False))
    return GraphService(**kw)


def _loaded_service(max_lanes=4, **kw):
    """A service with warm state in every snapshot domain: two tenants
    (str + int ids), cached array/bool/mst result rows, and a pending
    (undrained) queue."""
    g1 = kronecker(6, 4, seed=1)
    g2 = random_weights(erdos_renyi(50, 3.0, seed=2), seed=3)
    svc = _service(max_lanes=max_lanes, **kw)
    svc.register_graph("kron", g1)
    svc.register_graph(7, g2)
    drained = [svc.submit("kron", BfsQuery(0)),
               svc.submit("kron", StConnQuery(0, 9)),
               svc.submit(7, SsspQuery(3)),
               svc.submit(7, MstQuery())]
    svc.drain()
    pending = [svc.submit("kron", BfsQuery(5)),
               svc.submit(7, SsspQuery(1))]
    return svc, (g1, g2), drained, pending


def _rows_equal(a, b) -> bool:
    if isinstance(a, bool) or isinstance(b, bool):
        return a == b
    if isinstance(a, tuple):                     # mst rows
        return (np.array_equal(np.asarray(a[0]), np.asarray(b[0]))
                and float(a[1]) == float(b[1]) and int(a[2]) == int(b[2]))
    return np.array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# snapshot round-trip
# ---------------------------------------------------------------------------


def test_snapshot_roundtrip_in_memory():
    """restore(build_snapshot(svc)) preserves graphs, cache, results,
    the pending queue with its original tickets, and the ticket
    counter."""
    svc, _, drained, pending = _loaded_service()
    svc2 = GraphService.restore(svc.snapshot())
    assert set(svc2._graphs) == {"kron", 7}
    assert svc2._next_ticket == svc._next_ticket
    assert svc2.pending() == svc.pending() == 2
    for t in drained:
        assert _rows_equal(svc2.result(t), svc.result(t)), t
    assert set(svc2._cache) == set(svc._cache)
    # drain both: the replayed pending tickets answer identically
    svc.drain()
    svc2.drain()
    for t in pending:
        assert _rows_equal(svc2.result(t), svc.result(t)), t


def test_snapshot_roundtrip_through_checkpointer(tmp_path):
    """The on-disk path: save_snapshot -> load_snapshot across
    Checkpointer domain checkpoints."""
    svc, _, drained, pending = _loaded_service()
    ck = Checkpointer(tmp_path)
    step = save_snapshot(ck, svc.snapshot())
    assert step == 1 and ck.domains(step).keys() == {"graphs", "cache",
                                                     "results"}
    snap, got = load_snapshot(ck)
    assert got == step
    svc2 = restore_service(snap)
    for t in drained:
        assert _rows_equal(svc2.result(t), svc.result(t)), t
    svc.drain()
    svc2.drain()
    for t in pending:
        assert _rows_equal(svc2.result(t), svc.result(t)), t
    # a non-snapshot domain checkpoint is refused by schema
    ck2 = Checkpointer(tmp_path / "other")
    ck2.save_domains(1, {"d": {"x": jnp.arange(3)}}, meta={"schema": "???"})
    with pytest.raises(ValueError, match="not a service snapshot"):
        load_snapshot(ck2)


def test_snapshot_rejects_unportable_graph_ids():
    svc = _service()
    svc.register_graph(("tuple", "id"), kronecker(5, 4, seed=0))
    with pytest.raises(TypeError, match="str or int"):
        build_snapshot(svc)


def test_query_dict_roundtrip():
    for q in (BfsQuery(3), SsspQuery(1), StConnQuery(2, 5), MstQuery()):
        q2 = query_from_dict(query_to_dict(q))
        assert q2 == q and hash(q2) == hash(q)


# ---------------------------------------------------------------------------
# warm restore: parity on every backend, zero recalibration for auto
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_restored_service_parity_all_backends(backend):
    """A restored service answers a mixed-tenant batch bit-identical to
    the original, whatever the commit mechanism."""
    spec = None if backend == "auto" else CommitSpec(backend=backend,
                                                     stats=False)
    svc, (g1, g2), _, _ = _loaded_service(spec=spec, cache=False)
    svc2 = GraphService.restore(svc.snapshot())
    qs1 = [BfsQuery(2), BfsQuery(9), StConnQuery(0, 3)]
    qs2 = [SsspQuery(4), MstQuery()]
    ref = svc.run("kron", qs1) + svc.run(7, qs2)
    got = svc2.run("kron", qs1) + svc2.run(7, qs2)
    for i, (a, b) in enumerate(zip(ref, got)):
        assert _rows_equal(a, b), (backend, i)


def test_restored_auto_service_runs_zero_timed_calibrations(monkeypatch):
    """THE warm-restore claim: a fresh process (fresh DEFAULT_TUNER, no
    disk cache, cold jit caches) restoring a snapshot serves auto-spec
    waves with zero timed micro-benchmarks — the snapshot carries the
    calibration fits and race verdicts, and ServiceStats.timing_runs
    proves it."""
    monkeypatch.setenv(AT._CACHE_ENV, "off")
    jax.clear_caches()                # force auto-policy resolution
    t1 = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    monkeypatch.setattr(AT, "DEFAULT_TUNER", t1)
    svc = GraphService(max_lanes=2, cache=False)   # default auto spec
    svc.register_graph("g", kronecker(6, 4, seed=1))
    qs = [BfsQuery(2), BfsQuery(9)]
    ref = svc.run("g", qs)
    assert t1.timed_runs > 0          # the original service DID calibrate
    assert svc.stats.timing_runs > 0
    snap = svc.snapshot()
    assert snap.meta["autotune"]      # ... and the snapshot carries it
    # fresh-process stand-in: new tuner that MUST NOT time anything, and
    # cold jit caches so every wave re-resolves its policy
    t2 = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    monkeypatch.setattr(AT, "DEFAULT_TUNER", t2)
    monkeypatch.setattr(t2, "_time", lambda *a: pytest.fail(
        "restored service ran a timed micro-benchmark"))
    jax.clear_caches()
    svc2 = GraphService.restore(snap)
    got = svc2.run("g", qs)
    for a, b in zip(ref, got):
        assert _rows_equal(a, b)
    assert svc2.stats.timing_runs == 0


def test_import_entries_never_clobbers_local_fits(monkeypatch):
    monkeypatch.setenv(AT._CACHE_ENV, "off")
    t = AT.AutoTuner()
    t._disk_entries()["race|k"] = "coarse"
    t.import_entries({"race|k": "atomic", "race|new": "pallas"})
    assert t.export_entries() == {"race|k": "coarse", "race|new": "pallas"}


# ---------------------------------------------------------------------------
# learned-M ladder seeding
# ---------------------------------------------------------------------------


def test_commit_spec_seed_m_validation():
    assert CommitSpec(seed_m=64).seed_m == 64
    assert CommitSpec(seed_m=0).seed_m == 0      # 0 = whole batch
    with pytest.raises(ValueError):
        CommitSpec(seed_m=-2)


def test_seed_m_seeds_the_ladder_level(monkeypatch):
    """seed_m places the auto policy's initial ladder level at the
    learned M without pinning it (adaptation stays on)."""
    monkeypatch.setenv(AT._CACHE_ENV, "off")
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")  # deterministic policy
    t = AT.AutoTuner()
    pol = t.policy(CommitSpec(backend="auto", seed_m=64), n=5000,
                   pallas_ok=False)
    assert pol.ladder[pol.init_level] == 64
    assert pol.adaptive                          # seeded, not pinned
    pol0 = t.policy(CommitSpec(backend="auto", seed_m=0), n=5000,
                    pallas_ok=False)
    assert pol0.ladder[pol0.init_level] is None  # 0 = whole batch


def test_service_learns_and_seeds_m():
    svc = GraphService()                         # default auto spec
    assert svc._spec_for("bfs", "g") is svc.spec  # nothing learned yet

    class FakeRes:
        m_final = jnp.asarray(256, jnp.int32)

    svc._learn_m("bfs", "g", FakeRes)
    assert svc._m_learned[("bfs", "g")] == 256
    seeded = svc._spec_for("bfs", "g")
    assert seeded.seed_m == 256 and seeded.backend == "auto"

    class StaticRes:
        m_final = jnp.asarray(-1, jnp.int32)     # static spec: no signal

    svc._learn_m("sssp", "g", StaticRes)
    assert ("sssp", "g") not in svc._m_learned
    # learned levels ride the snapshot
    svc.register_graph("g", kronecker(5, 4, seed=0))
    svc2 = GraphService.restore(svc.snapshot())
    assert svc2._m_learned == {("bfs", "g"): 256}
    # a pinned-m spec never gets seeded
    pinned = GraphService(spec=CommitSpec(backend="auto", m=32))
    pinned._m_learned[("bfs", "g")] = 256
    assert pinned._spec_for("bfs", "g").m == 32


# ---------------------------------------------------------------------------
# ServiceSupervisor: WAL replay, crash mid-drain, crash mid-save
# ---------------------------------------------------------------------------


def _silent(*_):
    pass


def test_supervisor_crash_mid_drain_loses_no_ticket(tmp_path):
    """Acknowledged tickets survive a crash mid-drain: the supervisor
    restores the last snapshot, replays the WAL under the original
    ticket ids, and re-drains.  Nothing lost, nothing answered twice."""
    g = kronecker(6, 4, seed=1)
    svc = _service(max_lanes=2, cache=False)
    svc.register_graph("g", g)
    sup = ServiceSupervisor(svc, Checkpointer(tmp_path), log=_silent)
    pre = [sup.submit("g", BfsQuery(s)) for s in (0, 1)]
    sup.drain()
    pre_rows = [np.asarray(sup.result(t)) for t in pre]
    sup.save()                                   # snapshot: pre answered
    post = [sup.submit("g", BfsQuery(s)) for s in (2, 3, 4, 5)]

    crashes = {"n": 0}

    # the pre-drain already ran wave 0, so this drain's two waves are
    # i=1 and i=2: the first lands, the crash eats the second
    def injector(where, i):
        if i == 2:
            crashes["n"] += 1
            raise RuntimeError("host lost")

    svc.fault_injector = injector
    sup.drain()
    assert crashes["n"] == 1 and sup.restarts == 1
    assert sup.service is not svc                # faulted instance dropped
    for t, row in zip(pre, pre_rows):            # snapshot rows intact
        np.testing.assert_array_equal(np.asarray(sup.result(t)), row)
    for t, s in zip(post, (2, 3, 4, 5)):         # WAL-replayed, answered once
        np.testing.assert_array_equal(
            np.asarray(sup.result(t)),
            np.asarray(B.bfs(g, s, spec=svc.spec).dist))
    assert sup.service.pending() == 0
    # exactly-once: replaying result() is stable and no extra tickets exist
    assert sup.service._next_ticket == len(pre) + len(post)


def test_supervisor_replay_skips_tickets_inside_snapshot(tmp_path):
    """A crash between snapshot commit and WAL truncation leaves stale
    WAL lines; replay must skip tickets the snapshot already accounts
    for instead of double-answering them."""
    g = kronecker(6, 4, seed=1)
    svc = _service(max_lanes=2, cache=False)
    svc.register_graph("g", g)
    sup = ServiceSupervisor(svc, Checkpointer(tmp_path), log=_silent)
    t0 = sup.submit("g", BfsQuery(0))
    sup.drain()
    save_snapshot(sup.ckpt, svc.snapshot())      # snapshot WITHOUT the
    #                                              supervisor's WAL truncate
    assert sup._wal.read_text().strip()          # stale line survives
    restored = sup.restore()
    assert restored.pending() == 0               # not re-queued
    np.testing.assert_array_equal(np.asarray(restored.result(t0)),
                                  np.asarray(B.bfs(g, 0, spec=svc.spec).dist))


def test_supervisor_crash_mid_save_keeps_previous_snapshot(tmp_path):
    svc = _service(cache=False)
    svc.register_graph("g", kronecker(5, 4, seed=0))
    sup = ServiceSupervisor(svc, Checkpointer(tmp_path), log=_silent)
    t = sup.submit("g", BfsQuery(1))
    sup.drain()
    sup.save()
    sup.submit("g", BfsQuery(2))
    with pytest.raises(RuntimeError, match="disk gone"):
        sup.save(_pre_commit=lambda: (_ for _ in ()).throw(
            RuntimeError("disk gone")))
    restored = sup.restore()                     # previous snapshot wins
    restored.result(t)
    assert restored.pending() == 1               # BfsQuery(2) via the WAL


def test_supervisor_gives_up_past_max_restarts(tmp_path):
    svc = _service(cache=False)
    svc.register_graph("g", kronecker(5, 4, seed=0))
    sup = ServiceSupervisor(svc, Checkpointer(tmp_path), max_restarts=1,
                            log=_silent)
    sup.save()
    sup.submit("g", BfsQuery(0))

    def always_crash(where, i):
        raise RuntimeError("flaky host")

    svc.fault_injector = always_crash
    sup.drain()          # crash 1: restored instance (no injector) finishes
    assert sup.restarts == 1
    sup.service.fault_injector = always_crash
    sup.submit("g", BfsQuery(1))
    with pytest.raises(RuntimeError, match="restarts"):
        sup.drain()      # crash 2: budget exhausted


# ---------------------------------------------------------------------------
# degraded-mesh engine (P=1 replay path; 8-device shrink is tier 2)
# ---------------------------------------------------------------------------


def test_degraded_mesh_replays_round_snapshot_1dev():
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    g = kronecker(7, 8, seed=3)
    src = int(np.argmax(np.asarray(g.degrees)))
    ref = B.bfs_reference(g, src)
    faults = {"n": 0}

    def injector(chunk, rounds_done):
        if chunk == 1 and faults["n"] == 0:      # after chunk 0 landed
            faults["n"] += 1
            raise RuntimeError("host dropped")

    dist, _, res = B.distributed_bfs(mesh, g, src, capacity=64,
                                  max_subrounds=256, telemetry=True,
                                  snapshot_rounds=2,
                                  fault_injector=injector)
    assert faults["n"] == 1 and bool(res.degraded)
    assert bool(res.delivered_all)
    np.testing.assert_array_equal(np.asarray(dist, np.int64), ref)
    # chunked but fault-free: not degraded, same fixed point
    dist2, _, res2 = B.distributed_bfs(mesh, g, src, capacity=64,
                                    max_subrounds=256, telemetry=True,
                                    snapshot_rounds=2)
    assert not bool(res2.degraded)
    np.testing.assert_array_equal(np.asarray(dist2, np.int64), ref)


def test_degraded_mesh_gives_up_past_max_faults():
    from repro.core.engine import AlgorithmSpec, run_distributed
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    g = kronecker(5, 4, seed=0)

    def injector(chunk, rounds_done):
        raise RuntimeError("always down")

    def init(g, layout):
        return {"x": jnp.zeros((layout.vpad,), jnp.int32)}, {}

    def round_fn(rt, e, st, sc, it):
        return st, sc, jnp.asarray(False)

    alg = AlgorithmSpec("noop", "FF", init, round_fn, lambda g, l: 3)
    with pytest.raises(RuntimeError, match="always down"):
        run_distributed(alg, mesh, g, capacity=64, snapshot_rounds=1,
                        fault_injector=injector, max_faults=2)
