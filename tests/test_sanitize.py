"""Permutation-invariance of commit() + the runtime conflict sanitizer.

The HTM guarantee the sanitizer replaces: a batch of atomic active
messages commits as if in SOME serial order, and for our op algebra the
result must not depend on WHICH order.  These tests pin that down
directly (hypothesis-shuffled batches, all ops x all backends), pin the
``first`` cross-backend deterministic tiebreak (satellite of ISSUE 8),
and exercise the ``REPRO_SANITIZE=1`` / ``CommitSpec(sanitize=True)``
shadow-replay machinery end to end.

Tolerance note (documented per the issue): float ``add`` is permutation
invariant only up to reassociation rounding — compared with
``ADD_RTOL``/``ADD_ATOL`` from :mod:`repro.analysis.sanitize`; every
other (op, dtype) is bit-identical.  Vector ``[n, d]`` payloads are
commit-supported for ``add`` only, so the vector half of the matrix
runs on ``add`` (pallas falls back to coarse for vectors by design).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, strategies as st

from repro.analysis.sanitize import (ADD_ATOL, ADD_RTOL, SanitizeError,
                                     clear_reports, reports, shadow_check)
from repro.core.commit import CommitSpec, commit
from repro.core.messages import make_messages

SET = dict(max_examples=15, deadline=None)
BACKENDS4 = ("atomic", "coarse", "pallas", "fused", "auto")


def _spec(backend):
    # interpret=True keeps the pallas tier runnable on CPU; auto uses
    # the deterministic no-calibration fallback under REPRO_AUTOTUNE=off
    return CommitSpec(backend=backend, interpret=True)


def _init_state(op, v, dtype):
    if op == "first":
        return jnp.full((v,), -1, dtype)
    if op in ("add", "or"):
        return jnp.zeros((v,), dtype)
    big = 1000 if dtype == jnp.int32 else 1000.0
    return jnp.full((v,), big if op == "min" else -big, dtype)


@st.composite
def shuffled_batches(draw):
    v = draw(st.integers(4, 60))
    n = draw(st.integers(2, 120))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31 - 1)))
    tgt = rng.integers(0, v, n).astype(np.int32)
    val = rng.integers(-50, 50, n).astype(np.int32)
    valid = rng.random(n) < 0.8
    perm = rng.permutation(n)
    return v, tgt, val, valid, perm


@pytest.fixture(autouse=True)
def _no_timed_autotune(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")


@given(st.sampled_from(BACKENDS4),
       st.sampled_from(["min", "max", "add", "or"]), shuffled_batches())
@settings(**SET)
def test_commit_permutation_invariant_scalar(backend, op, b):
    """Reordering the message batch must not change the committed state
    — bit-identical for every commutative (op, int32) pair."""
    v, tgt, val, valid, perm = b
    if op == "or":
        val = (np.abs(val) % 2).astype(np.int32)
    st0 = _init_state(op, v, jnp.int32)
    spec = _spec(backend)
    a = commit(st0, make_messages(tgt, jnp.asarray(val),
                                  jnp.asarray(valid)), op, spec)
    bres = commit(st0, make_messages(tgt[perm], jnp.asarray(val[perm]),
                                     jnp.asarray(valid[perm])), op, spec)
    np.testing.assert_array_equal(np.asarray(a.state),
                                  np.asarray(bres.state))


@given(st.sampled_from(BACKENDS4), shuffled_batches())
@settings(**SET)
def test_commit_permutation_float_add_tolerance(backend, b):
    """float add: permutation only moves reassociation rounding — equal
    to the documented ADD_RTOL/ADD_ATOL tolerance."""
    v, tgt, val, valid, perm = b
    valf = (val / 7.0).astype(np.float32)
    st0 = jnp.zeros((v,), jnp.float32)
    spec = _spec(backend)
    a = commit(st0, make_messages(tgt, jnp.asarray(valf),
                                  jnp.asarray(valid)), "add", spec)
    bres = commit(st0, make_messages(tgt[perm], jnp.asarray(valf[perm]),
                                     jnp.asarray(valid[perm])), "add",
                  spec)
    np.testing.assert_allclose(np.asarray(a.state), np.asarray(bres.state),
                               rtol=ADD_RTOL, atol=ADD_ATOL)


@given(st.sampled_from(["atomic", "coarse", "pallas"]),
       shuffled_batches())
@settings(**SET)
def test_commit_permutation_invariant_vector_add(backend, b):
    """[n, d] vector payloads (the op commit supports vectors for)."""
    v, tgt, val, valid, perm = b
    d = 3
    rng = np.random.default_rng(val.sum() % (2 ** 31 - 1))
    pay = rng.integers(-9, 9, (tgt.size, d)).astype(np.int32)
    st0 = jnp.zeros((v, d), jnp.int32)
    spec = _spec(backend)
    a = commit(st0, make_messages(tgt, jnp.asarray(pay),
                                  jnp.asarray(valid)), "add", spec)
    bres = commit(st0, make_messages(tgt[perm], jnp.asarray(pay[perm]),
                                     jnp.asarray(valid[perm])), "add",
                  spec)
    np.testing.assert_array_equal(np.asarray(a.state),
                                  np.asarray(bres.state))


# -- `first`: deterministic min-message-index tiebreak ----------------------

def test_first_cross_backend_parity_with_ties():
    """All backends must pick the same winner for `first`, including on
    heavily tied targets: the minimum message index (satellite 2)."""
    rng = np.random.default_rng(7)
    v, n = 16, 200
    tgt = rng.integers(0, v, n).astype(np.int32)     # ~12 msgs per slot
    pay = rng.integers(0, 1000, n).astype(np.int32)
    valid = rng.random(n) < 0.9
    st0 = jnp.full((v,), -1, jnp.int32)
    msgs = make_messages(tgt, jnp.asarray(pay), jnp.asarray(valid))
    results = {b: np.asarray(commit(st0, msgs, "first", _spec(b)).state)
               for b in ("atomic", "coarse", "pallas")}
    # reference: lowest VALID message index per target wins
    exp = np.full(v, -1, np.int32)
    for i in range(n - 1, -1, -1):       # reverse => lowest index lands
        if valid[i]:
            exp[tgt[i]] = pay[i]
    for b, got in results.items():
        np.testing.assert_array_equal(got, exp, err_msg=f"backend={b}")


@given(shuffled_batches())
@settings(**SET)
def test_first_filled_slots_permutation_invariant(b):
    """`first` is order-DEPENDENT in which payload wins (documented),
    but the SET of slots filled and the candidate membership of each
    winner are order-free; with tied payloads it is bit-identical."""
    v, tgt, val, valid, perm = b
    st0 = jnp.full((v,), -1, jnp.int32)
    val = np.abs(val).astype(np.int32)       # >= 0 so "filled" = != -1
    spec = _spec("coarse")
    a = np.asarray(commit(st0, make_messages(
        tgt, jnp.asarray(val), jnp.asarray(valid)), "first", spec).state)
    bres = np.asarray(commit(st0, make_messages(
        tgt[perm], jnp.asarray(val[perm]), jnp.asarray(valid[perm])),
        "first", spec).state)
    np.testing.assert_array_equal(a >= 0, bres >= 0)
    for slot in np.nonzero(a >= 0)[0]:
        cands = set(val[(tgt == slot) & valid].tolist())
        assert a[slot] in cands and bres[slot] in cands
    # payload ties erase the order dependence entirely
    tied = np.full_like(val, 5)
    t1 = commit(st0, make_messages(tgt, jnp.asarray(tied),
                                   jnp.asarray(valid)), "first", spec)
    t2 = commit(st0, make_messages(tgt[perm], jnp.asarray(tied[perm]),
                                   jnp.asarray(valid[perm])), "first",
                spec)
    np.testing.assert_array_equal(np.asarray(t1.state),
                                  np.asarray(t2.state))


# -- sanitizer machinery ----------------------------------------------------

@pytest.mark.parametrize("backend", ["atomic", "coarse", "pallas"])
@pytest.mark.parametrize("op", ["min", "max", "add", "or", "first"])
def test_sanitize_spec_clean_on_shipped_ops(backend, op):
    """CommitSpec(sanitize=True): the shadow replay passes on every
    shipped (op, backend) pair — eager and jitted."""
    clear_reports()
    rng = np.random.default_rng(3)
    v, n = 32, 128
    tgt = rng.integers(0, v, n).astype(np.int32)
    pay = rng.integers(0, 100, n).astype(np.int32)
    if op == "or":
        pay = (pay % 2).astype(np.int32)
    st0 = _init_state(op, v, jnp.int32)
    spec = CommitSpec(backend=backend, interpret=True, sanitize=True)
    msgs = make_messages(tgt, jnp.asarray(pay))
    commit(st0, msgs, op, spec).state.block_until_ready()
    jax.jit(lambda s, m: commit(s, m, op, spec).state)(
        st0, msgs).block_until_ready()
    assert reports() == ()


def test_sanitize_bool_state_or_wave():
    """Regression: bool state (`or` waves, e.g. stconn marks) has no
    subtraction — the shadow compare must not try `a - b` on it."""
    clear_reports()
    rng = np.random.default_rng(6)
    v, n = 16, 64
    tgt = rng.integers(0, v, n).astype(np.int32)
    pay = rng.random(n) < 0.5
    spec = CommitSpec(backend="pallas", interpret=True, sanitize=True)
    res = commit(jnp.zeros((v,), bool),
                 make_messages(tgt, jnp.asarray(pay)), "or", spec)
    res.state.block_until_ready()
    assert reports() == ()


def test_sanitize_env_var(monkeypatch):
    """REPRO_SANITIZE=1 turns the shadow on without touching specs."""
    clear_reports()
    monkeypatch.setenv("REPRO_SANITIZE", "1")
    rng = np.random.default_rng(4)
    v, n = 16, 64
    tgt = rng.integers(0, v, n).astype(np.int32)
    pay = (rng.standard_normal(n) / 3).astype(np.float32)
    res = commit(jnp.zeros((v,), jnp.float32),
                 make_messages(tgt, jnp.asarray(pay)), "add",
                 CommitSpec(backend="coarse"))
    res.state.block_until_ready()
    assert reports() == ()


def test_sanitize_catches_order_dependence():
    """The failure path: hand the shadow a wrong result and it must
    raise SanitizeError and record a report."""
    clear_reports()
    rng = np.random.default_rng(5)
    v, n = 16, 64
    tgt = rng.integers(0, v, n).astype(np.int32)
    pay = rng.standard_normal(n).astype(np.float32)
    st0 = jnp.zeros((v,), jnp.float32)
    with pytest.raises(SanitizeError):
        shadow_check(st0, make_messages(tgt, jnp.asarray(pay)), "add",
                     CommitSpec(backend="atomic"), "atomic", st0 + 1.0)
    assert len(reports()) == 1 and reports()[0].op == "add"
    clear_reports()


def test_sanitize_rides_tuner_policy():
    """sanitize threads through TunerPolicy.spec_at so the adaptive
    ladder's per-level specs keep shadowing (engine wiring)."""
    from repro.core.autotune import TunerPolicy
    pol = TunerPolicy(backend="coarse", sanitize=True)
    assert pol.spec_at(0).sanitize is True
    assert TunerPolicy(backend="coarse").spec_at(0).sanitize is False
