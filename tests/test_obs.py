"""Wavescope (observability PR): the telemetry-return convention is
pinned across every distributed entry point, the span tracer and the
metrics registry behave and export valid schemas, the io_callback wave
tap fires when tracing is on and provably vanishes from the jaxpr when
off, a crash -> restore -> re-drain run yields ONE well-formed trace
(no orphan spans, replay instants, exactly-once tickets), the latency
histogram agrees with the bench percentile within one bucket, and the
bench rows carry the trace-summary schema."""
import dataclasses
import json
import math
import os
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune as AT
from repro.core.commit import CommitSpec
from repro.graphs.generators import erdos_renyi, kronecker, random_weights
from repro.obs import metrics as OM
from repro.obs import trace as OT
from repro.obs import wavetap as OW
from repro.serve.graph_service import GraphService, ServiceStats
from repro.serve.queries import BfsQuery, SsspQuery


class FakeClock:
    def __init__(self, now=100.0):
        self.now = now

    def __call__(self) -> float:
        return self.now

    def tick(self, dt: float) -> None:
        self.now += dt


class CountingClock(FakeClock):
    """Counts reads — span-accounting tests pin the exact number."""

    def __init__(self):
        super().__init__()
        self.reads = 0

    def __call__(self) -> float:
        self.reads += 1
        return self.now


def _mesh1():
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("data",))


# -- the telemetry= return-shape convention ---------------------------------


def test_telemetry_return_helper_semantics():
    from repro.core.engine import telemetry_return
    res = object()
    assert telemetry_return((1, 2), res, False) == (1, 2)
    assert telemetry_return((1, 2), res, True) == (1, 2, res)
    assert telemetry_return("x", res, False) == "x"
    assert telemetry_return("x", res, True) == ("x", res)


def test_telemetry_return_shapes():
    """Every distributed entry point: telemetry=True appends EXACTLY one
    trailing DistributedResult; the plain positions never shift."""
    from repro.core.engine import DistributedResult
    from repro.graphs.algorithms import (bfs, boruvka, coloring, pagerank,
                                         sssp, stconn)
    from repro.graphs.csr import GraphSet

    mesh = _mesh1()
    g = random_weights(erdos_renyi(16, 3.0, seed=0), seed=1)
    gs = GraphSet([erdos_renyi(7, 3.0, seed=1), erdos_renyi(9, 3.0,
                                                            seed=2)])
    srcL = jnp.zeros((2,), jnp.int32)
    srcG = jnp.zeros((2,), jnp.int32)
    srcLG = jnp.zeros((2, 2), jnp.int32)
    spec = CommitSpec()
    kw = dict(spec=spec, capacity=64)
    # entry -> plain arity (None = non-tuple plain return)
    cases = [
        (lambda t: bfs.distributed_bfs(mesh, g, 0, telemetry=t, **kw), 2),
        (lambda t: bfs.distributed_multi_source_bfs(
            mesh, g, srcL, telemetry=t, **kw), 2),
        (lambda t: bfs.distributed_product_bfs(
            mesh, gs, srcLG, telemetry=t, **kw), 2),
        (lambda t: sssp.distributed_sssp(mesh, g, 0, telemetry=t, **kw),
         2),
        (lambda t: sssp.distributed_multi_source_sssp(
            mesh, g, srcL, telemetry=t, **kw), 2),
        (lambda t: pagerank.distributed_pagerank(
            mesh, g, iters=2, telemetry=t, **kw), None),
        (lambda t: pagerank.distributed_multi_source_pagerank(
            mesh, g, srcL, iters=2, telemetry=t, **kw), None),
        (lambda t: coloring.distributed_coloring(
            mesh, g, telemetry=t, **kw), 3),
        (lambda t: stconn.distributed_stconn(
            mesh, g, 0, 1, telemetry=t, **kw), 2),
        (lambda t: stconn.distributed_multi_source_stconn(
            mesh, g, srcG, jnp.ones((2,), jnp.int32), telemetry=t, **kw),
         2),
        (lambda t: boruvka.distributed_boruvka(
            mesh, g, telemetry=t, **kw), 4),
    ]
    for entry, arity in cases:
        plain, full = entry(False), entry(True)
        if arity is None:
            assert not isinstance(plain, tuple)
            assert isinstance(full, tuple) and len(full) == 2
            assert isinstance(full[1], DistributedResult)
            np.testing.assert_array_equal(np.asarray(plain),
                                          np.asarray(full[0]))
        else:
            assert isinstance(plain, tuple) and len(plain) == arity
            assert len(full) == arity + 1
            assert isinstance(full[-1], DistributedResult)
            np.testing.assert_array_equal(np.asarray(plain[0]),
                                          np.asarray(full[0]))


# -- tracer -----------------------------------------------------------------


def test_tracer_span_nesting_and_export():
    clk = FakeClock(0.0)
    tr = OT.Tracer(clock=clk, enabled=True)
    with tr.span("outer", args={"a": 1}):
        clk.tick(1.0)
        with tr.span("inner"):
            clk.tick(0.5)
        clk.tick(0.25)
    tr.instant("mark")
    assert tr.open_spans() == []
    doc = tr.to_chrome()
    assert OT.validate_trace(doc) == []
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert by_name["inner"]["dur"] == pytest.approx(0.5e6)
    assert by_name["outer"]["dur"] == pytest.approx(1.75e6)
    assert by_name["mark"]["ph"] == "i"
    assert doc["otherData"]["schema"] == OT.TRACE_SCHEMA


def test_tracer_span_closes_on_exception():
    tr = OT.Tracer(clock=FakeClock(), enabled=True)
    with pytest.raises(RuntimeError):
        with tr.span("faulty"):
            raise RuntimeError("boom")
    assert tr.open_spans() == []
    assert [e["name"] for e in tr.events] == ["faulty"]


def test_tracer_inactive_reads_no_clock_and_records_nothing():
    clk = CountingClock()
    tr = OT.Tracer(clock=clk, enabled=False)
    with tr.span("s"):
        pass
    tr.instant("i")
    tr.complete("c", 0.0, 1.0)
    assert clk.reads == 0 and tr.events == []


def test_tracer_enabled_none_follows_env(monkeypatch):
    tr = OT.Tracer()
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert not tr.active
    monkeypatch.setenv("REPRO_TRACE", "1")
    assert tr.active
    monkeypatch.setenv("REPRO_TRACE", "0")
    assert not tr.active


# -- metrics ----------------------------------------------------------------


def test_histogram_quantile_within_one_bucket_of_exact():
    h = OM.Histogram("h")
    rng = np.random.default_rng(0)
    vals = rng.exponential(0.01, 500)
    for v in vals:
        h.observe(v)
    for q in (0.5, 0.9, 0.99):
        exact = float(np.percentile(vals, q * 100))
        assert abs(h.bucket_of(exact) - h.bucket_of(h.quantile(q))) <= 1
    assert h.count == 500 and h.sum == pytest.approx(vals.sum())


def test_registry_exports_validate():
    reg = OM.Registry()
    reg.counter("aam_c", help="a counter").inc(2)
    reg.gauge("aam_g").set(1.5)
    reg.histogram("aam_h").observe(0.25)
    snap = reg.snapshot()
    assert OM.validate_metrics_json(snap) == []
    assert snap["counters"]["aam_c"] == 2
    text = reg.prometheus_text()
    assert "# TYPE aam_c counter" in text
    assert 'aam_h_bucket{le="+Inf"} 1' in text and "aam_h_count 1" in text
    # malformed documents are findings, not crashes
    assert OM.validate_metrics_json({"schema": "nope"})
    bad = json.loads(json.dumps(snap).replace('"count": 1', '"count": 9'))
    assert OM.validate_metrics_json(bad)


def test_service_stats_is_registry_view():
    st = ServiceStats()
    st.waves += 3
    st.graph_waves += 2
    st.product_waves += 1
    st.last_drain_s = 0.5
    assert st.total_waves == 6
    assert st.registry.counter("aam_waves").value == 3
    assert st.registry.gauge("aam_last_drain_s").value == 0.5
    assert "aam_waves 3" in st.registry.prometheus_text()
    assert "waves=3" in repr(st)
    with pytest.raises(AttributeError):
        st.nonexistent_field


# -- the wave tap -----------------------------------------------------------


def test_commit_tap_records_and_off_jaxpr_is_clean():
    spec_on = CommitSpec(trace=True, stats=True)
    spec_off = CommitSpec(stats=True)
    state = jnp.zeros((8,), jnp.int32)

    def run(spec):
        step, lvl0 = AT.make_commit_step(spec, "add", state, n=16,
                                         label="test:add")
        from repro.core.messages import make_messages
        msgs = make_messages(jnp.arange(16, dtype=jnp.int32) % 8,
                             jnp.ones((16,), jnp.int32),
                             jnp.ones((16,), bool))
        return step, msgs

    step_off, msgs = run(spec_off)
    jx = jax.make_jaxpr(lambda s, m: step_off(s, m, jnp.int32(0)))(
        state, msgs)
    assert "callback" not in str(jx), \
        "trace=False commit step leaked a host callback into the jaxpr"

    step_on, msgs = run(spec_on)
    jx = jax.make_jaxpr(lambda s, m: step_on(s, m, jnp.int32(0)))(
        state, msgs)
    assert "callback" in str(jx)
    OW.clear()
    res, _ = jax.jit(step_on)(state, msgs, jnp.int32(0))
    jax.block_until_ready(res.state)
    recs = OW.records()
    assert len(recs) == 1 and recs[0]["kind"] == "commit"
    assert recs[0]["label"] == "test:add" and recs[0]["messages"] == 16
    OW.clear()


def test_engine_round_tap_records_per_round():
    from repro.graphs.algorithms.bfs import distributed_bfs
    g = erdos_renyi(24, 3.0, seed=3)
    OW.clear()
    dist, rounds = distributed_bfs(_mesh1(), g, 0, capacity=64,
                                   spec=CommitSpec(trace=True, stats=True))
    recs = [r for r in OW.records() if r["kind"] == "round"]
    assert len(recs) == int(rounds)
    assert [r["round"] for r in recs] == list(range(int(rounds)))
    assert all(r["shard"] == 0 for r in recs)
    s = OW.summary()
    assert s["rounds"] == int(rounds) and s["commits"] >= 0
    OW.clear()


def test_wavetap_flush_renders_device_events():
    OW.clear()
    OW.collector().add({"kind": "round", "label": "x", "t": 1.0,
                        "round": 0, "conflicts": 2, "messages": 10,
                        "subrounds": 1, "level": 0, "shard": 0})
    OW.collector().add({"kind": "round", "label": "x", "t": 1.5,
                        "round": 1, "conflicts": 0, "messages": 4,
                        "subrounds": 1, "level": 1, "shard": 0})
    tr = OT.Tracer(clock=FakeClock(), enabled=True)
    assert OW.flush_to(tr) == 2
    assert OW.records() == []           # drained
    assert [e["tid"] for e in tr.events] == [OT.TID_DEVICE] * 2
    assert tr.events[1]["dur"] == pytest.approx(0.5)
    assert OT.validate_trace(tr.to_chrome()) == []


def test_trace_off_clean_engine_and_control(monkeypatch):
    """The tier-1 gate on the zero-impact guarantee: one engine round
    loop traces clean with tracing off, and the trace=True control
    proves the jaxpr scan detects the tap (full catalog: `make lint`)."""
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    from repro.analysis import waverace
    from repro.core import engine as E
    pts = [p for p in waverace.entry_points() if p[0] == "bfs/distributed"]
    (label, cap), = waverace.capture_algorithms(pts)

    def jx(spec):
        r = E._Runner(cap.alg, _mesh1(), cap.g, axis="data", capacity=64,
                      m=8, spec=spec, batch=cap.batch, max_subrounds=8)
        return str(jax.make_jaxpr(r._jfn)(
            r.state0, r.scalars0, r.zero_carry(),
            jnp.asarray(1, jnp.int32), *r.arrays))

    assert "callback" not in jx(CommitSpec())
    assert "callback" in jx(CommitSpec(trace=True))


@pytest.mark.slow
def test_lint_trace_off_clean_cli():
    from repro.analysis import lint
    assert lint.main(["--skip-waverace", "--trace-off-clean"]) == 0


# -- serving spans ----------------------------------------------------------


def test_drain_span_reuses_clock_reads():
    """The pinned two-reads-per-drain contract survives tracing ON: the
    drain span is recorded from t0/dt the drain already read."""
    clk = CountingClock()
    tr = OT.Tracer(clock=clk, enabled=True)
    svc = GraphService(clock=clk, tracer=tr)
    svc.register_graph("g", erdos_renyi(20, 3.0, seed=0))
    svc.submit("g", BfsQuery(0))
    r0 = clk.reads
    svc.drain()
    # t0 + finally; wave spans add 2 more (begin/end of the one wave)
    assert clk.reads - r0 == 4
    names = [e["name"] for e in tr.events]
    assert "drain" in names and "wave" in names
    drain = next(e for e in tr.events if e["name"] == "drain")
    assert drain["args"]["done"] == 1
    assert tr.open_spans() == []


def test_submit_instants_record_cache_hits():
    tr = OT.Tracer(clock=FakeClock(), enabled=True)
    svc = GraphService(clock=FakeClock(), tracer=tr)
    svc.register_graph("g", erdos_renyi(20, 3.0, seed=0))
    svc.submit("g", BfsQuery(0))
    svc.drain()
    svc.submit("g", BfsQuery(0))        # cache hit
    subs = [e for e in tr.events if e["name"] == "submit"]
    assert [s["args"]["cache_hit"] for s in subs] == [False, True]


def test_crash_restore_redrain_single_trace():
    """Supervised crash -> restore -> re-drain is ONE well-formed trace:
    no orphan spans, restore + wal_replay instants present, every
    acknowledged ticket answered exactly once."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.serve.durable import ServiceSupervisor

    clk = FakeClock()
    tr = OT.Tracer(clock=clk, enabled=True)
    svc = GraphService(clock=clk, tracer=tr, cache=False)
    g = erdos_renyi(24, 3.0, seed=5)
    svc.register_graph("g", g)
    ckdir = tempfile.mkdtemp(prefix="obs_ck_")
    try:
        sup = ServiceSupervisor(svc, Checkpointer(ckdir),
                                log=lambda *_: None)
        sup.save()
        tickets = [sup.submit("g", BfsQuery(s)) for s in range(3)]
        kill = svc._wave_i
        svc.fault_injector = (
            lambda where, i: (_ for _ in ()).throw(
                RuntimeError("host lost")) if i == kill else None)
        done = sup.drain()              # crash -> restore -> re-drain
        assert sorted(done) == tickets  # exactly-once: all, none doubled
        svc2 = sup.service
        assert svc2.tracer is tr        # ONE trace across the restore
        assert tr.open_spans() == []    # the faulted wave span closed
        names = [e["name"] for e in tr.events]
        assert names.count("drain") == 2    # faulted + re-drain
        inst = [e["name"] for e in tr.events if e["ph"] == "i"]
        assert "restore" in inst and "wal_replay" in inst
        wal = next(e for e in tr.events if e["name"] == "wal_replay")
        assert wal["args"]["replayed"] == 3
        assert OT.validate_trace(tr.to_chrome()) == []
        rows = [sup.result(t) for t in tickets]
        from repro.graphs.algorithms.bfs import bfs
        for s, row in zip(range(3), rows):
            np.testing.assert_array_equal(np.asarray(row),
                                          np.asarray(bfs(g, s).dist))
    finally:
        shutil.rmtree(ckdir, ignore_errors=True)


# -- continuous server: latency histogram + cache-hit drains ----------------


def test_continuous_latency_histogram_matches_bench_percentile():
    from repro.serve.continuous import ContinuousServer
    svc = GraphService(cache=False)
    svc.register_graph("g", kronecker(5, 6, seed=1))
    svc.register_graph("h", erdos_renyi(30, 4.0, seed=2))
    with ContinuousServer(svc, max_wait_s=0.005) as cs:
        tickets = [cs.submit("g", BfsQuery(s)) for s in range(4)]
        tickets += [cs.submit("h", BfsQuery(s)) for s in range(3)]
        cs.results(tickets, timeout=120)
        if cs.last_error is not None:
            raise cs.last_error
    lat = [cs.done_at[t] - cs.submit_at[t] for t in tickets]
    h = cs.svc.stats.registry.histogram("aam_submit_to_answer_seconds")
    assert h.count == len(tickets)
    assert h.sum == pytest.approx(sum(lat))
    for q in (0.5, 0.99):
        bench = float(np.percentile(lat, q * 100))
        assert abs(h.bucket_of(bench) - h.bucket_of(h.quantile(q))) <= 1


def test_cache_hit_only_cycle_updates_drain_stats():
    from repro.serve.continuous import ContinuousServer
    clk = FakeClock()
    svc = GraphService(clock=clk)
    svc.register_graph("g", erdos_renyi(20, 3.0, seed=0))
    svc.submit("g", BfsQuery(0))
    svc.drain()
    drains0 = svc.stats.drains
    svc.stats.last_drain_s = 7.5        # stale marker
    cs = ContinuousServer(svc)          # no loop needed for a cache hit
    t = cs.submit("g", BfsQuery(0))
    assert t in svc._results            # answered at submit
    assert svc.stats.drains == drains0 + 1
    assert svc.stats.last_drain_s == 0.0
    h = svc.stats.registry.histogram("aam_submit_to_answer_seconds")
    assert h.count == 1 and h.sum == 0.0


# -- bench-row trace fields -------------------------------------------------


def test_open_loop_rows_carry_trace_fields_schema():
    from benchmarks.serve_qps import _open_rows_to_json
    from repro.analysis import lint
    rows = [{"kind": "bfs", "mode": "product", "offered_qps": 20,
             "achieved_qps": 19.5, "p50_ms": 1.0, "p99_ms": 2.0,
             "mean_ms": 1.2, "n": 8, "product_waves": 2,
             "trace_rounds": 5, "trace_mean_density": 0.12,
             "trace_ladder_moves": 1}]
    d = tempfile.mkdtemp(prefix="obs_bench_")
    try:
        path = os.path.join(d, "BENCH_t.json")
        _open_rows_to_json(rows, path)
        assert lint.run_bench_schema(d) == []
        doc = json.loads(open(path).read())
        row = doc["rows"][0]
        for k in ("trace_rounds", "trace_mean_density",
                  "trace_ladder_moves"):
            assert isinstance(row[k], (int, float)), k
        assert "rounds=5" in row["derived"]
    finally:
        shutil.rmtree(d, ignore_errors=True)


def test_trace_probe_summary_fields():
    from benchmarks.serve_qps import _trace_probe
    gp = {"hot": kronecker(5, 6, seed=1),
          "t0": erdos_renyi(24, 3.0, seed=2)}
    p = _trace_probe("bfs", gp, None, True, 0)
    assert set(p) == {"rounds", "commits", "mean_density", "ladder_moves"}
    assert p["rounds"] > 0 and 0.0 <= p["mean_density"] <= 1.0
