"""The lanes×graphs product axis (ISSUE 7): ProductAxis flat keys must
be a bijection that exactly composes QueryLanes over GraphBatch
(degenerate cases equivalent key-for-key), commit_product must equal
per-cell commits on every backend, and the product wave executor must
return each cell the answer its single-query run would — including
cells inserted at a round boundary of a RUNNING wave — so the service
can fuse a mixed tenant load into ONE wave."""
import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, strategies as st

from repro.core import commit as C
from repro.core.coalescing import GraphBatch, ProductAxis, QueryLanes
from repro.core.commit import BACKENDS, CommitSpec
from repro.core.messages import make_messages, product_messages
from repro.graphs.csr import GraphSet
from repro.graphs.generators import erdos_renyi, kronecker
from repro.serve.graph_service import GraphService
from repro.serve.product_wave import PRODUCT_KINDS, ProductWave
from repro.serve.queries import (BfsQuery, PprQuery, SsspQuery,
                                 StConnQuery)

ALL_BACKENDS = BACKENDS + ("auto",)


@st.composite
def _axes(draw):
    lanes = draw(st.integers(1, 5))
    sizes = tuple(draw(st.lists(st.integers(1, 9), min_size=1,
                                max_size=5)))
    return ProductAxis(lanes, sizes)


@settings(max_examples=40)
@given(_axes(), st.integers(0, 2 ** 31 - 1))
def test_product_flat_keys_bijective(axis, seed):
    """flatten3 over every (lane, graph, v) cell-vertex hits each key in
    [0, flat_size) exactly once, and split3 inverts it — including the
    L=1 and G=1 degenerate shapes."""
    keys = []
    for lane in range(axis.lanes):
        for g, sz in enumerate(axis.sizes):
            for v in range(sz):
                k = int(axis.flatten3(lane, g, v))
                assert axis.split3(k) == (lane, g, v)
                keys.append(k)
    assert sorted(keys) == list(range(axis.flat_size))
    # the two-level protocol agrees with the three-level helper
    rng = np.random.default_rng(seed)
    lane = jnp.asarray(rng.integers(0, axis.lanes, 16), jnp.int32)
    minor = jnp.asarray(rng.integers(0, axis.num_vertices, 16), jnp.int32)
    major2, minor2 = axis.unflatten(axis.flatten(lane, minor))
    np.testing.assert_array_equal(np.asarray(major2), np.asarray(lane))
    np.testing.assert_array_equal(np.asarray(minor2), np.asarray(minor))


@settings(max_examples=25)
@given(st.lists(st.integers(1, 9), min_size=1, max_size=5))
def test_product_of_one_lane_is_graph_batch(sizes):
    """ProductAxis(1, sizes) IS GraphBatch(sizes), key for key."""
    sizes = tuple(sizes)
    prod, gb = ProductAxis(1, sizes), GraphBatch(sizes)
    assert prod.flat_size == gb.flat_size
    for g, sz in enumerate(sizes):
        for v in range(sz):
            assert int(prod.flatten3(0, g, v)) == int(gb.flatten(g, v))


@settings(max_examples=25)
@given(st.integers(1, 6), st.integers(1, 12))
def test_product_of_one_graph_is_query_lanes(lanes, v):
    """ProductAxis(L, (V,)) IS QueryLanes(L, V), key for key."""
    prod, ql = ProductAxis(lanes, (v,)), QueryLanes(lanes, v)
    assert prod.flat_size == ql.flat_size
    assert prod.wave_width == ql.wave_width
    for lane in range(lanes):
        for u in range(v):
            assert int(prod.flatten3(lane, 0, u)) == \
                int(ql.flatten(lane, u))


def test_product_axis_validation():
    with pytest.raises(ValueError):
        ProductAxis(0, (3,))
    with pytest.raises(ValueError):
        ProductAxis(2, ())
    with pytest.raises(ValueError):
        ProductAxis(2, (3, 0))
    axis = ProductAxis(3, (4, 2))
    assert axis.wave_width == 3 and axis.race_width == 6
    assert axis.flat_size == 18


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("op", ["min", "add"])
def test_commit_product_equals_per_cell_commits(backend, op):
    """One product commit over [L * Vtot] == L × G independent per-cell
    commits: disjoint composite key ranges mean no cross-cell race, on
    every backend (bit-identical — per-cell message multisets match)."""
    rng = np.random.default_rng(7)
    axis = ProductAxis(3, (5, 8, 4))
    L, vt = axis.lanes, axis.num_vertices
    spec = CommitSpec(backend=backend, stats=False)
    dtype = np.float32 if op == "add" else np.int32
    state = rng.integers(1, 50, (L, vt)).astype(dtype)
    n = 60
    lane = rng.integers(0, L, n).astype(np.int32)
    gsel = rng.integers(0, axis.num_graphs, n).astype(np.int32)
    local = (rng.integers(0, 100, n) % np.asarray(axis.sizes)[gsel]) \
        .astype(np.int32)
    tgt_union = np.asarray(axis.offsets)[gsel] + local
    pay = rng.integers(0, 30, n).astype(dtype)
    valid = rng.random(n) < 0.8

    # [L, n] layout: message j is live only on its own lane's row
    msgs = product_messages(
        jnp.asarray(np.where(lane[None, :] == np.arange(L)[:, None],
                             tgt_union[None, :], 0), jnp.int32),
        jnp.asarray(np.where(lane[None, :] == np.arange(L)[:, None],
                             pay[None, :], 0).astype(dtype)),
        jnp.asarray((lane[None, :] == np.arange(L)[:, None])
                    & valid[None, :]),
        axis)
    res = C.commit(jnp.asarray(state.reshape(-1)), msgs, op, spec)
    fused = np.asarray(res.state).reshape(L, vt)

    # reference: each (lane, graph) cell commits alone
    expect = state.copy()
    for l in range(L):
        for g in range(axis.num_graphs):
            lo, hi = int(axis.offsets[g]), int(axis.offsets[g]) \
                + axis.sizes[g]
            sel = (lane == l) & (gsel == g) & valid
            cell = C.commit(jnp.asarray(state[l, lo:hi]),
                            make_messages(local[sel],
                                          jnp.asarray(pay[sel]),
                                          jnp.ones(sel.sum(), bool)),
                            op, spec)
            expect[l, lo:hi] = np.asarray(cell.state)
    np.testing.assert_array_equal(fused, expect)


def _gs():
    return GraphSet([kronecker(5, 6, seed=3), erdos_renyi(40, 4.0, seed=9),
                     erdos_renyi(24, 3.0, seed=1)])


def _cells(kind):
    if kind == "bfs":
        return [(0, 0, BfsQuery(1)), (1, 0, BfsQuery(5)),
                (0, 1, BfsQuery(0)), (1, 2, BfsQuery(7))]
    if kind == "sssp":
        return [(0, 0, SsspQuery(2)), (1, 1, SsspQuery(8)),
                (0, 2, SsspQuery(3))]
    if kind == "ppr":
        return [(0, 0, PprQuery(2, iters=6)), (1, 2, PprQuery(3, iters=6)),
                (0, 1, PprQuery(0, iters=6))]
    return [(0, 0, StConnQuery(0, 17)), (1, 1, StConnQuery(2, 2)),
            (0, 2, StConnQuery(0, 23))]


def _solo(kind, g, q, spec):
    """The single-query reference each cell must reproduce."""
    if kind == "bfs":
        from repro.graphs.algorithms.bfs import bfs
        return np.asarray(bfs(g, q.source, spec=spec).dist)
    if kind == "sssp":
        from repro.graphs.algorithms.sssp import sssp
        return np.asarray(sssp(g, q.source, spec=spec)[0])
    if kind == "ppr":
        from repro.graphs.algorithms.pagerank import personalized_pagerank
        return np.asarray(personalized_pagerank(g, q.source, iters=q.iters,
                                                d=q.d, spec=spec)[0])
    from repro.graphs.algorithms.stconn import st_connectivity
    return bool(st_connectivity(g, q.s, q.t, spec=spec)[0])


def _check(kind, got, want):
    if kind == "stconn":
        assert got == want
    elif kind == "ppr":      # float add: rounding-level, like any M change
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("kind", PRODUCT_KINDS)
@pytest.mark.parametrize("backend", ("coarse", "pallas", "auto"))
def test_product_wave_matches_solo_runs(kind, backend):
    """A partially-occupied L×G product wave answers every cell exactly
    as the cell's own single-query run (int kinds bit-identical; ppr to
    float-add rounding)."""
    gs = _gs()
    spec = CommitSpec(backend=backend, stats=False)
    fuse = {"iters": 6, "d": 0.85} if kind == "ppr" else {}
    wave = ProductWave(kind, gs, 2, spec=spec, fuse=fuse)
    for lane, g, q in _cells(kind):
        wave.insert(lane, g, q)
    wave.run()
    for lane, g, q in _cells(kind):
        assert wave.cell_done(lane, g)
        _check(kind, wave.extract(lane, g),
               _solo(kind, gs.graphs[g], q, spec))


@pytest.mark.parametrize("kind", PRODUCT_KINDS)
def test_product_wave_insert_mid_run_parity(kind):
    """A cell inserted at round k of a RUNNING wave (the continuous-
    batching boarding step) gets the same answer as boarding at round 0:
    disjoint key ranges make its per-round message multiset identical to
    an idle run's."""
    gs = _gs()
    spec = CommitSpec(backend="coarse", stats=False)
    fuse = {"iters": 6, "d": 0.85} if kind == "ppr" else {}
    cells = _cells(kind)
    wave = ProductWave(kind, gs, 2, spec=spec, fuse=fuse, round_chunk=2)
    lane0, g0, q0 = cells[0]
    wave.insert(lane0, g0, q0)
    wave.run_chunk()                       # 2 rounds in
    for lane, g, q in cells[1:]:
        wave.insert(lane, g, q)            # board the running wave
    while not wave.run_chunk():
        pass
    for lane, g, q in cells:
        _check(kind, wave.extract(lane, g),
               _solo(kind, gs.graphs[g], q, spec))


def test_product_wave_release_reuses_slot():
    gs = _gs()
    wave = ProductWave("bfs", gs, 1, round_chunk=3)
    wave.insert(0, 0, BfsQuery(1))
    wave.run()
    first = np.asarray(wave.extract(0, 0))
    wave.release(0, 0)
    assert wave.done and not wave.occupied.any()
    wave.insert(0, 0, BfsQuery(9))
    wave.run()
    _check("bfs", wave.extract(0, 0), _solo("bfs", gs.graphs[0],
                                            BfsQuery(9), wave.spec))
    assert not np.array_equal(np.asarray(wave.extract(0, 0)), first)


def test_graph_only_kinds_refused():
    with pytest.raises(ValueError):
        ProductWave("coloring", _gs(), 2)


def _mixed_service(**kw):
    svc = GraphService(**kw)
    svc.register_graph("hot", kronecker(5, 6, seed=3))
    for i in range(5):
        svc.register_graph(f"t{i}", erdos_renyi(30 + 6 * i, 4.0, seed=i))
    return svc


def test_mixed_workload_drains_as_one_product_wave():
    """THE acceptance shape: 1 hot graph × 3 lane queries + 5 single-
    query tenants is ONE product wave — not a lane wave plus a graph
    batch — and the answers match the single-axis drain bit-for-bit."""
    svc = _mixed_service()
    tickets = [svc.submit("hot", BfsQuery(s)) for s in (1, 5, 9)]
    tickets += [svc.submit(f"t{i}", BfsQuery(i + 2)) for i in range(5)]
    svc.drain()
    st = svc.stats
    assert st.product_waves == 1
    assert st.waves == 0 and st.graph_waves == 0
    assert st.product_cells == 4 * 6          # ladder width 4 × 6 graphs
    assert st.product_cells_padded == 4 * 6 - 8
    ref = _mixed_service(product=False)
    rt = [ref.submit("hot", BfsQuery(s)) for s in (1, 5, 9)]
    rt += [ref.submit(f"t{i}", BfsQuery(i + 2)) for i in range(5)]
    ref.drain()
    assert ref.stats.product_waves == 0
    assert ref.stats.waves >= 1 and ref.stats.graph_waves >= 1
    for a, b in zip(tickets, rt):
        np.testing.assert_array_equal(np.asarray(svc.result(a)),
                                      np.asarray(ref.result(b)))


def test_single_axis_groups_keep_their_axes():
    """Pure shapes stay on the cheaper single axis: all-singles still
    graph-batch, one multi-query graph still lane-fuses — the product
    path only fires on genuinely mixed groups."""
    svc = _mixed_service()
    for i in range(4):
        svc.submit(f"t{i}", BfsQuery(1))
    svc.drain()
    assert svc.stats.graph_waves == 1 and svc.stats.product_waves == 0
    svc2 = _mixed_service()
    for s in (1, 5, 9):
        svc2.submit("hot", BfsQuery(s))
    svc2.drain()
    assert svc2.stats.waves == 1 and svc2.stats.product_waves == 0


def test_product_snapshot_roundtrip():
    """The product flag rides the snapshot config."""
    svc = _mixed_service(product=False)
    restored = GraphService.restore(svc.snapshot())
    assert restored.product is False
    assert GraphService.restore(_mixed_service().snapshot()).product


def test_distributed_product_bfs_parity():
    """The engine-level proof: run_distributed with
    batch=ProductAxis(L, sizes) serves L queries over each tenant graph
    in one harness run, bit-identical per cell."""
    import jax
    from jax.sharding import Mesh
    from repro.graphs.algorithms.bfs import bfs, distributed_product_bfs

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    gs = GraphSet([kronecker(5, 6, seed=3), erdos_renyi(40, 4.0, seed=9)])
    sources = jnp.asarray([[1, 0], [5, 7]], jnp.int32)      # [L=2, G=2]
    dist, rounds = distributed_product_bfs(mesh, gs, sources)
    assert int(rounds) > 0
    for lane in range(2):
        rows = gs.split_vertex(dist[lane])
        for g in range(2):
            np.testing.assert_array_equal(
                np.asarray(rows[g]),
                np.asarray(bfs(gs.graphs[g],
                               int(sources[lane, g])).dist))
