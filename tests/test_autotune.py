"""Auto-tuner edge-case matrix + commit dispatch (ISSUE 3).

Covers: empty batch, all-invalid messages, single-vertex state, and
``backend="auto"`` parity against every concrete backend across all five
ops — plus the conflict-feedback ladder mechanics and the bench-JSON
schema smoke (``benchmarks.run --json``).

Calibration is timed micro-benchmarking; ``REPRO_AUTOTUNE=off`` pins the
deterministic heuristic policy for the tests that must not depend on
wall-clock noise.  Either way the FINAL STATE is backend-independent, so
every parity assertion below holds for any calibration outcome.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune as AT
from repro.core.commit import BACKENDS, OPS, CommitSpec, commit
from repro.core.messages import make_messages

REPO = Path(__file__).resolve().parent.parent

AUTO_SPEC = CommitSpec(backend="auto")


def _init_state(op, v, rng):
    if op == "min":
        return np.full(v, 1000, np.int32)
    if op == "max":
        return np.full(v, -1000, np.int32)
    if op == "first":
        return np.where(rng.random(v) < 0.5, -1, 777).astype(np.int32)
    return np.zeros(v, np.int32)    # add / or


def _batch(op, v, n, rng, valid=None):
    lo = 0 if op == "first" else (0 if op == "or" else -50)
    hi = 2 if op == "or" else 50
    tgt = rng.integers(0, v, n).astype(np.int32)
    val = rng.integers(lo, hi, n).astype(np.int32)
    if valid is None:
        valid = rng.random(n) < 0.8
    return make_messages(jnp.asarray(tgt), jnp.asarray(val),
                         jnp.asarray(valid))


# ---------------------------------------------------------------------------
# edge-case matrix: auto == every concrete backend, including the corners
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("case", ["random", "all_invalid", "single_vertex",
                                  "empty_batch"])
def test_auto_parity_matrix(op, case):
    rng = np.random.default_rng(sum(map(ord, op + case)))
    if case == "empty_batch":
        v, n = 16, 0
        msgs = make_messages(jnp.zeros((0,), jnp.int32),
                             jnp.zeros((0,), jnp.int32),
                             jnp.zeros((0,), bool))
    elif case == "single_vertex":
        v, n = 1, 40
        msgs = _batch(op, v, n, rng)
    elif case == "all_invalid":
        v, n = 61, 120
        msgs = _batch(op, v, n, rng, valid=np.zeros(120, bool))
    else:
        v, n = 61, 120
        msgs = _batch(op, v, n, rng)
    state = _init_state(op, v, rng)
    res_auto = commit(jnp.asarray(state), msgs, op, AUTO_SPEC)
    for backend in BACKENDS:
        res = commit(jnp.asarray(state), msgs, op,
                     CommitSpec(backend=backend))
        np.testing.assert_array_equal(
            np.asarray(res_auto.state), np.asarray(res.state),
            err_msg=f"auto vs {backend} on {op}/{case}")


def test_auto_honors_pinned_m():
    """A user-pinned transaction size survives auto resolution on EVERY
    entry point: resolve_spec, the policy ladder (engine + algorithm
    steppers run spec_at over the ladder), and the stepper itself."""
    state = jnp.full((8,), 1000, jnp.int32)
    msgs = make_messages(jnp.asarray([1, 1, 2], jnp.int32),
                         jnp.asarray([5, 3, 9], jnp.int32))
    pinned = CommitSpec(backend="auto", m=2)
    spec = AT.resolve_spec(pinned, state, msgs, "min")
    assert spec.backend in BACKENDS
    assert spec.m == 2
    pol = AT.policy_for(pinned, state, msgs, op="min")
    assert pol.ladder == (2,) and not pol.adaptive
    assert pol.spec_at(pol.init_level).m == 2
    step, lvl0 = AT.make_commit_step(pinned, "min", state, msgs_like=msgs)
    res, lvl1 = step(state, msgs, lvl0)
    assert int(lvl1) == int(lvl0)        # no ladder movement when pinned
    ref = commit(state, msgs, "min", CommitSpec(m=2))
    np.testing.assert_array_equal(np.asarray(res.state),
                                  np.asarray(ref.state))


def test_auto_without_telemetry_degrades_to_static_m():
    """coarse with sort=False + stats=False has no conflict signal
    (scatter path reports 0): the policy must not pretend to adapt."""
    pol = AT.DEFAULT_TUNER.policy(
        CommitSpec(backend="auto", sort=False, stats=False), n=4096,
        pallas_ok=False)
    if pol.backend == "coarse":
        assert not pol.adaptive
    # with the cheap sorted counters or full stats, coarse stays adaptive
    pol2 = AT.DEFAULT_TUNER.policy(
        CommitSpec(backend="auto", sort=True, stats=True), n=4096,
        pallas_ok=False)
    if pol2.backend == "coarse":
        assert pol2.adaptive


def test_auto_rejects_nothing_new():
    """'auto' is a valid CommitSpec backend; junk still raises."""
    state = jnp.zeros((4,), jnp.int32)
    msgs = make_messages(jnp.asarray([0], jnp.int32),
                         jnp.asarray([1], jnp.int32))
    commit(state, msgs, "min", CommitSpec(backend="auto"))
    with pytest.raises(ValueError):
        commit(state, msgs, "min", CommitSpec(backend="autotune"))


# ---------------------------------------------------------------------------
# the conflict-feedback ladder
# ---------------------------------------------------------------------------


def _policy(**kw):
    kw.setdefault("backend", "coarse")
    return AT.TunerPolicy(**kw)


def test_next_level_shrinks_under_abort_storm_and_regrows():
    pol = _policy(init_level=3)
    lvl = jnp.asarray(3, jnp.int32)
    # abort storm: conflict density 0.9 -> shrink M
    down = AT.next_level(pol, lvl, jnp.asarray(90), jnp.asarray(100))
    assert int(down) == 2
    # quiet round: density 0.0 -> grow M
    up = AT.next_level(pol, lvl, jnp.asarray(0), jnp.asarray(100))
    assert int(up) == 4
    # hysteresis band: hold
    hold = AT.next_level(pol, lvl, jnp.asarray(15), jnp.asarray(100))
    assert int(hold) == 3
    # clamped at both ends
    assert int(AT.next_level(pol, jnp.asarray(0, jnp.int32),
                             jnp.asarray(99), jnp.asarray(100))) == 0
    top = len(pol.ladder) - 1
    assert int(AT.next_level(pol, jnp.asarray(top, jnp.int32),
                             jnp.asarray(0), jnp.asarray(100))) == top
    # zero messages must not divide by zero
    assert int(AT.next_level(pol, lvl, jnp.asarray(0),
                             jnp.asarray(0))) == 4


def test_next_level_static_policy_is_identity():
    pol = _policy(backend="atomic", adaptive=False)
    lvl = jnp.asarray(2, jnp.int32)
    assert int(AT.next_level(pol, lvl, jnp.asarray(99),
                             jnp.asarray(100))) == 2


@pytest.mark.parametrize("op", OPS)
def test_ladder_commit_matches_oracle_at_every_level(op):
    """Final state is M-independent: any traced level produces the same
    state as the whole-batch reference."""
    rng = np.random.default_rng(11)
    v, n = 61, 120
    state = _init_state(op, v, rng)
    msgs = _batch(op, v, n, rng)
    ref = commit(jnp.asarray(state), msgs, op, CommitSpec())
    pol = _policy()
    for level in range(len(pol.ladder)):
        res = AT.ladder_commit(jnp.asarray(state), msgs, op, pol,
                               jnp.asarray(level, jnp.int32))
        np.testing.assert_array_equal(
            np.asarray(res.state), np.asarray(ref.state),
            err_msg=f"{op} ladder level {level}")


def test_make_commit_step_adapts_and_matches_static():
    """The single-shard stepper: commits match the static path and the
    carried level actually moves under conflict pressure."""
    rng = np.random.default_rng(5)
    v, n = 32, 256
    state = jnp.full((v,), 1000, jnp.int32)
    # all messages hammer 2 vertices: guaranteed abort storm
    msgs = make_messages(jnp.asarray(rng.integers(0, 2, n), jnp.int32),
                         jnp.asarray(rng.integers(0, 100, n), jnp.int32))
    step, lvl0 = AT.make_commit_step(CommitSpec(backend="auto"), "min",
                                     state, msgs_like=msgs)
    res, lvl1 = step(state, msgs, lvl0)
    ref = commit(state, msgs, "min", CommitSpec())
    np.testing.assert_array_equal(np.asarray(res.state),
                                  np.asarray(ref.state))
    # under a >99% conflict density the level may only move DOWN
    assert int(lvl1) <= int(lvl0)
    # static spec: level is a passthrough dummy
    step_s, lvl_s = AT.make_commit_step(CommitSpec(backend="coarse"),
                                        "min", state, msgs_like=msgs)
    res_s, lvl_s2 = step_s(state, msgs, lvl_s)
    assert int(lvl_s2) == int(lvl_s)
    np.testing.assert_array_equal(np.asarray(res_s.state),
                                  np.asarray(ref.state))


def test_policy_deterministic_with_autotune_off(monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE", "off")
    tuner = AT.AutoTuner()
    spec = CommitSpec(backend="auto")
    p1 = tuner.policy(spec, n=5000, pallas_ok=True)
    p2 = tuner.policy(spec, n=5000, pallas_ok=True)
    assert p1 == p2
    assert p1.backend == "coarse" and p1.adaptive
    assert p1.ladder[p1.init_level] in p1.ladder


def test_calibration_is_cached():
    tuner = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    c1 = tuner.calibrate(sort=True, stats=False, tile_m=64, block_v=128,
                         interpret=None, with_pallas=False)
    c2 = tuner.calibrate(sort=True, stats=False, tile_m=64, block_v=128,
                         interpret=None, with_pallas=False)
    assert c1 is c2
    assert {b for b, _ in c1.tiers} == {"atomic", "coarse"}
    assert c1.fine.slope > 0


# ---------------------------------------------------------------------------
# all six single-shard algorithms: auto == their default static spec
# ---------------------------------------------------------------------------


def test_auto_matches_static_on_all_six_algorithms():
    from repro.graphs.generators import (erdos_renyi, kronecker,
                                         random_weights)
    from repro.graphs.algorithms import bfs as B, boruvka as BO, \
        coloring as CO, pagerank as PR, sssp as S, stconn as ST

    g = kronecker(7, 8, seed=3)
    gw = random_weights(g, seed=4)
    src = int(np.argmax(np.asarray(g.degrees)))
    t = int(np.argmin(np.asarray(g.degrees)))
    auto = CommitSpec(backend="auto", stats=False)

    r1 = B.bfs(g, src)
    r2 = B.bfs(g, src, spec=auto)
    np.testing.assert_array_equal(np.asarray(r1.dist), np.asarray(r2.dist))

    d1, _ = S.sssp(gw, src)
    d2, _ = S.sssp(gw, src, spec=auto)
    np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))

    p1, _ = PR.pagerank(g, iters=5)
    p2, _ = PR.pagerank(g, iters=5, spec=auto)
    # float add: tiled transactions reorder the accumulate (exactly like
    # any static m change) -> rounding-level tolerance
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-6)

    c1, ro1, _ = CO.coloring(g, seed=0)
    c2, ro2, _ = CO.coloring(g, seed=0, spec=auto)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
    assert int(ro1) == int(ro2)

    b1 = BO.boruvka(gw)
    b2 = BO.boruvka(gw, spec=auto)
    np.testing.assert_array_equal(np.asarray(b1[0]), np.asarray(b2[0]))
    assert abs(float(b1[1]) - float(b2[1])) < 1e-5
    assert int(b1[2]) == int(b2[2])

    f1, _ = ST.st_connectivity(g, src, t)
    f2, _ = ST.st_connectivity(g, src, t, spec=auto)
    assert bool(f1) == bool(f2)

    gu = erdos_renyi(150, 5.0, seed=9)
    ru1 = B.bfs(gu, 0)
    ru2 = B.bfs(gu, 0, spec=auto)
    np.testing.assert_array_equal(np.asarray(ru1.dist),
                                  np.asarray(ru2.dist))


# ---------------------------------------------------------------------------
# pallas no-stats path (satellite): cheap path drops the conflict output
# ---------------------------------------------------------------------------


def test_pallas_commit_nostats_skips_conflict_reduction():
    from repro.kernels.coarse_commit import coarse_commit_pallas
    state = jnp.zeros((16,), jnp.int32)
    idx = jnp.asarray([1, 1, 2, 3, 3, 3, -1, -1], jnp.int32)
    val = jnp.ones((8,), jnp.int32)
    out = coarse_commit_pallas(state, idx, val, op="add", tile_m=8,
                               block_v=16, stats=False)
    assert isinstance(out, jnp.ndarray)          # single output, no tuple
    ref, conf = coarse_commit_pallas(state, idx, val, op="add", tile_m=8,
                                     block_v=16, stats=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    assert int(conf) == 5
    # the commit() wrapper: stats=False reports zero conflicts (cheap path)
    msgs = make_messages(idx, val, idx >= 0)
    res = commit(state, msgs, "add",
                 CommitSpec(backend="pallas", stats=False))
    np.testing.assert_array_equal(np.asarray(res.state), np.asarray(ref))
    assert int(res.conflicts) == 0


# ---------------------------------------------------------------------------
# bench JSON schema smoke (satellite: make bench-json / --json)
# ---------------------------------------------------------------------------


def test_bench_json_schema_smoke(tmp_path):
    """`benchmarks.run --json` emits a parseable, schema-stable document
    with the keys every future PR's trajectory comparison relies on."""
    out = tmp_path / "bench.json"
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    p = subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--json", str(out),
         "--sizes", "smoke"],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=600)
    assert p.returncode == 0, p.stderr[-3000:]
    doc = json.loads(out.read_text())
    assert doc["schema"] == "aam-bench/v1"
    assert doc["sizes"] == "smoke"
    assert isinstance(doc["rows"], list) and doc["rows"]
    for row in doc["rows"]:
        # required keys are pinned; serve rows may additionally carry
        # wavescope trace_* telemetry fields (lint --bench-schema
        # enforces the same required-subset contract)
        assert {"suite", "backend", "name", "us_per_call",
                "derived"} <= set(row)
        extras = set(row) - {"suite", "backend", "name", "us_per_call",
                             "derived"}
        assert extras <= {"trace_rounds", "trace_mean_density",
                          "trace_ladder_moves"}, extras
        assert row["us_per_call"] >= 0
    backends = {r["backend"] for r in doc["rows"]}
    assert "auto" in backends and "coarse" in backends
    assert "fig4" in doc["summary"] and "fig6" in doc["summary"]
    for suite in ("fig4", "fig6"):
        assert {"auto_over_best_static", "within_10pct",
                "points"} <= set(doc["summary"][suite])
