"""Offline stand-in for the ``hypothesis`` property-testing API.

The tier-1 suite must collect and pass in environments with no network and
no ``hypothesis`` wheel.  This module re-exports the real library when it
is importable and otherwise provides a minimal deterministic shim:
``@given`` runs the test body against ``max_examples`` examples drawn from
a ``numpy.random.Generator`` seeded from the test name, so failures are
reproducible run-to-run and the same test bodies work in both
environments.

Only the API surface this repo uses is implemented: ``given``,
``settings``, and ``strategies.{integers, booleans, lists, sampled_from,
composite}``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import types
    import zlib

    import numpy as np

    DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw = draw_fn

        def do_draw(self, rng: np.random.Generator):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    def _sampled_from(elements):
        seq = list(elements)
        return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    def _lists(elements, *, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.do_draw(rng) for _ in range(n)]
        return _Strategy(draw)

    def _composite(fn):
        def build(*args, **kwargs):
            def draw(rng):
                return fn(lambda s: s.do_draw(rng), *args, **kwargs)
            return _Strategy(draw)
        return build

    strategies = types.SimpleNamespace(
        integers=_integers, booleans=_booleans, lists=_lists,
        sampled_from=_sampled_from, composite=_composite)

    def settings(**kwargs):
        def deco(fn):
            fn._shim_settings = kwargs
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            cfg = getattr(fn, "_shim_settings", {})
            n_examples = cfg.get("max_examples", DEFAULT_MAX_EXAMPLES)
            # seed from the test name: deterministic, distinct per test
            seed = zlib.crc32(fn.__qualname__.encode())

            def runner():
                rng = np.random.default_rng(seed)
                for i in range(n_examples):
                    args = [s.do_draw(rng) for s in strats]
                    try:
                        fn(*args)
                    except Exception as e:
                        raise AssertionError(
                            f"{fn.__name__} failed on shim example {i}: "
                            f"args={args!r}") from e

            # zero-arg signature on purpose: pytest must not treat the
            # property arguments as fixtures (so no functools.wraps)
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__module__ = fn.__module__
            runner.__doc__ = fn.__doc__
            runner.hypothesis_shim = True
            return runner
        return deco
