"""Pallas kernel sweeps: shapes x dtypes x ops vs the pure-jnp oracles
(interpret mode on CPU; the same kernels compile on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels.ref import bucket_count_ref, coarse_commit_ref, ssd_chunk_ref

SET = dict(max_examples=15, deadline=None)
RNG = np.random.default_rng(0)


@pytest.mark.parametrize("op", ["min", "max", "add"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.int32])
@pytest.mark.parametrize("v,n", [(64, 32), (513, 1000), (2048, 300),
                                 (100, 4096)])
def test_coarse_commit_sweep(op, dtype, v, n):
    state = jnp.asarray(RNG.integers(-50, 50, v)).astype(dtype)
    idx = jnp.asarray(RNG.integers(-1, v, n), jnp.int32)
    val = jnp.asarray(RNG.integers(-50, 50, n)).astype(dtype)
    out = ops.coarse_commit(state, idx, val, op=op, tile_m=128, block_v=256)
    exp = coarse_commit_ref(state, idx, val, op=op)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-5)


@pytest.mark.parametrize("op", ["or", "first"])
@pytest.mark.parametrize("v,n", [(64, 32), (513, 1000), (100, 4096)])
def test_coarse_commit_or_first_sweep(op, v, n):
    if op == "or":
        state = jnp.asarray(RNG.integers(0, 2, v), jnp.int32)
        val = jnp.asarray(RNG.integers(0, 2, n), jnp.int32)
    else:  # first: negative state = empty slot, payloads non-negative
        state = jnp.asarray(np.where(RNG.random(v) < 0.5, -1,
                                     RNG.integers(0, 50, v)), jnp.int32)
        val = jnp.asarray(RNG.integers(0, 50, n), jnp.int32)
    idx = jnp.asarray(RNG.integers(-1, v, n), jnp.int32)
    out = ops.coarse_commit(state, idx, val, op=op, tile_m=128, block_v=256)
    exp = coarse_commit_ref(state, idx, val, op=op)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_coarse_commit_stats_output():
    """stats=True returns the in-transaction duplicate-target count."""
    from repro.kernels.coarse_commit import coarse_commit_pallas
    state = jnp.zeros((16,), jnp.int32)
    idx = jnp.asarray([1, 1, 2, 3, 3, 3, -1, -1], jnp.int32)
    val = jnp.ones((8,), jnp.int32)
    out, conf = coarse_commit_pallas(state, idx, val, op="add", tile_m=8,
                                     block_v=16, stats=True)
    assert int(conf) == 5  # 2 on vertex 1 + 3 on vertex 3
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(coarse_commit_ref(state, idx, val,
                                                      op="add")))
    # two transactions of 4: the duplicate pair on vertex 3 splits 2|1
    _, conf2 = coarse_commit_pallas(state, idx, val, op="add", tile_m=4,
                                    block_v=16, stats=True)
    assert int(conf2) == 4


@given(st.integers(1, 500), st.integers(2, 300), st.integers(32, 256),
       st.integers(64, 512))
@settings(**SET)
def test_coarse_commit_tile_shapes(n, v, tile_m, block_v):
    """Transaction size M / state block B must not change semantics."""
    state = jnp.asarray(RNG.integers(0, 100, v), jnp.int32)
    idx = jnp.asarray(RNG.integers(-1, v, n), jnp.int32)
    val = jnp.asarray(RNG.integers(0, 100, n), jnp.int32)
    out = ops.coarse_commit(state, idx, val, op="min", tile_m=tile_m,
                            block_v=block_v)
    exp = coarse_commit_ref(state, idx, val, op="min")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("nb", [3, 37, 128, 200])
@pytest.mark.parametrize("n", [17, 512, 2000])
def test_bucket_count(nb, n):
    owner = jnp.asarray(RNG.integers(-1, nb, n), jnp.int32)
    out = ops.bucket_count(owner, num_buckets=nb, tile_m=256)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(bucket_count_ref(owner, nb)))


@pytest.mark.parametrize("g,L,n,p", [(2, 32, 8, 16), (4, 64, 16, 64),
                                     (1, 128, 64, 32)])
def test_ssd_chunk(g, L, n, p):
    C = jnp.asarray(RNG.normal(size=(g, L, n)), jnp.float32)
    B = jnp.asarray(RNG.normal(size=(g, L, n)), jnp.float32)
    x = jnp.asarray(RNG.normal(size=(g, L, p)), jnp.float32)
    a = jnp.asarray(-np.abs(RNG.normal(size=(g, L))) * 0.1, jnp.float32)
    y = ops.ssd_chunk(C, B, x, a)
    ye = jax.vmap(ssd_chunk_ref)(C, B, x, a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ye), atol=1e-4)


def test_ssd_chunk_bf16_inputs():
    g, L, n, p = 2, 32, 8, 16
    C = jnp.asarray(RNG.normal(size=(g, L, n)), jnp.bfloat16)
    B = jnp.asarray(RNG.normal(size=(g, L, n)), jnp.bfloat16)
    x = jnp.asarray(RNG.normal(size=(g, L, p)), jnp.bfloat16)
    a = jnp.asarray(-np.abs(RNG.normal(size=(g, L))) * 0.1, jnp.float32)
    y = ops.ssd_chunk(C, B, x, a)
    ye = jax.vmap(ssd_chunk_ref)(C.astype(jnp.float32),
                                 B.astype(jnp.float32),
                                 x.astype(jnp.float32), a)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(ye),
                               atol=0.15, rtol=0.1)
