"""The continuous-batching serve loop (ISSUE 7): deadline admission is
a pure fake-clock policy, service timing stats read the injected clock
(no wall-clock flake), queries board running waves with bit-identical
answers on every backend, re-registration mid-drain defers to the wave
boundary, racing submitters never lose a ticket — even when a fault
injector kills the drain mid-wave and the supervisor restores from
snapshot + WAL — and the open-loop bench rows carry the schema the
trajectory diff expects."""
import threading
import time

import numpy as np
import pytest

from repro.core.commit import CommitSpec
from repro.graphs.generators import erdos_renyi, kronecker
from repro.serve.continuous import ContinuousServer, DeadlineAdmission
from repro.serve.graph_service import GraphService
from repro.serve.queries import (BfsQuery, ColoringQuery, MstQuery,
                                 PprQuery, SsspQuery, StConnQuery)


class FakeClock:
    """Deterministic injected timebase: advances only when told."""

    def __init__(self):
        self.now = 100.0

    def __call__(self) -> float:
        return self.now

    def tick(self, dt: float) -> None:
        self.now += dt


# -- deadline admission (pure, fake clock) ----------------------------------


def test_admission_window_opens_on_first_note():
    adm = DeadlineAdmission(max_wait_s=0.5, max_batch=4)
    assert not adm.due(0.0, 0)
    assert adm.remaining(0.0) == float("inf")
    adm.note(10.0)
    adm.note(10.4)                      # later notes don't extend it
    assert adm.deadline == 10.5
    assert not adm.due(10.49, 1)
    assert adm.due(10.5, 1)


def test_admission_batch_cap_fires_early():
    adm = DeadlineAdmission(max_wait_s=100.0, max_batch=3)
    adm.note(0.0)
    assert not adm.due(0.1, 2)
    assert adm.due(0.1, 3)              # full batch beats the deadline


def test_admission_reset_closes_window():
    adm = DeadlineAdmission(max_wait_s=0.5)
    adm.note(1.0)
    adm.reset()
    assert adm.deadline is None and not adm.due(99.0, 1)
    assert adm.remaining(99.0) == float("inf")


def test_service_timing_stats_read_injected_clock():
    """ServiceStats drain timing comes from the injected clock — exact
    values, no wall-clock flake.  (The latent flake this PR fixes:
    timing fields used to be unpinnable.)"""
    class SteppingClock(FakeClock):
        def __call__(self):
            self.now += 0.25            # every read advances 250ms
            return self.now

    svc = GraphService(clock=SteppingClock())
    svc.register_graph("g", erdos_renyi(30, 3.0, seed=0))
    svc.submit("g", BfsQuery(0))
    svc.drain()
    assert svc.stats.drains == 1
    # drain reads the clock exactly twice: t0 and the finally block
    assert svc.stats.last_drain_s == pytest.approx(0.25)
    assert svc.stats.drain_s == pytest.approx(0.25)
    svc.submit("g", BfsQuery(1))
    svc.drain()
    assert svc.stats.drains == 2
    assert svc.stats.drain_s == pytest.approx(0.5)

    # the plain fake clock pins an idle drain at exactly zero
    svc2 = GraphService(clock=FakeClock())
    svc2.register_graph("g", erdos_renyi(30, 3.0, seed=0))
    svc2.submit("g", BfsQuery(2))
    svc2.drain()
    assert svc2.stats.last_drain_s == 0.0


def test_clock_survives_snapshot_restore():
    clk = FakeClock()
    svc = GraphService(clock=clk)
    svc.register_graph("g", erdos_renyi(20, 3.0, seed=1))
    restored = GraphService.restore(svc.snapshot(), clock=clk)
    assert restored.clock is clk


# -- in-flight insertion parity ---------------------------------------------


def _graphs():
    gs = {"hot": kronecker(5, 6, seed=3)}
    for i in range(2):
        gs[f"t{i}"] = erdos_renyi(30 + 8 * i, 4.0, seed=i)
    return gs


def _probe(kind, g):
    v = g.num_vertices
    return {"bfs": BfsQuery(v // 3), "sssp": SsspQuery(v // 3),
            "ppr": PprQuery(v // 3, iters=6),
            "stconn": StConnQuery(1, v - 2),
            "coloring": ColoringQuery(seed=2), "mst": MstQuery()}[kind]


def _eq(kind, a, b):
    if kind == "stconn":
        assert a == b
    elif kind == "mst":
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        assert float(a[1]) == float(b[1]) and int(a[2]) == int(b[2])
    elif kind == "ppr":
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6)
    else:
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("kind", ("bfs", "sssp", "ppr", "stconn",
                                  "coloring", "mst"))
@pytest.mark.parametrize("backend", ("coarse", "pallas", "auto"))
def test_inflight_submission_parity(kind, backend):
    """A query submitted while the continuous loop is mid-drain (lane
    kinds board the RUNNING product wave; whole-graph kinds catch the
    next cycle) answers exactly as an idle service would, on every
    backend."""
    graphs = _graphs()
    if kind in ("sssp", "mst"):
        from repro.graphs.generators import random_weights
        graphs = {gid: random_weights(g, seed=4)
                  for gid, g in graphs.items()}
    spec = CommitSpec(backend=backend, stats=False)

    idle = GraphService(spec=spec, cache=False)
    for gid, g in graphs.items():
        idle.register_graph(gid, g)
    want = idle.run("t1", [_probe(kind, graphs["t1"])])[0]

    svc = GraphService(spec=spec, cache=False)
    for gid, g in graphs.items():
        svc.register_graph(gid, g)
    with ContinuousServer(svc, max_wait_s=0.01, round_chunk=1) as cs:
        # keep the loop busy with hot-graph lane pressure + tenant work
        busy = [cs.submit("hot", BfsQuery(s)) for s in (1, 5, 9)]
        busy.append(cs.submit("t0", BfsQuery(2)))
        time.sleep(0.02)                 # land mid-drain
        probe = cs.submit("t1", _probe(kind, graphs["t1"]))
        got = cs.result(probe, timeout=300)
        cs.results(busy, timeout=300)
    assert cs.last_error is None
    _eq(kind, got, want)


def test_boarding_joins_running_wave():
    """The boarded query rides the SAME product wave when a cell is
    free: one product wave total, not two."""
    svc = GraphService(cache=False)
    for gid, g in _graphs().items():
        svc.register_graph(gid, g)
    with ContinuousServer(svc, max_wait_s=0.01, round_chunk=1) as cs:
        first = [cs.submit("hot", BfsQuery(s)) for s in (1, 5, 9)]
        first.append(cs.submit("t0", BfsQuery(2)))
        time.sleep(0.02)
        # board while the wave runs: same fuse key, graph already
        # aboard, free cell in the hot column (lane ladder width 4 > 3)
        late = cs.submit("hot", BfsQuery(3))
        cs.results(first + [late], timeout=300)
    assert cs.last_error is None
    assert svc.stats.product_waves == 1


# -- deferred re-registration (the ISSUE-7 bugfix) --------------------------


def test_register_graph_mid_drain_defers_to_boundary():
    """Re-registering a graph while its drain is executing must NOT
    purge/void mid-wave: the in-progress queries answer against the
    graph they were admitted under; the swap (and its invalidation
    sweep) lands at the drain boundary."""
    svc = GraphService()
    svc.register_graph("g", erdos_renyi(50, 4.0, seed=1))
    svc.register_graph("h", erdos_renyi(40, 4.0, seed=2))
    g_new = erdos_renyi(50, 5.0, seed=7)
    seen = {}

    def reg(where, i):
        if i == 0:
            svc.register_graph("g", g_new)
            # the regression: this used to swap (and purge) immediately
            seen["deferred"] = svc._graphs["g"] is not g_new

    svc.fault_injector = reg
    t1 = svc.submit("g", BfsQuery(3))
    t2 = svc.submit("g", BfsQuery(4))
    t3 = svc.submit("h", BfsQuery(1))
    done = svc.drain()
    assert seen["deferred"], "mid-drain registration applied immediately"
    assert svc._graphs["g"] is g_new, "deferred swap never applied"
    assert t1 in done and t2 in done and t3 in done
    # boundary invalidation: g's cache rows (including the ones this
    # very drain produced) are gone, h's survive
    assert not any(k[0] == "g" for k in svc._cache)
    assert any(k[0] == "h" for k in svc._cache)
    # post-boundary submissions answer on the NEW topology
    row = svc.run("g", [BfsQuery(3)])[0]
    from repro.graphs.algorithms.bfs import bfs
    np.testing.assert_array_equal(np.asarray(row),
                                  np.asarray(bfs(g_new, 3).dist))


def test_new_graph_id_registers_immediately_mid_drain():
    svc = GraphService()
    svc.register_graph("g", erdos_renyi(30, 4.0, seed=1))
    fresh = erdos_renyi(20, 3.0, seed=9)

    def reg(where, i):
        if i == 0:
            svc.register_graph("new", fresh)

    svc.fault_injector = reg
    svc.submit("g", BfsQuery(0))
    svc.drain()
    assert svc._graphs["new"] is fresh


# -- concurrency stress (threads × faults × WAL) ----------------------------


@pytest.mark.slow
def test_racing_submitters_with_mid_wave_kill(tmp_path):
    """N submitter threads race submit() against the running drain loop
    while a fault injector kills the drain mid-wave; the supervised
    restore replays the WAL.  Every ticket is answered exactly once and
    every answer is bit-identical to a sequential single-axis run."""
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.serve.durable import ServiceSupervisor

    graphs = _graphs()
    svc = GraphService(cache=False)
    for gid, g in graphs.items():
        svc.register_graph(gid, g)
    sup = ServiceSupervisor(svc, Checkpointer(tmp_path),
                            log=lambda *a: None)
    sup.save()

    kills = {"n": 0}

    def injector(where, i):
        # one kill per drained batch for the first three batches
        if where == "continuous" and kills["n"] < 3 and i % 7 == 3:
            kills["n"] += 1
            raise RuntimeError(f"injected kill #{kills['n']}")

    svc.fault_injector = injector

    N, PER = 4, 6
    tickets: dict[int, tuple] = {}
    tlock = threading.Lock()

    with ContinuousServer(sup, max_wait_s=0.01, round_chunk=1) as cs:
        def submitter(tid):
            rng = np.random.default_rng(tid)
            for j in range(PER):
                gid = ["hot", "t0", "t1"][int(rng.integers(3))]
                q = BfsQuery(int(rng.integers(
                    graphs[gid].num_vertices)))
                t = cs.submit(gid, q)
                with tlock:
                    tickets[t] = (gid, q)
                time.sleep(0.002 * float(rng.random()))

        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(N)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        rows = {t: cs.result(t, timeout=300) for t in tickets}

    assert kills["n"] >= 1, "no kill fired — stress shape regressed"
    # exactly once: every ticket has exactly one publish timestamp
    assert sorted(rows) == sorted(tickets)
    assert sorted(cs.done_at) == sorted(cs.submit_at)
    # bit-identical to a sequential run (restored service may differ
    # object-wise; answers may not)
    seq = GraphService(product=False, cache=False)
    for gid, g in graphs.items():
        seq.register_graph(gid, g)
    for t, (gid, q) in tickets.items():
        np.testing.assert_array_equal(
            np.asarray(rows[t]), np.asarray(seq.run(gid, [q])[0]))


# -- bench schema smoke -----------------------------------------------------


def test_open_loop_bench_rows_schema(tmp_path):
    """BENCH_pr7.json rows from the open-loop bench must carry
    offered_qps/p99_ms inside a valid aam-bench/v1 doc (merge keeps
    other suites)."""
    import json

    from benchmarks.serve_qps import _open_rows_to_json

    rows = [{"kind": "bfs", "mode": m, "offered_qps": 20,
             "achieved_qps": 18.5, "p50_ms": 4.0, "p99_ms": 9.0,
             "mean_ms": 5.0, "n": 40, "product_waves": 7}
            for m in ("product", "single-axis")]
    path = tmp_path / "BENCH_pr7.json"
    path.write_text(json.dumps({
        "schema": "aam-bench/v1", "sizes": "tiny", "platform": "cpu",
        "rows": [{"suite": "fig3", "name": "x", "us_per_call": 1.0}],
        "summary": {}}))
    _open_rows_to_json(rows, str(path))
    doc = json.loads(path.read_text())
    assert doc["schema"] == "aam-bench/v1"
    open_rows = [r for r in doc["rows"] if r["suite"] == "serve_open"]
    assert len(open_rows) == 2
    for r in open_rows:
        assert isinstance(r["offered_qps"], (int, float))
        assert isinstance(r["p99_ms"], (int, float))
        assert isinstance(r["achieved_qps"], (int, float))
        assert r["name"].startswith("serve_open/bfs/")
    # the merge preserved the other suite's rows
    assert any(r["suite"] == "fig3" for r in doc["rows"])
    assert "serve_open" in doc["summary"]


def test_repo_bench_pr7_json_schema():
    """The committed BENCH_pr7.json (make bench-latency) carries the
    open-loop rows."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_pr7.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_pr7.json not generated yet")
    with open(path) as f:
        doc = json.load(f)
    assert doc["schema"] == "aam-bench/v1"
    rows = [r for r in doc["rows"] if r.get("suite") == "serve_open"]
    assert rows, "no serve_open rows — run make bench-latency"
    for r in rows:
        assert "offered_qps" in r and "p99_ms" in r
