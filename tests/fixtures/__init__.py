"""Seeded aamlint violation fixtures.

Each module here plants ONE specific wave-safety violation and exposes
it through the ``LINT_*`` surfaces ``python -m repro.analysis.lint
--module`` consumes.  The tier-1 smoke test asserts the CLI exits
nonzero on each — i.e. the analyzer actually catches the bug class it
claims to.
"""
