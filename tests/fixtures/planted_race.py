"""Planted violation: an in-wave read race.

The round below reads its distance array to build relaxations and then
writes the winners back with a raw ``.at[].min`` scatter instead of
``commit()`` — the exact bug ``commit()`` exists to prevent: XLA's
scatter applies updates in an unspecified order with no conflict
resolution, success telemetry, or sanitizer coverage, so duplicate
targets resolve nondeterministically and the MF success mask the
algorithm needs does not exist.  In hardware this is the "conflicting
access" an HTM transaction would abort on; in the software pipeline
only the analyzer can see it.

A second planted round commits through the FUSED KERNEL but calls
:func:`repro.kernels.fused_wave.fused_route_commit_pallas` raw — no
``jax.named_scope("aam_commit")``, i.e. not through ``commit()`` /
``fused_commit_site``.  The kernel itself resolves in-tile conflicts,
but an unscoped launch bypasses the sanitizer, the success telemetry,
and the fallback envelope checks, so the waverace pass flags in-scope-
less ``pallas_call`` writes exactly like raw scatters.

``aamlint --module tests.fixtures.planted_race`` must exit nonzero.
"""
import jax.numpy as jnp

_V = 16
_SRC = jnp.arange(_V, dtype=jnp.int32)
_DST = (jnp.arange(_V, dtype=jnp.int32) * 5 + 3) % _V


def _racy_round(state):
    dist = state["dist"]
    relax = dist[_SRC] + 1          # read of round state...
    dist2 = dist.at[_DST].min(relax)  # ...raw write to the SAME array
    return {"dist": dist2}


def _unscoped_kernel_round(state):
    from repro.kernels.fused_wave import fused_route_commit_pallas
    dist = state["dist"]
    relax = dist[_SRC] + 1          # read of round state...
    dist2 = fused_route_commit_pallas(   # ...raw kernel launch into it:
        dist, _DST, relax,               # not under aam_commit scope
        op="min", tile_m=8, block_v=8, interpret=True)
    return {"dist": dist2}


LINT_TRACEABLES = (
    ("planted: racy bfs round", _racy_round,
     {"dist": jnp.zeros((_V,), jnp.int32)}),
    ("planted: unscoped fused-kernel commit", _unscoped_kernel_round,
     {"dist": jnp.zeros((_V,), jnp.int32)}),
)
