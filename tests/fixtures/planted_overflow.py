"""Planted violation: an ``L x Vtot`` product axis past the int32 key
space.

The real :class:`repro.core.coalescing.ProductAxis` refuses to
construct past ``MAX_FLAT_KEYS`` (the satellite fix this fixture
guards), so the fixture ships a duck-typed axis with the same fields
but NO constructor guard — exactly what a future refactor that drops
``__post_init__`` (or a hand-rolled axis in serving code) would look
like.  ``aamlint --module tests.fixtures.planted_overflow`` must exit
nonzero: 4096 lanes x a 600M-vertex tenant union needs ~2.4e12 flat
keys, and ``fuse_keys`` int32 arithmetic would wrap silently into
OTHER tenants' vertex ranges.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class UncheckedProductAxis:
    """ProductAxis lookalike without the key-space guard."""
    lanes: int
    sizes: tuple


LINT_AXES = (
    ("planted: ProductAxis(4096, 600 x 1M)",
     UncheckedProductAxis(lanes=4096, sizes=(10 ** 6,) * 600)),
)
