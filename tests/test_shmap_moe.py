"""shard_map MoE (§Perf iteration 2) == SPMD AAM path, on multi-axis
meshes including a 'pod' axis (subprocess with 8 host devices)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

pytestmark = pytest.mark.slow   # spawns 8-device subprocesses

REPO = Path(__file__).resolve().parent.parent

CHILD = """
import json, dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs.archs import ARCHS
from repro.configs.base import smoke_model
from repro.moe import moe_layer

cfg = dataclasses.replace(smoke_model(ARCHS["qwen3-moe-235b-a22b"]),
                          d_model=64, moe_d_ff=32, num_experts=8,
                          experts_per_token=2, capacity_factor=8.0)
p, _ = moe_layer.moe_init(cfg, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (32, cfg.d_model), jnp.float32)
out = {}
for name, mesh in [
    ("2x2", jax.make_mesh((2, 2), ("data", "model"))),
    ("pod2x2x2", jax.make_mesh((2, 2, 2), ("pod", "data", "model"))),
]:
    with mesh:
        y0, m0 = jax.jit(lambda p, x: moe_layer.moe_apply(
            cfg, p, x, impl="aam"))(p, x)
        y1, m1 = jax.jit(lambda p, x: moe_layer.moe_apply(
            cfg, p, x, impl="aam_shmap"))(p, x)
    out[name] = {"diff": float(jnp.max(jnp.abs(y0 - y1))),
                 "drop0": int(m0["moe_dropped"]),
                 "drop1": int(m1["moe_dropped"])}
print("RESULT", json.dumps(out))
"""


def test_shmap_moe_matches_spmd_path_on_multiaxis_meshes():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(CHILD)],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    for mesh, v in out.items():
        assert v["diff"] < 1e-5, (mesh, v)
        assert v["drop0"] == v["drop1"] == 0, (mesh, v)
