"""Checkpointing (crash consistency, retention, elastic restore) and fault
tolerance (supervised restart, straggler watchdog)."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import StragglerWatchdog, TrainSupervisor


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": [jnp.arange(5), {"c": jnp.float32(3.5)}]}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(10, t)
    got, step = ck.restore(jax.eval_shape(lambda: t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), blocking=False)
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_partial_checkpoint_ignored(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree())
    # simulate a crash mid-save: directory without COMMITTED marker
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ck.latest_step() == 5


def test_elastic_restore_new_sharding(tmp_path):
    """Restore onto different device layout (topology-free format)."""
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(3, t)
    shardings = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
    got, _ = ck.restore(jax.eval_shape(lambda: t), shardings=shardings)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_supervisor_restores_after_injected_failure(tmp_path):
    ck = Checkpointer(tmp_path)
    state0 = {"w": jnp.zeros((4,)), "n": jnp.int32(0)}
    ck.save(0, state0)

    def step_fn(state, step, batch):
        return ({"w": state["w"] + 1.0, "n": state["n"] + 1},
                {"loss": float(step)})

    failed = {"done": False}

    def injector(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            raise RuntimeError("node lost")

    sup = TrainSupervisor(ck, save_every=5, max_restarts=3)
    state, final, _ = sup.run(state0, step_fn, lambda s: None,
                              start_step=0, num_steps=12,
                              fail_injector=injector, log=lambda *_: None)
    assert final == 12
    assert sup.restarts == 1
    # replay from step 5 checkpoint: w counts every executed step exactly once
    assert float(state["w"][0]) == 12.0


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(0, {"x": jnp.zeros(())})

    def bad_step(state, step, batch):
        raise RuntimeError("always broken")

    sup = TrainSupervisor(ck, save_every=100, max_restarts=2)
    with pytest.raises(RuntimeError):
        sup.run({"x": jnp.zeros(())}, bad_step, lambda s: None,
                start_step=0, num_steps=5, log=lambda *_: None)


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(threshold=3.0, window=16)
    flagged = []
    for i in range(20):
        wd.observe(i, 0.10)
    assert wd.observe(20, 0.50)      # 5x median -> straggler
    assert not wd.observe(21, 0.12)
    assert wd.stats.flagged == 1
