"""Checkpointing (crash consistency, retention, elastic restore) and fault
tolerance (supervised restart, straggler watchdog)."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.fault_tolerance import StragglerWatchdog, TrainSupervisor


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (16, 8)),
            "b": [jnp.arange(5), {"c": jnp.float32(3.5)}]}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(10, t)
    got, step = ck.restore(jax.eval_shape(lambda: t))
    assert step == 10
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), blocking=False)
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_partial_checkpoint_ignored(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(5, _tree())
    # simulate a crash mid-save: directory without COMMITTED marker
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ck.latest_step() == 5


def test_elastic_restore_new_sharding(tmp_path):
    """Restore onto different device layout (topology-free format)."""
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(3, t)
    shardings = jax.tree.map(
        lambda x: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
    got, _ = ck.restore(jax.eval_shape(lambda: t), shardings=shardings)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_validates_template_against_manifest(tmp_path):
    """The silent zip-truncation bugfix: a template whose leaf names /
    count disagree with the manifest must raise, not restore the wrong
    leaves into right-shaped arrays."""
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(1, t)
    short = {"a": t["a"]}                        # fewer leaves
    with pytest.raises(ValueError, match="does not match the manifest"):
        ck.restore(jax.eval_shape(lambda: short))
    renamed = {"a": t["a"], "z": t["b"]}         # same count, wrong names
    with pytest.raises(ValueError, match="does not match the manifest"):
        ck.restore(jax.eval_shape(lambda: renamed))


def test_restore_validates_shardings_leaf_count(tmp_path):
    """A truncated shardings pytree used to zip-truncate the restore —
    now it raises with the counts."""
    ck = Checkpointer(tmp_path)
    t = _tree()
    ck.save(1, t)
    sh = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    with pytest.raises(ValueError, match="shardings pytree"):
        ck.restore(jax.eval_shape(lambda: t), shardings=[sh])


def test_domain_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    dom_a = {"x": jnp.arange(6), "y": jnp.float32(2.5)}
    dom_b = [jnp.ones((3, 2))]
    ck.save_domains(7, {"alpha": dom_a, "beta": dom_b},
                    versions={"alpha": 2}, meta={"note": "hello"})
    assert ck.domains() == {"alpha": 2, "beta": 1}
    assert ck.meta() == {"note": "hello"}
    got, step = ck.restore_domain("alpha", jax.eval_shape(lambda: dom_a),
                                  expect_version=2)
    assert step == 7
    for a, b in zip(jax.tree.leaves(dom_a), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    arrays, version, _ = ck.load_domain_arrays("beta")
    assert version == 1 and len(arrays) == 1
    np.testing.assert_array_equal(arrays[0], np.ones((3, 2)))
    with pytest.raises(ValueError, match="version"):
        ck.restore_domain("alpha", jax.eval_shape(lambda: dom_a),
                          expect_version=9)
    with pytest.raises(KeyError):
        ck.restore_domain("nope", jax.eval_shape(lambda: dom_a))
    # the legacy restore path refuses domain checkpoints with a pointer
    with pytest.raises(ValueError, match="domain checkpoint"):
        ck.restore(jax.eval_shape(lambda: dom_a))


def test_domain_crash_mid_save_keeps_previous(tmp_path):
    """_pre_commit raising = host dies after the leaves, before the
    COMMITTED marker: the partial step is invisible, the previous
    snapshot intact."""
    ck = Checkpointer(tmp_path)
    ck.save_domains(1, {"d": {"x": jnp.arange(4)}}, meta={"gen": 1})
    with pytest.raises(RuntimeError, match="power cut"):
        ck.save_domains(2, {"d": {"x": jnp.arange(9)}}, meta={"gen": 2},
                        _pre_commit=lambda: (_ for _ in ()).throw(
                            RuntimeError("power cut")))
    assert ck.latest_step() == 1
    assert ck.meta() == {"gen": 1}
    arrays, _, _ = ck.load_domain_arrays("d")
    np.testing.assert_array_equal(arrays[0], np.arange(4))
    ck.save_domains(2, {"d": {"x": jnp.arange(9)}}, meta={"gen": 2})
    assert ck.latest_step() == 2                 # retry lands cleanly


def test_retention_skips_step_pinned_by_concurrent_restore(tmp_path,
                                                           monkeypatch):
    """Regression for the retention-vs-restore race: a save whose
    retention pass runs while a restore is mid-read must not delete the
    pinned step (keep=1 would otherwise reap it)."""
    import repro.checkpoint.checkpointer as CK
    ck = Checkpointer(tmp_path, keep=1)
    t = _tree(2)
    ck.save(2, t)
    orig_load = CK.np.load
    raced = {"done": False}

    def racing_load(path, *a, **kw):
        if not raced["done"]:
            raced["done"] = True
            # a concurrent save's retention fires mid-restore; without
            # the pin it deletes step 2 out from under the reader
            ck.save(3, _tree(3))
        return orig_load(path, *a, **kw)

    monkeypatch.setattr(CK.np, "load", racing_load)
    got, step = ck.restore(jax.eval_shape(lambda: t), step=2)
    assert step == 2 and raced["done"]
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # with the pin released, the next retention pass reaps step 2
    ck.save(4, _tree(4))
    assert ck.all_steps() == [4]


def test_supervisor_restores_after_injected_failure(tmp_path):
    ck = Checkpointer(tmp_path)
    state0 = {"w": jnp.zeros((4,)), "n": jnp.int32(0)}
    ck.save(0, state0)

    def step_fn(state, step, batch):
        return ({"w": state["w"] + 1.0, "n": state["n"] + 1},
                {"loss": float(step)})

    failed = {"done": False}

    def injector(step):
        if step == 7 and not failed["done"]:
            failed["done"] = True
            raise RuntimeError("node lost")

    sup = TrainSupervisor(ck, save_every=5, max_restarts=3)
    state, final, _ = sup.run(state0, step_fn, lambda s: None,
                              start_step=0, num_steps=12,
                              fail_injector=injector, log=lambda *_: None)
    assert final == 12
    assert sup.restarts == 1
    # replay from step 5 checkpoint: w counts every executed step exactly once
    assert float(state["w"][0]) == 12.0


def test_supervisor_gives_up_after_max_restarts(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(0, {"x": jnp.zeros(())})

    def bad_step(state, step, batch):
        raise RuntimeError("always broken")

    sup = TrainSupervisor(ck, save_every=100, max_restarts=2)
    with pytest.raises(RuntimeError):
        sup.run({"x": jnp.zeros(())}, bad_step, lambda s: None,
                start_step=0, num_steps=5, log=lambda *_: None)


def test_straggler_watchdog_flags_slow_steps():
    wd = StragglerWatchdog(threshold=3.0, window=16)
    flagged = []
    for i in range(20):
        wd.observe(i, 0.10)
    assert wd.observe(20, 0.50)      # 5x median -> straggler
    assert not wd.observe(21, 0.12)
    assert wd.stats.flagged == 1
