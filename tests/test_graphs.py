"""Graph algorithms vs networkx / reference oracles (property-based over
generated graph families)."""
import jax.numpy as jnp
import networkx as nx
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.graphs.csr import from_edges
from repro.graphs.generators import (erdos_renyi, grid2d, kronecker,
                                     preferential, random_weights)
from repro.graphs.algorithms.bfs import bfs, bfs_reference
from repro.graphs.algorithms.boruvka import boruvka, mst_reference
from repro.graphs.algorithms.coloring import coloring, validate_coloring
from repro.graphs.algorithms.pagerank import pagerank, pagerank_reference
from repro.graphs.algorithms.sssp import sssp, sssp_reference
from repro.graphs.algorithms.stconn import st_connectivity, st_reference

SET = dict(max_examples=10, deadline=None)
GRAPHS = [
    kronecker(8, 8, seed=1),
    erdos_renyi(300, 6.0, seed=2),
    grid2d(12),
    preferential(200, 3, seed=3),
]


@st.composite
def random_graph(draw):
    n = draw(st.integers(5, 120))
    m = draw(st.integers(0, 400))
    rng = np.random.default_rng(draw(st.integers(0, 1000)))
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return from_edges(src, dst, n, symmetrize=True), \
        draw(st.integers(0, n - 1))


@pytest.mark.parametrize("g", GRAPHS, ids=["kron", "er", "grid", "pref"])
@pytest.mark.parametrize("commit,m", [("atomic", None), ("coarse", None),
                                      ("coarse", 64), ("coarse", 1024)])
def test_bfs_families(g, commit, m):
    src = int(np.argmax(np.asarray(g.degrees)))
    r = bfs(g, src, commit=commit, m=m)
    np.testing.assert_array_equal(np.asarray(r.dist, np.int64),
                                  bfs_reference(g, src))


@given(random_graph())
@settings(**SET)
def test_bfs_property(gs):
    g, src = gs
    if g.num_edges == 0:
        return
    r = bfs(g, src, commit="coarse", m=32)
    np.testing.assert_array_equal(np.asarray(r.dist, np.int64),
                                  bfs_reference(g, src))


@pytest.mark.parametrize("g", GRAPHS, ids=["kron", "er", "grid", "pref"])
def test_pagerank_families(g):
    pr, _ = pagerank(g, iters=15)
    ref = pagerank_reference(g, iters=15)
    assert float(np.abs(np.asarray(pr) - ref).max()) < 1e-5
    assert abs(float(jnp.sum(pr)) - 1.0) < 1e-3


def test_pagerank_atomic_equals_coarse():
    g = GRAPHS[0]
    pa, _ = pagerank(g, iters=10, commit="atomic")
    pc, _ = pagerank(g, iters=10, commit="coarse", m=256)
    np.testing.assert_allclose(np.asarray(pa), np.asarray(pc), atol=1e-6)


@pytest.mark.parametrize("g", GRAPHS, ids=["kron", "er", "grid", "pref"])
def test_sssp_families(g):
    gw = random_weights(g, seed=7)
    src = int(np.argmax(np.asarray(g.degrees)))
    d, _ = sssp(gw, src)
    ref = sssp_reference(gw, src)
    reach = ref < 1e38
    np.testing.assert_allclose(np.asarray(d)[reach], ref[reach], rtol=1e-5)


@pytest.mark.parametrize("g", GRAPHS, ids=["kron", "er", "grid", "pref"])
def test_coloring_families(g):
    col, rounds, failed = coloring(g, seed=11)
    assert not bool(failed)
    assert validate_coloring(g, col)


@given(random_graph())
@settings(**SET)
def test_coloring_property(gs):
    g, _ = gs
    if g.num_edges == 0:
        return
    col, _, failed = coloring(g, seed=3)
    assert not bool(failed) and validate_coloring(g, col)


def test_stconn_connected_and_disconnected():
    g = grid2d(10)
    f, _ = st_connectivity(g, 0, 99)
    assert bool(f) == st_reference(g, 0, 99) is True
    # two disjoint grids
    side = 6
    a = grid2d(side)
    src = np.concatenate([np.asarray(a.src), np.asarray(a.src) + side * side])
    dst = np.concatenate([np.asarray(a.dst), np.asarray(a.dst) + side * side])
    g2 = from_edges(src, dst, 2 * side * side)
    f2, _ = st_connectivity(g2, 0, side * side)
    assert not bool(f2)
    assert not st_reference(g2, 0, side * side)


@pytest.mark.parametrize("g", GRAPHS, ids=["kron", "er", "grid", "pref"])
def test_boruvka_families(g):
    gw = random_weights(g, seed=13)
    _, w, ne, _ = boruvka(gw)
    ref = mst_reference(gw)
    assert abs(float(w) - ref) / max(ref, 1) < 1e-4
    # forest size = V - #components
    G = nx.Graph()
    G.add_nodes_from(range(g.num_vertices))
    G.add_edges_from(zip(np.asarray(g.src).tolist(),
                         np.asarray(g.dst).tolist()))
    ncc = nx.number_connected_components(G)
    assert int(ne) == g.num_vertices - ncc


def test_bfs_conflict_telemetry_nonzero_on_dense_graph():
    """The abort-statistics analogue (paper Tables 3c/3f): dense graphs
    produce duplicate-target messages."""
    g = kronecker(8, 16, seed=5)
    src = int(np.argmax(np.asarray(g.degrees)))
    r = bfs(g, src, commit="coarse", m=128)
    assert int(r.conflicts) > 0
    assert int(r.applied) <= int(r.messages)
