"""Property tests: commit engines == sequential oracle (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.commit import atomic_commit, coarse_commit
from repro.core.messages import make_messages

SET = dict(max_examples=25, deadline=None)


def _oracle(state, tgt, val, valid, op):
    out = np.array(state, copy=True)
    for t, v, ok in zip(tgt, val, valid):
        if not ok:
            continue
        if op == "min":
            out[t] = min(out[t], v)
        elif op == "max":
            out[t] = max(out[t], v)
        elif op == "add":
            out[t] += v
        elif op == "or":
            out[t] = out[t] or True
    return out


@st.composite
def batches(draw):
    v = draw(st.integers(4, 200))
    n = draw(st.integers(1, 300))
    tgt = draw(st.lists(st.integers(0, v - 1), min_size=n, max_size=n))
    val = draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    m = draw(st.sampled_from([None, 1, 7, 32, 1024]))
    sort = draw(st.booleans())
    return v, np.array(tgt), np.array(val), np.array(valid), m, sort


@given(batches(), st.sampled_from(["min", "max", "add"]))
@settings(**SET)
def test_coarse_matches_oracle(b, op):
    v, tgt, val, valid, m, sort = b
    state = np.full(v, 1000 if op == "min" else (-1000 if op == "max" else 0),
                    np.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32), jnp.asarray(val, jnp.int32),
                         jnp.asarray(valid))
    res = coarse_commit(jnp.asarray(state), msgs, op, m=m, sort=sort)
    exp = _oracle(state, tgt, val, valid, op)
    np.testing.assert_array_equal(np.asarray(res.state), exp)


@given(batches(), st.sampled_from(["min", "max", "add"]))
@settings(**SET)
def test_atomic_matches_oracle(b, op):
    v, tgt, val, valid, m, sort = b
    state = np.full(v, 1000 if op == "min" else (-1000 if op == "max" else 0),
                    np.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32), jnp.asarray(val, jnp.int32),
                         jnp.asarray(valid))
    res = atomic_commit(jnp.asarray(state), msgs, op)
    exp = _oracle(state, tgt, val, valid, op)
    np.testing.assert_array_equal(np.asarray(res.state), exp)


@given(batches())
@settings(**SET)
def test_mf_success_winners_cover_changed_vertices(b):
    """MF semantics (paper §3.2.2): each transaction tile commits at most
    one winner per vertex; across sequential tiles a vertex may improve
    repeatedly (like back-to-back HTM transactions), so per vertex the
    successful values are distinct and their minimum is the final state."""
    v, tgt, val, valid, m, sort = b
    state = jnp.full((v,), 1000, jnp.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32),
                         jnp.asarray(val, jnp.int32), jnp.asarray(valid))
    res = coarse_commit(state, msgs, "min", m=m, sort=sort)
    succ = np.asarray(res.success)
    final = np.asarray(res.state)
    changed = set(np.flatnonzero(final != 1000).tolist())
    winners = tgt[succ]
    assert set(winners.tolist()) == changed
    per_vertex: dict[int, list[int]] = {}
    for i in np.flatnonzero(succ):
        per_vertex.setdefault(int(tgt[i]), []).append(int(val[i]))
    for vx, vals in per_vertex.items():
        assert len(set(vals)) == len(vals), "duplicate winning value"
        assert min(vals) == final[vx]


@given(batches())
@settings(**SET)
def test_mf_success_unique_winner_single_transaction(b):
    """With one whole-batch transaction (m=None) there is EXACTLY one
    winner per changed vertex."""
    v, tgt, val, valid, _, sort = b
    state = jnp.full((v,), 1000, jnp.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32),
                         jnp.asarray(val, jnp.int32), jnp.asarray(valid))
    res = coarse_commit(state, msgs, "min", m=None, sort=sort)
    succ = np.asarray(res.success)
    final = np.asarray(res.state)
    changed = np.flatnonzero(final != 1000)
    winners = tgt[succ]
    assert len(set(winners.tolist())) == len(winners)
    assert set(winners.tolist()) == set(changed.tolist())
    for i in np.flatnonzero(succ):
        assert final[tgt[i]] == val[i]


@given(batches())
@settings(**SET)
def test_as_commit_never_fails(b):
    """AS semantics: every valid accumulate succeeds (paper §3.2.2)."""
    v, tgt, val, valid, m, sort = b
    state = jnp.zeros((v,), jnp.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32),
                         jnp.asarray(val, jnp.int32), jnp.asarray(valid))
    res = coarse_commit(state, msgs, "add", m=m, sort=sort)
    np.testing.assert_array_equal(np.asarray(res.success), valid)


def test_first_commit_ties_break_by_arrival_order():
    state = jnp.full((4,), -1, jnp.int32)
    msgs = make_messages(jnp.asarray([2, 2, 2], jnp.int32),
                         jnp.asarray([7, 8, 9], jnp.int32),
                         jnp.ones((3,), bool))
    res = coarse_commit(state, msgs, "first")
    assert int(res.state[2]) == 7
    np.testing.assert_array_equal(np.asarray(res.success), [True, False, False])


def test_conflict_telemetry_counts_duplicates():
    state = jnp.zeros((8,), jnp.float32)
    msgs = make_messages(jnp.asarray([1, 1, 2, 3, 3, 3], jnp.int32),
                         jnp.ones((6,), jnp.float32), jnp.ones((6,), bool))
    res = coarse_commit(state, msgs, "add")
    assert int(res.conflicts) == 5  # 2 on vertex 1 + 3 on vertex 3
