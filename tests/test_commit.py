"""Property tests: commit engines == sequential oracle (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.commit import (BACKENDS, OPS, CommitSpec, atomic_commit,
                               coarse_commit, commit)
from repro.core.messages import make_messages

SET = dict(max_examples=25, deadline=None)


def _oracle(state, tgt, val, valid, op):
    """Sequential reference: one message at a time, in arrival order."""
    out = np.array(state, copy=True)
    for t, v, ok in zip(tgt, val, valid):
        if not ok:
            continue
        if op == "min":
            out[t] = min(out[t], v)
        elif op == "max":
            out[t] = max(out[t], v)
        elif op == "add":
            out[t] += v
        elif op == "or":
            out[t] = max(out[t], int(v != 0))
        elif op == "first":
            if out[t] < 0:
                out[t] = v
    return out


@st.composite
def batches(draw):
    v = draw(st.integers(4, 200))
    n = draw(st.integers(1, 300))
    tgt = draw(st.lists(st.integers(0, v - 1), min_size=n, max_size=n))
    val = draw(st.lists(st.integers(-50, 50), min_size=n, max_size=n))
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    m = draw(st.sampled_from([None, 1, 7, 32, 1024]))
    sort = draw(st.booleans())
    return v, np.array(tgt), np.array(val), np.array(valid), m, sort


@given(batches(), st.sampled_from(["min", "max", "add"]))
@settings(**SET)
def test_coarse_matches_oracle(b, op):
    v, tgt, val, valid, m, sort = b
    state = np.full(v, 1000 if op == "min" else (-1000 if op == "max" else 0),
                    np.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32), jnp.asarray(val, jnp.int32),
                         jnp.asarray(valid))
    res = coarse_commit(jnp.asarray(state), msgs, op, m=m, sort=sort)
    exp = _oracle(state, tgt, val, valid, op)
    np.testing.assert_array_equal(np.asarray(res.state), exp)


@given(batches(), st.sampled_from(["min", "max", "add"]))
@settings(**SET)
def test_atomic_matches_oracle(b, op):
    v, tgt, val, valid, m, sort = b
    state = np.full(v, 1000 if op == "min" else (-1000 if op == "max" else 0),
                    np.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32), jnp.asarray(val, jnp.int32),
                         jnp.asarray(valid))
    res = atomic_commit(jnp.asarray(state), msgs, op)
    exp = _oracle(state, tgt, val, valid, op)
    np.testing.assert_array_equal(np.asarray(res.state), exp)


@given(batches())
@settings(**SET)
def test_mf_success_winners_cover_changed_vertices(b):
    """MF semantics (paper §3.2.2): each transaction tile commits at most
    one winner per vertex; across sequential tiles a vertex may improve
    repeatedly (like back-to-back HTM transactions), so per vertex the
    successful values are distinct and their minimum is the final state."""
    v, tgt, val, valid, m, sort = b
    state = jnp.full((v,), 1000, jnp.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32),
                         jnp.asarray(val, jnp.int32), jnp.asarray(valid))
    res = coarse_commit(state, msgs, "min", m=m, sort=sort)
    succ = np.asarray(res.success)
    final = np.asarray(res.state)
    changed = set(np.flatnonzero(final != 1000).tolist())
    winners = tgt[succ]
    assert set(winners.tolist()) == changed
    per_vertex: dict[int, list[int]] = {}
    for i in np.flatnonzero(succ):
        per_vertex.setdefault(int(tgt[i]), []).append(int(val[i]))
    for vx, vals in per_vertex.items():
        assert len(set(vals)) == len(vals), "duplicate winning value"
        assert min(vals) == final[vx]


@given(batches())
@settings(**SET)
def test_mf_success_unique_winner_single_transaction(b):
    """With one whole-batch transaction (m=None) there is EXACTLY one
    winner per changed vertex."""
    v, tgt, val, valid, _, sort = b
    state = jnp.full((v,), 1000, jnp.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32),
                         jnp.asarray(val, jnp.int32), jnp.asarray(valid))
    res = coarse_commit(state, msgs, "min", m=None, sort=sort)
    succ = np.asarray(res.success)
    final = np.asarray(res.state)
    changed = np.flatnonzero(final != 1000)
    winners = tgt[succ]
    assert len(set(winners.tolist())) == len(winners)
    assert set(winners.tolist()) == set(changed.tolist())
    for i in np.flatnonzero(succ):
        assert final[tgt[i]] == val[i]


@given(batches())
@settings(**SET)
def test_as_commit_never_fails(b):
    """AS semantics: every valid accumulate succeeds (paper §3.2.2)."""
    v, tgt, val, valid, m, sort = b
    state = jnp.zeros((v,), jnp.int32)
    msgs = make_messages(jnp.asarray(tgt, jnp.int32),
                         jnp.asarray(val, jnp.int32), jnp.asarray(valid))
    res = coarse_commit(state, msgs, "add", m=m, sort=sort)
    np.testing.assert_array_equal(np.asarray(res.success), valid)


def test_first_commit_ties_break_by_arrival_order():
    state = jnp.full((4,), -1, jnp.int32)
    msgs = make_messages(jnp.asarray([2, 2, 2], jnp.int32),
                         jnp.asarray([7, 8, 9], jnp.int32),
                         jnp.ones((3,), bool))
    res = coarse_commit(state, msgs, "first")
    assert int(res.state[2]) == 7
    np.testing.assert_array_equal(np.asarray(res.success), [True, False, False])


def test_conflict_telemetry_counts_duplicates():
    state = jnp.zeros((8,), jnp.float32)
    msgs = make_messages(jnp.asarray([1, 1, 2, 3, 3, 3], jnp.int32),
                         jnp.ones((6,), jnp.float32), jnp.ones((6,), bool))
    res = coarse_commit(state, msgs, "add")
    assert int(res.conflicts) == 5  # 2 on vertex 1 + 3 on vertex 3


# ---------------------------------------------------------------------------
# parity matrix: every op x every backend == the sequential oracle
# ---------------------------------------------------------------------------

V_PAR = 61


def _init_state(op, rng):
    if op == "min":
        return np.full(V_PAR, 1000, np.int32)
    if op == "max":
        return np.full(V_PAR, -1000, np.int32)
    if op == "first":
        # mix of empty (-1) and occupied slots
        return np.where(rng.random(V_PAR) < 0.5, -1, 777).astype(np.int32)
    return np.zeros(V_PAR, np.int32)    # add / or


def _parity_batches(op, rng):
    """(name, tgt, val, valid) cases incl. the edge cases."""
    n = 120
    # 'first' encodes empty as negative state => payloads non-negative;
    # 'or' payloads are truth values
    lo = 0 if op == "first" else (-2 if op == "or" else -50)
    hi = 2 if op == "or" else 50
    yield ("random", rng.integers(0, V_PAR, n),
           rng.integers(lo, hi, n), rng.random(n) < 0.8)
    yield ("duplicate_target", np.full(n, 7),
           rng.integers(lo, hi, n), np.ones(n, bool))
    yield ("all_invalid", rng.integers(0, V_PAR, n),
           rng.integers(lo, hi, n), np.zeros(n, bool))
    yield ("empty_batch", np.zeros(0, np.int64), np.zeros(0, np.int64),
           np.zeros(0, bool))


@pytest.mark.parametrize("op", OPS)
def test_parity_matrix(op):
    """All five ops produce bit-identical final state on every backend via
    the single commit() entry point, and identical success masks for
    whole-batch (m=None) transactions."""
    rng = np.random.default_rng(sum(map(ord, op)))
    for name, tgt, val, valid in _parity_batches(op, rng):
        state = _init_state(op, rng)
        exp = _oracle(state, tgt, val, valid, op)
        msgs = make_messages(jnp.asarray(tgt, jnp.int32),
                             jnp.asarray(val, jnp.int32),
                             jnp.asarray(valid))
        success = {}
        for backend in BACKENDS:
            spec = CommitSpec(backend=backend, m=None, tile_m=32)
            res = commit(jnp.asarray(state), msgs, op, spec)
            np.testing.assert_array_equal(
                np.asarray(res.state), exp,
                err_msg=f"{op}/{backend}/{name} state diverges from oracle")
            success[backend] = np.asarray(res.success)
        for backend in BACKENDS[1:]:
            np.testing.assert_array_equal(
                success[BACKENDS[0]], success[backend],
                err_msg=f"{op}/{backend}/{name} success mask diverges")


@pytest.mark.parametrize("op", OPS)
@pytest.mark.parametrize("m", [1, 7, 32])
def test_parity_matrix_tiled(op, m):
    """Transaction size must not change the final state on any backend."""
    rng = np.random.default_rng(17 + m)
    for name, tgt, val, valid in _parity_batches(op, rng):
        state = _init_state(op, rng)
        exp = _oracle(state, tgt, val, valid, op)
        msgs = make_messages(jnp.asarray(tgt, jnp.int32),
                             jnp.asarray(val, jnp.int32),
                             jnp.asarray(valid))
        for backend in BACKENDS:
            res = commit(jnp.asarray(state), msgs, op,
                         CommitSpec(backend=backend, m=m))
            np.testing.assert_array_equal(
                np.asarray(res.state), exp,
                err_msg=f"{op}/{backend}/{name}/m={m} diverges from oracle")


def test_pallas_falls_back_for_unsupported_dtypes():
    """pallas backend silently degrades to coarse on payloads the kernel
    does not take (bool state / vector payloads)."""
    msgs = make_messages(jnp.asarray([0, 1], jnp.int32),
                         jnp.asarray([True, False]))
    res = commit(jnp.zeros((4,), bool), msgs, "or",
                 CommitSpec(backend="pallas"))
    np.testing.assert_array_equal(np.asarray(res.state),
                                  [True, False, False, False])


def test_commit_rejects_unknown_op_and_backend():
    msgs = make_messages(jnp.asarray([0], jnp.int32),
                         jnp.asarray([1], jnp.int32))
    state = jnp.zeros((2,), jnp.int32)
    with pytest.raises(ValueError):
        commit(state, msgs, "xor")
    with pytest.raises(ValueError):
        commit(state, msgs, "min", CommitSpec(backend="cuda"))
