"""Property tests for the coalescing planner (paper §4.2, §5.6): the
bucket plan must partition messages exactly into kept + requeued, count
overflow instead of losing it, and scatter/gather must round-trip — plus
the batch-axis flat-key maps (ISSUE 5): QueryLanes/GraphBatch flatten
must be a bijection onto [0, flat_size) that unflatten inverts."""
import numpy as np
import jax.numpy as jnp

from _hypothesis_compat import given, settings, strategies as st

from repro.core.coalescing import (DENSE_PLANNER_MAX_BUCKETS, GraphBatch,
                                   QueryLanes, bucket_message_ids,
                                   gather_from_buckets, plan_buckets,
                                   plan_buckets_dense, plan_buckets_sorted,
                                   scatter_to_buckets)


@st.composite
def _cases(draw):
    n = draw(st.integers(1, 64))
    nb = draw(st.integers(1, 8))
    cap = draw(st.integers(1, 16))
    owner = draw(st.lists(st.integers(0, nb - 1), min_size=n, max_size=n))
    valid = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    seed = draw(st.integers(0, 2 ** 31 - 1))
    return (np.asarray(owner, np.int32), np.asarray(valid, bool),
            nb, cap, seed)


@settings(max_examples=30)
@given(_cases())
def test_kept_plus_dropped_partitions_valid_exactly(case):
    owner, valid, nb, cap, _ = case
    plan, _ = plan_buckets_sorted(jnp.asarray(owner), jnp.asarray(valid),
                                  nb, cap)
    kept = np.asarray(plan.kept)
    pos = np.asarray(plan.position)
    counts = np.asarray(plan.counts)
    assert not np.any(kept & ~valid)                       # kept ⊆ valid
    assert int(plan.dropped) == int(valid.sum() - kept.sum())
    for b in range(nb):
        in_b = valid & (owner == b)
        assert counts[b] == in_b.sum()
        # capacity C is honored exactly: min(count, C) kept per bucket
        assert (kept & in_b).sum() == min(int(in_b.sum()), cap)
        # kept slots are unique within the bucket and within capacity
        p = pos[kept & in_b]
        assert len(set(p.tolist())) == len(p) and (p < cap).all()
    # the dense O(n·buckets) planner and the sort-based planner agree
    plan2 = plan_buckets(jnp.asarray(owner), jnp.asarray(valid), nb, cap)
    assert np.array_equal(kept, np.asarray(plan2.kept))
    assert np.array_equal(pos[valid], np.asarray(plan2.position)[valid])
    assert int(plan.dropped) == int(plan2.dropped)


@settings(max_examples=20)
@given(st.integers(1, 128), st.integers(1, 200), st.integers(1, 8),
       st.integers(0, 2 ** 31 - 1))
def test_plan_buckets_dispatches_above_dense_threshold(nb_extra, n, cap,
                                                       seed):
    """Above DENSE_PLANNER_MAX_BUCKETS plan_buckets must route to the
    sort-based planner and still produce the SAME stable-rank plan the
    dense one-hot would (positions, counts, kept, dropped — the semantics
    this file pins)."""
    nb = DENSE_PLANNER_MAX_BUCKETS + nb_extra
    rng = np.random.default_rng(seed)
    owner = jnp.asarray(rng.integers(0, nb, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    plan = plan_buckets(owner, valid, nb, cap)          # -> sorted planner
    dense = plan_buckets_dense(owner, valid, nb, cap)   # O(n*nb) reference
    np.testing.assert_array_equal(np.asarray(plan.position),
                                  np.asarray(dense.position))
    np.testing.assert_array_equal(np.asarray(plan.counts),
                                  np.asarray(dense.counts))
    np.testing.assert_array_equal(np.asarray(plan.kept),
                                  np.asarray(dense.kept))
    assert int(plan.dropped) == int(dense.dropped)


@settings(max_examples=30)
@given(_cases())
def test_overflow_is_requeued_never_lost(case):
    owner, valid, nb, cap, _ = case
    pending = valid.copy()
    delivered = np.zeros_like(valid, np.int32)
    for _ in range(len(owner) + 1):
        if not pending.any():
            break
        plan, _ = plan_buckets_sorted(jnp.asarray(owner),
                                      jnp.asarray(pending), nb, cap)
        kept = np.asarray(plan.kept)
        # progress every sub-round: C >= 1 keeps >= 1 message per
        # non-empty bucket, so the requeue loop terminates
        assert kept.sum() > 0
        delivered += kept
        pending &= ~kept
    assert not pending.any()
    # exactly-once delivery over the sub-rounds
    assert np.array_equal(delivered, valid.astype(np.int32))


@settings(max_examples=30)
@given(st.lists(st.integers(1, 60), min_size=1, max_size=7),
       st.integers(0, 2 ** 31 - 1))
def test_graph_batch_flat_key_offset_roundtrip(sizes, seed):
    """GraphBatch.flatten is a bijection from {(g, v): v < sizes[g]}
    onto disjoint contiguous ranges of [0, flat_size); unflatten
    inverts it exactly (heterogeneous sizes, no padding)."""
    ax = GraphBatch(sizes=tuple(sizes))
    assert ax.flat_size == sum(sizes)
    assert ax.offsets == tuple(np.cumsum([0] + sizes[:-1]).tolist())
    rng = np.random.default_rng(seed)
    n = 64
    major = rng.integers(0, len(sizes), n)
    minor = np.asarray([rng.integers(0, sizes[m]) for m in major])
    key = np.asarray(ax.flatten(jnp.asarray(major), jnp.asarray(minor)))
    # in range, and distinct pairs -> distinct keys (disjointness: one
    # commit over flat keys == per-graph commits)
    assert (0 <= key).all() and (key < ax.flat_size).all()
    pairs = set(zip(major.tolist(), minor.tolist()))
    assert len(set(key.tolist())) == len(pairs)
    ma, mi = ax.unflatten(jnp.asarray(key))
    np.testing.assert_array_equal(np.asarray(ma), major)
    np.testing.assert_array_equal(np.asarray(mi), minor)
    # exhaustive bijection onto [0, flat_size)
    all_major = np.repeat(np.arange(len(sizes)), sizes)
    all_minor = np.concatenate([np.arange(s) for s in sizes])
    all_keys = np.asarray(ax.flatten(jnp.asarray(all_major),
                                     jnp.asarray(all_minor)))
    np.testing.assert_array_equal(np.sort(all_keys),
                                  np.arange(ax.flat_size))


@settings(max_examples=30)
@given(st.integers(1, 12), st.integers(1, 80), st.integers(0, 2 ** 31 - 1))
def test_query_lanes_flat_key_roundtrip(lanes, v, seed):
    ax = QueryLanes(lanes, v)
    assert ax.flat_size == lanes * v and ax.wave_width == lanes
    rng = np.random.default_rng(seed)
    major = rng.integers(0, lanes, 50)
    minor = rng.integers(0, v, 50)
    key = ax.flatten(jnp.asarray(major), jnp.asarray(minor))
    assert (np.asarray(key) == major * v + minor).all()
    ma, mi = ax.unflatten(key)
    np.testing.assert_array_equal(np.asarray(ma), major)
    np.testing.assert_array_equal(np.asarray(mi), minor)


@settings(max_examples=30)
@given(_cases())
def test_gather_scatter_roundtrip_is_identity_on_kept(case):
    owner, valid, nb, cap, seed = case
    n = len(owner)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    owner, valid = owner[perm], valid[perm]                # random order
    payload = {"i": jnp.asarray(rng.integers(1, 10 ** 6, n), jnp.int32),
               "f": jnp.asarray(rng.normal(size=n), jnp.float32)}
    plan, _ = plan_buckets_sorted(jnp.asarray(owner), jnp.asarray(valid),
                                  nb, cap)
    kept = np.asarray(plan.kept)
    buf = scatter_to_buckets(plan, payload, nb, cap, fill=0)
    out = gather_from_buckets(buf, plan, cap, fill=-7)
    for k in payload:
        got = np.asarray(out[k])
        want = np.asarray(payload[k])
        assert np.array_equal(got[kept], want[kept])       # identity
        assert (got[~kept] == -7).all()                    # fill elsewhere
    # slot ids map each kept message to exactly one buffer slot
    ids = np.asarray(bucket_message_ids(plan, nb, cap)).reshape(-1)
    ids = ids[ids >= 0]
    assert len(set(ids.tolist())) == len(ids)
    assert set(ids.tolist()) == set(np.flatnonzero(kept).tolist())
