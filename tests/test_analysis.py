"""aamlint static passes: registry checks, key-space bounds, race
detection, and the CLI smoke test (tier-1 gate of ISSUE 8).

The CLI must exit 0 on the shipped algorithms x axis kinds and nonzero
on each seeded violation fixture — that is, the analyzer demonstrably
catches the bug classes it exists for.
"""
import dataclasses

import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis import algebra, keyspace, lint, waverace
from repro.core.coalescing import (MAX_FLAT_KEYS, GraphBatch, ProductAxis,
                                   QueryLanes, require_key_space)


# -- satellite 1: int32 flat-key overflow guard -----------------------------

def test_key_space_boundary():
    """Exactly MAX_FLAT_KEYS is legal; one more raises with a clear
    message (regression for the fuse_keys/flatten3 silent wrap)."""
    assert require_key_space(MAX_FLAT_KEYS, where="x") == MAX_FLAT_KEYS
    with pytest.raises(OverflowError, match="int32 key space"):
        require_key_space(MAX_FLAT_KEYS + 1, where="x")


def test_axis_constructors_guard_key_space():
    # boundary: 2^31 - 2 cells exactly — constructs
    QueryLanes(2, (MAX_FLAT_KEYS // 2))
    with pytest.raises(OverflowError, match="QueryLanes"):
        QueryLanes(2, MAX_FLAT_KEYS // 2 + 1)
    with pytest.raises(OverflowError, match="GraphBatch"):
        GraphBatch((MAX_FLAT_KEYS, 2))
    # the L x Vtot product hazard: each factor fits easily, the product
    # does not
    with pytest.raises(OverflowError, match="L \\* Vtot"):
        ProductAxis(4096, (10 ** 6,) * 600)
    ProductAxis(4, (10 ** 6, 10 ** 6))      # same shapes, sane scale


# -- algebra registry -------------------------------------------------------

def test_algebra_registry_clean():
    assert algebra.check_algebra() == []


def test_algebra_covers_all_commit_ops():
    from repro.core.commit import OPS
    assert set(OPS) <= set(algebra.ALGEBRA)


def test_algebra_catches_bad_declaration(monkeypatch):
    """A stale declaration (add claimed idempotent) must be a finding."""
    bad = dict(algebra.ALGEBRA)
    bad["add"] = dataclasses.replace(algebra.ALGEBRA["add"],
                                     idempotent=True)
    monkeypatch.setattr(algebra, "ALGEBRA", bad)
    found = algebra.check_algebra()
    assert any("'add'" in f and "idempotent" in f for f in found)


def test_no_order_dependent_op_on_fused_waves():
    assert algebra.check_fused_order_dependence() == []


def test_replay_guards_verified():
    assert algebra.check_replay_paths() == []


def test_replay_guard_loss_is_detected(monkeypatch):
    """Rewriting a guard's witness away must produce a finding naming
    the non-idempotent ops at risk."""
    from repro.serve import durable
    broken = tuple(
        dataclasses.replace(s, witness="THIS STRING IS NOT IN THE SOURCE")
        if s.name == "wal-replay" else s
        for s in durable.REPLAY_GUARDS)
    monkeypatch.setattr(durable, "REPLAY_GUARDS", broken)
    found = algebra.check_replay_paths()
    assert len(found) == 1 and "wal-replay" in found[0] \
        and "add" in found[0]


# -- key-space pass ---------------------------------------------------------

def test_keyspace_exhaustive_disjointness():
    for ax in (QueryLanes(3, 11), GraphBatch((4, 9, 2)),
               ProductAxis(3, (4, 9, 2))):
        rep = keyspace.analyze_axis(ax)
        assert rep.ok and rep.disjoint is True


def test_keyspace_flags_colliding_axis():
    """A broken flatten (stride too small) collides cells — the
    exhaustive pass must prove NON-disjointness."""
    @dataclasses.dataclass(frozen=True)
    class Broken:
        lanes: int
        num_vertices: int

        def flatten(self, major, minor):
            # stride V-1 instead of V: lane k overlaps lane k+1
            return jnp.asarray(major) * (self.num_vertices - 1) \
                + jnp.asarray(minor)

    rep = keyspace.analyze_axis(Broken(4, 10))
    assert not rep.ok and any("NOT disjoint" in f for f in rep.findings)


def test_keyspace_flags_overflow_without_evaluating_int32():
    @dataclasses.dataclass(frozen=True)
    class Unchecked:
        lanes: int
        sizes: tuple

    rep = keyspace.analyze_axis(Unchecked(4096, (10 ** 6,) * 600))
    assert not rep.ok and "int32" in rep.findings[0]
    assert rep.flat_size == 4096 * 600 * 10 ** 6     # python ints, no wrap


# -- race pass (unit level; the full catalog runs via the CLI below) --------

def test_race_detector_fires_on_raw_scatter():
    def racy(state):
        d = state["dist"]
        return {"dist": d.at[jnp.arange(8) % 4].min(d[jnp.arange(8)] + 1)}

    rep = waverace.check_traceable("racy", racy,
                                   {"dist": jnp.zeros((8,), jnp.int32)})
    assert not rep.ok and rep.findings[0].primitive == "scatter-min"


def test_race_detector_accepts_commit_route():
    from repro.core.commit import CommitSpec, commit
    from repro.core.messages import make_messages

    def clean(state):
        d = state["dist"]
        res = commit(d, make_messages(jnp.arange(8) % 4,
                                      d[jnp.arange(8)] + 1), "min",
                     CommitSpec(backend="atomic", stats=False))
        return {"dist": res.state}

    rep = waverace.check_traceable("clean", clean,
                                   {"dist": jnp.zeros((8,), jnp.int32)})
    assert rep.ok and rep.commits == 1


def test_race_detector_sees_through_while_loop():
    """Raw writes hidden inside lax.while_loop bodies (where every
    production round loop lives) must still be found."""
    import jax

    def racy_loop(state):
        def body(c):
            d, it = c
            d2 = d.at[jnp.arange(8) % 4].add(d[jnp.arange(8)])
            return d2, it + 1

        d, _ = jax.lax.while_loop(lambda c: c[1] < 3, body,
                                  (state["x"], jnp.zeros((), jnp.int32)))
        return {"x": d}

    rep = waverace.check_traceable("racy-loop", racy_loop,
                                   {"x": jnp.zeros((8,), jnp.int32)})
    assert not rep.ok


# -- CLI smoke (the tier-1 acceptance gate) ---------------------------------

@pytest.fixture(scope="module")
def _autotune_off():
    import os
    old = os.environ.get("REPRO_AUTOTUNE")
    os.environ["REPRO_AUTOTUNE"] = "off"
    yield
    if old is None:
        os.environ.pop("REPRO_AUTOTUNE", None)
    else:
        os.environ["REPRO_AUTOTUNE"] = old


def test_cli_clean_on_shipped_code(_autotune_off):
    """python -m repro.analysis.lint exits 0 over six algorithms x
    {QueryLanes, GraphBatch, ProductAxis} + ProductWave chunks."""
    assert lint.main([]) == 0


def test_cli_bench_schema(_autotune_off):
    assert lint.main(["--skip-waverace", "--bench-schema"]) == 0


def test_cli_catches_planted_overflow(_autotune_off):
    assert lint.main(["--skip-waverace",
                      "--module", "tests.fixtures.planted_overflow"]) == 1


def test_cli_catches_planted_race(_autotune_off):
    assert lint.main(["--skip-waverace",
                      "--module", "tests.fixtures.planted_race"]) == 1
