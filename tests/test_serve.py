"""Batch-axis serving tests (ISSUE 4 lanes + ISSUE 5 graph batches).

Four layers:

* the QueryLanes parity matrix — ``multi_source_*`` with L lanes must
  equal L looped single-query runs bit-for-bit (float ``add`` to
  rounding) on every commit backend including ``auto``, and the 1-shard
  ``run_distributed`` lane path must match the single-shard fused loops
  (the 8-device version lives in tests/test_distributed.py under the
  ``slow`` marker);
* the GraphBatch parity matrix — ``batched_over_graphs_*`` for all SIX
  algorithms (including coloring and Boruvka, which have no lane form)
  must equal the looped single-graph runs on every backend, single-shard
  and through the 1-device ``run_distributed`` union path;
* the GraphService batching layer — admission/axis choice, per-axis
  ladder padding, in-flight dedup, result cache, re-registration
  invalidation, telemetry counters;
* the satellites — per-op/axis-width autotune calibration keys, the
  persistent calibration cache, and ``capacity="auto"``
  overflow-feedback sizing.
"""
import dataclasses
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune as AT
from repro.core.commit import (BACKENDS, CommitSpec, commit, commit_batched,
                               commit_lanes)
from repro.core.coalescing import GraphBatch, QueryLanes
from repro.core.messages import batch_messages, lane_messages, make_messages
from repro.graphs.csr import GraphSet
from repro.graphs.generators import (erdos_renyi, grid2d, kronecker,
                                     random_weights)
from repro.graphs.algorithms import bfs as B
from repro.graphs.algorithms import boruvka as BO
from repro.graphs.algorithms import coloring as CO
from repro.graphs.algorithms import pagerank as PR
from repro.graphs.algorithms import sssp as S
from repro.graphs.algorithms import stconn as ST

ALL_BACKENDS = BACKENDS + ("auto",)


def _graphs():
    return [("kron", kronecker(7, 8, seed=3)),
            ("uniform", erdos_renyi(150, 5.0, seed=9))]


def _sources(g, n=4):
    deg = np.asarray(g.degrees)
    picks = [int(np.argmax(deg)), 0, min(5, g.num_vertices - 1),
             int(np.argmin(deg))]
    return np.asarray(picks[:n], np.int32)


# ---------------------------------------------------------------------------
# commit_lanes / lane_messages: the composite-key layer itself
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_commit_lanes_equals_per_lane_commits(backend):
    """One composite-key commit == L independent commits, every backend."""
    rng = np.random.default_rng(0)
    lanes, v, n = 4, 33, 80
    state = jnp.asarray(rng.integers(0, 1000, (lanes, v)), jnp.int32)
    tgt = jnp.asarray(rng.integers(0, v, (lanes, n)), jnp.int32)
    val = jnp.asarray(rng.integers(-50, 50, (lanes, n)), jnp.int32)
    valid = jnp.asarray(rng.random((lanes, n)) < 0.8)
    spec = CommitSpec(backend=backend)
    res = commit_lanes(state, lane_messages(tgt, val, valid, v), "min",
                       spec)
    assert res.state.shape == (lanes, v)
    for l in range(lanes):
        ref = commit(state[l], make_messages(tgt[l], val[l], valid[l]),
                     "min", spec)
        np.testing.assert_array_equal(np.asarray(res.state[l]),
                                      np.asarray(ref.state),
                                      err_msg=f"lane {l} ({backend})")


# ---------------------------------------------------------------------------
# the lane-parity matrix: fused == L looped single-query runs
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("gname,g", _graphs())
def test_multi_source_bfs_parity(gname, g, backend):
    srcs = _sources(g)
    spec = CommitSpec(backend=backend, stats=False)
    ms = B.multi_source_bfs(g, jnp.asarray(srcs), spec=spec)
    assert ms.dist.shape == (len(srcs), g.num_vertices)
    for l, s in enumerate(srcs):
        one = B.bfs(g, int(s), spec=spec)
        np.testing.assert_array_equal(
            np.asarray(ms.dist[l]), np.asarray(one.dist),
            err_msg=f"{gname}/{backend} lane {l}")


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_multi_source_sssp_parity(backend):
    g = random_weights(kronecker(7, 8, seed=3), seed=4)
    srcs = _sources(g)
    spec = CommitSpec(backend=backend, stats=False)
    dist, _ = S.multi_source_sssp(g, jnp.asarray(srcs), spec=spec)
    for l, s in enumerate(srcs):
        one, _ = S.sssp(g, int(s), spec=spec)
        np.testing.assert_array_equal(np.asarray(dist[l]), np.asarray(one),
                                      err_msg=f"{backend} lane {l}")


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_multi_source_pagerank_parity(backend):
    g = kronecker(7, 8, seed=3)
    srcs = _sources(g)
    spec = CommitSpec(backend=backend, stats=False)
    rank, _ = PR.multi_source_pagerank(g, jnp.asarray(srcs), iters=6,
                                       spec=spec)
    for l, s in enumerate(srcs):
        one, _ = PR.personalized_pagerank(g, int(s), iters=6, spec=spec)
        # float add: the fused commit reorders each lane's accumulate
        # exactly like any transaction-size change -> rounding tolerance
        np.testing.assert_allclose(np.asarray(rank[l]), np.asarray(one),
                                   atol=1e-6, err_msg=f"{backend} lane {l}")
    # per-lane probability mass conserved
    np.testing.assert_allclose(np.asarray(rank.sum(axis=1)),
                               np.ones(len(srcs)), atol=1e-4)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_multi_source_stconn_parity(backend):
    g = kronecker(7, 8, seed=3)
    deg = np.asarray(g.degrees)
    # mix connected, (possibly) disconnected, and s == t lanes
    ss = np.asarray([int(np.argmax(deg)), 0, 5, 9], np.int32)
    ts = np.asarray([3, 0, int(np.argmin(deg)), 17], np.int32)
    spec = CommitSpec(backend=backend)
    found, _ = ST.multi_source_stconn(g, jnp.asarray(ss), jnp.asarray(ts),
                                      spec=spec)
    for l in range(len(ss)):
        ref = ST.st_reference(g, int(ss[l]), int(ts[l]))
        one, _ = ST.st_connectivity(g, int(ss[l]), int(ts[l]), spec=spec)
        assert bool(found[l]) == bool(one) == ref, (backend, l)


def test_multi_source_stconn_disconnected_lane():
    g = erdos_renyi(200, 1.2, seed=7)   # sparse: disconnected components
    deg = np.asarray(g.degrees)
    iso = int(np.argmin(deg))
    found, _ = ST.multi_source_stconn(g, jnp.asarray([0, iso]),
                                      jnp.asarray([3, 0]))
    for l, (a, b) in enumerate([(0, 3), (iso, 0)]):
        assert bool(found[l]) == ST.st_reference(g, a, b), l


def test_st_connectivity_s_equals_t():
    """s == t is connected by the empty path on every entry point."""
    g = kronecker(6, 4, seed=1)
    one, _ = ST.st_connectivity(g, 3, 3)
    multi, _ = ST.multi_source_stconn(g, jnp.asarray([3]), jnp.asarray([3]))
    assert bool(one) and bool(multi[0])


# ---------------------------------------------------------------------------
# single-shard fused loops == 1-shard run_distributed lane path
# ---------------------------------------------------------------------------


def test_multi_source_distributed_matches_single_shard_1dev():
    """The lane-tagged engine path on a 1-device mesh (capacity below the
    hub in-degree forces sub-round requeue of lane-tagged messages)."""
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    g = kronecker(7, 8, seed=3)
    gw = random_weights(g, seed=4)
    srcs = jnp.asarray(_sources(g))
    kw = dict(capacity=64, max_subrounds=256, telemetry=True)

    ms = B.multi_source_bfs(g, srcs)
    dist, _, res = B.distributed_multi_source_bfs(mesh, g, srcs, **kw)
    assert bool(res.delivered_all) and res.subrounds > res.rounds
    np.testing.assert_array_equal(np.asarray(dist), np.asarray(ms.dist))

    md, _ = S.multi_source_sssp(gw, srcs)
    dd, _, res = S.distributed_multi_source_sssp(mesh, gw, srcs, **kw)
    assert bool(res.delivered_all)
    np.testing.assert_array_equal(np.asarray(dd), np.asarray(md))

    mr, _ = PR.multi_source_pagerank(g, srcs, iters=6)
    dr, res = PR.distributed_multi_source_pagerank(mesh, g, srcs, iters=6,
                                                   **kw)
    assert bool(res.delivered_all)
    np.testing.assert_allclose(np.asarray(dr), np.asarray(mr), atol=1e-6)

    ts = jnp.asarray([3, 0, int(np.argmin(np.asarray(g.degrees))), 17],
                     jnp.int32)
    mf, _ = ST.multi_source_stconn(g, srcs, ts)
    df, _, res = ST.distributed_multi_source_stconn(mesh, g, srcs, ts, **kw)
    assert bool(res.delivered_all)
    np.testing.assert_array_equal(np.asarray(df), np.asarray(mf))


# ---------------------------------------------------------------------------
# the GraphBatch parity matrix: one fused wave over G graphs == G loops
# (the QueryLanes half of the 6-alg x backend x axis matrix is the
# multi_source_* section above; coloring/Boruvka exist only on this axis)
# ---------------------------------------------------------------------------


def _tenant_graphs(weighted: bool = False):
    """Four heterogeneous tenants: power-law, uniform, lattice, denser
    power-law — different V, E, degree regimes."""
    gs = [kronecker(5, 4, seed=1), erdos_renyi(50, 3.0, seed=2),
          grid2d(6), kronecker(6, 3, seed=7)]
    if weighted:
        gs = [random_weights(g, seed=i) for i, g in enumerate(gs)]
    return gs


GB_ALGS = ("bfs", "sssp", "ppr", "stconn", "coloring", "boruvka")


def _assert_graph_batch_parity(alg: str, backend: str, mesh=None):
    spec = CommitSpec(backend=backend, stats=False)
    kw = {} if mesh is None else dict(mesh=mesh, capacity=64,
                                      max_subrounds=256)
    graphs = _tenant_graphs(weighted=alg in ("sssp", "boruvka"))
    gs = GraphSet(graphs)
    srcs = [0, 3, 5, 1]
    tag = f"{alg}/{backend}"
    if alg == "bfs":
        rows = B.batched_over_graphs_bfs(gs, srcs, spec=spec, **kw)
        for i, (g, s) in enumerate(zip(graphs, srcs)):
            np.testing.assert_array_equal(
                np.asarray(rows[i]), np.asarray(B.bfs(g, s, spec=spec).dist),
                err_msg=f"{tag} graph {i}")
    elif alg == "sssp":
        rows = S.batched_over_graphs_sssp(gs, srcs, spec=spec, **kw)
        for i, (g, s) in enumerate(zip(graphs, srcs)):
            np.testing.assert_array_equal(
                np.asarray(rows[i]), np.asarray(S.sssp(g, s, spec=spec)[0]),
                err_msg=f"{tag} graph {i}")
    elif alg == "ppr":
        rows = PR.batched_over_graphs_pagerank(gs, srcs, iters=5, spec=spec,
                                               **kw)
        for i, (g, s) in enumerate(zip(graphs, srcs)):
            ref, _ = PR.personalized_pagerank(g, s, iters=5, spec=spec)
            # float add: the fused commit reorders each graph's
            # accumulate like any transaction-size change
            np.testing.assert_allclose(np.asarray(rows[i]), np.asarray(ref),
                                       atol=1e-6, err_msg=f"{tag} graph {i}")
    elif alg == "stconn":
        ts = [7, 7, 0, 0]
        found = ST.batched_over_graphs_stconn(gs, srcs, ts, spec=spec, **kw)
        for i, (g, s, t) in enumerate(zip(graphs, srcs, ts)):
            one, _ = ST.st_connectivity(g, s, t, spec=spec)
            ref = ST.st_reference(g, s, t)
            assert bool(found[i]) == bool(one) == ref, (tag, i)
    elif alg == "coloring":
        colors, _, not_conv = CO.batched_over_graphs_coloring(
            gs, seed=0, spec=spec, **kw)
        for i, g in enumerate(graphs):
            c1, _, nc1 = CO.coloring(g, seed=0, spec=spec)
            np.testing.assert_array_equal(np.asarray(colors[i]),
                                          np.asarray(c1),
                                          err_msg=f"{tag} graph {i}")
            assert bool(not_conv[i]) == bool(nc1), (tag, i)
            assert CO.validate_coloring(g, colors[i]), (tag, i)
    else:   # boruvka
        out, _ = BO.batched_over_graphs_boruvka(gs, spec=spec, **kw)
        for i, g in enumerate(graphs):
            comp1, w1, ne1, _ = BO.boruvka(g, spec=spec)
            comp, w, ne = out[i]
            np.testing.assert_array_equal(np.asarray(comp),
                                          np.asarray(comp1),
                                          err_msg=f"{tag} graph {i}")
            assert float(w) == float(w1) and int(ne) == int(ne1), (tag, i)


@pytest.mark.parametrize("backend", ALL_BACKENDS)
@pytest.mark.parametrize("alg", GB_ALGS)
def test_batched_over_graphs_parity_matrix(alg, backend):
    """All six algorithms x every backend (incl. auto): each batched
    element bit-identical to its unbatched run (ppr to float-add
    rounding)."""
    _assert_graph_batch_parity(alg, backend)


@pytest.mark.parametrize("alg", GB_ALGS)
def test_batched_over_graphs_distributed_1dev(alg):
    """The mesh= union path on a 1-device run_distributed (capacity 64
    forces sub-round requeue of the flat-keyed messages); the 8-device
    version lives in tests/test_distributed.py under `slow`."""
    from repro.launch.mesh import make_host_mesh
    _assert_graph_batch_parity(alg, "coarse", mesh=make_host_mesh(1, 1))


@pytest.mark.parametrize("backend", ALL_BACKENDS)
def test_graph_batch_commit_equals_per_graph_commits(backend):
    """commit_batched over GraphBatch flat keys == per-graph commits,
    every backend — the axis-level disjointness argument itself."""
    rng = np.random.default_rng(1)
    sizes = (17, 33, 8)
    ax = GraphBatch(sizes=sizes)
    states = [jnp.asarray(rng.integers(0, 1000, s), jnp.int32)
              for s in sizes]
    n = 60
    major = jnp.asarray(rng.integers(0, len(sizes), n), jnp.int32)
    minor = jnp.asarray([rng.integers(0, sizes[m]) for m in
                         np.asarray(major)], jnp.int32)
    val = jnp.asarray(rng.integers(-50, 50, n), jnp.int32)
    valid = jnp.asarray(rng.random(n) < 0.8)
    spec = CommitSpec(backend=backend)
    msgs = batch_messages(ax, major, minor, val, valid)
    res = commit_batched(jnp.concatenate(states), msgs, "min", spec,
                         axis=ax)
    offs = ax.offsets
    for gi, s in enumerate(sizes):
        mask = np.asarray(major) == gi
        ref = commit(states[gi],
                     make_messages(minor[mask], val[mask], valid[mask]),
                     "min", spec)
        np.testing.assert_array_equal(
            np.asarray(res.state[offs[gi]:offs[gi] + s]),
            np.asarray(ref.state), err_msg=f"graph {gi} ({backend})")


# ---------------------------------------------------------------------------
# GraphService: admission, lane ladder, dedup, cache
# ---------------------------------------------------------------------------


def _service(**kw):
    from repro.serve.graph_service import GraphService
    kw.setdefault("spec", CommitSpec(backend="coarse", stats=False))
    return GraphService(**kw)


def test_service_batches_pads_and_answers_correctly():
    from repro.serve.queries import BfsQuery
    g = kronecker(7, 8, seed=3)
    svc = _service(max_lanes=4)
    svc.register_graph("g", g)
    qs = [BfsQuery(int(s)) for s in (0, 5, 9)]      # 3 queries -> 4 lanes
    out = svc.run("g", qs)
    for q, row in zip(qs, out):
        ref = B.bfs(g, q.source, spec=svc.spec)
        np.testing.assert_array_equal(np.asarray(row), np.asarray(ref.dist))
    assert svc.stats.waves == 1
    assert svc.stats.lanes_executed == 4            # padded up the ladder
    assert svc.stats.lanes_padded == 1
    assert svc.pending() == 0


def test_service_lane_ladder_bounds_jit_shapes():
    from repro.serve.graph_service import _lane_ladder
    assert _lane_ladder(8) == (1, 2, 4, 8)
    assert _lane_ladder(1) == (1,)
    with pytest.raises(ValueError):
        _service(max_lanes=6)


def test_service_chunks_above_max_lanes():
    from repro.serve.queries import BfsQuery
    g = kronecker(6, 4, seed=1)
    svc = _service(max_lanes=2)
    svc.register_graph("g", g)
    out = svc.run("g", [BfsQuery(i) for i in range(5)])  # 2 + 2 + 1 lanes
    assert svc.stats.waves == 3
    assert svc.stats.lanes_executed == 5
    for i, row in enumerate(out):
        np.testing.assert_array_equal(
            np.asarray(row), np.asarray(B.bfs(g, i, spec=svc.spec).dist))


def test_service_cache_and_inflight_dedup():
    from repro.serve.queries import BfsQuery
    g = kronecker(6, 4, seed=1)
    svc = _service(max_lanes=4)
    svc.register_graph("g", g)
    t1 = svc.submit("g", BfsQuery(2))
    t2 = svc.submit("g", BfsQuery(2))        # in-flight duplicate
    assert svc.stats.deduped == 1 and svc.pending() == 1
    svc.drain()
    assert svc.stats.waves == 1 and svc.stats.lanes_executed == 1
    np.testing.assert_array_equal(np.asarray(svc.result(t1)),
                                  np.asarray(svc.result(t2)))
    t3 = svc.submit("g", BfsQuery(2))        # cache hit: no new wave
    assert svc.stats.cache_hits == 1
    np.testing.assert_array_equal(np.asarray(svc.result(t3)),
                                  np.asarray(svc.result(t1)))
    assert svc.pending() == 0 and svc.stats.waves == 1


def test_service_mixed_kinds_and_fuse_keys():
    """Different kinds (and different PPR static knobs) never share a
    wave; same-kind queries do."""
    from repro.serve.queries import BfsQuery, PprQuery, StConnQuery
    g = kronecker(6, 4, seed=1)
    svc = _service(max_lanes=4)
    svc.register_graph("g", g)
    tickets = [svc.submit("g", q) for q in (
        BfsQuery(0), PprQuery(0, iters=4), BfsQuery(3),
        PprQuery(5, iters=8), StConnQuery(0, 9), PprQuery(1, iters=4))]
    svc.drain()
    # bfs{0,3} fuse; ppr iters=4 {0,1} fuse; ppr iters=8 alone; stconn alone
    assert svc.stats.waves == 4
    ref, _ = PR.personalized_pagerank(g, 5, iters=8, spec=svc.spec)
    np.testing.assert_allclose(np.asarray(svc.result(tickets[3])),
                               np.asarray(ref), atol=1e-6)
    assert svc.result(tickets[4]) == ST.st_reference(g, 0, 9)


def test_service_distributed_execution_1dev():
    """mesh= routes waves through the distributed harness (1-device mesh
    in-process) with capacity="auto"; answers match single-shard runs."""
    from repro.launch.mesh import make_host_mesh
    from repro.serve.queries import BfsQuery, StConnQuery
    g = kronecker(6, 4, seed=1)
    svc = _service(max_lanes=2, mesh=make_host_mesh(1, 1),
                   capacity="auto")
    svc.register_graph("g", g)
    out = svc.run("g", [BfsQuery(0), BfsQuery(7), StConnQuery(0, 9)])
    for src, row in zip((0, 7), out):
        np.testing.assert_array_equal(
            np.asarray(row), np.asarray(B.bfs(g, src, spec=svc.spec).dist))
    assert out[2] == ST.st_reference(g, 0, 9)


def test_lane_key_fuse_split_roundtrip():
    from repro.core.coalescing import fuse_lane_keys, split_lane_keys
    rng = np.random.default_rng(3)
    major = jnp.asarray(rng.integers(0, 97, 50), jnp.int32)
    minor = jnp.asarray(rng.integers(0, 13, 50), jnp.int32)
    key = fuse_lane_keys(major, minor, 13)
    ma, mi = split_lane_keys(key, 13)
    np.testing.assert_array_equal(np.asarray(ma), np.asarray(major))
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(minor))


def test_service_rejects_out_of_range_vertices():
    """Admission is the error boundary: under jit an out-of-range source
    would be silently dropped by the scatter (all-INF answer, cached)."""
    from repro.serve.queries import BfsQuery, StConnQuery
    g = kronecker(5, 4, seed=0)          # V=32
    svc = _service()
    svc.register_graph("g", g)
    with pytest.raises(ValueError):
        svc.submit("g", BfsQuery(g.num_vertices))
    with pytest.raises(ValueError):
        svc.submit("g", StConnQuery(0, -1))
    svc.submit("g", BfsQuery(g.num_vertices - 1))    # boundary ok


def test_service_result_retention_is_bounded():
    from repro.serve.queries import BfsQuery
    g = kronecker(5, 4, seed=0)
    svc = _service(max_lanes=2, max_results=3, max_cache=2)
    svc.register_graph("g", g)
    tickets = [svc.submit("g", BfsQuery(i)) for i in range(6)]
    svc.drain()
    assert len(svc._results) == 3 and len(svc._cache) == 2
    svc.result(tickets[-1])                      # newest retained
    with pytest.raises(KeyError):
        svc.result(tickets[0])                   # oldest evicted


def test_service_rejects_unknown_graph_and_pending_result():
    from repro.serve.queries import BfsQuery
    svc = _service()
    with pytest.raises(KeyError):
        svc.submit("nope", BfsQuery(0))
    svc.register_graph("g", kronecker(5, 4, seed=0))
    t = svc.submit("g", BfsQuery(0))
    with pytest.raises(KeyError):
        svc.result(t)                        # not drained yet
    svc.drain()
    svc.result(t)


def test_service_mixed_axes_routing():
    """Axis choice at drain with the product axis OFF: same-graph
    requests fuse as lanes, same-kind single requests across graphs
    fuse as a graph batch, and the whole-graph kinds (coloring, mst)
    ride the graph axis they finally have.  (With the default
    ``product=True`` the mixed bfs group fuses as ONE lanes×graphs
    product wave instead — tests/test_product_axis.py.)"""
    from repro.serve.queries import BfsQuery, ColoringQuery, MstQuery
    g1, g2, g3 = (kronecker(6, 4, seed=1), erdos_renyi(60, 3.0, seed=2),
                  kronecker(5, 4, seed=9))
    svc = _service(max_lanes=4, max_graphs=4, product=False)
    for gid, g in (("a", g1), ("b", g2), ("c", g3)):
        svc.register_graph(gid, g)
    ta = [svc.submit("a", BfsQuery(s)) for s in (0, 1, 2)]   # lane wave
    tb = svc.submit("b", BfsQuery(5))                        # graph batch
    tc = svc.submit("c", BfsQuery(7))
    tcol = [svc.submit(gid, ColoringQuery()) for gid in ("a", "b", "c")]
    tmst = svc.submit("b", MstQuery())
    svc.drain()
    assert svc.stats.waves == 1                  # bfs{a x3} as lanes
    assert svc.stats.graph_waves == 3            # bfs{b,c}, coloring, mst
    assert svc.stats.graphs_padded == 1          # coloring 3 -> ladder 4
    for t, s in zip(ta, (0, 1, 2)):
        np.testing.assert_array_equal(
            np.asarray(svc.result(t)),
            np.asarray(B.bfs(g1, s, spec=svc.spec).dist))
    np.testing.assert_array_equal(
        np.asarray(svc.result(tb)),
        np.asarray(B.bfs(g2, 5, spec=svc.spec).dist))
    np.testing.assert_array_equal(
        np.asarray(svc.result(tc)),
        np.asarray(B.bfs(g3, 7, spec=svc.spec).dist))
    for t, g in zip(tcol, (g1, g2, g3)):
        c1, _, _ = CO.coloring(g, seed=0)
        np.testing.assert_array_equal(np.asarray(svc.result(t)),
                                      np.asarray(c1))
    comp, w, ne = svc.result(tmst)
    bcomp, bw, bne, _ = BO.boruvka(g2)
    np.testing.assert_array_equal(np.asarray(comp), np.asarray(bcomp))
    assert float(w) == float(bw) and int(ne) == int(bne)


def test_service_graph_ladder_and_chunking():
    """> max_graphs single-query tenants chunk into several graph waves,
    each padded up the graph ladder; results stay per-tenant correct."""
    from repro.serve.queries import BfsQuery
    graphs = [kronecker(5, 4, seed=i) for i in range(5)]
    svc = _service(max_graphs=2)
    for i, g in enumerate(graphs):
        svc.register_graph(i, g)
    tickets = [svc.submit(i, BfsQuery(0)) for i in range(5)]
    svc.drain()
    assert svc.stats.graph_waves == 3            # 2 + 2 + 1
    assert svc.stats.graphs_batched == 5 and svc.stats.graphs_padded == 0
    for i, t in enumerate(tickets):
        np.testing.assert_array_equal(
            np.asarray(svc.result(t)),
            np.asarray(B.bfs(graphs[i], 0, spec=svc.spec).dist))
    with pytest.raises(ValueError):
        _service(max_graphs=3)


def test_service_reregister_invalidates_cache_and_inflight():
    """The re-registration bugfix: different topology under the same
    graph_id must never serve answers computed on the old graph —
    cached rows are purged, queued tickets void (KeyError), and
    same-topology re-registration keeps the cache warm."""
    from repro.serve.queries import BfsQuery
    g_old = kronecker(6, 4, seed=1)
    g_new = kronecker(6, 4, seed=42)             # same V, different edges
    svc = _service(max_lanes=2)
    svc.register_graph("g", g_old)
    svc.run("g", [BfsQuery(0)])                  # populates the cache
    t_inflight = svc.submit("g", BfsQuery(3))    # queued, not drained
    svc.register_graph("g", g_new)
    assert svc.stats.invalidated == 1
    with pytest.raises(KeyError):
        svc.result(t_inflight)                   # voided forever
    t = svc.submit("g", BfsQuery(0))             # would have been a stale hit
    assert svc.stats.cache_hits == 0
    svc.drain()
    np.testing.assert_array_equal(
        np.asarray(svc.result(t)),
        np.asarray(B.bfs(g_new, 0, spec=svc.spec).dist))
    svc.register_graph("g", g_new)               # same topology: no purge
    svc.submit("g", BfsQuery(0))
    assert svc.stats.cache_hits == 1 and svc.stats.invalidated == 1


# ---------------------------------------------------------------------------
# satellite: per-op / axis-width calibration keys
# ---------------------------------------------------------------------------


def test_autotune_calibration_is_per_op(tmp_path, monkeypatch):
    """`add` (MXU path) and vector payloads get their own affine fits:
    the fit cache — in-memory and on disk — is keyed by (op, payload
    dtype, payload width), not just the knob set."""
    monkeypatch.setenv(AT._CACHE_ENV, str(tmp_path / "c.json"))
    t = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    base = dict(sort=True, stats=False, tile_m=64, block_v=128,
                interpret=None, with_pallas=False)
    c_min = t.calibrate(**base)
    c_add = t.calibrate(op="add", dtype=jnp.float32, **base)
    c_vec = t.calibrate(op="add", dtype=jnp.float32, width=4, **base)
    assert c_min is not c_add and c_add is not c_vec
    keys = list(json.loads((tmp_path / "c.json").read_text())["entries"])
    assert len(keys) == 3
    assert any("op=add|dtype=float32|w=1" in k for k in keys)
    assert any("op=add|dtype=float32|w=4" in k for k in keys)
    assert any("op=min|dtype=int32|w=1" in k for k in keys)


def test_autotune_race_key_records_axis_width(tmp_path, monkeypatch):
    """The race is re-run (and cached) per batch-axis width: a fused
    8-wide wave must not inherit the width-1 sort-vs-scatter verdict."""
    monkeypatch.setenv(AT._CACHE_ENV, str(tmp_path / "c.json"))
    t = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    base = dict(sort=True, stats=False, tile_m=64, block_v=128,
                interpret=None)
    finalists = {"coarse": None, "atomic": None}
    w1 = t.race(finalists, 64, **base)
    w8 = t.race(finalists, 64, axis_width=8, **base)
    assert w1 in finalists and w8 in finalists
    race_keys = [k for k in t._cache if k[0] == "race"]
    assert len(race_keys) == 2               # distinct cache rows per width
    dkeys = list(json.loads((tmp_path / "c.json").read_text())["entries"])
    assert any("|aw=1|" in k for k in dkeys)
    assert any("|aw=8|" in k for k in dkeys)


def test_policy_for_reads_payload_dtype_and_width():
    """policy_for must hand the tuner the payload's op/dtype/width so
    vector-payload callers calibrate their own workload."""
    state = jnp.zeros((64, 4), jnp.float32)
    msgs = make_messages(jnp.asarray([1, 2], jnp.int32),
                         jnp.zeros((2, 4), jnp.float32))
    monkey_calls = {}
    tuner = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    orig = tuner.policy

    def spy(spec, **kw):
        monkey_calls.update(kw)
        return orig(spec, **kw)

    tuner.policy = spy
    AT.policy_for(CommitSpec(backend="auto"), state, msgs, op="add",
                  tuner=tuner, axis_width=3)
    assert monkey_calls["op"] == "add"
    assert jnp.dtype(monkey_calls["dtype"]) == jnp.float32
    assert monkey_calls["width"] == 4 and monkey_calls["axis_width"] == 3


# ---------------------------------------------------------------------------
# satellite: persistent autotune calibration cache
# ---------------------------------------------------------------------------


def test_autotune_cache_persists_across_tuners(tmp_path, monkeypatch):
    path = tmp_path / "cache.json"
    monkeypatch.setenv(AT._CACHE_ENV, str(path))
    t1 = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    c1 = t1.calibrate(sort=True, stats=False, tile_m=64, block_v=128,
                      interpret=None, with_pallas=False)
    doc = json.loads(path.read_text())
    assert doc["schema"] == AT.CACHE_SCHEMA and doc["entries"]
    # a fresh tuner (fresh process stand-in) must load the fits from disk
    # without running a single timed micro-commit
    t2 = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    monkeypatch.setattr(t2, "_time", lambda *a: pytest.fail(
        "timed micro-commit ran despite a warm disk cache"))
    c2 = t2.calibrate(sort=True, stats=False, tile_m=64, block_v=128,
                      interpret=None, with_pallas=False)
    assert c2.tiers == c1.tiers and c2.fine == c1.fine


def test_autotune_cache_off_and_corrupt(tmp_path, monkeypatch):
    # escape hatch: no file is written
    monkeypatch.setenv(AT._CACHE_ENV, "off")
    t = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    t.calibrate(sort=True, stats=False, tile_m=64, block_v=128,
                interpret=None, with_pallas=False)
    assert not list(tmp_path.iterdir())
    # a corrupt cache file is ignored, never fatal
    path = tmp_path / "corrupt.json"
    path.write_text("{not json")
    monkeypatch.setenv(AT._CACHE_ENV, str(path))
    t2 = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    cal = t2.calibrate(sort=True, stats=False, tile_m=64, block_v=128,
                       interpret=None, with_pallas=False)
    assert cal.fine.slope >= 0
    # and gets overwritten with a valid one
    assert json.loads(path.read_text())["schema"] == AT.CACHE_SCHEMA


def test_autotune_cache_keys_include_device_kind(tmp_path, monkeypatch):
    monkeypatch.setenv(AT._CACHE_ENV, str(tmp_path / "c.json"))
    t = AT.AutoTuner(ns=(4, 16), v_cal=256, repeats=1, warmup=0)
    t.calibrate(sort=True, stats=False, tile_m=64, block_v=128,
                interpret=None, with_pallas=False)
    import jax
    doc = json.loads((tmp_path / "c.json").read_text())
    assert all(k.split("|")[1].startswith(jax.default_backend())
               for k in doc["entries"])


# ---------------------------------------------------------------------------
# satellite: capacity="auto" overflow-feedback sizing
# ---------------------------------------------------------------------------


def test_capacity_auto_grows_on_persistent_overflow():
    from repro.core import engine as E
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    g = kronecker(7, 8, seed=3)
    src = int(np.argmax(np.asarray(g.degrees)))
    key = (g.num_vertices, g.num_edges, 1)
    old = E._CAPACITY_CACHE.pop(key, None)
    try:
        E._CAPACITY_CACHE[key] = 64          # force overflow on run 1
        d1, _, r1 = B.distributed_bfs(mesh, g, src, capacity="auto",
                                   max_subrounds=256, telemetry=True)
        d2, _, r2 = B.distributed_bfs(mesh, g, src, capacity="auto",
                                   max_subrounds=256, telemetry=True)
        ref = B.bfs_reference(g, src)
        for d, r in ((d1, r1), (d2, r2)):
            assert bool(r.delivered_all)
            np.testing.assert_array_equal(np.asarray(d, np.int64), ref)
        assert int(r1.capacity) == 64
        assert int(r2.capacity) > int(r1.capacity)     # telemetry grew C
        assert int(r2.subrounds) < int(r1.subrounds)
    finally:
        E._CAPACITY_CACHE.pop(key, None)
        if old is not None:
            E._CAPACITY_CACHE[key] = old


def test_capacity_auto_heuristic_bounds():
    from repro.core import engine as E
    g = kronecker(6, 4, seed=1)
    for p in (1, 2, 8):
        c = E.auto_capacity(g, p)
        assert E.CAPACITY_MIN <= c <= E.CAPACITY_MAX
        assert c & (c - 1) == 0              # power of two
    # quiet runs leave the cache alone; overflowing runs double it
    key = (g.num_vertices, g.num_edges, 2)
    old = E._CAPACITY_CACHE.pop(key, None)
    try:
        E._capacity_feedback(g, 2, 256, subrounds=10, rounds=10)
        assert key not in E._CAPACITY_CACHE
        E._capacity_feedback(g, 2, 256, subrounds=50, rounds=10)
        assert E._CAPACITY_CACHE[key] == 512
    finally:
        E._CAPACITY_CACHE.pop(key, None)
        if old is not None:
            E._CAPACITY_CACHE[key] = old
