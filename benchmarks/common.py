"""Benchmark helpers: wall-clock timing with warmup + CSV emission."""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup: int = 2, repeats: int = 5) -> float:
    """Median seconds per call (blocks on all outputs)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, seconds: float, derived: str = ""):
    """CSV row: name,us_per_call,derived."""
    print(f"{name},{seconds * 1e6:.1f},{derived}")
