"""Paper Fig 4 — Graph500 BFS runtime vs transaction size M.

IMPORTANT FRAMING (EXPERIMENTS.md §Paper-claims): this container is ONE CPU
core, i.e. the paper's T=1 column.  The paper's own Fig 4a shows that at
T=1 atomics beat HTM at small M and the HTM curve *decreases monotonically
with M* — which is exactly what this benchmark must (and does) reproduce.
The T>1 contention regime, where coarsening overtakes atomics, cannot exist
on one core; it is projected structurally: the conflict depth (max
duplicate-target load per round) is the serialization factor a contended
atomics path pays, while the coarse path pays one conflict-free write per
distinct target after in-tile resolution (the Pallas kernel's VMEM
reduction).  Projected contended speedup ≈ conflict_depth is reported in
the derived column.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.commit import BACKENDS, CommitSpec
from repro.graphs.algorithms.bfs import bfs
from repro.graphs.generators import kronecker

MS = [16, 64, 256, 1024, 4096, 16384, None]


def conflict_depth(g) -> float:
    """Mean over BFS rounds of max duplicate-target messages — the
    serialization depth contended atomics would pay per round."""
    import collections
    from repro.graphs.algorithms.bfs import bfs_reference
    src = int(np.argmax(np.asarray(g.degrees)))
    dist = bfs_reference(g, src)
    dst = np.asarray(g.dst)
    srcs = np.asarray(g.src)
    depths = []
    for level in range(int(dist[dist < 2 ** 29].max()) + 1):
        active = dist[srcs] == level
        if not active.any():
            continue
        tgt = dst[active]
        counts = collections.Counter(tgt.tolist())
        depths.append(max(counts.values()))
    return float(np.mean(depths)) if depths else 1.0


def stats_overhead(g, src, backend: str = "pallas"):
    """Satellite check: commit(stats=False) must beat commit(stats=True)
    (the kernel skips the per-block conflict reduction and its extra
    output on the no-stats path)."""
    t_on = timeit(lambda: bfs(g, src, spec=CommitSpec(
        backend=backend, m=4096, sort=False, stats=True)), repeats=3)
    t_off = timeit(lambda: bfs(g, src, spec=CommitSpec(
        backend=backend, m=4096, sort=False, stats=False)), repeats=3)
    emit(f"fig4/{backend}/stats_overhead", t_on - t_off,
         f"stats_on={t_on*1e6:.0f}us stats_off={t_off*1e6:.0f}us "
         f"nostats_cheaper={t_off < t_on}")
    return t_on, t_off


def main(scale: int = 14, edge_factor: int = 16, backend: str = "coarse"):
    g = kronecker(scale, edge_factor, seed=1)
    src = int(np.argmax(np.asarray(g.degrees)))
    base = CommitSpec(backend="atomic", stats=False)
    t_atomic = timeit(lambda: bfs(g, src, spec=base), repeats=3)
    emit(f"fig4/atomic/V=2^{scale}", t_atomic, "T=1 baseline")
    if backend == "auto":
        # the tuner picks backend + M itself: one calibrated run, no sweep
        spec = CommitSpec(backend="auto", stats=False)
        t = timeit(lambda: bfs(g, src, spec=spec), repeats=3)
        emit("fig4/auto/M=auto", t, f"T1_ratio_vs_atomic={t_atomic/t:.2f}")
        return
    best = (None, float("inf"))
    for m in MS:
        for sort in (True, False):
            spec = CommitSpec(backend=backend, m=m, sort=sort, stats=False)
            t = timeit(lambda spec=spec: bfs(g, src, spec=spec), repeats=3)
            tag = "sorted" if sort else "unsorted"
            name = f"fig4/{backend}/{tag}/M={m or 'inf'}"
            emit(name, t, f"T1_ratio_vs_atomic={t_atomic/t:.2f}")
            if not sort and t < best[1]:
                best = (m, t)
    r = bfs(g, src, spec=CommitSpec(backend=backend, m=best[0], stats=False))
    depth = conflict_depth(g)
    emit("fig4/M_best_T1", best[1],
         f"M={best[0] or 'inf'} T1_ratio={t_atomic/best[1]:.2f} "
         f"conflicts={int(r.conflicts)} msgs={int(r.messages)} "
         f"projected_contended_speedup~{depth:.0f}x")
    stats_overhead(g, src, backend)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS + ("auto",),
                    default="coarse",
                    help="commit backend swept over transaction size M")
    ap.add_argument("--scale", type=int, default=14)
    ap.add_argument("--edge-factor", type=int, default=16)
    args = ap.parse_args()
    main(args.scale, args.edge_factor, args.backend)
