"""Paper Fig 3 + Tables 3c/3f — single-vertex activities under low/high
contention: CAS-analogue (min, May-Fail) vs ACC-analogue (add,
Always-Succeed), fine vs coarse, with conflict telemetry (the abort
statistics analogue)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.commit import atomic_commit, coarse_commit
from repro.core.messages import make_messages

V = 1 << 14
N = 4096  # concurrent "threads" (message lanes)


def main():
    rng = np.random.default_rng(0)
    for contention, reps in (("low", 10), ("high", 100)):
        # N lanes target V/reps distinct vertices => each vertex hit ~reps x
        tgt = jnp.asarray(rng.integers(0, max(N // reps, 1), N), jnp.int32)
        for op, st0 in (("min", jnp.full((V,), 2 ** 30, jnp.int32)),
                        ("add", jnp.zeros((V,), jnp.int32))):
            val = jnp.asarray(rng.integers(0, 100, N), jnp.int32)
            msgs = make_messages(tgt, val, jnp.ones((N,), bool))
            fine = jax.jit(lambda s, m, op=op: atomic_commit(s, m, op).state)
            coarse = jax.jit(
                lambda s, m, op=op: coarse_commit(s, m, op).state)
            tf = timeit(fine, st0, msgs)
            tc = timeit(coarse, st0, msgs)
            res = coarse_commit(st0, msgs, op)
            emit(f"fig3/{op}/{contention}/fine", tf,
                 f"conflicts={int(res.conflicts)}")
            emit(f"fig3/{op}/{contention}/coarse", tc,
                 f"applied={int(res.applied)}")
            # Table 3c/3f analogue: conflict fraction
            emit(f"fig3/{op}/{contention}/conflict_rate", 0.0,
                 f"{int(res.conflicts)/N:.3f}")


if __name__ == "__main__":
    main()
