"""Paper Fig 3 + Tables 3c/3f — single-vertex activities under low/high
contention: CAS-analogue (min, May-Fail) vs ACC-analogue (add,
Always-Succeed), swept over every commit backend via :class:`CommitSpec`,
with conflict telemetry (the abort statistics analogue)."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.commit import BACKENDS, CommitSpec, commit
from repro.core.messages import make_messages

V = 1 << 14
N = 4096  # concurrent "threads" (message lanes)


def main(backends=BACKENDS):
    rng = np.random.default_rng(0)
    for contention, reps in (("low", 10), ("high", 100)):
        # N lanes target V/reps distinct vertices => each vertex hit ~reps x
        tgt = jnp.asarray(rng.integers(0, max(N // reps, 1), N), jnp.int32)
        for op, st0 in (("min", jnp.full((V,), 2 ** 30, jnp.int32)),
                        ("add", jnp.zeros((V,), jnp.int32))):
            val = jnp.asarray(rng.integers(0, 100, N), jnp.int32)
            msgs = make_messages(tgt, val, jnp.ones((N,), bool))
            for backend in backends:
                spec = CommitSpec(backend=backend)
                fn = jax.jit(lambda s, m, op=op, spec=spec:
                             commit(s, m, op, spec).state)
                t = timeit(fn, st0, msgs)
                res = commit(st0, msgs, op, spec)
                emit(f"fig3/{op}/{contention}/{backend}", t,
                     f"conflicts={int(res.conflicts)} "
                     f"applied={int(res.applied)} "
                     f"conflict_rate={int(res.conflicts)/N:.3f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS + ("auto",), default=None,
                    help="restrict to one commit backend (default: sweep)")
    args = ap.parse_args()
    main((args.backend,) if args.backend else BACKENDS)
