"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Figures map to the paper as
documented in DESIGN.md §6; fig5/fig7 spawn child processes with forced
host-device counts (this process keeps 1 device).

  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1]
                                           [--backend atomic|coarse|pallas]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (bench_moe, fig2_perf_model, fig3_single_vertex,
                        fig4_coarsening, fig5_coalescing, fig6_bfs_scale,
                        fig7_scaling, table1_realworld)
from repro.core.commit import BACKENDS

SUITES = {
    "fig2": fig2_perf_model.main,
    "fig3": fig3_single_vertex.main,
    "fig4": fig4_coarsening.main,
    "fig5": fig5_coalescing.main,
    "fig6": fig6_bfs_scale.main,
    "table1": table1_realworld.main,
    "fig7": fig7_scaling.main,
    "moe": bench_moe.main,
}

# suites whose commit mechanism is a first-class CommitSpec axis:
# suite -> kwargs for a single-backend run
BACKEND_AWARE = {
    "fig3": lambda b: {"backends": (b,)},
    "fig4": lambda b: {"backend": b},
    "fig5": lambda b: {"backend": b},
    "fig6": lambda b: {"backend": b},
    "fig7": lambda b: {"backend": b},
    "table1": lambda b: {"backend": b},
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--backend", default=None, choices=BACKENDS,
                    help="commit backend for the backend-aware suites")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for n in names:
        t0 = time.time()
        try:
            if args.backend and n in BACKEND_AWARE:
                SUITES[n](**BACKEND_AWARE[n](args.backend))
            else:
                if args.backend and n not in BACKEND_AWARE:
                    print(f"{n}: --backend not applicable, ignored",
                          file=sys.stderr)
                SUITES[n]()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{n}/SUITE_ERROR,0,")
        print(f"{n}/total_wall,{(time.time() - t0) * 1e6:.0f},",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
