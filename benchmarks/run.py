"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Figures map to the paper as
documented in DESIGN.md §6; fig5/fig7 spawn child processes with forced
host-device counts (this process keeps 1 device).

  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1]
                                           [--backend atomic|coarse|pallas|auto]
                                           [--json BENCH_pr3.json [--sizes tiny]]

``--json`` runs the schema-stable tiny perf matrix (fig4/fig6 sweeps ×
every backend × the calibrated ``auto`` spec) and writes it as JSON — the
persistent bench trajectory every PR appends to and compares against.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import (bench_moe, fig2_perf_model, fig3_single_vertex,
                        fig4_coarsening, fig5_coalescing, fig6_bfs_scale,
                        fig7_scaling, serve_qps, table1_realworld)
from repro.core.commit import BACKENDS

SUITES = {
    "fig2": fig2_perf_model.main,
    "fig3": fig3_single_vertex.main,
    "fig4": fig4_coarsening.main,
    "fig5": fig5_coalescing.main,
    "fig6": fig6_bfs_scale.main,
    "table1": table1_realworld.main,
    "fig7": fig7_scaling.main,
    "moe": bench_moe.main,
    "serve": serve_qps.main,
}

# suites whose commit mechanism is a first-class CommitSpec axis:
# suite -> kwargs for a single-backend run
BACKEND_AWARE = {
    "fig3": lambda b: {"backends": (b,)},
    "fig4": lambda b: {"backend": b},
    "fig5": lambda b: {"backend": b},
    "fig6": lambda b: {"backend": b},
    "fig7": lambda b: {"backend": b},
    "table1": lambda b: {"backend": b},
    "serve": lambda b: {"backend": b},
}


# --json measurement matrix.  "tiny" backs the committed BENCH_*.json
# trajectory; "smoke" is the tier-1 CI schema check (seconds, not minutes).
# fig7 spawns forced-device-count children, so only "tiny" carries it.
SCHEMA = "aam-bench/v1"
JSON_SIZES = {
    "tiny": dict(fig4=dict(scale=10, edge_factor=8, ms=(64, 1024, None)),
                 fig6=dict(scales=(9, 10), densities=(16,), edge_factor=8,
                           density_scale=9),
                 fig3=dict(v=1 << 12, n=2048),
                 fused=dict(v=1 << 12, n=2048, width=4, base=1 << 20),
                 fig7=dict(scale=9, ps=(1, 2, 4), reps=3,
                           backends=("coarse",)),
                 serve=dict(kinds=("bfs", "ppr"), lanes=(1, 8), scale=7,
                            queries=16, repeats=7,
                            gkinds=("bfs", "coloring"), gcounts=(1, 8),
                            gscale=7),
                 backends=("atomic", "coarse", "pallas", "fused", "auto"),
                 repeats=7),
    "smoke": dict(fig4=dict(scale=8, edge_factor=4, ms=(64, None)),
                  fig6=dict(scales=(8,), densities=(4,), edge_factor=4,
                            density_scale=8),
                  fig3=dict(v=1 << 10, n=512),
                  serve=dict(kinds=("bfs",), lanes=(1, 4), scale=7,
                             queries=8, repeats=2,
                             gkinds=("bfs",), gcounts=(1, 4), gscale=6),
                  backends=("atomic", "coarse", "auto"), repeats=2),
}


def _summarize(rows: list) -> dict:
    """Per suite: calibrated-auto time over the best hand-picked static
    spec.

    "Best static spec" is ONE spec summed over the suite's points (what a
    user would actually pin), not a per-point min over every static row —
    the latter is winner's-curse-biased on a noisy host.  The per-point
    worst ratio is kept alongside for transparency."""
    out = {}
    for suite in ("fig4", "fig6"):
        srows = [r for r in rows if r["suite"] == suite
                 and "stats_" not in r["name"]]
        if not srows:
            continue

        def point(r):
            return r["name"].split("/")[1] if suite == "fig6" else "all"

        def spec_id(r):   # fig4 rows are one spec each; fig6 specs span points
            return r["name"] if suite == "fig4" else r["backend"]

        totals: dict = {}
        for r in srows:
            totals[spec_id(r)] = totals.get(spec_id(r), 0.0) \
                + r["us_per_call"]
        auto_keys = [k for k in totals if "auto" in str(k)]
        static = {k: v for k, v in totals.items() if k not in auto_keys}
        if not auto_keys or not static:
            continue
        auto_t = min(totals[k] for k in auto_keys)
        best_k = min(static, key=static.get)
        ratio = auto_t / static[best_k]
        worst_point = max(
            (min(r["us_per_call"] for r in srows
                 if point(r) == p and r["backend"] == "auto")
             / min(r["us_per_call"] for r in srows
                   if point(r) == p and r["backend"] != "auto"))
            for p in {point(r) for r in srows})
        out[suite] = {"auto_over_best_static": round(ratio, 3),
                      "best_static": str(best_k),
                      "worst_point_ratio": round(worst_point, 3),
                      "within_10pct": bool(ratio <= 1.10),
                      "points": len({point(r) for r in srows})}
    return out


def _diff_vs_previous(doc: dict, out_path: str) -> dict | None:
    """Auto-diff the fresh matrix against the most recent previous
    BENCH_*.json next to ``out_path`` (the persistent trajectory).

    Joins rows by name (so suites added later simply don't match) and
    reports the per-suite median current/previous time ratio — median,
    not mean, because one noisy row on a shared host must not flip the
    verdict.  Returns None when there is no usable baseline."""
    import statistics
    from pathlib import Path
    out = Path(out_path).resolve()
    try:
        cands = [p for p in out.parent.glob("BENCH_*.json")
                 if p.resolve() != out]
    except OSError:
        return None
    base = None
    for p in sorted(cands, key=lambda p: p.stat().st_mtime, reverse=True):
        try:
            bdoc = json.loads(p.read_text())
        except (OSError, ValueError):
            continue
        if bdoc.get("schema") == SCHEMA:
            base = (p, bdoc)
            break
    if base is None:
        return None
    prev = {r["name"]: r["us_per_call"]
            for r in base[1].get("rows", []) if r.get("us_per_call")}
    suites: dict = {}
    for r in doc["rows"]:
        p_us = prev.get(r["name"])
        if p_us:
            suites.setdefault(r["suite"], []).append(
                r["us_per_call"] / p_us)
    return {
        "baseline": base[0].name,
        "rows_compared": sum(len(v) for v in suites.values()),
        "suites": {s: {"median_ratio": round(statistics.median(v), 3),
                       "rows": len(v)}
                   for s, v in sorted(suites.items())},
    }


def _measure_interleaved(fns: dict, repeats: int, inner: int = 3) -> dict:
    """min-of-repeats per entry, measured ROUND-ROBIN so every entry sees
    the same host-noise environment (sequential per-spec timing lets CPU
    frequency drift hand arbitrary specs a 30%+ win).  Each sample
    averages ``inner`` consecutive calls to smooth dispatch jitter, and
    the order ROTATES every round so no entry systematically runs in the
    cache shadow of an expensive neighbor (e.g. always right after the
    interpret-mode pallas burst)."""
    import jax
    keys = list(fns)
    best = {}
    for k in keys:                      # warmup: compile + calibration
        jax.block_until_ready(fns[k]())
        jax.block_until_ready(fns[k]())
        best[k] = float("inf")
    for r in range(repeats):
        rot = keys[r % len(keys):] + keys[:r % len(keys)]
        for k in rot:
            t0 = time.perf_counter()
            for _ in range(inner):
                jax.block_until_ready(fns[k]())
            best[k] = min(best[k], (time.perf_counter() - t0) / inner)
    return best


def _count_kernel_launches(fn, *args) -> int:
    """pallas_call eqns in fn's jaxpr, descending into pjit/cond/scan
    sub-jaxprs (NOT into kernel bodies — they carry no pallas_call)."""
    import jax

    def cnt(jx):
        total = 0
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                total += 1
                continue
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    inner = getattr(sub, "jaxpr", sub)
                    if hasattr(inner, "eqns"):
                        total += cnt(inner)
        return total
    return cnt(jax.make_jaxpr(fn)(*args).jaxpr)


def _fused_rows(fu: dict, reps: int) -> list:
    """fused-vs-unfused route-tail rows (fig3-style contention ladder).

    The unfused baselines run the pre-fused pipeline verbatim: jnp-side
    local-key computation + ``make_messages`` + a SEPARATE commit pass
    (coarse sort / pallas kernel launch).  The fused row is one
    ``fused_commit_site`` launch doing key+reorder+commit in-kernel."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import commit as C
    from repro.core.commit import CommitSpec, commit
    from repro.core.messages import make_messages

    v, n, width, base = fu["v"], fu["n"], fu["width"], fu["base"]
    nrows = v // width
    interp = jax.default_backend() != "tpu"
    rng = np.random.default_rng(7)
    rows: list = []
    fsp = CommitSpec(backend="fused", sort=False, stats=False,
                     interpret=interp)
    for contention, conc in (("low", 10), ("high", 100)):
        tgt_np = base + rng.integers(0, max(nrows // conc, 1), n)
        tgt_np[rng.random(n) < 0.12] = -1        # bucket-fill slots
        tgt = jnp.asarray(tgt_np, jnp.int32)
        lane = jnp.asarray(rng.integers(0, width, n), jnp.int32)
        val = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
        for op, st0 in (("min", jnp.full((v,), 2 ** 30, jnp.int32)),
                        ("add", jnp.zeros((v,), jnp.int32))):

            def unfused(s, t, vl, ln, sp, op=op):
                ok = t >= 0
                key = jnp.where(ok, t - base, 0) * width + ln
                return commit(s, make_messages(key.astype(jnp.int32),
                                               vl, ok), op, sp).state

            def fused(s, t, vl, ln, op=op):
                return C.fused_commit_site(
                    s, t, vl, op, fsp, lane=ln, base=base,
                    width=width).state

            specs = {"unfused_coarse":
                     CommitSpec(backend="coarse", sort=False, stats=False),
                     "unfused_pallas":
                     CommitSpec(backend="pallas", sort=False, stats=False,
                                interpret=interp)}
            fns = {k: (lambda f=jax.jit(lambda s, t, vl, ln, sp=sp:
                                        unfused(s, t, vl, ln, sp)):
                       f(st0, tgt, val, lane))
                   for k, sp in specs.items()}
            jfused = jax.jit(fused)
            fns["fused"] = lambda: jfused(st0, tgt, val, lane)
            np.testing.assert_array_equal(         # parity before timing
                fns["fused"](), fns["unfused_coarse"]())
            launches = {k: _count_kernel_launches(
                (lambda s, t, vl, ln, sp=sp: unfused(s, t, vl, ln, sp)),
                st0, tgt, val, lane) for k, sp in specs.items()}
            launches["fused"] = _count_kernel_launches(
                fused, st0, tgt, val, lane)
            best = _measure_interleaved(fns, reps)
            for k, t in best.items():
                derived = f"kernel_launches={launches[k]}"
                if k == "fused":
                    derived += (" separate_commit_launch=0"
                                f" speedup_vs_unfused_coarse="
                                f"{best['unfused_coarse'] / t:.2f}"
                                f" speedup_vs_unfused_pallas="
                                f"{best['unfused_pallas'] / t:.2f}")
                else:
                    derived += " separate_commit_launch=1"
                rows.append({"suite": "fused",
                             "backend": "fused" if k == "fused"
                             else k.replace("unfused_", ""),
                             "name": f"fused/{op}/{contention}/{k}",
                             "us_per_call": round(t * 1e6, 1),
                             "derived": derived})
    return rows


def _fused_suite_main() -> None:
    """CSV entry point: ``--suite fused`` route-tail comparison rows."""
    fu = JSON_SIZES["tiny"]["fused"]
    for r in _fused_rows(fu, JSON_SIZES["tiny"]["repeats"]):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


SUITES["fused"] = _fused_suite_main


def bench_json(sizes: str) -> dict:
    """The fig4/fig6 tiny sweeps × every backend × auto, as stable rows."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import autotune as AT
    from repro.core.commit import CommitSpec
    from repro.graphs.algorithms.bfs import bfs
    from repro.graphs.generators import kronecker

    cfg = JSON_SIZES[sizes]
    reps = cfg["repeats"]
    rows: list = []

    def add(suite, backend, name, sec, derived="", **extra):
        rows.append({"suite": suite, "backend": backend, "name": name,
                     "us_per_call": round(sec * 1e6, 1),
                     "derived": derived, **extra})

    def spec_for(backend, m=None):
        if backend == "auto":
            # same sort/stats base as the static specs it races against
            return CommitSpec(backend="auto", sort=False, stats=False)
        if backend == "atomic":
            return CommitSpec(backend="atomic", stats=False)
        return CommitSpec(backend=backend, m=m, sort=False, stats=False)

    # fig4: BFS runtime vs transaction size M on one Kronecker graph
    f4 = cfg["fig4"]
    g = kronecker(f4["scale"], f4["edge_factor"], seed=1)
    src = int(np.argmax(np.asarray(g.degrees)))
    fns = {}
    for backend in cfg["backends"]:
        ms = (None,) if backend in ("atomic", "auto") else f4["ms"]
        for m in ms:
            sp = spec_for(backend, m)
            label = "auto" if backend == "auto" else f"M={m or 'inf'}"
            fns[(backend, label)] = (
                lambda sp=sp: bfs(g, src, spec=sp).dist)
    pol4 = AT.policy_for(spec_for("auto"),
                         jax.ShapeDtypeStruct((g.num_vertices,),
                                              jnp.int32),
                         n=g.src.shape[0])
    for (backend, label), t in _measure_interleaved(fns, reps).items():
        add("fig4", backend, f"fig4/{backend}/{label}", t,
            f"resolved={pol4.backend}" if backend == "auto" else "")
    if "pallas" in cfg["backends"]:
        # satellite: the no-stats kernel path must be the cheap one
        t_on, t_off = fig4_coarsening.stats_overhead(g, src, "pallas")
        add("fig4", "pallas", "fig4/pallas/stats_on", t_on)
        add("fig4", "pallas", "fig4/pallas/stats_off", t_off,
            f"nostats_cheaper={t_off < t_on}")

    # fig3: single-vertex commit under low/high contention, per backend
    f3 = cfg.get("fig3")
    if f3:
        from repro.core.commit import commit
        from repro.core.messages import make_messages
        rng = np.random.default_rng(0)
        v3, n3 = f3["v"], f3["n"]
        for contention, conc in (("low", 10), ("high", 100)):
            tgt = jnp.asarray(rng.integers(0, max(n3 // conc, 1), n3),
                              jnp.int32)
            val = jnp.asarray(rng.integers(0, 100, n3), jnp.int32)
            msgs = make_messages(tgt, val, jnp.ones((n3,), bool))
            for op, st0 in (("min", jnp.full((v3,), 2 ** 30, jnp.int32)),
                            ("add", jnp.zeros((v3,), jnp.int32))):
                fns = {}
                for b in cfg["backends"]:
                    f = jax.jit(lambda s, m, op=op, sp=spec_for(b):
                                commit(s, m, op, sp).state)
                    fns[b] = (lambda f=f, s=st0, m=msgs: f(s, m))
                for b, t in _measure_interleaved(fns, reps).items():
                    add("fig3", b, f"fig3/{op}/{contention}/{b}", t)

    # fused: the route tail after the all_to_all — key prep + separate
    # commit launch (pre-fused pipeline) vs ONE fused kernel launch
    fu = cfg.get("fused")
    if fu:
        for r in _fused_rows(fu, reps):
            rows.append(r)

    # fig6: BFS across |V| and density, per backend
    f6 = cfg["fig6"]
    points = [(f"V=2^{s}", kronecker(s, f6["edge_factor"], seed=3))
              for s in f6["scales"]]
    points += [(f"d={d}", kronecker(f6["density_scale"], d, seed=4))
               for d in f6["densities"]]
    for pname, gg in points:
        ss = int(np.argmax(np.asarray(gg.degrees)))
        fns = {b: (lambda sp=spec_for(b, 4096): bfs(gg, ss, spec=sp).dist)
               for b in cfg["backends"]}
        polp = AT.policy_for(spec_for("auto"),
                             jax.ShapeDtypeStruct((gg.num_vertices,),
                                                  jnp.int32),
                             n=gg.src.shape[0])
        for backend, t in _measure_interleaved(fns, reps).items():
            add("fig6", backend, f"fig6/{pname}/{backend}", t,
                f"resolved={polp.backend}" if backend == "auto" else "")

    # fig7: distributed strong scaling (forced-device-count children);
    # children resolve capacity="auto" (overflow-telemetry sizing) and the
    # derived column records the C they settled on
    f7 = cfg.get("fig7")
    if f7:
        for p_, child in _fig7_json(f7):
            for name, val in child.items():
                alg, backend, cap = name.split("/")
                add("fig7", backend, f"fig7/{alg}/{backend}/P={p_}", val,
                    f"capacity={cap}")

    # serve: lane-batched QPS vs the sequential loop (GraphService)
    sv = cfg.get("serve")
    if sv:
        # wave-level trace summary per kind: one tiny UNTIMED traced
        # drain (CommitSpec(trace=True)); the timed sweeps stay
        # untraced so their jaxprs are the shipped clean ones
        from repro.graphs.generators import random_weights

        def _probe(kind):
            gp = {"hot": kronecker(sv["scale"], 8, seed=1),
                  "t0": kronecker(max(sv["scale"] - 1, 2), 8, seed=2)}
            if kind == "sssp":
                gp = {k: random_weights(g, seed=3) for k, g in gp.items()}
            p = serve_qps._trace_probe(kind, gp, None, True, 0)
            return {"trace_rounds": p["rounds"],
                    "trace_mean_density": p["mean_density"],
                    "trace_ladder_moves": p["ladder_moves"]}

        probes = {k: _probe(k)
                  for k in dict.fromkeys(sv["kinds"] + sv["gkinds"])
                  if k in serve_qps.LANE_KINDS}
        stats = serve_qps.sweep(sv["kinds"], sv["lanes"], scale=sv["scale"],
                                queries=sv["queries"],
                                repeats=sv.get("repeats", 5))
        for st in stats:
            add("serve", "auto", f"serve/{st['kind']}/L={st['lanes']}",
                st["us_per_query"] / 1e6,
                f"qps={st['qps']:.0f} p50={st['p50_ms']:.1f}ms "
                f"p99={st['p99_ms']:.1f}ms "
                f"speedup_vs_seq={st['speedup_vs_seq']:.2f} "
                f"correct={st['correct']}",
                **probes.get(st["kind"], {}))
        serve_summary = {}
        for kind in sv["kinds"]:
            ks = [s for s in stats if s["kind"] == kind]
            top = max(ks, key=lambda s: s["lanes"])
            serve_summary[kind] = {
                "lanes": top["lanes"],
                "qps_vs_seq": round(top["speedup_vs_seq"], 3),
                "lane_batched_wins": bool(top["speedup_vs_seq"] > 1.0),
                "correct": all(s["correct"] for s in ks)}
        # the graph batch axis: same query kind over G tenant graphs
        # (interleaved with its G=1 sequential baseline inside
        # sweep_graphs, per the bench-host-noise rule)
        gstats = serve_qps.sweep_graphs(
            sv["gkinds"], sv["gcounts"], scale=sv["gscale"],
            repeats=sv.get("repeats", 5))
        for st in gstats:
            add("serve", "auto", f"serve/{st['kind']}/G={st['graphs']}",
                st["us_per_query"] / 1e6,
                f"qps={st['qps']:.0f} p50={st['p50_ms']:.1f}ms "
                f"p99={st['p99_ms']:.1f}ms "
                f"speedup_vs_seq={st['speedup_vs_seq']:.2f} "
                f"correct={st['correct']}",
                **probes.get(st["kind"], {}))
        for kind in sv["gkinds"]:
            ks = [s for s in gstats if s["kind"] == kind]
            top = max(ks, key=lambda s: s["graphs"])
            serve_summary[f"{kind}@graphs"] = {
                "graphs": top["graphs"],
                "qps_vs_seq": round(top["speedup_vs_seq"], 3),
                "graph_batched_wins": bool(top["speedup_vs_seq"] > 1.0),
                "correct": all(s["correct"] for s in ks)}
    else:
        serve_summary = None

    summary = _summarize(rows)
    if serve_summary is not None:
        summary["serve"] = serve_summary
    return {"schema": SCHEMA, "sizes": sizes,
            "platform": jax.default_backend(),
            "rows": rows, "summary": summary}


_F7_CHILD = """
import json, time, numpy as np, jax
from repro.launch.mesh import make_host_mesh
from repro.graphs.generators import kronecker
from repro.core.commit import CommitSpec
from repro.graphs.algorithms.bfs import distributed_bfs
from repro.graphs.algorithms.pagerank import distributed_pagerank
P = {P}
mesh = make_host_mesh(P, 1)
g = kronecker({scale}, 8, seed=5)
src = int(np.argmax(np.asarray(g.degrees)))
out = {{}}
for backend in {backends}:
    spec = CommitSpec(backend=backend, stats=False)
    # settle capacity="auto" first (growth recompiles), then time at the
    # resolved static C
    cap = None
    for _ in range(4):
        *_, r = distributed_bfs(mesh, g, src, spec=spec, capacity="auto",
                                telemetry=True)
        if cap == int(r.capacity):
            break
        cap = int(r.capacity)
    runs = {{
        "bfs": lambda: distributed_bfs(mesh, g, src, spec=spec,
                                       capacity=cap)[0].block_until_ready(),
        "pagerank": lambda: distributed_pagerank(
            mesh, g, iters=5, spec=spec, capacity=cap).block_until_ready(),
    }}
    for name, fn in runs.items():
        fn()
        ts = []
        for _ in range({reps}):
            t0 = time.perf_counter()
            fn()
            ts.append(time.perf_counter() - t0)
        out[name + "/" + backend + "/" + str(cap)] = min(ts)
print("RESULT", json.dumps(out))
"""


def _fig7_json(f7: dict):
    """Yield (P, {alg/backend/capacity: seconds}) per forced-device child."""
    import os
    import subprocess
    import textwrap
    from pathlib import Path
    env_base = dict(os.environ)
    env_base["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent /
                                 "src")
    for p_ in f7["ps"]:
        env = dict(env_base)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p_}"
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_F7_CHILD.format(
                P=p_, scale=f7["scale"], reps=f7["reps"],
                backends=tuple(f7["backends"])))],
            capture_output=True, text=True, env=env, timeout=1200)
        if r.returncode != 0:
            print(f"fig7 P={p_} child failed: {r.stderr[-400:]}",
                  file=sys.stderr)
            continue
        line = [l for l in r.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        yield p_, json.loads(line[len("RESULT "):])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", "--suite", dest="only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--backend", default=None,
                    choices=BACKENDS + ("auto",),
                    help="commit backend for the backend-aware suites")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the schema-stable bench matrix to PATH "
                         "and exit (skips the CSV suites)")
    ap.add_argument("--sizes", default="tiny", choices=tuple(JSON_SIZES),
                    help="problem sizes for --json")
    args = ap.parse_args()
    if args.json:
        doc = bench_json(args.sizes)
        diff = _diff_vs_previous(doc, args.json)
        if diff is not None:
            doc["diff"] = diff
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {args.json}: {len(doc['rows'])} rows, "
              f"summary={doc['summary']}", file=sys.stderr)
        if diff is not None:
            print(f"diff vs {diff['baseline']} "
                  f"({diff['rows_compared']} rows): "
                  + " ".join(f"{s}={d['median_ratio']}"
                             for s, d in diff["suites"].items()),
                  file=sys.stderr)
        return
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for n in names:
        t0 = time.time()
        try:
            if args.backend and n in BACKEND_AWARE:
                SUITES[n](**BACKEND_AWARE[n](args.backend))
            else:
                if args.backend and n not in BACKEND_AWARE:
                    print(f"{n}: --backend not applicable, ignored",
                          file=sys.stderr)
                SUITES[n]()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{n}/SUITE_ERROR,0,")
        print(f"{n}/total_wall,{(time.time() - t0) * 1e6:.0f},",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
