"""Benchmark runner — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Figures map to the paper as
documented in DESIGN.md §6; fig5/fig7 spawn child processes with forced
host-device counts (this process keeps 1 device).

  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1]
                                           [--backend atomic|coarse|pallas|auto]
                                           [--json BENCH_pr3.json [--sizes tiny]]

``--json`` runs the schema-stable tiny perf matrix (fig4/fig6 sweeps ×
every backend × the calibrated ``auto`` spec) and writes it as JSON — the
persistent bench trajectory every PR appends to and compares against.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

from benchmarks import (bench_moe, fig2_perf_model, fig3_single_vertex,
                        fig4_coarsening, fig5_coalescing, fig6_bfs_scale,
                        fig7_scaling, table1_realworld)
from repro.core.commit import BACKENDS

SUITES = {
    "fig2": fig2_perf_model.main,
    "fig3": fig3_single_vertex.main,
    "fig4": fig4_coarsening.main,
    "fig5": fig5_coalescing.main,
    "fig6": fig6_bfs_scale.main,
    "table1": table1_realworld.main,
    "fig7": fig7_scaling.main,
    "moe": bench_moe.main,
}

# suites whose commit mechanism is a first-class CommitSpec axis:
# suite -> kwargs for a single-backend run
BACKEND_AWARE = {
    "fig3": lambda b: {"backends": (b,)},
    "fig4": lambda b: {"backend": b},
    "fig5": lambda b: {"backend": b},
    "fig6": lambda b: {"backend": b},
    "fig7": lambda b: {"backend": b},
    "table1": lambda b: {"backend": b},
}


# --json measurement matrix.  "tiny" backs the committed BENCH_*.json
# trajectory; "smoke" is the tier-1 CI schema check (seconds, not minutes).
SCHEMA = "aam-bench/v1"
JSON_SIZES = {
    "tiny": dict(fig4=dict(scale=10, edge_factor=8, ms=(64, 1024, None)),
                 fig6=dict(scales=(9, 10), densities=(16,), edge_factor=8,
                           density_scale=9),
                 backends=("atomic", "coarse", "pallas", "auto"), repeats=7),
    "smoke": dict(fig4=dict(scale=8, edge_factor=4, ms=(64, None)),
                  fig6=dict(scales=(8,), densities=(4,), edge_factor=4,
                            density_scale=8),
                  backends=("atomic", "coarse", "auto"), repeats=2),
}


def _summarize(rows: list) -> dict:
    """Per suite: calibrated-auto time over the best hand-picked static
    spec.

    "Best static spec" is ONE spec summed over the suite's points (what a
    user would actually pin), not a per-point min over every static row —
    the latter is winner's-curse-biased on a noisy host.  The per-point
    worst ratio is kept alongside for transparency."""
    out = {}
    for suite in ("fig4", "fig6"):
        srows = [r for r in rows if r["suite"] == suite
                 and "stats_" not in r["name"]]
        if not srows:
            continue

        def point(r):
            return r["name"].split("/")[1] if suite == "fig6" else "all"

        def spec_id(r):   # fig4 rows are one spec each; fig6 specs span points
            return r["name"] if suite == "fig4" else r["backend"]

        totals: dict = {}
        for r in srows:
            totals[spec_id(r)] = totals.get(spec_id(r), 0.0) \
                + r["us_per_call"]
        auto_keys = [k for k in totals if "auto" in str(k)]
        static = {k: v for k, v in totals.items() if k not in auto_keys}
        if not auto_keys or not static:
            continue
        auto_t = min(totals[k] for k in auto_keys)
        best_k = min(static, key=static.get)
        ratio = auto_t / static[best_k]
        worst_point = max(
            (min(r["us_per_call"] for r in srows
                 if point(r) == p and r["backend"] == "auto")
             / min(r["us_per_call"] for r in srows
                   if point(r) == p and r["backend"] != "auto"))
            for p in {point(r) for r in srows})
        out[suite] = {"auto_over_best_static": round(ratio, 3),
                      "best_static": str(best_k),
                      "worst_point_ratio": round(worst_point, 3),
                      "within_10pct": bool(ratio <= 1.10),
                      "points": len({point(r) for r in srows})}
    return out


def _measure_interleaved(fns: dict, repeats: int, inner: int = 3) -> dict:
    """min-of-repeats per entry, measured ROUND-ROBIN so every entry sees
    the same host-noise environment (sequential per-spec timing lets CPU
    frequency drift hand arbitrary specs a 30%+ win).  Each sample
    averages ``inner`` consecutive calls to smooth dispatch jitter, and
    the order ROTATES every round so no entry systematically runs in the
    cache shadow of an expensive neighbor (e.g. always right after the
    interpret-mode pallas burst)."""
    import jax
    keys = list(fns)
    best = {}
    for k in keys:                      # warmup: compile + calibration
        jax.block_until_ready(fns[k]())
        jax.block_until_ready(fns[k]())
        best[k] = float("inf")
    for r in range(repeats):
        rot = keys[r % len(keys):] + keys[:r % len(keys)]
        for k in rot:
            t0 = time.perf_counter()
            for _ in range(inner):
                jax.block_until_ready(fns[k]())
            best[k] = min(best[k], (time.perf_counter() - t0) / inner)
    return best


def bench_json(sizes: str) -> dict:
    """The fig4/fig6 tiny sweeps × every backend × auto, as stable rows."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import autotune as AT
    from repro.core.commit import CommitSpec
    from repro.graphs.algorithms.bfs import bfs
    from repro.graphs.generators import kronecker

    cfg = JSON_SIZES[sizes]
    reps = cfg["repeats"]
    rows: list = []

    def add(suite, backend, name, sec, derived=""):
        rows.append({"suite": suite, "backend": backend, "name": name,
                     "us_per_call": round(sec * 1e6, 1), "derived": derived})

    def spec_for(backend, m=None):
        if backend == "auto":
            # same sort/stats base as the static specs it races against
            return CommitSpec(backend="auto", sort=False, stats=False)
        if backend == "atomic":
            return CommitSpec(backend="atomic", stats=False)
        return CommitSpec(backend=backend, m=m, sort=False, stats=False)

    # fig4: BFS runtime vs transaction size M on one Kronecker graph
    f4 = cfg["fig4"]
    g = kronecker(f4["scale"], f4["edge_factor"], seed=1)
    src = int(np.argmax(np.asarray(g.degrees)))
    fns = {}
    for backend in cfg["backends"]:
        ms = (None,) if backend in ("atomic", "auto") else f4["ms"]
        for m in ms:
            sp = spec_for(backend, m)
            label = "auto" if backend == "auto" else f"M={m or 'inf'}"
            fns[(backend, label)] = (
                lambda sp=sp: bfs(g, src, spec=sp).dist)
    pol4 = AT.policy_for(spec_for("auto"),
                         jax.ShapeDtypeStruct((g.num_vertices,),
                                              jnp.int32),
                         n=g.src.shape[0])
    for (backend, label), t in _measure_interleaved(fns, reps).items():
        add("fig4", backend, f"fig4/{backend}/{label}", t,
            f"resolved={pol4.backend}" if backend == "auto" else "")
    if "pallas" in cfg["backends"]:
        # satellite: the no-stats kernel path must be the cheap one
        t_on, t_off = fig4_coarsening.stats_overhead(g, src, "pallas")
        add("fig4", "pallas", "fig4/pallas/stats_on", t_on)
        add("fig4", "pallas", "fig4/pallas/stats_off", t_off,
            f"nostats_cheaper={t_off < t_on}")

    # fig6: BFS across |V| and density, per backend
    f6 = cfg["fig6"]
    points = [(f"V=2^{s}", kronecker(s, f6["edge_factor"], seed=3))
              for s in f6["scales"]]
    points += [(f"d={d}", kronecker(f6["density_scale"], d, seed=4))
               for d in f6["densities"]]
    for pname, gg in points:
        ss = int(np.argmax(np.asarray(gg.degrees)))
        fns = {b: (lambda sp=spec_for(b, 4096): bfs(gg, ss, spec=sp).dist)
               for b in cfg["backends"]}
        polp = AT.policy_for(spec_for("auto"),
                             jax.ShapeDtypeStruct((gg.num_vertices,),
                                                  jnp.int32),
                             n=gg.src.shape[0])
        for backend, t in _measure_interleaved(fns, reps).items():
            add("fig6", backend, f"fig6/{pname}/{backend}", t,
                f"resolved={polp.backend}" if backend == "auto" else "")

    return {"schema": SCHEMA, "sizes": sizes,
            "platform": jax.default_backend(),
            "rows": rows, "summary": _summarize(rows)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--backend", default=None,
                    choices=BACKENDS + ("auto",),
                    help="commit backend for the backend-aware suites")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the schema-stable bench matrix to PATH "
                         "and exit (skips the CSV suites)")
    ap.add_argument("--sizes", default="tiny", choices=tuple(JSON_SIZES),
                    help="problem sizes for --json")
    args = ap.parse_args()
    if args.json:
        doc = bench_json(args.sizes)
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {args.json}: {len(doc['rows'])} rows, "
              f"summary={doc['summary']}", file=sys.stderr)
        return
    names = args.only.split(",") if args.only else list(SUITES)
    print("name,us_per_call,derived")
    failures = 0
    for n in names:
        t0 = time.time()
        try:
            if args.backend and n in BACKEND_AWARE:
                SUITES[n](**BACKEND_AWARE[n](args.backend))
            else:
                if args.backend and n not in BACKEND_AWARE:
                    print(f"{n}: --backend not applicable, ignored",
                          file=sys.stderr)
                SUITES[n]()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{n}/SUITE_ERROR,0,")
        print(f"{n}/total_wall,{(time.time() - t0) * 1e6:.0f},",
              file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
