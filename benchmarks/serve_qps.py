"""Serving throughput/latency — lane-batched waves vs the sequential loop.

The serving claim (ISSUE 4 / ROADMAP north star): fusing L independent
queries into one wave amortizes the per-call overhead a
query-at-a-time loop pays L times.  This benchmark drives a
:class:`repro.serve.graph_service.GraphService` at every rung of its lane
ladder and reports QPS and per-query latency percentiles (a query's
latency is the wall time of the wave it rode — microbatching trades p50
for throughput exactly like LLM serving batchers do), checking along the
way that every lane count returns the sequential loop's answers.

  PYTHONPATH=src python -m benchmarks.serve_qps [--backend auto]
      [--kinds bfs,ppr] [--lanes 1,2,4,8] [--scale 9] [--queries 32]

CSV rows: ``serve/<kind>/L=<l>/qps`` with us-per-query;
``benchmarks.run --json`` folds the same ``sweep(...)`` measurements
into the persistent ``aam-bench/v1`` trajectory as its serve suite.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.commit import BACKENDS, CommitSpec
from repro.serve.graph_service import GraphService
from repro.serve.queries import BfsQuery, PprQuery, SsspQuery, StConnQuery

PPR_ITERS = 5


def _queries(kind: str, sources, extra):
    if kind == "bfs":
        return [BfsQuery(int(s)) for s in sources]
    if kind == "sssp":
        return [SsspQuery(int(s)) for s in sources]
    if kind == "ppr":
        return [PprQuery(int(s), iters=PPR_ITERS) for s in sources]
    return [StConnQuery(int(s), int(t)) for s, t in zip(sources, extra)]


def _spec(backend: str | None) -> CommitSpec | None:
    if backend is None or backend == "auto":
        return None                       # service default: calibrated auto
    return CommitSpec(backend=backend, stats=False)


def _pass(svc, qs, lanes: int):
    """One full pass of ``qs`` through ``svc`` in microbatches of
    ``lanes``: one timed drain per microbatch, so per-query latency =
    its wave's wall time.  Returns (wave_times, lat, results)."""
    wave_times, lat, results = [], [], []
    for lo in range(0, len(qs), lanes):
        chunk = qs[lo:lo + lanes]
        tickets = [svc.submit("g", q) for q in chunk]
        t0 = time.perf_counter()
        svc.drain()
        rows = [svc.result(t) for t in tickets]
        jax.block_until_ready([r for r in rows
                               if not isinstance(r, bool)])
        dt = time.perf_counter() - t0
        wave_times.append(dt)
        lat += [dt] * len(chunk)
        results += rows
    return wave_times, lat, results


def _stats(best, n_queries: int) -> dict:
    total, wave_times, lat, _ = best
    return {
        "qps": n_queries / total,
        "us_per_query": total / n_queries * 1e6,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "waves": len(wave_times),
    }


def measure_kind(kind: str, g, sources, extra, lane_counts,
                 backend: str | None, repeats: int = 5) -> dict:
    """Measure every lane count of one kind INTERLEAVED round-robin, min-
    of-passes per lane count — host noise arrives in multi-second waves,
    so sequential per-L measurement would hand arbitrary lane counts a
    2x win; interleaving keeps the L-vs-L ratios honest even while the
    absolute times drift (same reasoning as the fig-row
    ``_measure_interleaved``).  The cache is off so every query
    executes.  Returns {lanes: (stats dict, results)}."""
    qs = _queries(kind, sources, extra)
    svcs = {}
    for lanes in lane_counts:
        svc = GraphService(max_lanes=lanes, cache=False,
                           spec=_spec(backend))
        svc.register_graph("g", g)
        svc.run("g", qs[:lanes])    # compile (+ calibrate) per lane count
        svcs[lanes] = svc
    best: dict = {}
    order = list(lane_counts)
    for r in range(max(repeats, 1)):
        rot = order[r % len(order):] + order[:r % len(order)]
        for lanes in rot:
            wave_times, lat, results = _pass(svcs[lanes], qs, lanes)
            if lanes not in best or sum(wave_times) < best[lanes][0]:
                best[lanes] = (sum(wave_times), wave_times, lat, results)
    return {lanes: (_stats(b, len(qs)), b[3]) for lanes, b in best.items()}


def _same(kind: str, a, b) -> bool:
    if kind == "stconn":
        return all(x == y for x, y in zip(a, b))
    if kind == "ppr":          # float add: rounding-level, like any M change
        return all(np.allclose(np.asarray(x), np.asarray(y), atol=1e-6)
                   for x, y in zip(a, b))
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


def sweep(kinds, lanes, *, scale: int, queries: int,
          backend: str | None = None, edge_factor: int = 8, seed: int = 0,
          repeats: int = 5):
    """Returns [{kind, lanes, qps, p50_ms, p99_ms, us_per_query,
    speedup_vs_seq, correct}, ...] — lanes=1 is the sequential loop."""
    from repro.graphs.generators import kronecker, random_weights
    g = kronecker(scale, edge_factor, seed=seed)
    if "sssp" in kinds:
        g = random_weights(g, seed=seed + 1)
    rng = np.random.default_rng(seed)
    sources = rng.choice(g.num_vertices, queries, replace=False)
    extra = rng.choice(g.num_vertices, queries, replace=False)
    out = []
    for kind in kinds:
        by_lane = measure_kind(kind, g, sources, extra, lanes, backend,
                               repeats=repeats)
        base = by_lane[lanes[0]]
        for lane in lanes:
            st, res = by_lane[lane]
            st["kind"], st["lanes"] = kind, lane
            st["speedup_vs_seq"] = base[0]["us_per_query"] \
                / st["us_per_query"]
            st["correct"] = _same(kind, base[1], res)
            out.append(st)
    return out


def main(kinds=("bfs", "ppr"), lanes=(1, 2, 4, 8), scale: int = 8,
         queries: int = 32, backend: str | None = None):
    for st in sweep(kinds, lanes, scale=scale, queries=queries,
                    backend=backend):
        assert st["correct"], (st["kind"], st["lanes"],
                               "lane-batched results diverged from the "
                               "sequential loop")
        emit(f"serve/{st['kind']}/L={st['lanes']}/qps",
             st["us_per_query"] / 1e6,
             f"qps={st['qps']:.0f} p50={st['p50_ms']:.1f}ms "
             f"p99={st['p99_ms']:.1f}ms "
             f"speedup_vs_seq={st['speedup_vs_seq']:.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    choices=BACKENDS + ("auto",),
                    help="commit backend (default: the service's "
                         "calibrated auto spec)")
    ap.add_argument("--kinds", default="bfs,ppr")
    ap.add_argument("--lanes", default="1,2,4,8")
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--queries", type=int, default=32)
    args = ap.parse_args()
    main(kinds=tuple(args.kinds.split(",")),
         lanes=tuple(int(x) for x in args.lanes.split(",")),
         scale=args.scale, queries=args.queries, backend=args.backend)
