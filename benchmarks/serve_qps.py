"""Serving throughput/latency — batch-axis waves vs the sequential loop.

The serving claim (ISSUE 4/5 / ROADMAP north star): fusing independent
work items into one wave amortizes the per-call overhead a
query-at-a-time loop pays per item.  Two batch axes:

* ``--axis lanes`` (default): L queries over ONE graph fuse as lanes —
  the benchmark drives a :class:`repro.serve.graph_service.GraphService`
  at every rung of its lane ladder;
* ``--axis graphs``: the same query kind over G tenant graphs fuses as
  a graph batch (the only axis coloring/Boruvka have) — the service is
  driven at every rung of its GRAPH ladder, G=1 being the sequential
  per-graph loop.

Both report QPS and per-query latency percentiles (a query's latency is
the wall time of the wave it rode — microbatching trades p50 for
throughput exactly like LLM serving batchers do), and both check that
every batch width returns the sequential loop's answers.  All widths are
measured INTERLEAVED round-robin (host noise arrives in multi-minute
waves; sequential per-width measurement would hand arbitrary widths a
2x win).

  PYTHONPATH=src python -m benchmarks.serve_qps [--backend auto]
      [--axis lanes|graphs] [--kinds bfs,ppr] [--lanes 1,2,4,8]
      [--graphs 1,2,4,8] [--scale 9] [--queries 32]

``--open-loop`` (ISSUE 7) switches from this closed loop to an OPEN one:
Poisson arrivals at each ``--qps`` level drive the asynchronous
continuous-batching server (:mod:`repro.serve.continuous`) over a
mixed-tenant workload — one hot graph absorbing lane pressure plus
``--tenants`` single-query tenants — and report p50/p99 submit-to-answer
latency vs offered QPS, with the lanes×graphs product axis on
(``product`` mode) and off (``single-axis``, the PR-5 two-axis drain).
``--json`` merges rows carrying ``offered_qps``/``p99_ms`` into the
``aam-bench/v1`` trajectory.

CSV rows: ``serve/<kind>/L=<l>/qps`` / ``serve/<kind>/G=<g>/qps`` with
us-per-query; ``benchmarks.run --json`` folds the same ``sweep(...)`` /
``sweep_graphs(...)`` measurements into the persistent ``aam-bench/v1``
trajectory as its serve suite.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.commit import BACKENDS, CommitSpec
from repro.serve.graph_service import GraphService
from repro.serve.queries import (BfsQuery, PprQuery, SsspQuery,
                                 StConnQuery, ColoringQuery, MstQuery)

PPR_ITERS = 5


LANE_KINDS = ("bfs", "sssp", "ppr", "stconn")
GRAPH_KINDS = LANE_KINDS + ("coloring", "mst")


def _queries(kind: str, sources, extra):
    if kind == "bfs":
        return [BfsQuery(int(s)) for s in sources]
    if kind == "sssp":
        return [SsspQuery(int(s)) for s in sources]
    if kind == "ppr":
        return [PprQuery(int(s), iters=PPR_ITERS) for s in sources]
    if kind == "stconn":
        return [StConnQuery(int(s), int(t)) for s, t in zip(sources, extra)]
    raise ValueError(f"kind {kind!r} has no lane form; --axis lanes "
                     f"accepts {LANE_KINDS}")


def _spec(backend: str | None) -> CommitSpec | None:
    if backend is None or backend == "auto":
        return None                       # service default: calibrated auto
    return CommitSpec(backend=backend, stats=False)


def _pass(svc, qs, lanes: int):
    """One full pass of ``qs`` through ``svc`` in microbatches of
    ``lanes``: one timed drain per microbatch, so per-query latency =
    its wave's wall time.  Returns (wave_times, lat, results)."""
    wave_times, lat, results = [], [], []
    for lo in range(0, len(qs), lanes):
        chunk = qs[lo:lo + lanes]
        tickets = [svc.submit("g", q) for q in chunk]
        t0 = time.perf_counter()
        svc.drain()
        rows = [svc.result(t) for t in tickets]
        jax.block_until_ready([r for r in rows
                               if not isinstance(r, bool)])
        dt = time.perf_counter() - t0
        wave_times.append(dt)
        lat += [dt] * len(chunk)
        results += rows
    return wave_times, lat, results


def _stats(best, n_queries: int) -> dict:
    total, wave_times, lat, _ = best
    return {
        "qps": n_queries / total,
        "us_per_query": total / n_queries * 1e6,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "waves": len(wave_times),
    }


def _interleaved_best(widths, pass_fn, n_queries: int,
                      repeats: int = 5) -> dict:
    """THE batch-width measurement protocol, shared by both axes:
    every width measured INTERLEAVED round-robin (rotating start),
    min-of-passes per width — host noise arrives in multi-second waves,
    so sequential per-width measurement would hand arbitrary widths a
    2x win; interleaving keeps the width-vs-width ratios honest even
    while the absolute times drift (same reasoning as the fig-row
    ``_measure_interleaved``).  ``pass_fn(width)`` runs one full
    workload pass and returns (wave_times, lat, results).  Returns
    {width: (stats dict, results)}."""
    best: dict = {}
    order = list(widths)
    for r in range(max(repeats, 1)):
        rot = order[r % len(order):] + order[:r % len(order)]
        for width in rot:
            wave_times, lat, results = pass_fn(width)
            if width not in best or sum(wave_times) < best[width][0]:
                best[width] = (sum(wave_times), wave_times, lat, results)
    return {w: (_stats(b, n_queries), b[3]) for w, b in best.items()}


def measure_kind(kind: str, g, sources, extra, lane_counts,
                 backend: str | None, repeats: int = 5) -> dict:
    """Lane-axis instance of :func:`_interleaved_best` (cache off so
    every query executes).  Returns {lanes: (stats dict, results)}."""
    qs = _queries(kind, sources, extra)
    svcs = {}
    for lanes in lane_counts:
        svc = GraphService(max_lanes=lanes, cache=False,
                           spec=_spec(backend))
        svc.register_graph("g", g)
        svc.run("g", qs[:lanes])    # compile (+ calibrate) per lane count
        svcs[lanes] = svc
    return _interleaved_best(lane_counts,
                             lambda lanes: _pass(svcs[lanes], qs, lanes),
                             len(qs), repeats)


def _same(kind: str, a, b) -> bool:
    if kind == "stconn":
        return all(x == y for x, y in zip(a, b))
    if kind == "mst":          # (comp, weight, n_edges) per graph
        return all(np.array_equal(np.asarray(x[0]), np.asarray(y[0]))
                   and float(x[1]) == float(y[1]) and int(x[2]) == int(y[2])
                   for x, y in zip(a, b))
    if kind == "ppr":          # float add: rounding-level, like any M change
        return all(np.allclose(np.asarray(x), np.asarray(y), atol=1e-6)
                   for x, y in zip(a, b))
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(a, b))


def sweep(kinds, lanes, *, scale: int, queries: int,
          backend: str | None = None, edge_factor: int = 8, seed: int = 0,
          repeats: int = 5):
    """Returns [{kind, lanes, qps, p50_ms, p99_ms, us_per_query,
    speedup_vs_seq, correct}, ...] — lanes=1 is the sequential loop."""
    from repro.graphs.generators import kronecker, random_weights
    g = kronecker(scale, edge_factor, seed=seed)
    if "sssp" in kinds:
        g = random_weights(g, seed=seed + 1)
    rng = np.random.default_rng(seed)
    sources = rng.choice(g.num_vertices, queries, replace=False)
    extra = rng.choice(g.num_vertices, queries, replace=False)
    out = []
    for kind in kinds:
        by_lane = measure_kind(kind, g, sources, extra, lanes, backend,
                               repeats=repeats)
        base = by_lane[lanes[0]]
        for lane in lanes:
            st, res = by_lane[lane]
            st["kind"], st["lanes"] = kind, lane
            st["speedup_vs_seq"] = base[0]["us_per_query"] \
                / st["us_per_query"]
            st["correct"] = _same(kind, base[1], res)
            out.append(st)
    return out


# ---------------------------------------------------------------------------
# The graph batch axis: one query each over G tenant graphs
# ---------------------------------------------------------------------------


def _tenant_graphs(n: int, *, scale: int, edge_factor: int, seed: int,
                   weighted: bool):
    """n HETEROGENEOUS tenant graphs (alternating scales, distinct
    seeds — different vertex counts and topologies)."""
    from repro.graphs.generators import kronecker, random_weights
    out = []
    for i in range(n):
        g = kronecker(scale - (i % 2), edge_factor, seed=seed + 17 * i)
        out.append(random_weights(g, seed=seed + i) if weighted else g)
    return out


def _graph_query(kind: str, g, rng):
    deg = np.asarray(g.degrees)
    hub = int(np.argmax(deg))
    if kind == "bfs":
        return BfsQuery(hub)
    if kind == "sssp":
        return SsspQuery(hub)
    if kind == "ppr":
        return PprQuery(hub, iters=PPR_ITERS)
    if kind == "stconn":
        return StConnQuery(hub, int(rng.integers(0, g.num_vertices)))
    if kind == "coloring":
        return ColoringQuery()
    if kind == "mst":
        return MstQuery()
    raise ValueError(f"unknown kind {kind!r}; --axis graphs accepts "
                     f"{GRAPH_KINDS}")


def _pass_graphs(svc, queries_by_gid: dict, width: int):
    """One full pass of one-query-per-graph through ``svc`` in
    graph-batches of ``width`` (== the service's max_graphs, so each
    drain is exactly one graph wave).  Returns (wave_times, lat,
    results)."""
    gids = list(queries_by_gid)
    wave_times, lat, results = [], [], []
    for lo in range(0, len(gids), width):
        chunk = gids[lo:lo + width]
        tickets = [svc.submit(gid, queries_by_gid[gid]) for gid in chunk]
        t0 = time.perf_counter()
        svc.drain()
        rows = [svc.result(t) for t in tickets]
        jax.block_until_ready([x for r in rows
                               for x in (r if isinstance(r, tuple) else (r,))
                               if not isinstance(x, bool)])
        dt = time.perf_counter() - t0
        wave_times.append(dt)
        lat += [dt] * len(chunk)
        results += rows
    return wave_times, lat, results


def measure_kind_graphs(kind: str, graphs, counts, backend: str | None,
                        repeats: int = 5) -> dict:
    """Graph-axis instance of :func:`_interleaved_best`.  Returns
    {width: (stats dict, results)}."""
    rng = np.random.default_rng(0)
    queries = {i: _graph_query(kind, g, rng) for i, g in enumerate(graphs)}
    svcs = {}
    for width in counts:
        svc = GraphService(max_graphs=width, cache=False,
                           spec=_spec(backend))
        for i, g in enumerate(graphs):
            svc.register_graph(i, g)
        svcs[width] = svc
        _pass_graphs(svc, queries, width)   # compile (+ calibrate)
    return _interleaved_best(
        counts, lambda w: _pass_graphs(svcs[w], queries, w), len(graphs),
        repeats)


def sweep_graphs(kinds, counts, *, scale: int, backend: str | None = None,
                 edge_factor: int = 8, seed: int = 0, repeats: int = 5):
    """Returns [{kind, graphs, qps, p50_ms, p99_ms, us_per_query,
    speedup_vs_seq, correct}, ...] — graphs=1 is the sequential
    per-graph loop.  The tenant set has max(counts) heterogeneous
    graphs; every width serves the SAME one-query-per-graph workload."""
    n = max(counts)
    out = []
    for kind in kinds:
        graphs = _tenant_graphs(n, scale=scale, edge_factor=edge_factor,
                                seed=seed, weighted=(kind in ("sssp",
                                                              "mst")))
        by_width = measure_kind_graphs(kind, graphs, counts, backend,
                                       repeats=repeats)
        base = by_width[counts[0]]
        for width in counts:
            st, res = by_width[width]
            st["kind"], st["graphs"] = kind, width
            st["speedup_vs_seq"] = base[0]["us_per_query"] \
                / st["us_per_query"]
            st["correct"] = _same(kind, base[1], res)
            out.append(st)
    return out


# ---------------------------------------------------------------------------
# Open-loop latency under load: Poisson arrivals against the continuous
# batching loop (ISSUE 7) — p50/p99 vs offered QPS
# ---------------------------------------------------------------------------


def _open_workload(kind: str, graphs_by_gid: dict, n: int, rng,
                   hot_frac: float = 0.5):
    """One mixed-tenant arrival sequence: ``hot_frac`` of queries hit
    the hot graph (lane pressure), the rest spread over the single-query
    tenants (graph pressure) — the shape only the PRODUCT axis serves as
    one wave."""
    gids = [g for g in graphs_by_gid if g != "hot"]
    subs = []
    for _ in range(n):
        gid = "hot" if rng.random() < hot_frac \
            else gids[int(rng.integers(len(gids)))]
        g = graphs_by_gid[gid]
        src = int(rng.integers(g.num_vertices))
        if kind == "bfs":
            q = BfsQuery(src)
        elif kind == "sssp":
            q = SsspQuery(src)
        elif kind == "ppr":
            q = PprQuery(src, iters=PPR_ITERS)
        elif kind == "stconn":
            q = StConnQuery(src, int(rng.integers(g.num_vertices)))
        else:
            raise ValueError(f"kind {kind!r} has no lane form; the "
                             f"open-loop bench accepts {LANE_KINDS}")
        subs.append((gid, q))
    return subs


def _trace_probe(kind: str, graphs: dict, backend: str | None,
                 product: bool, seed: int) -> dict:
    """Wave-level trace summary (rounds / mean commit density / ladder
    moves) for one (kind, mode) config: a tiny UNTIMED drain with
    ``CommitSpec(trace=True)`` feeds :func:`repro.obs.wavetap.summary`.
    The timed open-loop runs stay untraced — the p99 acceptance gate
    needs the clean jaxprs ``aamlint --trace-off-clean`` proves."""
    import dataclasses
    from repro.obs import wavetap as OW
    base = _spec(backend)
    if base is None:
        base = CommitSpec(backend="auto", sort=False)
    svc = GraphService(cache=False, product=product,
                       spec=dataclasses.replace(base, trace=True,
                                                stats=True))
    for gid, g in graphs.items():
        svc.register_graph(gid, g)
    for gid, q in _open_workload(kind, graphs, 4,
                                 np.random.default_rng(seed)):
        svc.submit(gid, q)
    OW.clear()
    svc.drain()
    return OW.summary(OW.collector().drain())


def open_loop(kinds=("bfs",), *, qps_levels=(20, 50), duration_s: float = 2.0,
              scale: int = 7, tenants: int = 5, backend: str | None = None,
              seed: int = 0, max_wait_s: float = 0.005,
              modes=("product", "single-axis")):
    """The latency-under-load benchmark: an OPEN loop (arrivals don't
    wait for completions — Poisson gaps at each offered QPS) drives the
    asynchronous :class:`repro.serve.continuous.ContinuousServer` over a
    mixed-tenant workload, once with the product axis on and once
    degraded to the PR-5 two-axis drain (``product=False``).  Per-query
    latency is submit-to-publish through the service clock; rows carry
    ``offered_qps``/``achieved_qps``/``p50_ms``/``p99_ms`` per
    (kind, mode, level)."""
    from repro.graphs.generators import kronecker, random_weights
    from repro.serve.continuous import ContinuousServer

    rows = []
    for kind in kinds:
        graphs = {"hot": kronecker(scale, 8, seed=seed)}
        for i in range(tenants):
            graphs[f"t{i}"] = kronecker(max(scale - 1, 2), 8,
                                        seed=seed + 17 * i + 1)
        if kind == "sssp":
            graphs = {gid: random_weights(g, seed=seed + 3)
                      for gid, g in graphs.items()}
        for mode in modes:
            probe = _trace_probe(kind, graphs, backend,
                                 mode == "product", seed)
            svc = GraphService(cache=False, product=(mode == "product"),
                               spec=_spec(backend))
            for gid, g in graphs.items():
                svc.register_graph(gid, g)
            # warm the jit ladder: one mixed drain compiles the shapes
            # the open loop will hit (hot lane pressure + tenant spread)
            warm = _open_workload(kind, graphs, 2 * (tenants + 1),
                                  np.random.default_rng(seed + 7))
            for gid, q in warm:
                svc.submit(gid, q)
            svc.drain()
            for qps in qps_levels:
                rng = np.random.default_rng(seed + 11)
                n = max(8, int(duration_s * qps))
                subs = _open_workload(kind, graphs, n, rng)
                gaps = rng.exponential(1.0 / qps, n)
                with ContinuousServer(svc, max_wait_s=max_wait_s) as cs:
                    t0 = time.perf_counter()
                    tickets = []
                    for (gid, q), gap in zip(subs, gaps):
                        time.sleep(gap)
                        tickets.append(cs.submit(gid, q))
                    cs.results(tickets, timeout=600)
                    total = time.perf_counter() - t0
                    if cs.last_error is not None:
                        raise cs.last_error
                lat = [(cs.done_at[t] - cs.submit_at[t]) * 1e3
                       for t in tickets]
                rows.append({
                    "kind": kind, "mode": mode, "offered_qps": qps,
                    "achieved_qps": round(len(tickets) / total, 1),
                    "p50_ms": round(float(np.percentile(lat, 50)), 2),
                    "p99_ms": round(float(np.percentile(lat, 99)), 2),
                    "mean_ms": round(float(np.mean(lat)), 2),
                    "n": len(tickets),
                    "product_waves": svc.stats.product_waves,
                    "trace_rounds": probe["rounds"],
                    "trace_mean_density": probe["mean_density"],
                    "trace_ladder_moves": probe["ladder_moves"],
                })
    return rows


def _open_rows_to_json(rows, json_path: str) -> None:
    """Land the open-loop rows in the persistent ``aam-bench/v1``
    trajectory (same merge protocol as :func:`_crash_rows_to_json`:
    replace previous ``serve_open`` rows, keep everything else)."""
    import json
    import os
    doc = None
    if os.path.exists(json_path):
        try:
            with open(json_path) as f:
                doc = json.load(f)
            if doc.get("schema") != "aam-bench/v1":
                doc = None
        except (OSError, ValueError):
            doc = None
    if doc is None:
        doc = {"schema": "aam-bench/v1", "sizes": "open",
               "platform": jax.default_backend(), "rows": [],
               "summary": {}}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("suite") != "serve_open"]
    for r in rows:
        doc["rows"].append({
            "suite": "serve_open", "backend": "auto",
            "name": f"serve_open/{r['kind']}/{r['mode']}"
                    f"/qps={r['offered_qps']}",
            "us_per_call": round(r["p99_ms"] * 1e3, 1),
            "offered_qps": r["offered_qps"],
            "achieved_qps": r["achieved_qps"],
            "p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
            "trace_rounds": r.get("trace_rounds", 0),
            "trace_mean_density": r.get("trace_mean_density", 0.0),
            "trace_ladder_moves": r.get("trace_ladder_moves", 0),
            "derived": f"n={r['n']} mean={r['mean_ms']}ms "
                       f"product_waves={r['product_waves']} "
                       f"rounds={r.get('trace_rounds', 0)} "
                       f"density={r.get('trace_mean_density', 0.0)}"})
    doc.setdefault("summary", {})["serve_open"] = {
        f"{r['kind']}/{r['mode']}/qps={r['offered_qps']}": {
            "p50_ms": r["p50_ms"], "p99_ms": r["p99_ms"],
            "achieved_qps": r["achieved_qps"]}
        for r in rows}
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


# ---------------------------------------------------------------------------
# Crash-resume: kill mid-drain, restore from snapshot, finish the workload
# ---------------------------------------------------------------------------


def crash_resume(kinds=("bfs", "ppr"), *, scale: int = 8, queries: int = 32,
                 lanes: int = 8, crash_at: float = 0.5,
                 backend: str | None = None, seed: int = 0,
                 ckpt_dir: str | None = None):
    """The durability benchmark: a supervised service snapshots warm,
    takes the full workload (journaled tickets), crashes at
    ``crash_at`` of the way through its drain waves, restores, and
    finishes.  Reports restore latency and post-restore recovery QPS,
    and checks the recovered answers bit-match an uninterrupted
    service's.  Returns [{kind, restore_ms, recovery_qps, ...}] rows
    for the persistent bench trajectory."""
    import shutil
    import tempfile

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.graphs.generators import kronecker, random_weights
    from repro.serve.durable import ServiceSupervisor

    g = kronecker(scale, 8, seed=seed)
    if "sssp" in kinds:
        g = random_weights(g, seed=seed + 1)
    rng = np.random.default_rng(seed)
    sources = rng.choice(g.num_vertices, queries, replace=False)
    extra = rng.choice(g.num_vertices, queries, replace=False)
    base_dir = ckpt_dir or tempfile.mkdtemp(prefix="aam_crash_bench_")
    rows = []
    try:
        for kind in kinds:
            qs = _queries(kind, sources, extra)
            # the uninterrupted reference (also warms jit/calibration)
            ref = GraphService(max_lanes=lanes, cache=False,
                               spec=_spec(backend))
            ref.register_graph("g", g)
            ref_rows = ref.run("g", qs)
            svc = GraphService(max_lanes=lanes, cache=False,
                               spec=_spec(backend))
            svc.register_graph("g", g)
            sup = ServiceSupervisor(
                svc, Checkpointer(f"{base_dir}/{kind}"),
                log=lambda *_: None)
            sup.save()                      # snapshot the warm service
            tickets = [sup.submit("g", q) for q in qs]
            n_waves = max(-(-len(qs) // lanes), 1)
            kill_at = min(int(n_waves * crash_at), n_waves - 1)

            def injector(where, i, kill_at=kill_at):
                if i == kill_at:
                    raise RuntimeError("injected host loss")

            svc.fault_injector = injector
            try:
                svc.drain()
                raise AssertionError("injector never fired")
            except RuntimeError:
                pass                        # the crash
            t0 = time.perf_counter()
            restored = sup.restore()        # snapshot + WAL replay
            restore_s = time.perf_counter() - t0
            t0 = time.perf_counter()
            restored.drain()
            out = [restored.result(t) for t in tickets]
            jax.block_until_ready(
                [x for r in out
                 for x in (r if isinstance(r, tuple) else (r,))
                 if not isinstance(x, bool)])
            recover_s = time.perf_counter() - t0
            rows.append({
                "kind": kind, "lanes": lanes, "queries": len(qs),
                "crash_wave": kill_at,
                "restore_ms": round(restore_s * 1e3, 2),
                "recovery_qps": round(len(qs) / recover_s, 1),
                "recovery_s": round(recover_s, 4),
                "timing_runs_post_restore": restored.stats.timing_runs,
                "tickets_recovered": len(out) == len(tickets),
                "correct": _same(kind, ref_rows, out),
            })
    finally:
        if ckpt_dir is None:
            shutil.rmtree(base_dir, ignore_errors=True)
    return rows


def _crash_rows_to_json(rows, json_path: str) -> None:
    """Land the crash-resume rows in the persistent ``aam-bench/v1``
    trajectory: merge into ``json_path`` if it exists (replacing any
    previous crash rows), create a minimal doc otherwise."""
    import json
    import os
    doc = None
    if os.path.exists(json_path):
        try:
            with open(json_path) as f:
                doc = json.load(f)
            if doc.get("schema") != "aam-bench/v1":
                doc = None
        except (OSError, ValueError):
            doc = None
    if doc is None:
        doc = {"schema": "aam-bench/v1", "sizes": "crash",
               "platform": jax.default_backend(), "rows": [],
               "summary": {}}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("suite") != "crash"]
    for r in rows:
        doc["rows"].append({
            "suite": "crash", "backend": "auto",
            "name": f"crash/{r['kind']}/restore",
            "us_per_call": round(r["restore_ms"] * 1e3, 1),
            "derived": f"recovery_qps={r['recovery_qps']} "
                       f"crash_wave={r['crash_wave']} "
                       f"recovered={r['tickets_recovered']} "
                       f"correct={r['correct']} "
                       f"timing_runs={r['timing_runs_post_restore']}"})
    doc.setdefault("summary", {})["crash"] = {
        r["kind"]: {"restore_ms": r["restore_ms"],
                    "recovery_qps": r["recovery_qps"],
                    "recovered": r["tickets_recovered"],
                    "correct": r["correct"]}
        for r in rows}
    with open(json_path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")


def main(kinds=("bfs", "ppr"), lanes=(1, 2, 4, 8), scale: int = 8,
         queries: int = 32, backend: str | None = None,
         axis: str = "lanes", graphs=(1, 2, 4, 8)):
    if axis == "graphs":
        for st in sweep_graphs(kinds, graphs, scale=scale,
                               backend=backend):
            assert st["correct"], (st["kind"], st["graphs"],
                                   "graph-batched results diverged from "
                                   "the sequential loop")
            emit(f"serve/{st['kind']}/G={st['graphs']}/qps",
                 st["us_per_query"] / 1e6,
                 f"qps={st['qps']:.0f} p50={st['p50_ms']:.1f}ms "
                 f"p99={st['p99_ms']:.1f}ms "
                 f"speedup_vs_seq={st['speedup_vs_seq']:.2f}")
        return
    for st in sweep(kinds, lanes, scale=scale, queries=queries,
                    backend=backend):
        assert st["correct"], (st["kind"], st["lanes"],
                               "lane-batched results diverged from the "
                               "sequential loop")
        emit(f"serve/{st['kind']}/L={st['lanes']}/qps",
             st["us_per_query"] / 1e6,
             f"qps={st['qps']:.0f} p50={st['p50_ms']:.1f}ms "
             f"p99={st['p99_ms']:.1f}ms "
             f"speedup_vs_seq={st['speedup_vs_seq']:.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default=None,
                    choices=BACKENDS + ("auto",),
                    help="commit backend (default: the service's "
                         "calibrated auto spec)")
    ap.add_argument("--axis", default="lanes", choices=("lanes", "graphs"),
                    help="batch axis to sweep: query lanes over one "
                         "graph, or a graph batch over tenant graphs")
    ap.add_argument("--kinds", default=None,
                    help="default: bfs,ppr (lanes) / bfs,coloring (graphs)")
    ap.add_argument("--lanes", default="1,2,4,8")
    ap.add_argument("--graphs", default="1,2,4,8")
    ap.add_argument("--scale", type=int, default=8)
    ap.add_argument("--queries", type=int, default=32)
    ap.add_argument("--crash-resume", action="store_true",
                    help="durability mode: snapshot, crash mid-drain, "
                         "restore, finish; reports restore latency and "
                         "recovery QPS")
    ap.add_argument("--crash-at", type=float, default=0.5,
                    help="fraction of drain waves before the injected "
                         "crash (default 0.5)")
    ap.add_argument("--open-loop", action="store_true",
                    help="latency-under-load mode: Poisson arrivals "
                         "against the continuous-batching loop; p50/p99 "
                         "vs offered QPS, product vs single-axis drain")
    ap.add_argument("--qps", default="20,50",
                    help="open-loop offered QPS levels (default 20,50)")
    ap.add_argument("--duration", type=float, default=2.0,
                    help="open-loop seconds of arrivals per level")
    ap.add_argument("--tenants", type=int, default=5,
                    help="open-loop single-query tenant graphs beside "
                         "the hot graph (default 5)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="with --crash-resume/--open-loop: merge the "
                         "rows into this aam-bench/v1 trajectory file")
    args = ap.parse_args()
    if args.open_loop:
        kinds = tuple((args.kinds or "bfs").split(","))
        rows = open_loop(kinds,
                         qps_levels=tuple(int(x)
                                          for x in args.qps.split(",")),
                         duration_s=args.duration, scale=args.scale,
                         tenants=args.tenants, backend=args.backend)
        for r in rows:
            emit(f"serve_open/{r['kind']}/{r['mode']}"
                 f"/qps={r['offered_qps']}", r["p99_ms"] / 1e3,
                 f"p50={r['p50_ms']}ms p99={r['p99_ms']}ms "
                 f"achieved_qps={r['achieved_qps']} n={r['n']}")
        by_level: dict = {}
        for r in rows:
            by_level.setdefault((r["kind"], r["offered_qps"]),
                                {})[r["mode"]] = r["p99_ms"]
        for (kind, qps), modes in sorted(by_level.items()):
            if len(modes) == 2:
                print(f"# {kind} @ {qps} qps: p99 product="
                      f"{modes['product']}ms single-axis="
                      f"{modes['single-axis']}ms")
        if args.json:
            _open_rows_to_json(rows, args.json)
        raise SystemExit(0)
    if args.crash_resume:
        kinds = tuple((args.kinds or "bfs,ppr").split(","))
        lane = max(int(x) for x in args.lanes.split(","))
        rows = crash_resume(kinds, scale=args.scale, queries=args.queries,
                            lanes=lane, crash_at=args.crash_at,
                            backend=args.backend)
        for r in rows:
            assert r["tickets_recovered"], (r["kind"], "lost tickets")
            assert r["correct"], (r["kind"], "recovered answers diverged")
            emit(f"crash/{r['kind']}/restore", r["restore_ms"] / 1e3,
                 f"recovery_qps={r['recovery_qps']} "
                 f"crash_wave={r['crash_wave']} "
                 f"timing_runs={r['timing_runs_post_restore']}")
        if args.json:
            _crash_rows_to_json(rows, args.json)
        raise SystemExit(0)
    kinds = args.kinds or ("bfs,coloring" if args.axis == "graphs"
                           else "bfs,ppr")
    main(kinds=tuple(kinds.split(",")),
         lanes=tuple(int(x) for x in args.lanes.split(",")),
         graphs=tuple(int(x) for x in args.graphs.split(",")),
         scale=args.scale, queries=args.queries, backend=args.backend,
         axis=args.axis)
