"""Paper Table 1 — BFS across real-world graph families (structurally
matched synthetics, DESIGN.md §7): per-family optimal M and speedup over
the fine-atomics baseline.  The paper's finding that graph families cluster
around similar M* is checked here."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.commit import BACKENDS, CommitSpec
from repro.graphs.algorithms.bfs import bfs
from repro.graphs.generators import TABLE1_FAMILIES

MS = [64, 512, 4096, 16384]
N = 1 << 13


def main(backend: str = "coarse"):
    base = CommitSpec(backend="atomic", stats=False)
    for fam, gen in TABLE1_FAMILIES.items():
        g = gen(N)
        deg = np.asarray(g.degrees)
        src = int(np.argmax(deg))
        ta = timeit(lambda: bfs(g, src, spec=base), repeats=3)
        best = (None, float("inf"))
        for m in MS:
            spec = CommitSpec(backend=backend, m=m, sort=False, stats=False)
            t = timeit(lambda spec=spec: bfs(g, src, spec=spec), repeats=3)
            if t < best[1]:
                best = (m, t)
        emit(f"table1/{fam}/{backend}", best[1],
             f"V={g.num_vertices} E={g.num_edges} M*={best[0]} "
             f"T1_ratio={ta/best[1]:.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS, default="coarse")
    main(ap.parse_args().backend)
