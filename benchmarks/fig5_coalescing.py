"""Paper Fig 5 — inter-node activities: remote commit throughput vs
coalescing factor C, and the distributed-transaction scenarios O-1..O-4
(§5.7 ownership protocol).  Runs in a child process with 8 forced host
devices (the parent bench process keeps 1 device, per the assignment)."""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import emit
from repro.core.commit import BACKENDS

CHILD = """
import json, os, time, numpy as np, jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.graphs.generators import kronecker
from repro.core.commit import CommitSpec
from repro.core.engine import distributed_bfs, distributed_pagerank
from repro.core.ownership import run_transactions

spec = CommitSpec(backend=os.environ.get("AAM_BACKEND", "coarse"),
                  stats=True)
mesh = make_host_mesh(8, 1)
g = kronecker(13, 8, seed=2)
src = int(np.argmax(np.asarray(g.degrees)))
out = {}

def t(fn, reps=3):
    fn(); ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); fn(); ts.append(time.perf_counter() - t0)
    ts.sort(); return ts[len(ts)//2]

# remote marking (BFS-wave) vs coalescing factor C  [Fig 5c/5d analogue]
for C in (64, 256, 1024, 4096, 16384):
    out[f"bfs_C={C}"] = t(lambda C=C: distributed_bfs(
        mesh, g, src, capacity=C, spec=spec)[0].block_until_ready())

# remote accumulate (PR) vs C  [Fig 5e/5f analogue]
for C in (256, 4096, 16384):
    out[f"pr_C={C}"] = t(lambda C=C: distributed_pagerank(
        mesh, g, iters=3, capacity=C, spec=spec).block_until_ready(),
        reps=2)

# ownership-protocol scenarios [Fig 5i]: x txns of a local + b remote
rng = np.random.default_rng(0)
V = 1 << 14
for name, x, a, b in (("O-1", 100, 5, 1), ("O-2", 1000, 5, 1),
                      ("O-3", 100, 7, 3), ("O-4", 1000, 7, 3)):
    block = V // 8
    local = rng.integers(0, block, (8, x, a))
    local += (np.arange(8)[:, None, None] * block)
    remote = rng.integers(0, V, (8, x, b))
    txns = jnp.asarray(np.concatenate([local, remote], axis=2), jnp.int32)
    def run(txns=txns):
        vis, st = run_transactions(mesh, txns, V, capacity=8192)
        return vis.block_until_ready(), int(st.rounds), int(st.retries)
    _, rounds, retries = run()
    out[name] = {"s": t(lambda: run()[0], reps=2), "rounds": rounds,
                 "retries": retries}
print("RESULT", json.dumps(out))
"""


def main(backend: str = "coarse"):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent / "src")
    env["AAM_BACKEND"] = backend
    p = subprocess.run([sys.executable, "-c", textwrap.dedent(CHILD)],
                       capture_output=True, text=True, env=env, timeout=1200)
    if p.returncode != 0:
        emit("fig5/ERROR", 0.0, p.stderr[-300:].replace("\n", " "))
        return
    line = [l for l in p.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    for k, v in out.items():
        if isinstance(v, dict):
            emit(f"fig5/own/{k}", v["s"],
                 f"rounds={v['rounds']} retries={v['retries']}")
        else:
            emit(f"fig5/{backend}/{k}", v)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS, default="coarse",
                    help="commit backend used by the owner-side commits")
    main(ap.parse_args().backend)
