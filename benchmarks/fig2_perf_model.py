"""Paper Fig 2 — performance-model validation: T(N) = B + A·N for fine
(one atomic per vertex) vs coarse (one transaction over N vertices), the
linear fits, and the crossing point N*."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.commit import CommitSpec, commit
from repro.core.messages import make_messages
from repro.core.perf_model import crossing_point, fit, select_m

V = 1 << 16
NS = [1, 2, 4, 8, 16, 32, 64, 128, 256, 512]

FINE = CommitSpec(backend="atomic")
COARSE = CommitSpec(backend="coarse")


def _fine_activity(state, tgt, val):
    """N sequential single-message commits (the atomics baseline: one
    memory-system round trip per vertex)."""
    def body(st, tv):
        t, v_ = tv
        m = make_messages(t[None], v_[None], jnp.ones((1,), bool))
        return commit(st, m, "min", FINE).state, None
    out, _ = jax.lax.scan(body, state, (tgt, val))
    return out


@jax.jit
def _coarse_activity(state, tgt, val):
    m = make_messages(tgt, val, jnp.ones_like(tgt, bool))
    return commit(state, m, "min", COARSE).state


def main():
    rng = np.random.default_rng(0)
    state = jnp.full((V,), 2 ** 30, jnp.int32)
    fine_t, coarse_t = [], []
    fine_j = jax.jit(_fine_activity)
    for n in NS:
        tgt = jnp.asarray(rng.integers(0, V, n), jnp.int32)
        val = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
        tf = timeit(fine_j, state, tgt, val)
        tc = timeit(_coarse_activity, state, tgt, val)
        fine_t.append(tf)
        coarse_t.append(tc)
        emit(f"fig2/fine/N={n}", tf)
        emit(f"fig2/coarse/N={n}", tc)
    ff = fit(NS, fine_t)
    fc = fit(NS, coarse_t)
    n_star = crossing_point(ff, fc)
    m_star = select_m(ff, fc)
    emit("fig2/fit/fine", 0.0,
         f"B={ff.intercept*1e6:.1f}us A={ff.slope*1e6:.3f}us r2={ff.r2:.4f}")
    emit("fig2/fit/coarse", 0.0,
         f"B={fc.intercept*1e6:.1f}us A={fc.slope*1e6:.3f}us r2={fc.r2:.4f}")
    emit("fig2/crossing", 0.0, f"N*={n_star:.1f} M*={m_star}")


if __name__ == "__main__":
    main()
