"""Paper Fig 7 — scalability: BFS strong scaling over shard counts, and
distributed PageRank AAM (coalesced accumulate) vs the PBGL-like per-edge
baseline.  Child processes force 1/2/4/8 host devices."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import emit, timeit

CHILD = """
import json, time, numpy as np, jax
from repro.launch.mesh import make_host_mesh
from repro.graphs.generators import kronecker
from repro.core.engine import distributed_bfs, distributed_pagerank
P = {P}
mesh = make_host_mesh(P, 1)
g = kronecker(13, 8, seed=5)
src = int(np.argmax(np.asarray(g.degrees)))

def t(fn, reps=3):
    fn(); ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); fn(); ts.append(time.perf_counter()-t0)
    ts.sort(); return ts[len(ts)//2]

out = {{}}
out["bfs"] = t(lambda: distributed_bfs(mesh, g, src,
                                       capacity=8192)[0].block_until_ready())
out["pr"] = t(lambda: distributed_pagerank(mesh, g, iters=5,
                                           capacity=8192).block_until_ready(),
              reps=2)
print("RESULT", json.dumps(out))
"""


def main():
    # single-shard PBGL-like baseline: per-edge atomic accumulate PR
    from repro.core.commit import CommitSpec
    from repro.graphs.algorithms.pagerank import pagerank
    from repro.graphs.generators import kronecker
    import numpy as np
    g = kronecker(13, 8, seed=5)
    tb = timeit(lambda: pagerank(
        g, iters=5, spec=CommitSpec(backend="atomic", stats=False))[0]
        .block_until_ready(), repeats=2)
    ta = timeit(lambda: pagerank(
        g, iters=5, spec=CommitSpec(backend="coarse", sort=False,
                                    stats=False))[0]
        .block_until_ready(), repeats=2)
    emit("fig7/pr/1shard/pbgl_like", tb)
    emit("fig7/pr/1shard/aam", ta, f"T1_ratio={tb/ta:.2f}")

    env_base = dict(os.environ)
    env_base["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent /
                                 "src")
    for p_ in (2, 4, 8):
        env = dict(env_base)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p_}"
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(CHILD.format(P=p_))],
            capture_output=True, text=True, env=env, timeout=1200)
        if r.returncode != 0:
            emit(f"fig7/P={p_}/ERROR", 0.0, r.stderr[-200:].replace("\n", " "))
            continue
        line = [l for l in r.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        out = json.loads(line[len("RESULT "):])
        emit(f"fig7/bfs/P={p_}", out["bfs"])
        emit(f"fig7/pr/P={p_}", out["pr"])


if __name__ == "__main__":
    main()
