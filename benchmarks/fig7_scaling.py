"""Paper Fig 7 — scalability: strong scaling of ALL SIX distributed
algorithms over shard counts (one `run_distributed` harness), plus the
distributed PageRank AAM (coalesced accumulate) vs the PBGL-like per-edge
baseline.  Child processes force 1/2/4/8 host devices; ``--backend``
(or ``benchmarks.run --backend``) sweeps the commit mechanism."""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

from benchmarks.common import emit, timeit

ALGOS = ("bfs", "pagerank", "sssp", "coloring", "stconn", "boruvka")

CHILD = """
import json, time, numpy as np, jax
from repro.launch.mesh import make_host_mesh
from repro.graphs.generators import kronecker, random_weights
from repro.core.commit import CommitSpec
from repro.graphs.algorithms.bfs import distributed_bfs
from repro.graphs.algorithms.pagerank import distributed_pagerank
from repro.graphs.algorithms.sssp import distributed_sssp
from repro.graphs.algorithms.coloring import distributed_coloring
from repro.graphs.algorithms.stconn import distributed_stconn
from repro.graphs.algorithms.boruvka import distributed_boruvka
P = {P}
mesh = make_host_mesh(P, 1)
g = kronecker({scale}, 8, seed=5)
gw = random_weights(g, seed=2)
deg = np.asarray(g.degrees)
src = int(np.argmax(deg))
far = int(next(i for i in np.argsort(deg)[::-1] if i != src))
spec = CommitSpec(backend="{backend}", stats=False)
kw = dict(capacity=8192, spec=spec)
RUNS = {{
    "bfs": lambda: distributed_bfs(mesh, g, src, **kw)[0]
        .block_until_ready(),
    "pagerank": lambda: distributed_pagerank(mesh, g, iters=5, **kw)
        .block_until_ready(),
    "sssp": lambda: distributed_sssp(mesh, gw, src, **kw)[0]
        .block_until_ready(),
    "coloring": lambda: distributed_coloring(mesh, g, seed=0, **kw)[0]
        .block_until_ready(),
    "stconn": lambda: distributed_stconn(mesh, g, src, far, **kw)[0]
        .block_until_ready(),
    "boruvka": lambda: distributed_boruvka(mesh, gw, **kw)[0]
        .block_until_ready(),
}}

def t(fn, reps=3):
    fn(); ts = []
    for _ in range(reps):
        t0 = time.perf_counter(); fn(); ts.append(time.perf_counter()-t0)
    ts.sort(); return ts[len(ts)//2]

out = {{name: t(fn) for name, fn in RUNS.items()}}
print("RESULT", json.dumps(out))
"""


def main(backend: str = "coarse", scale: int = 11):
    # single-shard PBGL-like baseline: per-edge atomic accumulate PR
    from repro.core.commit import CommitSpec
    from repro.graphs.algorithms.pagerank import pagerank
    from repro.graphs.generators import kronecker
    g = kronecker(scale, 8, seed=5)
    tb = timeit(lambda: pagerank(
        g, iters=5, spec=CommitSpec(backend="atomic", stats=False))[0]
        .block_until_ready(), repeats=2)
    ta = timeit(lambda: pagerank(
        g, iters=5, spec=CommitSpec(backend="coarse", sort=False,
                                    stats=False))[0]
        .block_until_ready(), repeats=2)
    emit("fig7/pr/1shard/pbgl_like", tb)
    emit("fig7/pr/1shard/aam", ta, f"T1_ratio={tb/ta:.2f}")

    env_base = dict(os.environ)
    env_base["PYTHONPATH"] = str(Path(__file__).resolve().parent.parent /
                                 "src")
    for p_ in (2, 4, 8):
        env = dict(env_base)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={p_}"
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(
                CHILD.format(P=p_, scale=scale, backend=backend))],
            capture_output=True, text=True, env=env, timeout=2400)
        if r.returncode != 0:
            emit(f"fig7/P={p_}/ERROR", 0.0, r.stderr[-200:].replace("\n", " "))
            continue
        line = [l for l in r.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        out = json.loads(line[len("RESULT "):])
        for name in ALGOS:
            emit(f"fig7/{name}/{backend}/P={p_}", out[name])


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="coarse",
                    choices=("atomic", "coarse", "pallas"))
    ap.add_argument("--scale", type=int, default=11)
    args = ap.parse_args()
    main(backend=args.backend, scale=args.scale)
