"""Paper Fig 6 — intra-node BFS across graph scale |V| and density d̄:
AAM coarse transactions vs the fine-atomics Graph500 baseline."""
from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import emit, timeit
from repro.core.commit import BACKENDS, CommitSpec
from repro.graphs.algorithms.bfs import bfs
from repro.graphs.generators import kronecker

ATOMIC = CommitSpec(backend="atomic", stats=False)


def main(backend: str = "coarse", scales=(12, 13, 14, 15),
         densities=(4, 16, 64), edge_factor: int = 16,
         density_scale: int = 13):
    if backend == "auto":
        aam = CommitSpec(backend="auto", stats=False)   # tuner picks M
    else:
        aam = CommitSpec(backend=backend, m=4096, sort=False, stats=False)
    # |V| sweep at fixed edge factor
    for scale in scales:
        g = kronecker(scale, edge_factor, seed=3)
        src = int(np.argmax(np.asarray(g.degrees)))
        ta = timeit(lambda: bfs(g, src, spec=ATOMIC), repeats=3)
        tc = timeit(lambda: bfs(g, src, spec=aam), repeats=3)
        emit(f"fig6/V=2^{scale}/atomic", ta)
        emit(f"fig6/V=2^{scale}/aam", tc, f"T1_ratio={ta/tc:.2f}")
    # density sweep at fixed |V|
    for d in densities:
        g = kronecker(density_scale, d, seed=4)
        src = int(np.argmax(np.asarray(g.degrees)))
        ta = timeit(lambda: bfs(g, src, spec=ATOMIC), repeats=3)
        tc = timeit(lambda: bfs(g, src, spec=aam), repeats=3)
        emit(f"fig6/d={d}/atomic", ta)
        emit(f"fig6/d={d}/aam", tc, f"T1_ratio={ta/tc:.2f}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=BACKENDS + ("auto",),
                    default="coarse")
    main(ap.parse_args().backend)
