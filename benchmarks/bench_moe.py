"""Beyond-paper — MoE token dispatch: AAM sorted/coalesced path vs the
GShard dense one-hot baseline (the paper's technique applied to the LM
substrate, DESIGN.md §3)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit
from repro.configs.archs import ARCHS
from repro.configs.base import smoke_model
from repro.moe import moe_layer


def main():
    import dataclasses
    cfg = dataclasses.replace(
        smoke_model(ARCHS["qwen3-moe-235b-a22b"]),
        d_model=256, moe_d_ff=512, num_experts=32, experts_per_token=4)
    p, _ = moe_layer.moe_init(cfg, jax.random.PRNGKey(0))
    aam = jax.jit(lambda x: moe_layer.moe_apply_aam(cfg, p, x)[0])
    dense = jax.jit(lambda x: moe_layer.moe_apply_dense(cfg, p, x)[0])
    for t in (1024, 4096, 16384):
        x = jax.random.normal(jax.random.PRNGKey(1), (t, cfg.d_model),
                              jnp.bfloat16)
        ta = timeit(aam, x, repeats=3)
        td = timeit(dense, x, repeats=3)
        emit(f"moe/aam/T={t}", ta, f"speedup_vs_dense={td/ta:.2f}")
        emit(f"moe/dense/T={t}", td)


if __name__ == "__main__":
    main()
