#!/usr/bin/env bash
# Committed launch configuration for the perf trajectory (ROADMAP item 3).
#
# Every BENCH_*.json row is only comparable to the previous PR's rows if
# both were measured under the same allocator, XLA flag matrix, and dtype
# pins — this script IS that configuration.  Usage:
#
#   ./bench.sh                         # full tiny matrix -> $BENCH
#   ./bench.sh --suite fused           # CSV rows for one suite
#   BENCH=BENCH_pr11.json ./bench.sh   # next PR's trajectory file
#
# Extra args are passed through to benchmarks.run verbatim.
set -euo pipefail
cd "$(dirname "$0")"

# --- allocator: tcmalloc when the host has it (the HomebrewNLP/olmax
# run.sh trick) — glibc malloc fragments under the bucket-buffer churn
# of the wave loop.  Silently skipped where absent so the script stays
# runnable on any host.
for so in /usr/lib/x86_64-linux-gnu/libtcmalloc.so.4 \
          /usr/lib/x86_64-linux-gnu/libtcmalloc_minimal.so.4 \
          /usr/lib/libtcmalloc.so.4; do
    if [ -e "$so" ]; then
        export LD_PRELOAD="$so"
        export TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD=60000000000
        break
    fi
done

# --- XLA flag matrix: deterministic single-device CPU timing unless the
# caller pins their own (fig7 children force their device counts on top
# of this via REPRO_XLA_EXTRA — see tests/test_distributed.py).
#   - one host device: the timed suites are single-shard; oversubscribed
#     host "devices" only add scheduler noise to the rows
#   - no multi-threaded Eigen: same pin as the tier2 matrix, run-to-run
#     reproducible timings on shared hosts
BENCH_XLA="--xla_force_host_platform_device_count=1"
BENCH_XLA="$BENCH_XLA --xla_cpu_multi_thread_eigen=false"
export XLA_FLAGS="${XLA_FLAGS:-$BENCH_XLA}"

# --- dtype pins: the commit pipeline is int32/float32 end-to-end (key
# space, payloads, kernel envelope).  x64 mode would silently widen
# jnp literals, double the VMEM working set, and time a different
# kernel than production runs.
export JAX_ENABLE_X64=0
export JAX_DEFAULT_DTYPE_BITS=32

export TF_CPP_MIN_LOG_LEVEL=${TF_CPP_MIN_LOG_LEVEL:-4}
export PYTHONHASHSEED=0
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

BENCH="${BENCH:-BENCH_pr10.json}"
if [ "$#" -eq 0 ]; then
    exec python -m benchmarks.run --json "$BENCH" --sizes tiny
fi
exec python -m benchmarks.run "$@"
