"""Error-feedback int8 gradient compression for the cross-pod DP all-reduce.

Cross-pod gradient reduction rides the slowest links (inter-pod DCN/ICI);
int8 quantization cuts wire bytes 4x while error feedback (Karimireddy et
al., 2019) keeps convergence — the quantization residual is carried into
the next step instead of dropped.  Implemented as an explicit shard_map
reduction over the ``pod`` axis: each pod quantizes (grad + ef) per leaf
with a shared symmetric scale, all-gathers the int8 payloads (+ f32 scales,
negligible), and dequantize-averages locally.

Convergence is regression-tested (tests/test_grad_compression.py): tiny-LM
training with compression tracks the uncompressed loss curve.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as Ps


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(g, ef):
    x = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    err = x - q.astype(jnp.float32) * scale
    return q, scale, err


def compressed_psum_mean(grads, ef, axis: str):
    """Per-leaf int8 all-gather + local dequant-mean over ``axis``.
    Call INSIDE shard_map.  Returns (mean_grads, new_ef)."""
    n = jax.lax.psum(1, axis)

    def per_leaf(g, e):
        q, scale, err = _quantize(g, e)
        qs = jax.lax.all_gather(q, axis)                 # int8 on the wire
        ss = jax.lax.all_gather(scale, axis)             # [n] f32
        deq = qs.astype(jnp.float32) * ss.reshape(
            (n,) + (1,) * g.ndim)
        return jnp.mean(deq, axis=0).astype(g.dtype), err

    out = jax.tree.map(per_leaf, grads, ef)
    mean = jax.tree.map(lambda o: o[0], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree.map(lambda o: o[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_ef


def make_compressed_dp_step(loss_fn, opt, mesh, axis: str = "pod"):
    """Explicit-DP train step: per-shard grads -> compressed mean -> update.

    loss_fn(params, batch) -> (loss, metrics); batch sharded on ``axis``.
    Everything else (params, opt state, ef) is replicated over ``axis``.
    """
    def step(params, opt_state, ef, step_i, batch):
        def shard_fn(params, opt_state, ef, step_i, batch):
            batch = jax.tree.map(lambda x: x[0], batch)   # strip axis dim
            (l, metrics), g = jax.value_and_grad(
                lambda p: loss_fn(p, batch), has_aux=True)(params)
            g, ef2 = compressed_psum_mean(g, ef, axis)
            new_p, new_o = opt.update(g, opt_state, params, step_i)
            l = jax.lax.pmean(l, axis)
            return new_p, new_o, ef2, l
        fn = compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(Ps(), Ps(), Ps(), Ps(), Ps(axis)),
            out_specs=(Ps(), Ps(), Ps(), Ps()),
            check_vma=False)
        return fn(params, opt_state, ef, step_i, batch)

    return jax.jit(step)
