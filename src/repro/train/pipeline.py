"""GPipe-style pipeline parallelism over a mesh axis.

Stages hold contiguous slices of the stacked block parameters (the leading
``num_blocks`` axis), microbatches stream through a ``collective_permute``
chain, and the whole schedule differentiates through ``jax.grad`` (ppermute
has a transpose rule), so PP composes with the existing optimizer stack.
Stage 0 embeds; the last stage computes logits/loss; intermediate
activations are the only cross-stage traffic (one [mb, S, d] tensor per
microbatch per boundary — DCN-friendly, which is why PP is the alternative
to DP across pods: config ``pipeline_stages`` on the ``pod`` axis).

Forward-equivalence vs the plain stack is tested on a 2-stage host mesh
(tests/test_pipeline.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as Ps

from repro.configs.base import ModelConfig, RunConfig
from repro.models import layers as L
from repro.models import lm


def _stage_blocks(params_blocks, stage, num_stages, num_blocks):
    """Slice each pattern-position stack to this stage's block range."""
    per = num_blocks // num_stages
    return jax.tree.map(
        lambda x: jax.lax.dynamic_slice_in_dim(x, stage * per, per, axis=0),
        params_blocks)


def pipeline_forward(cfg: ModelConfig, rcfg: RunConfig, mesh, axis: str,
                     num_microbatches: int):
    """Returns f(params, tokens) -> logits, running the block stack as
    ``axis``-many pipeline stages.  num_blocks must divide evenly."""
    num_stages = mesh.shape[axis]
    assert cfg.num_blocks % num_stages == 0, (cfg.num_blocks, num_stages)

    def shard_fn(params, tokens):
        stage = jax.lax.axis_index(axis)
        nmb = num_microbatches
        b = tokens.shape[0]
        mb = b // nmb
        blocks = _stage_blocks(params["blocks"], stage, num_stages,
                               cfg.num_blocks)

        def run_stage(x):
            def block_fn(x, bp):
                for i, spec in enumerate(cfg.full_pattern):
                    x, _, _ = lm.apply_layer(cfg, rcfg, spec, bp[i], x,
                                             positions, mode="train")
                return x, None
            x, _ = jax.lax.scan(block_fn, x, blocks)
            return x

        s = tokens.shape[1]
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (mb, s))
        cd = jnp.dtype(rcfg.compute_dtype)

        # schedule: nmb + num_stages - 1 ticks
        ticks = nmb + num_stages - 1
        outs = []
        carry = jnp.zeros((mb, s, cfg.d_model), cd)
        for t in range(ticks):
            # stage 0 ingests microbatch t (if any)
            mb_idx = min(t, nmb - 1)
            tok_mb = jax.lax.dynamic_slice_in_dim(tokens, mb_idx * mb, mb, 0)
            fresh = L.embed_tokens(cfg, params["embed"], tok_mb, cd)
            x = jnp.where(stage == 0, fresh, carry)
            y = run_stage(x)
            # pass activations down the chain
            perm = [(i, i + 1) for i in range(num_stages - 1)]
            carry = jax.lax.ppermute(y, axis, perm)
            if t >= num_stages - 1:
                outs.append(y)          # last stage's finished microbatch
        out = jnp.concatenate(outs, axis=0)
        x = L.rmsnorm(out, params["final_norm"], cfg.norm_eps,
                      zero_centered=cfg.use_post_norm)
        logits = L.lm_logits(cfg, params["embed"], x)
        # only the last stage's logits are real; broadcast them
        src = num_stages - 1
        perm = [(src, i) for i in range(num_stages) if i != src]
        logits = jnp.where(stage == src, logits,
                           jnp.zeros_like(logits))
        logits = jax.lax.psum(logits, axis)
        return logits

    return jax.jit(compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(Ps(), Ps()),
        out_specs=Ps(), check_vma=False))
