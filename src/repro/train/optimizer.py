"""Optimizers implemented in-framework (no optax dependency).

* AdamW — default for ≤70B-scale configs.
* Adafactor (factored second moment, no first moment by default) — default
  for the 235B/398B configs so optimizer state fits 16 GB/chip HBM
  (DESIGN.md §4.1).

State layouts mirror param layouts, so the same sharding rules apply.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Any    # params -> opt_state
    update: Any  # (grads, opt_state, params, step) -> (new_params, new_state)


def _tree_zeros_like(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


def adamw(rcfg: RunConfig, b1=0.9, b2=0.95, eps=1e-8) -> Optimizer:
    lr, wd = rcfg.learning_rate, rcfg.weight_decay

    def init(params):
        return {"m": _tree_zeros_like(params, jnp.float32),
                "v": _tree_zeros_like(params, jnp.float32)}

    def update(grads, state, params, step):
        step_f = (step + 1).astype(jnp.float32)
        c1 = 1.0 - b1 ** step_f
        c2 = 1.0 - b2 ** step_f

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            u = u + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern, 2018) — factored second moments
# ---------------------------------------------------------------------------


def adafactor(rcfg: RunConfig, decay=0.8, eps=1e-30, clip=1.0) -> Optimizer:
    lr, wd = rcfg.learning_rate, rcfg.weight_decay

    def init(params):
        def per(p):
            if p.ndim >= 2:
                # factor over the two largest dims (trailing two for weights)
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return jax.tree.map(per, params)

    def update(grads, state, params, step):
        step_f = (step + 1).astype(jnp.float32)
        beta = 1.0 - step_f ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)[..., None]
                v = vr[..., None] * vc[..., None, :] / denom
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                new_s = {"v": v}
            u = g / jnp.sqrt(jnp.maximum(v, eps))
            # update clipping (RMS <= clip)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip)
            u = u + wd * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), new_s

        out = jax.tree_util.tree_map(
            upd, grads, state, params,
            is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x))
        # out has tuples at (param, state) positions
        flat, treedef = jax.tree_util.tree_flatten(
            out, is_leaf=lambda x: isinstance(x, tuple))
        new_p = treedef.unflatten([t[0] for t in flat])
        new_s = treedef.unflatten([t[1] for t in flat])
        return new_p, new_s

    return Optimizer(init, update)


def make_optimizer(rcfg: RunConfig) -> Optimizer:
    if rcfg.optimizer == "adafactor":
        return adafactor(rcfg)
    return adamw(rcfg)
