"""Train step: loss → grads (microbatched) → clip → optimizer update.

The step is a single jit program; XLA SPMD inserts the gradient
all-reduce over the ("pod", "data") axes from the sharding annotations.
Microbatching (sequential gradient accumulation via ``lax.scan``) bounds
activation memory independently of global batch.  The explicit-DP variant
with error-feedback int8 gradient compression (cross-pod DCN path) lives in
:mod:`repro.train.grad_compression`.
"""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model as M
from repro.runtime import sharding as shd
from repro.train.optimizer import Optimizer, make_optimizer

RULES = shd.ShardingRules(shd.TRAIN_RULES)


def constrain_like_params(tree):
    """Pin a gradient/accumulator tree to the parameter sharding (forces
    XLA to reduce-scatter into FSDP shards instead of all-reducing full
    f32 gradients — §Perf iteration 'shard-grads')."""
    mesh = shd.get_abstract_mesh()
    if mesh is None:
        return tree
    return jax.tree_util.tree_map_with_path(
        lambda path, g: jax.lax.with_sharding_constraint(
            g, RULES.spec_for(shd.resolve_axes(path, g.ndim), g.shape, mesh)),
        tree)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x * scale).astype(x.dtype), tree), g


def _split_microbatches(batch, n):
    def sp(x):
        b = x.shape[0]
        assert b % n == 0, f"batch {b} not divisible by microbatches {n}"
        return x.reshape((n, b // n) + x.shape[1:])
    return jax.tree.map(sp, batch)


def grads_fn(cfg: ModelConfig, rcfg: RunConfig, params, batch):
    """Microbatched grads + metrics (mean over microbatches)."""
    loss = lambda p, mb: M.loss_fn(cfg, rcfg, p, mb)
    maybe_shard = constrain_like_params if rcfg.shard_grads else (lambda t: t)
    if rcfg.microbatches <= 1:
        (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(
            params, batch)
        return maybe_shard(grads), l, metrics

    mbs = _split_microbatches(batch, rcfg.microbatches)
    zero = maybe_shard(
        jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def acc(carry, mb):
        g_acc, l_acc, m_acc = carry
        (l, metrics), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
        g = maybe_shard(g)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        m_acc = jax.tree.map(
            lambda a, b: a + b.astype(jnp.float32) / rcfg.microbatches,
            m_acc, metrics)
        return (g_acc, l_acc + l / rcfg.microbatches, m_acc), None

    metrics0 = jax.eval_shape(lambda: M.loss_fn(
        cfg, rcfg, params, jax.tree.map(lambda x: x[0], mbs))[1])
    metrics0 = jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                            metrics0)
    (g, l, metrics), _ = jax.lax.scan(
        acc, (zero, jnp.zeros((), jnp.float32), metrics0), mbs)
    g = jax.tree.map(lambda x: x / rcfg.microbatches, g)
    return g, l, metrics


def make_train_step(cfg: ModelConfig, rcfg: RunConfig,
                    opt: Optimizer | None = None):
    opt = opt or make_optimizer(rcfg)

    def train_step(params, opt_state, step, batch):
        grads, loss, metrics = grads_fn(cfg, rcfg, params, batch)
        grads, gnorm = clip_by_global_norm(grads, rcfg.grad_clip)
        params, opt_state = opt.update(grads, opt_state, params, step)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm, step=step + 1)
        return params, opt_state, metrics

    return train_step
