"""Pallas TPU kernel: coarse conflict-resolving commit (DESIGN.md §2.1).

One grid step = one *transaction*: a tile of M messages ``(idx, val)`` is
resolved against a ``B``-vertex block of the state array entirely in VMEM.
The M×B one-hot incidence is materialized in registers/VMEM and reduced:

* ``add`` (Always-Succeed accumulate): ``contrib = valᵀ · onehot`` — an MXU
  matmul (this is why the AS commit is *serialization-free* on TPU, unlike
  the paper's HTM abort storm for ACC in §5.4.2);
* ``min``/``max`` (May-Fail): masked VPU reduction over the tile dim;
* ``or`` (AS mark): any-reduction of truthy payloads;
* ``first`` (MF first-writer-wins into empty ``<0`` slots, ties broken by
  lowest global message id — payloads must be non-negative since negative
  state encodes "empty").

The (M × B) working set is the transaction's read/write set and must fit
VMEM — the exact analogue of the paper's HTM speculative-state capacity
(L1/L2): oversized M spills and "aborts" become tile re-fetches.  M is the
paper's transaction-size knob; the roofline sweep lives in
``benchmarks/fig4_coarsening.py``.

Grid = (state_blocks, message_tiles); message tiles iterate innermost so a
state block stays resident while every transaction visits it.  Messages
sorted by target (coalescing) make non-incident (tile, block) pairs cheap
(all-masked compare, no state traffic); unsorted messages model the paper's
uncoalesced baseline.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _identity(op: str, dtype):
    if op == "min":
        return (jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer)
                else jnp.inf)
    if op == "max":
        return (jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer)
                else -jnp.inf)
    if op == "first":
        return -1                                        # empty-slot marker
    return 0


_RANK_INF = 2 ** 30     # plain int: jnp constants can't be kernel captures


def _commit_kernel(idx_ref, val_ref, state_ref, out_ref, conf_ref=None, *,
                   op: str, tile_m: int, block_v: int):
    b = pl.program_id(0)
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = state_ref[...]

    idx = idx_ref[...]                                   # [M] int32
    val = val_ref[...]                                   # [M]
    base = b * block_v
    rel = idx - base
    mask = (rel >= 0) & (rel < block_v) & (idx >= 0)     # idx -1 = invalid
    relc = jnp.where(mask, rel, 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (tile_m, block_v), 1)
    onehot = (lane == relc[:, None]) & mask[:, None]     # [M, B]

    if conf_ref is not None:
        # conflict telemetry: in-transaction messages sharing a target in
        # this block (the abort-statistics analogue; summed over the grid
        # outside).  stats=False omits the ref and skips the reduction.
        cnt = jnp.sum(onehot.astype(jnp.int32), axis=0)  # [B]
        conf_ref[0, 0] = jnp.sum(jnp.where(cnt > 1, cnt, 0))

    if op == "add":
        if jnp.issubdtype(val.dtype, jnp.floating):
            contrib = jax.lax.dot(
                val[None, :].astype(jnp.float32),
                onehot.astype(jnp.float32),
                precision=jax.lax.Precision.HIGHEST)[0]  # MXU path
        else:
            contrib = jnp.sum(
                jnp.where(onehot, val[:, None], 0), axis=0)
        out_ref[...] += contrib.astype(out_ref.dtype)
    elif op == "min":
        ident = _identity(op, val.dtype)
        cand = jnp.where(onehot, val[:, None], ident)
        out_ref[...] = jnp.minimum(out_ref[...], jnp.min(cand, axis=0))
    elif op == "max":
        ident = _identity(op, val.dtype)
        cand = jnp.where(onehot, val[:, None], ident)
        out_ref[...] = jnp.maximum(out_ref[...], jnp.max(cand, axis=0))
    elif op == "or":
        hit = jnp.any(onehot & (val[:, None] != 0), axis=0)
        out_ref[...] = jnp.maximum(out_ref[...], hit.astype(out_ref.dtype))
    elif op == "first":
        # first-writer-wins into empty (<0) slots; tie-break = lowest
        # global message id.  Transactions execute in grid order, so the
        # in-tile winner composes to the batch-wide lowest id.
        cur = out_ref[...]
        empty = cur < 0
        rank = (m * tile_m
                + jax.lax.broadcasted_iota(jnp.int32, (tile_m, block_v), 0))
        key = jnp.where(onehot & empty[None, :], rank, _RANK_INF)
        win = jnp.min(key, axis=0)                       # [B]
        wsel = onehot & (key == win[None, :]) & (win[None, :] < _RANK_INF)
        wval = jnp.sum(jnp.where(wsel, val[:, None], 0), axis=0)
        out_ref[...] = jnp.where(empty & (win < _RANK_INF),
                                 wval.astype(cur.dtype), cur)
    else:
        raise ValueError(op)


@functools.partial(jax.jit, static_argnames=("op", "tile_m", "block_v",
                                             "interpret", "stats"))
def coarse_commit_pallas(state, idx, val, *, op: str = "min",
                         tile_m: int = 256, block_v: int = 512,
                         interpret: bool = True, stats: bool = False):
    """state: [V]; idx: [N] int32 (-1 = masked); val: [N].

    Returns the committed state; with ``stats=True`` returns
    ``(state, conflicts)`` where ``conflicts`` is the int32 count of
    in-transaction duplicate-target messages accumulated over the grid
    (one transaction = one ``tile_m`` tile), so :class:`CommitResult`
    telemetry is available from the kernel path too.  ``interpret=True``
    executes on CPU (this container); on real TPU pass ``interpret=False``.
    """
    v = state.shape[0]
    n = idx.shape[0]
    if n == 0 or v == 0:
        return (state, jnp.zeros((), jnp.int32)) if stats else state
    vpad = (-v) % block_v
    npad = (-n) % tile_m
    ident = _identity(op, state.dtype)
    state_p = jnp.pad(state, (0, vpad),
                      constant_values=state.dtype.type(ident)
                      if op not in ("add", "or") else 0)
    idx_p = jnp.pad(idx, (0, npad), constant_values=-1)
    val_p = jnp.pad(val, (0, npad))
    nb = (v + vpad) // block_v
    nm = (n + npad) // tile_m

    out_specs = [pl.BlockSpec((block_v,), lambda b, m: (b,))]
    out_shape = [jax.ShapeDtypeStruct(state_p.shape, state.dtype)]
    if stats:
        out_specs.append(pl.BlockSpec((1, 1), lambda b, m: (b, m)))
        out_shape.append(jax.ShapeDtypeStruct((nb, nm), jnp.int32))
    res = pl.pallas_call(
        functools.partial(_commit_kernel, op=op, tile_m=tile_m,
                          block_v=block_v),
        grid=(nb, nm),
        in_specs=[
            pl.BlockSpec((tile_m,), lambda b, m: (m,)),
            pl.BlockSpec((tile_m,), lambda b, m: (m,)),
            pl.BlockSpec((block_v,), lambda b, m: (b,)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(idx_p, val_p, state_p)
    if stats:
        out, conf = res
        return out[:v], jnp.sum(conf)
    return res[0][:v]
