"""Pallas TPU kernel: bucket histogram (coalescing planner hot-spot).

Counting messages per destination shard / expert is the first step of every
coalescing round (paper §4.2).  One grid step processes a tile of M owner
ids against the full [num_buckets] count vector in VMEM via a one-hot
column-sum — the same M×B tile pattern as the commit kernel with op=add on
unit payloads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _count_kernel(owner_ref, out_ref, *, tile_m: int, nb: int):
    m = pl.program_id(0)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    owner = owner_ref[...]                              # [M]
    mask = (owner >= 0) & (owner < nb)
    safe = jnp.where(mask, owner, 0)
    lane = jax.lax.broadcasted_iota(jnp.int32, (tile_m, nb), 1)
    onehot = (lane == safe[:, None]) & mask[:, None]
    out_ref[...] += jnp.sum(onehot.astype(jnp.int32), axis=0)


@functools.partial(jax.jit, static_argnames=("num_buckets", "tile_m",
                                             "interpret"))
def bucket_count_pallas(owner, *, num_buckets: int, tile_m: int = 512,
                        interpret: bool = True):
    """owner: [N] int32 (-1 = masked) -> counts [num_buckets] int32."""
    n = owner.shape[0]
    npad = (-n) % tile_m
    owner_p = jnp.pad(owner, (0, npad), constant_values=-1)
    nbpad = (-num_buckets) % 128
    nb = num_buckets + nbpad
    nm = (n + npad) // tile_m
    out = pl.pallas_call(
        functools.partial(_count_kernel, tile_m=tile_m, nb=nb),
        grid=(nm,),
        in_specs=[pl.BlockSpec((tile_m,), lambda m: (m,))],
        out_specs=pl.BlockSpec((nb,), lambda m: (0,)),
        out_shape=jax.ShapeDtypeStruct((nb,), jnp.int32),
        interpret=interpret,
    )(owner_p)
    return out[:num_buckets]
