"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def coarse_commit_ref(state, idx, val, *, op: str = "min"):
    """Semantics of the coarse commit: resolve in-batch conflicts with the
    reduction op, then combine with state.  idx -1 (or OOB) = masked."""
    v = state.shape[0]
    valid = (idx >= 0) & (idx < v)
    safe = jnp.where(valid, idx, v)
    if op == "add":
        red = jax.ops.segment_sum(jnp.where(valid, val, 0), safe,
                                  num_segments=v + 1)[:v]
        return state + red.astype(state.dtype)
    if op == "min":
        red = jax.ops.segment_min(jnp.where(valid, val, _big(val.dtype)),
                                  safe, num_segments=v + 1)[:v]
        return jnp.minimum(state, red.astype(state.dtype))
    if op == "max":
        red = jax.ops.segment_max(jnp.where(valid, val, _small(val.dtype)),
                                  safe, num_segments=v + 1)[:v]
        return jnp.maximum(state, red.astype(state.dtype))
    if op == "or":
        red = jax.ops.segment_max(jnp.where(valid, (val != 0).astype(
            jnp.int32), 0), safe, num_segments=v + 1)[:v]
        return jnp.maximum(state, red.astype(state.dtype))
    if op == "first":
        # first-writer-wins into empty (<0) slots, lowest message id wins
        n = idx.shape[0]
        rank = jnp.arange(n, dtype=jnp.int32)
        win = jax.ops.segment_min(jnp.where(valid, rank, n), safe,
                                  num_segments=v + 1)[:v]
        takes = (state < 0) & (win < n)
        return jnp.where(takes, val[jnp.clip(win, 0, n - 1)].astype(
            state.dtype), state)
    raise ValueError(op)


def _big(dt):
    return jnp.iinfo(dt).max if jnp.issubdtype(dt, jnp.integer) else jnp.inf


def _small(dt):
    return jnp.iinfo(dt).min if jnp.issubdtype(dt, jnp.integer) else -jnp.inf


def bucket_count_ref(owner, num_buckets: int):
    """Histogram: messages per bucket. owner -1 = masked."""
    valid = (owner >= 0) & (owner < num_buckets)
    safe = jnp.where(valid, owner, num_buckets)
    return jnp.bincount(safe, length=num_buckets + 1)[:num_buckets] \
        .astype(jnp.int32)


def ssd_chunk_ref(C, B, x, a):
    """SSD intra-chunk oracle (one chunk, one head).

    C, B: [L, N]; x: [L, P]; a: [L] log-decays.
    y[t] = sum_{s<=t} (C_t·B_s) exp(cumsum(a)_t - cumsum(a)_s) x_s."""
    cs = jnp.cumsum(a)
    L = a.shape[0]
    decay = jnp.exp(cs[:, None] - cs[None, :])
    tri = jnp.tril(jnp.ones((L, L), bool))
    G = (C @ B.T) * jnp.where(tri, decay, 0.0)
    return G @ x
