"""Public jit'd wrappers for the Pallas kernels.

``use_pallas`` in RunConfig routes the framework's hot-spots through these;
on CPU (this container) they run in interpret mode, on TPU compiled.
Every wrapper has a pure-jnp oracle in :mod:`repro.kernels.ref` and a
shape/dtype sweep in ``tests/test_kernels.py``.
"""
from __future__ import annotations

import jax

from repro.kernels.coalesce import bucket_count_pallas
from repro.kernels.coarse_commit import coarse_commit_pallas
from repro.kernels.ssd_chunk import ssd_chunk_pallas
from repro.kernels import ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def coarse_commit(state, idx, val, *, op="min", tile_m=256, block_v=512):
    return coarse_commit_pallas(state, idx, val, op=op, tile_m=tile_m,
                                block_v=block_v, interpret=not _on_tpu())


def bucket_count(owner, *, num_buckets, tile_m=512):
    return bucket_count_pallas(owner, num_buckets=num_buckets, tile_m=tile_m,
                               interpret=not _on_tpu())


def ssd_chunk(C, B, x, a):
    return ssd_chunk_pallas(C, B, x, a, interpret=not _on_tpu())


__all__ = ["coarse_commit", "bucket_count", "ssd_chunk", "ref"]
