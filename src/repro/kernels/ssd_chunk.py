"""Pallas TPU kernel: fused SSD intra-chunk block (Mamba2 hot-spot).

Computes the quadratic intra-chunk term of the state-space duality
y[t] = Σ_{s≤t} (C_t·B_s) · exp(Σ_{s<u≤t} a_u) · x_s for one (batch·chunk,
head) grid cell, fusing the C·Bᵀ matmul, the decay/causal mask, and the
·x contraction in VMEM — three MXU/VPU ops with no HBM round-trip for the
L×L Gram matrix (on HBM that matrix dominates traffic: L²·4B per head per
chunk).  The inter-chunk recurrence stays a lax.scan (tiny state).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(C_ref, B_ref, x_ref, a_ref, out_ref, *, L: int):
    C = C_ref[0].astype(jnp.float32)          # [L, N]
    B = B_ref[0].astype(jnp.float32)          # [L, N]
    x = x_ref[0].astype(jnp.float32)          # [L, P]
    a = a_ref[0].astype(jnp.float32)          # [1, L] (2-D for TPU layout)
    cs = jnp.cumsum(a[0])
    diff = cs[:, None] - cs[None, :]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    mask = s_idx <= t_idx
    G = jax.lax.dot(C, B.T, precision=jax.lax.Precision.HIGHEST)
    G = jnp.where(mask, G * jnp.exp(diff), 0.0)
    y = jax.lax.dot(G, x, precision=jax.lax.Precision.HIGHEST)
    out_ref[0] = y.astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(C, B, x, a, *, interpret: bool = True):
    """C, B: [G, L, N]; x: [G, L, P]; a: [G, L] log-decays.

    G = batch·chunks·heads flattened grid dim. Returns y [G, L, P]."""
    g, L, n = C.shape
    p = x.shape[-1]
    a2 = a[:, None, :]                        # [G, 1, L]
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, L=L),
        grid=(g,),
        in_specs=[
            pl.BlockSpec((1, L, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, L, p), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, 1, L), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, L, p), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, L, p), x.dtype),
        interpret=interpret,
    )(C, B, x, a2)
    return out
