"""Pallas TPU kernel: fused route+commit wave pass (ROADMAP item 3).

The unfused owner-side path of :func:`repro.core.engine.route_wave`
materializes the routed messages one more time after the exchange:
``local_idx = clip(rt - shard*block)`` and (for fused batch axes)
``fuse_keys(local_idx, lane, width)`` are full [P*C] jnp intermediates,
then a separate :func:`repro.kernels.coarse_commit.coarse_commit_pallas`
launch re-reads them.  The paper's HTM never pays that traffic — a
transaction reorders and commits inside its speculative read/write set —
and IARU/PIUMA (PAPERS.md) recover it on GPU/graph pipelines by fusing
the reorder with the update.

This kernel is the software analogue: ONE launch takes the post-exchange
bucket buffers ``rt``/``rp`` exactly as the all_to_all left them (global
target ids with ``-1`` empty-slot sentinels, optional per-message lane
ids) and, per grid step,

1. computes the local composite key in registers:
   ``key = (tgt - base) * width + lane`` — the ``local_idx``/
   ``fuse_keys`` arithmetic that was a jnp materialization;
2. reorders/coalesces the tile against the VMEM-resident state block via
   the M×B one-hot incidence (the in-VMEM analogue of sort-by-target:
   every message lands on its state column regardless of arrival order);
3. applies the commit op (``min``/``max``/``add``/``or``/``first`` with
   the pinned lowest-global-message-id ``first`` tiebreak, identical to
   the coarse kernel so cross-backend parity holds bit-for-bit);
4. (``stats=True``) reduces the in-transaction duplicate-target count —
   the abort-statistics analogue — into a per-(block, tile) output.

Grid/tiling/identity-padding follow :mod:`repro.kernels.coarse_commit`:
grid = (state_blocks, message_tiles), message tiles innermost so a state
block stays VMEM-resident while every transaction visits it; the (M × B)
working set is the HTM speculative-capacity analogue and M is the
paper's transaction-size knob (the adaptive ladder moves it per round).

``base`` is a traced scalar (the owner shard's first global vertex id,
``shard * block`` under ``shard_map``) carried as a (1,) int32 input so
the same compiled kernel serves every shard.  With ``base=None`` and
``width == 1`` the key computation folds away and the kernel degenerates
to the plain coarse-commit tile loop — that specialization is what
``CommitSpec(backend="fused")`` runs through the generic
:func:`repro.core.commit.commit` dispatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.coarse_commit import _RANK_INF, _identity


def _fused_kernel(*refs, op: str, tile_m: int, block_v: int, width: int,
                  nrows: int, with_lane: bool, with_base: bool,
                  stats: bool):
    it = iter(refs)
    idx_ref, val_ref, state_ref = next(it), next(it), next(it)
    lane_ref = next(it) if with_lane else None
    base_ref = next(it) if with_base else None
    out_ref = next(it)
    conf_ref = next(it) if stats else None

    b = pl.program_id(0)
    m = pl.program_id(1)

    @pl.when(m == 0)
    def _init():
        out_ref[...] = state_ref[...]

    idx = idx_ref[...]                                   # [M] global ids
    val = val_ref[...]                                   # [M]
    # --- in-kernel composite key: the fused local_idx/fuse_keys step ---
    rel = idx - (base_ref[0] if with_base else 0)
    ok = (idx >= 0) & (rel >= 0) & (rel < nrows)         # -1 = empty slot
    if with_lane:
        lane = lane_ref[...]
        ok = ok & (lane >= 0) & (lane < width)
        key = rel * width + jnp.where(ok, lane, 0)
    else:
        key = rel
    # --- in-VMEM reorder/coalesce: one-hot incidence vs this block ---
    kk = key - b * block_v
    mask = ok & (kk >= 0) & (kk < block_v)

    if op not in ("add", "min", "max", "or", "first"):
        raise ValueError(op)

    if conf_ref is not None:
        conf_ref[0, 0] = 0

    # Tile skip — the fusion dividend the unfused path cannot claim:
    # bucketed traffic is clustered (contention concentrates keys in few
    # state blocks), so most (block, tile) grid steps touch nothing and
    # the whole M×B incidence/commit is elided.  The separate-launch
    # pipeline can't do this: its commit kernel sees pre-flattened keys
    # with no cheap per-tile routing test left.
    @pl.when(jnp.any(mask))
    def _commit_tile():
        kkc = jnp.where(mask, kk, 0)
        col = jax.lax.broadcasted_iota(jnp.int32, (tile_m, block_v), 1)
        onehot = (col == kkc[:, None]) & mask[:, None]   # [M, B]

        if conf_ref is not None:
            cnt = jnp.sum(onehot.astype(jnp.int32), axis=0)  # [B]
            conf_ref[0, 0] = jnp.sum(jnp.where(cnt > 1, cnt, 0))

        if op == "add":
            if jnp.issubdtype(val.dtype, jnp.floating):
                contrib = jax.lax.dot(
                    val[None, :].astype(jnp.float32),
                    onehot.astype(jnp.float32),
                    precision=jax.lax.Precision.HIGHEST)[0]  # MXU path
            else:
                contrib = jnp.sum(jnp.where(onehot, val[:, None], 0),
                                  axis=0)
            out_ref[...] += contrib.astype(out_ref.dtype)
        elif op == "min":
            ident = _identity(op, val.dtype)
            cand = jnp.where(onehot, val[:, None], ident)
            out_ref[...] = jnp.minimum(out_ref[...],
                                       jnp.min(cand, axis=0))
        elif op == "max":
            ident = _identity(op, val.dtype)
            cand = jnp.where(onehot, val[:, None], ident)
            out_ref[...] = jnp.maximum(out_ref[...],
                                       jnp.max(cand, axis=0))
        elif op == "or":
            hit = jnp.any(onehot & (val[:, None] != 0), axis=0)
            out_ref[...] = jnp.maximum(out_ref[...],
                                       hit.astype(out_ref.dtype))
        elif op == "first":
            # first-writer-wins into empty (<0) slots; tie-break =
            # lowest GLOBAL message id (m * tile_m + row) — transactions
            # execute in grid order, so the in-tile winner composes to
            # the batch-wide lowest id, exactly like the coarse kernel.
            cur = out_ref[...]
            empty = cur < 0
            rank = (m * tile_m
                    + jax.lax.broadcasted_iota(jnp.int32,
                                               (tile_m, block_v), 0))
            rkey = jnp.where(onehot & empty[None, :], rank, _RANK_INF)
            win = jnp.min(rkey, axis=0)                  # [B]
            wsel = (onehot & (rkey == win[None, :])
                    & (win[None, :] < _RANK_INF))
            wval = jnp.sum(jnp.where(wsel, val[:, None], 0), axis=0)
            out_ref[...] = jnp.where(empty & (win < _RANK_INF),
                                     wval.astype(cur.dtype), cur)


@functools.partial(jax.jit, static_argnames=("op", "width", "tile_m",
                                             "block_v", "interpret",
                                             "stats"))
def fused_route_commit_pallas(state, tgt, val, *, lane=None, base=None,
                              width: int = 1, op: str = "min",
                              tile_m: int = 256, block_v: int = 512,
                              interpret: bool = True, stats: bool = False):
    """One launch from exchanged bucket buffers to committed state.

    state: [R * width] local composite-key slice (R vertex rows × width
    batch items, vertex-major — exactly the owner slice layout of
    :func:`repro.core.engine.route_wave`); tgt: [N] int32 GLOBAL vertex
    ids straight off the all_to_all (``-1`` = empty slot); val: [N]
    payloads; lane: [N] int32 per-message item ids (required iff
    ``width > 1``); base: traced scalar int32 — global id of local row 0
    (``None`` = 0, the single-shard case).

    Returns the committed state; ``stats=True`` returns
    ``(state, conflicts)`` with the grid-summed duplicate-target count.
    ``interpret=True`` executes on CPU; pass ``False`` on real TPU.
    """
    if (lane is None) == (width > 1):
        raise ValueError(f"lane ids are required iff width > 1 "
                         f"(width={width}, lane={'set' if lane is not None else 'None'})")
    v = state.shape[0]
    n = tgt.shape[0]
    if v % width:
        raise ValueError(f"state length {v} not divisible by width {width}")
    if n == 0 or v == 0:
        return (state, jnp.zeros((), jnp.int32)) if stats else state
    nrows = v // width
    vpad = (-v) % block_v
    npad = (-n) % tile_m
    ident = _identity(op, state.dtype)
    state_p = jnp.pad(state, (0, vpad),
                      constant_values=state.dtype.type(ident)
                      if op not in ("add", "or") else 0)
    tgt_p = jnp.pad(tgt.astype(jnp.int32), (0, npad), constant_values=-1)
    val_p = jnp.pad(val, (0, npad))
    nb = (v + vpad) // block_v
    nm = (n + npad) // tile_m

    tile_spec = pl.BlockSpec((tile_m,), lambda b, m: (m,))
    in_specs = [tile_spec, tile_spec,
                pl.BlockSpec((block_v,), lambda b, m: (b,))]
    inputs = [tgt_p, val_p, state_p]
    if lane is not None:
        in_specs.append(tile_spec)
        inputs.append(jnp.pad(lane.astype(jnp.int32), (0, npad)))
    if base is not None:
        in_specs.append(pl.BlockSpec((1,), lambda b, m: (0,)))
        inputs.append(jnp.reshape(jnp.asarray(base, jnp.int32), (1,)))
    out_specs = [pl.BlockSpec((block_v,), lambda b, m: (b,))]
    out_shape = [jax.ShapeDtypeStruct(state_p.shape, state.dtype)]
    if stats:
        out_specs.append(pl.BlockSpec((1, 1), lambda b, m: (b, m)))
        out_shape.append(jax.ShapeDtypeStruct((nb, nm), jnp.int32))
    res = pl.pallas_call(
        functools.partial(_fused_kernel, op=op, tile_m=tile_m,
                          block_v=block_v, width=width, nrows=nrows,
                          with_lane=lane is not None,
                          with_base=base is not None, stats=stats),
        grid=(nb, nm),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*inputs)
    if stats:
        out, conf = res
        return out[:v], jnp.sum(conf)
    return res[0][:v]
