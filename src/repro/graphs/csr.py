"""Graph containers: CSR + COO edge arrays, 1-D partitioning (paper §3.1).

Algorithms here are *edge-centric*: one vectorized pass over the edge arrays
generates the round's atomic active messages (src active -> message to dst).
This is the TPU-native layout — per-vertex ragged neighbor loops become
masked dense ops (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Graph:
    """CSR + COO. ``src``/``dst`` are edge-parallel arrays sorted by src."""
    indptr: jax.Array            # int32 [V+1]
    src: jax.Array               # int32 [E]
    dst: jax.Array               # int32 [E]
    weights: jax.Array           # float32 [E]
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    num_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def out_degree(self, v) -> jax.Array:
        return self.indptr[v + 1] - self.indptr[v]

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_vertices, 1)


def from_edges(src: np.ndarray, dst: np.ndarray, num_vertices: int,
               weights: np.ndarray | None = None, *,
               symmetrize: bool = False, dedupe: bool = True) -> Graph:
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if weights is None:
        weights = np.ones(src.shape, np.float32)
    keep = src != dst                       # drop self-loops
    src, dst, weights = src[keep], dst[keep], weights[keep]
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        weights = np.concatenate([weights, weights])
    if dedupe and len(src):
        key = src * num_vertices + dst
        _, idx = np.unique(key, return_index=True)
        src, dst, weights = src[idx], dst[idx], weights[idx]
    order = np.argsort(src, kind="stable")
    src, dst, weights = src[order], dst[order], weights[order]
    indptr = np.zeros(num_vertices + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(
        indptr=jnp.asarray(indptr, jnp.int32),
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        weights=jnp.asarray(weights, jnp.float32),
        num_vertices=int(num_vertices),
        num_edges=int(len(src)),
    )


# ---------------------------------------------------------------------------
# 1-D partitioning (paper §3.1: V split into contiguous owner ranges)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Partition:
    num_shards: int
    block: int          # vertices per shard (padded)

    def owner(self, v):
        return v // self.block

    def local(self, v):
        return v % self.block


def partition_edges(g: Graph, num_shards: int):
    """Split edges by OWNER OF THE SOURCE (each shard expands its own
    vertices), padded to equal length.  Returns numpy arrays shaped
    [num_shards, E_max]: (src, dst, w, valid, eid) + Partition.

    ``eid`` carries each lane's ORIGINAL edge index (``num_edges`` in
    padding lanes) so distributed algorithms can tie-break identically to
    their single-shard counterparts (Boruvka's lexicographic (weight, edge
    id) selection) and so per-edge shard state maps back to ``g``'s edge
    order."""
    v = g.num_vertices
    block = -(-v // num_shards)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.weights)
    owner = src // block
    counts = np.bincount(owner, minlength=num_shards)
    emax = max(int(counts.max()), 1)
    s_out = np.zeros((num_shards, emax), np.int32)
    d_out = np.zeros((num_shards, emax), np.int32)
    w_out = np.zeros((num_shards, emax), np.float32)
    valid = np.zeros((num_shards, emax), bool)
    eid = np.full((num_shards, emax), g.num_edges, np.int32)
    all_eids = np.arange(g.num_edges, dtype=np.int32)
    for p in range(num_shards):
        m = owner == p
        n = int(m.sum())
        s_out[p, :n] = src[m]
        d_out[p, :n] = dst[m]
        w_out[p, :n] = w[m]
        valid[p, :n] = True
        eid[p, :n] = all_eids[m]
    return (s_out, d_out, w_out, valid, eid), Partition(num_shards, block)
