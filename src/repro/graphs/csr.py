"""Graph containers: CSR + COO edge arrays, 1-D partitioning (paper §3.1).

Algorithms here are *edge-centric*: one vectorized pass over the edge arrays
generates the round's atomic active messages (src active -> message to dst).
This is the TPU-native layout — per-vertex ragged neighbor loops become
masked dense ops (DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Graph:
    """CSR + COO. ``src``/``dst`` are edge-parallel arrays sorted by src."""
    indptr: jax.Array            # int32 [V+1]
    src: jax.Array               # int32 [E]
    dst: jax.Array               # int32 [E]
    weights: jax.Array           # float32 [E]
    num_vertices: int = dataclasses.field(metadata=dict(static=True))
    num_edges: int = dataclasses.field(metadata=dict(static=True))

    @property
    def degrees(self) -> jax.Array:
        return self.indptr[1:] - self.indptr[:-1]

    def out_degree(self, v) -> jax.Array:
        return self.indptr[v + 1] - self.indptr[v]

    @property
    def avg_degree(self) -> float:
        return self.num_edges / max(self.num_vertices, 1)


def from_edges(src: np.ndarray, dst: np.ndarray, num_vertices: int,
               weights: np.ndarray | None = None, *,
               symmetrize: bool = False, dedupe: bool = True) -> Graph:
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    if weights is None:
        weights = np.ones(src.shape, np.float32)
    keep = src != dst                       # drop self-loops
    src, dst, weights = src[keep], dst[keep], weights[keep]
    if symmetrize:
        src, dst = np.concatenate([src, dst]), np.concatenate([dst, src])
        weights = np.concatenate([weights, weights])
    if dedupe and len(src):
        key = src * num_vertices + dst
        _, idx = np.unique(key, return_index=True)
        src, dst, weights = src[idx], dst[idx], weights[idx]
    order = np.argsort(src, kind="stable")
    src, dst, weights = src[order], dst[order], weights[order]
    indptr = np.zeros(num_vertices + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(
        indptr=jnp.asarray(indptr, jnp.int32),
        src=jnp.asarray(src, jnp.int32),
        dst=jnp.asarray(dst, jnp.int32),
        weights=jnp.asarray(weights, jnp.float32),
        num_vertices=int(num_vertices),
        num_edges=int(len(src)),
    )


# ---------------------------------------------------------------------------
# GraphSet: G tenant graphs stacked into one flat vertex/edge space
# ---------------------------------------------------------------------------


class GraphSet:
    """A batch of G independent graphs sharing one flat key space.

    The serving layer's *graph* batch axis (ISSUE 5): graph ``i``'s
    vertices occupy the contiguous range ``[vertex_offset(i),
    vertex_offset(i) + V_i)`` of the flat space, its edges the range
    ``[edge_offset(i), edge_offset(i) + E_i)`` of the stacked edge
    arrays.  :meth:`union` materialises the disjoint-union
    :class:`Graph` (per-graph CSR slices gathered from the stacked
    arrays) — running a wave algorithm over the union IS running it on
    every member at once, because components never exchange messages
    and the flat ranges never collide in the commit key space (the same
    disjointness argument as the query-lane composite keys,
    ``repro.core.coalescing``).

    The container is python-side/static: sizes and offsets are plain
    ints so they can live in jit static args via
    :class:`repro.core.coalescing.GraphBatch` (``self.axis``).
    """

    def __init__(self, graphs):
        self.graphs = tuple(graphs)
        if not self.graphs:
            raise ValueError("GraphSet needs at least one graph")
        self.vsizes = tuple(int(g.num_vertices) for g in self.graphs)
        self.esizes = tuple(int(g.num_edges) for g in self.graphs)
        self.voffs = np.concatenate(
            [[0], np.cumsum(self.vsizes)]).astype(np.int64)
        self.eoffs = np.concatenate(
            [[0], np.cumsum(self.esizes)]).astype(np.int64)
        self._union: Graph | None = None

    @property
    def num_graphs(self) -> int:
        return len(self.graphs)

    @property
    def num_vertices(self) -> int:
        return int(self.voffs[-1])

    @property
    def num_edges(self) -> int:
        return int(self.eoffs[-1])

    def vertex_offset(self, i: int) -> int:
        return int(self.voffs[i])

    @property
    def axis(self):
        """The :class:`repro.core.coalescing.GraphBatch` batch axis of
        this set (static, hashable)."""
        from repro.core.coalescing import GraphBatch
        return GraphBatch(sizes=self.vsizes)

    def union(self) -> Graph:
        """The disjoint-union graph (cached): stacked edge arrays with
        per-graph vertex offsets applied, concatenated CSR indptr."""
        if self._union is None:
            src = jnp.concatenate(
                [g.src + jnp.int32(self.voffs[i])
                 for i, g in enumerate(self.graphs)])
            dst = jnp.concatenate(
                [g.dst + jnp.int32(self.voffs[i])
                 for i, g in enumerate(self.graphs)])
            w = jnp.concatenate([g.weights for g in self.graphs])
            indptr = jnp.concatenate(
                [g.indptr[:-1] + jnp.int32(self.eoffs[i])
                 for i, g in enumerate(self.graphs)]
                + [jnp.asarray([self.num_edges], jnp.int32)])
            self._union = Graph(indptr=indptr, src=src, dst=dst, weights=w,
                                num_vertices=self.num_vertices,
                                num_edges=self.num_edges)
        return self._union

    def flat_vertices(self, per_graph) -> jax.Array:
        """Map per-graph vertex ids ``per_graph`` ([G] int) into the
        flat space: ``voffs[i] + per_graph[i]``."""
        ids = np.asarray(per_graph, np.int64)
        if ids.shape != (self.num_graphs,):
            raise ValueError(f"expected one vertex per graph "
                             f"({self.num_graphs}), got shape {ids.shape}")
        return jnp.asarray(self.voffs[:-1] + ids, jnp.int32)

    def split_vertex(self, flat) -> list:
        """Slice a flat [num_vertices] (or [num_vertices, ...]) array
        back into per-graph rows."""
        return [flat[self.voffs[i]:self.voffs[i + 1]]
                for i in range(self.num_graphs)]

    def split_edge(self, flat) -> list:
        return [flat[self.eoffs[i]:self.eoffs[i + 1]]
                for i in range(self.num_graphs)]

    def graph_of_vertex(self) -> jax.Array:
        """int32 [num_vertices] graph index per flat vertex id."""
        return jnp.asarray(np.repeat(np.arange(self.num_graphs),
                                     self.vsizes), jnp.int32)

    def graph_of_edge(self) -> jax.Array:
        """int32 [num_edges] graph index per stacked edge id."""
        return jnp.asarray(np.repeat(np.arange(self.num_graphs),
                                     self.esizes), jnp.int32)


# ---------------------------------------------------------------------------
# 1-D partitioning (paper §3.1: V split into contiguous owner ranges)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Partition:
    num_shards: int
    block: int          # vertices per shard (padded)

    def owner(self, v):
        return v // self.block

    def local(self, v):
        return v % self.block


def partition_edges(g: Graph, num_shards: int):
    """Split edges by OWNER OF THE SOURCE (each shard expands its own
    vertices), padded to equal length.  Returns numpy arrays shaped
    [num_shards, E_max]: (src, dst, w, valid, eid) + Partition.

    ``eid`` carries each lane's ORIGINAL edge index (``num_edges`` in
    padding lanes) so distributed algorithms can tie-break identically to
    their single-shard counterparts (Boruvka's lexicographic (weight, edge
    id) selection) and so per-edge shard state maps back to ``g``'s edge
    order."""
    v = g.num_vertices
    block = -(-v // num_shards)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.weights)
    owner = src // block
    counts = np.bincount(owner, minlength=num_shards)
    emax = max(int(counts.max()), 1)
    s_out = np.zeros((num_shards, emax), np.int32)
    d_out = np.zeros((num_shards, emax), np.int32)
    w_out = np.zeros((num_shards, emax), np.float32)
    valid = np.zeros((num_shards, emax), bool)
    eid = np.full((num_shards, emax), g.num_edges, np.int32)
    all_eids = np.arange(g.num_edges, dtype=np.int32)
    for p in range(num_shards):
        m = owner == p
        n = int(m.sum())
        s_out[p, :n] = src[m]
        d_out[p, :n] = dst[m]
        w_out[p, :n] = w[m]
        valid[p, :n] = True
        eid[p, :n] = all_eids[m]
    return (s_out, d_out, w_out, valid, eid), Partition(num_shards, block)
