"""PageRank — FF&AS atomic active messages (paper §3.3.1, Listing 3).

Every edge carries ``d * rank[src] / out_deg[src]`` to its destination; the
commit is an Always-Succeed accumulate.  On TPU the AS commit is a conflict-
free segment-sum — the paper's HTM abort storm for ACC (§5.4.2) disappears
by construction (DESIGN.md §2).  ``pagerank_baseline`` is the PBGL-like
per-edge scatter path used as the Fig-7 comparison.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.messages import make_messages
from repro.graphs.csr import Graph


@partial(jax.jit, static_argnames=("iters", "commit", "m", "sort", "spec"))
def pagerank(g: Graph, *, d: float = 0.85, iters: int = 20,
             commit: str = "coarse", m: int | None = None, sort: bool = True,
             spec: C.CommitSpec | None = None):
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    dangling = g.degrees == 0
    acc0 = jnp.zeros((v,), jnp.float32)
    step, lvl0 = AT.make_commit_step(spec, "add", acc0, n=g.src.shape[0])

    def body(carry, _):
        rank, conflicts, lvl = carry
        contrib = d * rank[g.src] / deg[g.src]
        msgs = make_messages(g.dst, contrib, jnp.ones_like(g.src, bool))
        res, lvl = step(acc0, msgs, lvl)
        dangle = d * jnp.sum(jnp.where(dangling, rank, 0.0)) / v
        rank = (1.0 - d) / v + res.state + dangle
        return (rank, conflicts + res.conflicts, lvl), None

    rank0 = jnp.full((v,), 1.0 / v, jnp.float32)
    (rank, conflicts, _), _ = jax.lax.scan(
        body, (rank0, jnp.zeros((), jnp.int32), lvl0), None, length=iters)
    return rank, conflicts


def distributed_pagerank(mesh, g: Graph, *, iters: int = 20,
                         capacity: int = 4096, m: int | None = None,
                         axis: str = "data", d: float = 0.85,
                         spec: C.CommitSpec | None = None,
                         max_subrounds: int = 64, telemetry: bool = False):
    """PageRank over a mesh axis — FF&AS accumulate waves on the shared
    harness.  Returns rank [V]; ``telemetry=True`` returns
    (rank, DistributedResult)."""
    from repro.core.engine import AlgorithmSpec, run_distributed
    v = g.num_vertices

    def init(g, layout):
        vpad = layout.vpad
        realv = jnp.zeros((vpad,), bool).at[:v].set(True)
        state = {
            "rank": jnp.where(realv, 1.0 / v, 0.0).astype(jnp.float32),
            "deg": jnp.zeros((vpad,), jnp.int32).at[:v].set(
                jnp.maximum(g.degrees, 1)),
            "dangling": jnp.zeros((vpad,), bool).at[:v].set(g.degrees == 0),
            "real": realv,
        }
        return state, {}

    def round_fn(rt, e, st, sc, it):
        rank = st["rank"]
        contrib = (d * rank[e.my_src]
                   / st["deg"][e.my_src].astype(jnp.float32))
        acc0 = jnp.zeros(rank.shape, jnp.float32)
        acc, _ = rt.wave(acc0, e.dst, contrib, e.valid, op="add")
        dm = rt.psum(jnp.sum(jnp.where(st["dangling"], rank, 0.0)))
        rank = jnp.where(st["real"], (1.0 - d) / v + acc + d * dm / v, 0.0)
        return dict(st, rank=rank), sc, jnp.ones((), bool)

    alg = AlgorithmSpec("pagerank", "FF&AS", init, round_fn,
                        lambda g, layout: iters)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    rank = res.state["rank"][:v]
    return (rank, res) if telemetry else rank


def pagerank_reference(g: Graph, d=0.85, iters=20):
    """NumPy oracle."""
    import numpy as np
    v = g.num_vertices
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    indptr = np.asarray(g.indptr)
    deg = np.maximum(indptr[1:] - indptr[:-1], 1)
    dangling = (indptr[1:] - indptr[:-1]) == 0
    rank = np.full(v, 1.0 / v)
    for _ in range(iters):
        acc = np.zeros(v)
        np.add.at(acc, dst, d * rank[src] / deg[src])
        acc += d * rank[dangling].sum() / v
        rank = (1 - d) / v + acc
    return rank
