"""PageRank — FF&AS atomic active messages (paper §3.3.1, Listing 3).

Every edge carries ``d * rank[src] / out_deg[src]`` to its destination; the
commit is an Always-Succeed accumulate.  On TPU the AS commit is a conflict-
free segment-sum — the paper's HTM abort storm for ACC (§5.4.2) disappears
by construction (DESIGN.md §2).  ``pagerank_baseline`` is the PBGL-like
per-edge scatter path used as the Fig-7 comparison.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.messages import lane_messages, make_messages
from repro.graphs.csr import Graph


@partial(jax.jit, static_argnames=("iters", "commit", "m", "sort", "spec"))
def pagerank(g: Graph, *, d: float = 0.85, iters: int = 20,
             commit: str = "coarse", m: int | None = None, sort: bool = True,
             spec: C.CommitSpec | None = None):
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    dangling = g.degrees == 0
    acc0 = jnp.zeros((v,), jnp.float32)
    step, lvl0 = AT.make_commit_step(spec, "add", acc0, n=g.src.shape[0])

    def body(carry, _):
        rank, conflicts, lvl = carry
        contrib = d * rank[g.src] / deg[g.src]
        msgs = make_messages(g.dst, contrib, jnp.ones_like(g.src, bool))
        res, lvl = step(acc0, msgs, lvl)
        dangle = d * jnp.sum(jnp.where(dangling, rank, 0.0)) / v
        rank = (1.0 - d) / v + res.state + dangle
        return (rank, conflicts + res.conflicts, lvl), None

    rank0 = jnp.full((v,), 1.0 / v, jnp.float32)
    (rank, conflicts, _), _ = jax.lax.scan(
        body, (rank0, jnp.zeros((), jnp.int32), lvl0), None, length=iters)
    return rank, conflicts


@partial(jax.jit, static_argnames=("iters", "commit", "m", "sort", "spec"))
def personalized_pagerank(g: Graph, source, *, d: float = 0.85,
                          iters: int = 20, commit: str = "coarse",
                          m: int | None = None, sort: bool = True,
                          spec: C.CommitSpec | None = None):
    """Personalized PageRank: the restart distribution is concentrated at
    ``source`` (random surfer teleports home) — the single-query form the
    serving layer lane-batches.  Dangling mass also returns to the source,
    so per-lane mass is conserved at 1."""
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    dangling = g.degrees == 0
    restart = jnp.zeros((v,), jnp.float32).at[source].set(1.0)
    acc0 = jnp.zeros((v,), jnp.float32)
    step, lvl0 = AT.make_commit_step(spec, "add", acc0, n=g.src.shape[0])

    def body(carry, _):
        rank, conflicts, lvl = carry
        contrib = d * rank[g.src] / deg[g.src]
        msgs = make_messages(g.dst, contrib, jnp.ones_like(g.src, bool))
        res, lvl = step(acc0, msgs, lvl)
        dangle = d * jnp.sum(jnp.where(dangling, rank, 0.0))
        rank = restart * ((1.0 - d) + dangle) + res.state
        return (rank, conflicts + res.conflicts, lvl), None

    (rank, conflicts, _), _ = jax.lax.scan(
        body, (restart, jnp.zeros((), jnp.int32), lvl0), None, length=iters)
    return rank, conflicts


@partial(jax.jit, static_argnames=("iters", "commit", "m", "sort", "spec"))
def multi_source_pagerank(g: Graph, sources, *, d: float = 0.85,
                          iters: int = 20, commit: str = "coarse",
                          m: int | None = None, sort: bool = True,
                          spec: C.CommitSpec | None = None):
    """L personalized-PageRank queries as lanes of one fused wave.

    Returns (rank [L, V], conflicts).  Row l matches
    ``personalized_pagerank(g, sources[l])`` to float-add rounding (the
    composite-key commit reorders each lane's accumulate exactly like any
    transaction-size change does)."""
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    sources = jnp.asarray(sources, jnp.int32)
    lanes = sources.shape[0]
    lidx = jnp.arange(lanes, dtype=jnp.int32)
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    dangling = g.degrees == 0
    restart = jnp.zeros((lanes, v), jnp.float32) \
        .at[lidx, sources].set(1.0)
    e = g.src.shape[0]
    dst_l = jnp.broadcast_to(g.dst, (lanes, e))
    valid_l = jnp.ones((lanes, e), bool)
    acc0 = jnp.zeros((lanes * v,), jnp.float32)
    step, lvl0 = AT.make_commit_step(spec, "add", acc0, n=lanes * e,
                                     axis_width=lanes)

    def body(carry, _):
        rank, conflicts, lvl = carry
        contrib = d * rank[:, g.src] / deg[g.src][None, :]
        msgs = lane_messages(dst_l, contrib, valid_l, v)
        res, lvl = step(acc0, msgs, lvl)
        dangle = d * jnp.sum(jnp.where(dangling[None, :], rank, 0.0),
                             axis=1)                      # [L]
        rank = restart * ((1.0 - d) + dangle[:, None]) \
            + res.state.reshape(lanes, v)
        return (rank, conflicts + res.conflicts, lvl), None

    (rank, conflicts, _), _ = jax.lax.scan(
        body, (restart, jnp.zeros((), jnp.int32), lvl0), None, length=iters)
    return rank, conflicts


@partial(jax.jit, static_argnames=("iters", "spec", "num_graphs",
                                   "axis_width"))
def _union_ppr(g: Graph, sources_flat, gov, d, *, iters: int,
               spec: C.CommitSpec | None, num_graphs: int,
               axis_width: int):
    """Personalized PageRank over a disjoint-union graph with PER-GRAPH
    dangling mass (segment sums by ``gov``, the graph-of-vertex map)."""
    v = g.num_vertices
    deg = jnp.maximum(g.degrees, 1).astype(jnp.float32)
    dangling = g.degrees == 0
    restart = jnp.zeros((v,), jnp.float32).at[sources_flat].set(1.0)
    acc0 = jnp.zeros((v,), jnp.float32)
    step, lvl0 = AT.make_commit_step(spec, "add", acc0, n=g.src.shape[0],
                                     axis_width=axis_width)

    def body(carry, _):
        rank, lvl = carry
        contrib = d * rank[g.src] / deg[g.src]
        msgs = make_messages(g.dst, contrib, jnp.ones_like(g.src, bool))
        res, lvl = step(acc0, msgs, lvl)
        dm = jax.ops.segment_sum(jnp.where(dangling, rank, 0.0), gov,
                                 num_segments=num_graphs)       # [G]
        rank = restart * ((1.0 - d) + d * dm[gov]) + res.state
        return (rank, lvl), None

    (rank, _), _ = jax.lax.scan(body, (restart, lvl0), None, length=iters)
    return rank


def batched_over_graphs_pagerank(gs, sources, *, d: float = 0.85,
                                 iters: int = 20,
                                 spec: C.CommitSpec | None = None,
                                 mesh=None, capacity: int | str = 4096,
                                 axis: str = "data",
                                 max_subrounds: int = 64):
    """G personalized-PageRank queries, one per tenant graph, fused on
    the graph batch axis (disjoint-union flat keys).  ``sources[g]`` is
    graph g's LOCAL restart vertex; all queries share the trace-time
    (iters, d) knobs — the admission fuse key.  Returns per-graph rank
    rows matching ``personalized_pagerank(gs.graphs[g], sources[g])`` to
    float-add rounding (the fused commit reorders each graph's
    accumulate exactly like any transaction-size change; per-graph
    dangling mass is a segment sum over the union)."""
    if spec is None:
        spec = C.CommitSpec(backend="coarse", stats=False)
    flat = gs.flat_vertices(sources)
    gov = gs.graph_of_vertex()
    if mesh is not None:
        rank = _distributed_union_ppr(
            mesh, gs, flat, d=d, iters=iters, spec=spec,
            capacity=capacity, axis=axis, max_subrounds=max_subrounds)
    else:
        rank = _union_ppr(gs.union(), flat, gov, d, iters=iters, spec=spec,
                          num_graphs=gs.num_graphs,
                          axis_width=gs.num_graphs)
    return gs.split_vertex(rank)


def _distributed_union_ppr(mesh, gs, sources_flat, *, d, iters, spec,
                           capacity, axis, max_subrounds):
    """Graph-batched personalized PageRank on the shared harness: FF&AS
    accumulate waves over the union's flat owner slices, per-graph
    dangling mass psum'd as a [G] vector."""
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)
    g = gs.union()
    v = g.num_vertices
    num_graphs = gs.num_graphs
    gov_np = gs.graph_of_vertex()

    def init(g, layout):
        vpad = layout.vpad
        restart = jnp.zeros((vpad,), jnp.float32).at[sources_flat].set(1.0)
        gov = jnp.full((vpad,), num_graphs - 1, jnp.int32) \
            .at[:v].set(gov_np)
        state = {
            "rank": restart,
            "restart": restart,
            "deg": jnp.zeros((vpad,), jnp.int32).at[:v].set(
                jnp.maximum(g.degrees, 1)),
            "dangling": jnp.zeros((vpad,), bool).at[:v].set(g.degrees == 0),
            "real": jnp.zeros((vpad,), bool).at[:v].set(True),
            "gov": gov,
        }
        return state, {}

    def round_fn(rt, e, st, sc, it):
        rank = st["rank"]
        contrib = (d * rank[e.my_src]
                   / st["deg"][e.my_src].astype(jnp.float32))
        acc0 = jnp.zeros(rank.shape, jnp.float32)
        acc, _ = rt.wave(acc0, e.dst, contrib, e.valid, op="add")
        dm = rt.psum(jax.ops.segment_sum(
            jnp.where(st["dangling"], rank, 0.0), st["gov"],
            num_segments=num_graphs))                           # [G]
        rank = jnp.where(st["real"],
                         st["restart"] * ((1.0 - d) + d * dm[st["gov"]])
                         + acc, 0.0)
        return dict(st, rank=rank), sc, jnp.ones((), bool)

    alg = AlgorithmSpec("graphs_ppr", "FF&AS", init, round_fn,
                        lambda g, layout: iters)
    res = run_distributed(alg, mesh, gs, capacity=capacity, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    return res.state["rank"][:v]


def distributed_pagerank(mesh, g: Graph, *, iters: int = 20,
                         capacity: int | str = 4096, m: int | None = None,
                         axis: str = "data", d: float = 0.85,
                         spec: C.CommitSpec | None = None,
                         max_subrounds: int = 64, telemetry: bool = False):
    """PageRank over a mesh axis — FF&AS accumulate waves on the shared
    harness.  Returns rank [V]; ``telemetry=True`` returns
    (rank, DistributedResult)."""
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)
    v = g.num_vertices

    def init(g, layout):
        vpad = layout.vpad
        realv = jnp.zeros((vpad,), bool).at[:v].set(True)
        state = {
            "rank": jnp.where(realv, 1.0 / v, 0.0).astype(jnp.float32),
            "deg": jnp.zeros((vpad,), jnp.int32).at[:v].set(
                jnp.maximum(g.degrees, 1)),
            "dangling": jnp.zeros((vpad,), bool).at[:v].set(g.degrees == 0),
            "real": realv,
        }
        return state, {}

    def round_fn(rt, e, st, sc, it):
        rank = st["rank"]
        contrib = (d * rank[e.my_src]
                   / st["deg"][e.my_src].astype(jnp.float32))
        acc0 = jnp.zeros(rank.shape, jnp.float32)
        acc, _ = rt.wave(acc0, e.dst, contrib, e.valid, op="add")
        dm = rt.psum(jnp.sum(jnp.where(st["dangling"], rank, 0.0)))
        rank = jnp.where(st["real"], (1.0 - d) / v + acc + d * dm / v, 0.0)
        return dict(st, rank=rank), sc, jnp.ones((), bool)

    alg = AlgorithmSpec("pagerank", "FF&AS", init, round_fn,
                        lambda g, layout: iters)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    rank = res.state["rank"][:v]
    return telemetry_return(rank, res, telemetry)


def distributed_multi_source_pagerank(mesh, g: Graph, sources, *,
                                      iters: int = 20,
                                      capacity: int | str = 4096,
                                      m: int | None = None,
                                      axis: str = "data", d: float = 0.85,
                                      spec: C.CommitSpec | None = None,
                                      max_subrounds: int = 64,
                                      telemetry: bool = False):
    """Lane-batched personalized PageRank over a mesh axis — FF&AS
    accumulate waves on vertex-major [vpad * L] state, per-lane dangling
    mass psum'd as an [L] vector.  Returns rank [L, V];
    ``telemetry=True`` returns (rank, DistributedResult)."""
    from repro.core.coalescing import QueryLanes
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)
    v = g.num_vertices

    sources = jnp.asarray(sources, jnp.int32)
    lanes = sources.shape[0]
    lidx = jnp.arange(lanes, dtype=jnp.int32)

    def init(g, layout):
        vpad = layout.vpad
        restart = jnp.zeros((vpad * lanes,), jnp.float32) \
            .at[sources * lanes + lidx].set(1.0)
        state = {
            "rank": restart,
            "restart": restart,
            "deg": jnp.zeros((vpad,), jnp.int32).at[:v].set(
                jnp.maximum(g.degrees, 1)),
            "dangling": jnp.zeros((vpad,), bool).at[:v].set(g.degrees == 0),
        }
        return state, {}

    def round_fn(rt, e, st, sc, it):
        rank = st["rank"]                      # [block * L]
        emax = e.dst.shape[0]
        fl = e.my_src[:, None] * lanes + lidx[None, :]
        contrib = d * rank[fl] / st["deg"][e.my_src] \
            .astype(jnp.float32)[:, None]
        tgt = jnp.broadcast_to(e.dst[:, None], (emax, lanes))
        lane = jnp.broadcast_to(lidx[None, :], (emax, lanes))
        valid = jnp.broadcast_to(e.valid[:, None], (emax, lanes))
        acc0 = jnp.zeros(rank.shape, jnp.float32)
        acc, _ = rt.wave(acc0, tgt.reshape(-1), contrib.reshape(-1),
                         valid.reshape(-1), op="add",
                         major=lane.reshape(-1))
        rk = rank.reshape(-1, lanes)
        dm = rt.psum(jnp.sum(
            jnp.where(st["dangling"][:, None], rk, 0.0), axis=0))   # [L]
        rank2 = st["restart"].reshape(-1, lanes) \
            * ((1.0 - d) + d * dm[None, :]) + acc.reshape(-1, lanes)
        return dict(st, rank=rank2.reshape(-1)), sc, jnp.ones((), bool)

    alg = AlgorithmSpec("multi_ppr", "FF&AS", init, round_fn,
                        lambda g, layout: iters)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds,
                          batch=QueryLanes(lanes, v))
    rank = res.state["rank"].reshape(-1, lanes).T[:, :v]
    return telemetry_return(rank, res, telemetry)


def pagerank_reference(g: Graph, d=0.85, iters=20):
    """NumPy oracle."""
    import numpy as np
    v = g.num_vertices
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    indptr = np.asarray(g.indptr)
    deg = np.maximum(indptr[1:] - indptr[:-1], 1)
    dangling = (indptr[1:] - indptr[:-1]) == 0
    rank = np.full(v, 1.0 / v)
    for _ in range(iters):
        acc = np.zeros(v)
        np.add.at(acc, dst, d * rank[src] / deg[src])
        acc += d * rank[dangling].sum() / v
        rank = (1 - d) / v + acc
    return rank
