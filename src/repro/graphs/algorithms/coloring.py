"""Boman graph coloring — FR&MF messages (paper §3.3.5, Listing 7).

Rounds: every active vertex proposes a color; conflicts (edge endpoints with
equal color) are resolved by a seeded coin flip choosing which endpoint
recolors — the paper's "return the ID of a vertex to be recolored" failure
handler, expressed as the FR path.  Terminates when no edge conflicts
remain; validity is property-tested.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import commit as C
from repro.core.messages import make_messages
from repro.graphs.csr import Graph


def _hash32(x):
    x = (x ^ (x >> 16)) * jnp.uint32(0x7feb352d)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846ca68b)
    return x ^ (x >> 16)


@partial(jax.jit, static_argnames=("max_rounds", "spec"))
def coloring(g: Graph, *, palette: int | None = None, seed: int = 0,
             max_rounds: int = 500, spec: C.CommitSpec | None = None):
    if spec is None:
        # sort=False: the 0/1 recolor mask needs no in-batch resolution —
        # a plain scatter-max (atomic tier) matches the pre-commit() cost
        spec = C.CommitSpec(backend="coarse", sort=False, stats=False)
    v = g.num_vertices
    max_deg = jnp.max(g.degrees)
    # Brooks-style palette bound Δ+1 (jnp scalar OK inside where/mod)
    pal = max_deg + 1

    def propose(active, color, rnd):
        mix = (jnp.asarray(seed, jnp.uint32)
               + rnd.astype(jnp.uint32) * jnp.uint32(2654435761))
        h = _hash32(jnp.arange(v, dtype=jnp.uint32) ^ _hash32(mix))
        prop = (h % pal.astype(jnp.uint32)).astype(jnp.int32)
        return jnp.where(active, prop, color)

    def cond(state):
        _, active, it = state
        return jnp.any(active) & (it < max_rounds)

    def body(state):
        color, active, it = state
        color = propose(active, color, it)
        cs, cd = color[g.src], color[g.dst]
        conflict = cs == cd                       # per-edge conflict
        # seeded coin flip per conflicting edge: loser recolors (FR return)
        eid = jnp.arange(g.num_edges, dtype=jnp.uint32)
        coin = (_hash32(eid ^ jnp.asarray(seed * 31 + 7, jnp.uint32) ^
                        _hash32(jnp.asarray(it).astype(jnp.uint32))) & 1) == 0
        loser = jnp.where(coin, g.src, g.dst)
        # the recolor notification is an FF&AS "or" commit into the
        # next-round active mask (losers may be named by many edges)
        msgs = make_messages(loser, conflict.astype(jnp.int32),
                             jnp.ones((g.num_edges,), bool))
        new_active = C.commit(jnp.zeros((v,), jnp.int32), msgs, "or",
                              spec).state != 0
        return color, new_active, it + 1

    color0 = jnp.zeros((v,), jnp.int32)
    active0 = jnp.ones((v,), bool)
    color, active, rounds = jax.lax.while_loop(
        cond, body, (color0, active0, jnp.zeros((), jnp.int32)))
    return color, rounds, jnp.any(active)   # any=True -> didn't converge


def validate_coloring(g: Graph, color) -> bool:
    import numpy as np
    c = np.asarray(color)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    return bool((c[src] != c[dst]).all())
