"""Boman graph coloring — FR&MF messages (paper §3.3.5, Listing 7).

Rounds: every active vertex proposes a color; conflicts (edge endpoints with
equal color) are resolved by a seeded coin flip choosing which endpoint
recolors — the paper's "return the ID of a vertex to be recolored" failure
handler, expressed as the FR path.  Terminates when no edge conflicts
remain; validity is property-tested.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.messages import make_messages
from repro.graphs.csr import Graph


def _hash32(x):
    x = (x ^ (x >> 16)) * jnp.uint32(0x7feb352d)
    x = (x ^ (x >> 15)) * jnp.uint32(0x846ca68b)
    return x ^ (x >> 16)


def _pair_loser(src, dst, seed, rnd):
    """Seeded coin flip per conflicting edge: which endpoint recolors (the
    paper's FR "return the ID of a vertex to be recolored").  Hashed on the
    CANONICAL (lo, hi) pair so both stored directions of an undirected edge
    — and every shard of a distributed run — agree on the loser."""
    lo = jnp.minimum(src, dst).astype(jnp.uint32)
    hi = jnp.maximum(src, dst).astype(jnp.uint32)
    mix = (jnp.asarray(seed * 31 + 7, jnp.uint32)
           ^ _hash32(jnp.asarray(rnd).astype(jnp.uint32)))
    coin = (_hash32(lo ^ _hash32(hi ^ mix)) & 1) == 0
    return jnp.where(coin, lo, hi).astype(jnp.int32)


def _propose(ids, active, color, pal, seed, rnd):
    """Seeded per-round color proposal for the ``active`` vertices — pure
    function of the GLOBAL vertex id, so every shard proposes exactly what
    the single-shard run would."""
    mix = (jnp.asarray(seed, jnp.uint32)
           + jnp.asarray(rnd).astype(jnp.uint32) * jnp.uint32(2654435761))
    h = _hash32(ids.astype(jnp.uint32) ^ _hash32(mix))
    prop = (h % jnp.asarray(pal, jnp.uint32)).astype(jnp.int32)
    return jnp.where(active, prop, color)


@partial(jax.jit, static_argnames=("max_rounds", "spec"))
def coloring(g: Graph, *, palette: int | None = None, seed: int = 0,
             max_rounds: int = 500, spec: C.CommitSpec | None = None):
    if spec is None:
        # sort=False: the 0/1 recolor mask needs no in-batch resolution —
        # a plain scatter-max (atomic tier) matches the pre-commit() cost
        spec = C.CommitSpec(backend="coarse", sort=False, stats=False)
    v = g.num_vertices
    max_deg = jnp.max(g.degrees)
    # Brooks-style palette bound Δ+1 (jnp scalar OK inside where/mod)
    pal = max_deg + 1

    def propose(active, color, rnd):
        return _propose(jnp.arange(v, dtype=jnp.uint32), active, color, pal,
                        seed, rnd)

    zeros = jnp.zeros((v,), jnp.int32)
    step, lvl0 = AT.make_commit_step(spec, "or", zeros, n=g.num_edges)

    def cond(state):
        _, active, it, _ = state
        return jnp.any(active) & (it < max_rounds)

    def body(state):
        color, active, it, lvl = state
        color = propose(active, color, it)
        cs, cd = color[g.src], color[g.dst]
        conflict = cs == cd                       # per-edge conflict
        loser = _pair_loser(g.src, g.dst, seed, it)
        # the recolor notification is an FF&AS "or" commit into the
        # next-round active mask (losers may be named by many edges)
        msgs = make_messages(loser, jnp.ones((g.num_edges,), jnp.int32),
                             conflict)
        res, lvl = step(zeros, msgs, lvl)
        return color, res.state != 0, it + 1, lvl

    color0 = jnp.zeros((v,), jnp.int32)
    active0 = jnp.ones((v,), bool)
    color, active, rounds, _ = jax.lax.while_loop(
        cond, body, (color0, active0, jnp.zeros((), jnp.int32), lvl0))
    return color, rounds, jnp.any(active)   # any=True -> didn't converge


@partial(jax.jit, static_argnames=("max_rounds", "spec", "num_graphs",
                                   "axis_width"))
def _union_coloring(g: Graph, gov, lid, voffs_e, lsrc, ldst, pal, seed, *,
                    max_rounds: int, spec: C.CommitSpec | None,
                    num_graphs: int, axis_width: int):
    """Boman coloring over a disjoint-union graph, bit-identical per
    member: proposals hash LOCAL vertex ids against the member's own
    palette and the coin flips hash LOCAL canonical pairs — exactly what
    each single-graph run computes — while the recolor notifications of
    ALL graphs share one ``or`` commit on flat keys."""
    v = g.num_vertices
    zeros = jnp.zeros((v,), jnp.int32)
    step, lvl0 = AT.make_commit_step(spec, "or", zeros, n=g.num_edges,
                                     axis_width=axis_width)

    def cond(state):
        _, active, it, _ = state
        return jnp.any(active) & (it < max_rounds)

    def body(state):
        color, active, it, lvl = state
        color = _propose(lid, active, color, pal[gov], seed, it)
        cs, cd = color[g.src], color[g.dst]
        conflict = cs == cd
        loser = _pair_loser(lsrc, ldst, seed, it) + voffs_e
        msgs = make_messages(loser, jnp.ones((g.num_edges,), jnp.int32),
                             conflict)
        res, lvl = step(zeros, msgs, lvl)
        return color, res.state != 0, it + 1, lvl

    color, active, rounds, _ = jax.lax.while_loop(
        cond, body, (jnp.zeros((v,), jnp.int32), jnp.ones((v,), bool),
                     jnp.zeros((), jnp.int32), lvl0))
    not_conv = jax.ops.segment_sum(active.astype(jnp.int32), gov,
                                   num_segments=num_graphs) > 0
    return color, rounds, not_conv


def _graphset_locals(gs):
    """Static local-id views of a GraphSet: (gov [V], lid [V] uint32,
    per-edge graph voffset [E], local src/dst [E], pal [G])."""
    import numpy as np
    gov = gs.graph_of_vertex()
    lid = (jnp.arange(gs.num_vertices, dtype=jnp.int32)
           - jnp.asarray(gs.voffs[:-1], jnp.int32)[gov]).astype(jnp.uint32)
    egov = gs.graph_of_edge()
    voffs_e = jnp.asarray(gs.voffs[:-1], jnp.int32)[egov]
    u = gs.union()
    lsrc = u.src - voffs_e
    ldst = u.dst - voffs_e
    pal = jnp.asarray([int(np.asarray(jnp.max(g.degrees))) + 1
                       for g in gs.graphs], jnp.uint32)
    return gov, lid, voffs_e, lsrc, ldst, pal


def batched_over_graphs_coloring(gs, *, seed: int = 0,
                                 max_rounds: int = 500,
                                 spec: C.CommitSpec | None = None,
                                 mesh=None, capacity: int | str = 4096,
                                 axis: str = "data",
                                 max_subrounds: int = 64):
    """G independent colorings, one per tenant graph, as ONE fused wave
    sequence — the graph batch axis that makes coloring *servable*: its
    FR&MF rounds share no query-lane structure (a second query on the
    same graph would collide on every vertex), but independent graphs
    trivially share each ``or`` wave on disjoint flat key ranges.

    Returns ``(colors, rounds, not_converged)``: per-graph color rows
    (each bit-identical to ``coloring(gs.graphs[g], seed=seed)`` on
    every backend), the fused round count (= max over members), and a
    [G] bool vector.  ``mesh=`` runs on the distributed harness."""
    if spec is None:
        spec = C.CommitSpec(backend="coarse", sort=False, stats=False)
    gov, lid, voffs_e, lsrc, ldst, pal = _graphset_locals(gs)
    if mesh is not None:
        color, rounds, not_conv = _distributed_union_coloring(
            mesh, gs, pal, seed=seed, max_rounds=max_rounds, spec=spec,
            capacity=capacity, axis=axis, max_subrounds=max_subrounds)
    else:
        color, rounds, not_conv = _union_coloring(
            gs.union(), gov, lid, voffs_e, lsrc, ldst, pal, seed,
            max_rounds=max_rounds, spec=spec, num_graphs=gs.num_graphs,
            axis_width=gs.num_graphs)
    return gs.split_vertex(color), rounds, not_conv


def _distributed_union_coloring(mesh, gs, pal, *, seed, max_rounds, spec,
                                capacity, axis, max_subrounds):
    """Graph-batched coloring on the shared harness: the same local-id
    proposals/coins as :func:`_union_coloring`, with remote endpoint
    colors read through the FR gather path."""
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)
    g = gs.union()
    v = g.num_vertices
    num_graphs = gs.num_graphs
    gov_np = gs.graph_of_vertex()
    voffs = jnp.asarray(gs.voffs, jnp.int32)

    def init(g, layout):
        vpad = layout.vpad
        gov = jnp.full((vpad,), num_graphs - 1, jnp.int32).at[:v].set(gov_np)
        return {"color": jnp.zeros((vpad,), jnp.int32),
                "active": jnp.zeros((vpad,), bool).at[:v].set(True),
                "gov": gov}, {}

    def round_fn(rt, e, st, sc, it):
        gov = st["gov"]
        lid = (rt.gid - voffs[gov]).astype(jnp.uint32)
        color = _propose(lid, st["active"], st["color"], pal[gov], seed, it)
        cs = color[e.my_src]
        cd = rt.gather(color, e.dst, e.valid, fill=-1)
        conflict = e.valid & (cs == cd)
        egov = jnp.clip(
            jnp.searchsorted(voffs[1:], e.src, side="right"), 0,
            num_graphs - 1).astype(jnp.int32)
        loser = _pair_loser(e.src - voffs[egov], e.dst - voffs[egov],
                            seed, it) + voffs[egov]
        act, _ = rt.wave(jnp.zeros(color.shape, jnp.int32), loser,
                         jnp.ones_like(e.src), conflict, op="or")
        new_active = act != 0
        return (dict(st, color=color, active=new_active), sc,
                rt.any(new_active))

    alg = AlgorithmSpec("graphs_coloring", "FR&MF", init, round_fn,
                        lambda g, layout: max_rounds)
    res = run_distributed(alg, mesh, gs, capacity=capacity, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    color = res.state["color"][:v]
    act = res.state["active"][:v]
    not_conv = jax.ops.segment_sum(act.astype(jnp.int32), gov_np,
                                   num_segments=num_graphs) > 0
    return color, res.rounds, not_conv


def distributed_coloring(mesh, g: Graph, *, seed: int = 0,
                         max_rounds: int = 500, capacity: int = 4096,
                         m: int | None = None, axis: str = "data",
                         spec: C.CommitSpec | None = None,
                         max_subrounds: int = 64, telemetry: bool = False):
    """Boman coloring on the shared harness — FR&MF rounds: propose
    locally, gather remote endpoint colors, and commit the pair-hash
    loser's recolor notification as an ``or`` wave.  Proposals and coin
    flips are pure functions of global ids, so the distributed run matches
    the single-shard :func:`coloring` bit-for-bit.

    Returns (color [V], rounds, not_converged); ``telemetry=True`` appends
    the DistributedResult."""
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)
    import numpy as np
    pal = int(np.asarray(jnp.max(g.degrees))) + 1

    def init(g, layout):
        return {"color": jnp.zeros((layout.vpad,), jnp.int32),
                "active": jnp.ones((layout.vpad,), bool)}, {}

    def round_fn(rt, e, st, sc, it):
        color = _propose(rt.gid, st["active"], st["color"], pal, seed, it)
        cs = color[e.my_src]
        cd = rt.gather(color, e.dst, e.valid, fill=-1)
        conflict = e.valid & (cs == cd)
        loser = _pair_loser(e.src, e.dst, seed, it)
        act, _ = rt.wave(jnp.zeros(color.shape, jnp.int32), loser,
                         jnp.ones_like(e.src), conflict, op="or")
        new_active = act != 0
        return ({"color": color, "active": new_active}, sc,
                rt.any(new_active))

    alg = AlgorithmSpec("coloring", "FR&MF", init, round_fn,
                        lambda g, layout: max_rounds)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    color = res.state["color"][:g.num_vertices]
    not_converged = jnp.any(res.state["active"][:g.num_vertices])
    out = (color, res.rounds, not_converged)
    return telemetry_return(out, res, telemetry)


def validate_coloring(g: Graph, color) -> bool:
    import numpy as np
    c = np.asarray(color)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    return bool((c[src] != c[dst]).all())
