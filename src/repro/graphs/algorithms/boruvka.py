"""Boruvka MST — FR&MF messages (paper §3.3.3, Listing 5).

Each round, every supervertex (component) selects its minimum-weight
outgoing edge (a segment-min commit — MF: only the winning edge per
component survives, the paper's conflicting-activity semantics), components
hook along the selected edges, and pointer-jumping contracts the forest.
Tie-breaking is lexicographic (weight, edge-id) so the MST is unique and
testable against networkx.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.messages import make_messages
from repro.graphs.csr import Graph

INF = jnp.float32(3.0e38)


def _shortcut(parent, iters):
    def body(p, _):
        return p[p], None
    p, _ = jax.lax.scan(body, parent, None, length=iters)
    return p


def _dedupe_mst_pairs(g: Graph, in_mst):
    """Undirected graphs store both directions: an MST edge may be selected
    from either side — count each canonical pair once (lexsorted dedupe).
    ``in_mst``: bool [E] per-direction selection.  Returns
    (weight, n_edges)."""
    e = g.num_edges
    lo = jnp.minimum(g.src, g.dst)
    hi = jnp.maximum(g.src, g.dst)
    o1 = jnp.argsort(hi, stable=True)
    order = o1[jnp.argsort(lo[o1], stable=True)]
    slo, shi, sm = lo[order], hi[order], in_mst[order]
    first = jnp.concatenate([jnp.ones((1,), bool),
                             (slo[1:] != slo[:-1]) | (shi[1:] != shi[:-1])])
    pair_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    pair_sel = jax.ops.segment_max(sm.astype(jnp.int32), pair_id,
                                   num_segments=e)
    uniq = first & (pair_sel[pair_id] > 0)
    weight = jnp.sum(jnp.where(uniq, g.weights[order], 0.0))
    n_edges = jnp.sum(uniq.astype(jnp.int32))
    return weight, n_edges


@partial(jax.jit, static_argnames=("spec", "axis_width"))
def boruvka_forest(g: Graph, *, spec: C.CommitSpec | None = None,
                   axis_width: int = 1):
    """The Boruvka contraction loop: returns (comp [V], in_mst [E] bool
    per-direction selection, rounds) — the piece :func:`boruvka` and the
    graph-batched entry point share.  Every step is a shift-equivariant
    function of vertex/edge ids, so running it on a disjoint-union graph
    equals running it per member graph (``batched_over_graphs_boruvka``
    relies on this).  ``axis_width`` tags the tuner race with the graph
    count of a batched caller."""
    if spec is None:
        # sort=False: scatter-min (atomic tier) == the old segment_min cost;
        # the sorted path would argsort all E edges per Boruvka round
        spec = C.CommitSpec(backend="coarse", sort=False, stats=False)
    v, e = g.num_vertices, g.num_edges
    jump = max(int(v).bit_length(), 1)
    # two commit sites with different state dtypes (f32 weights, i32 edge
    # ids) -> two independent adaptive ladders
    step_w, lvl_w0 = AT.make_commit_step(spec, "min", jnp.full((v,), INF),
                                         n=e, axis_width=axis_width)
    step_e, lvl_e0 = AT.make_commit_step(spec, "min",
                                         jnp.full((v,), e, jnp.int32), n=e,
                                         axis_width=axis_width)

    def cond(state):
        _, _, changed, it, *_ = state
        return changed & (it < jump + 1)

    def body(state):
        comp, in_mst, _, it, lvl_w, lvl_e = state
        cs, cd = comp[g.src], comp[g.dst]
        cross = cs != cd
        w = jnp.where(cross, g.weights, INF)
        # two-pass lexicographic argmin (weight, edge id): each pass is an
        # MF min-commit of per-edge messages into per-component state
        res_w, lvl_w = step_w(jnp.full((v,), INF),
                              make_messages(cs, g.weights, cross), lvl_w)
        best_w = res_w.state
        eid = jnp.arange(e, dtype=jnp.int32)
        cand = cross & (w == best_w[cs]) & (best_w[cs] < INF)
        res_e, lvl_e = step_e(jnp.full((v,), e, jnp.int32),
                              make_messages(cs, eid, cand), lvl_e)
        best_e = res_e.state
        has = best_e < e
        sel = jnp.clip(best_e, 0, e - 1)
        # hook: root of cs -> comp of chosen dst
        target = jnp.where(has, comp[g.dst[sel]], jnp.arange(v))
        parent = jnp.where(has, target, jnp.arange(v))
        # break mutual pairs (a<->b): larger id becomes root
        mutual = (parent[parent] == jnp.arange(v)) & \
            (jnp.arange(v) > parent)
        parent = jnp.where(mutual, jnp.arange(v), parent)
        parent = _shortcut(parent, jump)
        new_comp = parent[comp]
        in_mst = in_mst.at[sel].max(has, mode="drop")
        changed = jnp.any(new_comp != comp)
        return new_comp, in_mst, changed, it + 1, lvl_w, lvl_e

    comp0 = jnp.arange(v)
    in0 = jnp.zeros((e,), bool)
    comp, in_mst, _, rounds, _, _ = jax.lax.while_loop(
        cond, body, (comp0, in0, jnp.ones((), bool), jnp.zeros((), jnp.int32),
                     lvl_w0, lvl_e0))
    return comp, in_mst, rounds


@partial(jax.jit, static_argnames=("spec",))
def boruvka(g: Graph, *, spec: C.CommitSpec | None = None):
    comp, in_mst, rounds = boruvka_forest(g, spec=spec)
    weight, n_edges = _dedupe_mst_pairs(g, in_mst)
    return comp, weight, n_edges, rounds


def batched_over_graphs_boruvka(gs, *, spec: C.CommitSpec | None = None,
                                mesh=None, capacity: int | str = 4096,
                                axis: str = "data",
                                max_subrounds: int = 64):
    """G independent MSTs, one per tenant graph, as ONE fused Boruvka
    run over the :class:`repro.graphs.csr.GraphSet` union — the graph
    batch axis that finally makes Boruvka *servable*: its per-graph
    rounds share no query-lane structure, but independent graphs
    trivially share every wave (disjoint component-id key ranges in the
    two min-commits, disjoint edge-id ranges in the selection).

    Returns ``([(comp, weight, n_edges)] per graph, rounds)``; each
    triple is bit-identical to ``boruvka(gs.graphs[g])`` on every
    backend — the contraction loop is shift-equivariant and the
    canonical-pair dedupe runs per member graph."""
    if mesh is not None:
        comp_flat, in_mst_flat, rounds, _ = distributed_boruvka_forest(
            mesh, gs.union(), capacity=capacity, axis=axis, spec=spec,
            max_subrounds=max_subrounds, batch=gs.axis)
        in_mst_flat = jnp.asarray(in_mst_flat)
    else:
        comp_flat, in_mst_flat, rounds = boruvka_forest(
            gs.union(), spec=spec, axis_width=gs.num_graphs)
    comps = gs.split_vertex(comp_flat)
    sels = gs.split_edge(in_mst_flat)
    out = []
    for i, g in enumerate(gs.graphs):
        weight, n_edges = _dedupe_mst_pairs(g, sels[i])
        out.append((comps[i] - jnp.int32(gs.voffs[i]), weight, n_edges))
    return out, rounds


def distributed_boruvka_forest(mesh, g: Graph, *, capacity: int = 4096,
                               m: int | None = None, axis: str = "data",
                               spec: C.CommitSpec | None = None,
                               max_subrounds: int = 64, batch=None):
    """The distributed contraction loop behind :func:`distributed_boruvka`
    and the graph-batched entry point.  Returns (comp [V], in_mst numpy
    bool [E] in ORIGINAL edge order, rounds, DistributedResult);
    ``batch`` forwards a batch axis to ``run_distributed`` (the tuner's
    axis-width key for graph-batched runs)."""
    import numpy as np
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)
    from repro.graphs.csr import partition_edges

    v, e_tot = g.num_vertices, g.num_edges
    jump = max(int(v).bit_length(), 1)
    HOOK_EMPTY = jnp.int32(2 ** 30)

    def init(g, layout):
        return {"comp": jnp.arange(layout.vpad, dtype=jnp.int32),
                "in_mst": jnp.zeros((layout.vpad // layout.block
                                     * layout.emax,), bool)}, {}

    def round_fn(rt, e, st, sc, it):
        comp, in_mst = st["comp"], st["in_mst"]
        gid = rt.gid
        block = comp.shape[0]
        cs = comp[e.my_src]
        cd = rt.gather(comp, e.dst, e.valid, fill=0)
        cross = e.valid & (cs != cd)
        # lexicographic (weight, edge id) minimum per component: two MF
        # min-waves into the component owners, mirroring the single-shard
        # two-pass argmin
        bw, _ = rt.wave(jnp.full((block,), INF), cs, e.weight, cross,
                        op="min")
        bwcs = rt.gather(bw, cs, cross, fill=INF)
        cand = cross & (e.weight == bwcs) & (bwcs < INF)
        be, _ = rt.wave(jnp.full((block,), e_tot, jnp.int32), cs, e.eid,
                        cand, op="min")
        becs = rt.gather(be, cs, cand, fill=e_tot)
        winner = cand & (e.eid == becs)
        in_mst = in_mst | winner
        # hook: root of cs -> component of the chosen dst (exactly one
        # winner per component, delivered as a min-wave into empty slots)
        hook, _ = rt.wave(jnp.full((block,), HOOK_EMPTY, jnp.int32), cs,
                          cd, winner, op="min")
        parent = jnp.where(hook < HOOK_EMPTY, hook, gid)
        # break mutual pairs (a<->b): larger id becomes root
        gp = rt.gather(parent, parent)
        mutual = (gp == gid) & (gid > parent)
        parent = jnp.where(mutual, gid, parent)
        # pointer jumping via the FR read path (log V remote gathers)
        for _ in range(jump):
            parent = rt.gather(parent, parent)
        new_comp = rt.gather(parent, comp)
        changed = rt.any(new_comp != comp)
        return {"comp": new_comp, "in_mst": in_mst}, sc, changed

    alg = AlgorithmSpec("boruvka", "FR&MF", init, round_fn,
                        lambda g, layout: jump + 1)
    parts = partition_edges(g, mesh.shape[axis])   # shared with the harness
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds,
                          edges=parts, batch=batch)
    comp = res.state["comp"][:v]
    # map shard-lane selections back to original edge ids, then reuse the
    # single-shard canonical-pair dedupe
    (_, _, _, val_np, eid_np), _ = parts
    lanes = np.asarray(res.state["in_mst"]).reshape(val_np.shape)
    sel = np.zeros(e_tot, bool)
    sel[eid_np[val_np]] = lanes[val_np]
    return comp, sel, res.rounds, res


def distributed_boruvka(mesh, g: Graph, *, capacity: int = 4096,
                        m: int | None = None, axis: str = "data",
                        spec: C.CommitSpec | None = None,
                        max_subrounds: int = 64, telemetry: bool = False):
    """Boruvka MST on the shared harness — FR&MF rounds: two ``min``
    commit waves select each component's lexicographically-minimal outgoing
    edge (weight, then ORIGINAL edge id, so tie-breaks match the
    single-shard run exactly), a hook wave writes the component pointers,
    and pointer-jumping contracts the forest through the FR read path
    (``route_messages``/``return_to_spawners`` remote gathers).

    Returns (comp [V], weight, n_edges, rounds); ``telemetry=True``
    appends the DistributedResult."""
    from repro.core.engine import telemetry_return
    comp, sel, rounds, res = distributed_boruvka_forest(
        mesh, g, capacity=capacity, m=m, axis=axis, spec=spec,
        max_subrounds=max_subrounds)
    weight, n_edges = _dedupe_mst_pairs(g, jnp.asarray(sel))
    out = (comp, weight, n_edges, rounds)
    return telemetry_return(out, res, telemetry)


def mst_reference(g: Graph) -> float:
    import networkx as nx
    import numpy as np
    G = nx.Graph()
    G.add_nodes_from(range(g.num_vertices))
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.weights)
    for s, d, ww in zip(src, dst, w):
        u, vv = int(s), int(d)
        if G.has_edge(u, vv):
            if G[u][vv]["weight"] > ww:
                G[u][vv]["weight"] = float(ww)
        else:
            G.add_edge(u, vv, weight=float(ww))
    total = 0.0
    for cc in nx.connected_components(G):
        sub = G.subgraph(cc)
        total += sum(d["weight"] for _, _, d in
                     nx.minimum_spanning_edges(sub, data=True))
    return total
