"""Boruvka MST — FR&MF messages (paper §3.3.3, Listing 5).

Each round, every supervertex (component) selects its minimum-weight
outgoing edge (a segment-min commit — MF: only the winning edge per
component survives, the paper's conflicting-activity semantics), components
hook along the selected edges, and pointer-jumping contracts the forest.
Tie-breaking is lexicographic (weight, edge-id) so the MST is unique and
testable against networkx.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import commit as C
from repro.core.messages import make_messages
from repro.graphs.csr import Graph

INF = jnp.float32(3.0e38)


def _shortcut(parent, iters):
    def body(p, _):
        return p[p], None
    p, _ = jax.lax.scan(body, parent, None, length=iters)
    return p


@partial(jax.jit, static_argnames=("spec",))
def boruvka(g: Graph, *, spec: C.CommitSpec | None = None):
    if spec is None:
        # sort=False: scatter-min (atomic tier) == the old segment_min cost;
        # the sorted path would argsort all E edges per Boruvka round
        spec = C.CommitSpec(backend="coarse", sort=False, stats=False)
    v, e = g.num_vertices, g.num_edges
    jump = max(int(v).bit_length(), 1)

    def cond(state):
        _, _, changed, it = state
        return changed & (it < jump + 1)

    def body(state):
        comp, in_mst, _, it = state
        cs, cd = comp[g.src], comp[g.dst]
        cross = cs != cd
        w = jnp.where(cross, g.weights, INF)
        # two-pass lexicographic argmin (weight, edge id): each pass is an
        # MF min-commit of per-edge messages into per-component state
        best_w = C.commit(jnp.full((v,), INF),
                          make_messages(cs, g.weights, cross),
                          "min", spec).state
        eid = jnp.arange(e, dtype=jnp.int32)
        cand = cross & (w == best_w[cs]) & (best_w[cs] < INF)
        best_e = C.commit(jnp.full((v,), e, jnp.int32),
                          make_messages(cs, eid, cand),
                          "min", spec).state
        has = best_e < e
        sel = jnp.clip(best_e, 0, e - 1)
        # hook: root of cs -> comp of chosen dst
        target = jnp.where(has, comp[g.dst[sel]], jnp.arange(v))
        parent = jnp.where(has, target, jnp.arange(v))
        # break mutual pairs (a<->b): larger id becomes root
        mutual = (parent[parent] == jnp.arange(v)) & \
            (jnp.arange(v) > parent)
        parent = jnp.where(mutual, jnp.arange(v), parent)
        parent = _shortcut(parent, jump)
        new_comp = parent[comp]
        in_mst = in_mst.at[sel].max(has, mode="drop")
        changed = jnp.any(new_comp != comp)
        return new_comp, in_mst, changed, it + 1

    comp0 = jnp.arange(v)
    in0 = jnp.zeros((e,), bool)
    comp, in_mst, _, rounds = jax.lax.while_loop(
        cond, body, (comp0, in0, jnp.ones((), bool), jnp.zeros((), jnp.int32)))
    # undirected graphs store both directions: an MST edge may be selected
    # from either side — count each canonical pair once (lexsorted dedupe).
    lo = jnp.minimum(g.src, g.dst)
    hi = jnp.maximum(g.src, g.dst)
    o1 = jnp.argsort(hi, stable=True)
    order = o1[jnp.argsort(lo[o1], stable=True)]
    slo, shi, sm = lo[order], hi[order], in_mst[order]
    first = jnp.concatenate([jnp.ones((1,), bool),
                             (slo[1:] != slo[:-1]) | (shi[1:] != shi[:-1])])
    pair_id = jnp.cumsum(first.astype(jnp.int32)) - 1
    pair_sel = jax.ops.segment_max(sm.astype(jnp.int32), pair_id,
                                   num_segments=e)
    uniq = first & (pair_sel[pair_id] > 0)
    weight = jnp.sum(jnp.where(uniq, g.weights[order], 0.0))
    n_edges = jnp.sum(uniq.astype(jnp.int32))
    return comp, weight, n_edges, rounds


def mst_reference(g: Graph) -> float:
    import networkx as nx
    import numpy as np
    G = nx.Graph()
    G.add_nodes_from(range(g.num_vertices))
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    w = np.asarray(g.weights)
    for s, d, ww in zip(src, dst, w):
        u, vv = int(s), int(d)
        if G.has_edge(u, vv):
            if G[u][vv]["weight"] > ww:
                G[u][vv]["weight"] = float(ww)
        else:
            G.add_edge(u, vv, weight=float(ww))
    total = 0.0
    for cc in nx.connected_components(G):
        sub = G.subgraph(cc)
        total += sum(d["weight"] for _, _, d in
                     nx.minimum_spanning_edges(sub, data=True))
    return total
