"""ST-connectivity — FR&AS messages (paper §3.3.4, Listing 6).

Two concurrent BFS waves ("grey" from s, "green" from t) color white
vertices with a first-writer-wins commit; an edge whose endpoints carry
different non-white colors proves connectivity (the operator's ``return
true`` routed back to the spawner, which terminates the run)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import commit as C
from repro.core.messages import make_messages
from repro.graphs.csr import Graph

WHITE, GREY, GREEN = -1, 1, 2


@partial(jax.jit, static_argnames=("spec",))
def st_connectivity(g: Graph, s, t, *, spec: C.CommitSpec | None = None):
    if spec is None:
        spec = C.CommitSpec(backend="coarse")
    v = g.num_vertices
    color0 = jnp.full((v,), WHITE, jnp.int32).at[s].set(GREY).at[t].set(GREEN)
    frontier0 = jnp.zeros((v,), bool).at[s].set(True).at[t].set(True)

    def cond(state):
        color, frontier, found, it = state
        return jnp.any(frontier) & ~found & (it < v)

    def body(state):
        color, frontier, found, it = state
        active = frontier[g.src]
        # meeting check on live edges (the FR "returns true" path)
        meet = active & (color[g.src] != WHITE) & (color[g.dst] != WHITE) \
            & (color[g.src] != color[g.dst])
        found = found | jnp.any(meet)
        msgs = make_messages(g.dst, color[g.src], active)
        res = C.commit(color, msgs, "first", spec)
        changed = res.state != color
        return res.state, changed, found, it + 1

    color, _, found, rounds = jax.lax.while_loop(
        cond, body, (color0, frontier0, jnp.zeros((), bool),
                     jnp.zeros((), jnp.int32)))
    # exhaustive fallback: same color reached both? (disconnected otherwise)
    return found, rounds


def st_reference(g: Graph, s: int, t: int) -> bool:
    import numpy as np
    from repro.graphs.algorithms.bfs import bfs_reference
    dist = bfs_reference(g, s)
    return bool(dist[t] < 2 ** 29)
