"""ST-connectivity — FR&AS messages (paper §3.3.4, Listing 6).

Two concurrent BFS waves ("grey" from s, "green" from t) color white
vertices with a first-writer-wins commit; an edge whose endpoints carry
different non-white colors proves connectivity (the operator's ``return
true`` routed back to the spawner, which terminates the run)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.messages import lane_messages, make_messages
from repro.graphs.csr import Graph

WHITE, GREY, GREEN = -1, 1, 2


@partial(jax.jit, static_argnames=("spec",))
def st_connectivity(g: Graph, s, t, *, spec: C.CommitSpec | None = None):
    if spec is None:
        spec = C.CommitSpec(backend="coarse")
    v = g.num_vertices
    color0 = jnp.full((v,), WHITE, jnp.int32).at[s].set(GREY).at[t].set(GREEN)
    frontier0 = jnp.zeros((v,), bool).at[s].set(True).at[t].set(True)
    step, lvl0 = AT.make_commit_step(spec, "first", color0,
                                     n=g.src.shape[0])

    def cond(state):
        color, frontier, found, it, _ = state
        return jnp.any(frontier) & ~found & (it < v)

    def body(state):
        color, frontier, found, it, lvl = state
        active = frontier[g.src]
        # meeting check on live edges (the FR "returns true" path)
        meet = active & (color[g.src] != WHITE) & (color[g.dst] != WHITE) \
            & (color[g.src] != color[g.dst])
        found = found | jnp.any(meet)
        msgs = make_messages(g.dst, color[g.src], active)
        res, lvl = step(color, msgs, lvl)
        changed = res.state != color
        return res.state, changed, found, it + 1, lvl

    # s == t is connected by the empty path (distributed_stconn and the
    # lane-batched multi_source_stconn already answer True; the wave
    # below cannot — s's GREY is overwritten by t's GREEN at init)
    found0 = jnp.asarray(s) == jnp.asarray(t)
    color, _, found, rounds, _ = jax.lax.while_loop(
        cond, body, (color0, frontier0, found0,
                     jnp.zeros((), jnp.int32), lvl0))
    # exhaustive fallback: same color reached both? (disconnected otherwise)
    return found, rounds


@partial(jax.jit, static_argnames=("spec",))
def multi_source_stconn(g: Graph, ss, ts, *,
                        spec: C.CommitSpec | None = None):
    """L s-t connectivity queries as one fused wave.

    Query l runs its two BFS waves as lanes 2l (grey, from ``ss[l]``) and
    2l+1 (green, from ``ts[l]``) of a [2L, V] ``or``-mark state —
    connectivity is proven where both marks meet.  Returns
    (found [L] bool, rounds).  ``found[l]`` equals
    ``st_connectivity(g, ss[l], ts[l])`` for ss[l] != ts[l] (both compute
    ground-truth reachability); answered queries stop emitting messages
    while the wave keeps serving the rest."""
    if spec is None:
        spec = C.CommitSpec(backend="coarse")
    v = g.num_vertices
    ss = jnp.asarray(ss, jnp.int32)
    ts = jnp.asarray(ts, jnp.int32)
    lanes = ss.shape[0]
    l2 = 2 * lanes
    lidx = jnp.arange(lanes, dtype=jnp.int32)
    marks0 = jnp.zeros((l2, v), jnp.int32) \
        .at[2 * lidx, ss].set(1).at[2 * lidx + 1, ts].set(1)
    frontier0 = jnp.zeros((l2, v), bool) \
        .at[2 * lidx, ss].set(True).at[2 * lidx + 1, ts].set(True)
    found0 = ss == ts
    e = g.src.shape[0]
    dst_l = jnp.broadcast_to(g.dst, (l2, e))
    step, lvl0 = AT.make_commit_step(spec, "or", marks0.reshape(-1),
                                     n=l2 * e, axis_width=l2)

    def cond(state):
        _, frontier, found, it, _ = state
        live = frontier & jnp.repeat(~found, 2)[:, None]
        return jnp.any(live) & (it < v)

    def body(state):
        marks, frontier, found, it, lvl = state
        active = frontier[:, g.src] \
            & jnp.repeat(~found, 2)[:, None]    # answered lanes go quiet
        msgs = lane_messages(dst_l, active.astype(jnp.int32), active, v)
        res, lvl = step(marks.reshape(-1), msgs, lvl)
        marks2 = res.state.reshape(l2, v)
        frontier2 = (marks2 != 0) & (marks == 0)
        meet = (marks2[0::2] != 0) & (marks2[1::2] != 0)   # [L, V]
        return marks2, frontier2, found | jnp.any(meet, axis=1), \
            it + 1, lvl

    _, _, found, rounds, _ = jax.lax.while_loop(
        cond, body, (marks0, frontier0, found0,
                     jnp.zeros((), jnp.int32), lvl0))
    return found, rounds


@partial(jax.jit, static_argnames=("spec", "num_graphs", "axis_width"))
def _union_stconn(g: Graph, ss_flat, ts_flat, gov, egov, *,
                  spec: C.CommitSpec | None, num_graphs: int,
                  axis_width: int):
    """G s-t queries over a disjoint-union graph: grey marks live at flat
    keys [0, V), green at [V, 2V) (a nested 2-lane axis on top of the
    graph axis); per-graph found bits are segment reductions by the
    graph-of-vertex map."""
    v = g.num_vertices
    e = g.src.shape[0]
    marks0 = jnp.zeros((2 * v,), jnp.int32) \
        .at[ss_flat].set(1).at[v + ts_flat].set(1)
    frontier0 = jnp.zeros((2 * v,), bool) \
        .at[ss_flat].set(True).at[v + ts_flat].set(True)
    found0 = ss_flat == ts_flat
    tgt2 = jnp.concatenate([g.dst, v + g.dst])
    step, lvl0 = AT.make_commit_step(spec, "or", marks0, n=2 * e,
                                     axis_width=axis_width)

    def cond(state):
        marks, frontier, found, it, _ = state
        live = frontier & jnp.concatenate([~found[gov], ~found[gov]])
        return jnp.any(live) & (it < v)

    def body(state):
        marks, frontier, found, it, lvl = state
        live_e = ~found[egov]                    # answered graphs go quiet
        a_grey = frontier[g.src] & live_e
        a_green = frontier[v + g.src] & live_e
        active = jnp.concatenate([a_grey, a_green])
        msgs = make_messages(tgt2, active.astype(jnp.int32), active)
        res, lvl = step(marks, msgs, lvl)
        frontier2 = (res.state != 0) & (marks == 0)
        meet = (res.state[:v] != 0) & (res.state[v:] != 0)      # [V]
        found2 = found | (jax.ops.segment_sum(
            meet.astype(jnp.int32), gov, num_segments=num_graphs) > 0)
        return res.state, frontier2, found2, it + 1, lvl

    _, _, found, rounds, _ = jax.lax.while_loop(
        cond, body, (marks0, frontier0, found0,
                     jnp.zeros((), jnp.int32), lvl0))
    return found, rounds


def batched_over_graphs_stconn(gs, ss, ts, *,
                               spec: C.CommitSpec | None = None,
                               mesh=None, capacity: int | str = 4096,
                               axis: str = "data",
                               max_subrounds: int = 64):
    """G s-t connectivity queries, one per tenant graph, fused on the
    graph batch axis.  ``ss[g]``/``ts[g]`` are graph g's LOCAL
    endpoints.  Returns found [G] bool — ``found[g]`` equals
    ``st_connectivity(gs.graphs[g], ss[g], ts[g])`` on every backend
    (both compute ground-truth reachability; answered graphs stop
    emitting messages while the wave serves the rest)."""
    if spec is None:
        spec = C.CommitSpec(backend="coarse")
    ss_flat = gs.flat_vertices(ss)
    ts_flat = gs.flat_vertices(ts)
    if mesh is not None:
        found, _ = _distributed_union_stconn(
            mesh, gs, ss_flat, ts_flat, spec=spec, capacity=capacity,
            axis=axis, max_subrounds=max_subrounds)
        return found
    found, _ = _union_stconn(gs.union(), ss_flat, ts_flat,
                             gs.graph_of_vertex(), gs.graph_of_edge(),
                             spec=spec, num_graphs=gs.num_graphs,
                             axis_width=2 * gs.num_graphs)
    return found


def _distributed_union_stconn(mesh, gs, ss_flat, ts_flat, *, spec,
                              capacity, axis, max_subrounds):
    """Graph-batched s-t connectivity on the shared harness: the union's
    grey/green marks ride as TWO payload fields through one coalescing
    bucket per round, per-graph found bits psum'd as a [G] vector."""
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)
    g = gs.union()
    v = g.num_vertices
    num_graphs = gs.num_graphs
    gov_np = gs.graph_of_vertex()
    voffs = jnp.asarray(gs.voffs, jnp.int32)

    def init(g, layout):
        vpad = layout.vpad
        state = {"grey": jnp.zeros((vpad,), jnp.int32).at[ss_flat].set(1),
                 "green": jnp.zeros((vpad,), jnp.int32).at[ts_flat].set(1),
                 "fgrey": jnp.zeros((vpad,), bool).at[ss_flat].set(True),
                 "fgreen": jnp.zeros((vpad,), bool).at[ts_flat].set(True),
                 "gov": jnp.full((vpad,), num_graphs - 1, jnp.int32)
                 .at[:v].set(gov_np),
                 "real": jnp.zeros((vpad,), bool).at[:v].set(True)}
        return state, {"found": ss_flat == ts_flat}

    def round_fn(rt, e, st, sc, it):
        egov = jnp.clip(
            jnp.searchsorted(voffs[1:], e.src, side="right"), 0,
            num_graphs - 1).astype(jnp.int32)
        live_e = e.valid & ~sc["found"][egov]
        ag = st["fgrey"][e.my_src] & live_e
        agr = st["fgreen"][e.my_src] & live_e
        marks, _ = rt.wave(
            {"grey": st["grey"], "green": st["green"]}, e.dst,
            {"grey": ag.astype(jnp.int32), "green": agr.astype(jnp.int32)},
            ag | agr, op="or")
        fgrey = (marks["grey"] != 0) & (st["grey"] == 0)
        fgreen = (marks["green"] != 0) & (st["green"] == 0)
        meet = (marks["grey"] != 0) & (marks["green"] != 0) & st["real"]
        found = sc["found"] | (rt.psum(jax.ops.segment_sum(
            meet.astype(jnp.int32), st["gov"],
            num_segments=num_graphs)) > 0)
        live2 = (fgrey | fgreen) & ~found[st["gov"]] & st["real"]
        state = dict(st, grey=marks["grey"], green=marks["green"],
                     fgrey=fgrey, fgreen=fgreen)
        return state, {"found": found}, rt.any(live2)

    alg = AlgorithmSpec("graphs_stconn", "FR&AS", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, gs, capacity=capacity, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    return res.scalars["found"], res.rounds


def distributed_stconn(mesh, g: Graph, s: int, t: int, *,
                       capacity: int | str = 4096, m: int | None = None,
                       axis: str = "data",
                       spec: C.CommitSpec | None = None,
                       max_subrounds: int = 64, telemetry: bool = False):
    """ST-connectivity on the shared harness — two concurrent BFS waves
    ("grey" from s, "green" from t) carried as TWO payload fields through
    ONE coalescing bucket per round (``or`` commits into two frontier
    marks); connectivity is proven when any vertex holds both marks (the
    FR "return true" routed back as a psum).

    Returns (found, rounds); ``telemetry=True`` appends the
    DistributedResult."""
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)

    def init(g, layout):
        vpad = layout.vpad
        grey0 = jnp.zeros((vpad,), jnp.int32).at[s].set(1)
        green0 = jnp.zeros((vpad,), jnp.int32).at[t].set(1)
        state = {"grey": grey0, "green": green0,
                 "fgrey": jnp.zeros((vpad,), bool).at[s].set(True),
                 "fgreen": jnp.zeros((vpad,), bool).at[t].set(True)}
        return state, {"found": jnp.asarray(s == t, bool)}

    def round_fn(rt, e, st, sc, it):
        ag = st["fgrey"][e.my_src] & e.valid
        agr = st["fgreen"][e.my_src] & e.valid
        marks, _ = rt.wave(
            {"grey": st["grey"], "green": st["green"]}, e.dst,
            {"grey": ag.astype(jnp.int32), "green": agr.astype(jnp.int32)},
            ag | agr, op="or")
        fgrey = (marks["grey"] != 0) & (st["grey"] == 0)
        fgreen = (marks["green"] != 0) & (st["green"] == 0)
        found = sc["found"] | rt.any((marks["grey"] != 0)
                                     & (marks["green"] != 0))
        state = {"grey": marks["grey"], "green": marks["green"],
                 "fgrey": fgrey, "fgreen": fgreen}
        active = (rt.any(fgrey) | rt.any(fgreen)) & ~found
        return state, {"found": found}, active

    alg = AlgorithmSpec("stconn", "FR&AS", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    out = (res.scalars["found"], res.rounds)
    return telemetry_return(out, res, telemetry)


def distributed_multi_source_stconn(mesh, g: Graph, ss, ts, *,
                                    capacity: int | str = 4096,
                                    m: int | None = None,
                                    axis: str = "data",
                                    spec: C.CommitSpec | None = None,
                                    max_subrounds: int = 64,
                                    telemetry: bool = False):
    """Lane-batched s-t connectivity over a mesh axis: 2L mark lanes on
    vertex-major [vpad * 2L] state, per-lane found bits psum'd each round
    (the FR "return true" as an [L] vector).  Returns (found [L], rounds);
    ``telemetry=True`` appends the DistributedResult."""
    from repro.core.coalescing import QueryLanes
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)

    ss = jnp.asarray(ss, jnp.int32)
    ts = jnp.asarray(ts, jnp.int32)
    lanes = ss.shape[0]
    l2 = 2 * lanes
    lidx = jnp.arange(lanes, dtype=jnp.int32)
    l2idx = jnp.arange(l2, dtype=jnp.int32)

    def init(g, layout):
        vpad = layout.vpad
        marks0 = jnp.zeros((vpad * l2,), jnp.int32) \
            .at[ss * l2 + 2 * lidx].set(1) \
            .at[ts * l2 + 2 * lidx + 1].set(1)
        frontier0 = jnp.zeros((vpad * l2,), bool) \
            .at[ss * l2 + 2 * lidx].set(True) \
            .at[ts * l2 + 2 * lidx + 1].set(True)
        return {"marks": marks0, "frontier": frontier0}, \
            {"found": ss == ts}

    def round_fn(rt, e, st, sc, it):
        emax = e.dst.shape[0]
        live = jnp.repeat(~sc["found"], 2)              # [2L]
        fl = e.my_src[:, None] * l2 + l2idx[None, :]    # [emax, 2L]
        active = st["frontier"][fl] & e.valid[:, None] & live[None, :]
        tgt = jnp.broadcast_to(e.dst[:, None], (emax, l2))
        lane = jnp.broadcast_to(l2idx[None, :], (emax, l2))
        marks2, _ = rt.wave(st["marks"], tgt.reshape(-1),
                            active.astype(jnp.int32).reshape(-1),
                            active.reshape(-1), op="or",
                            major=lane.reshape(-1))
        frontier2 = (marks2 != 0) & (st["marks"] == 0)
        mk = marks2.reshape(-1, l2)
        meet = (mk[:, 0::2] != 0) & (mk[:, 1::2] != 0)  # [block, L]
        found = sc["found"] | (rt.psum(
            jnp.sum(meet.astype(jnp.int32), axis=0)) > 0)
        live2 = frontier2.reshape(-1, l2) & jnp.repeat(~found, 2)[None, :]
        return {"marks": marks2, "frontier": frontier2}, \
            {"found": found}, rt.any(live2)

    alg = AlgorithmSpec("multi_stconn", "FR&AS", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds,
                          batch=QueryLanes(l2, g.num_vertices))
    out = (res.scalars["found"], res.rounds)
    return telemetry_return(out, res, telemetry)


def st_reference(g: Graph, s: int, t: int) -> bool:
    import numpy as np
    from repro.graphs.algorithms.bfs import bfs_reference
    dist = bfs_reference(g, s)
    return bool(dist[t] < 2 ** 29)
