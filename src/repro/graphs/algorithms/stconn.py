"""ST-connectivity — FR&AS messages (paper §3.3.4, Listing 6).

Two concurrent BFS waves ("grey" from s, "green" from t) color white
vertices with a first-writer-wins commit; an edge whose endpoints carry
different non-white colors proves connectivity (the operator's ``return
true`` routed back to the spawner, which terminates the run)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.messages import make_messages
from repro.graphs.csr import Graph

WHITE, GREY, GREEN = -1, 1, 2


@partial(jax.jit, static_argnames=("spec",))
def st_connectivity(g: Graph, s, t, *, spec: C.CommitSpec | None = None):
    if spec is None:
        spec = C.CommitSpec(backend="coarse")
    v = g.num_vertices
    color0 = jnp.full((v,), WHITE, jnp.int32).at[s].set(GREY).at[t].set(GREEN)
    frontier0 = jnp.zeros((v,), bool).at[s].set(True).at[t].set(True)
    step, lvl0 = AT.make_commit_step(spec, "first", color0,
                                     n=g.src.shape[0])

    def cond(state):
        color, frontier, found, it, _ = state
        return jnp.any(frontier) & ~found & (it < v)

    def body(state):
        color, frontier, found, it, lvl = state
        active = frontier[g.src]
        # meeting check on live edges (the FR "returns true" path)
        meet = active & (color[g.src] != WHITE) & (color[g.dst] != WHITE) \
            & (color[g.src] != color[g.dst])
        found = found | jnp.any(meet)
        msgs = make_messages(g.dst, color[g.src], active)
        res, lvl = step(color, msgs, lvl)
        changed = res.state != color
        return res.state, changed, found, it + 1, lvl

    color, _, found, rounds, _ = jax.lax.while_loop(
        cond, body, (color0, frontier0, jnp.zeros((), bool),
                     jnp.zeros((), jnp.int32), lvl0))
    # exhaustive fallback: same color reached both? (disconnected otherwise)
    return found, rounds


def distributed_stconn(mesh, g: Graph, s: int, t: int, *,
                       capacity: int = 4096, m: int | None = None,
                       axis: str = "data",
                       spec: C.CommitSpec | None = None,
                       max_subrounds: int = 64, telemetry: bool = False):
    """ST-connectivity on the shared harness — two concurrent BFS waves
    ("grey" from s, "green" from t) carried as TWO payload fields through
    ONE coalescing bucket per round (``or`` commits into two frontier
    marks); connectivity is proven when any vertex holds both marks (the
    FR "return true" routed back as a psum).

    Returns (found, rounds); ``telemetry=True`` appends the
    DistributedResult."""
    from repro.core.engine import AlgorithmSpec, run_distributed

    def init(g, layout):
        vpad = layout.vpad
        grey0 = jnp.zeros((vpad,), jnp.int32).at[s].set(1)
        green0 = jnp.zeros((vpad,), jnp.int32).at[t].set(1)
        state = {"grey": grey0, "green": green0,
                 "fgrey": jnp.zeros((vpad,), bool).at[s].set(True),
                 "fgreen": jnp.zeros((vpad,), bool).at[t].set(True)}
        return state, {"found": jnp.asarray(s == t, bool)}

    def round_fn(rt, e, st, sc, it):
        ag = st["fgrey"][e.my_src] & e.valid
        agr = st["fgreen"][e.my_src] & e.valid
        marks, _ = rt.wave(
            {"grey": st["grey"], "green": st["green"]}, e.dst,
            {"grey": ag.astype(jnp.int32), "green": agr.astype(jnp.int32)},
            ag | agr, op="or")
        fgrey = (marks["grey"] != 0) & (st["grey"] == 0)
        fgreen = (marks["green"] != 0) & (st["green"] == 0)
        found = sc["found"] | rt.any((marks["grey"] != 0)
                                     & (marks["green"] != 0))
        state = {"grey": marks["grey"], "green": marks["green"],
                 "fgrey": fgrey, "fgreen": fgreen}
        active = (rt.any(fgrey) | rt.any(fgreen)) & ~found
        return state, {"found": found}, active

    alg = AlgorithmSpec("stconn", "FR&AS", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    out = (res.scalars["found"], res.rounds)
    return out + (res,) if telemetry else out


def st_reference(g: Graph, s: int, t: int) -> bool:
    import numpy as np
    from repro.graphs.algorithms.bfs import bfs_reference
    dist = bfs_reference(g, s)
    return bool(dist[t] < 2 ** 29)
