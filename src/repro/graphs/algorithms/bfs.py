"""BFS — FF&MF atomic active messages (paper §3.3.2, Listing 4).

Label-correcting edge-centric formulation: every round, each edge whose
source is in the frontier emits a message ``(dst, dist[src]+1)``; messages
commit with the MF ``min`` operator (losers fail silently — no rollback
needed on TPU, DESIGN.md §2); the next frontier is the set of vertices whose
distance changed.  ``commit="atomic"`` is the fine-grained Graph500-style
baseline; ``commit="coarse"`` is AAM with transaction size ``m``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import commit as C
from repro.core.messages import Messages, make_messages
from repro.graphs.csr import Graph

INF = jnp.int32(2 ** 30)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BfsResult:
    dist: jax.Array
    rounds: jax.Array
    messages: jax.Array
    conflicts: jax.Array
    applied: jax.Array


@partial(jax.jit, static_argnames=("commit", "m", "sort", "spec"))
def bfs(g: Graph, source, *, commit: str = "coarse", m: int | None = None,
        sort: bool = True, spec: C.CommitSpec | None = None) -> BfsResult:
    """``spec`` names the commit backend directly; the legacy
    ``commit``/``m``/``sort`` knobs build one when it is omitted."""
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    dist0 = jnp.full((v,), INF, jnp.int32).at[source].set(0)
    frontier0 = jnp.zeros((v,), bool).at[source].set(True)
    cfn = lambda st, msgs: C.commit(st, msgs, "min", spec)

    def cond(state):
        _, frontier, it, *_ = state
        return jnp.any(frontier) & (it < v)

    def body(state):
        dist, frontier, it, nmsg, ncf, nap = state
        active = frontier[g.src]
        msgs = make_messages(g.dst, dist[g.src] + 1, active)
        res = cfn(dist, msgs)
        changed = res.state != dist
        return (res.state, changed, it + 1,
                nmsg + jnp.sum(active.astype(jnp.int32)),
                ncf + res.conflicts, nap + res.applied)

    z = jnp.zeros((), jnp.int32)
    dist, _, rounds, nmsg, ncf, nap = jax.lax.while_loop(
        cond, body, (dist0, frontier0, z, z, z, z))
    return BfsResult(dist, rounds, nmsg, ncf, nap)


def bfs_reference(g: Graph, source: int):
    """Pure-python BFS oracle (tests)."""
    import collections
    import numpy as np
    indptr = np.asarray(g.indptr)
    dst = np.asarray(g.dst)
    dist = np.full(g.num_vertices, 2 ** 30, np.int64)
    dist[source] = 0
    q = collections.deque([source])
    while q:
        u = q.popleft()
        for e in range(indptr[u], indptr[u + 1]):
            w_ = dst[e]
            if dist[w_] > dist[u] + 1:
                dist[w_] = dist[u] + 1
                q.append(w_)
    return dist
