"""BFS — FF&MF atomic active messages (paper §3.3.2, Listing 4).

Label-correcting edge-centric formulation: every round, each edge whose
source is in the frontier emits a message ``(dst, dist[src]+1)``; messages
commit with the MF ``min`` operator (losers fail silently — no rollback
needed on TPU, DESIGN.md §2); the next frontier is the set of vertices whose
distance changed.  ``commit="atomic"`` is the fine-grained Graph500-style
baseline; ``commit="coarse"`` is AAM with transaction size ``m``.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.messages import Messages, lane_messages, make_messages
from repro.graphs.csr import Graph

INF = jnp.int32(2 ** 30)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BfsResult:
    dist: jax.Array
    rounds: jax.Array
    messages: jax.Array
    conflicts: jax.Array
    applied: jax.Array


@partial(jax.jit, static_argnames=("commit", "m", "sort", "spec"))
def bfs(g: Graph, source, *, commit: str = "coarse", m: int | None = None,
        sort: bool = True, spec: C.CommitSpec | None = None) -> BfsResult:
    """``spec`` names the commit backend directly; the legacy
    ``commit``/``m``/``sort`` knobs build one when it is omitted."""
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    dist0 = jnp.full((v,), INF, jnp.int32).at[source].set(0)
    frontier0 = jnp.zeros((v,), bool).at[source].set(True)
    # backend="auto": calibrated ladder commit; the level rides the carry
    step, lvl0 = AT.make_commit_step(spec, "min", dist0,
                                     n=g.src.shape[0])

    def cond(state):
        _, frontier, it, *_ = state
        return jnp.any(frontier) & (it < v)

    def body(state):
        dist, frontier, it, lvl, nmsg, ncf, nap = state
        active = frontier[g.src]
        msgs = make_messages(g.dst, dist[g.src] + 1, active)
        res, lvl = step(dist, msgs, lvl)
        changed = res.state != dist
        return (res.state, changed, it + 1, lvl,
                nmsg + jnp.sum(active.astype(jnp.int32)),
                ncf + res.conflicts, nap + res.applied)

    z = jnp.zeros((), jnp.int32)
    dist, _, rounds, _, nmsg, ncf, nap = jax.lax.while_loop(
        cond, body, (dist0, frontier0, z, lvl0, z, z, z))
    return BfsResult(dist, rounds, nmsg, ncf, nap)


@partial(jax.jit, static_argnames=("commit", "m", "sort", "spec"))
def multi_source_bfs(g: Graph, sources, *, commit: str = "coarse",
                     m: int | None = None, sort: bool = True,
                     spec: C.CommitSpec | None = None) -> BfsResult:
    """L independent BFS queries as lanes of ONE fused wave.

    ``sources`` is int32 [L]; the result's ``dist`` is [L, V] — row l
    bit-identical to ``bfs(g, sources[l])`` (``min`` is order-independent,
    and lanes occupy disjoint composite key ranges ``lane * V + v``, so
    one commit per round resolves every query's conflicts at once).
    Converged lanes stop emitting messages (per-query early exit) while
    the wave keeps serving the stragglers."""
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    sources = jnp.asarray(sources, jnp.int32)
    lanes = sources.shape[0]
    lidx = jnp.arange(lanes, dtype=jnp.int32)
    dist0 = jnp.full((lanes, v), INF, jnp.int32).at[lidx, sources].set(0)
    frontier0 = jnp.zeros((lanes, v), bool).at[lidx, sources].set(True)
    e = g.src.shape[0]
    dst_l = jnp.broadcast_to(g.dst, (lanes, e))
    step, lvl0 = AT.make_commit_step(spec, "min", dist0.reshape(-1),
                                     n=lanes * e, axis_width=lanes)

    def cond(state):
        _, frontier, it, *_ = state
        return jnp.any(frontier) & (it < v)

    def body(state):
        dist, frontier, it, lvl, nmsg, ncf, nap = state
        active = frontier[:, g.src]            # per-lane early-exit mask
        msgs = lane_messages(dst_l, dist[:, g.src] + 1, active, v)
        res, lvl = step(dist.reshape(-1), msgs, lvl)
        dist2 = res.state.reshape(lanes, v)
        return (dist2, dist2 != dist, it + 1, lvl,
                nmsg + jnp.sum(active.astype(jnp.int32)),
                ncf + res.conflicts, nap + res.applied)

    z = jnp.zeros((), jnp.int32)
    dist, _, rounds, _, nmsg, ncf, nap = jax.lax.while_loop(
        cond, body, (dist0, frontier0, z, lvl0, z, z, z))
    return BfsResult(dist, rounds, nmsg, ncf, nap)


def distributed_bfs(mesh, g: Graph, source: int, *,
                    capacity: int | str = 4096,
                    m: int | None = None, axis: str = "data",
                    spec: C.CommitSpec | None = None, max_subrounds: int = 64,
                    telemetry: bool = False,
                    snapshot_rounds: int | None = None,
                    fault_injector=None):
    """BFS over a mesh axis — FF&MF ``min`` waves on the shared harness.

    Returns (dist [V], rounds); ``telemetry=True`` appends the
    DistributedResult: (dist, rounds, res) — see
    :func:`repro.core.engine.telemetry_return`.  ``snapshot_rounds``/``fault_injector``
    enable the engine's degraded-mesh mode (survive a host drop by
    shrinking the mesh and replaying the last round snapshot — see
    :func:`repro.core.engine.run_distributed`)."""
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)

    def init(g, layout):
        dist0 = jnp.full((layout.vpad,), INF, jnp.int32).at[source].set(0)
        frontier0 = jnp.zeros((layout.vpad,), bool).at[source].set(True)
        return {"dist": dist0, "frontier": frontier0}, {}

    def round_fn(rt, e, st, sc, it):
        dist = st["dist"]
        active = st["frontier"][e.my_src] & e.valid
        dist2, _ = rt.wave(dist, e.dst, dist[e.my_src] + 1, active, op="min")
        changed = dist2 != dist
        return {"dist": dist2, "frontier": changed}, sc, rt.any(changed)

    alg = AlgorithmSpec("bfs", "FF&MF", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds,
                          snapshot_rounds=snapshot_rounds,
                          fault_injector=fault_injector)
    dist = res.state["dist"][:g.num_vertices]
    return telemetry_return((dist, res.rounds), res, telemetry)


def distributed_multi_source_bfs(mesh, g: Graph, sources, *,
                                 capacity: int | str = 4096,
                                 m: int | None = None, axis: str = "data",
                                 spec: C.CommitSpec | None = None,
                                 max_subrounds: int = 64,
                                 telemetry: bool = False,
                                 snapshot_rounds: int | None = None,
                                 fault_injector=None):
    """Lane-batched BFS over a mesh axis: L queries share every wave.

    Vertex state is vertex-major [vpad * L] (all lanes of a vertex live on
    its owner shard), lane ids ride the coalescing buckets as one more
    payload field, and owners commit on composite local keys — the
    distributed mirror of :func:`multi_source_bfs`.  Returns
    (dist [L, V], rounds); ``telemetry=True`` appends the
    DistributedResult: (dist, rounds, res).  ``snapshot_rounds``/
    ``fault_injector`` enable degraded-mesh mode (the vertex-major
    [vpad*L] state is not vpad-shaped, so a shrink restarts the query
    from round 0 on the surviving mesh rather than replaying)."""
    from repro.core.coalescing import QueryLanes
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)

    sources = jnp.asarray(sources, jnp.int32)
    lanes = sources.shape[0]
    lidx = jnp.arange(lanes, dtype=jnp.int32)

    def init(g, layout):
        flat = sources * lanes + lidx           # vertex-major composite
        dist0 = jnp.full((layout.vpad * lanes,), INF, jnp.int32) \
            .at[flat].set(0)
        frontier0 = jnp.zeros((layout.vpad * lanes,), bool) \
            .at[flat].set(True)
        return {"dist": dist0, "frontier": frontier0}, {}

    def round_fn(rt, e, st, sc, it):
        dist = st["dist"]                       # [block * L]
        emax = e.dst.shape[0]
        fl = e.my_src[:, None] * lanes + lidx[None, :]      # [emax, L]
        active = st["frontier"][fl] & e.valid[:, None]
        tgt = jnp.broadcast_to(e.dst[:, None], (emax, lanes))
        lane = jnp.broadcast_to(lidx[None, :], (emax, lanes))
        dist2, _ = rt.wave(dist, tgt.reshape(-1),
                           (dist[fl] + 1).reshape(-1),
                           active.reshape(-1), op="min",
                           major=lane.reshape(-1))
        changed = dist2 != dist
        return {"dist": dist2, "frontier": changed}, sc, rt.any(changed)

    alg = AlgorithmSpec("multi_bfs", "FF&MF", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds,
                          batch=QueryLanes(lanes, g.num_vertices),
                          snapshot_rounds=snapshot_rounds,
                          fault_injector=fault_injector)
    dist = res.state["dist"].reshape(-1, lanes).T[:, :g.num_vertices]
    return telemetry_return((dist, res.rounds), res, telemetry)


def distributed_product_bfs(mesh, gs, sources, *,
                            capacity: int | str = 4096,
                            m: int | None = None, axis: str = "data",
                            spec: C.CommitSpec | None = None,
                            max_subrounds: int = 64,
                            telemetry: bool = False):
    """Product-axis BFS over a mesh axis: L queries over EACH graph of a
    :class:`repro.graphs.csr.GraphSet` share every wave — the
    distributed proof that :class:`repro.core.coalescing.ProductAxis`
    threads through the harness unchanged.

    ``sources`` is int32 [L, G], graph-LOCAL source ids (cell (l, g)
    answers BFS from ``sources[l, g]`` in graph g).  State is
    vertex-major [vpad * L] over the UNION — the graph coordinate is
    pre-folded into the union vertex id, so each union vertex's L lanes
    live on its owner shard and the lane id rides the exchange as
    ``major`` exactly as in :func:`distributed_multi_source_bfs`; only
    ``batch=ProductAxis(L, sizes)`` (race width L·G) differs.  Returns
    (dist [L, Vtot], rounds), ``telemetry=True`` appending the
    DistributedResult; split per graph with
    ``gs.split_vertex(dist[l])``."""
    from repro.core.coalescing import ProductAxis
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)

    sources = jnp.asarray(sources, jnp.int32)
    lanes = sources.shape[0]
    lidx = jnp.arange(lanes, dtype=jnp.int32)
    product = ProductAxis(lanes, gs.axis.sizes)
    # per-cell union-flat source ids [L, G]
    flat_src = sources + jnp.asarray(gs.voffs[:-1], jnp.int32)[None, :]

    def init(g, layout):
        flat = flat_src * lanes + lidx[:, None]  # vertex-major composite
        dist0 = jnp.full((layout.vpad * lanes,), INF, jnp.int32) \
            .at[flat.reshape(-1)].set(0)
        frontier0 = jnp.zeros((layout.vpad * lanes,), bool) \
            .at[flat.reshape(-1)].set(True)
        return {"dist": dist0, "frontier": frontier0}, {}

    def round_fn(rt, e, st, sc, it):
        dist = st["dist"]                       # [block * L]
        emax = e.dst.shape[0]
        fl = e.my_src[:, None] * lanes + lidx[None, :]      # [emax, L]
        active = st["frontier"][fl] & e.valid[:, None]
        tgt = jnp.broadcast_to(e.dst[:, None], (emax, lanes))
        lane = jnp.broadcast_to(lidx[None, :], (emax, lanes))
        dist2, _ = rt.wave(dist, tgt.reshape(-1),
                           (dist[fl] + 1).reshape(-1),
                           active.reshape(-1), op="min",
                           major=lane.reshape(-1))
        changed = dist2 != dist
        return {"dist": dist2, "frontier": changed}, sc, rt.any(changed)

    alg = AlgorithmSpec("product_bfs", "FF&MF", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, gs, capacity=capacity, m=m,
                          axis=axis, spec=spec,
                          max_subrounds=max_subrounds, batch=product)
    dist = res.state["dist"].reshape(-1, lanes).T[:, :product.num_vertices]
    return telemetry_return((dist, res.rounds), res, telemetry)


def batched_over_graphs_bfs(gs, sources, *, spec: C.CommitSpec | None = None,
                            mesh=None, capacity: int | str = 4096,
                            axis: str = "data", max_subrounds: int = 64):
    """G independent BFS queries, one per tenant graph, as ONE AAM wave
    over the :class:`repro.graphs.csr.GraphSet` union (the *graph*
    batch axis — flat keys ``offset[g] + v``, see
    ``repro.core.coalescing.GraphBatch``).

    ``sources[g]`` is graph g's LOCAL source id.  Returns a list of
    per-graph distance rows, each bit-identical to
    ``bfs(gs.graphs[g], sources[g])`` on every backend including
    ``auto``: graphs exchange no messages in the union and occupy
    disjoint commit-key ranges, so the fused run IS the looped runs.
    ``mesh=`` executes through ``run_distributed`` (the union's flat
    ids key the owner slices and coalescing buckets directly)."""
    flat = gs.flat_vertices(sources)
    if mesh is not None:
        # run_distributed resolves the GraphSet itself: union edges,
        # batch=gs.axis (the tuner's axis-width key)
        dist, _ = distributed_bfs(mesh, gs, flat, spec=spec,
                                  capacity=capacity, axis=axis,
                                  max_subrounds=max_subrounds)
    else:
        dist = bfs(gs.union(), flat, spec=spec).dist
    return gs.split_vertex(dist)


def bfs_reference(g: Graph, source: int):
    """Pure-python BFS oracle (tests)."""
    import collections
    import numpy as np
    indptr = np.asarray(g.indptr)
    dst = np.asarray(g.dst)
    dist = np.full(g.num_vertices, 2 ** 30, np.int64)
    dist[source] = 0
    q = collections.deque([source])
    while q:
        u = q.popleft()
        for e in range(indptr[u], indptr[u + 1]):
            w_ = dst[e]
            if dist[w_] > dist[u] + 1:
                dist[w_] = dist[u] + 1
                q.append(w_)
    return dist
