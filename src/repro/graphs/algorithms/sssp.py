"""SSSP (Bellman-Ford label-correcting) — FF&MF messages, weighted ``min``
commit.  Same AAM structure as BFS with ``dist[src] + w`` payloads."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.messages import lane_messages, make_messages
from repro.graphs.csr import Graph

INF = jnp.float32(3.0e38)


@partial(jax.jit, static_argnames=("commit", "m", "sort", "spec"))
def sssp(g: Graph, source, *, commit: str = "coarse", m: int | None = None,
         sort: bool = True, spec: C.CommitSpec | None = None):
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    dist0 = jnp.full((v,), INF, jnp.float32).at[source].set(0.0)
    frontier0 = jnp.zeros((v,), bool).at[source].set(True)
    step, lvl0 = AT.make_commit_step(spec, "min", dist0,
                                     n=g.src.shape[0])

    def cond(state):
        _, frontier, it, _ = state
        return jnp.any(frontier) & (it < v)

    def body(state):
        dist, frontier, it, lvl = state
        active = frontier[g.src]
        msgs = make_messages(g.dst, dist[g.src] + g.weights, active)
        res, lvl = step(dist, msgs, lvl)
        return res.state, res.state != dist, it + 1, lvl

    dist, _, rounds, _ = jax.lax.while_loop(
        cond, body, (dist0, frontier0, jnp.zeros((), jnp.int32), lvl0))
    return dist, rounds


@partial(jax.jit, static_argnames=("commit", "m", "sort", "spec"))
def multi_source_sssp(g: Graph, sources, *, commit: str = "coarse",
                      m: int | None = None, sort: bool = True,
                      spec: C.CommitSpec | None = None):
    """L independent SSSP roots as lanes of one fused wave.

    Returns (dist [L, V], rounds); row l is bit-identical to
    ``sssp(g, sources[l])`` — f32 ``min`` over the same relaxation
    multiset is order-independent, so the composite-key commit
    (``lane * V + v``) changes nothing per lane."""
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    sources = jnp.asarray(sources, jnp.int32)
    lanes = sources.shape[0]
    lidx = jnp.arange(lanes, dtype=jnp.int32)
    dist0 = jnp.full((lanes, v), INF, jnp.float32) \
        .at[lidx, sources].set(0.0)
    frontier0 = jnp.zeros((lanes, v), bool).at[lidx, sources].set(True)
    e = g.src.shape[0]
    dst_l = jnp.broadcast_to(g.dst, (lanes, e))
    step, lvl0 = AT.make_commit_step(spec, "min", dist0.reshape(-1),
                                     n=lanes * e, axis_width=lanes)

    def cond(state):
        _, frontier, it, _ = state
        return jnp.any(frontier) & (it < v)

    def body(state):
        dist, frontier, it, lvl = state
        active = frontier[:, g.src]
        msgs = lane_messages(dst_l, dist[:, g.src] + g.weights[None, :],
                             active, v)
        res, lvl = step(dist.reshape(-1), msgs, lvl)
        dist2 = res.state.reshape(lanes, v)
        return dist2, dist2 != dist, it + 1, lvl

    dist, _, rounds, _ = jax.lax.while_loop(
        cond, body, (dist0, frontier0, jnp.zeros((), jnp.int32), lvl0))
    return dist, rounds


def distributed_sssp(mesh, g: Graph, source: int, *,
                     capacity: int | str = 4096,
                     m: int | None = None, axis: str = "data",
                     spec: C.CommitSpec | None = None,
                     max_subrounds: int = 64, telemetry: bool = False):
    """Bellman-Ford SSSP on the shared harness — FF&MF waves whose f32
    relaxation payloads ride next to the i32 targets in the same coalescing
    buckets.  Returns (dist [V], rounds); ``telemetry=True`` appends
    the DistributedResult: (dist, rounds, res) — see
    :func:`repro.core.engine.telemetry_return`."""
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)

    def init(g, layout):
        dist0 = jnp.full((layout.vpad,), INF, jnp.float32).at[source].set(0.0)
        frontier0 = jnp.zeros((layout.vpad,), bool).at[source].set(True)
        return {"dist": dist0, "frontier": frontier0}, {}

    def round_fn(rt, e, st, sc, it):
        dist = st["dist"]
        active = st["frontier"][e.my_src] & e.valid
        dist2, _ = rt.wave(dist, e.dst, dist[e.my_src] + e.weight, active,
                           op="min")
        changed = dist2 != dist
        return {"dist": dist2, "frontier": changed}, sc, rt.any(changed)

    alg = AlgorithmSpec("sssp", "FF&MF", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds)
    dist = res.state["dist"][:g.num_vertices]
    return telemetry_return((dist, res.rounds), res, telemetry)


def distributed_multi_source_sssp(mesh, g: Graph, sources, *,
                                  capacity: int | str = 4096,
                                  m: int | None = None, axis: str = "data",
                                  spec: C.CommitSpec | None = None,
                                  max_subrounds: int = 64,
                                  telemetry: bool = False):
    """Lane-batched Bellman-Ford over a mesh axis (vertex-major
    [vpad * L] state, lane ids riding the coalescing buckets) — the
    distributed mirror of :func:`multi_source_sssp`.  Returns
    (dist [L, V], rounds); ``telemetry=True`` appends the
    DistributedResult: (dist, rounds, res)."""
    from repro.core.coalescing import QueryLanes
    from repro.core.engine import (AlgorithmSpec, run_distributed,
                                   telemetry_return)

    sources = jnp.asarray(sources, jnp.int32)
    lanes = sources.shape[0]
    lidx = jnp.arange(lanes, dtype=jnp.int32)

    def init(g, layout):
        flat = sources * lanes + lidx
        dist0 = jnp.full((layout.vpad * lanes,), INF, jnp.float32) \
            .at[flat].set(0.0)
        frontier0 = jnp.zeros((layout.vpad * lanes,), bool) \
            .at[flat].set(True)
        return {"dist": dist0, "frontier": frontier0}, {}

    def round_fn(rt, e, st, sc, it):
        dist = st["dist"]
        emax = e.dst.shape[0]
        fl = e.my_src[:, None] * lanes + lidx[None, :]
        active = st["frontier"][fl] & e.valid[:, None]
        tgt = jnp.broadcast_to(e.dst[:, None], (emax, lanes))
        lane = jnp.broadcast_to(lidx[None, :], (emax, lanes))
        dist2, _ = rt.wave(dist, tgt.reshape(-1),
                           (dist[fl] + e.weight[:, None]).reshape(-1),
                           active.reshape(-1), op="min",
                           major=lane.reshape(-1))
        changed = dist2 != dist
        return {"dist": dist2, "frontier": changed}, sc, rt.any(changed)

    alg = AlgorithmSpec("multi_sssp", "FF&MF", init, round_fn,
                        lambda g, layout: layout.vpad)
    res = run_distributed(alg, mesh, g, capacity=capacity, m=m, axis=axis,
                          spec=spec, max_subrounds=max_subrounds,
                          batch=QueryLanes(lanes, g.num_vertices))
    dist = res.state["dist"].reshape(-1, lanes).T[:, :g.num_vertices]
    return telemetry_return((dist, res.rounds), res, telemetry)


def batched_over_graphs_sssp(gs, sources, *,
                             spec: C.CommitSpec | None = None,
                             mesh=None, capacity: int | str = 4096,
                             axis: str = "data", max_subrounds: int = 64):
    """G independent SSSP queries, one per tenant graph, fused on the
    graph batch axis (disjoint-union flat keys — see
    :func:`repro.graphs.algorithms.bfs.batched_over_graphs_bfs`).
    ``sources[g]`` is graph g's LOCAL root.  Returns per-graph f32
    distance rows, bit-identical to ``sssp(gs.graphs[g], sources[g])``
    on every backend (f32 ``min`` over the same relaxation multiset is
    order-independent)."""
    flat = gs.flat_vertices(sources)
    if mesh is not None:
        dist, _ = distributed_sssp(mesh, gs, flat, spec=spec,
                                   capacity=capacity, axis=axis,
                                   max_subrounds=max_subrounds)
    else:
        dist, _ = sssp(gs.union(), flat, spec=spec)
    return gs.split_vertex(dist)


def sssp_reference(g: Graph, source: int):
    import heapq
    import numpy as np
    indptr = np.asarray(g.indptr)
    dst = np.asarray(g.dst)
    w = np.asarray(g.weights)
    dist = np.full(g.num_vertices, np.inf)
    dist[source] = 0.0
    pq = [(0.0, source)]
    while pq:
        du, u = heapq.heappop(pq)
        if du > dist[u]:
            continue
        for e in range(indptr[u], indptr[u + 1]):
            nd = du + w[e]
            if nd < dist[dst[e]]:
                dist[dst[e]] = nd
                heapq.heappush(pq, (nd, int(dst[e])))
    return dist
