"""SSSP (Bellman-Ford label-correcting) — FF&MF messages, weighted ``min``
commit.  Same AAM structure as BFS with ``dist[src] + w`` payloads."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import commit as C
from repro.core.messages import make_messages
from repro.graphs.csr import Graph

INF = jnp.float32(3.0e38)


@partial(jax.jit, static_argnames=("commit", "m", "sort", "spec"))
def sssp(g: Graph, source, *, commit: str = "coarse", m: int | None = None,
         sort: bool = True, spec: C.CommitSpec | None = None):
    if spec is None:
        spec = C.CommitSpec(backend=commit, m=m, sort=sort, stats=False)
    v = g.num_vertices
    dist0 = jnp.full((v,), INF, jnp.float32).at[source].set(0.0)
    frontier0 = jnp.zeros((v,), bool).at[source].set(True)
    cfn = lambda st, msgs: C.commit(st, msgs, "min", spec)

    def cond(state):
        _, frontier, it = state
        return jnp.any(frontier) & (it < v)

    def body(state):
        dist, frontier, it = state
        active = frontier[g.src]
        msgs = make_messages(g.dst, dist[g.src] + g.weights, active)
        res = cfn(dist, msgs)
        return res.state, res.state != dist, it + 1

    dist, _, rounds = jax.lax.while_loop(
        cond, body, (dist0, frontier0, jnp.zeros((), jnp.int32)))
    return dist, rounds


def sssp_reference(g: Graph, source: int):
    import heapq
    import numpy as np
    indptr = np.asarray(g.indptr)
    dst = np.asarray(g.dst)
    w = np.asarray(g.weights)
    dist = np.full(g.num_vertices, np.inf)
    dist[source] = 0.0
    pq = [(0.0, source)]
    while pq:
        du, u = heapq.heappop(pq)
        if du > dist[u]:
            continue
        for e in range(indptr[u], indptr[u + 1]):
            nd = du + w[e]
            if nd < dist[dst[e]]:
                dist[dst[e]] = nd
                heapq.heappush(pq, (nd, int(dst[e])))
    return dist
