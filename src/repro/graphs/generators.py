"""Graph generators: Kronecker (Graph500), Erdős–Rényi, and structural
analogues of the paper's Table-1 SNAP families (offline container — see
DESIGN.md §7: degree-distribution + diameter-regime matched synthetics).
"""
from __future__ import annotations

import numpy as np

from repro.graphs.csr import Graph, from_edges


def kronecker(scale: int, edge_factor: int = 16, seed: int = 0,
              a=0.57, b=0.19, c=0.19) -> Graph:
    """Graph500 Kronecker generator (power-law degree distribution)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = edge_factor * n
    src = np.zeros(m, np.int64)
    dst = np.zeros(m, np.int64)
    ab, cn = a + b, a + b + c
    for _ in range(scale):
        r = rng.random(m)
        ii = (r >= ab).astype(np.int64)             # bottom half
        r2 = rng.random(m)
        jj = np.where(ii == 1, (r2 >= c / (1 - ab)).astype(np.int64),
                      (r2 >= a / ab).astype(np.int64))
        src = 2 * src + ii
        dst = 2 * dst + jj
    perm = rng.permutation(n)                       # relabel
    src, dst = perm[src], perm[dst]
    return from_edges(src, dst, n, symmetrize=True)


def erdos_renyi(n: int, avg_degree: float = 8.0, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree / 2)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    return from_edges(src, dst, n, symmetrize=True)


def grid2d(side: int) -> Graph:
    """Road-network analogue: 2-D grid (large diameter, degree <= 4)."""
    idx = np.arange(side * side).reshape(side, side)
    s1, d1 = idx[:, :-1].ravel(), idx[:, 1:].ravel()
    s2, d2 = idx[:-1, :].ravel(), idx[1:, :].ravel()
    src = np.concatenate([s1, s2])
    dst = np.concatenate([d1, d2])
    return from_edges(src, dst, side * side, symmetrize=True)


def preferential(n: int, m_per: int = 4, seed: int = 0) -> Graph:
    """Social-network analogue: Barabási–Albert preferential attachment."""
    rng = np.random.default_rng(seed)
    targets = list(range(m_per))
    repeated: list[int] = []
    src_l, dst_l = [], []
    for v in range(m_per, n):
        ts = rng.choice(targets if len(repeated) == 0 else repeated,
                        size=m_per)
        for t in ts:
            src_l.append(v)
            dst_l.append(int(t))
        repeated.extend(ts.tolist())
        repeated.extend([v] * m_per)
        targets.append(v)
    return from_edges(np.array(src_l), np.array(dst_l), n, symmetrize=True)


def bipartite_web(n: int, hubs: int = 32, avg_degree: float = 6.0,
                  seed: int = 0) -> Graph:
    """Web-graph analogue: hub-dominated structure (few very high degree
    vertices + sparse tail)."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_degree)
    hub_ids = rng.integers(0, hubs, m)
    src = rng.integers(0, n, m)
    dst = np.where(rng.random(m) < 0.7, hub_ids, rng.integers(0, n, m))
    return from_edges(src, dst, n, symmetrize=True)


def random_weights(g: Graph, seed: int = 0, low=0.1, high=10.0) -> Graph:
    """Attach symmetric random weights (for SSSP / Boruvka)."""
    import dataclasses as dc
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    src = np.asarray(g.src)
    dst = np.asarray(g.dst)
    lo = np.minimum(src, dst).astype(np.int64)
    hi = np.maximum(src, dst).astype(np.int64)
    key = lo * g.num_vertices + hi
    # same weight for both directions of an undirected edge
    h = (np.abs(np.sin(key * 12.9898 + seed)) * (high - low) + low)
    return dc.replace(g, weights=jnp.asarray(h.astype(np.float32)))


# Table-1 family registry (paper §6.1.2): structurally-matched synthetics.
TABLE1_FAMILIES = {
    "cWT-comm": lambda n, seed=0: bipartite_web(n, hubs=max(8, n // 1000),
                                                avg_degree=4, seed=seed),
    "sLV-social": lambda n, seed=0: kronecker(
        max(int(np.log2(max(n, 2))), 4), 14, seed=seed),
    "sYT-social": lambda n, seed=0: preferential(n, 3, seed=seed),
    "pAM-purchase": lambda n, seed=0: preferential(n, 8, seed=seed),
    "rCA-road": lambda n, seed=0: grid2d(int(np.sqrt(n))),
    "ciP-citation": lambda n, seed=0: erdos_renyi(n, 8.0, seed=seed),
    "wGL-web": lambda n, seed=0: bipartite_web(n, hubs=max(8, n // 500),
                                               avg_degree=12, seed=seed),
}
