"""Explicit expert-parallel MoE dispatch under shard_map (§Perf iteration 2).

The SPMD-auto AAM path (moe_layer.moe_apply_aam) leaves the token→expert
reshard to XLA, which gives up on the scatter and FULLY REPLICATES the
dispatch buffers ("involuntary full rematerialization" warnings) — measured
at ~85% of train-step wire bytes on the MoE cells.

This module is the paper-faithful fix: the owner-routing is EXPLICIT, like
an AAM coalescing round.  Tokens stay sharded over ('pod','data'); experts
are owned by 'model' shards.  Each device already holds its token slice
(activations are replicated over 'model'), so dispatch needs NO token
traffic at all: every (data, model) device locally selects the tokens bound
for its experts (bucket plan = the coalescing planner), runs them, and one
psum over 'model' combines the partial outputs — the FF&AS commit.  Expert
weights FSDP-sharded over 'data' are all-gathered once per layer
(unavoidable under FSDP; hoisted out of remat by XLA).

Collective bytes per layer pass drop from O(T·d·E-replication) to
O(T_local·d) psum + O(layer weights/16) gather — measured in
EXPERIMENTS.md §Perf (≈50x less wire on qwen3-moe train_4k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core.coalescing import plan_buckets_sorted, scatter_to_buckets
from repro.moe.moe_layer import _capacity, _route, aux_loss
from repro.runtime import sharding as shd


def _data_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def moe_apply_shmap(cfg: ModelConfig, p, x2d):
    """x2d: [T, d] (T sharded over pod/data; replicated over model)."""
    mesh = shd.get_abstract_mesh()
    if mesh is None or "model" not in mesh.shape:
        from repro.moe.moe_layer import moe_apply_aam
        return moe_apply_aam(cfg, p, x2d)
    daxes = _data_axes(mesh)
    n_model = mesh.shape["model"]
    n_data = 1
    for a in daxes:
        n_data *= mesh.shape[a]
    e, k = cfg.num_experts, cfg.experts_per_token
    e_local = e // n_model
    t_local = x2d.shape[0] // n_data
    cap = _capacity(cfg, t_local)
    has_gate = "wi_gate" in p

    # weights are FSDP-sharded over "data" only (never over "pod");
    # tokens are sharded over all data axes (pod + data).
    wg_axes = tuple(a for a in ("data",) if a in mesh.shape)

    def inner(router, wi, wi_gate, wo, x):
        j = jax.lax.axis_index("model")
        # assemble full expert weights for the local experts (FSDP gather)
        router = jax.lax.all_gather(router, "model", axis=1, tiled=True)
        for a in wg_axes:
            router = jax.lax.all_gather(router, a, axis=0, tiled=True)
            wi = jax.lax.all_gather(wi, a, axis=1, tiled=True)
            if wi_gate is not None:
                wi_gate = jax.lax.all_gather(wi_gate, a, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, a, axis=2, tiled=True)
        cd = x.dtype
        logits = (x @ router.astype(cd)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        w, experts = jax.lax.top_k(probs, k)
        w = (w / jnp.sum(w, axis=-1, keepdims=True)).astype(cd)
        experts = experts.astype(jnp.int32)

        # local-owner selection: this shard owns experts [j*e_local, ...)
        owner = experts.reshape(-1) - j * e_local          # [T_local*k]
        token = jnp.repeat(jnp.arange(t_local, dtype=jnp.int32), k)
        mine = (owner >= 0) & (owner < e_local)
        plan, _ = plan_buckets_sorted(jnp.clip(owner, 0, e_local - 1),
                                      mine, e_local, cap)
        xb = scatter_to_buckets(plan, x[token], e_local, cap, fill=0)

        h = jnp.einsum("ecd,edf->ecf", xb, wi.astype(cd))
        if wi_gate is not None:
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xb,
                                       wi_gate.astype(cd))) * h
        else:
            h = jax.nn.gelu(h)
        yb = jnp.einsum("ecf,efd->ecd", h, wo.astype(cd))

        # FR return: tokens gather their local-expert outputs; psum over
        # 'model' completes the FF&AS combine across expert owners.
        pos = plan.position.reshape(t_local, k)
        kept = plan.kept.reshape(t_local, k)
        eloc = jnp.clip(experts - j * e_local, 0, e_local - 1)
        flat = eloc * cap + jnp.clip(pos, 0, cap - 1)
        y = yb.reshape(e_local * cap, -1)[flat]            # [T_local, k, d]
        wk = jnp.where(kept, w, 0.0)
        out = jnp.einsum("tkd,tk->td", y, wk)
        out = jax.lax.psum(out, "model")
        dropped = jax.lax.psum(plan.dropped, ("model",) + tuple(daxes))
        aux = jax.lax.pmean(aux_loss(cfg, probs, experts),
                            ("model",) + tuple(daxes))
        return out, dropped, aux

    dspec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    if has_gate:
        fn = compat.shard_map(
            inner, mesh=mesh,
            in_specs=(P("data", "model"),             # router [d, E]
                      P("model", "data", None),       # wi [E, d, f]
                      P("model", "data", None),       # wi_gate
                      P("model", None, "data"),       # wo [E, f, d]
                      P(dspec, None)),                # x [T, d]
            out_specs=(P(dspec, None), P(), P()),
            check_vma=False)
        out, dropped, aux = fn(p["router"], p["wi"], p["wi_gate"], p["wo"],
                               x2d)
    else:
        def inner4(router, wi, wo, x):
            return inner(router, wi, None, wo, x)
        fn = compat.shard_map(
            inner4, mesh=mesh,
            in_specs=(P("data", "model"), P("model", "data", None),
                      P("model", None, "data"), P(dspec, None)),
            out_specs=(P(dspec, None), P(), P()),
            check_vma=False)
        out, dropped, aux = fn(p["router"], p["wi"], p["wo"], x2d)
    return out, {"moe_dropped": dropped, "moe_aux": aux}
