"""Mixture-of-Experts on Atomic Active Messages (DESIGN.md §3).

A token routed to an expert is an FF&AS atomic active message: target =
expert (owner shard under expert parallelism), payload = activation, handler
= expert MLP, combine = weighted-accumulate commit.  Two dispatch paths:

* ``aam``   — sort/bucket tokens per expert with the coalescing planner
  (:func:`repro.core.coalescing.plan_buckets_sorted`) into a fixed
  ``[E, C, d]`` buffer; the buffer is the coalesced message payload, C is
  the coalescing factor.  The combine gathers each token's top-k results —
  the FR return path.  This is the framework default.
* ``dense`` — GShard-style one-hot einsum dispatch; the fine-grained
  baseline (kept small-scale: used by tests as the oracle and by the
  dispatch benchmark as the comparison point).

Both paths drop over-capacity tokens with identical (arrival-order)
priority, so they agree exactly — property-tested.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.coalescing import plan_buckets_sorted, scatter_to_buckets
from repro.models.layers import dense_init
from repro.runtime import sharding as shd


def moe_init(cfg: ModelConfig, key, dtype=jnp.float32):
    d, e, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["router"], a["router"] = dense_init(ks[0], (d, e), ("embed", "experts"), dtype)
    if cfg.mlp_gated:
        p["wi_gate"], a["wi_gate"] = dense_init(
            ks[1], (e, d, ff), ("experts", "embed", "mlp"), dtype)
    p["wi"], a["wi"] = dense_init(ks[2], (e, d, ff), ("experts", "embed", "mlp"), dtype)
    p["wo"], a["wo"] = dense_init(ks[3], (e, ff, d), ("experts", "mlp", "embed"), dtype)
    return p, a


def _route(cfg: ModelConfig, p, x):
    """x: [T, d] -> (weights [T, k], experts [T, k], router probs [T, E])."""
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, e = jax.lax.top_k(probs, cfg.experts_per_token)
    w = w / jnp.sum(w, axis=-1, keepdims=True)          # renormalize top-k
    return w, e.astype(jnp.int32), probs


def _expert_ffn(cfg: ModelConfig, p, xb):
    """xb: [E, C, d] -> [E, C, d] through each expert's MLP."""
    h = jnp.einsum("ecd,edf->ecf", xb, p["wi"].astype(xb.dtype))
    if cfg.mlp_gated:
        g = jnp.einsum("ecd,edf->ecf", xb, p["wi_gate"].astype(xb.dtype))
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(xb.dtype))


def _capacity(cfg: ModelConfig, t: int, dropless: bool = False) -> int:
    if dropless:
        # inference: every assignment fits even if all tokens pick one
        # expert, so stepwise decode reproduces the batched forward
        c = t * cfg.experts_per_token
    else:
        c = int(t * cfg.experts_per_token * cfg.capacity_factor
                / cfg.num_experts)
    return max(8, -(-c // 8) * 8)   # round up to 8 lanes


def aux_loss(cfg: ModelConfig, probs, experts):
    """Switch-style load-balancing loss."""
    e = cfg.num_experts
    me = jnp.mean(probs, axis=0)                               # [E]
    assign = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
    fe = jnp.mean(assign, axis=0)
    return e * jnp.sum(me * fe)


def moe_apply_aam(cfg: ModelConfig, p, x, mode: str = "train"):
    """AAM dispatch. x: [T, d] -> (y [T, d], aux metrics dict)."""
    t, d = x.shape
    k, e = cfg.experts_per_token, cfg.num_experts
    cap = _capacity(cfg, t, dropless=mode != "train")
    w, experts, probs = _route(cfg, p, x)

    # flatten T×k assignments into one message batch
    owner = experts.reshape(-1)                                # [T*k]
    token = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)      # [T*k]
    valid = jnp.ones((t * k,), bool)
    plan, _ = plan_buckets_sorted(owner, valid, e, cap)

    # coalesced payload: [E, C, d] activation buffer
    xb = scatter_to_buckets(plan, x[token], e, cap, fill=0)
    xb = shd.logical_constraint(shd.ShardingRules(shd.TRAIN_RULES), xb,
                                ("experts", "expert_capacity", None))
    yb = _expert_ffn(cfg, p, xb)

    # FR return path: each token gathers its k expert outputs
    pos = plan.position.reshape(t, k)
    kept = plan.kept.reshape(t, k)
    flat = experts * cap + jnp.clip(pos, 0, cap - 1)           # [T, k]
    y = yb.reshape(e * cap, d)[flat]                           # [T, k, d]
    wk = jnp.where(kept, w, 0.0).astype(x.dtype)
    out = jnp.einsum("tkd,tk->td", y, wk)
    metrics = {
        "moe_dropped": plan.dropped,
        "moe_aux": aux_loss(cfg, probs, experts),
    }
    return out, metrics


def moe_apply_dense(cfg: ModelConfig, p, x, mode: str = "train"):
    """GShard one-hot dispatch baseline (oracle for tests/benchmarks)."""
    t, d = x.shape
    k, e = cfg.experts_per_token, cfg.num_experts
    cap = _capacity(cfg, t, dropless=mode != "train")
    w, experts, probs = _route(cfg, p, x)

    onehot = jax.nn.one_hot(experts, e, dtype=jnp.int32)       # [T, k, E]
    kth = jnp.sum(onehot, axis=1)                              # [T, E] (0/1)
    pos = jnp.cumsum(kth, axis=0) - kth                        # [T, E] rank
    pos_k = jnp.sum(onehot * pos[:, None, :], axis=-1)         # [T, k]
    keep_k = pos_k < cap                                       # [T, k]
    poh = jax.nn.one_hot(jnp.where(keep_k, pos_k, cap), cap,
                         dtype=x.dtype)                        # [T, k, C]
    dmat = jnp.einsum("tke,tkc->tec", onehot.astype(x.dtype), poh)
    xb = jnp.einsum("td,tec->ecd", x, dmat)
    yb = _expert_ffn(cfg, p, xb)
    wmat = jnp.einsum("tk,tke,tkc->tec", w.astype(x.dtype),
                      onehot.astype(x.dtype), poh)
    out = jnp.einsum("ecd,tec->td", yb, wmat)
    dropped = (t * k - jnp.sum(keep_k)).astype(jnp.int32)
    metrics = {"moe_dropped": dropped,
               "moe_aux": aux_loss(cfg, probs, experts)}
    return out, metrics


def moe_apply(cfg: ModelConfig, p, x2d, impl: str = "aam",
              mode: str = "train"):
    """Capacity dropping is a train-time throughput tradeoff; inference
    modes (prefill/decode) are dropless so a stepwise decode reproduces
    the batched forward exactly (the shmap path is train-only)."""
    if impl == "dense":
        return moe_apply_dense(cfg, p, x2d, mode=mode)
    if impl == "aam_shmap":
        if mode != "train":
            # shmap buffers are sized for train capacity; inference must
            # be dropless, so serve through the SPMD-auto path
            return moe_apply_aam(cfg, p, x2d, mode=mode)
        from repro.moe.shmap_moe import moe_apply_shmap
        return moe_apply_shmap(cfg, p, x2d)
    return moe_apply_aam(cfg, p, x2d, mode=mode)
