"""Distributed AAM engine — shard_map execution of atomic active messages.

Vertices are 1-D partitioned into contiguous owner ranges (paper §3.1); each
shard holds its vertex state slice and the edges whose source it owns.  One
*wave* = route all pending messages to their owners and commit:

  1. bucket messages per destination shard (coalescing, capacity C);
  2. one ``all_to_all`` exchanges the coalesced [P, C] buffers;
  3. owners run the coarse commit (transactions of size M);
  4. (FR) success flags return to spawners by the reverse ``all_to_all``.

Messages beyond C stay *pending* and go in the next sub-round — the
coalescing factor literally is the paper's C: fewer, larger network
messages, amortized per-message overhead (§5.6).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro import compat

from repro.core import commit as C
from repro.core.coalescing import (BucketPlan, gather_from_buckets,
                                   plan_buckets_sorted, scatter_to_buckets)
from repro.core.messages import make_messages


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_shards: int
    block: int              # vertices per shard
    capacity: int           # coalescing factor C (messages per dest/round)
    axis: str = "data"
    m: int | None = None    # transaction size (None = whole batch)
    op: str = "min"
    spec: C.CommitSpec | None = None   # commit backend; None = coarse(m)

    @property
    def commit_spec(self) -> C.CommitSpec:
        if self.spec is not None:
            return self.spec
        return C.CommitSpec(backend="coarse", m=self.m)


def route_wave(ecfg: EngineConfig, state_l, target, payload, pending):
    """One coalescing sub-round under shard_map.

    state_l: [block] local owner slice; target: [n] GLOBAL vertex ids;
    pending: [n] bool messages still to deliver.
    Returns (state_l, delivered_mask, success, conflicts)."""
    P, Cp = ecfg.num_shards, ecfg.capacity
    owner = target // ecfg.block
    plan, _ = plan_buckets_sorted(owner, pending, P, Cp)
    kept = plan.kept
    # sentinel -1 marks empty slots through the exchange
    buf_t = scatter_to_buckets(plan, jnp.where(kept, target, -1), P, Cp,
                               fill=-1)
    buf_p = scatter_to_buckets(plan, payload, P, Cp, fill=0)
    rt = jax.lax.all_to_all(buf_t, ecfg.axis, 0, 0, tiled=True)
    rp = jax.lax.all_to_all(buf_p, ecfg.axis, 0, 0, tiled=True)
    # local commit at the owner
    shard = jax.lax.axis_index(ecfg.axis)
    local_idx = rt.reshape(-1) - shard * ecfg.block
    valid = (rt.reshape(-1) >= 0)
    msgs = make_messages(jnp.clip(local_idx, 0, ecfg.block - 1),
                         rp.reshape(-1), valid)
    res = C.commit(state_l, msgs, ecfg.op, ecfg.commit_spec)
    # FR return path: success flags back to spawners
    back = jax.lax.all_to_all(res.success.reshape(P, Cp), ecfg.axis, 0, 0,
                              tiled=True)
    success = gather_from_buckets(back, plan, Cp, fill=False)
    return res.state, kept, success, res.conflicts


def wave_until_delivered(ecfg: EngineConfig, state_l, target, payload,
                         valid, max_subrounds: int = 64):
    """Deliver ALL messages (sub-rounds until nothing pending)."""
    n = target.shape[0]

    def cond(c):
        _, pending, *_ = c
        return (jax.lax.psum(jnp.sum(pending.astype(jnp.int32)), ecfg.axis)
                > 0) & (c[4] < max_subrounds)

    def body(c):
        state_l, pending, success, conflicts, it = c
        state_l, kept, succ, cf = route_wave(ecfg, state_l, target, payload,
                                             pending)
        success = jnp.where(kept, succ, success)
        return (state_l, pending & ~kept, success, conflicts + cf, it + 1)

    state_l, _, success, conflicts, subrounds = jax.lax.while_loop(
        cond, body, (state_l, valid, jnp.zeros((n,), bool),
                     jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))
    return state_l, success, conflicts, subrounds


def route_messages(ecfg: EngineConfig, target, payload, valid):
    """Route one sub-round of messages to owners WITHOUT committing —
    callers implement custom owner-side handlers (ownership protocol).

    Returns (local_idx [P*C], payload [P*C], rvalid [P*C], plan, kept)."""
    P, Cp = ecfg.num_shards, ecfg.capacity
    owner = target // ecfg.block
    plan, _ = plan_buckets_sorted(owner, valid, P, Cp)
    kept = plan.kept
    buf_t = scatter_to_buckets(plan, jnp.where(kept, target, -1), P, Cp,
                               fill=-1)
    buf_p = scatter_to_buckets(plan, payload, P, Cp, fill=0)
    rt = jax.lax.all_to_all(buf_t, ecfg.axis, 0, 0, tiled=True)
    rp = jax.lax.all_to_all(buf_p, ecfg.axis, 0, 0, tiled=True)
    shard = jax.lax.axis_index(ecfg.axis)
    local_idx = rt.reshape(-1) - shard * ecfg.block
    rvalid = rt.reshape(-1) >= 0
    return local_idx, rp.reshape(-1), rvalid, plan, kept


def return_to_spawners(ecfg: EngineConfig, reply, plan):
    """Reverse all_to_all of per-slot replies (FR return path)."""
    P, Cp = ecfg.num_shards, ecfg.capacity
    back = jax.lax.all_to_all(reply.reshape(P, Cp), ecfg.axis, 0, 0,
                              tiled=True)
    return gather_from_buckets(back, plan, Cp, fill=False)


# ---------------------------------------------------------------------------
# Distributed algorithms on the engine
# ---------------------------------------------------------------------------


def distributed_bfs(mesh, g, source: int, *, capacity: int = 4096,
                    m: int | None = None, axis: str = "data",
                    spec: C.CommitSpec | None = None):
    """BFS over a mesh axis. Returns (dist [P*block], rounds)."""
    from repro.graphs.csr import partition_edges
    P = mesh.shape[axis]
    (src, dst, w, val), part = partition_edges(g, P)
    block = part.block
    ecfg = EngineConfig(P, block, capacity, axis=axis, m=m, op="min",
                        spec=spec)
    INF = jnp.int32(2 ** 30)
    vpad = P * block
    dist0 = jnp.full((vpad,), INF, jnp.int32).at[source].set(0)

    def shard_fn(dist_l, src_l, dst_l, val_l):
        src_l, dst_l, val_l = src_l[0], dst_l[0], val_l[0]
        shard = jax.lax.axis_index(axis)
        my_src = src_l - shard * block

        def cond(c):
            _, frontier, it = c
            total = jax.lax.psum(jnp.sum(frontier.astype(jnp.int32)), axis)
            return (total > 0) & (it < vpad)

        def body(c):
            dist_l, frontier, it = c
            active = frontier[jnp.clip(my_src, 0, block - 1)] & val_l
            payload = dist_l[jnp.clip(my_src, 0, block - 1)] + 1
            new_dist, _, _, _ = wave_until_delivered(
                ecfg, dist_l, dst_l, payload, active)
            changed = new_dist != dist_l
            return new_dist, changed, it + 1

        frontier0 = dist_l != INF
        dist_l, _, rounds = jax.lax.while_loop(
            cond, body, (dist_l, frontier0, jnp.zeros((), jnp.int32)))
        return dist_l, rounds

    from jax.sharding import PartitionSpec as Ps
    fn = compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(Ps(axis), Ps(axis), Ps(axis), Ps(axis)),
        out_specs=(Ps(axis), Ps()),
        check_vma=False)
    dist, rounds = jax.jit(fn)(dist0, src, dst, val)
    return dist[:g.num_vertices], rounds


def distributed_pagerank(mesh, g, *, iters: int = 20, capacity: int = 4096,
                         m: int | None = None, axis: str = "data",
                         d: float = 0.85,
                         spec: C.CommitSpec | None = None):
    """PageRank over a mesh axis (FF&AS accumulate commits + coalescing)."""
    from repro.graphs.csr import partition_edges
    P = mesh.shape[axis]
    (src, dst, w, val), part = partition_edges(g, P)
    block = part.block
    ecfg = EngineConfig(P, block, capacity, axis=axis, m=m, op="add",
                        spec=spec)
    vpad = P * block
    v = g.num_vertices
    deg_full = jnp.zeros((vpad,), jnp.int32).at[:v].set(
        jnp.maximum(g.degrees, 1))
    dangling = jnp.zeros((vpad,), bool).at[:v].set(g.degrees == 0)
    realv = jnp.zeros((vpad,), bool).at[:v].set(True)

    def shard_fn(rank_l, deg_l, dang_l, real_l, src_l, dst_l, val_l):
        src_l, dst_l, val_l = src_l[0], dst_l[0], val_l[0]
        shard = jax.lax.axis_index(axis)
        my_src = jnp.clip(src_l - shard * block, 0, block - 1)

        def body(rank_l, _):
            contrib = d * rank_l[my_src] / deg_l[my_src].astype(jnp.float32)
            acc0 = jnp.zeros((block,), jnp.float32)
            acc, _, _, _ = wave_until_delivered(ecfg, acc0, dst_l, contrib,
                                                val_l)
            dm = jax.lax.psum(
                jnp.sum(jnp.where(dang_l, rank_l, 0.0)), axis)
            rank_l = jnp.where(real_l,
                               (1.0 - d) / v + acc + d * dm / v, 0.0)
            return rank_l, None

        rank_l, _ = jax.lax.scan(body, rank_l, None, length=iters)
        return rank_l

    from jax.sharding import PartitionSpec as Ps
    rank0 = jnp.where(realv, 1.0 / v, 0.0)
    fn = compat.shard_map(
        shard_fn, mesh=mesh,
        in_specs=(Ps(axis),) * 4 + (Ps(axis),) * 3,
        out_specs=Ps(axis), check_vma=False)
    rank = jax.jit(fn)(rank0, deg_full, dangling, realv, src, dst, val)
    return rank[:v]
