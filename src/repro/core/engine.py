"""Distributed AAM engine — shard_map execution of atomic active messages.

Vertices are 1-D partitioned into contiguous owner ranges (paper §3.1); each
shard holds its vertex state slice and the edges whose source it owns.  One
*wave* = route all pending messages to their owners and commit:

  1. bucket messages per destination shard (coalescing, capacity C);
  2. one ``all_to_all`` exchanges the coalesced [P, C] buffers;
  3. owners run the commit (transactions of size M, any backend);
  4. (FR) success flags return to spawners by the reverse ``all_to_all``.

Messages beyond C stay *pending* and go in the next sub-round — the
coalescing factor literally is the paper's C: fewer, larger network
messages, amortized per-message overhead (§5.6).

The public surface is the *harness*: :func:`run_distributed` executes an
:class:`AlgorithmSpec` — an ``init`` hook producing sharded state and a
``round_fn`` hook emitting one round of messages through a
:class:`WaveRuntime` — and owns partitioning, the round loop, the FR return
path, and conflict/sub-round telemetry.  All six paper case-studies
(`repro.graphs.algorithms`) are instances; ``distributed_bfs`` and
``distributed_pagerank`` re-export from their algorithm modules.

Payloads are *pytrees*: a routed message may carry several fields (e.g.
SSSP's f32 distances next to i32 targets, ST-connectivity's two frontier
bits) through one bucket plan and one exchange per field.

.. deprecated::
   Calling :func:`route_wave` directly is deprecated — it is a single
   sub-round with no requeue of coalescing overflow and no delivery
   guarantee.  Go through :func:`run_distributed` (algorithms) or
   :func:`wave_until_delivered` (custom protocols, e.g.
   `repro.core.ownership`) instead.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import compat

from repro.core import autotune as AT
from repro.core import commit as C
from repro.obs import trace as OT
from repro.core.coalescing import (BucketPlan, fuse_keys,
                                   gather_from_buckets, plan_buckets_sorted,
                                   require_key_space, scatter_to_buckets)
from repro.core.messages import make_messages


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    num_shards: int
    block: int              # vertices per shard
    capacity: int           # coalescing factor C (messages per dest/round)
    axis: str = "data"
    m: int | None = None    # transaction size (None = whole batch)
    op: str = "min"
    spec: C.CommitSpec | None = None   # commit backend; None = coarse(m)
    tuner: AT.TunerPolicy | None = None  # set by run_distributed for "auto"
    batch: Any = None       # default BatchAxis for waves (QueryLanes /
    #                         GraphBatch; None = unbatched targets)

    @property
    def commit_spec(self) -> C.CommitSpec:
        if self.spec is not None:
            return self.spec
        return C.CommitSpec(backend="coarse", m=self.m)

    def _commit(self, state, msgs, level=None):
        """Owner-side commit: calibrated ladder when a tuner policy is
        bound (``backend="auto"``), the static spec otherwise."""
        if self.tuner is not None and level is not None:
            return AT.ladder_commit(state, msgs, self.op, self.tuner, level)
        return C.commit(state, msgs, self.op, self.commit_spec)


def _tree_all_to_all(x, axis: str):
    return jax.tree.map(
        lambda a: jax.lax.all_to_all(a, axis, 0, 0, tiled=True), x)


def _fused_commit_leaf(ecfg: EngineConfig, st, tgt, payload, lane, base,
                       width, level):
    """Owner-side fused route+commit for one state/payload leaf —
    calibrated ladder when a tuner policy is bound (``backend="auto"``
    raced to the fused tier), the static spec otherwise."""
    if ecfg.tuner is not None:
        return AT.ladder_fused_site(st, tgt, payload, ecfg.op, ecfg.tuner,
                                    level, lane=lane, base=base,
                                    width=width)
    return C.fused_commit_site(st, tgt, payload, ecfg.op, ecfg.commit_spec,
                               lane=lane, base=base, width=width)


def route_wave(ecfg: EngineConfig, state_l, target, payload, pending,
               level=None, major=None, batch=None):
    """One coalescing sub-round under shard_map (DEPRECATED for direct use —
    see module docstring; overflow beyond C is NOT requeued here).

    state_l: pytree of [block] local owner slices; payload: matching pytree
    of [n] fields; target: [n] GLOBAL vertex ids; pending: [n] bool;
    level: traced ladder index for an ``ecfg.tuner`` adaptive commit.
    major/batch: the batch axis — ``batch`` is a
    :class:`repro.core.coalescing.QueryLanes`/``GraphBatch`` and
    ``major`` [n] int32 per-message item ids.  When
    ``batch.wave_width > 1`` (query lanes) the ids ride the exchange as
    one more payload field, state leaves are vertex-major
    [block * width] slices, and owners commit on composite local keys
    ``local_v * width + major`` so ONE commit resolves every item's
    conflicts (see ``repro.core.coalescing.fuse_keys``).  A
    ``GraphBatch`` has ``wave_width == 1`` — its targets are already
    flat union-graph ids, so owner slices and coalescing buckets are
    keyed by flat id with no extra field.  A
    :class:`~repro.core.coalescing.ProductAxis` composes both: targets
    are union-flat ids (graph coordinate pre-folded, so buckets/owners
    need nothing new) while the LANE id rides as ``major`` —
    ``wave_width == lanes`` and one commit resolves every
    (lane, graph) cell.
    Returns (state_l, delivered_mask, success pytree, conflicts)."""
    P, Cp = ecfg.num_shards, ecfg.capacity
    batch = batch if batch is not None else ecfg.batch
    width = batch.wave_width if batch is not None else 1
    if width > 1:   # block/width are static: a trace-time guard is free
        require_key_space(ecfg.block * width,
                          where="route_wave(block * wave_width)")
    owner = target // ecfg.block
    plan, _ = plan_buckets_sorted(owner, pending, P, Cp)
    kept = plan.kept
    # sentinel -1 marks empty slots through the exchange
    buf_t = scatter_to_buckets(plan, jnp.where(kept, target, -1), P, Cp,
                               fill=-1)
    buf_p = scatter_to_buckets(plan, payload, P, Cp, fill=0)
    rt = jax.lax.all_to_all(buf_t, ecfg.axis, 0, 0, tiled=True)
    rp = _tree_all_to_all(buf_p, ecfg.axis)
    # local commit at the owner, one per (state, payload) field pair
    shard = jax.lax.axis_index(ecfg.axis)
    rt_flat = rt.reshape(-1)
    rl_flat = None
    if width > 1:
        if major is None:
            raise ValueError("batch axis with wave_width > 1 needs "
                             "per-message `major` item ids")
        buf_l = scatter_to_buckets(plan, major, P, Cp, fill=0)
        rl = jax.lax.all_to_all(buf_l, ecfg.axis, 0, 0, tiled=True)
        rl_flat = rl.reshape(-1)
    valid = (rt_flat >= 0)
    st_leaves, tdef = jax.tree_util.tree_flatten(state_l)
    pl_leaves = tdef.flatten_up_to(rp)
    # fused fast path (backend="fused", static or tuner-raced): the
    # exchanged buffers go STRAIGHT into one kernel launch that computes
    # local composite keys, reorders in VMEM, and commits — the
    # local_idx/fuse_keys/make_messages intermediates below never
    # materialize.  Per-leaf: leaves outside the kernel envelope (vector
    # payloads, non-int32/f32 dtypes) take the unfused path.
    backend = (ecfg.tuner.backend if ecfg.tuner is not None
               else ecfg.commit_spec.backend)
    fused = [backend == "fused" and C.fused_site_supported(st, p)
             for st, p in zip(st_leaves, pl_leaves)]
    local_idx = None
    if not all(fused):
        local_idx = jnp.clip(rt_flat - shard * ecfg.block, 0,
                             ecfg.block - 1)
        if width > 1:
            local_idx = fuse_keys(
                local_idx, jnp.clip(rl_flat, 0, width - 1), width)
    new_st, succs = [], []
    conflicts = jnp.zeros((), jnp.int32)
    for i, (st, pl) in enumerate(zip(st_leaves, pl_leaves)):
        if fused[i]:
            res = _fused_commit_leaf(ecfg, st, rt_flat, pl.reshape(-1),
                                     rl_flat, shard * ecfg.block, width,
                                     level)
        else:
            res = ecfg._commit(st, make_messages(local_idx, pl.reshape(-1),
                                                 valid), level)
        new_st.append(res.state)
        if i == 0:
            # slot collisions depend on (target, valid) only, which every
            # payload field shares — count conflicts once per routed
            # message, not once per field
            conflicts = res.conflicts
        succs.append(res.success)
    # FR return path: ONE reverse exchange carries every field's flags
    back = jax.lax.all_to_all(
        jnp.stack(succs, axis=-1).reshape(P, Cp, len(succs)),
        ecfg.axis, 0, 0, tiled=True)
    succ = tdef.unflatten(
        [gather_from_buckets(back[..., i], plan, Cp, fill=False)
         for i in range(len(succs))])
    return tdef.unflatten(new_st), kept, succ, conflicts


def wave_until_delivered(ecfg: EngineConfig, state_l, target, payload,
                         valid, max_subrounds: int = 64, level=None,
                         major=None, batch=None):
    """Deliver ALL messages (sub-rounds until nothing pending).

    Returns (state_l, success pytree, conflicts, subrounds, delivered_all).
    ``delivered_all`` is False when ``max_subrounds`` was exhausted with
    messages still pending — callers MUST surface it instead of silently
    dropping the tail (the capacity-C requeue loop normally terminates for
    any C >= 1: each sub-round delivers up to C messages per owner).
    ``level`` is the (constant-per-wave) adaptive-ladder index when
    ``ecfg.tuner`` is set; ``major``/``batch`` thread the batch axis
    through every sub-round (see :func:`route_wave`)."""
    n = target.shape[0]
    st_leaves, tdef = jax.tree_util.tree_flatten(state_l)
    succ0 = tdef.unflatten([jnp.zeros((n,), bool) for _ in st_leaves])

    def cond(c):
        _, pending, _, _, it = c
        return (jax.lax.psum(jnp.sum(pending.astype(jnp.int32)), ecfg.axis)
                > 0) & (it < max_subrounds)

    def body(c):
        state_l, pending, success, conflicts, it = c
        state_l, kept, succ, cf = route_wave(ecfg, state_l, target, payload,
                                             pending, level, major, batch)
        success = jax.tree.map(lambda sn, so: jnp.where(kept, sn, so),
                               succ, success)
        return (state_l, pending & ~kept, success, conflicts + cf, it + 1)

    state_l, pending, success, conflicts, subrounds = jax.lax.while_loop(
        cond, body, (state_l, valid, succ0,
                     jnp.zeros((), jnp.int32), jnp.zeros((), jnp.int32)))
    delivered_all = (jax.lax.psum(jnp.sum(pending.astype(jnp.int32)),
                                  ecfg.axis) == 0)
    # commits run at the owners: the Tables-3c/3f conflict total is the
    # sum over shards (replicated, so Ps() out-specs stay consistent)
    conflicts = jax.lax.psum(conflicts, ecfg.axis)
    return state_l, success, conflicts, subrounds, delivered_all


def route_messages(ecfg: EngineConfig, target, payload, valid):
    """Route one sub-round of messages to owners WITHOUT committing —
    callers implement custom owner-side handlers (ownership protocol,
    pointer-jumping reads).  ``payload`` may be a pytree of [n] fields, or
    ``None`` for pure read requests (skips the payload exchange).

    Returns (local_idx [P*C], payload pytree of [P*C] or None,
    rvalid [P*C], plan, kept)."""
    P, Cp = ecfg.num_shards, ecfg.capacity
    owner = target // ecfg.block
    plan, _ = plan_buckets_sorted(owner, valid, P, Cp)
    kept = plan.kept
    buf_t = scatter_to_buckets(plan, jnp.where(kept, target, -1), P, Cp,
                               fill=-1)
    rt = jax.lax.all_to_all(buf_t, ecfg.axis, 0, 0, tiled=True)
    if payload is None:
        rp_flat = None
    else:
        buf_p = scatter_to_buckets(plan, payload, P, Cp, fill=0)
        rp = _tree_all_to_all(buf_p, ecfg.axis)
        rp_flat = jax.tree.map(lambda b: b.reshape(-1), rp)
    shard = jax.lax.axis_index(ecfg.axis)
    local_idx = rt.reshape(-1) - shard * ecfg.block
    rvalid = rt.reshape(-1) >= 0
    return local_idx, rp_flat, rvalid, plan, kept


def return_to_spawners(ecfg: EngineConfig, reply, plan: BucketPlan, fill=0):
    """Reverse all_to_all of per-slot replies (FR return path).  ``reply``
    may be a pytree of [P*C] fields; unkept slots read as ``fill``."""
    P, Cp = ecfg.num_shards, ecfg.capacity
    back = _tree_all_to_all(
        jax.tree.map(lambda r: r.reshape(P, Cp), reply), ecfg.axis)
    return gather_from_buckets(back, plan, Cp, fill=fill)


def gather_until_answered(ecfg: EngineConfig, arr_l, idx, valid, fill=0,
                          max_subrounds: int = 64):
    """Remote gather: read the distributed array ``arr_l`` (pytree of
    [block] owner slices) at GLOBAL indices ``idx`` [n], requeueing
    coalescing overflow until every valid request is answered.  This is the
    FR read path (``route_messages`` + owner lookup + ``return_to_spawners``)
    — the ownership-protocol building block Boruvka's pointer-jumping uses.

    Returns (values pytree of [n] — ``fill`` where ~valid, subrounds,
    delivered_all)."""
    n = idx.shape[0]
    leaves, tdef = jax.tree_util.tree_flatten(arr_l)
    out0 = tdef.unflatten([jnp.full((n,), fill, a.dtype) for a in leaves])

    def cond(c):
        _, pending, it = c
        return (jax.lax.psum(jnp.sum(pending.astype(jnp.int32)), ecfg.axis)
                > 0) & (it < max_subrounds)

    def body(c):
        out, pending, it = c
        local_idx, _, rvalid, plan, kept = route_messages(
            ecfg, idx, None, pending)
        lidx = jnp.clip(local_idx, 0, ecfg.block - 1)
        reply = jax.tree.map(
            lambda a: jnp.where(rvalid, a[lidx], jnp.asarray(fill, a.dtype)),
            arr_l)
        back = return_to_spawners(ecfg, reply, plan, fill=fill)
        out = jax.tree.map(lambda o, b: jnp.where(kept, b, o), out, back)
        return out, pending & ~kept, it + 1

    out, pending, subrounds = jax.lax.while_loop(
        cond, body, (out0, valid, jnp.zeros((), jnp.int32)))
    delivered_all = (jax.lax.psum(jnp.sum(pending.astype(jnp.int32)),
                                  ecfg.axis) == 0)
    return out, subrounds, delivered_all


# ---------------------------------------------------------------------------
# Coalescing-capacity auto-sizing (paper §5.6)
# ---------------------------------------------------------------------------

# ``capacity="auto"``: C starts from the average per-shard inbound load and
# then a process-level feedback cache grows it for the NEXT run whenever a
# run's waves persistently overflowed (sub-rounds per round above
# OVERFLOW_RATIO means messages kept getting requeued past C) — the same
# measure-then-adapt loop the autotuner closes for backend/M.
CAPACITY_MIN = 64
CAPACITY_MAX = 1 << 15
OVERFLOW_RATIO = 2.0
_CAPACITY_CACHE: dict = {}


def auto_capacity(g, num_shards: int) -> int:
    """Current C for (graph shape, shard count): the cached feedback value
    when a previous run reported overflow, the static heuristic otherwise
    (power of two ~2x the average per-shard inbound load, clamped)."""
    key = (g.num_vertices, g.num_edges, num_shards)
    hit = _CAPACITY_CACHE.get(key)
    if hit is not None:
        return hit
    per_shard = max(1, (2 * g.num_edges) // max(num_shards, 1))
    return max(CAPACITY_MIN, min(1 << (per_shard - 1).bit_length(),
                                 CAPACITY_MAX))


def _capacity_feedback(g, num_shards: int, capacity: int,
                       subrounds: int, rounds: int) -> None:
    """Grow the cached C when waves persistently overflowed this run.

    Algorithms issuing several waves per round (Boruvka) inflate the
    sub-round count without real overflow; the growth is monotone and
    capped, so a spurious doubling costs padding, never correctness."""
    if subrounds > OVERFLOW_RATIO * max(rounds, 1) and capacity < CAPACITY_MAX:
        _CAPACITY_CACHE[(g.num_vertices, g.num_edges, num_shards)] = \
            min(capacity * 2, CAPACITY_MAX)


# ---------------------------------------------------------------------------
# The distributed-algorithm harness
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardLayout:
    """Static shapes of one distributed run (1-D partition, paper §3.1)."""
    num_shards: int
    block: int          # vertices per shard (padded)
    emax: int           # edges per shard (padded)
    num_vertices: int
    num_edges: int

    @property
    def vpad(self) -> int:
        return self.num_shards * self.block


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EdgeSlice:
    """One shard's edge slice (sources owned locally, padded to emax)."""
    src: jax.Array      # int32 [emax] GLOBAL source ids
    dst: jax.Array      # int32 [emax] GLOBAL destination ids
    weight: jax.Array   # float32 [emax]
    valid: jax.Array    # bool [emax]
    eid: jax.Array      # int32 [emax] ORIGINAL edge ids (tie-breaking)
    my_src: jax.Array   # int32 [emax] local row of src (clipped to block)


class WaveRuntime:
    """Per-round handle the harness passes to ``round_fn``.

    Wraps the wave primitives with an :class:`EngineConfig` bound to the
    run and accumulates telemetry (conflicts, sub-rounds, delivery flag)
    across every wave/gather the round issues.  Do NOT call its methods
    from inside ``lax.scan``/``lax.while_loop`` bodies of the round — the
    accumulators are trace-level.
    """

    def __init__(self, ecfg: EngineConfig, layout: ShardLayout,
                 max_subrounds: int, level=None):
        self.ecfg = ecfg
        self.layout = layout
        self.max_subrounds = max_subrounds
        self.level = level          # adaptive-ladder index (traced int32)
        self.conflicts = jnp.zeros((), jnp.int32)
        self.subrounds = jnp.zeros((), jnp.int32)
        self.messages = jnp.zeros((), jnp.int32)   # routed msgs this round
        self.delivered_all = jnp.ones((), bool)

    @property
    def shard(self) -> jax.Array:
        return jax.lax.axis_index(self.ecfg.axis)

    @property
    def gid(self) -> jax.Array:
        """GLOBAL vertex ids of the local block."""
        return self.shard * self.ecfg.block + jnp.arange(
            self.ecfg.block, dtype=jnp.int32)

    def psum(self, x):
        return jax.lax.psum(x, self.ecfg.axis)

    def any(self, mask) -> jax.Array:
        """Global any() over a per-shard bool array."""
        return self.psum(jnp.sum(mask.astype(jnp.int32))) > 0

    def wave(self, state_l, target, payload, valid, *, op: str,
             major=None, batch=None):
        """Deliver + commit messages ``(target, payload)`` with ``op``;
        returns (state_l, success pytree).  state_l/payload are matching
        pytrees of [block]/[n] fields sharing one bucket plan.  With a
        ``batch`` axis of ``wave_width`` W > 1 (query lanes) the state
        leaves are vertex-major [block * W] item slices and the
        ``major`` item ids ride the same bucket plan; a ``GraphBatch``
        (W == 1, flat union-graph targets) routes like a single graph.
        ``batch=None`` falls back to the axis the run was configured
        with (``run_distributed(batch=...)``)."""
        ecfg = dataclasses.replace(self.ecfg, op=op)
        state_l, success, cf, sr, dall = wave_until_delivered(
            ecfg, state_l, target, payload, valid, self.max_subrounds,
            self.level, major, batch)
        self.conflicts = self.conflicts + cf
        self.subrounds = self.subrounds + sr
        self.messages = self.messages + self.psum(
            jnp.sum(valid.astype(jnp.int32)))
        self.delivered_all = self.delivered_all & dall
        return state_l, success

    def gather(self, arr_l, idx, valid=None, *, fill=0):
        """Remote gather of the distributed array ``arr_l`` at GLOBAL
        indices ``idx`` (``fill`` where ~valid)."""
        if valid is None:
            valid = jnp.ones(idx.shape, bool)
        out, sr, dall = gather_until_answered(
            self.ecfg, arr_l, idx, valid, fill=fill,
            max_subrounds=self.max_subrounds)
        self.subrounds = self.subrounds + sr
        self.delivered_all = self.delivered_all & dall
        return out


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    """One irregular algorithm expressed as AAM rounds.

    name:         display/registry name.
    message_type: AAM taxonomy tag of the dominant message ("FF&AS",
                  "FF&MF", "FR&AS", "FR&MF") — documentation/telemetry.
    init:         ``(g, layout) -> (state, scalars)``; ``state`` is a
                  pytree of GLOBAL arrays whose leading dim is divisible by
                  ``num_shards`` ([vpad] vertex state, [P*emax] edge
                  state), ``scalars`` a pytree of replicated scalars.
    round_fn:     ``(rt, edges, state, scalars, it) ->
                  (state, scalars, active)`` — one round: read the local
                  :class:`EdgeSlice`, issue waves/gathers through the
                  :class:`WaveRuntime`, return the globally-consistent
                  ``active`` bool (False terminates the loop).
    max_rounds:   ``(g, layout) -> int`` round cap.
    """
    name: str
    message_type: str
    init: Callable[..., Any]
    round_fn: Callable[..., Any]
    max_rounds: Callable[..., int]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DistributedResult:
    """Harness output: final state + the telemetry the paper tabulates.

    delivered_all is the anti-wedge flag: False means some wave hit
    ``max_subrounds`` with messages still pending, i.e. the returned state
    is NOT the fixed point — assert on it (the parity matrix does)."""
    state: Any              # pytree of GLOBAL (padded) arrays
    scalars: Any            # replicated scalar pytree
    rounds: jax.Array       # int32 — algorithm rounds executed
    conflicts: jax.Array    # int32 — commit conflicts across all waves
    subrounds: jax.Array    # int32 — coalescing sub-rounds across all waves
    delivered_all: jax.Array  # bool
    m_final: jax.Array      # int32 — final adaptive transaction size M
    #                         (0 = whole batch, -1 = static spec, no tuner)
    capacity: jax.Array     # int32 — the coalescing factor C the run used
    #                         (resolved value when capacity="auto")
    degraded: jax.Array = None  # bool — True when the run survived a mesh
    #                         shrink (host drop) by re-deriving ownership
    #                         and replaying from the last round snapshot


def telemetry_return(base, res: "DistributedResult", telemetry: bool):
    """THE ``telemetry=`` return-shape convention, shared by every
    ``distributed_*`` algorithm entry point (regression-pinned by
    ``tests/test_obs.py::test_telemetry_return_shapes``):

    * ``telemetry=False`` — return ``base`` unchanged (the entry
      point's documented plain shape);
    * ``telemetry=True`` — APPEND the :class:`DistributedResult` as one
      trailing element: a tuple ``base`` gains ``res`` at the end, a
      non-tuple ``base`` becomes the pair ``(base, res)``.

    So ``*out, res = distributed_x(..., telemetry=True)`` always works,
    and the plain positions never shift between the two modes.
    """
    if not telemetry:
        return base
    if isinstance(base, tuple):
        return base + (res,)
    return (base, res)


class _Runner:
    """One compiled round-loop over one mesh shape.

    Owns the partition, layout, calibrated tuner policy, and the jitted
    shard_map'd loop body for a fixed (mesh, P).  The loop carry
    ``(conflicts, subrounds, delivered_all, level, it, active)`` enters
    and leaves as replicated scalars, and the round cap is a TRACED
    ``limit`` — so the same compiled function serves both the single-shot
    path (limit = max_rounds) and the chunked/degraded path (limit = next
    snapshot boundary), and a degraded continuation re-enters mid-run.
    """

    def __init__(self, alg: AlgorithmSpec, mesh, g, *, axis: str,
                 capacity: int, m, spec, batch, max_subrounds: int,
                 edges=None):
        from jax.sharding import PartitionSpec as Ps
        from repro.graphs.csr import partition_edges

        self.P = mesh.shape[axis]
        self.mesh = mesh
        if edges is None:
            edges = partition_edges(g, self.P)
        (src, dst, w, val, eid), part = edges
        self.arrays = (src, dst, w, val, eid)
        self.layout = ShardLayout(self.P, part.block, src.shape[1],
                                  g.num_vertices, g.num_edges)
        ecfg = EngineConfig(self.P, part.block, capacity, axis=axis, m=m,
                            spec=spec, batch=batch)
        self.state0, self.scalars0 = alg.init(g, self.layout)
        self.tuner = None
        if ecfg.commit_spec.backend == C.AUTO:
            # stage-1 calibration BEFORE tracing: per-shard commits see a
            # [block] state slice and up to P*C routed messages/sub-round
            leaf = jax.tree_util.tree_leaves(self.state0)[0]
            self.tuner = AT.policy_for(
                ecfg.commit_spec,
                jax.ShapeDtypeStruct((part.block,), leaf.dtype),
                n=min(self.P * capacity, g.num_edges or 1),
                axis_width=batch.race_width if batch is not None else 1)
            ecfg = dataclasses.replace(ecfg, spec=None, tuner=self.tuner)
        self.max_rounds = int(alg.max_rounds(g, self.layout))
        tuner = self.tuner
        # wave telemetry tap, decided AT TRACE TIME (a _Runner is built
        # per run_distributed call, so flipping REPRO_TRACE takes effect
        # on the next run): one unordered io_callback per round per
        # shard — unordered so a multi-device mesh never serializes on
        # the host; the round index rides in the payload
        trace_cb = None
        if (spec is not None and spec.trace) or OT.trace_enabled():
            from repro.obs import wavetap
            trace_cb = wavetap.round_recorder(alg.name)

        def shard_fn(state, scalars, carry, limit,
                     src_l, dst_l, w_l, val_l, eid_l):
            shard = jax.lax.axis_index(axis)
            edges = EdgeSlice(
                src=src_l[0], dst=dst_l[0], weight=w_l[0], valid=val_l[0],
                eid=eid_l[0],
                my_src=jnp.clip(src_l[0] - shard * part.block, 0,
                                part.block - 1))

            def cond(c):
                return c[-1] & (c[-2] < limit)

            def body(c):
                state, scalars, conflicts, subrounds, dall, level, it, _ = c
                rt = WaveRuntime(ecfg, self.layout, max_subrounds,
                                 level=level)
                state, scalars, active = alg.round_fn(rt, edges, state,
                                                      scalars, it)
                if trace_cb is not None:
                    from jax.experimental import io_callback
                    io_callback(trace_cb, None, it, rt.conflicts,
                                rt.subrounds, rt.messages, level, shard,
                                ordered=False)
                if tuner is not None:
                    # stage-2 feedback: this round's psum'd conflicts vs
                    # routed messages move the ladder (replicated =>
                    # every shard steps identically)
                    level = AT.next_level(tuner, level, rt.conflicts,
                                          rt.messages)
                return (state, scalars, conflicts + rt.conflicts,
                        subrounds + rt.subrounds, dall & rt.delivered_all,
                        level, it + 1, active)

            out = jax.lax.while_loop(cond, body, (state, scalars) + carry)
            return out[:2], out[2:]

        st_specs = jax.tree.map(lambda _: Ps(axis), self.state0)
        sc_specs = jax.tree.map(lambda _: Ps(), self.scalars0)
        fn = compat.shard_map(
            shard_fn, mesh=mesh,
            in_specs=(st_specs, sc_specs, (Ps(),) * 6, Ps())
            + (Ps(axis),) * 5,
            out_specs=((st_specs, sc_specs), (Ps(),) * 6),
            check_vma=False)
        self._jfn = jax.jit(fn)

    def zero_carry(self) -> tuple:
        z = jnp.zeros((), jnp.int32)
        level0 = jnp.asarray(self.tuner.init_level if self.tuner else 0,
                             jnp.int32)
        return (z, z, jnp.ones((), bool), level0, z, jnp.ones((), bool))

    def run(self, state, scalars, carry, limit: int):
        (state, scalars), carry = self._jfn(
            state, scalars, carry, jnp.asarray(limit, jnp.int32),
            *self.arrays)
        return state, scalars, carry

    def m_final(self, level) -> jax.Array:
        if self.tuner is None:
            return jnp.full((), -1, jnp.int32)
        ms = jnp.asarray([m or 0 for m in self.tuner.ladder], jnp.int32)
        return ms[jnp.clip(level, 0, len(self.tuner.ladder) - 1)]


def _shrink_mesh(mesh, axis: str, new_size: int):
    """The surviving sub-mesh after a host drop: slice the device array
    along ``axis`` (the simulation of 'P-1 hosts remain')."""
    import numpy as np
    devs = np.asarray(mesh.devices)
    sl = [slice(None)] * devs.ndim
    sl[list(mesh.axis_names).index(axis)] = slice(0, new_size)
    return jax.sharding.Mesh(devs[tuple(sl)], mesh.axis_names)


def _remap_state(alg: AlgorithmSpec, g, old_layout: ShardLayout,
                 new_layout: ShardLayout, state):
    """Re-home a round-snapshot state onto a smaller mesh.

    The 1-D partition puts vertex v at GLOBAL index v with padding only at
    the tail, so vertex-state leaves ([vpad, ...]) carry over by value:
    a fresh ``alg.init`` on the new layout supplies the canonical padding
    rows, and the first V rows are overwritten with the snapshot.  Leaves
    NOT shaped by vpad (per-edge state — the partition order changed under
    them) cannot be re-homed; returns None => restart from round 0.
    """
    V = g.num_vertices
    fresh, _ = alg.init(g, new_layout)
    conforms = all(
        getattr(o, "ndim", 0) >= 1 and o.shape[0] == old_layout.vpad
        and n.shape[0] == new_layout.vpad and o.shape[1:] == n.shape[1:]
        for o, n in zip(jax.tree.leaves(state), jax.tree.leaves(fresh)))
    if not conforms:
        return None
    return jax.tree.map(lambda n, o: n.at[:V].set(o[:V]), fresh, state)


_LINT_CAPTURE = False   # toggled by repro.analysis.waverace.capture()


class LintCapture(Exception):
    """Carries the normalized (alg, graph, batch) out of
    :func:`run_distributed` when the analyzer only wants the round
    function, not a mesh execution."""

    def __init__(self, alg, g, batch):
        super().__init__(f"lint capture: {alg.name}")
        self.alg, self.g, self.batch = alg, g, batch


def run_distributed(alg: AlgorithmSpec, mesh, g, *,
                    capacity: int | str = 4096,
                    m: int | None = None, axis: str = "data",
                    spec: C.CommitSpec | None = None,
                    max_subrounds: int = 64,
                    edges=None, batch=None,
                    snapshot_rounds: int | None = None,
                    fault_injector=None,
                    max_faults: int = 8) -> DistributedResult:
    """Execute ``alg`` over ``mesh[axis]`` shards — the one distributed
    driver behind all six ``distributed_*`` algorithms.

    Owns: 1-D edge partitioning, the shard_map wrapper, the round loop
    (``while active and rounds < max_rounds``), and telemetry aggregation.
    ``capacity``/``m`` are the paper's C (coalescing factor) and M
    (transaction size); ``capacity="auto"`` sizes C from the per-shard
    load heuristic plus the sub-round overflow telemetry of previous runs
    on the same (graph shape, shard count) — see :func:`auto_capacity`.
    ``spec`` picks the commit backend per
    :class:`repro.core.commit.CommitSpec` — ``backend="auto"`` calibrates
    the perf model once per run (backend + ladder seed M*) and then
    adapts the transaction size per round from the psum'd conflict
    telemetry (Tables 3c/3f feedback).  ``edges`` accepts a precomputed
    ``partition_edges(g, mesh.shape[axis])`` result so wrappers that also
    need the lane layout (Boruvka's edge-state finalize) partition only
    once.

    ``g`` may be a :class:`repro.graphs.csr.GraphSet`: the run executes
    over its disjoint-union graph (per-graph CSR slices gathered from the
    stacked edge arrays), which IS the graph-batch axis — flat union ids
    key the owner slices and coalescing buckets.  ``batch`` names the
    run's default batch axis (``QueryLanes``/``GraphBatch``/
    ``ProductAxis``); waves issued without an explicit ``batch=`` use
    it, and its ``race_width`` (L lanes / G graphs / L·G cells) keys
    the tuner's axis-aware race.  A ``ProductAxis`` run passes a
    GraphSet here with ``batch=ProductAxis(L, gs.axis.sizes)``: union
    ids route exactly as the graph batch while lane ids ride as
    ``major`` (see :func:`route_wave`) — e.g.
    :func:`repro.graphs.algorithms.bfs.distributed_product_bfs`.

    **Degraded-mesh mode.**  ``snapshot_rounds`` chunks the round loop:
    every chunk boundary the (replicated) carry and global state come
    back to the host as a round snapshot.  ``fault_injector(chunk,
    rounds_done)`` raising simulates a host drop — instead of failing the
    query, the run shrinks the mesh by one device along ``axis``,
    re-derives the 1-D ownership for the smaller mesh, re-homes the last
    snapshot onto it (see :func:`_remap_state`; per-edge state restarts
    from round 0), and finishes there.  ``DistributedResult.degraded``
    reports it.  With neither parameter set the loop runs single-shot,
    exactly as before.
    """
    from repro.graphs.csr import GraphSet, partition_edges

    if isinstance(g, GraphSet):
        batch = batch if batch is not None else g.axis
        g = g.union()
    if _LINT_CAPTURE:
        # repro.analysis.waverace sets this flag, calls the public
        # distributed_* wrappers (so their own state/payload plumbing
        # runs), and catches the normalized (alg, graph, axis) triple
        # here instead of executing the mesh program.
        raise LintCapture(alg, g, batch)
    P = mesh.shape[axis]
    auto_cap = capacity == "auto"
    if auto_cap:
        capacity = auto_capacity(g, P)
    if edges is None:
        edges = partition_edges(g, P)
    kw = dict(axis=axis, capacity=capacity, m=m, spec=spec, batch=batch,
              max_subrounds=max_subrounds)
    r = _Runner(alg, mesh, g, edges=edges, **kw)
    state, scalars, carry = r.state0, r.scalars0, r.zero_carry()
    degraded, faults, chunk_i = False, 0, 0
    chunk = (snapshot_rounds if snapshot_rounds
             else max(r.max_rounds, 1))
    snap = (state, scalars, carry)
    while bool(carry[5]) and int(carry[4]) < r.max_rounds:
        limit = min(int(carry[4]) + chunk, r.max_rounds)
        try:
            if fault_injector is not None:
                fault_injector(chunk_i, int(carry[4]))
            state, scalars, carry = r.run(state, scalars, carry, limit)
            jax.block_until_ready(carry)     # surface device faults HERE
            snap = (state, scalars, carry)
        except KeyboardInterrupt:
            raise
        except Exception:
            faults += 1
            if faults > max_faults:
                raise
            degraded = True
            tr = OT.get_tracer()
            if tr.active:
                tr.instant("mesh_shrink", cat="engine",
                           args={"alg": alg.name, "P": r.P,
                                 "survivors": max(r.P - 1, 1),
                                 "rounds_done": int(carry[4]),
                                 "faults": faults})
            state, scalars, carry = snap     # last completed chunk
            if r.P > 1:
                new_mesh = _shrink_mesh(r.mesh, axis, r.P - 1)
                old_layout = r.layout
                r = _Runner(alg, new_mesh, g, **kw)
                remapped = _remap_state(alg, g, old_layout, r.layout,
                                        state)
                if remapped is None:
                    # per-edge state can't be re-homed: restart the
                    # query from round 0 on the surviving mesh
                    state, scalars = r.state0, r.scalars0
                    carry = r.zero_carry()
                else:
                    state = remapped
            # P == 1: nothing to shrink — retry the snapshot in place
        chunk_i += 1
    conflicts, subrounds, dall, level, rounds, _ = carry
    if auto_cap:
        _capacity_feedback(g, P, capacity, int(subrounds), int(rounds))
    return DistributedResult(state=state, scalars=scalars, rounds=rounds,
                             conflicts=conflicts, subrounds=subrounds,
                             delivered_all=dall, m_final=r.m_final(level),
                             capacity=jnp.asarray(capacity, jnp.int32),
                             degraded=jnp.asarray(degraded))


# Legacy entry points live with their algorithms now; keep the old import
# path (`from repro.core.engine import distributed_bfs`) working without a
# circular import at module load.
def __getattr__(name):
    if name == "distributed_bfs":
        from repro.graphs.algorithms.bfs import distributed_bfs
        return distributed_bfs
    if name == "distributed_pagerank":
        from repro.graphs.algorithms.pagerank import distributed_pagerank
        return distributed_pagerank
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
