"""Atomic Active Messages — message taxonomy (paper §3.2).

Two orthogonal criteria classify every message:

* direction of data flow: Fire-and-Forget (FF) vs Fire-and-Return (FR);
* activity commits: Always-Succeed (AS) vs May-Fail (MF).

A :class:`Messages` batch is the unit the runtime coarsens (executes M per
"transaction" tile) and coalesces (buckets per destination shard).  SoA
layout; payload may be a scalar per message or a vector (LM activations in
the MoE application).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any

import jax
import jax.numpy as jnp


class Direction(enum.Enum):
    FF = "fire_and_forget"
    FR = "fire_and_return"


class CommitMode(enum.Enum):
    AS = "always_succeed"
    MF = "may_fail"


@dataclasses.dataclass(frozen=True)
class MessageType:
    direction: Direction
    commit: CommitMode

    @property
    def tag(self) -> str:
        return f"{'FF' if self.direction is Direction.FF else 'FR'}&" \
               f"{'AS' if self.commit is CommitMode.AS else 'MF'}"


FF_AS = MessageType(Direction.FF, CommitMode.AS)   # PageRank
FF_MF = MessageType(Direction.FF, CommitMode.MF)   # BFS
FR_AS = MessageType(Direction.FR, CommitMode.AS)   # ST-connectivity
FR_MF = MessageType(Direction.FR, CommitMode.MF)   # coloring, Boruvka


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Messages:
    """A batch of atomic active messages.

    target:  int32 [n] destination element id (global vertex id / expert id)
    payload: [n] or [n, d] operator argument
    valid:   bool [n] — lanes beyond the live count are masked out
    """
    target: jax.Array
    payload: Any
    valid: jax.Array

    @property
    def capacity(self) -> int:
        return self.target.shape[0]

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32))


def make_messages(target, payload, valid=None) -> Messages:
    target = jnp.asarray(target, jnp.int32)
    if valid is None:
        valid = jnp.ones(target.shape, bool)
    return Messages(target=target, payload=payload, valid=valid)


def batch_messages(axis, major, target, payload, valid) -> Messages:
    """Axis-generic fusion: one flat batch on keys
    ``axis.flatten(major, target)`` (see the batch-axis taxonomy in
    :mod:`repro.core.coalescing`).

    ``major`` names each message's batch item (lane index / graph
    index), ``target`` its per-item vertex id; both [n] (or any common
    shape — everything is flattened), payload a matching pytree with
    optional trailing feature dims.  Committing the result against the
    [axis.flat_size] flat state resolves every item's conflicts in one
    pass."""
    major = jnp.asarray(major, jnp.int32)
    key = axis.flatten(major, jnp.asarray(target, jnp.int32))
    lead = key.size
    return Messages(
        target=key.reshape(-1),
        payload=jax.tree.map(
            lambda x: x.reshape((lead,) + x.shape[key.ndim:]), payload),
        valid=jnp.asarray(valid, bool).reshape(-1),
    )


def lane_messages(target, payload, valid, num_vertices: int) -> Messages:
    """Thin wrapper over :func:`batch_messages` for the query-lane axis:
    an [L, n] lane batch fuses on composite keys
    ``lane * num_vertices + target``.

    target/valid: int32/bool [L, n]; payload: [L, n] (or pytree of such).
    Committing the result against [L * num_vertices] flattened state
    resolves every lane's conflicts in one pass."""
    from repro.core.coalescing import QueryLanes
    target = jnp.asarray(target, jnp.int32)
    lanes, n = target.shape
    lane = jnp.broadcast_to(
        jnp.arange(lanes, dtype=jnp.int32)[:, None], (lanes, n))
    return batch_messages(QueryLanes(lanes, num_vertices), lane, target,
                          payload, valid)


def product_messages(target, payload, valid, axis) -> Messages:
    """Thin wrapper over :func:`batch_messages` for the lanes×graphs
    PRODUCT axis: an [L, n] batch of UNION-flat targets fuses on
    composite keys ``lane * Vtot + target`` (the graph coordinate is
    already folded into the union-flat target id — see
    :class:`repro.core.coalescing.ProductAxis`).

    target/valid: int32/bool [L, n] with targets in ``[0, Vtot)``;
    payload: [L, n] (or pytree of such).  Committing the result against
    [L * Vtot] flattened state resolves every (lane, graph) cell's
    conflicts in one pass."""
    target = jnp.asarray(target, jnp.int32)
    lanes, n = target.shape
    lane = jnp.broadcast_to(
        jnp.arange(lanes, dtype=jnp.int32)[:, None], (lanes, n))
    return batch_messages(axis, lane, target, payload, valid)


def concat_messages(a: Messages, b: Messages) -> Messages:
    return Messages(
        target=jnp.concatenate([a.target, b.target]),
        payload=jax.tree.map(lambda x, y: jnp.concatenate([x, y]),
                             a.payload, b.payload),
        valid=jnp.concatenate([a.valid, b.valid]),
    )
