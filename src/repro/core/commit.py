"""Commit engines — the HTM-transaction analogue (DESIGN.md §2).

One semantic operation — "commit a batch of atomic active messages" —
executed by interchangeable mechanisms, mirroring the paper's
atomics → HTM spectrum (AAM §4–§5):

* ``atomic`` — :func:`atomic_commit`: one scatter element per message
  (XLA scatter with conflict semantics resolved by the memory system).
  The *fine-grained atomics* baseline the paper compares against
  (Graph500-style CAS/ACC).
* ``coarse`` — :func:`coarse_commit`: the AAM path — messages are
  processed in "transactions" of M messages; each transaction's conflicts
  are resolved on-chip (sort + segment reduction over the tile) and the
  state is written once per distinct target.
* ``pallas`` — :mod:`repro.kernels.coarse_commit` executes one
  transaction per grid step against VMEM-resident state blocks (interpret
  mode on CPU, compiled on real TPU).
* ``fused`` — :mod:`repro.kernels.fused_wave`: the pallas tile loop with
  the route-side key computation folded INTO the kernel — one launch
  from the post-exchange bucket buffers (global ids + ``-1`` sentinels,
  optional lane ids) to committed state, no ``local_idx``/
  ``make_messages`` materialization.  Through the generic :func:`commit`
  entry (plain local targets) it matches ``pallas`` launch-for-launch;
  the engine's :func:`fused_commit_site` fast path is where the
  intermediate drop happens.

:func:`commit` is the single entry point: a :class:`CommitSpec` names the
backend and its knobs, and every backend returns the same
:class:`CommitResult` carrying MF success flags (the "did my transaction
win" bit routed back for FR messages) and conflict telemetry (the
abort-statistics analogue of paper Tables 3c/3f).  Backends that cannot
execute a request (e.g. ``pallas`` on vector payloads or unsupported
dtypes) fall back to ``coarse`` automatically.
"""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.core.messages import Messages, make_messages

OPS = ("min", "max", "add", "or", "first")
BACKENDS = ("atomic", "coarse", "pallas", "fused")
AUTO = "auto"   # CommitSpec(backend="auto"): online-calibrated backend + M


def _identity(op: str, dtype):
    if op == "min":
        return jnp.array(jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer)
                         else jnp.inf, dtype)
    if op == "max":
        return jnp.array(jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer)
                         else -jnp.inf, dtype)
    if op == "add":
        return jnp.array(0, dtype)
    if op == "or":
        return jnp.array(False, bool)
    if op == "first":
        return jnp.array(-1, dtype)     # "empty slot" marker
    raise ValueError(op)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CommitResult:
    state: jax.Array        # updated state array [V] (or [V, d])
    success: jax.Array      # bool [n] — MF: message won; AS: valid mask
    conflicts: jax.Array    # int32 — duplicate-target messages this batch
    applied: jax.Array      # int32 — messages that changed state


@dataclasses.dataclass(frozen=True)
class CommitSpec:
    """How to execute a commit — the mechanism, not the semantics.

    backend:   one of :data:`BACKENDS`, or ``"auto"`` — the
               :mod:`repro.core.autotune` tuner calibrates the §5.3 perf
               model at trace time (timed micro-commits of a synthetic
               workload sized to this call's batch) and picks the
               backend and transaction size M*; the kernel tiers
               (``pallas``/``fused``) fall back to ``coarse`` for
               payload shapes/dtypes the kernel does not support.
    m:         transaction size (messages per transaction); ``None`` = the
               whole batch is one transaction.
    sort:      coalesce by sorting messages by target before resolution
               (jnp tiers only; the kernel always resolves in-VMEM).
    stats:     compute full MF success flags + O(V) telemetry.  ``False``:
               the sorted jnp tiers keep cheap O(N) conflict/applied
               counters; the unsorted scatter path and the ``pallas``
               kernel (which then skips its in-kernel conflict reduction
               and extra output entirely) report zero conflicts.
    tile_m:    pallas transaction tile (used when ``m`` is None).
    block_v:   pallas state block resident in VMEM.
    interpret: force pallas interpret mode; ``None`` = off-TPU auto.
    seed_m:    warm-start hint for ``backend="auto"``: seed the
               conflict-feedback ladder at this transaction size instead
               of the calibrated M* (0 = whole batch).  Unlike ``m`` this
               does NOT pin the size — the ladder still adapts.  Restored
               services use it to re-enter at the learned level.
    sanitize:  shadow every commit with a permuted-message-order replay
               and assert the state is reorder-invariant (bit-identical;
               float ``add`` to documented rounding tolerance) — the
               runtime conflict sanitizer of :mod:`repro.analysis`.
               ``REPRO_SANITIZE=1`` in the environment turns it on
               globally without touching specs.  Mismatches raise
               :class:`repro.analysis.sanitize.SanitizeError` (surfaced
               as ``XlaRuntimeError`` under jit) and are recorded in
               :func:`repro.analysis.sanitize.reports`.
    trace:     stream per-commit telemetry (conflicts, applied, routed
               messages, ladder level) to the host through
               :mod:`repro.obs.wavetap` — an ``io_callback`` per commit
               inside the jitted loop.  ``REPRO_TRACE=1`` in the
               environment turns it on globally without touching specs;
               with both off the tap never enters the jaxpr
               (``aamlint --trace-off-clean`` proves it).

    Frozen + hashable so a spec can be a ``static_argnames`` entry of any
    jitted caller.
    """
    backend: str = "coarse"
    m: int | None = None
    sort: bool = True
    stats: bool = True
    tile_m: int = 256
    block_v: int = 512
    interpret: bool | None = None
    seed_m: int | None = None
    sanitize: bool = False
    trace: bool = False

    def __post_init__(self):
        if self.m is not None and self.m < 1:
            raise ValueError(f"transaction size m must be >= 1, got {self.m}")
        if self.seed_m is not None and self.seed_m < 0:
            raise ValueError(f"seed_m must be >= 0 (0 = whole batch), "
                             f"got {self.seed_m}")
        if self.tile_m < 1 or self.block_v < 1:
            raise ValueError(f"tile_m/block_v must be >= 1, got "
                             f"{self.tile_m}/{self.block_v}")


def commit(state: jax.Array, msgs: Messages, op: str,
           spec: CommitSpec | None = None) -> CommitResult:
    """Commit a batch of atomic active messages via ``spec.backend``.

    The single dispatch point for every mechanism tier — algorithm code
    names *what* (``op``) and the spec names *how*.  All backends agree on
    the final state for every op in :data:`OPS`; ``success`` masks agree
    whenever the whole batch is one transaction (``m=None`` — tiled
    commits may legitimately report one winner per tile, like back-to-back
    HTM transactions).
    """
    spec = spec if spec is not None else CommitSpec()
    if op not in OPS:
        raise ValueError(f"op {op!r} not in {OPS}")
    if spec.backend not in BACKENDS + (AUTO,):
        raise ValueError(f"backend {spec.backend!r} not in "
                         f"{BACKENDS + (AUTO,)}")
    if msgs.capacity == 0:
        z = jnp.zeros((), jnp.int32)
        return CommitResult(state, jnp.zeros((0,), bool), z, z)
    if spec.backend == AUTO:
        from repro.core.autotune import resolve_spec   # lazy: no cycle
        spec = resolve_spec(spec, state, msgs, op)
    backend = spec.backend
    if backend in ("pallas", "fused") and not _pallas_supported(state, msgs,
                                                                op):
        backend = "coarse"
    # the named scope marks every scatter/gather of the conflict-resolved
    # write path in traced jaxprs — repro.analysis.waverace keys its
    # in-wave-race rule on it (raw state writes OUTSIDE this scope are
    # unserialized and get flagged)
    with jax.named_scope("aam_commit"):
        res = _dispatch(state, msgs, op, spec, backend)
        if (spec.sanitize or _sanitize_env()) and msgs.capacity > 1:
            from repro.analysis.sanitize import shadow_check  # lazy: no cycle
            shadow_check(state, msgs, op, spec, backend, res.state)
    return res


def _sanitize_env() -> bool:
    return os.environ.get("REPRO_SANITIZE", "").lower() in (
        "1", "true", "on", "yes")


def _dispatch(state: jax.Array, msgs: Messages, op: str, spec: CommitSpec,
              backend: str) -> CommitResult:
    """Backend dispatch with fallback already resolved — shared by
    :func:`commit` and the sanitizer's shadow replay (which must NOT
    re-enter :func:`commit`, or the shadow would shadow itself)."""
    if backend == "atomic":
        return atomic_commit(state, msgs, op, stats=spec.stats)
    if backend == "coarse":
        return coarse_commit(state, msgs, op, m=spec.m, sort=spec.sort,
                             stats=spec.stats)
    if backend == "fused":
        return _fused_commit(state, msgs, op, spec)
    return _pallas_commit(state, msgs, op, spec)


def commit_batched(state: jax.Array, msgs: Messages, op: str,
                   spec: CommitSpec | None = None, *,
                   axis) -> CommitResult:
    """Commit an axis-fused batch against the axis's flat key space.

    ``axis`` is a batch axis (:class:`repro.core.coalescing.QueryLanes`,
    :class:`~repro.core.coalescing.GraphBatch`, or their composition
    :class:`~repro.core.coalescing.ProductAxis`); ``state`` is the
    flat [axis.flat_size] array and ``msgs.target`` carries flat keys
    (build them with :func:`repro.core.messages.batch_messages`), so
    ONE ``commit()`` call — any backend, including ``"auto"`` —
    resolves conflicts for every batch item at once.  Items occupy
    disjoint key ranges, so the result equals the looped per-item
    commits (bit-for-bit for the order-independent ops; float ``add``
    to rounding, exactly like any transaction-size change)."""
    if state.shape[0] != axis.flat_size:
        raise ValueError(f"state leading dim {state.shape[0]} != "
                         f"axis flat size {axis.flat_size}")
    return commit(state, msgs, op, spec)


def commit_lanes(state: jax.Array, msgs: Messages, op: str,
                 spec: CommitSpec | None = None) -> CommitResult:
    """Thin wrapper over :func:`commit_batched` for the query-lane axis:
    commit a lane-fused batch against [L, V] lane-major state (composite
    keys ``lane * V + v`` from :func:`repro.core.messages.lane_messages`).
    """
    from repro.core.coalescing import QueryLanes
    lanes, v = state.shape
    res = commit_batched(state.reshape(lanes * v), msgs, op, spec,
                         axis=QueryLanes(lanes, v))
    return dataclasses.replace(res, state=res.state.reshape(lanes, v))


def commit_product(state: jax.Array, msgs: Messages, op: str,
                   spec: CommitSpec | None = None, *,
                   axis) -> CommitResult:
    """Thin wrapper over :func:`commit_batched` for the lanes×graphs
    product axis: commit a product-fused batch against [L, Vtot]
    lane-major union state (composite keys ``lane * Vtot + flat`` from
    :func:`repro.core.messages.product_messages`); ``axis`` is the
    :class:`repro.core.coalescing.ProductAxis`."""
    lanes, vtot = state.shape
    if (lanes, vtot) != (axis.lanes, axis.num_vertices):
        raise ValueError(f"state shape {state.shape} != product axis "
                         f"({axis.lanes}, {axis.num_vertices})")
    res = commit_batched(state.reshape(lanes * vtot), msgs, op, spec,
                         axis=axis)
    return dataclasses.replace(res, state=res.state.reshape(lanes, vtot))


_PALLAS_DTYPES = (jnp.int32, jnp.float32)


def _pallas_supported(state, msgs: Messages, op: str) -> bool:
    payload = msgs.payload
    return (isinstance(payload, jax.Array) and payload.ndim == 1
            and state.ndim == 1
            and state.dtype in _PALLAS_DTYPES
            and payload.dtype in _PALLAS_DTYPES)


def _pallas_commit(state, msgs: Messages, op: str,
                   spec: CommitSpec) -> CommitResult:
    from repro.kernels.coarse_commit import coarse_commit_pallas
    idx = jnp.where(msgs.valid, msgs.target, -1).astype(jnp.int32)
    interpret = (spec.interpret if spec.interpret is not None
                 else jax.default_backend() != "tpu")
    tile_m = spec.m if spec.m is not None else spec.tile_m
    if not spec.stats:
        # cheap path: the kernel skips the per-block conflict reduction
        # and its extra output entirely
        new = coarse_commit_pallas(
            state, idx, msgs.payload, op=op, tile_m=tile_m,
            block_v=spec.block_v, interpret=interpret, stats=False)
        z = jnp.zeros((), jnp.int32)
        return CommitResult(new, msgs.valid, z, z)
    new, conflicts = coarse_commit_pallas(
        state, idx, msgs.payload, op=op, tile_m=tile_m,
        block_v=spec.block_v, interpret=interpret, stats=True)
    if op == "first":
        success, _, applied = _first_stats(state, msgs)
    else:
        success, _, applied = _success_stats(state, new, msgs, op)
    return CommitResult(new, success, conflicts, applied)


def _fused_commit(state, msgs: Messages, op: str,
                  spec: CommitSpec) -> CommitResult:
    """Generic-entry fused tier: plain local targets, no base/lane —
    the kernel's key computation folds away and this is launch-for-launch
    the pallas tier (the parity matrix and the tuner race treat it as
    such); the engine's :func:`fused_commit_site` is the fast path."""
    from repro.kernels.fused_wave import fused_route_commit_pallas
    idx = jnp.where(msgs.valid, msgs.target, -1).astype(jnp.int32)
    interpret = (spec.interpret if spec.interpret is not None
                 else jax.default_backend() != "tpu")
    tile_m = spec.m if spec.m is not None else spec.tile_m
    if not spec.stats:
        new = fused_route_commit_pallas(
            state, idx, msgs.payload, op=op, tile_m=tile_m,
            block_v=spec.block_v, interpret=interpret, stats=False)
        z = jnp.zeros((), jnp.int32)
        return CommitResult(new, msgs.valid, z, z)
    new, conflicts = fused_route_commit_pallas(
        state, idx, msgs.payload, op=op, tile_m=tile_m,
        block_v=spec.block_v, interpret=interpret, stats=True)
    if op == "first":
        success, _, applied = _first_stats(state, msgs)
    else:
        success, _, applied = _success_stats(state, new, msgs, op)
    return CommitResult(new, success, conflicts, applied)


def fused_site_supported(state, payload) -> bool:
    """Kernel envelope of the engine's fused fast path: 1-D int32/float32
    state slice, scalar-per-message payload leaf (flat [n] or the [P, C]
    exchanged buffer).  Vector payloads / other dtypes take the unfused
    per-leaf fallback in :func:`repro.core.engine.route_wave`."""
    return (isinstance(payload, jax.Array)
            and getattr(state, "ndim", 0) == 1
            and payload.ndim <= 2
            and state.dtype in _PALLAS_DTYPES
            and payload.dtype in _PALLAS_DTYPES)


def fused_commit_site(state, tgt, payload, op: str, spec: CommitSpec, *,
                      lane=None, base=None, width: int = 1) -> CommitResult:
    """Owner-side fused route+commit — THE commit site of the engine's
    fused fast path (:func:`repro.core.engine.route_wave`).

    ``tgt``/``payload``/``lane`` are the flattened post-exchange bucket
    buffers exactly as the all_to_all left them (``tgt`` global ids with
    ``-1`` empty-slot sentinels); ``base`` is the owner's first global
    vertex id (``shard * block``, traced) and ``width`` the batch-axis
    wave width.  One kernel launch computes local composite keys,
    reorders in VMEM, and commits — the ``local_idx``/``fuse_keys``/
    ``make_messages`` jnp intermediates never materialize.

    ``stats=False`` (the hot path) reports ``success = slot occupied``
    like every backend's cheap mode; ``stats=True`` reconstructs the
    local keys jnp-side ONLY for the MF success/applied accounting (the
    committed state still comes from the single launch).

    Runs under ``jax.named_scope("aam_commit")`` — the aamlint waverace
    pass recognizes in-scope ``pallas_call`` writes as the protected
    commit site and flags out-of-scope kernel writes.
    """
    interpret = (spec.interpret if spec.interpret is not None
                 else jax.default_backend() != "tpu")
    tile_m = spec.m if spec.m is not None else spec.tile_m
    kw = dict(lane=lane, base=base, width=width, op=op, tile_m=tile_m,
              block_v=spec.block_v, interpret=interpret)
    from repro.kernels.fused_wave import fused_route_commit_pallas
    with jax.named_scope("aam_commit"):
        if not spec.stats:
            new = fused_route_commit_pallas(state, tgt, payload,
                                            stats=False, **kw)
            z = jnp.zeros((), jnp.int32)
            return CommitResult(new, tgt >= 0, z, z)
        new, conflicts = fused_route_commit_pallas(state, tgt, payload,
                                                   stats=True, **kw)
        nrows = state.shape[0] // width
        rel = tgt - (0 if base is None else base)
        ok = (tgt >= 0) & (rel >= 0) & (rel < nrows)   # mirror the kernel
        local = jnp.where(ok, rel, 0)
        if width > 1:
            ok = ok & (lane >= 0) & (lane < width)
            local = local * width + jnp.where(ok, lane, 0)
        msgs = make_messages(local.astype(jnp.int32), payload, ok)
        if op == "first":
            success, _, applied = _first_stats(state, msgs)
        else:
            success, _, applied = _success_stats(state, new, msgs, op)
        return CommitResult(new, success, conflicts, applied)


# ---------------------------------------------------------------------------
# Tier 1: fine-grained baseline (per-message scatter = atomics analogue)
# ---------------------------------------------------------------------------


def atomic_commit(state: jax.Array, msgs: Messages, op: str,
                  stats: bool = True) -> CommitResult:
    """One scatter element per message; conflicts resolved by scatter
    semantics (the TPU analogue of a CAS/FAO per vertex)."""
    idx = jnp.where(msgs.valid, msgs.target, state.shape[0])  # OOB -> dropped
    val = msgs.payload
    old = state
    mode = jax.lax.GatherScatterMode.FILL_OR_DROP
    if op == "min":
        new = state.at[idx].min(val, mode=mode)
    elif op == "max":
        new = state.at[idx].max(val, mode=mode)
    elif op == "add":
        new = state.at[idx].add(jnp.where(
            _bcast(msgs.valid, val), val, jnp.zeros_like(val)), mode=mode)
    elif op == "or":
        # payload is a truth value: all tiers agree on max(state, val != 0)
        new = state.at[idx].max((val != 0).astype(state.dtype), mode=mode)
    elif op == "first":
        # first-writer-wins on empty slots (id -1 = empty), ties -> min msg id
        return _first_commit(state, msgs)
    else:
        raise ValueError(op)
    if not stats:
        z = jnp.zeros((), jnp.int32)
        return CommitResult(new, msgs.valid, z, z)
    success, conflicts, applied = _success_stats(old, new, msgs, op)
    return CommitResult(new, success, conflicts, applied)


def _bcast(mask, val):
    return mask.reshape(mask.shape + (1,) * (val.ndim - mask.ndim))


# ---------------------------------------------------------------------------
# Tier 2: coarse transactions (sort + in-tile conflict resolution)
# ---------------------------------------------------------------------------


def coarse_commit(state: jax.Array, msgs: Messages, op: str,
                  m: int | None = None, sort: bool = True,
                  stats: bool = True) -> CommitResult:
    """AAM coarse commit.

    Conflict resolution happens *before* touching state: duplicate targets
    inside the batch are reduced to one update per distinct target (sort by
    target + segment reduce), then committed with one conflict-free scatter.
    ``m`` is the transaction size — the batch is processed in ceil(n/m)
    tiles via ``lax.map`` (each tile = one "transaction"; the Pallas kernel
    executes one tile per grid step).  ``sort=False`` models uncoalesced
    message streams (pure in-tile resolution, cross-tile conflicts still hit
    the scatter path) — the benchmark knob for paper Fig 4.
    """
    n = msgs.capacity
    if m is None or m >= n:
        return _resolved_commit(state, msgs, op, sort=sort, stats=stats)

    pad = (-n) % m
    msgs_p = jax.tree.map(
        lambda x: jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)), msgs)
    msgs_p = dataclasses.replace(
        msgs_p, valid=jnp.pad(msgs.valid, (0, pad), constant_values=False))
    tiles = jax.tree.map(
        lambda x: x.reshape((n + pad) // m, m) if x.ndim == 1
        else x.reshape(((n + pad) // m, m) + x.shape[1:]), msgs_p)

    def tx(state, tile):
        r = _resolved_commit(state, tile, op, sort=sort, stats=stats)
        return r.state, (r.success, r.conflicts, r.applied)

    new_state, (succ, conf, app) = jax.lax.scan(tx, state, tiles)
    succ = succ.reshape(-1)[:n]
    return CommitResult(new_state, succ, jnp.sum(conf), jnp.sum(app))


def _resolved_commit(state, msgs: Messages, op: str, sort: bool,
                     stats: bool = True) -> CommitResult:
    """One transaction: resolve in-batch conflicts, then write state.

    sorted path (coalesced AAM): sort by target, reduce duplicate runs with
    a segmented associative scan (O(N log N), no O(V) buffers — this is the
    jnp mirror of the Pallas kernel's in-VMEM resolution), then ONE
    conflict-free scatter (unique targets).
    unsorted path: the uncoalesced stream — duplicates go straight to the
    scatter and conflicts serialize in the memory system (atomics-like).
    ``stats=False`` skips the O(V) success accounting and reports cheap
    O(N) conflict/applied counts (success == valid placeholder).
    """
    v = state.shape[0]
    idx = jnp.where(msgs.valid, msgs.target, v)
    if op == "first":
        return _first_commit(state, msgs)
    val = msgs.payload
    old = state
    mode = jax.lax.GatherScatterMode.FILL_OR_DROP

    if not sort:
        return atomic_commit(state, msgs, op, stats=stats)

    order = jnp.argsort(idx, stable=True)          # coalescing: sort by target
    s_idx = idx[order]
    s_val = val[order]
    s_valid = msgs.valid[order]

    if op == "add":
        s_val = jnp.where(_bcast(s_valid, s_val), s_val,
                          jnp.zeros_like(s_val))
    elif op == "or":
        s_val = (s_valid & s_val.astype(bool))

    # segmented inclusive scan over sorted runs of equal target
    first = jnp.concatenate([jnp.ones((1,), bool), s_idx[1:] != s_idx[:-1]])
    f = {"min": jnp.minimum, "max": jnp.maximum,
         "add": jnp.add, "or": jnp.logical_or}[op]

    def comb(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(_bcast(fb, vb), vb, f(va, vb))

    _, scanned = jax.lax.associative_scan(comb, (first, s_val))
    last = jnp.concatenate([first[1:], jnp.ones((1,), bool)])
    # one conflict-free write per distinct target (run reductions at `last`)
    w_idx = jnp.where(last, s_idx, v)
    if op == "add":
        new = state.at[w_idx].add(scanned.astype(state.dtype), mode=mode)
    elif op == "min":
        new = state.at[w_idx].min(scanned.astype(state.dtype), mode=mode)
    elif op == "max":
        new = state.at[w_idx].max(scanned.astype(state.dtype), mode=mode)
    else:  # or
        new = state.at[w_idx].max(scanned.astype(state.dtype), mode=mode)
    if stats:
        success, conflicts, applied = _success_stats(old, new, msgs, op)
    else:
        n_valid = jnp.sum(s_valid.astype(jnp.int32))
        n_runs = jnp.sum((first & s_valid).astype(jnp.int32))
        conflicts = n_valid - n_runs
        changed = new[jnp.clip(s_idx, 0, v - 1)] != old[jnp.clip(s_idx, 0, v - 1)]
        if changed.ndim > 1:    # vector payload: any component changed
            changed = jnp.any(changed, axis=tuple(range(1, changed.ndim)))
        applied = jnp.sum((last & s_valid & changed).astype(jnp.int32))
        success = msgs.valid
    return CommitResult(new, success, conflicts, applied)


def _first_winner(state, msgs: Messages, rank=None):
    """(winner_rank [V], takes [V]) for first-writer-wins into empty (-1)
    slots; in-batch ties -> lowest message index.

    ``rank`` overrides the per-message tiebreak key (default: position in
    the batch).  The sanitizer's permuted-order shadow replay passes the
    original indices here so the winner is order-independent."""
    v = state.shape[0]
    n = msgs.capacity
    idx = jnp.where(msgs.valid, msgs.target, v)
    msg_rank = (jnp.arange(n, dtype=jnp.int32) if rank is None
                else jnp.asarray(rank, jnp.int32))
    winner_rank = jax.ops.segment_min(msg_rank, idx, num_segments=v + 1)[:v]
    takes = (state < 0) & (winner_rank < n)
    return winner_rank, takes


def _first_stats(state, msgs: Messages):
    """(success, conflicts, applied) of a whole-batch 'first' commit
    against the pre-commit ``state``."""
    v = state.shape[0]
    winner_rank, takes = _first_winner(state, msgs)
    tgt = jnp.clip(msgs.target, 0, v - 1)
    msg_rank = jnp.arange(msgs.capacity, dtype=jnp.int32)
    success = msgs.valid & (msg_rank == winner_rank[tgt]) & (state < 0)[tgt]
    conflicts = jnp.sum(msgs.valid) - jnp.sum(takes)
    return success, conflicts.astype(jnp.int32), \
        jnp.sum(takes).astype(jnp.int32)


def _first_commit(state, msgs: Messages) -> CommitResult:
    """First-writer-wins into empty (-1) slots; in-batch ties -> lowest
    message index (the paper's 'one of them succeeds')."""
    n = msgs.capacity
    winner_rank, takes = _first_winner(state, msgs)
    winner_val = jnp.where(
        takes, msgs.payload[jnp.clip(winner_rank, 0, n - 1)], state)
    new = jnp.where(takes, winner_val, state)
    success, conflicts, applied = _first_stats(state, msgs)
    return CommitResult(new, success, conflicts, applied)


def _success_stats(old, new, msgs: Messages, op: str):
    n = msgs.capacity
    v = old.shape[0]
    tgt = jnp.clip(msgs.target, 0, v - 1)
    if op == "add":
        success = msgs.valid
        applied = jnp.sum(msgs.valid)
    elif op == "or":
        success = msgs.valid & ~old[tgt].astype(bool)
        applied = jnp.sum((new != old).astype(jnp.int32))
    else:  # min/max — MF: message wins iff it set the final value
        val = msgs.payload
        final = new[tgt]
        improved = (val == final) & (final != old[tgt]) & msgs.valid
        # first among equal winners
        msg_rank = jnp.arange(n, dtype=jnp.int32)
        rank_key = jnp.where(improved, msg_rank, n)
        idx = jnp.where(improved, msgs.target, v)
        first_rank = jax.ops.segment_min(rank_key, idx, num_segments=v + 1)[:v]
        success = improved & (msg_rank == first_rank[tgt])
        applied = jnp.sum((new != old).astype(jnp.int32))
    # conflicts = valid messages sharing a target with another message
    idx = jnp.where(msgs.valid, msgs.target, v)
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), idx,
                                 num_segments=v + 1)[:v]
    conflicts = jnp.sum(jnp.where(msgs.valid & (counts[tgt] > 1), 1, 0))
    return success, conflicts.astype(jnp.int32), applied.astype(jnp.int32)
