"""Commit engines — the HTM-transaction analogue (DESIGN.md §2).

Three tiers, mirroring the paper's atomics → HTM spectrum:

* :func:`atomic_commit` — one scatter element per message (XLA scatter with
  conflict semantics resolved by the memory system).  This is the
  *fine-grained atomics* baseline the paper compares against (Graph500-style
  CAS/ACC).
* :func:`coarse_commit` — the AAM path: messages are processed in
  "transactions" of M messages; each transaction's conflicts are resolved
  on-chip (sort + segment reduction over the tile) and the state is written
  once per distinct target.  Semantically identical, structurally what the
  Pallas kernel (:mod:`repro.kernels.coarse_commit`) does on TPU VMEM/MXU.
* the Pallas kernel itself (used on real TPU via ``use_pallas``).

All commits return a :class:`CommitResult` carrying MF success flags (the
"did my transaction win" bit routed back for FR messages) and conflict
telemetry (the abort-statistics analogue of paper Tables 3c/3f).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.messages import Messages

OPS = ("min", "max", "add", "or", "first")


def _identity(op: str, dtype):
    if op == "min":
        return jnp.array(jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer)
                         else jnp.inf, dtype)
    if op == "max":
        return jnp.array(jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer)
                         else -jnp.inf, dtype)
    if op == "add":
        return jnp.array(0, dtype)
    if op == "or":
        return jnp.array(False, bool)
    raise ValueError(op)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CommitResult:
    state: jax.Array        # updated state array [V] (or [V, d])
    success: jax.Array      # bool [n] — MF: message won; AS: valid mask
    conflicts: jax.Array    # int32 — duplicate-target messages this batch
    applied: jax.Array      # int32 — messages that changed state


# ---------------------------------------------------------------------------
# Tier 1: fine-grained baseline (per-message scatter = atomics analogue)
# ---------------------------------------------------------------------------


def atomic_commit(state: jax.Array, msgs: Messages, op: str,
                  stats: bool = True) -> CommitResult:
    """One scatter element per message; conflicts resolved by scatter
    semantics (the TPU analogue of a CAS/FAO per vertex)."""
    n = msgs.capacity
    idx = jnp.where(msgs.valid, msgs.target, state.shape[0])  # OOB -> dropped
    val = msgs.payload
    old = state
    mode = jax.lax.GatherScatterMode.FILL_OR_DROP
    if op == "min":
        new = state.at[idx].min(val, mode=mode)
    elif op == "max":
        new = state.at[idx].max(val, mode=mode)
    elif op == "add":
        new = state.at[idx].add(jnp.where(
            _bcast(msgs.valid, val), val, jnp.zeros_like(val)), mode=mode)
    elif op == "or":
        new = state.at[idx].max(val.astype(state.dtype), mode=mode)
    elif op == "first":
        # first-writer-wins on empty slots (id -1 = empty), ties -> min msg id
        return _first_commit(state, msgs)
    else:
        raise ValueError(op)
    if not stats:
        z = jnp.zeros((), jnp.int32)
        return CommitResult(new, msgs.valid, z, z)
    success, conflicts, applied = _success_stats(old, new, msgs, op)
    return CommitResult(new, success, conflicts, applied)


def _bcast(mask, val):
    return mask.reshape(mask.shape + (1,) * (val.ndim - mask.ndim))


# ---------------------------------------------------------------------------
# Tier 2: coarse transactions (sort + in-tile conflict resolution)
# ---------------------------------------------------------------------------


def coarse_commit(state: jax.Array, msgs: Messages, op: str,
                  m: int | None = None, sort: bool = True,
                  stats: bool = True) -> CommitResult:
    """AAM coarse commit.

    Conflict resolution happens *before* touching state: duplicate targets
    inside the batch are reduced to one update per distinct target (sort by
    target + segment reduce), then committed with one conflict-free scatter.
    ``m`` is the transaction size — the batch is processed in ceil(n/m)
    tiles via ``lax.map`` (each tile = one "transaction"; the Pallas kernel
    executes one tile per grid step).  ``sort=False`` models uncoalesced
    message streams (pure in-tile resolution, cross-tile conflicts still hit
    the scatter path) — the benchmark knob for paper Fig 4.
    """
    n = msgs.capacity
    if m is None or m >= n:
        return _resolved_commit(state, msgs, op, sort=sort, stats=stats)

    pad = (-n) % m
    msgs_p = jax.tree.map(
        lambda x: jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)), msgs)
    msgs_p = dataclasses.replace(
        msgs_p, valid=jnp.pad(msgs.valid, (0, pad), constant_values=False))
    tiles = jax.tree.map(
        lambda x: x.reshape((n + pad) // m, m) if x.ndim == 1
        else x.reshape(((n + pad) // m, m) + x.shape[1:]), msgs_p)

    def tx(state, tile):
        r = _resolved_commit(state, tile, op, sort=sort, stats=stats)
        return r.state, (r.success, r.conflicts, r.applied)

    new_state, (succ, conf, app) = jax.lax.scan(tx, state, tiles)
    succ = succ.reshape(-1)[:n]
    return CommitResult(new_state, succ, jnp.sum(conf), jnp.sum(app))


def _resolved_commit(state, msgs: Messages, op: str, sort: bool,
                     stats: bool = True) -> CommitResult:
    """One transaction: resolve in-batch conflicts, then write state.

    sorted path (coalesced AAM): sort by target, reduce duplicate runs with
    a segmented associative scan (O(N log N), no O(V) buffers — this is the
    jnp mirror of the Pallas kernel's in-VMEM resolution), then ONE
    conflict-free scatter (unique targets).
    unsorted path: the uncoalesced stream — duplicates go straight to the
    scatter and conflicts serialize in the memory system (atomics-like).
    ``stats=False`` skips the O(V) success accounting and reports cheap
    O(N) conflict/applied counts (success == valid placeholder).
    """
    n = msgs.capacity
    v = state.shape[0]
    idx = jnp.where(msgs.valid, msgs.target, v)
    if op == "first":
        return _first_commit(state, msgs)
    val = msgs.payload
    old = state
    mode = jax.lax.GatherScatterMode.FILL_OR_DROP

    if not sort:
        if stats:
            return atomic_commit(state, msgs, op)
        new = atomic_commit(state, msgs, op).state
        return CommitResult(new, msgs.valid, jnp.zeros((), jnp.int32),
                            jnp.zeros((), jnp.int32))

    order = jnp.argsort(idx, stable=True)          # coalescing: sort by target
    s_idx = idx[order]
    s_val = val[order]
    s_valid = msgs.valid[order]

    if op == "add":
        s_val = jnp.where(_bcast(s_valid, s_val), s_val,
                          jnp.zeros_like(s_val))
    elif op == "or":
        s_val = (s_valid & s_val.astype(bool))

    # segmented inclusive scan over sorted runs of equal target
    first = jnp.concatenate([jnp.ones((1,), bool), s_idx[1:] != s_idx[:-1]])
    f = {"min": jnp.minimum, "max": jnp.maximum,
         "add": jnp.add, "or": jnp.logical_or}[op]

    def comb(a, b):
        fa, va = a
        fb, vb = b
        return fa | fb, jnp.where(_bcast(fb, vb), vb, f(va, vb))

    _, scanned = jax.lax.associative_scan(comb, (first, s_val))
    last = jnp.concatenate([first[1:], jnp.ones((1,), bool)])
    # one conflict-free write per distinct target (run reductions at `last`)
    w_idx = jnp.where(last, s_idx, v)
    if op == "add":
        new = state.at[w_idx].add(scanned.astype(state.dtype), mode=mode)
    elif op == "min":
        new = state.at[w_idx].min(scanned.astype(state.dtype), mode=mode)
    elif op == "max":
        new = state.at[w_idx].max(scanned.astype(state.dtype), mode=mode)
    else:  # or
        new = state.at[w_idx].max(scanned.astype(state.dtype), mode=mode)
    if stats:
        success, conflicts, applied = _success_stats(old, new, msgs, op)
    else:
        n_valid = jnp.sum(s_valid.astype(jnp.int32))
        n_runs = jnp.sum((first & s_valid).astype(jnp.int32))
        conflicts = n_valid - n_runs
        changed = new[jnp.clip(s_idx, 0, v - 1)] != old[jnp.clip(s_idx, 0, v - 1)]
        applied = jnp.sum((last & s_valid & changed).astype(jnp.int32))
        success = msgs.valid
    return CommitResult(new, success, conflicts, applied)


def _segment(val, idx, op, num_segments):
    f = {"min": jax.ops.segment_min, "max": jax.ops.segment_max,
         "add": jax.ops.segment_sum}[op]
    return f(val, idx, num_segments=num_segments)


def _first_commit(state, msgs: Messages) -> CommitResult:
    """First-writer-wins into empty (-1) slots; in-batch ties -> lowest
    message index (the paper's 'one of them succeeds')."""
    v = state.shape[0]
    n = msgs.capacity
    idx = jnp.where(msgs.valid, msgs.target, v)
    msg_rank = jnp.arange(n, dtype=jnp.int32)
    winner_rank = jax.ops.segment_min(msg_rank, idx, num_segments=v + 1)[:v]
    empty = state < 0
    takes = empty & (winner_rank < n)
    val = msgs.payload
    winner_val = jnp.where(
        takes, val[jnp.clip(winner_rank, 0, n - 1)], state)
    new = jnp.where(takes, winner_val, state)
    success = msgs.valid & (msg_rank == winner_rank[jnp.clip(msgs.target, 0, v - 1)]) \
        & empty[jnp.clip(msgs.target, 0, v - 1)]
    conflicts = jnp.sum(msgs.valid) - jnp.sum(takes)
    return CommitResult(new, success, conflicts.astype(jnp.int32),
                        jnp.sum(takes).astype(jnp.int32))


def _success_stats(old, new, msgs: Messages, op: str):
    n = msgs.capacity
    v = old.shape[0]
    tgt = jnp.clip(msgs.target, 0, v - 1)
    if op == "add":
        success = msgs.valid
        applied = jnp.sum(msgs.valid)
    elif op == "or":
        success = msgs.valid & ~old[tgt].astype(bool)
        applied = jnp.sum((new != old).astype(jnp.int32))
    else:  # min/max — MF: message wins iff it set the final value
        val = msgs.payload
        final = new[tgt]
        improved = (val == final) & (final != old[tgt]) & msgs.valid
        # first among equal winners
        msg_rank = jnp.arange(n, dtype=jnp.int32)
        rank_key = jnp.where(improved, msg_rank, n)
        idx = jnp.where(improved, msgs.target, v)
        first_rank = jax.ops.segment_min(rank_key, idx, num_segments=v + 1)[:v]
        success = improved & (msg_rank == first_rank[tgt])
        applied = jnp.sum((new != old).astype(jnp.int32))
    # conflicts = valid messages sharing a target with another message
    idx = jnp.where(msgs.valid, msgs.target, v)
    counts = jax.ops.segment_sum(jnp.ones((n,), jnp.int32), idx,
                                 num_segments=v + 1)[:v]
    conflicts = jnp.sum(jnp.where(msgs.valid & (counts[tgt] > 1), 1, 0))
    return success, conflicts.astype(jnp.int32), applied.astype(jnp.int32)
