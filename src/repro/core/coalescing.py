"""Coalescing — bucket messages per destination shard (paper §4.2, §5.6).

On BG/Q the paper aggregates activities flowing to the same node into one
network message (factor C).  The TPU analogue: messages are bucketed into a
fixed-capacity ``[num_owners, C]`` buffer and exchanged with one
``all_to_all`` per round — C is the coalescing factor.  The same planning
code is the MoE token-dispatch planner (experts = owners, capacity factor =
C / expected load): DESIGN.md §3.

All shapes are static; overflow beyond capacity is *counted and kept* — the
caller re-queues dropped messages next round (label-correcting algorithms
tolerate deferral; MoE drops by priority like every capacity-factor router).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.messages import Messages


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class BucketPlan:
    """Routing plan for one coalescing round."""
    owner: jax.Array          # int32 [n] destination bucket per message
    position: jax.Array       # int32 [n] slot within the bucket (may exceed C)
    counts: jax.Array         # int32 [num_buckets] messages per bucket
    kept: jax.Array           # bool [n] — within capacity
    dropped: jax.Array        # int32 — overflow count (requeued by caller)


# Above this many buckets the dense planner's O(n·num_buckets) one-hot
# dominates memory (large-mesh routing); the sort-based planner computes the
# SAME stable ranks in O(n log n) — test_bucket_roundtrip pins the equality.
DENSE_PLANNER_MAX_BUCKETS = 32


# ---------------------------------------------------------------------------
# Batch axes — the first-class fusion dimension (ISSUE 5)
#
# The paper's lever is amortization: coarsening and coalescing pack many
# irregular updates into one atomic region so per-batch overhead is paid
# once (§4).  One level up, a server packs many independent WORK ITEMS
# into one wave.  A BatchAxis names what the items are and how they share
# one flat commit-key space:
#
# * QueryLanes(L, V)  — L queries over ONE graph: item l's private copy
#   of vertex v lives at flat key ``l * V + v``;
# * GraphBatch(sizes) — ONE query each over G graphs: graph g's vertex v
#   lives at flat key ``offset[g] + v`` (the disjoint-union key space of
#   ``repro.graphs.csr.GraphSet``);
# * ProductAxis(L, sizes) — the PRODUCT: up to L queries over EACH of G
#   graphs, flat key ``lane * Vtot + offset[g] + v`` (lane axis nested
#   over the graph axis — one wave serves many queries on many tenant
#   graphs at once, ISSUE 7).
#
# Items never collide (disjoint flat ranges), so conflict resolution over
# flat keys is exactly per-item conflict resolution: one commit() — any
# backend — equals the looped per-item commits (bit-for-bit for
# order-independent ops).  The axis-generic entry points are
# fuse_keys/split_keys here, batch_messages (repro.core.messages) and
# commit_batched (repro.core.commit); the lane-named forms are thin
# wrappers kept for the PR-4 surface.
# ---------------------------------------------------------------------------


# Largest admissible flat key space for int32 composite keys.  Commit
# backends reserve one slot PAST the state (``idx = flat_size`` is the
# drop sentinel and ``num_segments = flat_size + 1`` sizes the segment
# reductions), so the bound is iinfo(int32).max - 1, not .max: both the
# sentinel id and the segment count must stay representable.  Checked
# statically wherever a composite key space is born (the batch axes
# below, ``repro.core.engine.route_wave``'s vertex-major local keys) —
# the aamlint keyspace pass (repro.analysis.keyspace) re-derives the
# same bound as a diagnostic for axis shapes that never get built.
MAX_FLAT_KEYS = 2 ** 31 - 2


def require_key_space(flat_size: int, *, where: str) -> int:
    """Static int32-overflow guard for a composite commit-key space.

    Raises ``OverflowError`` when ``flat_size`` flat keys cannot be
    carried in int32 (keys are ``major * stride + minor`` int32
    arithmetic — beyond the bound they silently wrap and items ALIAS
    each other's state).  Call with python ints at trace/build time;
    returns ``flat_size`` so it can be used inline."""
    flat_size = int(flat_size)
    if flat_size > MAX_FLAT_KEYS:
        raise OverflowError(
            f"{where}: {flat_size} flat keys exceed the int32 key space "
            f"(max {MAX_FLAT_KEYS}; commit needs one extra slot for the "
            f"drop sentinel).  Shrink the batch (fewer lanes/graphs per "
            f"wave) or upcast the key pipeline to int64 "
            f"(jax.config.update('jax_enable_x64', True) plus int64 "
            f"targets end-to-end — fuse_keys, messages, commit).")
    return flat_size


def fuse_keys(major: jax.Array, minor: jax.Array, stride: int) -> jax.Array:
    """Axis-generic composite commit key ``major * stride + minor`` —
    THE place the composite-key convention lives; both layouts go
    through it:

    * major-major (single-shard [L, V] lane state):
      ``fuse_keys(lane, vertex, V)`` — see
      :func:`repro.core.messages.batch_messages`;
    * vertex-major (distributed [block * W] owner slices, all batch
      items of a vertex co-located on its owner shard):
      ``fuse_keys(local_vertex, item, W)`` — see
      :func:`repro.core.engine.route_wave`.

    Items never collide: conflict resolution over composite keys is
    exactly per-item conflict resolution, so one ``commit()`` call
    resolves every item's conflicts bit-identically to separate calls
    (for order-independent ops)."""
    return major.astype(jnp.int32) * stride + minor.astype(jnp.int32)


def split_keys(key: jax.Array, stride: int):
    """Inverse of :func:`fuse_keys`: ``(major, minor)``."""
    return key // stride, key % stride


def fuse_lane_keys(major: jax.Array, minor: jax.Array,
                   stride: int) -> jax.Array:
    """PR-4 name for :func:`fuse_keys` (the query-lane axis)."""
    return fuse_keys(major, minor, stride)


def split_lane_keys(key: jax.Array, stride: int):
    """PR-4 name for :func:`split_keys`."""
    return split_keys(key, stride)


@dataclasses.dataclass(frozen=True)
class QueryLanes:
    """Batch axis: L independent queries over one V-vertex graph.

    Flat key = ``lane * num_vertices + v`` (lane-major — each lane owns
    a contiguous [V] block, the layout the single-shard fused loops
    commit against).  Frozen + hashable: rides in jit static args and
    :class:`repro.core.engine.EngineConfig`."""
    lanes: int
    num_vertices: int

    def __post_init__(self):
        if int(self.lanes) < 1 or int(self.num_vertices) < 1:
            raise ValueError(f"QueryLanes needs lanes/num_vertices >= 1, "
                             f"got {self.lanes}/{self.num_vertices}")
        require_key_space(int(self.lanes) * int(self.num_vertices),
                          where="QueryLanes(L, V)")

    @property
    def flat_size(self) -> int:
        return self.lanes * self.num_vertices

    @property
    def wave_width(self) -> int:
        """Items co-located per vertex in the distributed vertex-major
        layout ([block * lanes] owner slices)."""
        return self.lanes

    @property
    def race_width(self) -> int:
        """Batch width the autotuner's race key records (the argsort of
        a fused wave spans all L lanes' messages)."""
        return self.lanes

    def flatten(self, major, minor) -> jax.Array:
        return fuse_keys(jnp.asarray(major), jnp.asarray(minor),
                         self.num_vertices)

    def unflatten(self, key):
        return split_keys(key, self.num_vertices)


@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """Batch axis: one query each over G independent graphs.

    ``sizes[g]`` is graph g's vertex count; flat key = ``offset[g] + v``
    with ``offset`` the exclusive prefix sum — the disjoint-union key
    space of :class:`repro.graphs.csr.GraphSet` (heterogeneous sizes,
    no padding).  Because the target ids of a stacked edge array are
    ALREADY flat, a graph-batched wave needs no extra item field:
    ``wave_width == 1`` and the engine routes/commits it exactly like a
    single graph (owner slices and coalescing buckets keyed by flat
    id)."""
    sizes: tuple

    def __post_init__(self):
        if not self.sizes or any(int(s) < 1 for s in self.sizes):
            raise ValueError(f"GraphBatch needs positive per-graph sizes, "
                             f"got {self.sizes}")
        require_key_space(sum(int(s) for s in self.sizes),
                          where="GraphBatch(sizes)")

    @property
    def offsets(self) -> tuple:
        out, acc = [], 0
        for s in self.sizes:
            out.append(acc)
            acc += int(s)
        return tuple(out)

    @property
    def flat_size(self) -> int:
        return sum(int(s) for s in self.sizes)

    @property
    def wave_width(self) -> int:
        return 1        # keys are already globally flat

    @property
    def race_width(self) -> int:
        """A graph-batched wave still fuses G graphs' messages into one
        commit — the race must not inherit the width-1 verdict even
        though no extra item field rides the exchange."""
        return len(self.sizes)

    def flatten(self, major, minor) -> jax.Array:
        offs = jnp.asarray(self.offsets, jnp.int32)
        return offs[jnp.asarray(major)] + jnp.asarray(minor, jnp.int32)

    def unflatten(self, key):
        bounds = jnp.asarray(self.offsets[1:] + (self.flat_size,),
                             jnp.int32)
        key = jnp.asarray(key, jnp.int32)
        major = jnp.searchsorted(bounds, key, side="right").astype(jnp.int32)
        offs = jnp.asarray(self.offsets, jnp.int32)
        return major, key - offs[jnp.clip(major, 0, len(self.sizes) - 1)]


@dataclasses.dataclass(frozen=True)
class ProductAxis:
    """Batch axis PRODUCT: up to L queries over EACH of G tenant graphs.

    The composite key nests the lane axis over the graph axis::

        flat = lane * Vtot + (offset[g] + v),   Vtot = sum(sizes)

    i.e. ``fuse_keys(lane, GraphBatch(sizes).flatten(g, v), Vtot)`` —
    lane-major over the disjoint-union key space, exactly the 2-mark
    nesting ``_union_stconn`` already uses (grey marks at ``[0, Vtot)``,
    green at ``[Vtot, 2*Vtot)``).  Cells (lane, graph) are independent
    work items occupying disjoint flat ranges, so one ``commit()`` over
    product keys resolves every cell's conflicts bit-identically to
    per-cell commits (order-independent ops).

    Degenerate forms collapse key-for-key onto the single axes
    (pinned by tests/test_product_axis.py)::

        ProductAxis(1, sizes).flatten3(0, g, v) == GraphBatch(sizes).flatten(g, v)
        ProductAxis(L, (V,)).flatten3(l, 0, v)  == QueryLanes(L, V).flatten(l, v)

    Frozen + hashable: rides in jit static args and
    :class:`repro.core.engine.EngineConfig` like the other axes."""
    lanes: int
    sizes: tuple

    def __post_init__(self):
        if int(self.lanes) < 1:
            raise ValueError(f"ProductAxis needs lanes >= 1, got {self.lanes}")
        if not self.sizes or any(int(s) < 1 for s in self.sizes):
            raise ValueError(f"ProductAxis needs positive per-graph sizes, "
                             f"got {self.sizes}")
        # L × Vtot is where the int32 hazard actually bites (a modest lane
        # budget times a big tenant union overflows long before either
        # axis would alone) — flatten3 arithmetic wraps silently past it
        require_key_space(int(self.lanes) * sum(int(s) for s in self.sizes),
                          where="ProductAxis(L, sizes): L * Vtot")

    @property
    def graph_axis(self) -> GraphBatch:
        """The inner (minor) axis — the union key space."""
        return GraphBatch(self.sizes)

    @property
    def num_graphs(self) -> int:
        return len(self.sizes)

    @property
    def num_vertices(self) -> int:
        """Union vertex count Vtot — the lane stride."""
        return sum(int(s) for s in self.sizes)

    @property
    def offsets(self) -> tuple:
        return self.graph_axis.offsets

    @property
    def flat_size(self) -> int:
        return self.lanes * self.num_vertices

    @property
    def wave_width(self) -> int:
        """Distributed vertex-major layout co-locates all L lanes of a
        union vertex on its owner shard ([block * lanes] slices); the
        graph coordinate is already folded into the flat vertex id, so
        only the lane id rides the exchange — same as QueryLanes."""
        return self.lanes

    @property
    def race_width(self) -> int:
        """The autotuner race key: a product wave's argsort spans every
        cell's messages — L lanes × G graphs."""
        return self.lanes * len(self.sizes)

    def flatten(self, major, minor) -> jax.Array:
        """2-part key: (lane, flat_union_vertex) -> product key."""
        return fuse_keys(jnp.asarray(major), jnp.asarray(minor),
                         self.num_vertices)

    def unflatten(self, key):
        """Inverse of :func:`flatten`: (lane, flat_union_vertex)."""
        return split_keys(key, self.num_vertices)

    def flatten3(self, lane, graph, v) -> jax.Array:
        """3-part key: (lane, graph, LOCAL vertex) -> product key."""
        return self.flatten(lane, self.graph_axis.flatten(graph, v))

    def split3(self, key):
        """Inverse of :func:`flatten3`: (lane, graph, local_vertex)."""
        lane, flat = self.unflatten(key)
        g, v = self.graph_axis.unflatten(flat)
        return lane, g, v


def plan_buckets(owner: jax.Array, valid: jax.Array, num_buckets: int,
                 capacity: int) -> BucketPlan:
    """Stable bucketing: position = rank of the message within its bucket
    in original order (priority = arrival order, like the paper's queues and
    like position-priority MoE routers).

    Dispatches to :func:`plan_buckets_sorted` above
    :data:`DENSE_PLANNER_MAX_BUCKETS` so large-mesh routing never
    materializes the O(n·num_buckets) one-hot; both planners produce
    identical plans (stable arrival-order ranks)."""
    if num_buckets > DENSE_PLANNER_MAX_BUCKETS:
        return plan_buckets_sorted(owner, valid, num_buckets, capacity)[0]
    return plan_buckets_dense(owner, valid, num_buckets, capacity)


def plan_buckets_dense(owner: jax.Array, valid: jax.Array, num_buckets: int,
                       capacity: int) -> BucketPlan:
    """The dense one-hot planner (O(n·num_buckets) — small bucket counts)."""
    n = owner.shape[0]
    owner = jnp.where(valid, owner, num_buckets)
    onehot = jax.nn.one_hot(owner, num_buckets + 1, dtype=jnp.int32)
    # rank within bucket = exclusive cumsum of one-hot along messages
    ranks = jnp.cumsum(onehot, axis=0) - onehot
    position = jnp.take_along_axis(ranks, owner[:, None], axis=1)[:, 0]
    counts = jnp.sum(onehot, axis=0)[:num_buckets]
    kept = valid & (position < capacity)
    dropped = jnp.sum(valid) - jnp.sum(kept)
    return BucketPlan(owner=owner.astype(jnp.int32),
                      position=position.astype(jnp.int32),
                      counts=counts, kept=kept,
                      dropped=dropped.astype(jnp.int32))


# Histogram backend for plan_buckets_sorted: "jnp" (bincount, default) or
# "pallas" (kernels.coalesce.bucket_count_pallas — one-hot tile sums in
# VMEM).  The env var sets the default; the keyword wins when given.
BUCKET_COUNT_ENV = "REPRO_BUCKET_COUNT"
_COUNT_BACKENDS = ("jnp", "pallas")


def _bucket_counts(owner_c: jax.Array, valid: jax.Array, num_buckets: int,
                   count_backend: str | None) -> jax.Array:
    backend = count_backend or os.environ.get(BUCKET_COUNT_ENV, "jnp")
    if backend not in _COUNT_BACKENDS:
        raise ValueError(
            f"count_backend={backend!r} not in {_COUNT_BACKENDS}")
    if backend == "pallas":
        from repro.kernels.coalesce import bucket_count_pallas
        masked = jnp.where(valid, owner_c, -1).astype(jnp.int32)
        interp = jax.default_backend() != "tpu"
        return bucket_count_pallas(masked, num_buckets=num_buckets,
                                   interpret=interp)
    return jnp.bincount(owner_c, length=num_buckets + 1)[:num_buckets]


def plan_buckets_sorted(owner: jax.Array, valid: jax.Array, num_buckets: int,
                        capacity: int,
                        count_backend: str | None = None,
                        ) -> tuple[BucketPlan, jax.Array]:
    """Sort-based planner (O(n log n) instead of O(n·buckets)); used when
    num_buckets is large (MoE with 128 experts).  Returns (plan, sort_order).

    ``count_backend`` selects the histogram path ("jnp" | "pallas"); unset
    it falls back to ``$REPRO_BUCKET_COUNT`` and then "jnp".
    """
    n = owner.shape[0]
    owner_c = jnp.where(valid, owner, num_buckets)
    order = jnp.argsort(owner_c, stable=True)
    sorted_owner = owner_c[order]
    counts = _bucket_counts(owner_c, valid, num_buckets, count_backend)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)])[:num_buckets + 1]
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[
        jnp.clip(sorted_owner, 0, num_buckets)].astype(jnp.int32)
    position = jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)
    kept = valid & (position < capacity)
    dropped = jnp.sum(valid) - jnp.sum(kept)
    return BucketPlan(owner=owner_c.astype(jnp.int32), position=position,
                      counts=counts.astype(jnp.int32), kept=kept,
                      dropped=dropped.astype(jnp.int32)), order


def scatter_to_buckets(plan: BucketPlan, payload: Any, num_buckets: int,
                       capacity: int, fill=0) -> Any:
    """Build the [num_buckets, capacity, ...] coalesced buffer (payload may
    be a pytree; int payloads fill with ``fill``)."""
    flat = plan.owner * capacity + jnp.where(plan.kept, plan.position, capacity)
    flat = jnp.where(plan.kept, flat, num_buckets * capacity)  # OOB drop

    def scat(x):
        buf = jnp.full((num_buckets * capacity + 1,) + x.shape[1:], fill,
                       x.dtype)
        buf = buf.at[flat].set(x, mode="drop")
        return buf[:-1].reshape((num_buckets, capacity) + x.shape[1:])
    return jax.tree.map(scat, payload)


def bucket_message_ids(plan: BucketPlan, num_buckets: int,
                       capacity: int) -> jax.Array:
    """[num_buckets, capacity] original message index per slot (-1 empty)."""
    ids = jnp.arange(plan.owner.shape[0], dtype=jnp.int32)
    buf = scatter_to_buckets(plan, ids + 1, num_buckets, capacity, fill=0)
    return buf - 1


def gather_from_buckets(buf: Any, plan: BucketPlan, capacity: int,
                        fill=0) -> Any:
    """Inverse of scatter_to_buckets: per-message gather of returned values
    (the FR return path)."""
    pos = jnp.where(plan.kept, plan.position, 0)
    def gat(x):
        nb, cap = x.shape[0], x.shape[1]
        flatx = x.reshape((nb * cap,) + x.shape[2:])
        idx = jnp.clip(plan.owner, 0, nb - 1) * cap + jnp.clip(pos, 0, cap - 1)
        out = flatx[idx]
        mask = plan.kept.reshape(plan.kept.shape + (1,) * (out.ndim - 1))
        return jnp.where(mask, out, fill)
    return jax.tree.map(gat, buf)
