"""Adaptive commit auto-tuner — closing the paper's §5.3–§5.4 loop.

The paper's performance analysis is about *choosing* HTM parameters:
mechanism tier (atomics vs transactions), transaction size M, coarsening
factor.  ``CommitSpec`` exposes them as static knobs; this module chooses
them at runtime, in two stages:

1. **Online calibration** (trace time, concrete).  Timed micro-commits of
   a synthetic workload run through every mechanism tier, the §5.3 affine
   model ``T(N) = B + A·N`` is fit per tier
   (:func:`repro.core.perf_model.fit`), the backend with the lowest
   predicted time at the workload's batch size wins, and
   :func:`~repro.core.perf_model.select_m` picks M* from the fine/coarse
   crossing point.  Results are cached process-wide, so a calibration runs
   once, not per jit trace.

2. **Conflict-feedback transaction sizing** (traced, per round).  The
   chosen M* seeds a position on a power-of-two *ladder* of transaction
   sizes; every round the conflict telemetry already carried by
   :class:`~repro.core.commit.CommitResult` (the paper's Tables 3c/3f
   abort statistics) updates the ladder level — abort storms shrink M
   (smaller speculative state, fewer conflicts per transaction), quiet
   rounds re-grow it.  The level is a traced ``int32``, the ladder a
   ``lax.switch`` over pre-built commit branches, so adaptation runs
   inside ``lax.while_loop`` round loops and under ``shard_map`` —
   mirroring DyAdHyTM's runtime mechanism switching on one device graph.

Entry points:

* ``CommitSpec(backend="auto")`` through :func:`repro.core.commit.commit`
  — resolved by :func:`resolve_spec` to a concrete calibrated spec
  (stage 1 only; per-callsite, zero API change).
* :func:`make_commit_step` — the uniform handle the single-shard wave
  loops thread through their carries (stages 1 + 2).
* :func:`policy_for` / :func:`ladder_commit` / :func:`next_level` — the
  pieces ``run_distributed`` plumbs through its round loop.

``REPRO_AUTOTUNE=off`` disables the timed calibration (deterministic
heuristic policy; conflict feedback stays on).  Pin a concrete backend in
the spec for bit-reproducible mechanism choice across hosts — final
*state* is backend-independent either way (the parity matrix pins it).
"""
from __future__ import annotations

import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import perf_model
from repro.core.commit import AUTO, BACKENDS, CommitSpec, CommitResult, \
    _pallas_supported, commit
from repro.core.messages import Messages, make_messages

# Power-of-two transaction-size ladder (None = whole batch, the M -> inf
# column of paper Fig 4).  Chosen to bracket the kernel's VMEM-capacity
# analogue: 4096 * block_v is the largest speculative working set swept in
# benchmarks/fig4_coarsening.py.
M_LADDER: tuple = (16, 64, 256, 1024, 4096, None)

# Conflict-density waterlines (conflicts / routed messages per round).
# Above HIGH the serialization analogue dominates -> shrink M; below LOW
# transactions are conflict-free -> amortize more dispatch overhead per
# transaction by growing M.  Between them the level holds (hysteresis).
HIGH_WATER = 0.30
LOW_WATER = 0.05


@dataclasses.dataclass(frozen=True)
class TunerPolicy:
    """Resolved calibration output — frozen + hashable so it can ride in
    an :class:`~repro.core.engine.EngineConfig` or a jit static arg.

    ``adaptive=False`` (atomic tier: M is meaningless) makes
    :func:`ladder_commit`/:func:`next_level` degenerate to a plain commit.
    """
    backend: str
    ladder: tuple = M_LADDER
    init_level: int = len(M_LADDER) - 1
    adaptive: bool = True
    high_water: float = HIGH_WATER
    low_water: float = LOW_WATER
    sort: bool = True
    stats: bool = True
    tile_m: int = 256
    block_v: int = 512
    interpret: bool | None = None
    sanitize: bool = False

    def spec_at(self, level: int) -> CommitSpec:
        """Concrete CommitSpec for one ladder level."""
        return CommitSpec(backend=self.backend, m=self.ladder[level],
                          sort=self.sort, stats=self.stats,
                          tile_m=self.tile_m, block_v=self.block_v,
                          interpret=self.interpret, sanitize=self.sanitize)


@dataclasses.dataclass(frozen=True)
class Calibration:
    """Per-tier affine fits from one timed micro-benchmark run."""
    fine: perf_model.LinearFit          # per-message activity model
    tiers: tuple                        # ((backend, LinearFit), ...)

    def tier(self, backend: str) -> perf_model.LinearFit | None:
        for b, f in self.tiers:
            if b == backend:
                return f
        return None


def _autotune_enabled() -> bool:
    return os.environ.get("REPRO_AUTOTUNE", "on").lower() not in (
        "off", "0", "false")


# ---------------------------------------------------------------------------
# Persistent calibration cache (survives processes)
# ---------------------------------------------------------------------------
#
# Calibration is timed micro-benchmarking: ~100ms of wall clock per knob
# set.  Long-lived servers pay it once, but short-lived CLI runs (every
# `benchmarks.run` child, every `make bench-json`) re-pay it per process.
# The JSON cache next to BENCH_*.json persists the fitted tiers across
# processes, keyed by knob set + device kind (fits are only portable
# within one accelerator class).  REPRO_AUTOTUNE_CACHE names the file
# (default .repro_autotune_cache.json in the cwd) or "off" disables it —
# a corrupt/alien file is ignored, never fatal.

CACHE_SCHEMA = "aam-autotune/v1"
_CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
_CACHE_DEFAULT = ".repro_autotune_cache.json"


def _cache_path() -> str | None:
    v = os.environ.get(_CACHE_ENV, "")
    if v.lower() in ("off", "0", "false"):
        return None
    return v or _CACHE_DEFAULT


def _fit_to_json(f: perf_model.LinearFit) -> dict:
    return {"intercept": f.intercept, "slope": f.slope, "r2": f.r2}


def _fit_from_json(d) -> perf_model.LinearFit:
    return perf_model.LinearFit(intercept=float(d["intercept"]),
                                slope=float(d["slope"]), r2=float(d["r2"]))


def _sanitize(f: perf_model.LinearFit) -> perf_model.LinearFit:
    """Clamp a measured fit to the physical region (B, A >= 0).

    Tiny-N timings are noisy; a slightly negative fitted slope
    extrapolated to a large workload N would predict NEGATIVE time and
    hand the win to the slowest tier."""
    return perf_model.LinearFit(intercept=max(f.intercept, 0.0),
                                slope=max(f.slope, 0.0), r2=f.r2)


class AutoTuner:
    """Process-wide calibration cache + policy factory.

    Measurements use a fixed synthetic ``min``-commit workload (int32,
    ``v_cal`` vertices) — the mechanism cost is dominated by the
    sort/scatter/kernel structure shared by every op, so one calibration
    serves all five ops; the per-call knobs that DO change the executed
    code (``sort``/``stats``/kernel tiles/interpret) key the cache.
    """

    def __init__(self, *, ns=(8, 64, 512), v_cal: int = 1 << 12,
                 warmup: int = 1, repeats: int = 3):
        self.ns = tuple(ns)
        self.v_cal = v_cal
        self.warmup = warmup
        self.repeats = repeats
        self._cache: dict = {}
        self._disk: dict | None = None      # lazy-loaded JSON entries
        # timed micro-benchmark invocations this process — a restored
        # warm service asserts this stays flat (zero recalibration)
        self.timed_runs = 0
        # decision audit log: every calibration fit, finalist race, and
        # policy verdict, with the measurements that justified it
        # (bounded FIFO; ladder moves stream via repro.obs.wavetap)
        self.audit: list[dict] = []

    def _audit(self, event: dict) -> None:
        self.audit.append(event)
        if len(self.audit) > 512:
            del self.audit[:len(self.audit) - 512]

    # -- persistent cache -------------------------------------------------

    def _disk_entries(self) -> dict:
        if self._disk is None:
            self._disk = {}
            p = _cache_path()
            if p and os.path.exists(p):
                try:
                    with open(p) as f:
                        doc = json.load(f)
                    if doc.get("schema") == CACHE_SCHEMA:
                        self._disk = dict(doc.get("entries", {}))
                except (OSError, ValueError):
                    pass                     # corrupt cache = no cache
        return self._disk

    def _disk_put(self, key: str, value) -> None:
        # the in-memory entry dict is ALWAYS updated (it is what
        # export_entries snapshots), even when no cache file is
        # configured — only the file write is conditional
        entries = self._disk_entries()
        entries[key] = value
        p = _cache_path()
        if p is None:
            return
        try:
            tmp = f"{p}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump({"schema": CACHE_SCHEMA, "entries": entries}, f,
                          indent=1)
                f.write("\n")
            os.replace(tmp, p)               # atomic vs concurrent readers
        except OSError:
            pass                             # read-only cwd = no cache

    def export_entries(self) -> dict:
        """Every calibration fit and race verdict this tuner knows, in
        the portable JSON disk-cache format (:data:`CACHE_SCHEMA`
        entries) — what a service snapshot persists."""
        return dict(self._disk_entries())

    def import_entries(self, entries: dict) -> None:
        """Warm this tuner from exported entries (snapshot restore).
        Entries already measured in this process win — imports only fill
        gaps, so a restore can never clobber fresher local fits."""
        mine = self._disk_entries()
        for k, v in dict(entries).items():
            mine.setdefault(k, v)

    def _knob_key(self, *, sort, stats, tile_m, block_v, interpret,
                  op="min", dtype=jnp.int32, width=1) -> str:
        # per-op calibration (ISSUE 5): the commit op and payload
        # dtype/width key the fit — `add` runs a different reduction
        # (MXU-path accumulate) and vector payloads a different memory
        # shape than the `min` scalar workload, so they get their own
        # affine fits instead of inheriting min's backend pick
        return (f"{jax.default_backend()}|sort={sort}|stats={stats}"
                f"|tile_m={tile_m}|block_v={block_v}|interpret={interpret}"
                f"|ns={list(self.ns)}|v={self.v_cal}"
                f"|op={op}|dtype={np.dtype(dtype).name}|w={width}")

    # -- measurement ------------------------------------------------------

    def _time(self, fn, *args) -> float:
        import time
        self.timed_runs += 1
        for _ in range(self.warmup):
            jax.block_until_ready(fn(*args))
        ts = []
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            ts.append(time.perf_counter() - t0)
        # min, not median: micro-benchmark noise is one-sided (scheduler
        # preemption only ever ADDS time), and a polluted sample here
        # would mis-seed the whole policy
        return min(ts)

    def _workload(self, n: int, v: int | None = None, *, op: str = "min",
                  dtype=jnp.int32, width: int = 1, axis_width: int = 1):
        """Synthetic commit batch: n ``op``-messages into a [v] (or
        [v, width]) state (default ``v_cal``).  ``v`` lets the race
        reproduce the caller's contention — n/v is the duplicate-target
        factor, and it decides whether the sorted tier's
        dedup-before-scatter pays for itself.  ``axis_width`` > 1
        reproduces a fused batch's composite-key structure: each
        message targets its own item's contiguous key range, the exact
        input distribution the sorted tier's argsort sees on a
        lane/graph-fused wave."""
        v = min(v or self.v_cal, 1 << 20)
        dtype = jnp.dtype(dtype)
        rng = np.random.default_rng(0)
        shape = (v,) if width == 1 else (v, width)
        if op == "min":
            fill = jnp.iinfo(dtype).max if jnp.issubdtype(dtype, jnp.integer) \
                else jnp.inf
        elif op == "max":
            fill = jnp.iinfo(dtype).min if jnp.issubdtype(dtype, jnp.integer) \
                else -jnp.inf
        elif op == "first":
            fill = -1
        else:                                # add / or accumulate from 0
            fill = 0
        state = jnp.full(shape, fill, dtype)
        if axis_width > 1:
            stride = max(v // axis_width, 1)
            item = rng.integers(0, axis_width, n)
            tgt = jnp.asarray(item * stride
                              + rng.integers(0, stride, n), jnp.int32)
        else:
            tgt = jnp.asarray(rng.integers(0, v, n), jnp.int32)
        vshape = (n,) if width == 1 else (n, width)
        if op == "or":
            val = jnp.asarray(rng.integers(0, 2, vshape), dtype)
        elif jnp.issubdtype(dtype, jnp.integer):
            val = jnp.asarray(rng.integers(0, 100, vshape), dtype)
        else:
            val = jnp.asarray(rng.random(vshape), dtype)
        return state, make_messages(tgt, val)

    def calibrate(self, *, sort: bool, stats: bool, tile_m: int,
                  block_v: int, interpret: bool | None,
                  with_pallas: bool, op: str = "min", dtype=jnp.int32,
                  width: int = 1) -> Calibration:
        """Timed micro-commits -> per-tier affine fits (cached per
        knob set AND per (op, payload dtype, payload width))."""
        dtype = jnp.dtype(dtype)
        key = ("cal", sort, stats, tile_m, block_v, interpret, with_pallas,
               op, dtype.name, width)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        dkey = "cal|" + self._knob_key(sort=sort, stats=stats,
                                       tile_m=tile_m, block_v=block_v,
                                       interpret=interpret, op=op,
                                       dtype=dtype, width=width) \
            + f"|pallas={with_pallas}"
        disk = self._disk_entries().get(dkey)
        if disk is not None:
            try:
                cal = Calibration(
                    fine=_fit_from_json(disk["fine"]),
                    tiers=tuple((b, _fit_from_json(f))
                                for b, f in disk["tiers"]))
                self._cache[key] = cal
                return cal                   # no timed micro-commits
            except (KeyError, TypeError, ValueError):
                pass
        wl = dict(op=op, dtype=dtype, width=width)
        # fine tier: ONE message per activity => T_fine(N) = N * t_unit
        state, msgs1 = self._workload(1, **wl)
        spec_f = CommitSpec(backend="atomic", stats=stats)
        t_unit = self._time(
            jax.jit(lambda s, m: commit(s, m, op, spec_f).state),
            state, msgs1)
        fine = perf_model.LinearFit(intercept=0.0, slope=t_unit, r2=1.0)
        tiers = []
        backends = [b for b in BACKENDS
                    if with_pallas or b not in KERNEL_BACKENDS]
        for b in backends:
            spec = CommitSpec(backend=b, m=None, sort=sort, stats=stats,
                              tile_m=tile_m, block_v=block_v,
                              interpret=interpret)
            fn = jax.jit(lambda s, m, spec=spec:
                         commit(s, m, op, spec).state)
            times = [self._time(fn, *self._workload(n, **wl))
                     for n in self.ns]
            tiers.append((b, _sanitize(perf_model.fit(self.ns, times))))
        cal = Calibration(fine=fine, tiers=tuple(tiers))
        self._cache[key] = cal
        self._disk_put(dkey, {
            "fine": _fit_to_json(fine),
            "tiers": [[b, _fit_to_json(f)] for b, f in cal.tiers]})
        self._audit({
            "event": "calibrate", "op": op, "dtype": dtype.name,
            "width": width, "with_pallas": with_pallas,
            "t_unit_us": round(t_unit * 1e6, 3),
            "tiers": {b: {"intercept_us": round(f.intercept * 1e6, 3),
                          "slope_us": round(f.slope * 1e6, 4),
                          "r2": round(f.r2, 4)} for b, f in tiers}})
        return cal

    def race(self, finalists: dict, n: int, *, sort: bool, stats: bool,
             tile_m: int, block_v: int,
             interpret: bool | None, v: int | None = None,
             op: str = "min", dtype=jnp.int32, width: int = 1,
             axis_width: int = 1) -> str:
        """Head-to-head at (near-)workload batch size.

        ``finalists`` maps backend -> the transaction size it would
        actually RUN with (its ladder seed M*; None = whole batch) — a
        whole-batch race would make tiers that only differ when tiled
        indistinguishable.  Affine fits from tiny-N points separate tiers
        that differ in shape, but tiers within ~20% of each other at the
        workload's N are inside extrapolation error — measure them
        directly (cached per power-of-two N bucket) and let the clock
        decide.  ``axis_width`` (lanes or graphs of a fused batch) keys
        the race and shapes its workload: the sorted tier's argsort cost
        on a W-item fused batch is what gets measured, so the
        sort-vs-scatter verdict is decided per axis width, not
        globally."""
        dtype = jnp.dtype(dtype)
        n = min(1 << (max(n, 2) - 1).bit_length(), 32768)
        v = min(v or self.v_cal, 1 << 20)   # same clamp as _workload, so
        #                                     the cache key matches what
        #                                     actually gets timed
        axis_width = min(axis_width, n)
        key = ("race", tuple(sorted(finalists.items(),
                                    key=lambda kv: kv[0])), n, v,
               sort, stats, tile_m, block_v, interpret,
               op, dtype.name, width, axis_width)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        dkey = "race|" + "|".join(
            f"{b}:{m}" for b, m in sorted(finalists.items())) \
            + f"|n={n}|v={v}|aw={axis_width}|" \
            + self._knob_key(sort=sort, stats=stats, tile_m=tile_m,
                             block_v=block_v, interpret=interpret,
                             op=op, dtype=dtype, width=width)
        disk = self._disk_entries().get(dkey)
        if disk in finalists:                # winner must still be a runner
            self._cache[key] = disk
            return disk
        times = {}
        for b, m in finalists.items():
            spec = CommitSpec(backend=b, m=m, sort=sort, stats=stats,
                              tile_m=tile_m, block_v=block_v,
                              interpret=interpret)
            fn = jax.jit(lambda s, msgs, spec=spec:
                         commit(s, msgs, op, spec).state)
            times[b] = self._time(fn, *self._workload(
                n, v, op=op, dtype=dtype, width=width,
                axis_width=axis_width))
        winner = min(times, key=times.get)
        self._cache[key] = winner
        self._disk_put(dkey, winner)
        self._audit({
            "event": "race", "op": op, "n": n, "v": v,
            "axis_width": axis_width,
            "finalists": {b: m for b, m in finalists.items()},
            "times_us": {b: round(t * 1e6, 2) for b, t in times.items()},
            "winner": winner})
        return winner

    # -- policy -----------------------------------------------------------

    def policy(self, spec: CommitSpec, *, n: int,
               pallas_ok: bool, v: int | None = None, op: str = "min",
               dtype=jnp.int32, width: int = 1,
               axis_width: int = 1) -> TunerPolicy:
        pol = self._policy(spec, n=n, pallas_ok=pallas_ok, v=v, op=op,
                           dtype=dtype, width=width,
                           axis_width=axis_width)
        m0 = pol.ladder[pol.init_level] if pol.ladder else None
        self._audit({
            "event": "policy", "op": op, "n": int(n),
            "axis_width": axis_width, "backend": pol.backend,
            "m0": m0, "init_level": pol.init_level,
            "adaptive": pol.adaptive})
        return pol

    def _policy(self, spec: CommitSpec, *, n: int,
                pallas_ok: bool, v: int | None = None, op: str = "min",
                dtype=jnp.int32, width: int = 1,
                axis_width: int = 1) -> TunerPolicy:
        """Backend + M* + ladder seed for an n-message workload against a
        [v] state (``v`` shapes the race's duplicate-target factor; None
        = the calibration default).  ``op``/``dtype``/``width`` key the
        per-op calibration; ``axis_width`` is the fused batch-axis width
        (lanes or graphs) the race reproduces."""
        n = max(int(n), 1)
        base = dict(sort=spec.sort, stats=spec.stats, tile_m=spec.tile_m,
                    block_v=spec.block_v, interpret=spec.interpret)
        wl = dict(op=op, dtype=dtype, width=width)
        if not _autotune_enabled():
            # deterministic fallback: the paper's default tier (coarse
            # transactions), M* at the Fig-4 sweet spot bounded by n
            m_star = min(1024, 1 << max(n - 1, 1).bit_length())
            if spec.m is None and spec.seed_m is not None:
                m_star = spec.seed_m or n   # 0 = whole batch
            backend = "coarse"
        else:
            cal = self.calibrate(with_pallas=pallas_ok, **base, **wl)
            cap = max(min(4096, 1 << (n - 1).bit_length()), 2)

            def m_for(b):
                # the M this tier would seed its ladder with (atomic
                # ignores M -> whole batch); a user-pinned m wins
                if b == "atomic":
                    return None
                if spec.m is not None:
                    return spec.m
                if spec.seed_m is not None:
                    return spec.seed_m or None   # 0 = whole batch
                f = cal.tier(b) or cal.tiers[0][1]
                return perf_model.select_m(cal.fine, f, cap=cap)

            preds = {b: float(f.predict(n)) for b, f in cal.tiers}
            ranked = sorted(preds, key=preds.get)
            backend = ranked[0]
            # far beyond the calibration points the affine fits are pure
            # extrapolation (a noise-clamped slope of ~0 predicts
            # constant time at ANY n — it handed lane-fused serving
            # batches to the sorted tier, whose argsort grows with the
            # fused size): race whenever n leaves the measured regime,
            # not only when the predictions are close
            extrapolated = n > 4 * max(self.ns)
            if (len(ranked) > 1
                    and (extrapolated
                         or preds[ranked[0]] > 0.8 * preds[ranked[1]])):
                # race the two finalists at the workload's size, each at
                # the M it would actually run with
                backend = self.race({b: m_for(b) for b in ranked[:2]}, n,
                                    v=v, axis_width=axis_width,
                                    **base, **wl)
            m_star = m_for(backend) or n
        if spec.m is not None:
            # user pinned the transaction size: tune the backend only
            return TunerPolicy(backend=backend, ladder=(spec.m,),
                               init_level=0, adaptive=False,
                               sanitize=spec.sanitize, **base)
        if backend == "atomic":
            return TunerPolicy(backend=backend, adaptive=False,
                               sanitize=spec.sanitize, **base)
        # stage-2 feedback needs conflict telemetry: stats=True (full), or
        # the sorted coarse path's cheap O(N) counters.  Without either
        # (e.g. coarse sort=False stats=False routes through the raw
        # scatter, conflicts=0) density reads 0.0 forever — degrade
        # honestly to the calibrated static M* instead of pretending.
        has_telemetry = spec.stats or (backend == "coarse" and spec.sort)
        level = next((i for i, m in enumerate(M_LADDER)
                      if m is not None and m >= m_star), len(M_LADDER) - 1)
        if m_star >= n:          # whole batch fits one transaction
            level = len(M_LADDER) - 1
        return TunerPolicy(backend=backend, ladder=M_LADDER,
                           init_level=level, adaptive=has_telemetry,
                           sanitize=spec.sanitize, **base)


DEFAULT_TUNER = AutoTuner()

# The kernel tiers share one interpret-vs-compiled story: both run the
# same Pallas tile loop (fused additionally folds the route-side key
# computation into the launch), so eligibility is decided for the pair.
KERNEL_BACKENDS = ("pallas", "fused")

_ALLOW_INTERP_ENV = "REPRO_AUTOTUNE_ALLOW_INTERP"


def _allow_interp() -> bool:
    """Escape hatch: let interpret-mode kernel tiers into the candidate
    set anyway (tests exercising the auto->fused selection path on CPU
    set ``REPRO_AUTOTUNE_ALLOW_INTERP=1``)."""
    return os.environ.get(_ALLOW_INTERP_ENV, "").lower() in (
        "1", "true", "on", "yes")


def _kernel_compiled(spec: CommitSpec) -> bool:
    """True when the kernel tiers (pallas/fused) would run COMPILED for
    this spec.

    Interpret mode (CPU) is a functional simulator — its flat, huge
    per-grid-step overhead makes tiny-N calibration fits extrapolate
    deceptively, and it is never a performance contender.  Fitting the
    §5.3 cost model on interpret-mode timings teaches the tuner a lie,
    so both kernel tiers stay out of the candidate set unless the kernel
    actually compiles (or the :data:`_ALLOW_INTERP_ENV` escape hatch is
    set)."""
    if _allow_interp():
        return True
    if spec.interpret is not None:
        return not spec.interpret
    return jax.default_backend() == "tpu"


# Back-compat alias (pre-fused name).
_pallas_compiled = _kernel_compiled


def policy_for(spec: CommitSpec, state, msgs: Messages | None = None, *,
               n: int | None = None, op: str = "min",
               tuner: AutoTuner | None = None,
               axis_width: int = 1) -> TunerPolicy:
    """Resolve an ``"auto"`` spec against a concrete workload shape.

    ``state``/``msgs`` may be tracers — only shapes/dtypes are read; the
    timed calibration runs on synthetic concrete arrays at trace time.
    ``op`` and the payload dtype/width key the per-op calibration;
    ``axis_width`` is the batch-axis width (query lanes / graphs) of a
    fused caller, recorded in the race key so the sort-vs-scatter
    verdict is per axis width."""
    tuner = tuner or DEFAULT_TUNER
    width = 1
    dtype = getattr(state, "dtype", jnp.int32)
    if msgs is not None:
        pallas_ok = _pallas_supported(state, msgs, op)
        n = msgs.capacity if n is None else n
        payload = msgs.payload
        if isinstance(payload, (jax.Array, jax.ShapeDtypeStruct)) \
                or hasattr(payload, "dtype"):
            dtype = payload.dtype
            if getattr(payload, "ndim", 1) > 1:
                width = int(payload.shape[1])
    else:
        pallas_ok = (getattr(state, "ndim", 1) == 1
                     and state.dtype in (jnp.int32, jnp.float32))
        n = 1 if n is None else n
    if pallas_ok and not _kernel_compiled(spec):
        # autotune-on-interpret fix: the kernel tiers would run in
        # interpret mode here — exclude them rather than fit the cost
        # model on simulator timings (audited so the decision is
        # inspectable; REPRO_AUTOTUNE_ALLOW_INTERP=1 overrides)
        (tuner or DEFAULT_TUNER)._audit({
            "event": "kernel_tiers_excluded",
            "backends": list(KERNEL_BACKENDS), "op": op,
            "reason": "interpret-mode (no compiled TPU kernel); timings "
                      "would be simulator artifacts",
            "escape_hatch": _ALLOW_INTERP_ENV})
        pallas_ok = False
    v = getattr(state, "shape", None)
    v = v[0] if v else None         # [V] or [W*V] composite key space
    return tuner.policy(spec, n=n, pallas_ok=pallas_ok, v=v, op=op,
                        dtype=dtype, width=width, axis_width=axis_width)


def resolve_spec(spec: CommitSpec, state, msgs: Messages,
                 op: str) -> CommitSpec:
    """``commit()``'s hook: auto spec -> concrete calibrated spec.

    A user-pinned ``m`` survives (the policy pins its ladder to it)."""
    pol = policy_for(spec, state, msgs, op=op)
    return pol.spec_at(pol.init_level)


# ---------------------------------------------------------------------------
# Stage 2: the conflict-feedback ladder (traced)
# ---------------------------------------------------------------------------


def ladder_commit(state, msgs: Messages, op: str, policy: TunerPolicy,
                  level) -> CommitResult:
    """Commit at the ladder level selected by the traced ``level`` index.

    A ``lax.switch`` over one pre-built branch per ladder entry — every
    branch returns identical shapes (final state is M-independent, pinned
    by ``test_parity_matrix_tiled``), so the transaction size can change
    round-to-round inside ``lax.while_loop``/``shard_map``.
    """
    if not policy.adaptive or msgs.capacity == 0:
        return commit(state, msgs, op, policy.spec_at(policy.init_level))
    branches = [
        (lambda s, m, _sp=policy.spec_at(i): commit(s, m, op, _sp))
        for i in range(len(policy.ladder))
    ]
    lvl = jnp.clip(jnp.asarray(level, jnp.int32), 0, len(branches) - 1)
    return jax.lax.switch(lvl, branches, state, msgs)


def ladder_fused_site(state, tgt, payload, op: str, policy: TunerPolicy,
                      level, *, lane=None, base=None, width: int = 1):
    """Fused-tier twin of :func:`ladder_commit` for the engine's
    owner-side fast path: commit the exchanged buffers through
    :func:`repro.core.commit.fused_commit_site` at the ladder level
    selected by the traced ``level`` (a ``lax.switch`` over one
    pre-built kernel launch per transaction size)."""
    from repro.core.commit import fused_commit_site
    kw = dict(lane=lane, base=base, width=width)
    if not policy.adaptive or level is None:
        return fused_commit_site(state, tgt, payload, op,
                                 policy.spec_at(policy.init_level), **kw)
    branches = [
        (lambda s, t, p, _sp=policy.spec_at(i):
         fused_commit_site(s, t, p, op, _sp, **kw))
        for i in range(len(policy.ladder))
    ]
    lvl = jnp.clip(jnp.asarray(level, jnp.int32), 0, len(branches) - 1)
    return jax.lax.switch(lvl, branches, state, tgt, payload)


def next_level(policy: TunerPolicy, level, conflicts, messages):
    """One feedback step: conflict density -> ladder move.

    density > high_water (abort storm)  => level-1 (shrink M);
    density < low_water  (quiet round)  => level+1 (grow M);
    otherwise hold.  All inputs replicated scalars, so every shard of a
    distributed run moves in lockstep.
    """
    if not policy.adaptive:
        return level
    level = jnp.asarray(level, jnp.int32)
    dens = (conflicts.astype(jnp.float32)
            / jnp.maximum(messages.astype(jnp.float32), 1.0))
    step = (jnp.where(dens < policy.low_water, 1, 0)
            - jnp.where(dens > policy.high_water, 1, 0))
    return jnp.clip(level + step, 0, len(policy.ladder) - 1)


def make_commit_step(spec: CommitSpec | None, op: str, state, msgs_like=None,
                     *, n: int | None = None, axis_width: int = 1,
                     label: str | None = None):
    """Uniform per-round commit handle for the single-shard wave loops.

    Returns ``(step, level0)`` where ``step(state, msgs, level) ->
    (CommitResult, level')``.  For concrete backends the level is a dummy
    passthrough; for ``backend="auto"`` stage-1 calibration seeds the
    ladder and ``step`` applies stage-2 conflict feedback.  Call at trace
    time (outside the loop), carry ``level`` through the loop.
    ``axis_width`` is the fused batch-axis width (query lanes / graphs)
    of the caller's wave — see :meth:`AutoTuner.race`.

    When tracing is on at trace time (``spec.trace`` or
    ``REPRO_TRACE=1``) the step is wrapped with the
    :mod:`repro.obs.wavetap` commit tap — one ``io_callback`` per
    commit streaming (conflicts, applied, messages, ladder level) under
    ``label`` — THE hook that instruments all six single-shard loops
    and the ``ProductWave`` chunk bodies at once.
    """
    from repro.obs.trace import trace_enabled
    trace_on = trace_enabled() or (spec is not None and spec.trace)
    level0 = jnp.zeros((), jnp.int32)
    if spec is None or spec.backend != AUTO:
        def step(state, msgs, level, _spec=spec):
            return commit(state, msgs, op, _spec), level
        if trace_on:
            from repro.obs import wavetap
            step = wavetap.tap_commit_step(
                step, label=label or op, op=op,
                backend=spec.backend if spec is not None else "default")
        return step, level0
    policy = policy_for(spec, state, msgs_like, n=n, op=op,
                        axis_width=axis_width)

    def step(state, msgs, level):
        res = ladder_commit(state, msgs, op, policy, level)
        nv = jnp.sum(msgs.valid.astype(jnp.int32))
        return res, next_level(policy, level, res.conflicts, nv)

    if trace_on:
        from repro.obs import wavetap
        step = wavetap.tap_commit_step(step, label=label or op, op=op,
                                       backend=policy.backend)
    return step, jnp.asarray(policy.init_level, jnp.int32)
