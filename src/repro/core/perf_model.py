"""The paper's performance model (§5.3): T(N) = B + A·N.

Fine (atomics-analogue) and coarse (transaction-analogue) commit paths are
both affine in the number of modified vertices N; coarse has higher
intercept B (per-transaction dispatch/commit overhead) but lower slope A
(conflict resolution on-chip instead of per-element memory-system round
trips).  The crossing point N* = (B_c - B_f) / (A_f - A_c) predicts the
transaction size where coarsening starts to win — validated against
measurement in ``benchmarks/fig2_perf_model.py``, used to pre-select M* in
``select_m``.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinearFit:
    intercept: float       # B — per-activity overhead
    slope: float           # A — per-vertex cost
    r2: float

    def predict(self, n):
        return self.intercept + self.slope * np.asarray(n)


def fit(ns, times) -> LinearFit:
    ns = np.asarray(ns, dtype=np.float64)
    ts = np.asarray(times, dtype=np.float64)
    a, b = np.polyfit(ns, ts, 1)
    pred = a * ns + b
    ss_res = float(np.sum((ts - pred) ** 2))
    ss_tot = float(np.sum((ts - ts.mean()) ** 2)) or 1e-30
    return LinearFit(intercept=float(b), slope=float(a),
                     r2=1.0 - ss_res / ss_tot)


def crossing_point(fine: LinearFit, coarse: LinearFit) -> float | None:
    """N above which one coarse activity beats N fine activities.

    Fine path cost for N vertices: N · (B_f + A_f)   (one activity each).
    Coarse path: B_c + A_c · N  (one activity, N vertices)."""
    per_vertex_fine = fine.intercept + fine.slope
    if per_vertex_fine <= coarse.slope:
        return None            # coarsening never wins
    return coarse.intercept / (per_vertex_fine - coarse.slope)


def select_m(fine: LinearFit, coarse: LinearFit, *, cap: int = 4096,
             safety: float = 2.0) -> int:
    """Pick a transaction size comfortably past the crossing point but
    bounded by the VMEM-capacity analogue ``cap`` (paper: HTM buffer).

    The result is a power of two and NEVER exceeds ``cap``: rounding up
    could overshoot the speculative-state capacity (e.g. ``cap=3000`` with
    ``n*safety >= 2049`` used to return 4096), so an overshooting round-up
    falls back to the largest power of two <= cap."""
    n = crossing_point(fine, coarse)
    if n is None:
        return 1
    m = int(max(2, min(cap, n * safety)))
    p = 1 << (m - 1).bit_length()      # round to power of two tiles
    while p > cap:                     # respect the HTM-buffer cap
        p >>= 1
    return max(p, 1)
