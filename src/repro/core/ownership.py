"""Distributed multi-vertex transactions — the ownership protocol (§4.3).

The paper's protocol: a transaction touching remote vertices CAS-marks each
element's *ownership marker*, migrates marked elements, retries on conflict
with random backoff (livelock possible — §5.7).

TPU adaptation (DESIGN.md §7): synchronous *bidding rounds*.  Every pending
transaction bids for ALL its vertices with a min-commit of its rotating
priority key (the CAS analogue — lowest bid wins the marker); a transaction
that wins every bid applies atomically this round, everyone else retries
next round.  Rotating priorities make the protocol deterministic and
livelock-free (the globally-minimal pending transaction always wins all its
bids), replacing random backoff.

Used by ``benchmarks/fig5_coalescing.py`` scenarios O-1..O-4.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import compat
from jax.sharding import PartitionSpec as Ps

from repro.core import commit as C
from repro.core.engine import EngineConfig, wave_until_delivered
from repro.core.messages import make_messages


@dataclasses.dataclass
class TxnStats:
    rounds: jax.Array           # rounds until all transactions committed
    retries: jax.Array          # total (txn, round) retry events
    bids: jax.Array             # total bid messages sent


def run_transactions(mesh, txns, num_vertices: int, *, axis: str = "data",
                     capacity: int = 2048, max_rounds: int = 1024):
    """txns: int32 [P, X, K] global vertex ids per shard-local transaction.
    Applies visited |= 1 to every vertex of every transaction, atomically
    per transaction.  Returns (visited [V], TxnStats)."""
    P = mesh.shape[axis]
    X, K = txns.shape[1], txns.shape[2]
    block = -(-num_vertices // P)
    vpad = P * block
    total = P * X
    ecfg_bid = EngineConfig(P, block, capacity, axis=axis, op="min")
    ecfg_apply = EngineConfig(P, block, capacity, axis=axis, op="or")

    def shard_fn(txn):
        txn = txn[0]                                    # [X, K]
        shard = jax.lax.axis_index(axis)
        gid = shard * X + jnp.arange(X, dtype=jnp.int32)
        # duplicate vertices inside one transaction bid once (the dup lanes
        # auto-succeed — a transaction cannot conflict with itself)
        dup = jnp.zeros((X, K), bool)
        for k in range(1, K):
            dup = dup.at[:, k].set(
                jnp.any(txn[:, :k] == txn[:, k:k + 1], axis=1))

        def cond(c):
            done, visited, it, *_ = c
            n = jax.lax.psum(jnp.sum((~done).astype(jnp.int32)), axis)
            return (n > 0) & (it < max_rounds)

        def body(c):
            done, visited, it, retries, bids = c
            prio = (gid + it * jnp.int32(1000003)) % total
            key = prio * total + gid   # unique, rotating; needs total^2 < 2^31
            markers = jnp.full((block,), jnp.int32(2 ** 30), jnp.int32)
            targets = txn.reshape(X * K)
            payload = jnp.repeat(key, K)
            valid = jnp.repeat(~done, K) & ~dup.reshape(X * K)
            markers, success, _, _, _ = wave_until_delivered(
                ecfg_bid, markers, targets, payload, valid)
            granted = success.reshape(X, K) | dup
            win = jnp.all(granted, axis=1) & ~done
            # winners apply atomically (visited-mark wave)
            visited, _, _, _, _ = wave_until_delivered(
                ecfg_apply, visited, targets,
                jnp.ones((X * K,), bool), jnp.repeat(win, K))
            retries = retries + jnp.sum((~done & ~win).astype(jnp.int32))
            bids = bids + jnp.sum(valid.astype(jnp.int32))
            return done | win, visited, it + 1, retries, bids

        done0 = jnp.zeros((X,), bool)
        vis0 = jnp.zeros((block,), bool)
        z = jnp.zeros((), jnp.int32)
        done, visited, rounds, retries, bids = jax.lax.while_loop(
            cond, body, (done0, vis0, z, z, z))
        all_done = jax.lax.psum(jnp.sum(done.astype(jnp.int32)), axis)
        return visited, rounds, retries, bids, all_done

    fn = compat.shard_map(shard_fn, mesh=mesh, in_specs=(Ps(axis),),
                       out_specs=(Ps(axis), Ps(), Ps(), Ps(), Ps()),
                       check_vma=False)
    visited, rounds, retries, bids, all_done = jax.jit(fn)(txns)
    assert int(all_done) == total, (int(all_done), total)
    return (visited[:num_vertices],
            TxnStats(rounds=rounds, retries=retries, bids=bids))
