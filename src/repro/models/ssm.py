"""Mamba2 (SSD — state-space duality) mixer, chunked-scan formulation.

Math (per head h, state size N, head dim P):
    H_t = exp(A_h * dt_t) * H_{t-1} + dt_t * (B_t ⊗ x_t)        H: [P, N]
    y_t = H_t @ C_t + D_h * x_t
The chunked algorithm splits S into chunks of length L: an intra-chunk
quadratic (attention-like) term computed on the MXU plus an inter-chunk
recurrence over chunk states via ``lax.scan`` — the standard SSD trade that
maps the recurrence onto matmul hardware (this IS the TPU-native layout; no
CUDA-specific mechanism is ported, see DESIGN.md §7).

``ssm_ref`` is the sequential oracle used by property tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm


def ssm_init(cfg: ModelConfig, key, dtype=jnp.float32):
    d, din = cfg.d_model, cfg.d_inner
    g, st, nh, kk = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_kernel
    ks = jax.random.split(key, 12)
    p, a = {}, {}
    p["wz"], a["wz"] = dense_init(ks[0], (d, din), ("embed", "ssm_inner"), dtype)
    p["wx"], a["wx"] = dense_init(ks[1], (d, din), ("embed", "ssm_inner"), dtype)
    p["wB"], a["wB"] = dense_init(ks[2], (d, g * st), ("embed", "ssm_state"), dtype)
    p["wC"], a["wC"] = dense_init(ks[3], (d, g * st), ("embed", "ssm_state"), dtype)
    p["wdt"], a["wdt"] = dense_init(ks[4], (d, nh), ("embed", "ssm_heads"), dtype)
    p["conv_x"], a["conv_x"] = dense_init(
        ks[5], (kk, din), ("conv_kernel", "ssm_inner"), dtype, scale=(1 / kk) ** 0.5)
    p["conv_B"], a["conv_B"] = dense_init(
        ks[6], (kk, g * st), ("conv_kernel", "ssm_state"), dtype, scale=(1 / kk) ** 0.5)
    p["conv_C"], a["conv_C"] = dense_init(
        ks[7], (kk, g * st), ("conv_kernel", "ssm_state"), dtype, scale=(1 / kk) ** 0.5)
    # A in [-16, -1): A_log ~ log(U[1, 16))
    u = jax.random.uniform(ks[8], (nh,), minval=1.0, maxval=16.0)
    p["A_log"], a["A_log"] = jnp.log(u).astype(dtype), ("ssm_heads",)
    p["D"], a["D"] = jnp.ones((nh,), dtype), ("ssm_heads",)
    # dt init: softplus(dt_bias) ~ logspace[1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[9], (nh,),
                                    minval=jnp.log(1e-3), maxval=jnp.log(1e-1)))
    p["dt_bias"], a["dt_bias"] = (
        (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype), ("ssm_heads",))
    p["norm"], a["norm"] = jnp.ones((din,), dtype), ("ssm_inner",)
    p["wo"], a["wo"] = dense_init(ks[10], (din, d), ("ssm_inner", "embed"), dtype)
    return p, a


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: [B, S, C]; w: [K, C].

    state: [B, K-1, C] previous inputs (decode/prefill chaining) or None.
    Returns (y [B, S, C], new_state [B, K-1, C]).
    """
    k = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype)
            for i in range(k))
    new_state = xp[:, -(k - 1):] if k > 1 else state
    return y, new_state


def _segsum_mask(a):
    """a: [..., L] log-decays -> M[..., t, s] = exp(sum_{s<u<=t} a_u), s<=t."""
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]       # [..., t, s]
    tri = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(tri, jnp.exp(diff), 0.0)


def _project(cfg, p, x):
    cd = x.dtype
    z = x @ p["wz"].astype(cd)
    xin = x @ p["wx"].astype(cd)
    B = x @ p["wB"].astype(cd)
    C = x @ p["wC"].astype(cd)
    dt = jax.nn.softplus((x @ p["wdt"].astype(cd)).astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return z, xin, B, C, dt


def _finish(cfg, p, y, x_heads, z):
    b, s = y.shape[0], y.shape[1]
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * x_heads.astype(jnp.float32)
    y = y.reshape(b, s, cfg.d_inner).astype(z.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"].astype(z.dtype)


def ssm_apply(cfg: ModelConfig, p, x, *, chunk: int = 128, initial_state=None,
              use_pallas: bool = False):
    """x: [B, S, d]. Returns (out [B, S, d], (conv_state, ssm_state))."""
    b, s, _ = x.shape
    nh, hd, st, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xin, B, C, dt = _project(cfg, p, x)

    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    conv_state_in = initial_state[0] if initial_state is not None else None
    conv_out, conv_state = _causal_conv(conv_in, conv_w, conv_state_in)
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :cfg.d_inner]
    B = conv_out[..., cfg.d_inner:cfg.d_inner + g * st]
    C = conv_out[..., cfg.d_inner + g * st:]

    L = min(chunk, s)
    while s % L:
        L -= 1
    nc = s // L
    xh = xin.reshape(b, nc, L, nh, hd).astype(jnp.float32)
    Bh = B.reshape(b, nc, L, g, st).astype(jnp.float32)
    Ch = C.reshape(b, nc, L, g, st).astype(jnp.float32)
    # broadcast groups over heads
    hpg = nh // g
    Bh = jnp.repeat(Bh, hpg, axis=3)                     # [b, nc, L, nh, st]
    Ch = jnp.repeat(Ch, hpg, axis=3)
    dtc = dt.reshape(b, nc, L, nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))         # [nh]
    a = dtc * A[None, None, None, :]                     # log decay [b,nc,L,nh]
    a_t = jnp.swapaxes(a, -1, -2)                        # [b, nc, nh, L]
    xdt = xh * dtc[..., None]                            # dt-weighted input

    # --- intra-chunk (quadratic, MXU-friendly) ---
    if use_pallas:
        # fused Pallas kernel: one (batch·chunk·head) cell per grid step
        from repro.kernels import ops as kops
        g_ = b * nc * nh
        Cg = Ch.transpose(0, 1, 3, 2, 4).reshape(g_, L, st)
        Bg = Bh.transpose(0, 1, 3, 2, 4).reshape(g_, L, st)
        xg = xdt.transpose(0, 1, 3, 2, 4).reshape(g_, L, hd)
        ag = a_t.reshape(g_, L)
        yg = kops.ssd_chunk(Cg, Bg, xg, ag)
        y_intra = yg.reshape(b, nc, nh, L, hd).transpose(0, 1, 3, 2, 4)
    else:
        G = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)     # [b,nc,nh,L,L]
        M = _segsum_mask(a_t)                            # [b,nc,nh,L,L]
        y_intra = jnp.einsum("bchls,bcshp->bclhp", G * M, xdt)

    # --- chunk states ---
    cs = jnp.cumsum(a_t, axis=-1)
    decay_to_end = jnp.exp(cs[..., -1:] - cs)            # [b,nc,nh,L]
    S_c = jnp.einsum("bchl,bclhn,bclhp->bchpn", decay_to_end, Bh, xdt)

    # --- inter-chunk recurrence ---
    chunk_decay = jnp.exp(cs[..., -1])                   # [b,nc,nh]
    h0 = (initial_state[1].astype(jnp.float32) if initial_state is not None
          else jnp.zeros((b, nh, hd, st), jnp.float32))

    def body(h, inp):
        dec, s_c = inp                                   # [b,nh], [b,nh,hd,st]
        h_new = h * dec[..., None, None] + s_c
        return h_new, h                                  # emit state *before* chunk

    (h_final, h_prevs) = jax.lax.scan(
        body, h0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_c, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                # [b,nc,nh,hd,st]

    decay_from_start = jnp.exp(cs)                       # [b,nc,nh,L]
    y_inter = jnp.einsum("bclhn,bchpn,bchl->bclhp", Ch, h_prevs,
                         decay_from_start)
    y = (y_intra + y_inter).reshape(b, s, nh, hd)
    out = _finish(cfg, p, y, xin.reshape(b, s, nh, hd), z)
    return out, (conv_state, h_final.astype(jnp.float32))


def ssm_decode(cfg: ModelConfig, p, x, conv_state, ssm_state):
    """One-token decode. x: [B, 1, d]. States as returned by ssm_apply."""
    b = x.shape[0]
    nh, hd, st, g = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    z, xin, B, C, dt = _project(cfg, p, x)
    conv_in = jnp.concatenate([xin, B, C], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    conv_out, conv_state = _causal_conv(conv_in, conv_w,
                                        conv_state.astype(conv_in.dtype))
    conv_out = jax.nn.silu(conv_out)
    xin = conv_out[..., :cfg.d_inner]
    B = conv_out[..., cfg.d_inner:cfg.d_inner + g * st]
    C = conv_out[..., cfg.d_inner + g * st:]

    xh = xin.reshape(b, nh, hd).astype(jnp.float32)
    Bh = jnp.repeat(B.reshape(b, g, st), nh // g, axis=1).astype(jnp.float32)
    Ch = jnp.repeat(C.reshape(b, g, st), nh // g, axis=1).astype(jnp.float32)
    dt1 = dt[:, 0]                                       # [b, nh]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dec = jnp.exp(dt1 * A[None, :])                      # [b, nh]
    h = ssm_state.astype(jnp.float32)
    h = h * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt1, xh, Bh)
    y = jnp.einsum("bhpn,bhn->bhp", h, Ch)[:, None]      # [b, 1, nh, hd]
    out = _finish(cfg, p, y, xh[:, None], z)
    return out, (conv_state, h.astype(jnp.float32))


def ssm_ref(cfg: ModelConfig, p, x):
    """Sequential oracle: step ssm_decode over every position."""
    b, s, _ = x.shape
    nh, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_state = jnp.zeros((b, cfg.ssm_conv_kernel - 1,
                            cfg.d_inner + 2 * cfg.ssm_groups * st), x.dtype)
    h = jnp.zeros((b, nh, hd, st), jnp.float32)
    outs = []
    for t in range(s):
        o, (conv_state, h) = ssm_decode(cfg, p, x[:, t:t + 1], conv_state, h)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
