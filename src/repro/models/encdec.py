"""Encoder-decoder backbone (whisper-small).

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings [B, encoder_seq, d_model].  Encoder = bidirectional
attention stack; decoder = causal self-attention + cross-attention stack with
learned positional embeddings.  Cross K/V are computed once at prefill.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, RunConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models.lm import _constraint, _embed_in, _is_axes, _remat


def _enc_layer_init(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 2)
    p, a = {}, {}
    p["norm1"], a["norm1"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["mixer"], a["mixer"] = attn.attn_init(cfg, ks[0], dtype)
    p["norm2"], a["norm2"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["mlp"], a["mlp"] = L.mlp_init(cfg, ks[1], dtype=dtype)
    return p, a


def _dec_layer_init(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 3)
    p, a = _enc_layer_init(cfg, key, dtype)
    p["norm_cross"], a["norm_cross"] = L.rmsnorm_init(cfg.d_model, dtype)
    p["cross"], a["cross"] = attn.attn_init(cfg, ks[2], dtype, cross=True)
    return p, a


def init_encdec(cfg: ModelConfig, key, param_dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    params, axes = {}, {}
    params["embed"], axes["embed"] = L.embed_init(cfg, ks[0], param_dtype)
    # encoder positional table (separate from decoder's learned positions)
    params["enc_pos"], axes["enc_pos"] = L.dense_init(
        ks[3], (cfg.encoder_seq, cfg.d_model), ("pos", "embed"),
        param_dtype, scale=0.02)

    def stack(init_fn, n, key):
        bkeys = jax.random.split(key, n)
        stacked = jax.vmap(lambda k: init_fn(cfg, k, param_dtype)[0])(bkeys)
        _, a = init_fn(cfg, key, param_dtype)
        return stacked, jax.tree.map(lambda ax: (None,) + ax, a,
                                     is_leaf=_is_axes)

    params["encoder"], axes["encoder"] = stack(
        _enc_layer_init, cfg.encoder_layers, ks[1])
    params["decoder"], axes["decoder"] = stack(
        _dec_layer_init, cfg.num_layers, ks[2])
    params["enc_final_norm"], axes["enc_final_norm"] = L.rmsnorm_init(
        cfg.d_model, param_dtype)
    params["final_norm"], axes["final_norm"] = L.rmsnorm_init(
        cfg.d_model, param_dtype)
    return params, axes


def encode(cfg: ModelConfig, rcfg: RunConfig, params, frames):
    """frames: [B, Se, d] stub embeddings -> encoder states [B, Se, d]."""
    cd = jnp.dtype(rcfg.compute_dtype)
    x = frames.astype(cd) + params["enc_pos"].astype(cd)[None]
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    call = attn.AttnCall(causal=False, window=None, use_rope=False)

    def layer(x, p):
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, _ = attn.attn_apply(cfg, p["mixer"], h, positions, call)
        x = x + y
        h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp_apply(cfg, p["mlp"], h)
        return _constraint(x, ("batch", "seq", "act_embed")), None

    x, _ = jax.lax.scan(_remat(layer, rcfg), x, params["encoder"])
    return L.rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def _cross_kv(cfg, p, enc):
    k, v = attn.project_kv(cfg, p["cross"], enc,
                           jnp.zeros(enc.shape[:2], jnp.int32),
                           use_rope=False)
    return k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)


def forward(cfg: ModelConfig, rcfg: RunConfig, params, tokens, frames,
            mode="train"):
    """Teacher-forced decoder over encoder states.

    Returns (logits, cache|None, metrics). cache = (self_kv, cross_kv)."""
    enc = encode(cfg, rcfg, params, frames)
    x, positions = _embed_in(cfg, rcfg, params, tokens)
    call = attn.AttnCall(causal=True, window=None, use_rope=False)

    def layer(x, p):
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, (k, v) = attn.attn_apply(cfg, p["mixer"], h, positions, call)
        x = x + y
        h = L.rmsnorm(x, p["norm_cross"], cfg.norm_eps)
        x = x + attn.cross_attn_apply(cfg, p["cross"], h, *_cross_kv(cfg, p, enc))
        h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp_apply(cfg, p["mlp"], h)
        x = _constraint(x, ("batch", "seq", "act_embed"))
        if mode == "prefill":
            ck, cv = _cross_kv(cfg, p, enc)
            cache = {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16),
                     "pos": positions[0].astype(jnp.int32),
                     "cross_k": ck, "cross_v": cv}
        else:
            cache = None
        return x, cache

    x, cache = jax.lax.scan(_remat(layer, rcfg), x, params["decoder"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(cfg, params["embed"], x)
    logits = _constraint(logits, ("batch", "seq", "vocab"))
    metrics = {"moe_dropped": jnp.zeros((), jnp.int32),
               "moe_aux": jnp.zeros((), jnp.float32)}
    return logits, cache, metrics


def init_cache(cfg: ModelConfig, rcfg: RunConfig, batch: int, max_len: int):
    cd = jnp.bfloat16
    kvshape = (batch, max_len, cfg.num_kv_heads, cfg.head_dim)
    crshape = (batch, cfg.encoder_seq, cfg.num_kv_heads, cfg.head_dim)
    e = {"k": jnp.zeros(kvshape, cd), "v": jnp.zeros(kvshape, cd),
         "pos": jnp.full((max_len,), -1, jnp.int32),
         "cross_k": jnp.zeros(crshape, cd), "cross_v": jnp.zeros(crshape, cd)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), e)


def decode_step(cfg: ModelConfig, rcfg: RunConfig, params, cache, token, pos):
    """token: [B, 1]; decode one step against cached self+cross K/V."""
    x, _ = _embed_in(cfg, rcfg, params, token, pos_offset=pos)
    call = attn.AttnCall(causal=True, window=None, use_rope=False)

    def layer(x, inp):
        p, c = inp
        h = L.rmsnorm(x, p["norm1"], cfg.norm_eps)
        y, ck, cv, cp = attn.attn_decode(cfg, p["mixer"], h, pos, c["k"],
                                         c["v"], c["pos"], call)
        x = x + y
        h = L.rmsnorm(x, p["norm_cross"], cfg.norm_eps)
        x = x + attn.cross_attn_apply(cfg, p["cross"], h, c["cross_k"],
                                      c["cross_v"])
        h = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        x = x + L.mlp_apply(cfg, p["mlp"], h)
        new_c = {"k": ck, "v": cv, "pos": cp,
                 "cross_k": c["cross_k"], "cross_v": c["cross_v"]}
        return x, new_c

    x, new_cache = jax.lax.scan(layer, x, (params["decoder"], cache))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = L.lm_logits(cfg, params["embed"], x)
    return logits, new_cache
