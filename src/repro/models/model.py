"""Unified model API: init / loss / prefill / decode across all families,
plus ``input_specs`` — ShapeDtypeStruct stand-ins for every model input
(the dry-run contract; no device allocation).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig, ShapeConfig
from repro.models import encdec, lm


def is_encdec(cfg: ModelConfig) -> bool:
    return cfg.encoder_layers > 0


def init(cfg: ModelConfig, key, param_dtype=jnp.float32):
    if is_encdec(cfg):
        return encdec.init_encdec(cfg, key, param_dtype)
    return lm.init_lm(cfg, key, param_dtype)


def _forward(cfg, rcfg, params, batch, mode):
    if is_encdec(cfg):
        return encdec.forward(cfg, rcfg, params, batch["tokens"],
                              batch["frames"], mode=mode)
    extra = batch.get("patch_embeds")
    return lm.forward(cfg, rcfg, params, batch["tokens"],
                      extra_embeds=extra, mode=mode)


def loss_fn(cfg: ModelConfig, rcfg: RunConfig, params, batch):
    """Next-token cross entropy (labels < 0 are ignored) + MoE aux."""
    logits, _, metrics = _forward(cfg, rcfg, params, batch, mode="train")
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # vlm prefix: pad labels with -1
        pad = logits.shape[1] - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (pad, 0)), constant_values=-1)
    valid = labels >= 0
    labels_c = jnp.clip(labels, 0, cfg.padded_vocab - 1)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels_c[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(valid), 1)
    ce = -jnp.sum(jnp.where(valid, ll, 0.0)) / denom
    total = ce + cfg.router_aux_weight * metrics.get(
        "moe_aux", jnp.zeros((), jnp.float32)) / max(cfg.num_layers, 1)
    metrics = dict(metrics)
    metrics["ce"] = ce
    return total, metrics


def prefill(cfg: ModelConfig, rcfg: RunConfig, params, batch):
    logits, cache, _ = _forward(cfg, rcfg, params, batch, mode="prefill")
    return logits[:, -1:], cache


def init_cache(cfg: ModelConfig, rcfg: RunConfig, batch: int, max_len: int):
    if is_encdec(cfg):
        return encdec.init_cache(cfg, rcfg, batch, max_len)
    return lm.init_cache(cfg, rcfg, batch, max_len)


def decode_step(cfg: ModelConfig, rcfg: RunConfig, params, cache, token, pos):
    if is_encdec(cfg):
        return encdec.decode_step(cfg, rcfg, params, cache, token, pos)
    return lm.decode_step(cfg, rcfg, params, cache, token, pos)


# ---------------------------------------------------------------------------
# input_specs — the dry-run contract
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                compute_dtype=jnp.bfloat16) -> dict[str, Any]:
    """ShapeDtypeStructs for every model input of this (arch, shape) cell.

    train/prefill: {"tokens", "labels"?, frontend stubs}
    decode:        {"token", "pos"} (cache comes from cache_specs()).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def sds(shp, dt):
        return jax.ShapeDtypeStruct(shp, dt)

    if shape.kind == "decode":
        return {"token": sds((b, 1), i32), "pos": sds((), i32)}

    batch: dict[str, Any] = {}
    if is_encdec(cfg):
        batch["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), compute_dtype)
        batch["tokens"] = sds((b, s), i32)
    elif cfg.frontend == "patch":
        f = cfg.frontend_seq
        batch["patch_embeds"] = sds((b, f, cfg.d_model), compute_dtype)
        batch["tokens"] = sds((b, s - f), i32)
    else:
        batch["tokens"] = sds((b, s), i32)
    if shape.kind == "train":
        batch["labels"] = sds((b, s), i32)
    return batch


def cache_specs(cfg: ModelConfig, rcfg: RunConfig, shape: ShapeConfig):
    """Abstract KV/SSM cache shapes for the decode dry-run."""
    return jax.eval_shape(
        lambda: init_cache(cfg, rcfg, shape.global_batch, shape.seq_len))


def param_specs(cfg: ModelConfig, param_dtype=jnp.float32):
    """Abstract params (ShapeDtypeStructs) without touching devices.
    Sharding comes from path-based resolution (runtime.sharding)."""
    key = jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init(cfg, k, param_dtype)[0], key)
