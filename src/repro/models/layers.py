"""Shared building blocks: init helpers, norms, RoPE, MLPs, embeddings.

All layers are pure functions over explicit param pytrees.  Every init
returns ``(params, logical_axes)`` — two pytrees with identical structure,
the second holding tuples of logical axis names consumed by
:mod:`repro.runtime.sharding`.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Axes = tuple  # tuple of logical axis names (str | None)


def dense_init(key, shape: Sequence[int], axes: Axes, dtype=jnp.float32,
               scale: float | None = None):
    """He/Glorot-ish init for a weight of the given shape."""
    fan_in = shape[0] if len(shape) > 1 else 1
    scale = (1.0 / fan_in) ** 0.5 if scale is None else scale
    w = jax.random.normal(key, shape, dtype=jnp.float32) * scale
    return w.astype(dtype), axes


def zeros_init(shape, axes: Axes, dtype=jnp.float32):
    return jnp.zeros(shape, dtype=dtype), axes


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype=jnp.float32):
    return jnp.ones((d,), dtype), ("norm",)


def rmsnorm(x, w, eps: float = 1e-6, *, zero_centered: bool = False):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = w.astype(jnp.float32)
    if zero_centered:          # gemma-style (1 + w)
        w = 1.0 + w
    return (y * w).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, D]; positions: broadcastable to [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [..., S, half]
    angles = angles[..., None, :]                             # [..., S, 1, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (gated SwiGLU or plain GELU)
# ---------------------------------------------------------------------------


def mlp_init(cfg: ModelConfig, key, d_ff: int | None = None, dtype=jnp.float32):
    d_ff = d_ff or cfg.d_ff
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    params, axes = {}, {}
    if cfg.mlp_gated:
        params["wi_gate"], axes["wi_gate"] = dense_init(
            ks[0], (d, d_ff), ("embed", "mlp"), dtype)
    params["wi"], axes["wi"] = dense_init(ks[1], (d, d_ff), ("embed", "mlp"), dtype)
    params["wo"], axes["wo"] = dense_init(ks[2], (d_ff, d), ("mlp", "embed"), dtype)
    return params, axes


def mlp_apply(cfg: ModelConfig, p, x):
    h = x @ p["wi"].astype(x.dtype)
    if cfg.mlp_gated:
        g = x @ p["wi_gate"].astype(x.dtype)
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------


def embed_init(cfg: ModelConfig, key, dtype=jnp.float32):
    v, d = cfg.padded_vocab, cfg.d_model
    params, axes = {}, {}
    params["embedding"], axes["embedding"] = dense_init(
        key, (v, d), ("vocab", "embed"), dtype, scale=1.0)
    if not cfg.tie_embeddings:
        params["lm_head"], axes["lm_head"] = dense_init(
            jax.random.fold_in(key, 1), (d, v), ("embed", "vocab"), dtype)
    if cfg.pos_embedding == "learned":
        n_pos = cfg.max_position or max(cfg.encoder_seq, 8192)
        params["pos_embedding"], axes["pos_embedding"] = dense_init(
            jax.random.fold_in(key, 2), (n_pos, d), ("pos", "embed"),
            dtype, scale=0.02)
    return params, axes


def embed_tokens(cfg: ModelConfig, p, tokens, compute_dtype):
    x = p["embedding"].astype(compute_dtype)[tokens]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, compute_dtype)
    return x


def add_positions(cfg: ModelConfig, p, x, positions):
    if cfg.pos_embedding == "learned":
        x = x + p["pos_embedding"].astype(x.dtype)[positions]
    return x


def lm_logits(cfg: ModelConfig, p, x):
    if cfg.tie_embeddings:
        logits = x @ p["embedding"].astype(x.dtype).T
    else:
        logits = x @ p["lm_head"].astype(x.dtype)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    if cfg.padded_vocab != cfg.vocab_size:   # mask padding vocab entries
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        logits = jnp.where(pad_mask, logits, -1e30)
    return logits


def softcap(x, cap):
    return jnp.tanh(x / cap) * cap if cap else x
