"""Generic decoder LM over a repeating heterogeneous layer pattern.

One code path serves all ten assigned architectures: the stack is
``num_blocks`` repeats of ``cfg.pattern`` (a tuple of LayerSpecs mixing
attention / local-attention / Mamba mixers with dense / MoE / absent MLPs).
Parameters for each pattern position are stacked over blocks and the stack
runs under ``jax.lax.scan`` (+ optional remat), so HLO size is O(|pattern|)
— 95-layer configs compile in one scan.

Decoder-only families: dense, moe, hybrid, ssm, vlm (patch-prefix stub).
The enc-dec family (whisper) lives in :mod:`repro.models.encdec`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig, RunConfig
from repro.models import attention as attn
from repro.models import layers as L
from repro.models import ssm
from repro.moe import moe_layer
from repro.runtime import sharding as shd

RULES = shd.ShardingRules(shd.TRAIN_RULES)


def _constraint(x, axes):
    return shd.logical_constraint(RULES, x, axes)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def layer_init(cfg: ModelConfig, spec: LayerSpec, key, dtype):
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["norm1"], a["norm1"] = L.rmsnorm_init(cfg.d_model, dtype)
    if spec.mixer in ("attn", "attn_local"):
        p["mixer"], a["mixer"] = attn.attn_init(cfg, ks[0], dtype)
    elif spec.mixer == "mamba":
        p["mixer"], a["mixer"] = ssm.ssm_init(cfg, ks[0], dtype)
    else:
        raise ValueError(spec.mixer)
    if cfg.use_post_norm:
        p["post_norm1"], a["post_norm1"] = L.rmsnorm_init(cfg.d_model, dtype)
    if spec.mlp != "none":
        p["norm2"], a["norm2"] = L.rmsnorm_init(cfg.d_model, dtype)
        if spec.mlp == "dense":
            p["mlp"], a["mlp"] = L.mlp_init(cfg, ks[1], dtype=dtype)
        elif spec.mlp == "moe":
            p["mlp"], a["mlp"] = moe_layer.moe_init(cfg, ks[1], dtype)
        if cfg.use_post_norm:
            p["post_norm2"], a["post_norm2"] = L.rmsnorm_init(cfg.d_model, dtype)
    return p, a


def init_lm(cfg: ModelConfig, key, param_dtype=jnp.float32):
    """Returns (params, logical_axes) with block params stacked over
    num_blocks (leading axis consumed by lax.scan)."""
    keys = jax.random.split(key, 2 + len(cfg.full_pattern))
    params: dict[str, Any] = {}
    axes: dict[str, Any] = {}
    params["embed"], axes["embed"] = L.embed_init(cfg, keys[0], param_dtype)
    blocks_p, blocks_a = [], []
    for i, spec in enumerate(cfg.full_pattern):
        def one(k, spec=spec):
            return layer_init(cfg, spec, k, param_dtype)[0]
        bkeys = jax.random.split(keys[1 + i], cfg.num_blocks)
        stacked = jax.vmap(one)(bkeys)
        _, a = layer_init(cfg, spec, keys[1 + i], param_dtype)
        blocks_p.append(stacked)
        blocks_a.append(jax.tree.map(lambda ax: (None,) + ax, a,
                                     is_leaf=_is_axes))
    params["blocks"] = blocks_p
    axes["blocks"] = blocks_a
    params["final_norm"], axes["final_norm"] = L.rmsnorm_init(
        cfg.d_model, param_dtype)
    return params, axes


def _is_axes(x):
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


# ---------------------------------------------------------------------------
# Layer application (shared by train forward / prefill / decode)
# ---------------------------------------------------------------------------


def _attn_call(cfg: ModelConfig, spec: LayerSpec) -> attn.AttnCall:
    window = cfg.sliding_window if spec.mixer == "attn_local" else None
    return attn.AttnCall(causal=True, window=window,
                         use_rope=cfg.pos_embedding == "rope")


def apply_layer(cfg: ModelConfig, rcfg: RunConfig, spec: LayerSpec, p, x,
                positions, cache=None, pos=None, mode="train"):
    """Returns (x, new_cache_entry, metrics)."""
    metrics = {}
    h = L.rmsnorm(x, p["norm1"], cfg.norm_eps, zero_centered=cfg.use_post_norm)
    if spec.mixer in ("attn", "attn_local"):
        call = _attn_call(cfg, spec)
        if mode == "decode":
            y, ck, cv, cp = attn.attn_decode(
                cfg, p["mixer"], h, pos, cache["k"], cache["v"],
                cache["pos"], call)
            new_cache = {"k": _constraint(ck, CACHE_KV_AXES),
                         "v": _constraint(cv, CACHE_KV_AXES), "pos": cp}
        else:
            y, (k, v) = attn.attn_apply(
                cfg, p["mixer"], h, positions, call,
                causal_skip=getattr(rcfg, "attn_causal_skip", False),
                seq_parallel=rcfg.seq_parallel)
            new_cache = _prefill_cache(cfg, spec, k, v, positions, mode)
    else:  # mamba
        if mode == "decode":
            y, (cs, hs) = ssm.ssm_decode(cfg, p["mixer"], h, cache["conv"],
                                         cache["ssm"])
            new_cache = {"conv": cs, "ssm": hs}
        else:
            y, (cs, hs) = ssm.ssm_apply(cfg, p["mixer"], h,
                                        use_pallas=rcfg.use_pallas)
            new_cache = ({"conv": cs.astype(jnp.bfloat16),
                          "ssm": hs} if mode == "prefill" else None)
    if cfg.use_post_norm:
        y = L.rmsnorm(y, p["post_norm1"], cfg.norm_eps, zero_centered=True)
    x = x + y
    if spec.mlp != "none":
        h = L.rmsnorm(x, p["norm2"], cfg.norm_eps,
                      zero_centered=cfg.use_post_norm)
        if spec.mlp == "dense":
            y = L.mlp_apply(cfg, p["mlp"], h)
        else:
            b, s, d = h.shape
            y2d, metrics = moe_layer.moe_apply(cfg, p["mlp"],
                                               h.reshape(b * s, d),
                                               impl=rcfg.moe_impl,
                                               mode=mode)
            y = y2d.reshape(b, s, d)
        if cfg.use_post_norm:
            y = L.rmsnorm(y, p["post_norm2"], cfg.norm_eps, zero_centered=True)
        x = x + y
    seq_ax = "act_seq" if (rcfg.seq_parallel and mode != "decode") else "seq"
    x = _constraint(x, ("batch", seq_ax, "act_embed"))
    return x, new_cache, metrics


CACHE_KV_AXES = ("batch", "cache_seq", "kv_heads", "head_dim")


def _prefill_cache(cfg, spec, k, v, positions, mode):
    if mode != "prefill":
        return None
    # local layers keep only the trailing window (ring layout: slot = pos % W)
    s = k.shape[1]
    if spec.mixer == "attn_local" and cfg.sliding_window and \
            cfg.sliding_window < s:
        w = cfg.sliding_window
        k, v = k[:, -w:], v[:, -w:]
        pos_slice = positions[0, -w:]
        # re-order so slot i holds position with pos % w == i
        slots = pos_slice % w
        order = jnp.argsort(slots)
        k, v, pos_slice = k[:, order], v[:, order], pos_slice[order]
    else:
        pos_slice = positions[0]
    return {"k": _constraint(k.astype(jnp.bfloat16), CACHE_KV_AXES),
            "v": _constraint(v.astype(jnp.bfloat16), CACHE_KV_AXES),
            "pos": pos_slice.astype(jnp.int32)}


# ---------------------------------------------------------------------------
# Full forward (train / prefill) and decode
# ---------------------------------------------------------------------------


def _embed_in(cfg: ModelConfig, rcfg: RunConfig, params, tokens,
              extra_embeds=None, pos_offset=0):
    cd = jnp.dtype(rcfg.compute_dtype)
    x = L.embed_tokens(cfg, params["embed"], tokens, cd)
    if extra_embeds is not None:   # vlm/audio prefix stub
        x = jnp.concatenate([extra_embeds.astype(cd), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(
        jnp.arange(s, dtype=jnp.int32)[None] + pos_offset, (b, s))
    x = L.add_positions(cfg, params["embed"], x, positions)
    x = _constraint(x, ("batch", "seq", "act_embed"))
    return x, positions


def _remat(f, rcfg: RunConfig):
    if rcfg.remat == "none":
        return f
    policy = (jax.checkpoint_policies.nothing_saveable if rcfg.remat == "full"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(f, policy=policy)


def forward(cfg: ModelConfig, rcfg: RunConfig, params, tokens,
            extra_embeds=None, mode="train"):
    """tokens: [B, S] -> (logits [B, S', V], cache|None, metrics).

    mode="train": returns logits over the full sequence, no cache.
    mode="prefill": also returns the stacked KV/SSM cache.
    """
    x, positions = _embed_in(cfg, rcfg, params, tokens, extra_embeds)

    def block_fn(x, block_params):
        caches, mets = [], []
        for i, spec in enumerate(cfg.full_pattern):
            x, c, m = apply_layer(cfg, rcfg, spec, block_params[i], x,
                                  positions, mode=mode)
            caches.append(c)
            mets.append(m)
        met = _merge_metrics(mets)
        return x, (caches if mode == "prefill" else None, met)

    x, (cache, mets) = jax.lax.scan(
        _remat(block_fn, rcfg), x, params["blocks"])
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps,
                  zero_centered=cfg.use_post_norm)
    logits = L.lm_logits(cfg, params["embed"], x)
    logits = _constraint(logits, ("batch", "seq", "vocab"))
    metrics = jax.tree.map(jnp.sum, mets)
    return logits, cache, metrics


def _merge_metrics(mets: list[dict]) -> dict:
    out: dict[str, jax.Array] = {}
    for m in mets:
        for k_, v_ in m.items():
            out[k_] = out.get(k_, 0) + v_
    if not out:
        out = {"moe_dropped": jnp.zeros((), jnp.int32),
               "moe_aux": jnp.zeros((), jnp.float32)}
    return out


def init_cache(cfg: ModelConfig, rcfg: RunConfig, batch: int, max_len: int):
    """Zero cache for decode-from-scratch (shapes match prefill output)."""
    entries = []
    cd = jnp.bfloat16
    for spec in cfg.full_pattern:
        if spec.mixer in ("attn", "attn_local"):
            w = max_len
            if spec.mixer == "attn_local" and cfg.sliding_window:
                w = min(max_len, cfg.sliding_window)
            e = {"k": jnp.zeros((batch, w, cfg.num_kv_heads, cfg.head_dim), cd),
                 "v": jnp.zeros((batch, w, cfg.num_kv_heads, cfg.head_dim), cd),
                 "pos": jnp.full((w,), -1, jnp.int32)}
        else:
            e = {"conv": jnp.zeros(
                    (batch, cfg.ssm_conv_kernel - 1,
                     cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state), cd),
                 "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                                   cfg.ssm_state), jnp.float32)}
        entries.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_blocks,) + x.shape), e))
    return entries


def decode_step(cfg: ModelConfig, rcfg: RunConfig, params, cache, token, pos):
    """token: [B, 1]; pos: scalar int32. Returns (logits [B, 1, V], cache)."""
    x, _ = _embed_in(cfg, rcfg, params, token, pos_offset=pos)
    positions = None

    def block_fn(x, inp):
        block_params, block_cache = inp
        new_caches = []
        for i, spec in enumerate(cfg.full_pattern):
            x, c, _ = apply_layer(cfg, rcfg, spec, block_params[i], x,
                                  positions, cache=block_cache[i], pos=pos,
                                  mode="decode")
            new_caches.append(c)
        return x, new_caches

    x, new_cache = jax.lax.scan(block_fn, x, (params["blocks"], cache))
    x = L.rmsnorm(x, params["final_norm"], cfg.norm_eps,
                  zero_centered=cfg.use_post_norm)
    logits = L.lm_logits(cfg, params["embed"], x)
    return logits, new_cache
