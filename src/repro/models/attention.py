"""Attention: GQA with RoPE, local/global windows, softcaps, KV caches.

Two numerically-equivalent paths (property-tested against each other):

* ``direct``  — one [Sq, Sk] logits tensor; used for short sequences and
  decode (where Sq == 1).
* ``chunked`` — pure-JAX flash attention: q tiled with ``lax.map``, online
  softmax over kv chunks with ``lax.scan``.  Bounded memory for 32k prefill.
  With ``causal_skip`` the q-chunk loop is unrolled and each q chunk scans
  only its causal prefix of kv chunks (a compute-roofline optimization
  recorded in EXPERIMENTS.md §Perf).

GQA sharding: K/V are stored grouped ([B, S, KV, D]) but *repeated* to the
full head count at use so every einsum shards cleanly over the ``model``
axis even when KV < mesh "model" size (DESIGN.md §4.1 divisibility rule).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init, rope

NEG_INF = -1e30


def attn_init(cfg: ModelConfig, key, dtype=jnp.float32, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], (d, h, hd), ("embed", "heads", "head_dim"), dtype)
    p["wk"], a["wk"] = dense_init(ks[1], (d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype)
    p["wv"], a["wv"] = dense_init(ks[2], (d, kv, hd), ("embed", "kv_heads", "head_dim"), dtype)
    p["wo"], a["wo"] = dense_init(ks[3], (h, hd, d), ("heads", "head_dim", "embed"), dtype)
    if cfg.qkv_bias:
        p["bq"], a["bq"] = (jnp.zeros((h, hd), dtype), ("heads", "head_dim"))
        p["bk"], a["bk"] = (jnp.zeros((kv, hd), dtype), ("kv_heads", "head_dim"))
        p["bv"], a["bv"] = (jnp.zeros((kv, hd), dtype), ("kv_heads", "head_dim"))
    if cfg.qk_norm:
        p["q_norm"], a["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"], a["k_norm"] = rmsnorm_init(hd, dtype)
    return p, a


def project_q(cfg: ModelConfig, p, x, positions, *, use_rope=True):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    if use_rope and cfg.pos_embedding == "rope":
        q = rope(q, positions, cfg.rope_theta)
    return q


def project_kv(cfg: ModelConfig, p, x, positions, *, use_rope=True):
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and cfg.pos_embedding == "rope":
        k = rope(k, positions, cfg.rope_theta)
    return k, v


def repeat_kv(x: jax.Array, num_heads: int) -> jax.Array:
    """[B, S, KV, D] -> [B, S, H, D] by repeating each kv head H//KV times."""
    b, s, kv, d = x.shape
    reps = num_heads // kv
    if reps == 1:
        return x
    return jnp.repeat(x, reps, axis=2)


def _mask(q_pos, k_pos, *, causal: bool, window: int | None):
    """q_pos: [B, Sq]; k_pos: [B, Sk] -> bool [B, 1, Sq, Sk]."""
    qp = q_pos[:, None, :, None]
    kp = k_pos[:, None, None, :]
    m = kp >= 0                       # ring-buffer invalid slots carry -1
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    return m


def _direct(q, k, v, q_pos, k_pos, *, causal, window, softcap_val):
    # q: [B, Sq, H, D] (already scaled); k, v: [B, Sk, H, D]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32)
    if softcap_val:
        logits = jnp.tanh(logits / softcap_val) * softcap_val
    mask = _mask(q_pos, k_pos, causal=causal, window=window)
    logits = jnp.where(mask, logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    m = jnp.maximum(m, NEG_INF)  # guard fully-masked rows
    w = jnp.exp(logits - m)
    l = jnp.sum(w, axis=-1, keepdims=True)
    w = w / jnp.maximum(l, 1e-30)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def _chunk_scan_body(q, q_pos, *, causal, window, softcap_val):
    """Returns a scan body computing online softmax over one kv chunk."""
    def body(carry, inputs):
        m_prev, l_prev, acc = carry
        k_c, v_c, kpos_c = inputs  # [B, Ck, H, D], [B, Ck]
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_c,
                            preferred_element_type=jnp.float32)
        if softcap_val:
            logits = jnp.tanh(logits / softcap_val) * softcap_val
        mask = _mask(q_pos, kpos_c, causal=causal, window=window)
        logits = jnp.where(mask, logits, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
        alpha = jnp.exp(m_prev - m_cur)
        w = jnp.exp(logits - m_cur[..., None])
        l_cur = l_prev * alpha + jnp.sum(w, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", w.astype(v_c.dtype), v_c)
        acc = acc * alpha[..., None].astype(acc.dtype) + pv.astype(acc.dtype)
        return (m_cur, l_cur, acc), None
    return body


def _chunked(q, k, v, q_pos, k_pos, *, causal, window, softcap_val,
             chunk_q, chunk_k, causal_skip):
    b, sq, h, d = q.shape
    sk = k.shape[1]
    cq = min(chunk_q, sq)
    ck = min(chunk_k, sk)
    nq, nk = sq // cq, sk // ck
    assert sq % cq == 0 and sk % ck == 0, (sq, cq, sk, ck)

    k_ch = k.reshape(b, nk, ck, h, d).transpose(1, 0, 2, 3, 4)
    v_ch = v.reshape(b, nk, ck, h, d).transpose(1, 0, 2, 3, 4)
    kpos_ch = k_pos.reshape(b, nk, ck).transpose(1, 0, 2)

    def run_q_chunk(q_c, qpos_c, n_kv):
        m0 = jnp.full((b, h, q_c.shape[1]), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, q_c.shape[1]), jnp.float32)
        a0 = jnp.zeros((b, h, q_c.shape[1], d), jnp.float32)
        body = _chunk_scan_body(q_c, qpos_c, causal=causal, window=window,
                                softcap_val=softcap_val)
        (m, l, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (k_ch[:n_kv], v_ch[:n_kv], kpos_ch[:n_kv]))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.transpose(0, 2, 1, 3)  # [B, Cq, H, D]

    if causal_skip and causal and window is None:
        # unrolled q-chunk loop; chunk i attends to kv chunks [0, i*ck/cq+1)
        outs = []
        for i in range(nq):
            q_c = q[:, i * cq:(i + 1) * cq]
            qpos_c = q_pos[:, i * cq:(i + 1) * cq]
            last_k = ((i + 1) * cq - 1) // ck  # last kv chunk with any unmasked key
            outs.append(run_q_chunk(q_c, qpos_c, last_k + 1))
        return jnp.concatenate(outs, axis=1).astype(v.dtype)

    q_ch = q.reshape(b, nq, cq, h, d).transpose(1, 0, 2, 3, 4)
    qpos_chunks = q_pos.reshape(b, nq, cq).transpose(1, 0, 2)
    out = jax.lax.map(lambda args: run_q_chunk(args[0], args[1], nk),
                      (q_ch, qpos_chunks))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, d)
    return out.astype(v.dtype)


def attention_core(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                   softcap_val=None, chunk=2048, causal_skip=False,
                   force_direct=False, kv_chunk_only=False):
    """q: [B, Sq, H, D]; k, v: [B, Sk, H, D] (kv already repeated to H).

    q_pos/k_pos: int32 [B, Sq] / [B, Sk]; k slots with pos < 0 are invalid.
    ``kv_chunk_only``: keep q whole (required under sequence parallelism —
    lax.map over a seq-sharded q-chunk axis would force an all-gather).
    """
    d = q.shape[-1]
    q = q * jnp.asarray(d ** -0.5, q.dtype)
    sq, sk = q.shape[1], k.shape[1]
    if force_direct or sq == 1 or sk <= chunk:
        return _direct(q, k, v, q_pos, k_pos, causal=causal, window=window,
                       softcap_val=softcap_val)
    # choose divisible chunk sizes
    cq = sq if kv_chunk_only else _largest_divisor_leq(sq, max(chunk // 2, 1))
    ck = _largest_divisor_leq(sk, chunk)
    return _chunked(q, k, v, q_pos, k_pos, causal=causal, window=window,
                    softcap_val=softcap_val, chunk_q=cq, chunk_k=ck,
                    causal_skip=causal_skip and not kv_chunk_only)


def _largest_divisor_leq(n: int, cap: int) -> int:
    for c in range(min(cap, n), 0, -1):
        if n % c == 0:
            return c
    return 1


# ---------------------------------------------------------------------------
# Full attention layer (projections + core + output), with KV cache support.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AttnCall:
    """Static attention-call options resolved from the layer kind."""
    causal: bool = True
    window: int | None = None
    use_rope: bool = True


def attn_apply(cfg: ModelConfig, p, x, positions, call: AttnCall,
               *, chunk=None, causal_skip=False, seq_parallel=False):
    """Training / prefill self-attention (no cache). Returns (out, (k, v))."""
    q = project_q(cfg, p, x, positions, use_rope=call.use_rope)
    k, v = project_kv(cfg, p, x, positions, use_rope=call.use_rope)
    if seq_parallel:
        # SP: residual/q stay seq-sharded over 'model'; only the grouped
        # K/V (kv_heads << heads) gathers to full sequence length.  The
        # double constraint pins the all-gather AFTER the projection so XLA
        # cannot hoist it to the (16x larger, f32) norm output.
        from repro.models.lm import _constraint
        q = _constraint(q, ("batch", "act_seq", None, None))
        k = _constraint(_constraint(k, ("batch", "act_seq", None, None)),
                        ("batch", None, None, None))
        v = _constraint(_constraint(v, ("batch", "act_seq", None, None)),
                        ("batch", None, None, None))
    kf = repeat_kv(k, cfg.num_heads)
    vf = repeat_kv(v, cfg.num_heads)
    if seq_parallel:
        # ...and pin the repeated views replicated so the gather happens on
        # the grouped K/V (kv_heads), not the H-expanded copy.
        from repro.models.lm import _constraint
        kf = _constraint(kf, ("batch", None, None, None))
        vf = _constraint(vf, ("batch", None, None, None))
    out = attention_core(
        q, kf, vf, positions, positions, causal=call.causal,
        window=call.window, softcap_val=cfg.attn_softcap,
        chunk=chunk or cfg.attn_chunk, causal_skip=causal_skip,
        kv_chunk_only=seq_parallel)
    y = jnp.einsum("bqhd,hdm->bqm", out, p["wo"].astype(x.dtype))
    return y, (k, v)


def attn_decode(cfg: ModelConfig, p, x, pos, cache_k, cache_v, cache_pos,
                call: AttnCall):
    """Single-token decode. x: [B, 1, d]; pos: scalar int32 (uniform batch).

    cache_k/v: [B, W, KV, D]; cache_pos: [W] int32 (absolute pos per slot,
    -1 = empty).  Returns (out, new_cache_k, new_cache_v, new_cache_pos).
    """
    b = x.shape[0]
    w = cache_k.shape[1]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = project_q(cfg, p, x, positions, use_rope=call.use_rope)
    k, v = project_kv(cfg, p, x, positions, use_rope=call.use_rope)
    slot = pos % w
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)
    cache_pos = jax.lax.dynamic_update_slice_in_dim(
        cache_pos, jnp.full((1,), pos, jnp.int32), slot, axis=0)
    kf = repeat_kv(cache_k.astype(x.dtype), cfg.num_heads)
    vf = repeat_kv(cache_v.astype(x.dtype), cfg.num_heads)
    k_pos = jnp.broadcast_to(cache_pos[None, :], (b, w))
    out = attention_core(q, kf, vf, positions, k_pos, causal=call.causal,
                         window=call.window, softcap_val=cfg.attn_softcap,
                         force_direct=True)
    y = jnp.einsum("bqhd,hdm->bqm", out, p["wo"].astype(x.dtype))
    return y, cache_k, cache_v, cache_pos


def cross_attn_apply(cfg: ModelConfig, p, x, enc_k, enc_v, enc_valid_len=None):
    """Encoder-decoder cross attention (whisper). enc_k/v: [B, Se, KV, D]."""
    b, sq = x.shape[0], x.shape[1]
    positions = jnp.zeros((b, sq), jnp.int32)
    q = project_q(cfg, p, x, positions, use_rope=False)
    kf = repeat_kv(enc_k.astype(x.dtype), cfg.num_heads)
    vf = repeat_kv(enc_v.astype(x.dtype), cfg.num_heads)
    se = enc_k.shape[1]
    k_pos = jnp.broadcast_to(jnp.arange(se, dtype=jnp.int32)[None], (b, se))
    out = attention_core(q, kf, vf, positions, k_pos, causal=False,
                         window=None, softcap_val=cfg.attn_softcap,
                         force_direct=(sq == 1))
    return jnp.einsum("bqhd,hdm->bqm", out, p["wo"].astype(x.dtype))
