"""Sharded checkpointing with async save, retention, and elastic restore.

Format: one directory per step containing a ``manifest.json`` (tree
structure, shapes, dtypes, step metadata) and one ``.npy`` per leaf.  A
``COMMITTED`` marker is written last — partially-written checkpoints (host
failure mid-save) are ignored at restore, giving crash-consistency.

Two layouts share the step directory and the COMMITTED protocol:

* the **legacy single-tree** layout (:meth:`Checkpointer.save` /
  :meth:`Checkpointer.restore`): leaf ``.npy`` files at the step root —
  what the train loop checkpoints;
* the **domain** layout (:meth:`Checkpointer.save_domains` /
  :meth:`Checkpointer.restore_domain`): named, versioned sub-trees, one
  subdirectory per domain, plus a free-form JSON ``meta`` blob in the
  manifest.  This is the service-durability format: a
  ``ServiceSnapshot`` (repro.serve.durable) stores its array payload as
  domains (graphs / result cache / in-flight results) and its python
  structure (graph ids, queries, ticket journal, autotune fits, ladder
  levels) as meta.

Every restore path validates the manifest: leaf names and counts must
match what was written (a truncated ``shardings`` pytree or a renamed
field raises instead of silently zip-truncating), and domain versions are
checked against the caller's expectation.

Elastic restore: leaves are loaded as host arrays and ``device_put`` with
the *target* sharding — restoring onto a different mesh shape (scale up /
down) works because the on-disk format is topology-free.  On a multi-host
fleet each host writes only its addressable shard slices (the per-leaf
writer goes through ``_to_numpy`` which gathers only for single-process
runs) — noted in DESIGN.md §4.1.

Concurrency: saves may run on a background thread (``blocking=False``)
whose retention pass deletes old steps.  A concurrent :meth:`restore`
pins the step it is reading — retention skips any step newer than or
equal to the pin, so a restore never has its files deleted out from
under it mid-read.
"""
from __future__ import annotations

import contextlib
import json
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return _SAFE.sub("_", ".".join(parts))


def _to_numpy(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


def _named_leaves(tree) -> tuple[list, Any]:
    """[(name, leaf)] in flatten order + the treedef — the one naming
    scheme save and restore must agree on."""
    flat, structure = jax.tree_util.tree_flatten_with_path(tree)
    return [(f"{i:04d}.{_leaf_name(p)}", x)
            for i, (p, x) in enumerate(flat)], structure


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None
        # step a concurrent restore is reading (retention must not
        # delete it, or anything newer, mid-read)
        self._restore_pin: int | None = None
        self._pin_lock = threading.Lock()

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: dict | None = None):
        """Serialize ``tree`` (any pytree of arrays) at ``step``
        (legacy single-tree layout)."""
        self.wait()
        named, structure = _named_leaves(tree)
        leaves = [(name, _to_numpy(x)) for name, x in named]

        def _write():
            tmp = self._tmp_dir(step)
            names = self._write_leaves(tmp, leaves)
            manifest = {"step": step, "leaves": names,
                        "treedef": str(structure),
                        "time": time.time(), "extra": extra or {}}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            self._commit_dir(step, tmp)
            self._retain()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def save_domains(self, step: int, domains: dict, *,
                     versions: dict | None = None,
                     meta: dict | None = None, blocking: bool = True,
                     _pre_commit=None):
        """Serialize named sub-trees at ``step`` (domain layout).

        domains:   {name: pytree of arrays} — each domain gets its own
                   subdirectory and manifest entry.
        versions:  {name: int} schema version per domain (default 1);
                   validated by :meth:`restore_domain`.
        meta:      free-form JSON blob stored in the manifest — the
                   python-side structure that describes the arrays.
        _pre_commit: test hook, called after every leaf is written but
                   BEFORE the COMMITTED marker — raising here simulates a
                   crash mid-save (the partial checkpoint is ignored at
                   restore).
        """
        self.wait()
        versions = versions or {}
        flat_domains = {}
        for name, tree in domains.items():
            if _SAFE.search(name):
                raise ValueError(f"domain name {name!r} has unsafe chars")
            named, _ = _named_leaves(tree)
            flat_domains[name] = [(n, _to_numpy(x)) for n, x in named]

        def _write():
            tmp = self._tmp_dir(step)
            entry = {}
            for name, leaves in flat_domains.items():
                sub = tmp / name
                sub.mkdir()
                names = self._write_leaves(sub, leaves)
                entry[name] = {"version": int(versions.get(name, 1)),
                               "leaves": names}
            manifest = {"step": step, "domains": entry,
                        "time": time.time(), "extra": meta or {}}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if _pre_commit is not None:
                _pre_commit()
            self._commit_dir(step, tmp)
            self._retain()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    @staticmethod
    def _write_leaves(d: Path, leaves) -> list:
        names = []
        for name, arr in leaves:
            np.save(d / f"{name}.npy", arr)
            names.append(name)
        return names

    def _tmp_dir(self, step: int) -> Path:
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        return tmp

    def _commit_dir(self, step: int, tmp: Path) -> None:
        (tmp / "COMMITTED").write_text("ok")
        d = self.dir / f"step_{step:08d}"
        if d.exists():
            shutil.rmtree(d)
        tmp.rename(d)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self):
        """Delete steps beyond ``keep`` — EXCEPT any step a concurrent
        restore has pinned (or anything newer): the async save thread
        must never delete files a restore is reading mid-way."""
        with self._pin_lock:
            pin = self._restore_pin
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            if pin is not None and s >= pin:
                continue
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    @contextlib.contextmanager
    def _pinned(self, step: int):
        with self._pin_lock:
            self._restore_pin = step
        try:
            yield
        finally:
            with self._pin_lock:
                self._restore_pin = None

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _resolve_step(self, step: int | None) -> tuple[int, Path, dict]:
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        return step, d, manifest

    @staticmethod
    def _validate_names(written: list, expected: list, what: str) -> None:
        """Leaf names computed from the template must equal what the
        manifest says was written — a silent zip-truncate here restores
        the WRONG leaves into the right-shaped arrays."""
        if list(written) == list(expected):
            return
        missing = [n for n in expected if n not in written]
        surplus = [n for n in written if n not in expected]
        raise ValueError(
            f"{what}: template does not match the manifest "
            f"({len(expected)} template leaves vs {len(written)} written; "
            f"template-only={missing[:4]}, checkpoint-only={surplus[:4]}) "
            f"— restore into the structure that was saved")

    def _load_tree(self, d: Path, written_names: list, template: Any,
                   shardings: Any) -> Any:
        named, _ = _named_leaves(template)
        self._validate_names(written_names, [n for n, _ in named],
                             f"restore from {d.name}")
        tmpl_leaves = [x for _, x in named]
        if shardings is None:
            shard_leaves = [None] * len(tmpl_leaves)
        else:
            shard_leaves = jax.tree.leaves(shardings)
            if len(shard_leaves) != len(tmpl_leaves):
                raise ValueError(
                    f"shardings pytree has {len(shard_leaves)} leaves but "
                    f"template has {len(tmpl_leaves)} — pass one sharding "
                    f"per template leaf (or None)")
        out = []
        for (name, tmpl), sh in zip(named, shard_leaves):
            arr = np.load(d / f"{name}.npy")
            if tuple(arr.shape) != tuple(tmpl.shape):
                raise ValueError(f"leaf {name}: checkpoint shape "
                                 f"{arr.shape} != template {tmpl.shape}")
            arr = arr.astype(tmpl.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.device_put(arr))
        return jax.tree_util.tree_unflatten(jax.tree.structure(template),
                                            out)

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Load into the structure of ``template``; optionally device_put
        each leaf with the matching sharding (elastic restore)."""
        step, d, manifest = self._resolve_step(step)
        if "leaves" not in manifest:
            raise ValueError(
                f"step {step} is a domain checkpoint "
                f"({sorted(manifest.get('domains', {}))}); use "
                f"restore_domain")
        with self._pinned(step):
            tree = self._load_tree(d, manifest["leaves"], template,
                                   shardings)
        return tree, step

    # -- domain layout ----------------------------------------------------

    def domains(self, step: int | None = None) -> dict:
        """{name: version} of a domain checkpoint."""
        _, _, manifest = self._resolve_step(step)
        return {n: e["version"]
                for n, e in manifest.get("domains", {}).items()}

    def meta(self, step: int | None = None) -> dict:
        """The free-form JSON blob stored by :meth:`save_domains`."""
        _, _, manifest = self._resolve_step(step)
        return manifest.get("extra", {})

    def _domain_entry(self, name: str, step: int | None):
        step, d, manifest = self._resolve_step(step)
        entry = manifest.get("domains", {}).get(name)
        if entry is None:
            raise KeyError(
                f"step {step} has no domain {name!r} "
                f"(has {sorted(manifest.get('domains', {}))})")
        return step, d / name, entry

    def restore_domain(self, name: str, template: Any,
                       step: int | None = None, *, shardings: Any = None,
                       expect_version: int | None = None) -> tuple[Any, int]:
        """Load one named domain into ``template`` (manifest-validated:
        leaf names, counts, and — when ``expect_version`` is given — the
        domain's schema version)."""
        step, sub, entry = self._domain_entry(name, step)
        if expect_version is not None and entry["version"] != expect_version:
            raise ValueError(f"domain {name!r} at step {step} has version "
                             f"{entry['version']}, expected {expect_version}")
        with self._pinned(step):
            tree = self._load_tree(sub, entry["leaves"], template,
                                   shardings)
        return tree, step

    def load_domain_arrays(self, name: str,
                           step: int | None = None) -> tuple[list, int, int]:
        """Template-free load of one domain: the raw numpy leaves in
        manifest order.  Returns (arrays, version, step) — for callers
        whose tree structure lives in :meth:`meta` (the service
        snapshot)."""
        step, sub, entry = self._domain_entry(name, step)
        with self._pinned(step):
            arrays = []
            for leaf in entry["leaves"]:
                p = sub / f"{leaf}.npy"
                if not p.exists():
                    raise ValueError(f"domain {name!r} at step {step}: "
                                     f"manifest names leaf {leaf!r} but "
                                     f"the file is missing")
                arrays.append(np.load(p))
        return arrays, entry["version"], step
