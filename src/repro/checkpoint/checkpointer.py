"""Sharded checkpointing with async save, retention, and elastic restore.

Format: one directory per step containing a ``manifest.json`` (tree
structure, shapes, dtypes, step metadata) and one ``.npy`` per leaf.  A
``COMMITTED`` marker is written last — partially-written checkpoints (host
failure mid-save) are ignored at restore, giving crash-consistency.

Elastic restore: leaves are loaded as host arrays and ``device_put`` with
the *target* sharding — restoring onto a different mesh shape (scale up /
down) works because the on-disk format is topology-free.  On a multi-host
fleet each host writes only its addressable shard slices (the per-leaf
writer goes through ``_to_numpy`` which gathers only for single-process
runs) — noted in DESIGN.md §4.1.
"""
from __future__ import annotations

import json
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any

import jax
import numpy as np

_SAFE = re.compile(r"[^A-Za-z0-9_.-]")


def _leaf_name(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return _SAFE.sub("_", ".".join(parts))


def _to_numpy(x) -> np.ndarray:
    return np.asarray(jax.device_get(x))


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- save
    def save(self, step: int, tree: Any, *, blocking: bool = True,
             extra: dict | None = None):
        """Serialize ``tree`` (any pytree of arrays) at ``step``."""
        self.wait()
        flat, structure = jax.tree_util.tree_flatten_with_path(tree)
        leaves = [(f"{i:04d}.{_leaf_name(p)}", _to_numpy(x))
                  for i, (p, x) in enumerate(flat)]

        def _write():
            d = self.dir / f"step_{step:08d}"
            tmp = self.dir / f".tmp_step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            names = []
            for name, arr in leaves:
                np.save(tmp / f"{name}.npy", arr)
                names.append(name)
            manifest = {"step": step, "leaves": names,
                        "treedef": str(structure),
                        "time": time.time(), "extra": extra or {}}
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            (tmp / "COMMITTED").write_text("ok")
            if d.exists():
                shutil.rmtree(d)
            tmp.rename(d)
            self._retain()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _retain(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if (p / "COMMITTED").exists():
                out.append(int(p.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, int]:
        """Load into the structure of ``template``; optionally device_put
        each leaf with the matching sharding (elastic restore)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        d = self.dir / f"step_{step:08d}"
        if shardings is None:
            shard_leaves = [None] * len(jax.tree.leaves(template))
        else:
            shard_leaves = jax.tree.leaves(shardings)

        flat_template, treedef = jax.tree_util.tree_flatten_with_path(template)
        out = []
        for i, ((path, tmpl), sh) in enumerate(zip(flat_template,
                                                   shard_leaves)):
            arr = np.load(d / f"{i:04d}.{_leaf_name(path)}.npy")
            assert tuple(arr.shape) == tuple(tmpl.shape), \
                (path, arr.shape, tmpl.shape)
            arr = arr.astype(tmpl.dtype)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.device_put(arr))
        tree = jax.tree_util.tree_unflatten(
            jax.tree.structure(template), out)
        return tree, step
