"""Serving: prefill → pad cache → batched greedy/temperature decode."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, RunConfig
from repro.models import model as M


def _pad_entry(e, tgt: int):
    w = e["k"].shape[-3]
    if w >= tgt:
        return e
    padw = tgt - w
    out = dict(e)
    for key_ in ("k", "v"):
        x = e[key_]
        pad = [(0, 0)] * x.ndim
        pad[-3] = (0, padw)
        out[key_] = jnp.pad(x, pad)
    pos = e["pos"]
    ppad = [(0, 0)] * pos.ndim
    ppad[-1] = (0, padw)
    out["pos"] = jnp.pad(pos, ppad, constant_values=-1)
    return out


def pad_cache(cfg: ModelConfig, cache, target_len: int):
    """Grow prefill caches to decode capacity.  Global-attention entries pad
    their seq dim to ``target_len``; sliding-window entries to the ring size
    min(window, target); SSM states are fixed-size.  Ring arithmetic stays
    valid because prefill slots satisfy slot = pos %% W for every W >= S."""
    if not isinstance(cache, list):       # enc-dec: dict over stacked layers
        return _pad_entry(cache, target_len)
    out = []
    for spec, e in zip(cfg.full_pattern, cache):
        if spec.mixer == "attn_local" and cfg.sliding_window:
            out.append(_pad_entry(e, min(cfg.sliding_window, target_len)))
        elif spec.mixer in ("attn",):
            out.append(_pad_entry(e, target_len))
        else:
            out.append(e)
    return out


def sample(logits, key, temperature: float = 0.0):
    """logits: [B, 1, V] -> tokens [B, 1]."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature, axis=-1) \
        .astype(jnp.int32)


def generate(cfg: ModelConfig, rcfg: RunConfig, params, batch, *,
             max_new_tokens: int, temperature: float = 0.0, seed: int = 0):
    """Prefill the prompt batch then decode ``max_new_tokens`` greedily.
    Returns tokens [B, max_new_tokens]."""
    prompt_len = batch["tokens"].shape[1]
    if cfg.frontend == "patch":
        prompt_len += cfg.frontend_seq
    logits, cache = M.prefill(cfg, rcfg, params, batch)
    cache = pad_cache(cfg, cache, prompt_len + max_new_tokens)
    key = jax.random.PRNGKey(seed)
    tok = sample(logits, key, temperature)

    decode = jax.jit(partial(M.decode_step, cfg, rcfg))

    toks = [tok]
    for i in range(max_new_tokens - 1):
        key, sub = jax.random.split(key)
        logits, cache = decode(params, cache, tok,
                               jnp.int32(prompt_len + i))
        tok = sample(logits, sub, temperature)
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)
