"""Graph query taxonomy for the serving layer.

A query is one user's question about a registered graph — the unit the
:class:`repro.serve.graph_service.GraphService` admits, microbatches into
lanes of a fused AAM wave, and caches.  Queries are frozen dataclasses:
hashable (result-cache keys, in-flight dedup) and cheap to compare.

``fuse_key()`` names the static knobs two queries must share to ride the
same fused wave (same jit cache entry): BFS/SSSP/st-conn queries fuse
unconditionally per kind; personalized-PageRank queries fuse per
(iters, damping) pair because those are trace-time constants of the loop.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar


@dataclasses.dataclass(frozen=True)
class BfsQuery:
    """Unweighted distances from ``source`` — result row: int32 [V]."""
    source: int
    kind: ClassVar[str] = "bfs"

    def fuse_key(self) -> tuple:
        return (self.kind,)


@dataclasses.dataclass(frozen=True)
class SsspQuery:
    """Weighted distances from ``source`` — result row: float32 [V]."""
    source: int
    kind: ClassVar[str] = "sssp"

    def fuse_key(self) -> tuple:
        return (self.kind,)


@dataclasses.dataclass(frozen=True)
class PprQuery:
    """Personalized PageRank with restart at ``source`` — float32 [V]."""
    source: int
    iters: int = 20
    d: float = 0.85
    kind: ClassVar[str] = "ppr"

    def fuse_key(self) -> tuple:
        return (self.kind, self.iters, self.d)


@dataclasses.dataclass(frozen=True)
class StConnQuery:
    """Is ``t`` reachable from ``s``? — result: bool scalar."""
    s: int
    t: int
    kind: ClassVar[str] = "stconn"

    def fuse_key(self) -> tuple:
        return (self.kind,)


QUERY_KINDS = ("bfs", "sssp", "ppr", "stconn")
