"""Graph query taxonomy for the serving layer.

A query is one user's question about a registered graph — the unit the
:class:`repro.serve.graph_service.GraphService` admits, microbatches into
lanes of a fused AAM wave, and caches.  Queries are frozen dataclasses:
hashable (result-cache keys, in-flight dedup) and cheap to compare.

``fuse_key()`` names the static knobs two queries must share to ride the
same fused wave (same jit cache entry): BFS/SSSP/st-conn queries fuse
unconditionally per kind; personalized-PageRank queries fuse per
(iters, damping) pair because those are trace-time constants of the loop.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar


@dataclasses.dataclass(frozen=True)
class BfsQuery:
    """Unweighted distances from ``source`` — result row: int32 [V]."""
    source: int
    kind: ClassVar[str] = "bfs"

    def fuse_key(self) -> tuple:
        return (self.kind,)


@dataclasses.dataclass(frozen=True)
class SsspQuery:
    """Weighted distances from ``source`` — result row: float32 [V]."""
    source: int
    kind: ClassVar[str] = "sssp"

    def fuse_key(self) -> tuple:
        return (self.kind,)


@dataclasses.dataclass(frozen=True)
class PprQuery:
    """Personalized PageRank with restart at ``source`` — float32 [V]."""
    source: int
    iters: int = 20
    d: float = 0.85
    kind: ClassVar[str] = "ppr"

    def fuse_key(self) -> tuple:
        return (self.kind, self.iters, self.d)


@dataclasses.dataclass(frozen=True)
class StConnQuery:
    """Is ``t`` reachable from ``s``? — result: bool scalar."""
    s: int
    t: int
    kind: ClassVar[str] = "stconn"

    def fuse_key(self) -> tuple:
        return (self.kind,)


@dataclasses.dataclass(frozen=True)
class ColoringQuery:
    """Boman coloring of the whole graph — result row: int32 [V] colors.

    Coloring has no query-lane form (two colorings of the same graph
    would collide on every vertex), so it fuses on the GRAPH batch axis
    only: one query each over many tenant graphs shares a wave.  The
    seeded coin flips are trace-shared, so ``seed``/``max_rounds`` are
    part of the fuse key."""
    seed: int = 0
    max_rounds: int = 500
    kind: ClassVar[str] = "coloring"

    def fuse_key(self) -> tuple:
        return (self.kind, self.seed, self.max_rounds)


@dataclasses.dataclass(frozen=True)
class MstQuery:
    """Boruvka MST forest of the whole graph — result:
    ``(comp int32 [V], weight, n_edges)``.

    Like coloring, MST is a whole-graph query with no lane form; it
    fuses on the graph batch axis."""
    kind: ClassVar[str] = "mst"

    def fuse_key(self) -> tuple:
        return (self.kind,)


QUERY_KINDS = ("bfs", "sssp", "ppr", "stconn", "coloring", "mst")
# kinds with no query-lane form — servable via the graph batch axis only
GRAPH_ONLY_KINDS = ("coloring", "mst")
# kinds with a lane form — servable on the lanes×graphs PRODUCT axis
# (one wave = many queries × many tenant graphs; see
# repro.serve.product_wave)
PRODUCT_KINDS = tuple(k for k in QUERY_KINDS if k not in GRAPH_ONLY_KINDS)

QUERY_CLASSES = {cls.kind: cls for cls in
                 (BfsQuery, SsspQuery, PprQuery, StConnQuery,
                  ColoringQuery, MstQuery)}


def query_to_dict(q) -> dict:
    """JSON-portable form of a query — what the service snapshot's
    ticket journal and result index store."""
    if q.kind not in QUERY_CLASSES:
        raise ValueError(f"unknown query kind {q.kind!r}")
    return {"kind": q.kind, **dataclasses.asdict(q)}


def query_from_dict(d: dict):
    """Inverse of :func:`query_to_dict` (frozen dataclasses round-trip
    by field dict; hash/equality are value-based, so a rebuilt query
    hits the same cache keys)."""
    d = dict(d)
    cls = QUERY_CLASSES[d.pop("kind")]
    return cls(**d)
