"""Graph query service — batch-axis multi-tenant serving of AAM queries.

The paper's waves amortize per-message overhead by coalescing many active
messages into one transaction; at serving scale the same move applies one
level up, along TWO orthogonal batch axes (``repro.core.coalescing``):

* **query lanes** — many independent queries over ONE graph fuse into
  lanes of a single wave (composite commit keys ``lane * V + v``);
* **graph batch** — the same query kind over MANY tenant graphs fuses
  into one wave over the disjoint-union flat key space
  (``offset[g] + v``) — the axis that makes coloring and Boruvka
  servable at all (their rounds share no lane structure, but
  independent graphs trivially share a wave);
* **product axis** — their composition (``lane * Vtot + offset[g] + v``,
  :class:`repro.core.coalescing.ProductAxis`): MANY queries over MANY
  graphs in ONE wave, so a mixed tenant load (one hot graph with
  several queries + a tail of single-query tenants) drains as a single
  commit stream instead of a lane wave plus a graph batch
  (:mod:`repro.serve.product_wave`; asynchronous continuous batching on
  top lives in :mod:`repro.serve.continuous`).

UpDown's event fabric and PIUMA's multi-tenant pipelines make the
identical aggregate-small-events-into-big-atomic-steps bet in hardware.

The service owns the non-wave half of serving:

* **admission / axis choice** — submitted queries queue per
  (graph, fuse key); ``drain()`` picks the fusion axis per fuse-key
  group: graphs holding SEVERAL queries of a kind fuse them as lanes
  (at most ``max_lanes``, lane count padded up a power-of-two ladder),
  graphs holding ONE query each fuse across graphs as a graph batch (at
  most ``max_graphs``, graph count padded up its own ladder) — the
  power-of-two ladder applied per axis keeps jit caches to
  ``log2(width)+1`` entries per kind; padding repeats a real
  query/graph and is discarded;
* **in-flight dedup** — identical queries submitted before a drain share
  one lane;
* **result cache** — keyed by ``(graph_id, query)``; hits answer at
  submit time without touching the accelerator.  Re-registering a
  ``graph_id`` with different topology invalidates that graph's cache
  entries AND its in-flight queue (stale tickets raise KeyError
  forever) instead of serving answers computed on the old graph;
* **telemetry** — :class:`ServiceStats` counts what the ladders and
  cache actually saved.

Execution is the batch-axis algorithm entry points (``multi_source_*``
for lanes, ``batched_over_graphs_*`` for graph batches); pass ``mesh=``
to serve from the distributed harness (``capacity="auto"``) instead of
the single-shard loops.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.core import autotune as AT
from repro.core import commit as C
from repro.obs import trace as OT
from repro.obs import wavetap as OW
from repro.serve.queries import (BfsQuery, PprQuery, SsspQuery, StConnQuery,
                                 ColoringQuery, MstQuery, QUERY_KINDS,
                                 GRAPH_ONLY_KINDS, PRODUCT_KINDS)


class ServiceStats:
    """What the batching layer did (not wave-level telemetry — that lives
    in CommitResult/DistributedResult).

    A thin attribute view over a :class:`repro.obs.metrics.Registry` —
    ``stats.waves += 1`` increments the ``aam_waves`` counter, so one
    store backs both the historical attribute surface and the
    Prometheus/JSON exports (``stats.registry.prometheus_text()`` /
    ``stats.registry.snapshot()``).  The continuous server's
    submit-to-answer latency histogram lives in the same registry.
    """

    # counter fields (ints; drain_s is a float counter)
    _COUNTERS = (
        "submitted",
        "cache_hits",
        "deduped",           # submissions that joined an in-flight lane
        "waves",             # fused lane waves executed
        "lanes_executed",    # total lanes across waves (incl. padding)
        "lanes_padded",      # ladder-padding lanes (discarded results)
        "graph_waves",       # fused graph-batch waves executed
        "graphs_batched",    # graphs across graph waves (incl. padding)
        "graphs_padded",     # ladder-padding graphs (discarded results)
        "invalidated",       # in-flight tickets voided by re-registration
        "timing_runs",       # autotune timed micro-benchmarks drains paid
        #                      (a warm-restored service asserts it stays 0)
        "product_waves",     # fused lanes×graphs product waves executed
        "product_cells",     # (lane, graph) cells across product waves
        "product_cells_padded",  # empty cells (no query) in those waves
        # drain timing — read through the service's injected clock, so a
        # fake-clock test sees deterministic values (no wall-clock flake)
        "drains",
        "drain_s",           # total time inside drain()
    )
    _GAUGES = ("last_drain_s",)

    def __init__(self, registry=None):
        from repro.obs import metrics as OM
        reg = registry if registry is not None else OM.Registry()
        object.__setattr__(self, "registry", reg)
        for f in self._COUNTERS:
            reg.counter("aam_" + f)
        for f in self._GAUGES:
            reg.gauge("aam_" + f)

    def __getattr__(self, name):
        if name in self._COUNTERS:
            return self.registry.counter("aam_" + name).value
        if name in self._GAUGES:
            return self.registry.gauge("aam_" + name).value
        raise AttributeError(f"{type(self).__name__!r} object has no "
                             f"attribute {name!r}")

    def __setattr__(self, name, value):
        if name in self._COUNTERS:
            self.registry.counter("aam_" + name).set(value)
        elif name in self._GAUGES:
            self.registry.gauge("aam_" + name).set(value)
        else:
            object.__setattr__(self, name, value)

    @property
    def total_waves(self) -> int:
        """Waves of ANY axis (lane + graph + product) — the denominator
        dashboards actually want."""
        return self.waves + self.graph_waves + self.product_waves

    def __repr__(self) -> str:
        fields = ", ".join(f"{f}={getattr(self, f)!r}"
                           for f in self._COUNTERS + self._GAUGES)
        return f"ServiceStats({fields})"


def _pow2_ladder(width: int) -> tuple:
    """(1, 2, 4, ..., width) — the per-axis jit-shape ladder."""
    ladder = []
    w = 1
    while w < width:
        ladder.append(w)
        w *= 2
    return tuple(ladder) + (width,)


# PR-4 name (the lane-axis instance of the per-axis ladder)
_lane_ladder = _pow2_ladder


def _same_topology(a, b) -> bool:
    """Do two Graphs have identical topology/weights?  (The
    re-registration staleness check — cheap shape gate first.)"""
    if a is b:
        return True
    if (a.num_vertices, a.num_edges) != (b.num_vertices, b.num_edges):
        return False
    return (np.array_equal(np.asarray(a.src), np.asarray(b.src))
            and np.array_equal(np.asarray(a.dst), np.asarray(b.dst))
            and np.array_equal(np.asarray(a.weights), np.asarray(b.weights)))


class GraphService:
    """Serve streams of independent graph queries as fused batch-axis
    waves: same-graph requests as query lanes, same-kind requests across
    tenant graphs as graph batches (see the module docstring).

    spec:       CommitSpec for every fused commit.  None (default) serves
                with ``CommitSpec(backend="auto", sort=False,
                stats=False)`` — the calibrated mechanism tier minus the
                jnp sort emulation: the sorted coarse path pays an
                L-times-larger argsort on every fused wave (mostly over
                masked-out lanes once queries start converging), which a
                single all-valid micro-race can mistakenly favor but
                dispatch amortization never recoups; the scatter and
                Pallas tiers stay in the race.  Pass a concrete spec to
                pin the mechanism.
    max_lanes:  lane budget L of one fused wave (power of two).
    max_graphs: graph budget G of one graph-batch wave (power of two).
    mesh:       optional — execute on the distributed harness over
                ``mesh[axis]`` shards instead of the single-shard loops.
    capacity:   coalescing factor for distributed execution ("auto" =
                telemetry-sized, see ``repro.core.engine.auto_capacity``).
    cache:      keep a ``(graph_id, query) -> result`` cache.
    max_results / max_cache: retention bounds (FIFO eviction) — a serving
                daemon must not hold every [V] result row it ever
                produced; ``result()`` raises KeyError for tickets older
                than the last ``max_results``.
    product:    fuse mixed-shape fuse-key groups (several graphs, some
                holding several queries) as ONE lanes×graphs product
                wave (:mod:`repro.serve.product_wave`) instead of a lane
                wave per multi-query graph plus a graph batch for the
                singles.  Single-shard only; mesh services keep the
                two-axis drain.
    clock:      0-arg callable returning seconds (default
                ``time.perf_counter``) — every timing stat reads THIS
                clock, so tests inject a fake clock and assert exact
                values instead of flaking on wall time.
    tracer:     a :class:`repro.obs.trace.Tracer` for span export.  None
                (default): with an injected ``clock`` the service binds
                a private tracer to that same clock (deterministic span
                timestamps under a fake clock); otherwise it shares the
                process-global tracer, so every service of one
                continuous-batching run lands in ONE trace.  Inert
                unless tracing is enabled (``REPRO_TRACE=1`` or an
                explicitly-enabled tracer).
    """

    def __init__(self, *, spec: C.CommitSpec | None = None,
                 max_lanes: int = 8, max_graphs: int = 8, mesh=None,
                 capacity: int | str = "auto", axis: str = "data",
                 cache: bool = True, max_results: int = 4096,
                 max_cache: int = 1024, product: bool = True,
                 clock=None, tracer=None):
        if max_lanes < 1 or (max_lanes & (max_lanes - 1)):
            raise ValueError(f"max_lanes must be a power of two, got "
                             f"{max_lanes}")
        if max_graphs < 1 or (max_graphs & (max_graphs - 1)):
            raise ValueError(f"max_graphs must be a power of two, got "
                             f"{max_graphs}")
        self.spec = spec if spec is not None \
            else C.CommitSpec(backend="auto", sort=False, stats=False)
        if OT.trace_enabled() and not self.spec.trace:
            # promote the wave telemetry tap into every fused commit's
            # (static) spec — the jitted entry points and ProductWave
            # chunks all trace with it
            self.spec = dataclasses.replace(self.spec, trace=True)
        self.max_lanes = max_lanes
        self.max_graphs = max_graphs
        self.lane_ladder = _pow2_ladder(max_lanes)
        self.graph_ladder = _pow2_ladder(max_graphs)
        self.mesh = mesh
        self.capacity = capacity
        self.axis = axis
        self.max_results = max_results
        self.max_cache = max_cache
        self.product = product
        self.clock = clock if clock is not None else time.perf_counter
        if tracer is not None:
            self.tracer = tracer
        elif clock is not None:
            self.tracer = OT.Tracer(clock=self.clock)
        else:
            self.tracer = OT.get_tracer()
        self.stats = ServiceStats()
        self._graphs: dict[Any, Any] = {}
        # (graph_id tuple) -> GraphSet memo: keeps the union arrays (and
        # therefore jit cache keys) stable across drains of a stable
        # tenant mix
        self._graphsets: dict[tuple, Any] = {}
        # (graph_id, fuse_key) -> {query: [tickets]} in arrival order
        self._queue: dict[tuple, dict] = {}
        self._results: dict[int, Any] = {}
        self._cache: dict | None = {} if cache else None
        self._next_ticket = 0
        # (kind, graph_id) -> last adaptive transaction size M the mesh
        # harness converged to (0 = whole batch); seeds the next wave's
        # conflict ladder and rides the service snapshot so a restored
        # service re-enters at the learned level
        self._m_learned: dict[tuple, int] = {}
        # fault injection (tests / crash-resume bench): callable
        # (where, wave_index) raising to simulate a crash mid-drain
        self.fault_injector = None
        self._wave_i = 0
        # re-registrations arriving while a drain is executing are
        # DEFERRED to the drain boundary (see register_graph)
        self._drain_depth = 0
        self._deferred_regs: list = []

    @staticmethod
    def _bounded_put(d: dict, key, value, bound: int) -> None:
        """Insert with FIFO eviction (python dicts iterate insertion
        order) so long-running services hold O(bound) result rows."""
        d[key] = value
        while len(d) > bound:
            d.pop(next(iter(d)))

    # -- admission --------------------------------------------------------

    def register_graph(self, graph_id, g) -> None:
        """Register a graph under ``graph_id`` (the tenant key).

        Re-registering an id with DIFFERENT topology invalidates every
        ``(graph_id, query)`` result-cache entry and drops the graph's
        in-flight queue — their tickets raise KeyError forever (counted
        in ``stats.invalidated``) — so no answer computed on the old
        topology is ever served.  Same-topology re-registration is a
        no-op for the cache.

        Re-registering an EXISTING id while a drain is executing (the
        async continuous loop, or a fault injector calling back into the
        service mid-drain) defers the swap to the drain/wave boundary:
        applying it immediately would purge the cache only for the
        in-progress wave's ``finish`` to re-cache rows computed on the
        old topology, and would void queue entries the drain's crash
        handler is about to merge back.  The in-progress wave answers
        against the graph its queries were admitted under; the new
        topology (and its invalidation sweep) takes effect before the
        next wave is built.  Brand-new ids register immediately — no
        in-flight state can refer to them."""
        if self._drain_depth > 0 and graph_id in self._graphs:
            self._deferred_regs.append((graph_id, g))
            return
        old = self._graphs.get(graph_id)
        if old is not None and not _same_topology(old, g):
            if self._cache is not None:
                for k in [k for k in self._cache if k[0] == graph_id]:
                    del self._cache[k]
            for qk in [qk for qk in self._queue if qk[0] == graph_id]:
                for tickets in self._queue.pop(qk).values():
                    self.stats.invalidated += len(tickets)
        if old is not None:
            # the union memo interns the old arrays — rebuild on demand
            for k in [k for k in self._graphsets if graph_id in k]:
                del self._graphsets[k]
        self._graphs[graph_id] = g

    def _apply_deferred_regs(self) -> None:
        """Apply re-registrations that arrived mid-drain (always called
        at the drain boundary with ``_drain_depth`` back at 0 — the
        point where cache purge + ticket voiding are race-free)."""
        regs, self._deferred_regs = self._deferred_regs, []
        for graph_id, g in regs:
            self.register_graph(graph_id, g)

    def _graphset(self, graph_ids: tuple):
        from repro.graphs.csr import GraphSet
        gs = self._graphsets.get(graph_ids)
        if gs is None:
            gs = GraphSet([self._graphs[gid] for gid in graph_ids])
            self._bounded_put(self._graphsets, graph_ids, gs, 32)
        return gs

    def submit(self, graph_id, query) -> int:
        """Enqueue one query; returns a ticket for :meth:`result`.

        Cache hits resolve immediately; identical in-flight queries share
        a lane (the ticket still gets its own result entry).  Vertex ids
        are validated here — under jit an out-of-range source would be
        silently DROPPED by the scatter (an all-INF answer, then
        cached), so admission is the error boundary."""
        if graph_id not in self._graphs:
            raise KeyError(f"unknown graph_id {graph_id!r}; "
                           f"register_graph first")
        if query.kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {query.kind!r}")
        v = self._graphs[graph_id].num_vertices
        if query.kind == "stconn":
            ids = (query.s, query.t)
        elif query.kind in GRAPH_ONLY_KINDS:
            ids = ()                      # whole-graph queries name no vertex
        else:
            ids = (query.source,)
        for i in ids:
            if not 0 <= int(i) < v:
                raise ValueError(f"{query} names vertex {i} outside "
                                 f"[0, {v}) of graph {graph_id!r}")
        ticket = self._next_ticket
        self._next_ticket += 1
        self.stats.submitted += 1
        ck = (graph_id, query)
        if self._cache is not None and ck in self._cache:
            self.stats.cache_hits += 1
            self._bounded_put(self._results, ticket, self._cache[ck],
                              self.max_results)
            self.tracer.instant("submit", args={"ticket": ticket,
                                                "kind": query.kind,
                                                "cache_hit": True})
            return ticket
        lanes = self._queue.setdefault((graph_id, query.fuse_key()), {})
        if query in lanes:
            self.stats.deduped += 1
        lanes.setdefault(query, []).append(ticket)
        self.tracer.instant("submit", args={"ticket": ticket,
                                            "kind": query.kind,
                                            "cache_hit": False})
        return ticket

    def _replay_submit(self, graph_id, query, ticket: int) -> None:
        """Re-enter an already-acknowledged submission under its ORIGINAL
        ticket id (snapshot-restore WAL replay).  Idempotent: tickets that
        already have a result (or are already queued) are left alone."""
        self._next_ticket = max(self._next_ticket, ticket + 1)
        if ticket in self._results:
            return
        ck = (graph_id, query)
        if self._cache is not None and ck in self._cache:
            self._bounded_put(self._results, ticket, self._cache[ck],
                              self.max_results)
            return
        lanes = self._queue.setdefault((graph_id, query.fuse_key()), {})
        tickets = lanes.setdefault(query, [])
        if ticket not in tickets:
            tickets.append(ticket)

    def pending(self) -> int:
        """Distinct queries waiting for the next :meth:`drain`."""
        return sum(len(q) for q in self._queue.values())

    def result(self, ticket: int):
        """The answer for ``ticket`` (KeyError until drained)."""
        return self._results[ticket]

    # -- execution --------------------------------------------------------

    def drain(self) -> dict:
        """Execute every queued query in fused batch-axis waves.

        Per fuse-key group the fusion axis is chosen here: a MIXED group
        — several graphs, at least one holding several queries — fuses
        as ONE lanes×graphs PRODUCT wave (``product=True``, single-shard
        only); otherwise graphs holding SEVERAL distinct queries of the
        kind lane-fuse them (one wave per graph, ``multi_source_*``) and
        graphs holding ONE query each fuse ACROSS graphs as a graph
        batch (``batched_over_graphs_*``) — whole-graph kinds (coloring,
        MST) only have the graph axis.  Returns {ticket: result} for
        everything completed by this call.

        Crash safety: a wave raising mid-drain (device fault, injected
        crash) re-queues every not-yet-finished query — with its original
        tickets — before the exception propagates, so a retry or a
        restore-and-replay never loses an acknowledged submission."""
        done: dict[int, Any] = {}
        queues, self._queue = self._queue, {}
        # queries not finished yet — merged back on a mid-drain fault
        remaining = {k: dict(v) for k, v in queues.items()}
        t0_timing = AT.DEFAULT_TUNER.timed_runs
        t0 = self.clock()
        by_fuse: dict[tuple, list] = {}
        for (graph_id, fk), lanes in queues.items():
            by_fuse.setdefault(fk, []).append((graph_id, lanes))

        def finish(graph_id, q, row):
            if self._cache is not None:
                self._bounded_put(self._cache, (graph_id, q), row,
                                  self.max_cache)
            for t in queues[(graph_id, q.fuse_key())][q]:
                self._bounded_put(self._results, t, row, self.max_results)
                done[t] = row
            remaining[(graph_id, q.fuse_key())].pop(q, None)

        self._drain_depth += 1
        try:
            for fk, entries in by_fuse.items():
                kind = fk[0]
                if (self.product and self.mesh is None
                        and kind in PRODUCT_KINDS and len(entries) >= 2
                        and any(len(lanes) > 1 for _, lanes in entries)):
                    # product axis: many queries × many graphs, one wave
                    for gid, q, row in self._execute_product(kind,
                                                             entries):
                        finish(gid, q, row)
                    continue
                singles = [(gid, next(iter(lanes)))
                           for gid, lanes in entries if len(lanes) == 1]
                multis = [(gid, lanes) for gid, lanes in entries
                          if len(lanes) > 1]
                if len(singles) >= 2 or (singles
                                         and kind in GRAPH_ONLY_KINDS):
                    # graph axis: one query per graph, chunked by
                    # max_graphs
                    for lo in range(0, len(singles), self.max_graphs):
                        chunk = singles[lo:lo + self.max_graphs]
                        with self.tracer.span(
                                "wave", args={"axis": "graph",
                                              "kind": kind,
                                              "graphs": len(chunk)}):
                            rows = self._execute_graph_batch(kind, chunk)
                        for (gid, q), row in zip(chunk, rows):
                            finish(gid, q, row)
                else:
                    multis += [(gid, {q: queues[(gid, fk)][q]})
                               for gid, q in singles]
                for graph_id, lanes in multis:
                    # lane axis: many queries, one graph
                    g = self._graphs[graph_id]
                    queries = list(lanes)
                    for lo in range(0, len(queries), self.max_lanes):
                        chunk = queries[lo:lo + self.max_lanes]
                        with self.tracer.span(
                                "wave", args={"axis": "lane", "kind": kind,
                                              "queries": len(chunk)}):
                            rows = self._execute_wave(g, chunk,
                                                      graph_id=graph_id)
                        for q, row in zip(chunk, rows):
                            finish(graph_id, q, row)
        except Exception:
            for key, lanes in remaining.items():
                if not lanes:
                    continue
                tgt = self._queue.setdefault(key, {})
                for q, tickets in lanes.items():
                    tgt.setdefault(q, []).extend(
                        t for t in tickets if t not in tgt.get(q, ()))
            raise
        finally:
            self._drain_depth -= 1
            if self._drain_depth == 0:
                self._apply_deferred_regs()
            self.stats.timing_runs += AT.DEFAULT_TUNER.timed_runs \
                - t0_timing
            dt = self.clock() - t0
            self.stats.drains += 1
            self.stats.drain_s += dt
            self.stats.last_drain_s = dt
            if self.tracer.active:
                # reuse t0/dt — the drain span adds ZERO clock reads
                # (a fake-clock test pins drain() to exactly two)
                self.tracer.complete("drain", t0, dt,
                                     args={"done": len(done),
                                           "waves": self.stats.waves,
                                           "graph_waves":
                                           self.stats.graph_waves,
                                           "product_waves":
                                           self.stats.product_waves})
                OW.flush_to(self.tracer)
        return done

    def _fault(self, where: str) -> None:
        """Fault-injection hook: called before every wave with a running
        wave index; the injector raising simulates a crash mid-drain."""
        i = self._wave_i
        self._wave_i += 1
        if self.fault_injector is not None:
            self.fault_injector(where, i)

    def _spec_for(self, kind: str, graph_id) -> C.CommitSpec:
        """The commit spec for one wave: the service spec, seeded with
        the learned ladder M when serving ``backend="auto"`` and a
        previous mesh wave on this (kind, graph) reported its converged
        transaction size."""
        if self.spec.backend != C.AUTO or self.spec.m is not None:
            return self.spec
        m = self._m_learned.get((kind, graph_id))
        if m is None:
            return self.spec
        return dataclasses.replace(self.spec, seed_m=m)

    def _learn_m(self, kind: str, graph_id, res) -> None:
        """Record the adaptive ladder's final M from a mesh wave's
        telemetry (-1 = static spec, nothing to learn)."""
        m = int(res.m_final)
        if m >= 0:
            self._m_learned[(kind, graph_id)] = m

    def _execute_graph_batch(self, kind: str, chunk: list) -> list:
        """One graph-batch wave: ``chunk`` is [(graph_id, query)], one
        per graph; pad the graph count up the graph ladder, execute the
        ``batched_over_graphs_*`` entry point, return one result row per
        real (graph, query) pair."""
        self._fault("graph_batch")
        k = len(chunk)
        width = next(w for w in self.graph_ladder if w >= k)
        padded = chunk + [chunk[-1]] * (width - k)
        self.stats.graph_waves += 1
        self.stats.graphs_batched += width
        self.stats.graphs_padded += width - k
        gs = self._graphset(tuple(gid for gid, _ in padded))
        qs = [q for _, q in padded]
        kw = dict(spec=self.spec, mesh=self.mesh, capacity=self.capacity,
                  axis=self.axis)
        if kind == "bfs":
            from repro.graphs.algorithms.bfs import batched_over_graphs_bfs
            rows = batched_over_graphs_bfs(gs, [q.source for q in qs], **kw)
        elif kind == "sssp":
            from repro.graphs.algorithms.sssp import \
                batched_over_graphs_sssp
            rows = batched_over_graphs_sssp(gs, [q.source for q in qs],
                                            **kw)
        elif kind == "ppr":
            from repro.graphs.algorithms.pagerank import \
                batched_over_graphs_pagerank
            rows = batched_over_graphs_pagerank(
                gs, [q.source for q in qs], iters=qs[0].iters, d=qs[0].d,
                **kw)
        elif kind == "stconn":
            from repro.graphs.algorithms.stconn import \
                batched_over_graphs_stconn
            found = batched_over_graphs_stconn(
                gs, [q.s for q in qs], [q.t for q in qs], **kw)
            rows = [bool(found[i]) for i in range(width)]
        elif kind == "coloring":
            from repro.graphs.algorithms.coloring import \
                batched_over_graphs_coloring
            rows, _, _ = batched_over_graphs_coloring(
                gs, seed=qs[0].seed, max_rounds=qs[0].max_rounds, **kw)
        else:   # mst
            from repro.graphs.algorithms.boruvka import \
                batched_over_graphs_boruvka
            rows, _ = batched_over_graphs_boruvka(gs, **kw)
        return list(rows)[:k]

    def _execute_product(self, kind: str, entries: list) -> list:
        """Lanes×graphs product waves for one fuse-key group:
        ``entries`` is [(graph_id, {query: tickets})] spanning several
        graphs with mixed per-graph query counts.  Graphs chunk by
        ``max_graphs``; the lane budget of each wave is the ladder width
        of the deepest graph in the chunk (capped at ``max_lanes``;
        deeper columns board follow-up waves).  Returns
        [(graph_id, query, row)] for every real cell — empty cells are
        padding, executed and discarded like ladder lanes."""
        from repro.serve.product_wave import ProductWave
        out = []
        for lo in range(0, len(entries), self.max_graphs):
            chunk = entries[lo:lo + self.max_graphs]
            gids = tuple(gid for gid, _ in chunk)
            gs = self._graphset(gids)
            per_graph = [list(lanes) for _, lanes in chunk]
            depth = max(len(qs) for qs in per_graph)
            width = next(w for w in self.lane_ladder
                         if w >= min(depth, self.max_lanes))
            q0 = per_graph[0][0]
            fuse = {"iters": q0.iters, "d": q0.d} if kind == "ppr" else {}
            for r in range(0, depth, width):
                self._fault("product")
                wave = ProductWave(kind, gs, width, spec=self.spec,
                                   fuse=fuse)
                cells = []
                for gi, qs in enumerate(per_graph):
                    for li, q in enumerate(qs[r:r + width]):
                        wave.insert(li, gi, q)
                        cells.append((gi, li, q))
                self.stats.product_waves += 1
                self.stats.product_cells += width * len(chunk)
                self.stats.product_cells_padded += \
                    width * len(chunk) - len(cells)
                with self.tracer.span(
                        "wave", args={"axis": "product", "kind": kind,
                                      "lanes": width,
                                      "graphs": len(chunk),
                                      "cells": len(cells)}):
                    wave.run()
                for gi, li, q in cells:
                    out.append((gids[gi], q, wave.extract(li, gi)))
        return out

    def run(self, graph_id, queries) -> list:
        """Convenience: submit all, drain, return results in order."""
        tickets = [self.submit(graph_id, q) for q in queries]
        self.drain()
        return [self._results[t] for t in tickets]

    def _execute_wave(self, g, chunk: list, *, graph_id=None) -> list:
        """One fused wave: pad ``chunk`` up the lane ladder, execute,
        return one result row per real query.

        Mesh waves run with telemetry so the adaptive ladder's converged
        M is learned per (kind, graph) — seeding the NEXT wave's ladder
        (and, through the snapshot, the first wave after a restore) at
        the learned level.  The single-shard loops do not expose their
        final ladder level, so learning is mesh-path only."""
        self._fault("wave")
        k = len(chunk)
        lanes = next(l for l in self.lane_ladder if l >= k)
        padded = chunk + [chunk[-1]] * (lanes - k)
        self.stats.waves += 1
        self.stats.lanes_executed += lanes
        self.stats.lanes_padded += lanes - k
        kind = chunk[0].kind
        spec = self._spec_for(kind, graph_id)
        if kind == "bfs":
            srcs = jnp.asarray([q.source for q in padded], jnp.int32)
            if self.mesh is not None:
                from repro.graphs.algorithms.bfs import \
                    distributed_multi_source_bfs
                dist, _, res = distributed_multi_source_bfs(
                    self.mesh, g, srcs, spec=spec,
                    capacity=self.capacity, axis=self.axis, telemetry=True)
                self._learn_m(kind, graph_id, res)
            else:
                from repro.graphs.algorithms.bfs import multi_source_bfs
                dist = multi_source_bfs(g, srcs, spec=spec).dist
            return [dist[i] for i in range(k)]
        if kind == "sssp":
            srcs = jnp.asarray([q.source for q in padded], jnp.int32)
            if self.mesh is not None:
                from repro.graphs.algorithms.sssp import \
                    distributed_multi_source_sssp
                dist, _, res = distributed_multi_source_sssp(
                    self.mesh, g, srcs, spec=spec,
                    capacity=self.capacity, axis=self.axis, telemetry=True)
                self._learn_m(kind, graph_id, res)
            else:
                from repro.graphs.algorithms.sssp import multi_source_sssp
                dist, _ = multi_source_sssp(g, srcs, spec=spec)
            return [dist[i] for i in range(k)]
        if kind == "ppr":
            srcs = jnp.asarray([q.source for q in padded], jnp.int32)
            iters, d = chunk[0].iters, chunk[0].d
            if self.mesh is not None:
                from repro.graphs.algorithms.pagerank import \
                    distributed_multi_source_pagerank
                rank, res = distributed_multi_source_pagerank(
                    self.mesh, g, srcs, iters=iters, d=d, spec=spec,
                    capacity=self.capacity, axis=self.axis, telemetry=True)
                self._learn_m(kind, graph_id, res)
            else:
                from repro.graphs.algorithms.pagerank import \
                    multi_source_pagerank
                rank, _ = multi_source_pagerank(g, srcs, iters=iters, d=d,
                                                spec=spec)
            return [rank[i] for i in range(k)]
        # stconn
        ss = jnp.asarray([q.s for q in padded], jnp.int32)
        ts = jnp.asarray([q.t for q in padded], jnp.int32)
        if self.mesh is not None:
            from repro.graphs.algorithms.stconn import \
                distributed_multi_source_stconn
            found, _, res = distributed_multi_source_stconn(
                self.mesh, g, ss, ts, spec=spec,
                capacity=self.capacity, axis=self.axis, telemetry=True)
            self._learn_m(kind, graph_id, res)
        else:
            from repro.graphs.algorithms.stconn import multi_source_stconn
            found, _ = multi_source_stconn(g, ss, ts, spec=spec)
        return [bool(found[i]) for i in range(k)]

    # -- durability -------------------------------------------------------

    def snapshot(self):
        """Freeze the warm state of this service into a
        :class:`repro.serve.durable.ServiceSnapshot`: registered graphs,
        result cache, issued results, the in-flight ticket journal,
        learned ladder levels, and the autotuner's calibration fits."""
        from repro.serve.durable import build_snapshot
        return build_snapshot(self)

    @classmethod
    def restore(cls, snap, *, mesh=None, clock=None):
        """Rebuild a WARM service from a snapshot: same config, graphs,
        cache, pending queue (original tickets), learned M levels, and
        imported autotune fits — the first drain runs zero timed
        calibrations and commits at the learned transaction size.
        ``mesh`` re-attaches distributed execution and ``clock`` the
        injected timebase (both are process resources and do not
        serialize)."""
        from repro.serve.durable import restore_service
        return restore_service(snap, mesh=mesh, clock=clock)
