"""Graph query service — lane-batched multi-tenant serving of AAM queries.

The paper's waves amortize per-message overhead by coalescing many active
messages into one transaction; at serving scale the same move applies one
level up: many *independent user queries* fuse into lanes of a single
wave (composite commit keys ``lane * V + v``, one conflict resolution for
all lanes — see ``repro.core.coalescing``).  UpDown's event fabric and
PIUMA's multi-tenant pipelines make the identical
aggregate-small-events-into-big-atomic-steps bet in hardware.

The service owns the non-wave half of serving:

* **admission / microbatching** — submitted queries queue per
  (graph, fuse key); ``drain()`` packs each queue into waves of at most
  ``max_lanes`` lanes, padding the lane count up to the next rung of a
  power-of-two lane ladder so only ``log2(max_lanes)+1`` jit cache
  entries per query kind ever exist (pad lanes repeat a real query and
  are discarded);
* **in-flight dedup** — identical queries submitted before a drain share
  one lane;
* **result cache** — keyed by ``(graph_id, query)``; hits answer at
  submit time without touching the accelerator;
* **telemetry** — :class:`ServiceStats` counts what the lane ladder and
  cache actually saved.

Execution is the lane-extended algorithm entry points
(``multi_source_*``); pass ``mesh=`` to serve from the distributed
harness (``distributed_multi_source_*`` + ``capacity="auto"``) instead of
the single-shard loops.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp

from repro.core import commit as C
from repro.serve.queries import (BfsQuery, PprQuery, SsspQuery, StConnQuery,
                                 QUERY_KINDS)


@dataclasses.dataclass
class ServiceStats:
    """What the batching layer did (not wave-level telemetry — that lives
    in CommitResult/DistributedResult)."""
    submitted: int = 0
    cache_hits: int = 0
    deduped: int = 0         # submissions that joined an in-flight lane
    waves: int = 0           # fused waves executed
    lanes_executed: int = 0  # total lanes across waves (incl. padding)
    lanes_padded: int = 0    # ladder-padding lanes (discarded results)


def _lane_ladder(max_lanes: int) -> tuple:
    """(1, 2, 4, ..., max_lanes)."""
    ladder = []
    lane = 1
    while lane < max_lanes:
        ladder.append(lane)
        lane *= 2
    return tuple(ladder) + (max_lanes,)


class GraphService:
    """Serve streams of independent graph queries as fused lane waves.

    spec:       CommitSpec for every fused commit.  None (default) serves
                with ``CommitSpec(backend="auto", sort=False,
                stats=False)`` — the calibrated mechanism tier minus the
                jnp sort emulation: the sorted coarse path pays an
                L-times-larger argsort on every fused wave (mostly over
                masked-out lanes once queries start converging), which a
                single all-valid micro-race can mistakenly favor but
                dispatch amortization never recoups; the scatter and
                Pallas tiers stay in the race.  Pass a concrete spec to
                pin the mechanism.
    max_lanes:  lane budget L of one fused wave (power of two).
    mesh:       optional — execute on the distributed harness over
                ``mesh[axis]`` shards instead of the single-shard loops.
    capacity:   coalescing factor for distributed execution ("auto" =
                telemetry-sized, see ``repro.core.engine.auto_capacity``).
    cache:      keep a ``(graph_id, query) -> result`` cache.
    max_results / max_cache: retention bounds (FIFO eviction) — a serving
                daemon must not hold every [V] result row it ever
                produced; ``result()`` raises KeyError for tickets older
                than the last ``max_results``.
    """

    def __init__(self, *, spec: C.CommitSpec | None = None,
                 max_lanes: int = 8, mesh=None,
                 capacity: int | str = "auto", axis: str = "data",
                 cache: bool = True, max_results: int = 4096,
                 max_cache: int = 1024):
        if max_lanes < 1 or (max_lanes & (max_lanes - 1)):
            raise ValueError(f"max_lanes must be a power of two, got "
                             f"{max_lanes}")
        self.spec = spec if spec is not None \
            else C.CommitSpec(backend="auto", sort=False, stats=False)
        self.max_lanes = max_lanes
        self.lane_ladder = _lane_ladder(max_lanes)
        self.mesh = mesh
        self.capacity = capacity
        self.axis = axis
        self.max_results = max_results
        self.max_cache = max_cache
        self.stats = ServiceStats()
        self._graphs: dict[Any, Any] = {}
        # (graph_id, fuse_key) -> {query: [tickets]} in arrival order
        self._queue: dict[tuple, dict] = {}
        self._results: dict[int, Any] = {}
        self._cache: dict | None = {} if cache else None
        self._next_ticket = 0

    @staticmethod
    def _bounded_put(d: dict, key, value, bound: int) -> None:
        """Insert with FIFO eviction (python dicts iterate insertion
        order) so long-running services hold O(bound) result rows."""
        d[key] = value
        while len(d) > bound:
            d.pop(next(iter(d)))

    # -- admission --------------------------------------------------------

    def register_graph(self, graph_id, g) -> None:
        """Register a graph under ``graph_id`` (the tenant key)."""
        self._graphs[graph_id] = g

    def submit(self, graph_id, query) -> int:
        """Enqueue one query; returns a ticket for :meth:`result`.

        Cache hits resolve immediately; identical in-flight queries share
        a lane (the ticket still gets its own result entry).  Vertex ids
        are validated here — under jit an out-of-range source would be
        silently DROPPED by the scatter (an all-INF answer, then
        cached), so admission is the error boundary."""
        if graph_id not in self._graphs:
            raise KeyError(f"unknown graph_id {graph_id!r}; "
                           f"register_graph first")
        if query.kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {query.kind!r}")
        v = self._graphs[graph_id].num_vertices
        ids = (query.s, query.t) if query.kind == "stconn" \
            else (query.source,)
        for i in ids:
            if not 0 <= int(i) < v:
                raise ValueError(f"{query} names vertex {i} outside "
                                 f"[0, {v}) of graph {graph_id!r}")
        ticket = self._next_ticket
        self._next_ticket += 1
        self.stats.submitted += 1
        ck = (graph_id, query)
        if self._cache is not None and ck in self._cache:
            self.stats.cache_hits += 1
            self._bounded_put(self._results, ticket, self._cache[ck],
                              self.max_results)
            return ticket
        lanes = self._queue.setdefault((graph_id, query.fuse_key()), {})
        if query in lanes:
            self.stats.deduped += 1
        lanes.setdefault(query, []).append(ticket)
        return ticket

    def pending(self) -> int:
        """Distinct queries waiting for the next :meth:`drain`."""
        return sum(len(q) for q in self._queue.values())

    def result(self, ticket: int):
        """The answer for ``ticket`` (KeyError until drained)."""
        return self._results[ticket]

    # -- execution --------------------------------------------------------

    def drain(self) -> dict:
        """Execute every queued query in fused lane waves.

        Returns {ticket: result} for everything completed by this call."""
        done: dict[int, Any] = {}
        queues, self._queue = self._queue, {}
        for (graph_id, _), lanes in queues.items():
            g = self._graphs[graph_id]
            queries = list(lanes)
            for lo in range(0, len(queries), self.max_lanes):
                chunk = queries[lo:lo + self.max_lanes]
                rows = self._execute_wave(g, chunk)
                for q, row in zip(chunk, rows):
                    if self._cache is not None:
                        self._bounded_put(self._cache, (graph_id, q), row,
                                          self.max_cache)
                    for t in lanes[q]:
                        self._bounded_put(self._results, t, row,
                                          self.max_results)
                        done[t] = row
        return done

    def run(self, graph_id, queries) -> list:
        """Convenience: submit all, drain, return results in order."""
        tickets = [self.submit(graph_id, q) for q in queries]
        self.drain()
        return [self._results[t] for t in tickets]

    def _execute_wave(self, g, chunk: list) -> list:
        """One fused wave: pad ``chunk`` up the lane ladder, execute,
        return one result row per real query."""
        k = len(chunk)
        lanes = next(l for l in self.lane_ladder if l >= k)
        padded = chunk + [chunk[-1]] * (lanes - k)
        self.stats.waves += 1
        self.stats.lanes_executed += lanes
        self.stats.lanes_padded += lanes - k
        kind = chunk[0].kind
        if kind == "bfs":
            srcs = jnp.asarray([q.source for q in padded], jnp.int32)
            if self.mesh is not None:
                from repro.graphs.algorithms.bfs import \
                    distributed_multi_source_bfs
                dist, _ = distributed_multi_source_bfs(
                    self.mesh, g, srcs, spec=self.spec,
                    capacity=self.capacity, axis=self.axis)
            else:
                from repro.graphs.algorithms.bfs import multi_source_bfs
                dist = multi_source_bfs(g, srcs, spec=self.spec).dist
            return [dist[i] for i in range(k)]
        if kind == "sssp":
            srcs = jnp.asarray([q.source for q in padded], jnp.int32)
            if self.mesh is not None:
                from repro.graphs.algorithms.sssp import \
                    distributed_multi_source_sssp
                dist, _ = distributed_multi_source_sssp(
                    self.mesh, g, srcs, spec=self.spec,
                    capacity=self.capacity, axis=self.axis)
            else:
                from repro.graphs.algorithms.sssp import multi_source_sssp
                dist, _ = multi_source_sssp(g, srcs, spec=self.spec)
            return [dist[i] for i in range(k)]
        if kind == "ppr":
            srcs = jnp.asarray([q.source for q in padded], jnp.int32)
            iters, d = chunk[0].iters, chunk[0].d
            if self.mesh is not None:
                from repro.graphs.algorithms.pagerank import \
                    distributed_multi_source_pagerank
                rank = distributed_multi_source_pagerank(
                    self.mesh, g, srcs, iters=iters, d=d, spec=self.spec,
                    capacity=self.capacity, axis=self.axis)
            else:
                from repro.graphs.algorithms.pagerank import \
                    multi_source_pagerank
                rank, _ = multi_source_pagerank(g, srcs, iters=iters, d=d,
                                                spec=self.spec)
            return [rank[i] for i in range(k)]
        # stconn
        ss = jnp.asarray([q.s for q in padded], jnp.int32)
        ts = jnp.asarray([q.t for q in padded], jnp.int32)
        if self.mesh is not None:
            from repro.graphs.algorithms.stconn import \
                distributed_multi_source_stconn
            found, _ = distributed_multi_source_stconn(
                self.mesh, g, ss, ts, spec=self.spec,
                capacity=self.capacity, axis=self.axis)
        else:
            from repro.graphs.algorithms.stconn import multi_source_stconn
            found, _ = multi_source_stconn(g, ss, ts, spec=self.spec)
        return [bool(found[i]) for i in range(k)]
