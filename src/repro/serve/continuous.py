"""Continuous batching on the lanes×graphs product axis (ISSUE 7).

:meth:`GraphService.drain` is a synchronous boundary: callers submit,
somebody calls drain, everyone waits for the full batch.  At serving
scale the batch never closes — queries arrive WHILE a wave is running.
:class:`ContinuousServer` runs the drain as a background loop and turns
the product wave's round boundaries into admission points, the same
shape LLM serving stacks use for prefill-insert-generate continuous
batching:

* **deadline admission** — a submitted query starts a wave after at
  most ``max_wait_s`` (or immediately once ``max_batch`` are pending);
  the pure :class:`DeadlineAdmission` policy is fake-clock testable;
* **in-flight insertion** — while a product wave executes in
  ``round_chunk``-round jitted chunks, newly admitted compatible
  queries (same fuse key, a registered graph of the wave's GraphSet,
  a free (lane, graph) cell) BOARD the running wave at the next round
  boundary instead of waiting for the next one.  Disjoint flat key
  ranges make the late cell's answer bit-identical to an idle-service
  run (float add to rounding);
* **incremental harvest** — converged cells publish their results (and
  free their slots) at each boundary; one straggler no longer holds the
  whole batch's latency;
* **supervised recovery** — wrapped around a
  :class:`repro.serve.durable.ServiceSupervisor`, a fault mid-wave
  restores the last snapshot and replays the WAL: every acknowledged
  ticket is answered exactly once, none lost, none doubled.

Whole-graph kinds (coloring, MST) and mesh execution fall back to the
service's synchronous axes inside the same loop; ``product=False`` on
the service degrades the whole loop to the PR-5 two-axis drain — the
open-loop benchmark's baseline mode.
"""
from __future__ import annotations

import threading
import time
from typing import Any

from repro.core import autotune as AT
from repro.obs import wavetap as OW
from repro.serve.graph_service import GraphService
from repro.serve.product_wave import ProductWave
from repro.serve.queries import PRODUCT_KINDS


class DeadlineAdmission:
    """When does a pending batch start?  Pure policy over an injected
    ``now`` — no threads, no wall clock, exactly testable.

    The first pending submission opens a window of ``max_wait_s``; the
    batch is due when the window closes or ``max_batch`` queries are
    pending, whichever is first."""

    def __init__(self, max_wait_s: float = 0.05, max_batch: int = 32):
        self.max_wait_s = float(max_wait_s)
        self.max_batch = int(max_batch)
        self.deadline: float | None = None

    def note(self, now: float) -> None:
        """A submission was queued at ``now``."""
        if self.deadline is None:
            self.deadline = now + self.max_wait_s

    def due(self, now: float, pending: int) -> bool:
        if pending <= 0:
            return False
        return pending >= self.max_batch or (
            self.deadline is not None and now >= self.deadline)

    def remaining(self, now: float) -> float:
        """Seconds until the open window closes (inf if none open)."""
        if self.deadline is None:
            return float("inf")
        return max(0.0, self.deadline - now)

    def reset(self) -> None:
        self.deadline = None


class ContinuousServer:
    """Asynchronous continuous-batching facade over a
    :class:`GraphService` (or a
    :class:`repro.serve.durable.ServiceSupervisor` for WAL-journaled,
    crash-recovered serving).

    ``submit`` is thread-safe and returns a ticket immediately;
    ``result(ticket, timeout=...)`` blocks until the background drain
    loop publishes the answer.  Use as a context manager (or call
    ``start()``/``stop()``)."""

    def __init__(self, service, *, max_wait_s: float = 0.02,
                 max_batch: int = 64, round_chunk: int = 4,
                 poll_s: float = 0.005):
        sup = service if hasattr(service, "service") else None
        self.sup = sup
        self._svc = sup.service if sup is not None else service
        self.admission = DeadlineAdmission(max_wait_s, max_batch)
        self.round_chunk = int(round_chunk)
        self.poll_s = float(poll_s)
        self.lock = threading.RLock()
        self.cond = threading.Condition(self.lock)
        self.submit_at: dict[int, float] = {}
        self.done_at: dict[int, float] = {}
        self._voided: set[int] = set()
        self.last_error: BaseException | None = None
        self._stop = False
        self._thread: threading.Thread | None = None

    @property
    def svc(self) -> GraphService:
        """The live service (a supervisor swaps it on restore)."""
        return self.sup.service if self.sup is not None else self._svc

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "ContinuousServer":
        if self._thread is not None:
            raise RuntimeError("already started")
        self._stop = False
        self._thread = threading.Thread(target=self._loop,
                                        name="aam-drain", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        with self.cond:
            self._stop = True
            self.cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def __enter__(self) -> "ContinuousServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client surface ---------------------------------------------------

    def register_graph(self, graph_id, g) -> None:
        with self.lock:
            self.svc.register_graph(graph_id, g)

    def submit(self, graph_id, query) -> int:
        """Thread-safe admission; never blocks on the accelerator.  The
        ticket's submit timestamp (service clock) feeds the open-loop
        latency benchmark."""
        with self.cond:
            svc = self.svc
            now = svc.clock()
            if self.sup is not None:
                ticket = self.sup.submit(graph_id, query)
            else:
                ticket = svc.submit(graph_id, query)
            self.submit_at[ticket] = now
            if ticket in svc._results:       # cache hit — answered now
                self.done_at[ticket] = now
                # a cache-hit-only cycle never reaches _drain_once, so
                # the drain stats would go stale: count it as a
                # zero-length drain and record the (zero) latency
                svc.stats.drains += 1
                svc.stats.last_drain_s = 0.0
                self._observe_latency(svc, 0.0)
            else:
                self.admission.note(now)
            self.cond.notify_all()
            return ticket

    def result(self, ticket: int, timeout: float | None = None):
        """Block until the drain loop answers ``ticket`` (KeyError for
        voided tickets — their graph was re-registered; TimeoutError
        past ``timeout`` seconds of host time)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self.cond:
            while True:
                res = self.svc._results
                if ticket in res:
                    return res[ticket]
                if ticket in self._voided:
                    raise KeyError(f"ticket {ticket} voided by "
                                   f"re-registration")
                if deadline is not None:
                    left = deadline - time.monotonic()
                    if left <= 0:
                        raise TimeoutError(f"ticket {ticket} not "
                                           f"answered in {timeout}s")
                    self.cond.wait(min(left, self.poll_s))
                else:
                    self.cond.wait(self.poll_s)

    def results(self, tickets, timeout: float | None = None) -> list:
        return [self.result(t, timeout) for t in tickets]

    # -- drain loop -------------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self.cond:
                while not self._stop:
                    svc = self.svc
                    now = svc.clock()
                    pending = svc.pending()
                    if pending and self.admission.deadline is None:
                        # work with no open window — re-queued after a
                        # fault or replayed by a restore; open one so it
                        # drains without a fresh submit
                        self.admission.note(now)
                    if self.admission.due(now, pending):
                        if (svc.tracer.active
                                and self.admission.deadline is not None):
                            # window opened max_wait_s before the
                            # deadline — reuse timestamps already read
                            t_open = (self.admission.deadline
                                      - self.admission.max_wait_s)
                            svc.tracer.complete(
                                "admit", t_open, max(now - t_open, 0.0),
                                args={"pending": pending})
                        break
                    wait = min(self.poll_s,
                               self.admission.remaining(now))
                    self.cond.wait(wait if wait > 0 else self.poll_s)
                if self._stop:
                    return
                self.admission.reset()
            try:
                self._drain_once()
            except Exception as e:  # noqa: BLE001 — keep serving
                with self.cond:
                    self.last_error = e
                    self.cond.notify_all()

    @staticmethod
    def _observe_latency(svc, dt: float) -> None:
        """Record one submit-to-answer latency in the service registry
        (get-or-create: a supervisor swaps the service on restore)."""
        svc.stats.registry.histogram(
            "aam_submit_to_answer_seconds").observe(max(dt, 0.0))

    def _publish(self, graph_id, q, row, queues) -> None:
        """Answer every ticket of one finished (graph, query) cell —
        caller holds the lock."""
        svc = self.svc
        now = svc.clock()
        if svc._cache is not None:
            svc._bounded_put(svc._cache, (graph_id, q), row,
                             svc.max_cache)
        for t in queues.pop((graph_id, q), ()):
            svc._bounded_put(svc._results, t, row, svc.max_results)
            self.done_at[t] = now
            self._observe_latency(svc, now - self.submit_at.get(t, now))
        self.cond.notify_all()

    def _sweep_voided(self) -> None:
        """Tickets acked but no longer answerable (their queue entries
        were invalidated by a deferred re-registration) — caller holds
        the lock, drain idle."""
        svc = self.svc
        queued = {t for lanes in svc._queue.values()
                  for tickets in lanes.values() for t in tickets}
        for t in self.submit_at:
            if (t not in self.done_at and t not in svc._results
                    and t not in queued):
                self.done_at[t] = svc.clock()
                self._voided.add(t)

    def _drain_once(self) -> None:
        """One admission cycle: product kinds board continuous product
        waves (with mid-wave insertion); everything else takes the
        service's synchronous axes."""
        svc = self.svc
        t0_timing = AT.DEFAULT_TUNER.timed_runs
        t0 = svc.clock()
        with self.lock:
            taken: dict[tuple, dict] = {}
            if svc.product and svc.mesh is None:
                for key in [k for k in svc._queue
                            if k[1][0] in PRODUCT_KINDS]:
                    taken[key] = svc._queue.pop(key)
            svc._drain_depth += 1
        try:
            if any(lanes for lanes in taken.values()):
                self._run_product(taken)
            if svc.pending():
                # coloring / MST / mesh / product=False: synchronous
                # axes, supervised when a supervisor is attached
                done = (self.sup.drain() if self.sup is not None
                        else svc.drain())
                with self.cond:
                    svc = self.svc        # a fault may have swapped it
                    now = svc.clock()
                    for t in done:
                        if t not in self.done_at:
                            self.done_at[t] = now
                            self._observe_latency(
                                svc, now - self.submit_at.get(t, now))
                    self.cond.notify_all()
        except Exception as e:  # noqa: BLE001
            if self.sup is None:
                raise
            # supervised: restore last snapshot + WAL replay; every
            # unanswered acknowledged ticket is back in the queue
            with self.cond:
                self.sup.recover_step(e, what="continuous-drain",
                                      log=self.sup.log)
                self.sup.restore()
                self.last_error = e
                self.cond.notify_all()
        finally:
            with self.cond:
                svc = self.svc
                svc._drain_depth = max(0, svc._drain_depth - 1)
                if svc._drain_depth == 0:
                    svc._apply_deferred_regs()
                self._sweep_voided()
                svc.stats.timing_runs += \
                    AT.DEFAULT_TUNER.timed_runs - t0_timing
                dt = svc.clock() - t0
                svc.stats.drains += 1
                svc.stats.drain_s += dt
                svc.stats.last_drain_s = dt
                if svc.tracer.active:
                    # reuse t0/dt — zero extra clock reads
                    svc.tracer.complete(
                        "drain", t0, dt,
                        args={"product_waves": svc.stats.product_waves,
                              "waves": svc.stats.waves,
                              "graph_waves": svc.stats.graph_waves})
                    OW.flush_to(svc.tracer)
                self.cond.notify_all()

    # -- continuous product waves -----------------------------------------

    def _run_product(self, taken: dict) -> None:
        """Execute the taken (graph, fuse-key) queues as product waves,
        boarding newly submitted compatible queries at round
        boundaries.  On a fault, unfinished queries re-queue under
        their original tickets before the exception propagates (the
        supervised path then restores + replays instead)."""
        svc = self.svc
        # queues: (graph_id, query) -> tickets, the exactly-once ledger
        queues: dict[tuple, list] = {}
        by_fuse: dict[tuple, dict] = {}
        for (gid, fk), lanes in taken.items():
            for q, tickets in lanes.items():
                queues[(gid, q)] = list(tickets)
                by_fuse.setdefault(fk, {}).setdefault(gid, []).append(q)
        try:
            for fk, per_gid in by_fuse.items():
                gids = list(per_gid)
                for lo in range(0, len(gids), svc.max_graphs):
                    self._product_wave(fk, gids[lo:lo + svc.max_graphs],
                                       per_gid, queues)
        except Exception:
            with self.lock:
                for (gid, q), tickets in queues.items():
                    lanes = svc._queue.setdefault((gid, q.fuse_key()), {})
                    tgt = lanes.setdefault(q, [])
                    tgt.extend(t for t in tickets if t not in tgt)
            raise

    def _board(self, wave: ProductWave, fk, gids, waiting, queues,
               inflight) -> None:
        """Fill free cells — leftovers first, then queries submitted
        since the last boundary (same fuse key, a graph already in the
        wave) — caller holds the lock."""
        svc = self.svc
        col = {gid: i for i, gid in enumerate(gids)}
        for gid in gids:
            key = (gid, fk)
            lanes = svc._queue.get(key)
            if not lanes:
                continue
            for q in list(lanes):
                if (gid, q) in inflight or (gid, q) in queues:
                    # joins the in-flight cell / pending leftovers
                    queues.setdefault((gid, q), []).extend(
                        lanes.pop(q))
                    continue
                queues[(gid, q)] = lanes.pop(q)
                waiting.append((gid, q))
            if not lanes:
                del svc._queue[key]
        still = []
        for gid, q in waiting:
            lane = wave.free_cell(col[gid])
            if lane is None:
                still.append((gid, q))
                continue
            wave.insert(lane, col[gid], q)
            inflight[(gid, q)] = (lane, col[gid])
        waiting[:] = still

    def _product_wave(self, fk, gids, per_gid, queues) -> None:
        """One continuous product wave over the graphs ``gids``."""
        svc = self.svc
        kind = fk[0]
        q0 = per_gid[gids[0]][0]
        fuse = {"iters": q0.iters, "d": q0.d} if kind == "ppr" else {}
        depth = max(len(per_gid[g]) for g in gids)
        width = next(w for w in svc.lane_ladder
                     if w >= min(depth, svc.max_lanes))
        wave = ProductWave(kind, svc._graphset(tuple(gids)), width,
                           spec=svc.spec, fuse=fuse,
                           round_chunk=self.round_chunk)
        waiting = [(gid, q) for gid in gids for q in per_gid[gid]]
        inflight: dict[tuple, tuple] = {}
        with self.lock:
            self._board(wave, fk, gids, waiting, queues, inflight)
            svc.stats.product_waves += 1
            svc.stats.product_cells += width * len(gids)
            svc.stats.product_cells_padded += \
                width * len(gids) - len(inflight)
        with svc.tracer.span("product_wave",
                             args={"kind": kind, "lanes": width,
                                   "graphs": len(gids)}):
            while True:
                svc._fault("continuous")
                done = wave.run_chunk()      # accelerator, lock NOT held
                with self.lock:
                    for (gid, q), (lane, gi) in list(inflight.items()):
                        if wave.cell_done(lane, gi):
                            self._publish(gid, q,
                                          wave.extract(lane, gi), queues)
                            wave.release(lane, gi)
                            del inflight[(gid, q)]
                    boarded = len(inflight)
                    self._board(wave, fk, gids, waiting, queues,
                                inflight)
                    boarded = len(inflight) - boarded
                    if boarded:
                        svc.stats.product_cells_padded -= boarded
                if done and not inflight and not waiting:
                    return
