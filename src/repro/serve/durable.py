"""Durable serving: snapshot/restore + supervised crash recovery for
:class:`repro.serve.graph_service.GraphService`.

A service holds four kinds of warm state that are expensive (or
impossible) to recompute after a crash:

* **graphs** — every registered tenant CSR;
* **results + cache** — answered tickets and the ``(graph_id, query)``
  result cache;
* **in-flight ticket journal** — acknowledged-but-unanswered
  submissions (the queue) plus a write-ahead log of submissions since
  the last snapshot;
* **adaptive state** — the autotuner's calibration fits/race verdicts
  and the per-(kind, graph) learned conflict-ladder levels (the
  DyAdHyTM-style dynamically-tuned policy state).

:class:`ServiceSnapshot` is the portable unit: array payload as
checkpoint *domains* (``Checkpointer.save_domains``), python structure
as the manifest's JSON meta.  :func:`restore_service` rebuilds a WARM
service — the first post-restore drain runs zero timed calibrations
(fits are imported, asserted via ``ServiceStats.timing_runs``) and
commits at the learned M (``CommitSpec.seed_m``).

:class:`ServiceSupervisor` wires it to the generic restart core
(:class:`repro.runtime.fault_tolerance.Supervisor`): ``submit`` appends
to the WAL, ``save`` commits a snapshot (truncating the WAL with it),
and a drain that faults mid-wave restores the last snapshot, replays
the WAL under the original ticket ids, and drains again — no
acknowledged ticket lost, no ticket answered twice (replay skips
tickets the snapshot already accounts for).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core import autotune as AT
from repro.core import commit as C
from repro.graphs.csr import Graph
from repro.runtime.fault_tolerance import Supervisor
from repro.serve.graph_service import GraphService
from repro.serve.queries import query_from_dict, query_to_dict

SNAPSHOT_VERSION = 1
_DOMAINS = ("graphs", "cache", "results")


# -- at-least-once replay sites (aamlint registry) --------------------------

@dataclasses.dataclass(frozen=True)
class ReplaySite:
    """One path that can re-deliver already-submitted work.

    ``witness`` is a source fragment of the guard that makes the replay
    effectively exactly-once; ``repro.analysis.algebra.check_replay_paths``
    asserts it is still present — refactoring a guard away (or moving
    it without re-pointing the declaration) becomes a lint finding,
    because non-idempotent commit ops (pagerank/ppr ``add``) would then
    double-apply on replay."""
    name: str
    module: str
    qualname: str
    witness: str
    note: str


REPLAY_GUARDS = (
    ReplaySite(
        name="wal-replay",
        module="repro.serve.graph_service",
        qualname="GraphService._replay_submit",
        witness="if ticket in self._results",
        note="ServiceSupervisor WAL replay re-enters acknowledged "
             "submissions; answered tickets are skipped so a ticket is "
             "never drained (and its adds never committed) twice."),
    ReplaySite(
        name="degraded-mesh-rehome",
        module="repro.core.engine",
        qualname="run_distributed",
        witness="state, scalars, carry = snap",
        note="a host drop re-homes the LAST COMPLETED chunk snapshot "
             "onto the shrunk mesh — rounds re-execute from a committed "
             "state, never half-applied on top of it."),
    ReplaySite(
        name="continuous-restore",
        module="repro.serve.continuous",
        qualname="ContinuousServer._publish",
        witness="svc._bounded_put(svc._results, t, row",
        note="restore re-runs the wave; results publish keyed by ticket "
             "id into the results map, so a ticket observed twice "
             "overwrites with an identical row instead of appending."),
)


# -- graph ids / result rows over the JSON boundary -------------------------

def _gid_enc(gid) -> dict:
    if isinstance(gid, bool) or not isinstance(gid, (str, int)):
        raise TypeError(f"snapshot graph ids must be str or int, got "
                        f"{type(gid).__name__} ({gid!r})")
    return {"t": "s" if isinstance(gid, str) else "i", "v": gid}


def _gid_dec(d: dict):
    return str(d["v"]) if d["t"] == "s" else int(d["v"])


def _row_enc(row, arrays: list) -> dict:
    """One result row -> meta entry; array parts append to ``arrays``
    (the domain payload, order = meta order)."""
    if isinstance(row, (bool, np.bool_)):
        return {"f": "bool", "v": bool(row)}
    if isinstance(row, tuple):                   # mst: (comp, weight, n)
        comp, weight, n_edges = row
        arrays.append(np.asarray(comp))
        return {"f": "mst", "w": float(weight), "n": int(n_edges)}
    arrays.append(np.asarray(row))
    return {"f": "array"}


def _row_dec(entry: dict, arrays: iter):
    if entry["f"] == "bool":
        return entry["v"]
    if entry["f"] == "mst":
        return (jnp.asarray(next(arrays)), jnp.float32(entry["w"]),
                jnp.int32(entry["n"]))
    return jnp.asarray(next(arrays))


@dataclasses.dataclass
class ServiceSnapshot:
    """One frozen service: JSON-portable ``meta`` (structure) + numpy
    ``domains`` (array payload, keyed by :data:`_DOMAINS`)."""
    meta: dict
    domains: dict

    @property
    def next_ticket(self) -> int:
        return self.meta["next_ticket"]


def build_snapshot(svc: GraphService) -> ServiceSnapshot:
    graphs_meta, graph_arrays = [], []
    for gid, g in svc._graphs.items():
        graphs_meta.append({"id": _gid_enc(gid), "v": g.num_vertices,
                            "e": g.num_edges})
        graph_arrays += [np.asarray(g.indptr), np.asarray(g.src),
                         np.asarray(g.dst), np.asarray(g.weights)]
    cache_meta, cache_arrays = [], []
    if svc._cache is not None:
        for (gid, q), row in svc._cache.items():
            cache_meta.append({"id": _gid_enc(gid),
                               "q": query_to_dict(q),
                               "row": _row_enc(row, cache_arrays)})
    results_meta, result_arrays = [], []
    for ticket, row in svc._results.items():
        results_meta.append({"t": int(ticket),
                             "row": _row_enc(row, result_arrays)})
    queue_meta = []
    for (gid, _fk), lanes in svc._queue.items():
        for q, tickets in lanes.items():
            queue_meta.append({"id": _gid_enc(gid), "q": query_to_dict(q),
                               "tickets": [int(t) for t in tickets]})
    spec = svc.spec
    meta = {
        "schema": "aam-service-snapshot",
        "version": SNAPSHOT_VERSION,
        "config": {
            "spec": dataclasses.asdict(spec),
            "max_lanes": svc.max_lanes, "max_graphs": svc.max_graphs,
            "capacity": svc.capacity, "axis": svc.axis,
            "cache": svc._cache is not None,
            "max_results": svc.max_results, "max_cache": svc.max_cache,
            "product": svc.product,
        },
        "graphs": graphs_meta,
        "cache": cache_meta,
        "results": results_meta,
        "queue": queue_meta,
        "next_ticket": svc._next_ticket,
        "m_learned": [[kind, _gid_enc(gid), int(m)]
                      for (kind, gid), m in svc._m_learned.items()
                      if isinstance(gid, (str, int))
                      and not isinstance(gid, bool)],
        "autotune": AT.DEFAULT_TUNER.export_entries(),
    }
    return ServiceSnapshot(meta=meta, domains={
        "graphs": graph_arrays, "cache": cache_arrays,
        "results": result_arrays})


def restore_service(snap: ServiceSnapshot, *, mesh=None,
                    clock=None) -> GraphService:
    meta = snap.meta
    if meta.get("version", 0) > SNAPSHOT_VERSION:
        raise ValueError(f"snapshot version {meta.get('version')} is newer "
                         f"than this build ({SNAPSHOT_VERSION})")
    cfg = meta["config"]
    svc = GraphService(spec=C.CommitSpec(**cfg["spec"]),
                       max_lanes=cfg["max_lanes"],
                       max_graphs=cfg["max_graphs"], mesh=mesh,
                       capacity=cfg["capacity"], axis=cfg["axis"],
                       cache=cfg["cache"],
                       max_results=cfg["max_results"],
                       max_cache=cfg["max_cache"],
                       # pre-PR-7 snapshots predate the product axis
                       product=cfg.get("product", True),
                       # clocks are process resources (like meshes):
                       # re-injected at restore, never serialized
                       clock=clock)
    ga = iter(snap.domains["graphs"])
    for entry in meta["graphs"]:
        indptr, src, dst, weights = (next(ga) for _ in range(4))
        g = Graph(indptr=jnp.asarray(indptr), src=jnp.asarray(src),
                  dst=jnp.asarray(dst), weights=jnp.asarray(weights),
                  num_vertices=int(entry["v"]), num_edges=int(entry["e"]))
        svc.register_graph(_gid_dec(entry["id"]), g)
    ca = iter(snap.domains["cache"])
    if svc._cache is not None:
        for entry in meta["cache"]:        # insertion order = FIFO order
            svc._cache[(_gid_dec(entry["id"]),
                        query_from_dict(entry["q"]))] = \
                _row_dec(entry["row"], ca)
    ra = iter(snap.domains["results"])
    for entry in meta["results"]:
        svc._results[int(entry["t"])] = _row_dec(entry["row"], ra)
    for entry in meta["queue"]:
        q = query_from_dict(entry["q"])
        gid = _gid_dec(entry["id"])
        lanes = svc._queue.setdefault((gid, q.fuse_key()), {})
        lanes.setdefault(q, []).extend(int(t) for t in entry["tickets"])
    svc._next_ticket = int(meta["next_ticket"])
    svc._m_learned = {(kind, _gid_dec(gid)): int(m)
                      for kind, gid, m in meta.get("m_learned", [])}
    # warm adaptive state: imported fits mean the first drain's policy
    # resolution is a pure cache lookup — zero timed micro-benchmarks
    AT.DEFAULT_TUNER.import_entries(meta.get("autotune", {}))
    return svc


# -- checkpoint-backed persistence ------------------------------------------

def save_snapshot(ckpt: Checkpointer, snap: ServiceSnapshot,
                  step: int | None = None, *, blocking: bool = True,
                  _pre_commit=None) -> int:
    """Commit a snapshot as a domain checkpoint (crash-consistent: the
    COMMITTED marker lands after every leaf; ``_pre_commit`` raising
    simulates a crash mid-save and leaves the previous snapshot intact)."""
    if step is None:
        last = ckpt.latest_step()
        step = (last + 1) if last is not None else 1
    ckpt.save_domains(step, dict(snap.domains),
                      versions={d: SNAPSHOT_VERSION for d in _DOMAINS},
                      meta=snap.meta, blocking=blocking,
                      _pre_commit=_pre_commit)
    return step


def load_snapshot(ckpt: Checkpointer,
                  step: int | None = None) -> tuple[ServiceSnapshot, int]:
    meta = ckpt.meta(step)
    if meta.get("schema") != "aam-service-snapshot":
        raise ValueError(f"checkpoint at {ckpt.dir} is not a service "
                         f"snapshot (schema {meta.get('schema')!r})")
    domains = {}
    got = None
    for d in _DOMAINS:
        arrays, _version, got = ckpt.load_domain_arrays(d, step)
        domains[d] = arrays
    return ServiceSnapshot(meta=meta, domains=domains), got


class ServiceSupervisor(Supervisor):
    """Crash-resumable facade over a GraphService.

    ``submit`` acknowledges a ticket only after journaling it to the WAL
    (JSON-lines next to the checkpoints); ``save`` commits a snapshot
    and truncates the WAL; ``drain`` restores-and-replays on a fault.
    ``mesh`` is re-attached on every restore (process resource)."""

    def __init__(self, service: GraphService, ckpt: Checkpointer, *,
                 max_restarts: int = 10, log=print):
        super().__init__(ckpt, max_restarts=max_restarts)
        self.service = service
        self.log = log
        self._wal = ckpt.dir / "wal.jsonl"

    # -- journaled admission ---------------------------------------------

    def submit(self, graph_id, query) -> int:
        ticket = self.service.submit(graph_id, query)
        with open(self._wal, "a") as f:
            f.write(json.dumps({"t": ticket, "id": _gid_enc(graph_id),
                                "q": query_to_dict(query)}) + "\n")
        return ticket

    def result(self, ticket: int):
        return self.service.result(ticket)

    # -- snapshot lifecycle ----------------------------------------------

    def save(self, step: int | None = None, *, blocking: bool = True,
             _pre_commit=None) -> int:
        """Snapshot the service; the WAL restarts empty at the snapshot
        (its tickets are now accounted inside it).  A crash between
        commit and truncate only leaves already-accounted WAL lines —
        replay skips tickets below the snapshot's ``next_ticket``."""
        step = save_snapshot(self.ckpt, self.service.snapshot(), step,
                             blocking=blocking, _pre_commit=_pre_commit)
        self.ckpt.wait()
        self._wal.write_text("")
        return step

    def restore(self, *, mesh=None) -> GraphService:
        """Last committed snapshot + WAL replay -> a warm service bound
        to this supervisor (original ticket ids preserved)."""
        snap, step = load_snapshot(self.ckpt)
        # the clock survives restore the same way the mesh does: it is a
        # process resource, re-attached rather than serialized
        svc = restore_service(snap, mesh=mesh, clock=self.service.clock)
        # so is the tracer: carrying it over keeps a crash -> restore ->
        # re-drain run a SINGLE trace (one timeline, replay instants
        # between the faulted spans and the re-executed ones)
        svc.tracer = self.service.tracer
        svc.tracer.instant("restore", cat="durable",
                           args={"step": step,
                                 "graphs": len(svc._graphs)})
        base = snap.next_ticket
        replayed = 0
        if self._wal.exists():
            for line in self._wal.read_text().splitlines():
                if not line.strip():
                    continue
                entry = json.loads(line)
                if int(entry["t"]) < base:
                    continue        # already inside the snapshot
                svc._replay_submit(_gid_dec(entry["id"]),
                                   query_from_dict(entry["q"]),
                                   int(entry["t"]))
                replayed += 1
        svc.tracer.instant("wal_replay", cat="durable",
                           args={"replayed": replayed,
                                 "pending": svc.pending()})
        self.log(f"[service] restored snapshot step {step} "
                 f"({len(svc._graphs)} graphs, {svc.pending()} pending)")
        self.service = svc
        return svc

    # -- supervised execution --------------------------------------------

    def drain(self, *, mesh=None) -> dict:
        """``service.drain()`` with restore-and-replay on any fault.
        The faulted service instance is abandoned; the restored one
        re-executes every unanswered acknowledged ticket."""
        try:
            return self.service.drain()
        except KeyboardInterrupt:
            raise
        except Exception as e:  # noqa: BLE001 — any fault → restore
            self.recover_step(e, what="drain", log=self.log)
            self.restore(mesh=mesh if mesh is not None else
                         self.service.mesh)
            return self.service.drain()
