"""Lanes×graphs product waves — resumable, insertable AAM execution
(ISSUE 7).

A :class:`ProductWave` runs ONE fused wave over the
:class:`repro.core.coalescing.ProductAxis`: up to L queries over EACH
graph of a :class:`repro.graphs.csr.GraphSet`.  State is lane-major
over the union key space (``[L, Vtot]``; composite commit keys
``lane * Vtot + offset[g] + v``), so a (lane, graph) CELL is an
independent work item — the hot tenant's three BFS queries and five
single-query tenants drain as one commit stream instead of a lane wave
plus a graph wave.

Two properties make it the serving substrate for continuous batching
(the MaxText prefill/insert/generate shape):

* **resumable** — rounds execute in jit'd chunks of ``round_chunk``;
  between chunks the host owns the state;
* **insertable** — an empty (padding or freed) cell admits a NEW query
  mid-run by splicing its initial state at a round boundary
  (:meth:`insert`); disjoint flat key ranges mean the late cell's
  per-round arithmetic is exactly what an idle run would do, so its
  answer is bit-identical (float ``add`` to rounding — same caveat as
  every transaction-size change) no matter at which round it boarded.

Per-cell completion (:meth:`cell_done`) lets a drain loop harvest and
free finished cells while stragglers keep the wave warm.  Whole-graph
kinds (coloring, MST) have no lane form and stay on the graph batch
axis — ``PRODUCT_KINDS`` names what can ride here.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autotune as AT
from repro.core import commit as C
from repro.core.coalescing import ProductAxis
from repro.core.messages import product_messages
from repro.graphs.csr import GraphSet
from repro.serve.queries import PRODUCT_KINDS

INT_INF = jnp.int32(2 ** 30)
F32_INF = jnp.float32(3.0e38)

# full-run chunk limit: round loops are frontier/rem-bounded, the limit
# only guards the while_loop — one static value keeps the jit key stable
_RUN_ALL = 1 << 30


def _strip_it(st):
    st = dict(st)
    st.pop("it")
    return st


@partial(jax.jit, static_argnames=("axis", "spec", "limit", "weighted"))
def _dist_chunk(g, axis, state, spec, limit, weighted):
    """BFS/SSSP product rounds: FF&MF ``min`` relaxation over the union,
    every lane at once.  Cells converge independently (empty frontier);
    extra rounds cannot move a converged cell (min is monotone and
    components are disjoint)."""
    lanes, vt = axis.lanes, axis.num_vertices
    e = g.src.shape[0]
    dst_b = jnp.broadcast_to(g.dst, (lanes, e))
    step, _ = AT.make_commit_step(spec, "min", state["dist"].reshape(-1),
                                  n=lanes * e, axis_width=axis.race_width,
                                  label="product:dist")

    def cond(st):
        return jnp.any(st["frontier"]) & (st["it"] < limit)

    def body(st):
        dist = st["dist"]
        active = st["frontier"][:, g.src]
        pay = dist[:, g.src] + (g.weights[None, :] if weighted else 1)
        msgs = product_messages(dst_b, pay, active, axis)
        res, lvl = step(dist.reshape(-1), msgs, st["lvl"])
        dist2 = res.state.reshape(lanes, vt)
        return dict(st, dist=dist2, frontier=dist2 != dist, lvl=lvl,
                    it=st["it"] + 1)

    st = jax.lax.while_loop(cond, body,
                            dict(state, it=jnp.zeros((), jnp.int32)))
    return _strip_it(st), ~jnp.any(st["frontier"]), st["it"]


@partial(jax.jit, static_argnames=("axis", "spec", "limit"))
def _ppr_chunk(g, axis, gov, egov, deg, dangling, d, state, spec, limit):
    """Personalized-PageRank product rounds: FF&AS ``add`` waves with a
    per-CELL iteration budget ``rem`` [L, G] (a cell inserted at round k
    still runs its full ``iters`` rounds while earlier cells stop on
    their own schedule) and per-cell dangling mass (segment sums by the
    graph-of-vertex map, one per lane)."""
    lanes, vt = axis.lanes, axis.num_vertices
    ng = axis.num_graphs
    e = g.src.shape[0]
    dst_b = jnp.broadcast_to(g.dst, (lanes, e))
    acc0 = jnp.zeros((lanes * vt,), jnp.float32)
    step, _ = AT.make_commit_step(spec, "add", acc0, n=lanes * e,
                                  axis_width=axis.race_width,
                                  label="product:ppr")

    def cond(st):
        return jnp.any(st["rem"] > 0) & (st["it"] < limit)

    def body(st):
        rank = st["rank"]
        alive = st["rem"] > 0                               # [L, G]
        contrib = d * rank[:, g.src] / deg[g.src][None, :]
        msgs = product_messages(dst_b, contrib, alive[:, egov], axis)
        res, lvl = step(acc0, msgs, st["lvl"])
        dm = jax.ops.segment_sum(
            jnp.where(dangling[None, :], rank, 0.0).T, gov,
            num_segments=ng).T                              # [L, G]
        rank2 = st["restart"] * ((1.0 - d) + d * dm[:, gov]) \
            + res.state.reshape(lanes, vt)
        alive_v = alive[:, gov]                             # [L, Vt]
        return dict(st, rank=jnp.where(alive_v, rank2, rank),
                    rem=st["rem"] - alive.astype(jnp.int32),
                    lvl=lvl, it=st["it"] + 1)

    st = jax.lax.while_loop(cond, body,
                            dict(state, it=jnp.zeros((), jnp.int32)))
    return _strip_it(st), ~jnp.any(st["rem"] > 0), st["it"]


@partial(jax.jit, static_argnames=("axis", "spec", "limit"))
def _stconn_chunk(g, axis, gov, egov, state, spec, limit):
    """s-t connectivity product rounds: query cell (l, g) runs its two
    BFS marks as PAIRED lanes 2l (grey) / 2l+1 (green) of the product
    axis — the same 2-mark nesting ``_union_stconn`` proves, one level
    up.  ``found`` is [L, G] (per-cell segment reduction of the
    mark-meet by graph); answered cells go quiet."""
    l2, vt = axis.lanes, axis.num_vertices        # axis.lanes == 2L
    ng = axis.num_graphs
    e = g.src.shape[0]
    dst_b = jnp.broadcast_to(g.dst, (l2, e))
    step, _ = AT.make_commit_step(spec, "or", state["marks"].reshape(-1),
                                  n=l2 * e, axis_width=axis.race_width,
                                  label="product:stconn")

    def live(st):
        quiet = jnp.repeat(~st["found"], 2, axis=0)         # [2L, G]
        return st["frontier"] & quiet[:, gov]

    def cond(st):
        return jnp.any(live(st)) & (st["it"] < limit)

    def body(st):
        marks = st["marks"]
        quiet_e = jnp.repeat(~st["found"], 2, axis=0)[:, egov]
        active = st["frontier"][:, g.src] & quiet_e
        msgs = product_messages(dst_b, active.astype(jnp.int32), active,
                                axis)
        res, lvl = step(marks.reshape(-1), msgs, st["lvl"])
        marks2 = res.state.reshape(l2, vt)
        frontier2 = (marks2 != 0) & (marks == 0)
        meet = (marks2[0::2] != 0) & (marks2[1::2] != 0)    # [L, Vt]
        found2 = st["found"] | (jax.ops.segment_sum(
            meet.astype(jnp.int32).T, gov, num_segments=ng).T > 0)
        return dict(st, marks=marks2, frontier=frontier2, found=found2,
                    lvl=lvl, it=st["it"] + 1)

    st = jax.lax.while_loop(cond, body,
                            dict(state, it=jnp.zeros((), jnp.int32)))
    return _strip_it(st), ~jnp.any(live(st)), st["it"]


class ProductWave:
    """One resumable lanes×graphs wave over a GraphSet.

    ``lanes`` is the lane budget L (cells per graph); stconn internally
    doubles the axis (paired mark lanes) but its cell coordinates are
    still (lane < L, graph).  ``fuse`` carries the kind's trace-time
    knobs (ppr: ``{"iters": .., "d": ..}``) — queries sharing the wave
    must share them (the service's fuse-key grouping guarantees it).
    """

    def __init__(self, kind: str, gs: GraphSet, lanes: int, *,
                 spec: C.CommitSpec | None = None, fuse: dict | None = None,
                 round_chunk: int = 4):
        if kind not in PRODUCT_KINDS:
            raise ValueError(f"kind {kind!r} has no lane form — serve it "
                             f"on the graph batch axis")
        self.kind = kind
        self.gs = gs
        self.lanes = int(lanes)
        self.spec = spec if spec is not None \
            else C.CommitSpec(backend="coarse", stats=False)
        self.fuse = dict(fuse or {})
        self.round_chunk = int(round_chunk)
        width = 2 * self.lanes if kind == "stconn" else self.lanes
        self.axis = ProductAxis(width, gs.axis.sizes)
        self.g = gs.union()
        self._gov = gs.graph_of_vertex()
        self._egov = gs.graph_of_edge()
        self.occupied = np.zeros((self.lanes, gs.num_graphs), bool)
        self.rounds = 0
        self.done = True                 # empty wave has nothing to run
        vt = self.axis.num_vertices
        lvl_state = jax.ShapeDtypeStruct(
            (self.axis.flat_size,),
            jnp.float32 if kind in ("sssp", "ppr") else jnp.int32)
        _, lvl0 = AT.make_commit_step(
            self.spec, {"bfs": "min", "sssp": "min", "ppr": "add",
                        "stconn": "or"}[kind],
            lvl_state, n=self.axis.flat_size,
            axis_width=self.axis.race_width)
        if kind == "bfs":
            self.state = {"dist": jnp.full((width, vt), INT_INF, jnp.int32),
                          "frontier": jnp.zeros((width, vt), bool),
                          "lvl": lvl0}
        elif kind == "sssp":
            self.state = {"dist": jnp.full((width, vt), F32_INF,
                                           jnp.float32),
                          "frontier": jnp.zeros((width, vt), bool),
                          "lvl": lvl0}
        elif kind == "ppr":
            self.state = {"rank": jnp.zeros((width, vt), jnp.float32),
                          "restart": jnp.zeros((width, vt), jnp.float32),
                          "rem": jnp.zeros((width, gs.num_graphs),
                                           jnp.int32),
                          "lvl": lvl0}
            deg = jnp.maximum(self.g.degrees, 1).astype(jnp.float32)
            self._deg, self._dangling = deg, self.g.degrees == 0
        else:                            # stconn
            self.state = {"marks": jnp.zeros((width, vt), jnp.int32),
                          "frontier": jnp.zeros((width, vt), bool),
                          "found": jnp.zeros((self.lanes, gs.num_graphs),
                                             bool),
                          "lvl": lvl0}

    # -- cell lifecycle ---------------------------------------------------

    def free_cell(self, graph: int) -> int | None:
        """Lowest free lane slot in column ``graph`` (None = full)."""
        for lane in range(self.lanes):
            if not self.occupied[lane, graph]:
                return lane
        return None

    def insert(self, lane: int, graph: int, query) -> None:
        """Claim cell (lane, graph) for ``query`` and splice its initial
        state — legal at ANY round boundary, including round 0 of an
        idle wave and round k of a running one (the continuous-batching
        insert)."""
        if self.occupied[lane, graph]:
            raise ValueError(f"cell ({lane}, {graph}) is occupied")
        off = int(self.gs.voffs[graph])
        st = self.state
        if self.kind in ("bfs", "sssp"):
            src = off + int(query.source)
            zero = 0 if self.kind == "bfs" else 0.0
            self.state = dict(
                st, dist=st["dist"].at[lane, src].set(zero),
                frontier=st["frontier"].at[lane, src].set(True))
        elif self.kind == "ppr":
            src = off + int(query.source)
            self.state = dict(
                st, rank=st["rank"].at[lane, src].set(1.0),
                restart=st["restart"].at[lane, src].set(1.0),
                rem=st["rem"].at[lane, graph].set(int(query.iters)))
        else:                            # stconn: paired mark lanes
            s, t = off + int(query.s), off + int(query.t)
            marks = st["marks"].at[2 * lane, s].set(1) \
                .at[2 * lane + 1, t].set(1)
            frontier = st["frontier"].at[2 * lane, s].set(True) \
                .at[2 * lane + 1, t].set(True)
            self.state = dict(
                st, marks=marks, frontier=frontier,
                found=st["found"].at[lane, graph].set(
                    int(query.s) == int(query.t)))
        self.occupied[lane, graph] = True
        self.done = False

    def cell_done(self, lane: int, graph: int) -> bool:
        """Has cell (lane, graph) converged?  (Monotone kinds cannot
        un-converge — a done cell's answer is final even while the wave
        keeps running for the stragglers.)"""
        if not self.occupied[lane, graph]:
            return False
        lo = int(self.gs.voffs[graph])
        hi = int(self.gs.voffs[graph + 1])
        st = self.state
        if self.kind in ("bfs", "sssp"):
            return not bool(jnp.any(st["frontier"][lane, lo:hi]))
        if self.kind == "ppr":
            return int(st["rem"][lane, graph]) == 0
        if bool(st["found"][lane, graph]):
            return True
        return not bool(jnp.any(st["frontier"][2 * lane:2 * lane + 2,
                                               lo:hi]))

    def extract(self, lane: int, graph: int):
        """The cell's result row (same row types the service caches)."""
        lo = int(self.gs.voffs[graph])
        hi = int(self.gs.voffs[graph + 1])
        st = self.state
        if self.kind in ("bfs", "sssp"):
            return st["dist"][lane, lo:hi]
        if self.kind == "ppr":
            return st["rank"][lane, lo:hi]
        return bool(st["found"][lane, graph])

    def release(self, lane: int, graph: int) -> None:
        """Reset cell (lane, graph) to empty so a later :meth:`insert`
        can reuse the slot mid-run (the continuous loop's harvest)."""
        lo = int(self.gs.voffs[graph])
        hi = int(self.gs.voffs[graph + 1])
        st = self.state
        if self.kind in ("bfs", "sssp"):
            inf = INT_INF if self.kind == "bfs" else F32_INF
            self.state = dict(
                st,
                dist=st["dist"].at[lane, lo:hi].set(inf),
                frontier=st["frontier"].at[lane, lo:hi].set(False))
        elif self.kind == "ppr":
            self.state = dict(
                st,
                rank=st["rank"].at[lane, lo:hi].set(0.0),
                restart=st["restart"].at[lane, lo:hi].set(0.0),
                rem=st["rem"].at[lane, graph].set(0))
        else:
            self.state = dict(
                st,
                marks=st["marks"].at[2 * lane:2 * lane + 2, lo:hi].set(0),
                frontier=st["frontier"]
                .at[2 * lane:2 * lane + 2, lo:hi].set(False),
                found=st["found"].at[lane, graph].set(False))
        self.occupied[lane, graph] = False
        if not self.occupied.any():
            self.done = True

    # -- execution --------------------------------------------------------

    def _step(self, limit: int):
        if self.kind in ("bfs", "sssp"):
            st, done, it = _dist_chunk(self.g, self.axis, self.state,
                                       self.spec, limit,
                                       self.kind == "sssp")
        elif self.kind == "ppr":
            st, done, it = _ppr_chunk(
                self.g, self.axis, self._gov, self._egov, self._deg,
                self._dangling, float(self.fuse.get("d", 0.85)),
                self.state, self.spec, limit)
        else:
            st, done, it = _stconn_chunk(self.g, self.axis, self._gov,
                                         self._egov, self.state,
                                         self.spec, limit)
        self.state = st
        self.rounds += int(it)
        self.done = bool(done)
        return self.done

    def run_chunk(self, rounds: int | None = None) -> bool:
        """Execute up to ``rounds`` (default ``round_chunk``) rounds;
        returns True when no live work remains.  The gap between chunks
        is the ROUND BOUNDARY where :meth:`insert`/:meth:`release` are
        legal."""
        if self.done:
            return True
        return self._step(int(rounds or self.round_chunk))

    def run(self) -> int:
        """Run to completion (the synchronous drain path); returns total
        rounds executed."""
        if not self.done:
            self._step(_RUN_ALL)
        return self.rounds


def lint_traceables(*, lanes: int = 2, sizes=(5, 7), seed: int = 0):
    """``(name, fn_of_state, example_state)`` triples exposing each
    product-chunk round body to ``repro.analysis.waverace``.

    The returned callables take ONLY the chunk's state dict — graph
    arrays, governor maps, and degree vectors are closed over, so the
    analyzer can seed its state chain from exactly the jaxpr's invars.
    Traced via the chunks' unjitted ``__wrapped__`` bodies at
    ``limit=1`` with a concrete ``atomic`` spec (no calibration runs at
    trace time)."""
    from repro.graphs.generators import erdos_renyi, random_weights
    gs = GraphSet([
        random_weights(erdos_renyi(int(s), avg_degree=3.0, seed=seed + i),
                       seed=i)
        for i, s in enumerate(sizes)])
    spec = C.CommitSpec(backend="atomic", stats=False)
    out = []
    for kind in PRODUCT_KINDS:
        pw = ProductWave(kind, gs, lanes, spec=spec)
        if kind in ("bfs", "sssp"):
            fn = (lambda st, pw=pw, w=(kind == "sssp"):
                  _dist_chunk.__wrapped__(pw.g, pw.axis, st, pw.spec,
                                          1, w))
        elif kind == "ppr":
            fn = (lambda st, pw=pw:
                  _ppr_chunk.__wrapped__(pw.g, pw.axis, pw._gov,
                                         pw._egov, pw._deg,
                                         pw._dangling, 0.85, st,
                                         pw.spec, 1))
        else:
            fn = (lambda st, pw=pw:
                  _stconn_chunk.__wrapped__(pw.g, pw.axis, pw._gov,
                                            pw._egov, st, pw.spec, 1))
        out.append((f"product_wave/{kind}", fn, pw.state))
    return out
