"""Production mesh builders.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (jax locks the device count on first init, and the
dry-run needs to set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (v5e pod slice); multi-pod adds a leading 'pod'
    axis (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int | None = None):
    """Small mesh over host devices (tests / CPU-distributed runs)."""
    if pod is not None:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))
