import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation) and record memory / cost / collective
analyses for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k [--multi-pod] [--out artifacts/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell, both meshes
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs.archs import ARCHS, skip_reason
from repro.configs.base import RunConfig, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.runtime import sharding as shd
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step

RULES = shd.ShardingRules(shd.TRAIN_RULES)

# optimizer choice per scale (DESIGN.md §4.1): adafactor >= 100B total params
def pick_optimizer(cfg) -> str:
    return "adafactor" if cfg.param_count() > 1e11 else "adamw"


def batch_shardings(batch_specs, mesh):
    def spec(path, x):
        name = path[-1].key
        if name in ("tokens", "labels", "token"):
            ax = ("batch", "seq")[:len(x.shape)]
        elif name in ("frames", "patch_embeds"):
            ax = ("batch", "seq", "act_embed")
        elif name == "pos":
            ax = ()
        else:
            ax = (None,) * len(x.shape)
        return RULES.sharding_for(ax, x.shape, mesh)
    return jax.tree_util.tree_map_with_path(spec, batch_specs)


def _microbatches(arch: str, shape_name: str) -> int:
    cfg = ARCHS[arch]
    if shape_name != "train_4k":
        return 1
    # keep per-device token count per microbatch <= ~16k for >20B models
    return 4 if cfg.param_count() > 2e10 else 1


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               extra: dict | None = None):
    """Lower + compile one cell. Returns the result record."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    extra = extra or {}
    rcfg = RunConfig(
        model=cfg, shape=shape, multi_pod=multi_pod,
        optimizer=pick_optimizer(cfg),
        # remat only matters under grad; for serve kinds it merely creates
        # reshard boundaries at f32 intermediates (§Perf iteration 8)
        remat=extra.get("remat", "full" if shape.kind == "train" else "none"),
        microbatches=extra.get("microbatches", _microbatches(arch, shape_name)),
        moe_impl=extra.get("moe_impl", "aam"),
        attn_causal_skip=extra.get("attn_causal_skip", False),
        shard_grads=extra.get("shard_grads", False),
        serve_tp=extra.get("serve_tp", False),
        seq_parallel=extra.get("seq_parallel", False),
    )

    t0 = time.time()
    serve_tp = rcfg.serve_tp and shape.kind != "train"
    rules = (shd.ShardingRules(shd.SERVE_TP_RULES) if serve_tp else RULES)
    param_dtype = jnp.bfloat16 if serve_tp else jnp.float32
    params_s = M.param_specs(cfg, param_dtype)
    param_sh = shd.tree_shardings(rules, params_s, mesh)
    batch_s = M.input_specs(cfg, shape)
    batch_sh = batch_shardings(batch_s, mesh)

    with mesh:
        if shape.kind == "train":
            opt = make_optimizer(rcfg)
            opt_s = jax.eval_shape(opt.init, params_s)
            opt_sh = shd.tree_shardings(RULES, opt_s, mesh)
            step_fn = make_train_step(cfg, rcfg, opt)
            step_s = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                step_fn,
                in_shardings=(param_sh, opt_sh, None, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
                donate_argnums=(0, 1),
            )
            lowered = jitted.lower(params_s, opt_s, step_s, batch_s)
        elif shape.kind == "prefill":
            def prefill_fn(params, batch):
                return M.prefill(cfg, rcfg, params, batch)
            cache_s = jax.eval_shape(
                lambda p, b: M.prefill(cfg, rcfg, p, b)[1], params_s, batch_s)
            cache_sh = shd.tree_shardings(RULES, cache_s, mesh)
            jitted = jax.jit(prefill_fn,
                             in_shardings=(param_sh, batch_sh),
                             out_shardings=(None, cache_sh))
            lowered = jitted.lower(params_s, batch_s)
        else:  # decode
            cache_s = M.cache_specs(cfg, rcfg, shape)
            cache_sh = shd.tree_shardings(RULES, cache_s, mesh)

            def decode_fn(params, cache, token, pos):
                return M.decode_step(cfg, rcfg, params, cache, token, pos)
            jitted = jax.jit(
                decode_fn,
                in_shardings=(param_sh, cache_sh,
                              batch_sh["token"], batch_sh["pos"]),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(params_s, cache_s, batch_s["token"],
                                   batch_s["pos"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_stats(compiled.as_text())

    # exact jaxpr-level cost (scan/remat aware; global, unsharded)
    from repro.runtime.flops import cost_of
    if shape.kind == "train":
        jc = cost_of(step_fn, params_s, opt_s, step_s, batch_s)
    elif shape.kind == "prefill":
        jc = cost_of(prefill_fn, params_s, batch_s)
    else:
        jc = cost_of(decode_fn, params_s, cache_s, batch_s["token"],
                     batch_s["pos"])

    # per-device static state bytes from the actual shardings
    def sharded_bytes(specs, shardings):
        tot = 0
        for s, sh in zip(jax.tree.leaves(specs), jax.tree.leaves(shardings)):
            shp = sh.shard_shape(s.shape)
            n = 1
            for d in shp:
                n *= d
            tot += n * jnp.dtype(s.dtype).itemsize
        return tot

    state_bytes = sharded_bytes(params_s, param_sh)
    if shape.kind == "train":
        state_bytes += sharded_bytes(opt_s, opt_sh)
    elif shape.kind == "decode":
        state_bytes += sharded_bytes(cache_s, cache_sh)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_act = cfg.active_param_count()
    model_flops = (6 if shape.kind == "train" else 2) * n_act * tokens

    record = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": mesh.size,
        "kind": shape.kind,
        "optimizer": rcfg.optimizer,
        "microbatches": rcfg.microbatches,
        "moe_impl": rcfg.moe_impl,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": memory_record(mem),
        "state_bytes_per_device": int(state_bytes),
        "xla_cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "bytes accessed output",
                      "optimal_seconds") if k in cost},
        "jaxpr_cost": {"flops": jc.flops, "dot_flops": jc.dot_flops,
                       "bytes_unfused": jc.bytes,
                       "top_prims": dict(sorted(
                           jc.by_prim.items(), key=lambda kv: -kv[1])[:8])},
        "model_flops": float(model_flops),
        "collectives": coll,
        "params_total": cfg.param_count(),
        "params_active": n_act,
    }
    print(f"memory_analysis: {record['memory']}")
    print(f"state_bytes/device: {state_bytes/2**30:.2f} GiB")
    print(f"cost_analysis(xla): {record['xla_cost']}")
    print(f"jaxpr flops={jc.flops:.3e} dot={jc.dot_flops:.3e} "
          f"model_flops={model_flops:.3e}")
    print(f"collectives: {coll['totals']}")
    return record


def memory_record(mem) -> dict:
    out = {}
    for f in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, f, None)
        if v is not None:
            out[f] = int(v)
    if not out:
        out["repr"] = str(mem)
    return out


_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _parse_computations(hlo_text: str):
    """name -> (is_entry, [instruction lines])."""
    comps: dict[str, list[str]] = {}
    entry = None
    current = None
    for ln in hlo_text.splitlines():
        m = _COMP_HDR_RE.match(ln.strip())
        if m and not ln.startswith("  "):
            current = m.group(2)
            comps[current] = []
            if m.group(1):
                entry = current
            continue
        if ln.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(ln)
    return comps, entry


def _computation_multipliers(comps, entry):
    """Execution count per computation: while bodies scale by trip count,
    call/fusion/reduce edges propagate the caller's multiplier."""
    mult = {name: 0.0 for name in comps}
    if entry is None:
        return {name: 1.0 for name in comps}
    mult[entry] = 1.0
    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(len(comps)):
        changed = False
        for name, lines in comps.items():
            m0 = mult.get(name, 0.0)
            if m0 == 0.0:
                continue
            for ln in lines:
                if " while(" in ln or ln.strip().startswith("%while") \
                        or "= (" in ln and "while(" in ln:
                    body = _BODY_RE.search(ln)
                    trip = _TRIP_RE.search(ln)
                    n = float(trip.group(1)) if trip else 1.0
                    for mm, factor in ((body, n), (_COND_RE.search(ln), n + 1)):
                        if mm and mult.get(mm.group(1), 0.0) < m0 * factor:
                            mult[mm.group(1)] = m0 * factor
                            changed = True
                else:
                    for cm in _CALL_RE.finditer(ln):
                        if mult.get(cm.group(1), 0.0) < m0:
                            mult[cm.group(1)] = m0
                            changed = True
        if not changed:
            break
    return {k: (v if v > 0 else 1.0) for k, v in mult.items()}


def collective_stats(hlo_text: str) -> dict:
    """Per-collective bytes from the compiled HLO, with while-loop
    trip-count multipliers (collectives inside the layer scan count
    num_blocks times).  Records result bytes, estimated wire bytes per
    device (ring formulas), and the participant-group size."""
    comps, entry = _parse_computations(hlo_text)
    mult = _computation_multipliers(comps, entry)

    # name -> result shape string (global, for operand lookup)
    shapes: dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                name, rhs = m.groups()
                shapes[name] = rhs.split(" ")[0]

    per_op: dict[str, dict] = {c: {"count": 0, "result_bytes": 0,
                                   "wire_bytes": 0}
                               for c in _COLLECTIVES}
    for cname, lines in comps.items():
        k = mult.get(cname, 1.0)
        for ln in lines:
            m = _DEF_RE.match(ln)
            if not m:
                continue
            _, rhs = m.groups()
            opm = re.search(
                r"\b(" + "|".join(_COLLECTIVES) + r")(-start)?\(", rhs)
            if not opm or "-done(" in rhs:
                continue
            op = opm.group(1)
            rb = _shape_bytes(rhs.split(" ")[0])
            gm = _GROUPS_RE.search(rhs)
            gsize = int(gm.group(2)) if gm else 0
            n = max(gsize, 2)
            ring = (n - 1) / n
            if op == "all-reduce":
                wire = 2 * rb * ring
            elif op == "all-gather":
                wire = rb * ring          # result is the gathered tensor
            elif op == "reduce-scatter":
                wire = rb * (n - 1)       # operand = result * n
            elif op == "all-to-all":
                wire = rb * ring
            else:                          # collective-permute
                wire = rb
            per_op[op]["count"] += int(k)
            per_op[op]["result_bytes"] += int(rb * k)
            per_op[op]["wire_bytes"] += int(wire * k)
            per_op[op].setdefault("group_sizes", set()).add(gsize)
    for v in per_op.values():
        if "group_sizes" in v:
            v["group_sizes"] = sorted(v["group_sizes"])
    totals = {"count": sum(v["count"] for v in per_op.values()),
              "result_bytes": sum(v["result_bytes"] for v in per_op.values()),
              "wire_bytes": sum(v["wire_bytes"] for v in per_op.values())}
    return {"per_op": per_op, "totals": totals}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(SHAPES), default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-impl", default=None,
                    choices=["aam", "dense", "aam_shmap"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--shard-grads", action="store_true")
    ap.add_argument("--serve-tp", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                for mp in (False, True):
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, args.multi_pod)]

    extra = {}
    if args.moe_impl:
        extra["moe_impl"] = args.moe_impl
    if args.microbatches:
        extra["microbatches"] = args.microbatches
    if args.causal_skip:
        extra["attn_causal_skip"] = True
    if args.shard_grads:
        extra["shard_grads"] = True
    if args.serve_tp:
        extra["serve_tp"] = True
    if args.seq_parallel:
        extra["seq_parallel"] = True

    failures = 0
    for arch, shape_name, mp in cells:
        mesh_tag = "2x16x16" if mp else "16x16"
        stem = f"{arch}__{shape_name}__{mesh_tag}{args.tag}"
        path = outdir / f"{stem}.json"
        reason = skip_reason(arch, shape_name)
        if reason:
            path.write_text(json.dumps(
                {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                 "skipped": reason}, indent=1))
            print(f"[skip] {stem}: {reason}")
            continue
        print(f"[cell] {stem} ...", flush=True)
        try:
            rec = build_cell(arch, shape_name, mp, extra)
            rec["tag"] = args.tag
            path.write_text(json.dumps(rec, indent=1))
            print(f"[ok]   {stem} compile={rec['compile_s']}s "
                  f"jaxpr_flops={rec['jaxpr_cost']['flops']:.3e}")
        except Exception:
            failures += 1
            err = traceback.format_exc()
            path.with_suffix(".err").write_text(err)
            print(f"[FAIL] {stem}\n{err}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
