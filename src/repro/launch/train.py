"""Training launcher.

CPU-runnable end-to-end (reduced configs) and structured exactly like the
TPU path: mesh → shardings → jit train_step → supervised loop with async
checkpoints, straggler watchdog, restore-on-failure, exact resume.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.archs import ARCHS
from repro.configs.base import RunConfig, ShapeConfig, smoke_model
from repro.data.pipeline import TokenStream
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import model as M
from repro.runtime import sharding as shd
from repro.runtime.fault_tolerance import StragglerWatchdog, TrainSupervisor
from repro.train.optimizer import make_optimizer
from repro.train.train_step import make_train_step

RULES = shd.ShardingRules(shd.TRAIN_RULES)


def build(arch: str, *, smoke: bool, batch: int, seq: int, lr: float,
          microbatches: int, moe_impl: str, production_mesh: bool):
    cfg = ARCHS[arch]
    if smoke:
        cfg = smoke_model(cfg)
    shape = ShapeConfig("cli", seq, batch, "train")
    rcfg = RunConfig(model=cfg, shape=shape, learning_rate=lr,
                     microbatches=microbatches, moe_impl=moe_impl,
                     remat="full" if not smoke else "none")
    if production_mesh:
        mesh = make_production_mesh()
    else:
        nd = jax.device_count()
        mesh = make_host_mesh(nd, 1)
    return cfg, rcfg, mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--moe-impl", default="aam", choices=["aam", "dense"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg, rcfg, mesh = build(
        args.arch, smoke=args.smoke, batch=args.batch, seq=args.seq,
        lr=args.lr, microbatches=args.microbatches, moe_impl=args.moe_impl,
        production_mesh=args.production_mesh)
    print(f"[launch] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"mesh {dict(mesh.shape)}")

    opt = make_optimizer(rcfg)
    with mesh:
        params = jax.jit(lambda k: M.init(cfg, k)[0])(
            jax.random.PRNGKey(args.seed))
        opt_state = jax.jit(opt.init)(params)
        param_sh = shd.tree_shardings(RULES, params, mesh)
        opt_sh = shd.tree_shardings(RULES, opt_state, mesh)
        params = jax.device_put(params, param_sh)
        opt_state = jax.device_put(opt_state, opt_sh)

        step_fn = jax.jit(make_train_step(cfg, rcfg, opt),
                          donate_argnums=(0, 1))
        stream = TokenStream(cfg, rcfg.shape, seed=args.seed)
        ckpt = Checkpointer(args.ckpt_dir)
        sup = TrainSupervisor(ckpt, save_every=args.save_every,
                              watchdog=StragglerWatchdog())

        start = 0
        if ckpt.latest_step() is not None:
            (params, opt_state), start = ckpt.restore((params, opt_state))
            print(f"[launch] resumed from step {start}")

        def run_step(state, step, batch):
            params, opt_state = state
            batch = jax.tree.map(jnp.asarray, batch)
            params, opt_state, metrics = step_fn(
                params, opt_state, jnp.int32(step), batch)
            return (params, opt_state), metrics

        t0 = time.time()
        state, final, log = sup.run(
            (params, opt_state), run_step, stream.batch,
            start_step=start, num_steps=args.steps)
        dt = time.time() - t0
        tokens = (args.steps - start) * args.batch * args.seq
        print(f"[launch] done: {final} steps, {tokens/dt:.0f} tok/s, "
              f"final metrics: {log[-1][1] if log else {}}")


if __name__ == "__main__":
    main()
