"""Serving launcher: batched prefill + decode over the production layout.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 32 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.archs import ARCHS
from repro.configs.base import RunConfig, ShapeConfig, smoke_model
from repro.launch.mesh import make_host_mesh
from repro.models import model as M
from repro.serve.serve_step import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = ARCHS[args.arch]
    if args.smoke:
        cfg = smoke_model(cfg)
    shape = ShapeConfig("serve", args.prompt_len + args.new_tokens,
                        args.batch, "decode")
    rcfg = RunConfig(model=cfg, shape=shape, remat="none")
    mesh = make_host_mesh(1, jax.device_count())

    rng = np.random.default_rng(args.seed)
    with mesh:
        params, _ = M.init(cfg, jax.random.PRNGKey(args.seed))
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
            jnp.int32)}
        if cfg.encoder_layers:
            batch["frames"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16)
        if cfg.frontend == "patch":
            batch["patch_embeds"] = jnp.asarray(rng.standard_normal(
                (args.batch, cfg.frontend_seq, cfg.d_model)), jnp.bfloat16)
        t0 = time.time()
        toks = generate(cfg, rcfg, params, batch,
                        max_new_tokens=args.new_tokens,
                        temperature=args.temperature, seed=args.seed)
        dt = time.time() - t0
        print(f"[serve] {args.arch}: generated {toks.shape} in {dt:.2f}s "
              f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
        print("[serve] sample:", np.asarray(toks[0][:16]))


if __name__ == "__main__":
    main()
