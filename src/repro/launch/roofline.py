"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Terms per (arch × shape × mesh), TPU v5e constants:
  compute    = FLOPs / (chips × 197 TF/s bf16)
  memory     = HBM bytes / (chips × 819 GB/s)     [lo/hi bounds — see below]
  collective = wire bytes per chip / (4 links × 50 GB/s aggregate? NO —
               per the assignment formula: collective_bytes/(chips×link_bw),
               i.e. one 50 GB/s link per chip as the conservative unit]

FLOPs come from the jaxpr walker (exact, scan/remat aware) — XLA-CPU
``cost_analysis`` counts while bodies once and is reported alongside for
transparency.  HBM bytes are bounded: ``lo`` = 2×resident state (params/opt/
cache read+write once per step), ``hi`` = unfused per-op traffic from the
jaxpr (XLA fusion only reduces it).  Collective wire bytes come from the
compiled HLO with while-loop trip-count multipliers and ring-algorithm
cost formulas.

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/dryrun]
      [--mesh 16x16] [--csv out.csv] [--md out.md]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # B/s / chip
LINK_BW = 50e9             # B/s / ICI link (assignment constant)


def structural_mem_bytes(d: dict) -> float:
    """Fusion-aware HBM-traffic estimate per device per step.

    Components: parameter reads per pass (fwd + remat recompute + bwd for
    train), gradient + optimizer state traffic, layer-boundary activation
    tensors (~12 reads/writes of [tokens, d_model] per layer per pass —
    attention/MLP internals stay fused in VMEM per the flash/Pallas
    designs), and KV-cache traffic for decode.  The jaxpr unfused number is
    kept as the upper bound; this is the engineering estimate the §Perf
    iterations target."""
    from repro.configs.archs import ARCHS
    from repro.configs.base import SHAPES
    cfg = ARCHS[d["arch"]]
    shape = SHAPES[d["shape"]]
    chips = d["n_devices"]
    kind = d["kind"]
    mb = d.get("microbatches", 1)
    serve_tp = "tp" in d.get("tag", "")
    p_dtype = 2 if serve_tp else 4
    params_local = cfg.param_count() * p_dtype / chips
    active_local = cfg.active_param_count() * p_dtype / chips
    # activations are sharded over data axes only (replicated over model):
    # tokens per device = global_tokens / n_data  (n_data = chips / 16)
    n_data = chips / 16
    tokens_dev = shape.global_batch * (
        1 if kind == "decode" else shape.seq_len) / n_data
    act = 12 * cfg.num_layers * tokens_dev * cfg.d_model * 2  # bf16
    if kind == "train":
        passes = 3 * mb           # fwd + remat + bwd per microbatch
        traffic = params_local * (2 * passes / 2 +  # bf16 casts read
                                  4)                # grad w+r, opt r+w
        traffic += act * passes / mb
    elif kind == "prefill":
        traffic = params_local + act
        traffic += d["state_bytes_per_device"]      # cache write
    else:  # decode
        traffic = active_local + 2 * d["state_bytes_per_device"]
    return traffic


def load(dirpath: str, mesh: str | None = None, tag: str = ""):
    rows = []
    for p in sorted(Path(dirpath).glob("*.json")):
        d = json.loads(p.read_text())
        if d.get("skipped"):
            rows.append(d)
            continue
        if mesh and d["mesh"] != mesh:
            continue
        if d.get("tag", "") != tag:
            continue
        rows.append(d)
    return rows


def terms(d: dict) -> dict:
    chips = d["n_devices"]
    flops = d["jaxpr_cost"]["flops"]
    t_compute = flops / (chips * PEAK_FLOPS)
    state = d["state_bytes_per_device"]
    t_mem_lo = 2.0 * state / HBM_BW
    t_mem_hi = d["jaxpr_cost"]["bytes_unfused"] / (chips * HBM_BW)
    wire = d["collectives"]["totals"]["wire_bytes"]   # per device
    t_coll = wire / LINK_BW
    t_mem_struct = structural_mem_bytes(d) / HBM_BW
    terms3 = {"compute": t_compute, "memory": t_mem_struct,
              "collective": t_coll}
    dominant = max(terms3, key=terms3.get)
    bound = max(terms3.values())
    mf = d["model_flops"]
    return {
        "t_compute": t_compute, "t_mem_lo": t_mem_lo, "t_mem_hi": t_mem_hi,
        "t_mem": t_mem_struct,
        "t_coll": t_coll, "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / max(flops, 1),
        # roofline fraction: useful-model-compute time / bound time
        "roofline_frac": (mf / (chips * PEAK_FLOPS)) / max(bound, 1e-12),
        "step_s_bound": bound,
    }


_LEVER = {
    "collective": "cut re-gathered weights (move FSDP all-gather out of the "
                  "microbatch loop / reduce-scatter grads instead of "
                  "all-reduce)",
    "memory": "fuse/eliminate layout ops; bf16 state; bigger tiles to raise "
              "arithmetic intensity",
    "compute": "remove remat waste / causal-skip attention / larger M tiles "
               "to cut dispatch overhead",
}


def lever(d: dict, t: dict) -> str:
    if t["dominant"] == "compute" and t["useful_ratio"] < 0.7:
        return ("compute-bound with useful/total=%.2f: cut remat recompute "
                "or attention waste" % t["useful_ratio"])
    return _LEVER[t["dominant"]]


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s (struct; unfused-hi)"
           " | collective s | dominant | 6ND/HLO | roofline frac | lever |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for d in rows:
        if d.get("skipped"):
            out.append(f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — "
                       f"| — | SKIP | — | — | {d['skipped']} |")
            continue
        t = terms(d)
        out.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {t['t_compute']:.3f} "
            f"| {t['t_mem']:.3f} ({t['t_mem_hi']:.1f}) "
            f"| {t['t_coll']:.3f} | **{t['dominant']}** "
            f"| {t['useful_ratio']:.2f} | {t['roofline_frac']:.3f} "
            f"| {lever(d, t)} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--tag", default="")
    ap.add_argument("--md", default=None)
    ap.add_argument("--csv", default=None)
    args = ap.parse_args()
    rows = load(args.dir, args.mesh, args.tag)
    md = to_markdown(rows)
    print(md)
    if args.md:
        Path(args.md).write_text(md + "\n")
    if args.csv:
        import csv
        with open(args.csv, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(["arch", "shape", "mesh", "t_compute", "t_mem",
                        "t_mem_lo", "t_mem_hi", "t_coll", "dominant",
                        "useful_ratio", "roofline_frac"])
            for d in rows:
                if d.get("skipped"):
                    continue
                t = terms(d)
                w.writerow([d["arch"], d["shape"], d["mesh"],
                            t["t_compute"], t["t_mem"], t["t_mem_lo"],
                            t["t_mem_hi"], t["t_coll"], t["dominant"],
                            t["useful_ratio"], t["roofline_frac"]])


if __name__ == "__main__":
    main()
