"""Deterministic synthetic data pipeline.

A seeded mixture of Markov chains over the vocabulary — learnable structure
(a transformer drives the loss well below the unigram entropy), fully
offline, and reproducible across restarts: batch ``i`` is a pure function of
(seed, i), which is what makes checkpoint-resume exactly replayable and
elastic rescaling deterministic (the stream is indexed by *global step*,
not by host).  Host-sharding: each host materializes only its slice.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class TokenStream:
    cfg: ModelConfig
    shape: ShapeConfig
    seed: int = 0
    order: int = 1            # markov order
    num_chains: int = 8

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = min(self.cfg.vocab_size, 4096)
        self.v = v
        # sparse-ish row-stochastic transitions, peaked for learnability
        self.trans = np.zeros((self.num_chains, v, 8), np.int64)
        for c in range(self.num_chains):
            self.trans[c] = rng.integers(0, v, (v, 8))

    def batch(self, step: int, *, host_id: int = 0, num_hosts: int = 1):
        """Global batch slice for this host: dict(tokens, labels[, stubs])."""
        b = self.shape.global_batch // num_hosts
        s = self.shape.seq_len
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + host_id)
        chain = rng.integers(0, self.num_chains, b)
        toks = np.zeros((b, s + 1), np.int64)
        toks[:, 0] = rng.integers(0, self.v, b)
        for t in range(s):
            nxt = self.trans[chain, toks[:, t],
                             rng.integers(0, 8, b)]
            # occasional uniform noise keeps entropy positive
            noise = rng.random(b) < 0.1
            toks[:, t + 1] = np.where(noise, rng.integers(0, self.v, b), nxt)
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        out = {"tokens": tokens, "labels": labels}
        d = self.cfg.d_model
        if self.cfg.encoder_layers:
            out["frames"] = rng.standard_normal(
                (b, self.cfg.encoder_seq, d)).astype(np.float32)
        elif self.cfg.frontend == "patch":
            f = self.cfg.frontend_seq
            out["patch_embeds"] = rng.standard_normal(
                (b, f, d)).astype(np.float32)
            out["tokens"] = tokens[:, :s - f]
            out["labels"] = labels
        return out
