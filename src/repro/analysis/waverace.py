"""Jaxpr-level wave-race detection.

An HTM transaction aborts when another core touches its read/write set;
our software rounds have no such tripwire — a round that scatters into
a state array OUTSIDE ``commit()``'s conflict resolution while also
reading it produces silently order-dependent results (the classic
in-wave read race the paper's Table 2 "conflicting access" aborts would
have caught in hardware).

The detector traces each algorithm's round step to a jaxpr and walks
it:

* the *state chain* starts at the round's state-leaf inputs and grows
  through aliasing primitives (reshape/convert/select/...) and through
  scatter outputs (a functional scatter's result aliases its operand);
* every ``commit()`` executes under ``jax.named_scope("aam_commit")``,
  which JAX records in each equation's ``source_info.name_stack`` —
  including inside ``while``/``scan`` sub-jaxprs;
* a scatter whose operand is on the chain **without** ``aam_commit`` on
  its name stack is a finding: a raw state write that bypasses conflict
  resolution.  Gathers of chained arrays outside the scope are recorded
  as the read half of the race (evidence, not findings — reading state
  is what rounds are for).

Round steps come from two seams:

* :func:`capture_algorithms` calls every public ``distributed_*`` /
  ``batched_over_graphs_*`` wrapper on a tiny graph with
  ``repro.core.engine._LINT_CAPTURE`` set; :class:`~repro.core.engine.
  LintCapture` carries out the normalized ``(alg, graph, batch)`` so the
  wrapper's own state/payload plumbing is what gets analyzed;
* :func:`repro.serve.product_wave.lint_traceables` exposes the three
  ``ProductWave`` chunk bodies as state-only callables.

The round is traced against :class:`LintRuntime`, a single-shard
``WaveRuntime`` stand-in whose ``wave`` is a plain ``commit()`` on the
same composite keys (so the scoped write path looks exactly like
production) and whose collectives are identities.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
from types import SimpleNamespace

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import engine as E
from repro.core.commit import CommitSpec, commit
from repro.core.coalescing import fuse_keys
from repro.core.messages import make_messages

# output var aliases input: chain propagates through
ALIAS_PRIMS = {
    "reshape", "convert_element_type", "transpose", "squeeze",
    "broadcast_in_dim", "select_n", "copy", "rev", "slice",
    "concatenate", "expand_dims", "add", "sub", "mul", "max", "min",
    "and", "or", "where", "pad",
}
# functional state writes
SCATTER_PRIMS = {"scatter", "scatter-add", "scatter-min", "scatter-max",
                 "scatter-mul"}
# kernel-launch state writes: a Pallas kernel whose operands include
# round state commits directly from VMEM (the fused route+commit pass of
# repro.kernels.fused_wave, the coarse-commit kernel) — same rule as the
# scatters: in-scope = the protected commit site, out-of-scope = a raw
# state write that bypasses conflict resolution.  Handled BEFORE the
# generic call-descent: a pallas_call's params carry the KERNEL jaxpr
# (refs + get/swap primitives, a different var universe), which must not
# be walked as if it were a pjit body.
KERNEL_PRIMS = {"pallas_call"}
# state reads
GATHER_PRIMS = {"gather", "dynamic_slice"}

_SCOPE = "aam_commit"


@dataclasses.dataclass
class RaceFinding:
    where: str          # algorithm / traceable name
    primitive: str
    scoped: bool
    detail: str


@dataclasses.dataclass
class RaceReport:
    name: str
    findings: list = dataclasses.field(default_factory=list)
    reads: int = 0          # unscoped gathers of chained state (evidence)
    commits: int = 0        # scoped writes (the healthy path)

    @property
    def ok(self) -> bool:
        return not self.findings


def _in_scope(eqn) -> bool:
    return _SCOPE in str(eqn.source_info.name_stack)


def _vars(atoms):
    return [a for a in atoms if not isinstance(a, jax.core.Literal)]


def _walk(jaxpr, chain: set, rep: RaceReport, where: str,
          scoped: bool = False) -> set:
    """Walk one (open) jaxpr; ``chain`` holds this jaxpr's vars known to
    alias round state.  Returns the chain (mutated in place too).

    ``scoped=True`` means an ENCLOSING call eqn already carried the
    ``aam_commit`` scope: sub-jaxpr name stacks are relative to their
    call eqn (a jitted kernel wrapper records the scope on the pjit eqn,
    not inside it), so scope inherits down the descent."""
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        invars = _vars(eqn.invars)
        on_chain = [v for v in invars if v in chain]
        eqn_scoped = scoped or _in_scope(eqn)

        if prim in ("while",):
            _walk_while(eqn, chain, rep, where, eqn_scoped)
            continue
        if prim == "scan":
            _walk_scan(eqn, chain, rep, where, eqn_scoped)
            continue
        if prim == "cond":
            _walk_cond(eqn, chain, rep, where, eqn_scoped)
            continue
        if prim in KERNEL_PRIMS:
            if on_chain:
                if eqn_scoped:
                    rep.commits += 1
                else:
                    rep.findings.append(RaceFinding(
                        where=where, primitive=prim, scoped=False,
                        detail=f"kernel launch ({prim}) writes round "
                               f"state outside commit()'s conflict "
                               f"resolution — a fused-kernel commit "
                               f"site must run under "
                               f"jax.named_scope({_SCOPE!r}) (reads of "
                               f"the same array this round: "
                               f"{rep.reads})"))
                chain.update(_vars(eqn.outvars))
            continue
        inner = _call_jaxpr(eqn)
        if inner is not None:
            _walk_call(eqn, inner, chain, rep, where, eqn_scoped)
            continue

        if prim in SCATTER_PRIMS:
            operand = eqn.invars[0]
            if not isinstance(operand, jax.core.Literal) \
                    and operand in chain:
                if eqn_scoped:
                    rep.commits += 1
                else:
                    rep.findings.append(RaceFinding(
                        where=where, primitive=prim, scoped=False,
                        detail=f"raw {prim} into round state outside "
                               f"commit()'s conflict resolution — an "
                               f"in-wave write race (reads of the same "
                               f"array this round: {rep.reads})"))
                chain.update(_vars(eqn.outvars))
            continue
        if prim in GATHER_PRIMS:
            if on_chain and not eqn_scoped:
                rep.reads += 1
            continue
        if on_chain and prim in ALIAS_PRIMS:
            chain.update(_vars(eqn.outvars))
    return chain


def _call_jaxpr(eqn):
    """ClosedJaxpr of a call-like primitive (pjit/closed_call/remat...)."""
    for key in ("jaxpr", "call_jaxpr"):
        ij = eqn.params.get(key)
        if ij is not None:
            return ij
    return None


def _map_in(inner_jaxpr, outer_invars, chain):
    return {iv for iv, ov in zip(inner_jaxpr.invars, outer_invars)
            if not isinstance(ov, jax.core.Literal) and ov in chain}


def _map_out(inner_jaxpr, inner_chain, eqn, chain):
    for ov, res in zip(eqn.outvars, inner_jaxpr.outvars):
        if not isinstance(res, jax.core.Literal) and res in inner_chain:
            chain.add(ov)


def _walk_call(eqn, closed, chain, rep, where, scoped=False):
    ij = closed.jaxpr if hasattr(closed, "jaxpr") else closed
    inner = _map_in(ij, eqn.invars, chain)
    _walk(ij, inner, rep, where, scoped)
    _map_out(ij, inner, eqn, chain)


def _walk_while(eqn, chain, rep, where, scoped=False):
    cn = eqn.params["cond_nconsts"]
    bn = eqn.params["body_nconsts"]
    body = eqn.params["body_jaxpr"].jaxpr
    cond = eqn.params["cond_jaxpr"].jaxpr
    body_outer = eqn.invars[cn:]                  # body consts + carry
    inner = _map_in(body, body_outer, chain)
    # carry fixpoint: a chained carry slot may only become chained after
    # one body pass — two passes reach the fixpoint for alias chains
    for _ in range(2):
        snapshot = set(inner)
        _walk(body, inner, rep, where, scoped)
        # feed body outputs (carry') back into carry invars
        carry_in = body.invars[bn:]
        for civ, res in zip(carry_in, body.outvars):
            if not isinstance(res, jax.core.Literal) and res in inner:
                inner.add(civ)
        if inner == snapshot:
            break
    cond_inner = _map_in(cond, eqn.invars[:cn] + body_outer[bn:], chain)
    _walk(cond, cond_inner, rep, where, scoped)
    # while outvars = final carry
    carry_results = body.outvars
    for ov, res in zip(eqn.outvars, carry_results):
        if not isinstance(res, jax.core.Literal) and res in inner:
            chain.add(ov)


def _walk_scan(eqn, chain, rep, where, scoped=False):
    nc = eqn.params["num_consts"]
    ncar = eqn.params["num_carry"]
    body = eqn.params["jaxpr"].jaxpr
    inner = _map_in(body, eqn.invars, chain)
    for _ in range(2):
        snapshot = set(inner)
        _walk(body, inner, rep, where, scoped)
        carry_in = body.invars[nc:nc + ncar]
        for civ, res in zip(carry_in, body.outvars[:ncar]):
            if not isinstance(res, jax.core.Literal) and res in inner:
                inner.add(civ)
        if inner == snapshot:
            break
    for ov, res in zip(eqn.outvars, body.outvars):
        if not isinstance(res, jax.core.Literal) and res in inner:
            chain.add(ov)


def _walk_cond(eqn, chain, rep, where, scoped=False):
    operands = eqn.invars[1:]
    for closed in eqn.params["branches"]:
        ij = closed.jaxpr
        inner = _map_in(ij, operands, chain)
        _walk(ij, inner, rep, where, scoped)
        _map_out(ij, inner, eqn, chain)


@contextlib.contextmanager
def _no_env_sanitize():
    """Trace the SHIPPED program: the REPRO_SANITIZE shadow replay would
    otherwise inject its own commit dispatch into the jaxpr and skew
    commit counts (spec-level ``sanitize=True`` is still honored — that
    is part of the program under analysis)."""
    old = os.environ.get("REPRO_SANITIZE")
    os.environ["REPRO_SANITIZE"] = "0"
    try:
        yield
    finally:
        if old is None:
            os.environ.pop("REPRO_SANITIZE", None)
        else:
            os.environ["REPRO_SANITIZE"] = old


def check_traceable(name: str, fn, *example_args) -> RaceReport:
    """Race-check one callable whose positional args are ALL round
    state (each pytree leaf seeds the chain)."""
    rep = RaceReport(name=name)
    with _no_env_sanitize():
        closed = jax.make_jaxpr(fn)(*example_args)
    n_state = len(jax.tree.leaves(example_args))
    chain = set(closed.jaxpr.invars[:n_state])
    _walk(closed.jaxpr, chain, rep, name)
    return rep


# -- single-shard WaveRuntime stand-in --------------------------------------

class LintRuntime:
    """Single-shard :class:`repro.core.engine.WaveRuntime` mimic.

    ``wave`` commits on the same composite keys production uses (so the
    protected write path carries the ``aam_commit`` scope); collectives
    are identities (one shard owns everything); telemetry attributes
    exist so round functions can read them."""

    def __init__(self, block: int, batch=None,
                 spec: CommitSpec | None = None):
        self.block = int(block)
        self.batch = batch
        self.spec = spec if spec is not None \
            else CommitSpec(backend="atomic", stats=False)
        self.level = None
        self.max_subrounds = 1
        self.conflicts = jnp.zeros((), jnp.int32)
        self.subrounds = jnp.zeros((), jnp.int32)
        self.messages = jnp.zeros((), jnp.int32)
        self.delivered_all = jnp.ones((), bool)

    @property
    def shard(self):
        return jnp.zeros((), jnp.int32)

    @property
    def gid(self):
        return jnp.arange(self.block, dtype=jnp.int32)

    def psum(self, x):
        return x

    def any(self, mask):
        return jnp.any(mask)

    def wave(self, state_l, target, payload, valid, *, op: str,
             major=None, batch=None):
        batch = batch if batch is not None else self.batch
        width = batch.wave_width if batch is not None else 1
        key = jnp.clip(jnp.asarray(target, jnp.int32), 0, self.block - 1)
        if width > 1:
            if major is None:
                raise ValueError("wave_width > 1 needs per-message "
                                 "`major` item ids")
            key = fuse_keys(key, jnp.clip(jnp.asarray(major, jnp.int32),
                                          0, width - 1), width)
        key = jnp.where(jnp.asarray(valid, bool), key, -1)
        s_leaves, tdef = jax.tree.flatten(state_l)
        p_leaves = jax.tree.leaves(payload)
        if len(p_leaves) != len(s_leaves):
            raise ValueError("state/payload pytrees must match")
        new_s, succ = [], []
        for s, p in zip(s_leaves, p_leaves):
            res = commit(s, make_messages(key, jnp.asarray(p),
                                          jnp.asarray(valid, bool)),
                         op, self.spec)
            new_s.append(res.state)
            succ.append(res.success)
        return tdef.unflatten(new_s), tdef.unflatten(succ)

    def gather(self, arr_l, idx, valid=None, *, fill=0):
        idx = jnp.asarray(idx, jnp.int32)
        if valid is None:
            valid = jnp.ones(idx.shape, bool)
        idxc = jnp.clip(idx, 0, self.block - 1)

        def read(a):
            out = a[idxc]
            f = jnp.asarray(fill, out.dtype)
            return jnp.where(valid, out, f)

        return jax.tree.map(read, arr_l)


# -- entry-point catalog ----------------------------------------------------

def _tiny_graphs(seed: int = 0):
    """One weighted tiny graph + a 2-graph GraphSet (sizes differ so
    graph-batch offsets are non-trivial)."""
    from repro.graphs.csr import GraphSet
    from repro.graphs.generators import erdos_renyi, random_weights
    g = random_weights(erdos_renyi(12, avg_degree=3.0, seed=seed), seed=1)
    gs = GraphSet([
        random_weights(erdos_renyi(7, avg_degree=3.0, seed=seed + 1),
                       seed=2),
        random_weights(erdos_renyi(11, avg_degree=3.0, seed=seed + 2),
                       seed=3),
    ])
    return g, gs


def _one_device_mesh(axis: str = "data"):
    return jax.sharding.Mesh(np.asarray(jax.devices()[:1]), (axis,))


def entry_points():
    """``(label, thunk)`` for every public distributed/batched wrapper —
    the thunk raises :class:`repro.core.engine.LintCapture`."""
    from repro.graphs.algorithms import (bfs, boruvka, coloring, pagerank,
                                         sssp, stconn)
    g, gs = _tiny_graphs()
    mesh = _one_device_mesh()
    L = 2
    srcL = jnp.zeros((L,), jnp.int32)
    srcG = jnp.zeros((len(gs.graphs),), jnp.int32)
    srcLG = jnp.zeros((L, len(gs.graphs)), jnp.int32)
    tG = jnp.ones((len(gs.graphs),), jnp.int32)
    return [
        ("bfs/distributed",
         lambda: bfs.distributed_bfs(mesh, g, 0)),
        ("bfs/lanes",
         lambda: bfs.distributed_multi_source_bfs(mesh, g, srcL)),
        ("bfs/product",
         lambda: bfs.distributed_product_bfs(mesh, gs, srcLG)),
        ("bfs/graphs",
         lambda: bfs.batched_over_graphs_bfs(gs, srcG, mesh=mesh)),
        ("sssp/distributed",
         lambda: sssp.distributed_sssp(mesh, g, 0)),
        ("sssp/lanes",
         lambda: sssp.distributed_multi_source_sssp(mesh, g, srcL)),
        ("sssp/graphs",
         lambda: sssp.batched_over_graphs_sssp(gs, srcG, mesh=mesh)),
        ("pagerank/distributed",
         lambda: pagerank.distributed_pagerank(mesh, g)),
        ("pagerank/lanes",
         lambda: pagerank.distributed_multi_source_pagerank(mesh, g,
                                                            srcL)),
        ("pagerank/graphs",
         lambda: pagerank.batched_over_graphs_pagerank(gs, srcG,
                                                       mesh=mesh)),
        ("coloring/distributed",
         lambda: coloring.distributed_coloring(mesh, g)),
        ("coloring/graphs",
         lambda: coloring.batched_over_graphs_coloring(gs, mesh=mesh)),
        ("stconn/distributed",
         lambda: stconn.distributed_stconn(mesh, g, 0, 1)),
        ("stconn/lanes",
         lambda: stconn.distributed_multi_source_stconn(mesh, g, srcG,
                                                        tG)),
        ("stconn/graphs",
         lambda: stconn.batched_over_graphs_stconn(gs, srcG, tG,
                                                   mesh=mesh)),
        ("boruvka/distributed",
         lambda: boruvka.distributed_boruvka(mesh, g)),
        ("boruvka/forest",
         lambda: boruvka.distributed_boruvka_forest(mesh, g)),
        ("boruvka/graphs",
         lambda: boruvka.batched_over_graphs_boruvka(gs, mesh=mesh)),
    ]


def capture_algorithms(points=None):
    """Run every entry point under the capture seam; returns
    ``[(label, LintCapture)]``."""
    out = []
    points = entry_points() if points is None else points
    E._LINT_CAPTURE = True
    try:
        for label, thunk in points:
            try:
                thunk()
            except E.LintCapture as cap:
                out.append((label, cap))
                continue
            raise RuntimeError(
                f"{label}: run_distributed was never reached — entry "
                f"point changed shape; update the aamlint catalog")
    finally:
        E._LINT_CAPTURE = False
    return out


def _lint_edges(g):
    n = g.src.shape[0]
    return E.EdgeSlice(
        src=jnp.asarray(g.src, jnp.int32),
        dst=jnp.asarray(g.dst, jnp.int32),
        weight=jnp.asarray(g.weights, jnp.float32),
        valid=jnp.ones((n,), bool),
        eid=jnp.arange(n, dtype=jnp.int32),
        my_src=jnp.asarray(g.src, jnp.int32))


def check_algorithm(label: str, cap) -> RaceReport:
    """Trace one captured algorithm's round step and race-check it."""
    g, batch = cap.g, cap.batch
    layout = SimpleNamespace(num_shards=1, block=g.num_vertices,
                             emax=g.src.shape[0],
                             num_vertices=g.num_vertices,
                             num_edges=g.src.shape[0],
                             vpad=g.num_vertices)
    state0, scalars0 = cap.alg.init(g, layout)
    edges = _lint_edges(g)
    # block = vertex range; wave() clamps targets to it and fuses the
    # major ids itself, so fused [block * width] state needs no special
    # casing here
    rt = LintRuntime(block=layout.block, batch=batch)

    def round_step(state, scalars):
        return cap.alg.round_fn(rt, edges, state, scalars, 0)

    rep = RaceReport(name=f"{label} ({cap.alg.name})")
    closed = jax.make_jaxpr(round_step)(state0, scalars0)
    n_state = len(jax.tree.leaves(state0))
    chain = set(closed.jaxpr.invars[:n_state])
    _walk(closed.jaxpr, chain, rep, rep.name)
    return rep


def check_all(extra_traceables=()) -> list[RaceReport]:
    """Race-check every distributed entry point + the ProductWave chunk
    bodies (+ any ``(name, fn, example_state)`` extras, e.g. planted
    fixtures)."""
    reports = [check_algorithm(label, cap)
               for label, cap in capture_algorithms()]
    from repro.serve.product_wave import lint_traceables
    for name, fn, example in lint_traceables():
        reports.append(check_traceable(name, fn, example))
    for name, fn, example in extra_traceables:
        reports.append(check_traceable(name, fn, example))
    return reports
