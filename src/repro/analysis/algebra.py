"""Commit-op algebra registry + at-least-once reachability pass.

An HTM transaction serializes *some* order; the AAM pipeline reorders
freely (coalescing sorts by key, the exchange interleaves shards, the
adaptive ladder re-tiles), so every commit op must declare — and
provably have — the algebraic properties that make all orders
equivalent:

* **commutative + associative**: any batch order commits to the same
  state (``min``/``max``/``or``/``add``; float ``add`` only up to
  rounding — flagged ``float_reassoc``).
* **idempotent**: delivering a message twice is harmless — required
  wherever a batch can replay (the at-least-once paths below).
  ``add`` is NOT idempotent: replayed mass double-counts.
* **order_dependent** (``first``): not commutative; legal only at
  unfused single-graph sites, and only with a deterministic tiebreak
  so runs are reproducible across backends.

:func:`check_algebra` verifies every declaration *exhaustively* at
small widths (all argument triples over a small value set), in both
directions — a property declared False must exhibit a counterexample.

:func:`check_replay_paths` then walks the registered at-least-once
replay sites (:data:`repro.serve.durable.REPLAY_GUARDS`) and checks
each guard witness is still present in the shipped source: a WAL
replay, degraded-mesh re-home, or restore path that lost its
exactly-once guard while a non-idempotent op (pagerank/ppr ``add``) is
in the fleet is reported as a finding.
"""
from __future__ import annotations

import dataclasses
import importlib
import inspect
import itertools
import re


@dataclasses.dataclass(frozen=True)
class OpAlgebra:
    """Declared algebra of one commit op (binary combine ``f``)."""
    op: str
    commutative: bool
    associative: bool
    idempotent: bool
    order_dependent: bool = False
    float_reassoc: bool = False          # assoc exact only in exact arith
    deterministic_tiebreak: str | None = None


ALGEBRA = {
    "min": OpAlgebra("min", commutative=True, associative=True,
                     idempotent=True),
    "max": OpAlgebra("max", commutative=True, associative=True,
                     idempotent=True),
    "or": OpAlgebra("or", commutative=True, associative=True,
                    idempotent=True),
    "add": OpAlgebra("add", commutative=True, associative=True,
                     idempotent=False, float_reassoc=True),
    # first-writer-wins: f(a, b) = a — associative and idempotent but
    # NOT commutative; commit order picks the winner, so backends pin
    # the tiebreak to the minimum message index (see
    # repro.core.commit._first_winner and the sanitizer's rank-aware
    # replay).
    "first": OpAlgebra("first", commutative=False, associative=True,
                       idempotent=True, order_dependent=True,
                       deterministic_tiebreak="min message index"),
}

# binary combine semantics, on plain python ints/bools (exact arith so
# the exhaustive check is decisive; float reassociation is a separate,
# declared hazard)
_COMBINE = {
    "min": min,
    "max": max,
    "add": lambda a, b: a + b,
    "or": lambda a, b: a | b,
    "first": lambda a, b: a,
}

_VALUES = {
    "or": (0, 1),
    # small ints exercise sign, zero, and ties
    "min": tuple(range(-3, 4)),
    "max": tuple(range(-3, 4)),
    "add": tuple(range(-3, 4)),
    "first": tuple(range(-3, 4)),
}


def _holds_comm(f, vals):
    return all(f(a, b) == f(b, a) for a, b in itertools.product(vals, vals))


def _holds_assoc(f, vals):
    return all(f(f(a, b), c) == f(a, f(b, c))
               for a, b, c in itertools.product(vals, vals, vals))


def _holds_idem(f, vals):
    return all(f(a, a) == a for a in vals)


def check_algebra() -> list[str]:
    """Exhaustively verify every registry declaration; returns findings
    (empty = every declaration matches the op's actual behaviour)."""
    findings = []
    from repro.core.commit import OPS
    for op in OPS:
        if op not in ALGEBRA:
            findings.append(
                f"algebra: commit op {op!r} has no OpAlgebra declaration "
                f"— the analyzer cannot reason about its reorder safety")
    for op, decl in ALGEBRA.items():
        f, vals = _COMBINE[op], _VALUES[op]
        for prop, holds in (("commutative", _holds_comm(f, vals)),
                            ("associative", _holds_assoc(f, vals)),
                            ("idempotent", _holds_idem(f, vals))):
            declared = getattr(decl, prop)
            if declared and not holds:
                findings.append(
                    f"algebra: op {op!r} declared {prop} but a "
                    f"counterexample exists at width <= 3")
            if not declared and holds:
                findings.append(
                    f"algebra: op {op!r} declared NOT {prop} but no "
                    f"counterexample exists over {vals} — declaration "
                    f"is stale")
        if decl.order_dependent and decl.deterministic_tiebreak is None:
            findings.append(
                f"algebra: order-dependent op {op!r} has no declared "
                f"deterministic tiebreak — results would vary by backend")
        if decl.order_dependent == decl.commutative:
            findings.append(
                f"algebra: op {op!r} order_dependent must be the "
                f"negation of commutative")
    return findings


_OP_RE = re.compile(r'''(?:op\s*=\s*|make_commit_step\(\s*\w+\s*,\s*)
                        ["']([a-z]+)["']''', re.VERBOSE)

ALGO_MODULES = (
    "repro.graphs.algorithms.bfs",
    "repro.graphs.algorithms.sssp",
    "repro.graphs.algorithms.pagerank",
    "repro.graphs.algorithms.coloring",
    "repro.graphs.algorithms.stconn",
    "repro.graphs.algorithms.boruvka",
)


def ops_in_module(modname: str) -> set[str]:
    """Commit ops a module's waves use (source census: ``op="..."``
    keywords plus ``make_commit_step(spec, "op", ...)`` sites)."""
    mod = importlib.import_module(modname)
    from repro.core.commit import OPS
    return {m.group(1) for m in _OP_RE.finditer(inspect.getsource(mod))
            if m.group(1) in OPS}


def check_fused_order_dependence() -> list[str]:
    """Order-dependent ops (``first``) may not appear in distributed /
    batch-fused rounds: the exchange interleaves shards arbitrarily, so
    even a deterministic tiebreak yields mesh-shape-dependent answers.
    Single-shard sites are fine (one batch, one documented order)."""
    findings = []
    for modname in ALGO_MODULES:
        mod = importlib.import_module(modname)
        src = inspect.getsource(mod)
        for fn_name, fn in inspect.getmembers(mod, inspect.isfunction):
            if fn.__module__ != modname:
                continue
            if not (fn_name.startswith("distributed")
                    or "batched_over" in fn_name):
                continue
            try:
                fsrc = inspect.getsource(fn)
            except OSError:
                fsrc = src
            for m in _OP_RE.finditer(fsrc):
                op = m.group(1)
                decl = ALGEBRA.get(op)
                if decl is not None and decl.order_dependent:
                    findings.append(
                        f"algebra: {modname}.{fn_name} commits "
                        f"order-dependent op {op!r} on a distributed/"
                        f"fused wave — shard interleave makes the "
                        f"result mesh-shape-dependent")
    return findings


def check_replay_paths() -> list[str]:
    """Verify every registered at-least-once replay site still carries
    its idempotence guard, and report non-idempotent ops in the fleet.

    The serving stack has three paths that can re-deliver work after a
    crash/shrink; each is exactly-once only because of a specific guard
    (result-keyed WAL replay, chunk-snapshot rollback, keyed publish).
    pagerank/ppr commit ``add`` — NOT idempotent — so losing any guard
    turns a replay into double-counted mass.  The guards are declared in
    :data:`repro.serve.durable.REPLAY_GUARDS` with a source *witness*
    string; a missing witness means the guard was refactored away (or
    moved — re-point the declaration)."""
    findings = []
    from repro.serve.durable import REPLAY_GUARDS
    non_idem = sorted(
        op for modname in ALGO_MODULES for op in ops_in_module(modname)
        if not ALGEBRA[op].idempotent)
    for site in REPLAY_GUARDS:
        try:
            mod = importlib.import_module(site.module)
            obj = mod
            for part in site.qualname.split("."):
                obj = getattr(obj, part)
            src = inspect.getsource(obj)
        except (ImportError, AttributeError, OSError) as e:
            findings.append(
                f"replay: at-least-once site {site.name} "
                f"({site.module}.{site.qualname}) cannot be resolved "
                f"({e}) — guard unverifiable")
            continue
        if site.witness not in src:
            findings.append(
                f"replay: at-least-once site {site.name} lost its "
                f"idempotence guard (witness {site.witness!r} no longer "
                f"in {site.module}.{site.qualname}); non-idempotent "
                f"commit ops in the fleet: {non_idem or 'none'} — "
                f"replayed batches would double-apply")
    return findings
