"""aamlint CLI — ``python -m repro.analysis.lint``.

Runs every static pass over the shipped pipeline and exits nonzero on
findings:

* **algebra** — commit-op declarations verified exhaustively; no
  order-dependent op on a distributed/fused wave; every at-least-once
  replay site still carries its idempotence guard
  (:mod:`repro.analysis.algebra`);
* **keyspace** — composite-key disjointness + int32 bound for
  representative ``QueryLanes``/``GraphBatch``/``ProductAxis`` shapes
  (:mod:`repro.analysis.keyspace`);
* **waverace** — jaxpr race detection over all six algorithms on each
  axis kind plus the ``ProductWave`` chunk bodies
  (:mod:`repro.analysis.waverace`).

``--module pkg.mod`` additionally lints a module's declared surfaces —
``LINT_AXES`` (axis objects or ``(name, axis)`` pairs for the keyspace
pass), ``LINT_TRACEABLES`` (``(name, fn_of_state, example_state)`` for
the race pass), ``LINT_ALGORITHMS`` (``(name, AlgorithmSpec, graph)``
or ``AlgorithmSpec`` traced on a default tiny graph).  The seeded
violation fixtures under ``tests/fixtures/`` use exactly this hook.

``--bench-schema`` also validates the committed ``BENCH_*.json``
trajectory files (the ``make lint`` target runs both).
"""
from __future__ import annotations

import argparse
import dataclasses
import importlib
import json
import os
import pathlib
import sys
from types import SimpleNamespace


def _print(msg: str) -> None:
    print(msg, flush=True)


def run_algebra() -> list[str]:
    from repro.analysis import algebra
    return (algebra.check_algebra()
            + algebra.check_fused_order_dependence()
            + algebra.check_replay_paths())


# representative shapes: the tiny trio gets the exhaustive bijection
# proof, the serving-scale trio exercises the stride probe + int32
# headroom arithmetic near real deployments (L lanes x multi-M unions)
def _default_axes():
    from repro.core.coalescing import GraphBatch, ProductAxis, QueryLanes
    return [
        ("QueryLanes(8, 97)", QueryLanes(8, 97)),
        ("GraphBatch(7, 13, 29)", GraphBatch((7, 13, 29))),
        ("ProductAxis(4, (7, 13, 29))", ProductAxis(4, (7, 13, 29))),
        ("QueryLanes(64, 2^20)", QueryLanes(64, 1 << 20)),
        ("GraphBatch(3 x ~2^20)",
         GraphBatch((1 << 18, 1 << 19, 1 << 20))),
        ("ProductAxis(8, 3 x ~2^20)",
         ProductAxis(8, (1 << 18, 1 << 19, 1 << 20))),
    ]


def run_keyspace(axes=None) -> list[str]:
    from repro.analysis import keyspace
    findings = []
    for rep in keyspace.analyze_axes(axes if axes is not None
                                     else _default_axes()):
        proof = {True: "disjoint (exhaustive)", False: "NOT disjoint",
                 None: "bound-checked"}[rep.disjoint]
        _print(f"  keyspace {rep.name}: {rep.flat_size} keys, "
               f"headroom {rep.headroom}, {proof}")
        findings.extend(rep.findings)
    return findings


def run_waverace(extra_traceables=()) -> list[str]:
    from repro.analysis import waverace
    findings = []
    for rep in waverace.check_all(extra_traceables=extra_traceables):
        status = "ok" if rep.ok else "RACE"
        _print(f"  waverace {rep.name}: {status} "
               f"(commits={rep.commits}, state reads={rep.reads})")
        findings.extend(f"{f.where}: {f.detail}" for f in rep.findings)
    return findings


def run_module(modname: str) -> list[str]:
    """Lint one module's declared LINT_* surfaces."""
    from repro.analysis import keyspace, waverace
    mod = importlib.import_module(modname)
    findings = []
    for rep in keyspace.analyze_axes(getattr(mod, "LINT_AXES", ())):
        findings.extend(rep.findings)
    for name, fn, example in getattr(mod, "LINT_TRACEABLES", ()):
        rep = waverace.check_traceable(name, fn, example)
        findings.extend(f"{f.where}: {f.detail}" for f in rep.findings)
    algos = getattr(mod, "LINT_ALGORITHMS", ())
    if algos:
        g, _ = waverace._tiny_graphs()
        for item in algos:
            if isinstance(item, tuple):
                name, alg, graph = item
            else:
                name, alg, graph = item.name, item, g
            cap = SimpleNamespace(alg=alg, g=graph, batch=None)
            rep = waverace.check_algorithm(name, cap)
            findings.extend(f"{f.where}: {f.detail}"
                            for f in rep.findings)
    if not (hasattr(mod, "LINT_AXES") or hasattr(mod, "LINT_TRACEABLES")
            or hasattr(mod, "LINT_ALGORITHMS")):
        findings.append(
            f"module {modname} declares no LINT_AXES / LINT_TRACEABLES "
            f"/ LINT_ALGORITHMS — nothing to lint")
    return findings


def run_trace_off_clean() -> list[str]:
    """Prove the wavescope zero-impact-when-off guarantee: with tracing
    off (no ``REPRO_TRACE``, ``CommitSpec(trace=False)``) the jaxpr of
    every engine round loop and every ProductWave chunk body contains NO
    host-callback primitive; one positive control
    (``CommitSpec(trace=True)``) must show the callback, so the scan is
    never vacuous.  Also schema-smokes the trace and metrics JSON
    validators over freshly built documents."""
    import jax
    import jax.numpy as jnp
    from repro.analysis import waverace
    from repro.core import commit as Cm
    from repro.core import engine as E
    findings: list[str] = []
    saved = os.environ.pop("REPRO_TRACE", None)
    try:
        with waverace._no_env_sanitize():
            spec = Cm.CommitSpec()          # coarse: no calibration
            mesh = waverace._one_device_mesh()

            def runner_jaxpr(cap, sp):
                r = E._Runner(cap.alg, mesh, cap.g, axis="data",
                              capacity=64, m=8, spec=sp, batch=cap.batch,
                              max_subrounds=8)
                return str(jax.make_jaxpr(r._jfn)(
                    r.state0, r.scalars0, r.zero_carry(),
                    jnp.asarray(1, jnp.int32), *r.arrays))

            # one runner per algorithm — the tap placement is per-engine,
            # not per-wrapper, so the distributed/lanes/graphs variants of
            # one algorithm share a round loop
            seen: dict[str, tuple] = {}
            for label, cap in waverace.capture_algorithms():
                seen.setdefault(cap.alg.name, (label, cap))
            for name, (label, cap) in sorted(seen.items()):
                dirty = "callback" in runner_jaxpr(cap, spec)
                _print(f"  trace-off engine {label}: "
                       f"{'CALLBACK IN JAXPR' if dirty else 'clean'}")
                if dirty:
                    findings.append(
                        f"trace-off: {label} round loop contains a host "
                        f"callback with tracing OFF")
            from repro.serve.product_wave import lint_traceables
            for name, fn, example in lint_traceables():
                dirty = "callback" in str(jax.make_jaxpr(fn)(example))
                _print(f"  trace-off product {name}: "
                       f"{'CALLBACK IN JAXPR' if dirty else 'clean'}")
                if dirty:
                    findings.append(
                        f"trace-off: product chunk {name} contains a "
                        f"host callback with tracing OFF")
            # positive control: trace=True MUST plant the tap, or the
            # "clean" verdicts above prove nothing
            label0, cap0 = sorted(seen.items())[0][1]
            on = dataclasses.replace(spec, trace=True)
            if "callback" not in runner_jaxpr(cap0, on):
                findings.append(
                    "trace-off: positive control failed — "
                    "CommitSpec(trace=True) planted no callback; the "
                    "jaxpr scan is vacuous")
            else:
                _print(f"  trace-off control {label0}: tap detected with "
                       f"trace=True")
    finally:
        if saved is not None:
            os.environ["REPRO_TRACE"] = saved
    # schema smoke: the validators must accept what we actually emit
    from repro.obs import metrics as OM
    from repro.obs import trace as OT
    tr = OT.Tracer(enabled=True)
    with tr.span("smoke", args={"k": 1}):
        tr.instant("mark")
    findings += [f"trace-off: {f}"
                 for f in OT.validate_trace(tr.to_chrome())]
    reg = OM.Registry()
    reg.counter("aam_smoke").inc(3)
    reg.gauge("aam_g").set(0.5)
    reg.histogram("aam_h").observe(0.01)
    findings += [f"trace-off: {f}"
                 for f in OM.validate_metrics_json(reg.snapshot())]
    assert reg.prometheus_text().endswith("\n")
    _print("  trace-off schemas: trace + metrics validators clean")
    return findings


BENCH_TOP_KEYS = {"schema", "sizes", "platform", "rows", "summary"}
BENCH_ROW_KEYS = {"suite", "backend", "name", "us_per_call", "derived"}
BENCH_SCHEMA = "aam-bench/v1"


def run_bench_schema(root: str = ".") -> list[str]:
    findings = []
    paths = sorted(pathlib.Path(root).glob("BENCH_*.json"))
    if not paths:
        _print("  bench-schema: no BENCH_*.json files found")
    for p in paths:
        try:
            d = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError) as e:
            findings.append(f"bench: {p.name} unreadable: {e}")
            continue
        missing = BENCH_TOP_KEYS - set(d)
        if missing:
            findings.append(
                f"bench: {p.name} missing top-level keys {sorted(missing)}")
        if d.get("schema") != BENCH_SCHEMA:
            findings.append(
                f"bench: {p.name} schema {d.get('schema')!r} != "
                f"{BENCH_SCHEMA!r}")
        rows = d.get("rows", [])
        if not isinstance(rows, list) or not rows:
            findings.append(f"bench: {p.name} has no rows")
            continue
        for i, row in enumerate(rows):
            rmissing = BENCH_ROW_KEYS - set(row)
            if rmissing:
                findings.append(
                    f"bench: {p.name} row {i} missing {sorted(rmissing)}")
                break
            if not isinstance(row["us_per_call"], (int, float)):
                findings.append(
                    f"bench: {p.name} row {i} us_per_call not numeric")
                break
        _print(f"  bench-schema {p.name}: {len(rows)} rows")
    return findings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="wave-safety static analysis for the AAM pipeline")
    ap.add_argument("--module", action="append", default=[],
                    help="additionally lint a module's LINT_* surfaces "
                         "(repeatable)")
    ap.add_argument("--bench-schema", action="store_true",
                    help="also validate BENCH_*.json trajectory files")
    ap.add_argument("--skip-waverace", action="store_true",
                    help="skip the (slow) jaxpr race pass — for quick "
                         "keyspace/algebra iterations")
    ap.add_argument("--trace-off-clean", action="store_true",
                    help="prove tracing-off jaxprs contain no host "
                         "callbacks + schema-smoke trace/metrics JSON")
    args = ap.parse_args(argv)

    findings: list[str] = []
    _print("aamlint: algebra")
    findings += run_algebra()
    _print("aamlint: keyspace")
    findings += run_keyspace()
    if not args.skip_waverace:
        _print("aamlint: waverace")
        findings += run_waverace()
    for modname in args.module:
        _print(f"aamlint: module {modname}")
        findings += run_module(modname)
    if args.bench_schema:
        _print("aamlint: bench-schema")
        findings += run_bench_schema()
    if args.trace_off_clean:
        _print("aamlint: trace-off-clean")
        findings += run_trace_off_clean()

    if findings:
        _print(f"\naamlint: {len(findings)} finding(s)")
        for f in findings:
            _print(f"  FINDING: {f}")
        return 1
    _print("\naamlint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
