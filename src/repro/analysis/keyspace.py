"""Key-space analysis — composite-key disjointness + dtype bounds.

HTM detected conflicts at cache-line granularity; our software commit
detects them by *flat key equality*.  Two different work items (lane,
graph, vertex cells) must therefore never share a flat key — otherwise
their updates silently merge — and the largest flat key must fit the
int32 key pipeline (``fuse_keys`` arithmetic, message targets, and
``commit()``'s drop sentinel at ``key == flat_size``, which needs one
slot of headroom).  ``L × Vtot`` product axes are where the overflow
actually bites: a modest lane budget times a big tenant union wraps
int32 long before either axis would alone, and wrapped keys alias
*other tenants' vertices* — a cross-tenant data corruption, not a
crash.

:func:`analyze_axis` proves both properties for a
``QueryLanes``/``GraphBatch``/``ProductAxis`` (or any duck-typed axis
exposing the same fields): exhaustively for small axes (every valid
coordinate maps to a unique key in ``[0, flat_size)``), by
stride/corner probing for large ones.  All bound arithmetic runs in
python ints — the hazard under analysis is exactly that the jnp int32
pipeline cannot represent these values.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.coalescing import MAX_FLAT_KEYS

# axes up to this many cells get the exhaustive bijection proof
EXHAUSTIVE_LIMIT = 1 << 16


@dataclasses.dataclass
class KeyspaceReport:
    name: str
    kind: str                    # lanes | graphs | product
    flat_size: int               # python-int cell count (never wraps)
    max_key: int                 # flat_size - 1
    headroom: int                # MAX_FLAT_KEYS - max_key
    disjoint: bool | None        # True = proven; None = bound-only
    findings: list = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.findings


def _axis_kind(axis) -> str:
    has_lanes = hasattr(axis, "lanes")
    has_sizes = hasattr(axis, "sizes")
    if has_lanes and has_sizes:
        return "product"
    if has_sizes:
        return "graphs"
    return "lanes"


def _flat_size(axis, kind: str) -> int:
    # python-int arithmetic from the declared fields — axis.flat_size
    # itself is trustworthy (same formula) but recomputing here keeps
    # the analyzer honest against a buggy property
    if kind == "lanes":
        return int(axis.lanes) * int(axis.num_vertices)
    if kind == "graphs":
        return sum(int(s) for s in axis.sizes)
    return int(axis.lanes) * sum(int(s) for s in axis.sizes)


def _coords(axis, kind: str):
    """(major, minor) int64 arrays covering every valid cell-coordinate
    pair of a small axis, plus the flatten callable."""
    if kind == "lanes":
        L, V = int(axis.lanes), int(axis.num_vertices)
        l = np.repeat(np.arange(L), V)
        v = np.tile(np.arange(V), L)
        return l, v, axis.flatten
    if kind == "graphs":
        g = np.concatenate([np.full(int(s), i)
                            for i, s in enumerate(axis.sizes)])
        v = np.concatenate([np.arange(int(s)) for s in axis.sizes])
        return g, v, axis.flatten
    # product: enumerate (lane, graph, local v) through flatten3
    g1 = np.concatenate([np.full(int(s), i)
                         for i, s in enumerate(axis.sizes)])
    v1 = np.concatenate([np.arange(int(s)) for s in axis.sizes])
    L = int(axis.lanes)
    lane = np.repeat(np.arange(L), g1.size)
    g = np.tile(g1, L)
    v = np.tile(v1, L)
    return lane, (g, v), (lambda a, b: axis.flatten3(a, b[0], b[1]))


def _probe_strides(axis, kind: str, flat_size: int) -> list:
    """Large-axis spot check: unit stride on the minor coordinate,
    declared stride on the major, and the max coordinate lands on
    ``flat_size - 1``.  Catches a mis-nested flatten without
    enumerating 2^31 cells."""
    findings = []
    f = {"lanes": lambda a, b: int(axis.flatten(a, b)),
         "graphs": lambda a, b: int(axis.flatten(a, b)),
         "product": lambda a, b: int(axis.flatten(a, b))}[kind]
    if kind == "lanes":
        stride, last_major = int(axis.num_vertices), int(axis.lanes) - 1
        last_minor = int(axis.num_vertices) - 1
    elif kind == "graphs":
        stride = int(axis.sizes[0])          # offset of graph 1
        last_major = len(axis.sizes) - 1
        last_minor = int(axis.sizes[-1]) - 1
        f = lambda a, b: int(axis.flatten(a, b))  # noqa: E731
    else:
        stride = sum(int(s) for s in axis.sizes)
        last_major = int(axis.lanes) - 1
        last_minor = stride - 1              # minor = flat union vertex
    checks = [
        ("flatten(0, 0) == 0", f(0, 0), 0),
        ("unit minor stride", f(0, 1) - f(0, 0), 1),
        ("major stride", f(min(1, last_major), 0) - f(0, 0),
         stride if last_major >= 1 else 0),
        ("max coordinate -> flat_size - 1", f(last_major, last_minor),
         flat_size - 1),
    ]
    for what, got, want in checks:
        if got != want:
            findings.append(
                f"keyspace: {kind} axis stride probe failed — {what}: "
                f"got {got}, expected {want} (composite keys are not "
                f"the documented nesting; cells may alias)")
    return findings


def analyze_axis(axis, name: str | None = None) -> KeyspaceReport:
    """Prove disjointness + int32 bound for one batch axis."""
    kind = _axis_kind(axis)
    flat_size = _flat_size(axis, kind)
    rep = KeyspaceReport(name=name or f"{type(axis).__name__}", kind=kind,
                         flat_size=flat_size, max_key=flat_size - 1,
                         headroom=MAX_FLAT_KEYS - (flat_size - 1),
                         disjoint=None)
    if flat_size > MAX_FLAT_KEYS:
        rep.findings.append(
            f"keyspace: {rep.name} needs {flat_size} flat keys — "
            f"exceeds the int32 key space (max {MAX_FLAT_KEYS} + drop "
            f"sentinel).  fuse_keys/flatten3 arithmetic wraps silently: "
            f"high cells alias OTHER tenants' vertices (cross-tenant "
            f"corruption).  Shrink the wave or upcast to int64 "
            f"end-to-end.")
        # don't evaluate flatten: the int32 pipeline under analysis
        # cannot represent these keys
        return rep
    if flat_size <= EXHAUSTIVE_LIMIT:
        major, minor, flatten = _coords(axis, kind)
        keys = np.asarray(flatten(major, minor), np.int64)
        in_range = (keys >= 0) & (keys < flat_size)
        if not bool(in_range.all()):
            rep.findings.append(
                f"keyspace: {rep.name} maps coordinates outside "
                f"[0, {flat_size}) — min {int(keys.min())}, "
                f"max {int(keys.max())}")
        if np.unique(keys).size != keys.size:
            dup = int(keys.size - np.unique(keys).size)
            rep.findings.append(
                f"keyspace: {rep.name} composite keys are NOT disjoint "
                f"— {dup} colliding cell pairs; conflicting work items "
                f"would silently merge in one commit")
        rep.disjoint = not rep.findings
    else:
        rep.findings.extend(_probe_strides(axis, kind, flat_size))
        rep.disjoint = None if not rep.findings else False
    return rep


def analyze_axes(axes) -> list[KeyspaceReport]:
    """``axes``: iterable of axis objects or (name, axis) pairs."""
    out = []
    for item in axes:
        if isinstance(item, tuple) and len(item) == 2:
            name, axis = item
        else:
            name, axis = None, item
        out.append(analyze_axis(axis, name=name))
    return out
