"""Runtime conflict sanitizer — permuted-message-order commit replay.

HTM hardware guarantees that a batch of atomic active messages commits
as if in *some* serial order; our software commit claims the stronger
property that the result does not depend on the order at all (the op
algebra makes every serialization equivalent).  The sanitizer checks
that claim where it actually matters — at every ``commit()`` call, on
the live workload — by replaying the same batch through the same
backend with the messages in a fixed pseudo-random permutation and
asserting the state arrays match.

* ``min``/``max``/``or`` and integer ``add``: bit-identical.
* float ``add``: reassociation moves float rounding, so the replay is
  compared to tolerance (:data:`ADD_RTOL`/:data:`ADD_ATOL`) — the same
  caveat the pagerank/ppr parity tests document.
* ``first``: order-dependent by construction; the shadow instead
  re-derives the winner *rank-aware* (tiebreak = original message
  index, the documented deterministic rule) from the permuted batch and
  checks the shipped positional tiebreak picked the same winner.

Enable per-site with ``CommitSpec(sanitize=True)`` or globally with
``REPRO_SANITIZE=1``.  A mismatch is recorded in :func:`reports` and
raised as :class:`SanitizeError` from a :func:`jax.debug.callback`
(surfacing as ``XlaRuntimeError`` under jit).
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

# float add replay tolerance: one segmented reduction vs another with a
# different association order; 2e-4 relative covers f32 across the
# calibration workloads with ~100x margin.
ADD_RTOL = 2e-4
ADD_ATOL = 1e-6

_PERM_SEED = 0xA51


class SanitizeError(AssertionError):
    """A commit produced an order-dependent result."""


@dataclasses.dataclass(frozen=True)
class SanitizeReport:
    op: str
    backend: str
    capacity: int
    max_abs_err: float
    note: str


_REPORTS: list[SanitizeReport] = []


def reports() -> tuple[SanitizeReport, ...]:
    """Mismatches recorded so far (host-side, survives the raise)."""
    return tuple(_REPORTS)


def clear_reports() -> None:
    _REPORTS.clear()


def _perm(n: int) -> np.ndarray:
    """Fixed permutation of ``range(n)`` — deterministic per capacity so
    sanitized runs stay reproducible (and jit caches stay warm)."""
    return np.asarray(np.random.default_rng(_PERM_SEED).permutation(n),
                      np.int32)


def _permute_messages(msgs, perm):
    take = lambda a: jnp.asarray(a)[perm]
    return dataclasses.replace(
        msgs, target=take(msgs.target),
        payload=jax.tree.map(take, msgs.payload),
        valid=take(msgs.valid))


def _record(ok, err, *, op: str, backend: str, capacity: int, note: str):
    ok = bool(ok)
    err = float(err)
    if not ok:
        rep = SanitizeReport(op=op, backend=backend, capacity=capacity,
                             max_abs_err=err, note=note)
        _REPORTS.append(rep)
        raise SanitizeError(
            f"commit(op={op!r}, backend={backend!r}, n={capacity}) is "
            f"order-dependent: permuted replay diverges by {err:.3e} "
            f"({note}).  The wave feeding this commit violates the "
            f"reorder-invariance the AAM pipeline assumes — see "
            f"`python -m repro.analysis.lint`.")


def _compare(result, shadow, op: str, *, exact: bool):
    a = jnp.asarray(result)
    b = jnp.asarray(shadow)
    if exact:
        eq = a == b
        # subtract after the float cast: bool state (`or` waves) has no `-`
        err = jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)))
    else:
        d = jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))
        tol = ADD_ATOL + ADD_RTOL * jnp.abs(b).astype(jnp.float32)
        eq = d <= tol
        err = jnp.max(d)
    return jnp.all(eq), err


def _first_shadow(state, msgs, perm):
    """Rank-aware replay of a ``first`` commit from the permuted batch.

    ``_first_winner(..., rank=perm)`` makes the tiebreak key the
    *original* message index, so the winner is position-independent;
    the payload is then fetched from the permuted batch at the winner's
    permuted position — if the shipped positional tiebreak disagreed
    with the documented min-message-index rule, the states differ."""
    from repro.core import commit as C
    pm = _permute_messages(msgs, perm)
    n = msgs.capacity
    winner_rank, takes = C._first_winner(state, pm, rank=perm)
    pos = jnp.argsort(perm)[jnp.clip(winner_rank, 0, n - 1)]
    return jnp.where(takes, pm.payload[pos], state)


def shadow_check(state, msgs, op: str, spec, backend: str, result_state):
    """Replay ``commit(state, msgs, op)`` with permuted messages through
    the *same* backend and assert the state is unchanged.

    Called from :func:`repro.core.commit.commit` (never re-enters it —
    the replay dispatches directly, else ``REPRO_SANITIZE=1`` would
    shadow the shadow forever)."""
    from repro.core import commit as C
    n = msgs.capacity
    perm = jnp.asarray(_perm(n))
    if op == "first":
        shadow = _first_shadow(state, msgs, perm)
        exact = True
        note = "rank-aware first replay"
    else:
        pm = _permute_messages(msgs, perm)
        shadow = C._dispatch(state, pm, op, spec, backend).state
        exact = not (op == "add"
                     and jnp.issubdtype(jnp.asarray(state).dtype,
                                        jnp.floating))
        note = ("permuted replay" if exact
                else f"permuted replay, float add tol rtol={ADD_RTOL}")
    ok, err = _compare(result_state, shadow, op, exact=exact)
    jax.debug.callback(_record, ok, err, op=op, backend=backend,
                       capacity=n, note=note)
