"""aamlint — wave-safety static analysis + runtime conflict sanitizer.

The paper's HTM gives serializability of atomic active messages in
hardware; the software reproduction only inherits the guarantee when
every commit site obeys three preconditions that hardware enforced
implicitly:

* the commit op is reorder-safe (commutative/associative, idempotent
  where a message can be delivered more than once) — checked by
  :mod:`repro.analysis.algebra`;
* composite batch-axis keys are disjoint and fit the key dtype —
  checked by :mod:`repro.analysis.keyspace`;
* no round reads a state array it is also writing outside ``commit()``'s
  conflict resolution — checked by :mod:`repro.analysis.waverace`;

plus a dynamic check, :mod:`repro.analysis.sanitize`, that replays every
``commit()`` in a permuted message order and asserts the result is
unchanged (``REPRO_SANITIZE=1`` / ``CommitSpec(sanitize=True)``).

``python -m repro.analysis.lint`` runs all static passes and exits
nonzero on findings.
"""
from repro.analysis.sanitize import (SanitizeError, clear_reports,
                                     reports)

__all__ = ["SanitizeError", "clear_reports", "reports"]
