"""Metrics registry: counters, gauges, log-bucket histograms.

A :class:`Registry` is cheap enough to exist per service —
:class:`repro.serve.graph_service.ServiceStats` is a thin attribute
view over one, and :class:`repro.serve.continuous.ContinuousServer`
observes submit-to-answer latency into a histogram natively (before
this, only the bench harness could compute a p99).

Two export formats:

* :meth:`Registry.prometheus_text` — Prometheus text exposition
  (cumulative ``le`` buckets, ``_sum``/``_count``);
* :meth:`Registry.snapshot` — an ``aam-metrics/v1`` JSON document,
  schema-checked by :func:`validate_metrics_json` (wired into
  ``aamlint --trace-off-clean`` and tier-1).

Histograms use base-2 log buckets: ``quantile(q)`` returns the upper
bound of the bucket where the cumulative count crosses ``q`` — so a
bench-computed percentile always lands within one bucket of the
histogram's answer (the acceptance check for the latency histogram).
"""
from __future__ import annotations

import math
import threading

METRICS_SCHEMA = "aam-metrics/v1"

# 2^-20 s (~1 us) .. 2^6 s (64 s): covers a cache-hit submit through a
# cold-compile drain in 27 buckets
_DEFAULT_BOUNDS = tuple(2.0 ** e for e in range(-20, 7))


class Counter:
    """Monotone counter (``set`` exists only for the ServiceStats
    back-compat view, which assigns via augmented attribute ops)."""

    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n

    def set(self, v) -> None:
        self.value = v


class Gauge:
    def __init__(self, name: str, help: str = ""):
        self.name, self.help = name, help
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def inc(self, n=1) -> None:
        self.value += n


class Histogram:
    """Log-bucket histogram over fixed upper bounds (+Inf implicit)."""

    def __init__(self, name: str, help: str = "", bounds=_DEFAULT_BOUNDS):
        self.name, self.help = name, help
        self.bounds = tuple(bounds)
        self.counts = [0] * (len(self.bounds) + 1)   # last = +Inf
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        v = float(v)
        self.sum += v
        self.count += 1
        for i, b in enumerate(self.bounds):
            if v <= b:
                self.counts[i] += 1
                return
        self.counts[-1] += 1

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket where the cumulative count crosses
        ``q * count`` (inf if the overflow bucket holds it); nan when
        empty."""
        if self.count == 0:
            return math.nan
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= target:
                return self.bounds[i] if i < len(self.bounds) \
                    else math.inf
        return math.inf

    def bucket_of(self, v: float) -> int:
        """Index of the bucket ``v`` falls in — the within-one-bucket
        acceptance check compares ``bucket_of(bench_p99)`` against
        ``bucket_of(quantile(0.99))``."""
        for i, b in enumerate(self.bounds):
            if v <= b:
                return i
        return len(self.bounds)


class Registry:
    """Get-or-create metric namespace; all mutation under one lock-free
    discipline (CPython attribute ops are atomic enough for counters;
    creation is locked)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, cls, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  bounds=_DEFAULT_BOUNDS) -> Histogram:
        return self._get(name, Histogram, help=help, bounds=bounds)

    # -- export -----------------------------------------------------------

    def prometheus_text(self) -> str:
        """Prometheus text exposition format."""
        lines = []
        with self._lock:
            metrics = sorted(self._metrics.items())
        for name, m in metrics:
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            if isinstance(m, Counter):
                lines.append(f"# TYPE {name} counter")
                lines.append(f"{name} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {name} gauge")
                lines.append(f"{name} {m.value}")
            else:
                lines.append(f"# TYPE {name} histogram")
                cum = 0
                for b, c in zip(m.bounds, m.counts):
                    cum += c
                    lines.append(f'{name}_bucket{{le="{b:.9g}"}} {cum}')
                cum += m.counts[-1]
                lines.append(f'{name}_bucket{{le="+Inf"}} {cum}')
                lines.append(f"{name}_sum {m.sum}")
                lines.append(f"{name}_count {m.count}")
        return "\n".join(lines) + "\n"

    def snapshot(self) -> dict:
        """The ``aam-metrics/v1`` JSON document."""
        out = {"schema": METRICS_SCHEMA, "counters": {}, "gauges": {},
               "histograms": {}}
        with self._lock:
            metrics = list(self._metrics.items())
        for name, m in metrics:
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = {
                    "count": m.count, "sum": m.sum,
                    "buckets": [[b, c] for b, c in
                                zip(m.bounds + (math.inf,), m.counts)]}
        return out


def validate_metrics_json(doc) -> list[str]:
    """Schema smoke check for :meth:`Registry.snapshot` documents."""
    findings = []
    if not isinstance(doc, dict):
        return ["metrics: document is not an object"]
    if doc.get("schema") != METRICS_SCHEMA:
        findings.append(f"metrics: schema {doc.get('schema')!r} != "
                        f"{METRICS_SCHEMA!r}")
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(doc.get(section), dict):
            findings.append(f"metrics: missing section {section!r}")
    for name, v in (doc.get("counters") or {}).items():
        if not isinstance(v, (int, float)):
            findings.append(f"metrics: counter {name} not numeric")
    for name, h in (doc.get("histograms") or {}).items():
        if not isinstance(h, dict) or not {"count", "sum",
                                           "buckets"} <= set(h):
            findings.append(f"metrics: histogram {name} malformed")
            continue
        counts = [c for _, c in h["buckets"]]
        if sum(counts) != h["count"]:
            findings.append(f"metrics: histogram {name} bucket counts "
                            f"{sum(counts)} != count {h['count']}")
    return findings
