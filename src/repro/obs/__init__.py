"""wavescope — tracing, metrics, and wave-level telemetry (ISSUE 9).

Three layers, threaded through the whole serving stack:

* :mod:`repro.obs.trace` — span tracer over an injected clock, exporting
  Chrome/Perfetto trace JSON (submit/admit/drain/wave spans from
  :mod:`repro.serve`, restore/WAL-replay/mesh-shrink instants from
  :mod:`repro.serve.durable` and ``run_distributed``);
* :mod:`repro.obs.wavetap` — the per-round telemetry stream fed via
  ``jax.experimental.io_callback`` from INSIDE the jitted round loops
  (engine ``_Runner``, ``AT.make_commit_step``, the ``ProductWave``
  chunk bodies): round index, conflicts, commit density, ladder level,
  backend tier, subrounds, messages routed;
* :mod:`repro.obs.metrics` — counters/gauges/log-bucket histograms with
  Prometheus text exposition and an ``aam-metrics/v1`` JSON snapshot
  (:class:`repro.serve.graph_service.ServiceStats` is a view over one).

Everything is OFF by default and provably zero-impact when off: the
taps only enter a jaxpr when ``REPRO_TRACE=1`` or
``CommitSpec(trace=True)`` was set at trace time, and
``python -m repro.analysis.lint --trace-off-clean`` proves the shipped
jaxprs contain no callback primitives otherwise.

``python -m repro.obs.dump`` runs a mixed-tenant continuous-batching
workload and writes the trace + metrics artifacts (the ``make trace``
target).
"""
from repro.obs.trace import (Tracer, get_tracer, set_tracer,  # noqa: F401
                             trace_enabled, validate_trace)
from repro.obs.metrics import (Registry, validate_metrics_json,  # noqa: F401
                               METRICS_SCHEMA)
from repro.obs import wavetap  # noqa: F401
