"""Span tracer + Chrome/Perfetto export.

One :class:`Tracer` holds a flat event list; spans are "X" complete
events (begin/end read the tracer's clock), instants are "i" events
(restore, WAL replay, mesh shrink).  The tracer's clock defaults to
``time.perf_counter`` but a service constructed with an injected clock
binds its tracer to THE SAME clock, so fake-clock tests see
deterministic span timestamps.

Everything is inert unless the tracer is *active*: ``enabled=None``
(the default) follows the ``REPRO_TRACE`` environment variable, so the
zero-impact-when-off guarantee extends to the host side — an inactive
span context manager performs no clock reads and allocates nothing.

``to_chrome()`` exports ``{"traceEvents": [...]}`` (Chrome tracing /
Perfetto JSON, microsecond timestamps); :func:`validate_trace` is the
schema smoke check the lint CLI and tier-1 tests run over every
exported document.
"""
from __future__ import annotations

import contextlib
import os
import threading
import time

TRACE_SCHEMA = "aam-trace/v1"

# tid convention for the one-process serving stack: host-side serving
# spans vs device-side wavetap events render as two named rows
TID_SERVE = 0
TID_DEVICE = 1


def trace_enabled() -> bool:
    """The global toggle: ``REPRO_TRACE`` set to anything but ``0``."""
    return os.environ.get("REPRO_TRACE", "").strip() not in ("", "0")


class Tracer:
    """Collects trace events; thread-safe (the continuous drain loop
    publishes from its own thread while clients submit).

    clock:   0-arg callable returning seconds.  Bind the service's
             injected clock so spans and ``ServiceStats`` timing agree.
    enabled: True/False pins the tracer on/off; None (default) follows
             ``REPRO_TRACE`` at each use site.
    """

    def __init__(self, clock=None, enabled: bool | None = None):
        self.clock = clock if clock is not None else time.perf_counter
        self.enabled = enabled
        self.events: list[dict] = []
        self._lock = threading.Lock()
        # per-thread stacks of open spans (orphan detection)
        self._open: dict[int, list[dict]] = {}

    @property
    def active(self) -> bool:
        return trace_enabled() if self.enabled is None else self.enabled

    # -- recording --------------------------------------------------------

    def begin(self, name: str, *, cat: str = "serve", tid: int = TID_SERVE,
              args: dict | None = None) -> None:
        """Open a span (reads the clock once).  Prefer :meth:`span`."""
        if not self.active:
            return
        ev = {"name": name, "cat": cat, "tid": tid, "ts": self.clock(),
              "args": dict(args or {})}
        with self._lock:
            self._open.setdefault(threading.get_ident(), []).append(ev)

    def end(self, args: dict | None = None) -> None:
        """Close the innermost open span of this thread (one clock
        read); no-op if none is open (e.g. tracing flipped mid-span)."""
        if not self.active:
            return
        now = self.clock()
        with self._lock:
            stack = self._open.get(threading.get_ident())
            if not stack:
                return
            ev = stack.pop()
            ev["ph"] = "X"
            ev["dur"] = max(now - ev["ts"], 0.0)
            if args:
                ev["args"].update(args)
            self.events.append(ev)

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "serve", tid: int = TID_SERVE,
             args: dict | None = None):
        """``with tracer.span("drain"): ...`` — the try/finally
        guarantees a fault inside the span still closes it, so a crash →
        restore run never leaves orphans."""
        if not self.active:
            yield
            return
        self.begin(name, cat=cat, tid=tid, args=args)
        try:
            yield
        finally:
            self.end()

    def complete(self, name: str, ts: float, dur: float, *,
                 cat: str = "serve", tid: int = TID_SERVE,
                 args: dict | None = None) -> None:
        """Record a finished span from timestamps the caller ALREADY
        read — ``GraphService.drain`` reuses its own t0/dt so tracing
        adds zero clock reads there (a fake-clock test pins the exact
        read count)."""
        if not self.active:
            return
        ev = {"name": name, "cat": cat, "tid": tid, "ts": ts,
              "dur": max(dur, 0.0), "ph": "X", "args": dict(args or {})}
        with self._lock:
            self.events.append(ev)

    def instant(self, name: str, *, cat: str = "serve",
                tid: int = TID_SERVE, ts: float | None = None,
                args: dict | None = None) -> None:
        """Record an instant event (restore, WAL replay, mesh shrink)."""
        if not self.active:
            return
        ev = {"name": name, "cat": cat, "tid": tid,
              "ts": self.clock() if ts is None else ts, "ph": "i",
              "args": dict(args or {})}
        with self._lock:
            self.events.append(ev)

    # -- inspection / export ----------------------------------------------

    def open_spans(self) -> list[str]:
        """Names of spans begun but never ended — MUST be empty in a
        well-formed trace (the fault-path test asserts it)."""
        with self._lock:
            return [ev["name"] for stack in self._open.values()
                    for ev in stack]

    def clear(self) -> None:
        with self._lock:
            self.events.clear()
            self._open.clear()

    def to_chrome(self) -> dict:
        """Chrome tracing / Perfetto JSON: seconds -> microseconds."""
        with self._lock:
            events = [dict(e) for e in self.events]
        out = []
        for e in sorted(events, key=lambda e: e["ts"]):
            ev = {"name": e["name"], "cat": e["cat"], "ph": e["ph"],
                  "pid": 1, "tid": e["tid"],
                  "ts": round(e["ts"] * 1e6, 3), "args": e["args"]}
            if e["ph"] == "X":
                ev["dur"] = round(e["dur"] * 1e6, 3)
            else:
                ev["s"] = "p"        # process-scoped instant
            out.append(ev)
        return {"traceEvents": out, "displayTimeUnit": "ms",
                "otherData": {"schema": TRACE_SCHEMA}}


def validate_trace(doc) -> list[str]:
    """Schema smoke check over an exported trace document; returns
    findings (empty = valid).  Run by ``aamlint --trace-off-clean`` and
    the tier-1 tests over every trace this repo emits."""
    findings = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["trace: document has no traceEvents list"]
    events = doc["traceEvents"]
    if not isinstance(events, list):
        return ["trace: traceEvents is not a list"]
    for i, e in enumerate(events):
        missing = {"name", "ph", "ts", "pid", "tid"} - set(e)
        if missing:
            findings.append(f"trace: event {i} missing {sorted(missing)}")
            continue
        if not isinstance(e["ts"], (int, float)):
            findings.append(f"trace: event {i} ts not numeric")
        if e["ph"] == "X":
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                findings.append(
                    f"trace: X event {i} ({e['name']}) bad dur")
        elif e["ph"] == "i":
            if e.get("s") not in ("g", "p", "t"):
                findings.append(
                    f"trace: instant {i} ({e['name']}) bad scope")
        elif e["ph"] not in ("B", "E", "M"):
            findings.append(f"trace: event {i} unknown phase {e['ph']!r}")
    return findings


# -- the process-global tracer ------------------------------------------
# Services share it by default (one continuous-batching run = one
# trace); engine instants (mesh shrink) land here too.  A test injects
# its own Tracer(clock=fake) either via set_tracer or per-service.

_TRACER: Tracer | None = None
_TRACER_LOCK = threading.Lock()


def get_tracer() -> Tracer:
    global _TRACER
    with _TRACER_LOCK:
        if _TRACER is None:
            _TRACER = Tracer()
        return _TRACER


def set_tracer(tracer: Tracer | None) -> None:
    global _TRACER
    with _TRACER_LOCK:
        _TRACER = tracer
