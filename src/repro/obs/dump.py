"""``python -m repro.obs.dump`` — the ``make trace`` demo.

Runs a mixed-tenant continuous-batching workload with tracing forced on
(one hot graph holding several query kinds + a tail of single-query
tenants, the product-axis shape from the PR-7 ISSUE), then writes next
to the repo root:

* ``TRACE_serve.json``   — Chrome/Perfetto trace (open in
  https://ui.perfetto.dev or ``chrome://tracing``): drain/admit/
  product_wave serving spans on the serve row, wavetap commit/round
  events on the device row, submit instants threading them together;
* ``METRICS_serve.prom`` — Prometheus text exposition of the service
  registry (wave/ladder counters + the submit-to-answer latency
  histogram);
* ``METRICS_serve.json`` — the ``aam-metrics/v1`` snapshot.

Both documents are schema-checked before writing — a nonzero exit means
the exporters and validators disagree, which is exactly what the trace
smoke in tier-1 guards against.
"""
from __future__ import annotations

import json
import os
import sys


def main(argv=None) -> int:
    import argparse
    ap = argparse.ArgumentParser(prog="python -m repro.obs.dump")
    ap.add_argument("--out", default="TRACE_serve.json")
    ap.add_argument("--metrics", default="METRICS_serve")
    ap.add_argument("--scale", type=int, default=6,
                    help="graph size exponent for the hot graph")
    args = ap.parse_args(argv)

    os.environ["REPRO_TRACE"] = "1"     # before any service is built
    from repro.graphs.generators import erdos_renyi, kronecker
    from repro.obs import trace as OT
    from repro.obs import wavetap as OW
    from repro.serve.continuous import ContinuousServer
    from repro.serve.graph_service import GraphService
    from repro.serve.queries import BfsQuery, PprQuery, SsspQuery

    tracer = OT.Tracer(enabled=True)
    OT.set_tracer(tracer)
    OW.clear()

    svc = GraphService(tracer=tracer)
    n = 1 << args.scale
    svc.register_graph("hot", kronecker(args.scale, 8, seed=7))
    for i in range(3):
        svc.register_graph(f"t{i}", erdos_renyi(n, 4.0, seed=i))

    queries = [("hot", BfsQuery(s)) for s in range(4)]
    queries += [("hot", SsspQuery(s)) for s in range(2)]
    queries += [(f"t{i}", BfsQuery(i)) for i in range(3)]
    queries += [("hot", PprQuery(0))]

    with ContinuousServer(svc, max_wait_s=0.01, max_batch=8) as cs:
        tickets = [cs.submit(gid, q) for gid, q in queries]
        cs.results(tickets, timeout=60.0)
        # resubmit one — a cache hit shows up as a zero-length drain
        cs.result(cs.submit("hot", BfsQuery(0)), timeout=60.0)

    OW.flush_to(tracer)
    doc = tracer.to_chrome()
    findings = OT.validate_trace(doc)
    reg = svc.stats.registry
    snap = reg.snapshot()
    from repro.obs.metrics import validate_metrics_json
    findings += validate_metrics_json(snap)
    if tracer.open_spans():
        findings.append(f"orphan spans: {tracer.open_spans()}")
    if findings:
        for f in findings:
            print(f"TRACE FINDING: {f}", file=sys.stderr)
        return 1

    with open(args.out, "w") as f:
        json.dump(doc, f)
    with open(args.metrics + ".prom", "w") as f:
        f.write(reg.prometheus_text())
    with open(args.metrics + ".json", "w") as f:
        json.dump(snap, f, indent=1)
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    insts = [e for e in doc["traceEvents"] if e["ph"] == "i"]
    lat = reg.histogram("aam_submit_to_answer_seconds")
    print(f"{args.out}: {len(spans)} spans, {len(insts)} instants "
          f"(open in https://ui.perfetto.dev)")
    print(f"{args.metrics}.prom / .json: "
          f"{len(snap['counters'])} counters, "
          f"latency p50={lat.quantile(0.5) * 1e3:.3g}ms "
          f"p99={lat.quantile(0.99) * 1e3:.3g}ms over {lat.count} queries")
    return 0


if __name__ == "__main__":
    sys.exit(main())
