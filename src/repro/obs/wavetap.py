"""Per-round wave telemetry fed from INSIDE jitted round loops.

The paper's adaptive story runs on signals that only exist device-side
mid-loop: per-round conflicts, commit density, the ladder level M.
``wavetap`` streams them to the host with
``jax.experimental.io_callback``:

* :func:`tap_commit_step` wraps the ``step`` returned by
  ``repro.core.autotune.make_commit_step`` — one ordered callback per
  commit (all six single-shard loops and the ``ProductWave`` chunk
  bodies route through that one hook);
* :func:`round_recorder` is the engine ``_Runner`` tap — one unordered
  callback per round per shard (unordered: multi-device shard_map must
  not serialize on the host; the round index rides in the payload).

Records accumulate in a process-global :class:`Collector`;
:func:`flush_to` converts them into Chrome trace events on the device
tid (span duration = gap to the previous record in the same stream —
the host-side arrival cadence, which is what a round boundary costs),
and :func:`summary` reduces them to the per-row bench fields
(rounds, mean commit density, ladder moves).

The tap only enters a jaxpr when tracing was enabled AT TRACE TIME
(``CommitSpec(trace=True)`` or ``REPRO_TRACE=1``) — with tracing off
the wrapped step is returned untouched, and
``aamlint --trace-off-clean`` proves the shipped jaxprs are clean.
"""
from __future__ import annotations

import threading
import time

import jax.numpy as jnp
from jax.experimental import io_callback

from repro.obs import trace as _trace


class Collector:
    """Append-only record sink (io_callback may fire from runtime
    threads; everything is lock-guarded)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._records: list[dict] = []

    def add(self, rec: dict) -> None:
        with self._lock:
            self._records.append(rec)

    def records(self) -> list[dict]:
        with self._lock:
            return list(self._records)

    def drain(self) -> list[dict]:
        with self._lock:
            out, self._records = self._records, []
            return out

    def clear(self) -> None:
        with self._lock:
            self._records.clear()


_COLLECTOR = Collector()


def collector() -> Collector:
    return _COLLECTOR


def records() -> list[dict]:
    return _COLLECTOR.records()


def clear() -> None:
    _COLLECTOR.clear()


# -- device-side taps ---------------------------------------------------


def commit_recorder(label: str, op: str, backend: str):
    """Host callback for one commit stream."""
    def cb(conflicts, applied, messages, level):
        _COLLECTOR.add({
            "kind": "commit", "label": label, "op": op,
            "backend": backend, "t": time.perf_counter(),
            "conflicts": int(conflicts), "applied": int(applied),
            "messages": int(messages), "level": int(level)})
    return cb


def round_recorder(label: str):
    """Host callback for the engine's per-round stream."""
    def cb(it, conflicts, subrounds, messages, level, shard):
        _COLLECTOR.add({
            "kind": "round", "label": label, "t": time.perf_counter(),
            "round": int(it), "conflicts": int(conflicts),
            "subrounds": int(subrounds), "messages": int(messages),
            "level": int(level), "shard": int(shard)})
    return cb


def tap_commit_step(step, *, label: str, op: str, backend: str):
    """Wrap a ``make_commit_step`` step with the commit tap.

    Ordered: the single-shard loops run one commit stream, and ordering
    keeps the ladder-level sequence faithful."""
    cb = commit_recorder(label, op, backend)

    def traced_step(state, msgs, level):
        res, lvl = step(state, msgs, level)
        io_callback(cb, None, res.conflicts, res.applied,
                    jnp.sum(msgs.valid.astype(jnp.int32)), lvl,
                    ordered=True)
        return res, lvl

    return traced_step


# -- host-side reductions -----------------------------------------------


def summary(recs: list[dict] | None = None) -> dict:
    """Reduce records to the bench-row trace fields.

    rounds:       engine round records (shard 0) if any, else the
                  number of commits (one commit per round in the
                  single-shard loops);
    mean_density: mean conflicts/messages over commit+round records
                  with routed messages;
    ladder_moves: level changes between consecutive records of the
                  same stream (label);
    commits:      commit records seen.
    """
    recs = _COLLECTOR.records() if recs is None else recs
    rounds = sum(1 for r in recs
                 if r["kind"] == "round" and r.get("shard", 0) == 0)
    commits = sum(1 for r in recs if r["kind"] == "commit")
    dens = [r["conflicts"] / r["messages"] for r in recs
            if r.get("messages", 0) > 0]
    moves, last = 0, {}
    for r in recs:
        key = (r["kind"], r["label"])
        if key in last and r["level"] != last[key]:
            moves += 1
        last[key] = r["level"]
    return {"rounds": rounds if rounds else commits,
            "commits": commits,
            "mean_density": round(sum(dens) / len(dens), 4) if dens
            else 0.0,
            "ladder_moves": moves}


def flush_to(tracer, tid: int = _trace.TID_DEVICE) -> int:
    """Drain the collector into ``tracer`` as device-tid trace events;
    returns the number of records flushed.  Round/commit spans get
    ``dur`` = host gap since the previous record of their stream (first
    record of a stream renders as a zero-width span)."""
    recs = _COLLECTOR.drain()
    if not tracer.active:
        return len(recs)
    prev: dict[tuple, float] = {}
    for r in recs:
        key = (r["kind"], r["label"])
        t = r["t"]
        t0 = prev.get(key, t)
        prev[key] = t
        args = {k: v for k, v in r.items()
                if k not in ("kind", "label", "t")}
        name = (f"round[{r['label']}]" if r["kind"] == "round"
                else f"commit[{r['label']}]")
        tracer.complete(name, t0, t - t0, cat=r["kind"], tid=tid,
                        args=args)
    return len(recs)
